#!/usr/bin/env bash
# Tier-1 verification for the repo (referenced from ROADMAP.md):
#
#   scripts/ci.sh            build + test + style
#   scripts/ci.sh --fast     skip the style pass
#
# Runs: cargo build --release, cargo test -q, and cargo fmt --check
# (falling back to cargo clippy when rustfmt is unavailable offline).
# Python kernel tests run too when pytest is present.
set -euo pipefail
cd "$(dirname "$0")/.."

fast=0
[ "${1:-}" = "--fast" ] && fast=1

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: FATAL: no cargo in PATH — the Rust tier-1 suite cannot run." >&2
    echo "ci.sh: install a Rust toolchain (>= 1.70) or run inside the build image." >&2
    exit 1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [ "$fast" -eq 0 ]; then
    if cargo fmt --version >/dev/null 2>&1; then
        echo "== cargo fmt --check =="
        cargo fmt --check
    elif cargo clippy --version >/dev/null 2>&1; then
        echo "== cargo clippy (fmt unavailable) =="
        cargo clippy --release -- -D warnings
    else
        echo "== style pass skipped (neither rustfmt nor clippy available offline) =="
    fi
fi

if command -v pytest >/dev/null 2>&1; then
    echo "== pytest python/tests =="
    pytest -q python/tests || {
        echo "ci.sh: python kernel tests failed (jax/pallas image required)" >&2
        exit 1
    }
else
    echo "== pytest unavailable; python kernel tests skipped =="
fi

echo "ci.sh: OK"
