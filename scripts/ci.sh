#!/usr/bin/env bash
# Tier-1 verification for the repo (referenced from ROADMAP.md):
#
#   scripts/ci.sh            build + test + lint + style + benches/examples compile
#   scripts/ci.sh --fast     skip the style pass
#   scripts/ci.sh --lint-only  run only `sfw lint` (the repo-native
#                            static-analysis pass: panic-freedom in the
#                            protocol hot modules, SAFETY comments, wire
#                            round-trip coverage, lock-across-IO, error
#                            variant liveness; writes
#                            bench_out/lint_report.json) and exit
#   scripts/ci.sh --smoke    additionally run the deterministic smoke sweep
#                            (writes bench_out/sweep_smoke.json; the grid
#                            includes one flaky-net chaos cell per
#                            TCP-capable solver, the dense-vs-factored
#                            scale cells and the f32-vs-int8 uplink cells,
#                            and the artifact check asserts nonzero
#                            injected-event counts, the factored-downlink
#                            saving and the >= 3x compressed-uplink saving)
#   scripts/ci.sh --bench    additionally run the hotpath microbenchmarks
#                            and write bench_out/BENCH_hotpath.json (the
#                            perf trajectory; scripts/bench_snapshot.py).
#                            First self-tests the blocking regression gate
#                            (scripts/test_bench_gate.py); when a previous
#                            snapshot exists at bench_out/bench_prev.json,
#                            the snapshot runs as a BLOCKING compare
#                            against it (per-op thresholds from
#                            scripts/bench_thresholds.json; an expected
#                            slowdown ships with [skip-bench-gate] in the
#                            commit message, which skips the compare in
#                            the CI workflow)
#
# Runs: cargo build --release, cargo test -q, cargo bench --no-run and
# cargo build --examples (so benches/examples can't silently rot), then
# the style pass — cargo fmt --check AND cargo clippy when both are
# installed, whichever subset exists otherwise.  Python kernel tests run
# too when pytest is present.
set -euo pipefail
cd "$(dirname "$0")/.."

fast=0
smoke=0
bench=0
lint_only=0
for arg in "$@"; do
    case "$arg" in
        --fast) fast=1 ;;
        --smoke) smoke=1 ;;
        --bench) bench=1 ;;
        --lint-only) lint_only=1 ;;
        *)
            echo "ci.sh: unknown flag '$arg' (known: --fast --smoke --bench --lint-only)" >&2
            exit 2
            ;;
    esac
done

if ! command -v cargo >/dev/null 2>&1; then
    echo "ci.sh: FATAL: no cargo in PATH — the Rust tier-1 suite cannot run." >&2
    echo "ci.sh: install a Rust toolchain (>= 1.70) or run inside the build image." >&2
    exit 1
fi

if [ "$lint_only" -eq 1 ]; then
    echo "== sfw lint (static-analysis pass only) =="
    cargo run --release -- lint
    echo "ci.sh: OK (lint only)"
    exit 0
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== sfw lint (repo-native static analysis) =="
cargo run --release -- lint

echo "== cargo bench --no-run (benches must keep compiling) =="
cargo bench --no-run

echo "== cargo build --examples (examples must keep compiling) =="
cargo build --examples

if [ "$fast" -eq 0 ]; then
    ran_style=0
    if cargo fmt --version >/dev/null 2>&1; then
        echo "== cargo fmt --check =="
        cargo fmt --check
        ran_style=1
    fi
    if cargo clippy --version >/dev/null 2>&1; then
        echo "== cargo clippy =="
        cargo clippy --release --all-targets -- -D warnings
        ran_style=1
    fi
    if [ "$ran_style" -eq 0 ]; then
        echo "== style pass skipped (neither rustfmt nor clippy available offline) =="
    fi
fi

if command -v pytest >/dev/null 2>&1; then
    echo "== pytest python/tests =="
    pytest -q python/tests || {
        echo "ci.sh: python kernel tests failed (jax/pallas image required)" >&2
        exit 1
    }
else
    echo "== pytest unavailable; python kernel tests skipped =="
fi

if [ "$smoke" -eq 1 ]; then
    echo "== smoke sweep (sfw sweep --smoke) =="
    cargo run --release -- sweep --smoke
    test -s bench_out/sweep_smoke.json || {
        echo "ci.sh: smoke sweep did not write bench_out/sweep_smoke.json" >&2
        exit 1
    }
    if command -v python3 >/dev/null 2>&1; then
        python3 scripts/check_smoke_bytes.py bench_out/sweep_smoke.json
    else
        echo "ci.sh: python3 unavailable; skipping smoke-artifact byte check"
    fi
    echo "ci.sh: smoke artifact at bench_out/sweep_smoke.json"
fi

if [ "$bench" -eq 1 ]; then
    echo "== hotpath bench snapshot (scripts/bench_snapshot.py) =="
    if command -v python3 >/dev/null 2>&1; then
        echo "== bench gate self-test (scripts/test_bench_gate.py) =="
        python3 scripts/test_bench_gate.py
        if [ -s bench_out/bench_prev.json ]; then
            echo "== bench snapshot + BLOCKING compare vs bench_out/bench_prev.json =="
            python3 scripts/bench_snapshot.py --compare bench_out/bench_prev.json
        else
            echo "== bench snapshot (no bench_out/bench_prev.json baseline; compare skipped) =="
            python3 scripts/bench_snapshot.py
        fi
        test -s bench_out/BENCH_hotpath.json || {
            echo "ci.sh: bench snapshot did not write bench_out/BENCH_hotpath.json" >&2
            exit 1
        }
    else
        echo "ci.sh: python3 unavailable; running the bench without the JSON snapshot"
        cargo bench --bench hotpath
    fi
fi

echo "ci.sh: OK"
