#!/usr/bin/env python3
"""Run the hotpath microbenchmarks and snapshot them as BENCH_hotpath.json.

Usage:
    scripts/bench_snapshot.py [--out bench_out/BENCH_hotpath.json] [--skip-run]
                              [--compare prev.json] [--threshold 1.25]
                              [--thresholds scripts/bench_thresholds.json]

Runs `cargo bench --bench hotpath` (which writes the machine-readable
series to bench_out/hotpath_raw.csv), converts it to a stable JSON
document (schema `sfw.bench/v1`), and asserts the dense-vs-factored
cells are present — the perf trajectory the ROADMAP's "make hot paths
measurably faster" goal is tracked against.  `--skip-run` converts an
existing hotpath_raw.csv (used by tests and by CI steps that already ran
the bench).

`--compare prev.json` additionally diffs the fresh snapshot against a
previous one (matching rows by op name): prints the mean-time ratio per
op and **exits nonzero when any op slowed past its threshold** — this is
the BLOCKING bench gate CI runs on every push.  Thresholds come from the
per-op table `scripts/bench_thresholds.json` ({"default": R, "ops":
{name: R}}; `--thresholds` overrides the path); `--threshold` overrides
the table's default ratio.  A genuinely expected slowdown lands by
putting `[skip-bench-gate]` in the commit message, which makes the CI
workflow skip the compare step (see .github/workflows/ci.yml) — the
next push rebuilds the baseline.  scripts/test_bench_gate.py self-tests
the gate on synthetic regressions.
"""
import csv
import json
import os
import subprocess
import sys

out_path = "bench_out/BENCH_hotpath.json"
skip_run = False
compare_path = None
threshold = None  # CLI override of the threshold table's default
thresholds_path = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "bench_thresholds.json")
args = sys.argv[1:]
while args:
    a = args.pop(0)
    if a == "--out":
        out_path = args.pop(0)
    elif a == "--skip-run":
        skip_run = True
    elif a == "--compare":
        compare_path = args.pop(0)
    elif a == "--threshold":
        threshold = float(args.pop(0))
    elif a == "--thresholds":
        thresholds_path = args.pop(0)
    else:
        sys.exit(f"bench_snapshot.py: unknown arg '{a}' "
                 "(known: --out, --skip-run, --compare, --threshold, --thresholds)")

raw_path = "bench_out/hotpath_raw.csv"
if not skip_run:
    subprocess.run(["cargo", "bench", "--bench", "hotpath"], check=True)

if not os.path.exists(raw_path):
    sys.exit(f"bench_snapshot.py: {raw_path} missing (bench did not run?)")

rows = []
with open(raw_path, newline="") as f:
    for rec in csv.DictReader(f):
        rows.append({
            "op": rec["op"],
            "mean_s": float(rec["mean_s"]),
            "p50_s": float(rec["p50_s"]),
            "p90_s": float(rec["p90_s"]),
            "notes": rec["notes"],
        })

assert rows, f"{raw_path}: no benchmark rows"
ops = [r["op"] for r in rows]
for needed in ("lmo 196x196 dense operator",
               "lmo 196x196 factored operator k=64",
               "pnn grad m=256 factored k=16"):
    assert needed in ops, f"hotpath bench lost its '{needed}' cell (have: {ops})"

doc = {
    "schema": "sfw.bench/v1",
    "bench": "hotpath",
    "rows": rows,
}
# Environment sidecar written by the bench alongside the raw CSV: CPU
# feature dispatch + kernel pool size.  Embedded so --compare can tell
# whether two snapshots came from the same class of machine.
env_path = "bench_out/hotpath_env.json"
if os.path.exists(env_path):
    with open(env_path) as f:
        doc["env"] = json.load(f)
os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
with open(out_path, "w") as f:
    json.dump(doc, f, indent=1, sort_keys=True)
    f.write("\n")
print(f"OK: {len(rows)} hotpath rows -> {out_path}")

if compare_path:
    with open(compare_path) as f:
        prev = json.load(f)
    prev_means = {r["op"]: r["mean_s"] for r in prev.get("rows", [])}
    # Ratios across different CPU feature sets (e.g. an AVX2 laptop vs a
    # scalar-dispatch CI box) measure the machines, not the code: print
    # the table for the record but do NOT judge the gate on it.
    cur_env, prev_env = doc.get("env"), prev.get("env")
    env_mismatch = (cur_env is not None and prev_env is not None
                    and cur_env.get("cpu_features") != prev_env.get("cpu_features"))
    if env_mismatch:
        print(f"\nWARNING: environment mismatch — current snapshot ran with "
              f"cpu_features={cur_env.get('cpu_features')!r}, baseline with "
              f"{prev_env.get('cpu_features')!r}; ratios below are "
              "informational and the gate is NOT judged")
    table = {"default": 1.25, "ops": {}}
    if os.path.exists(thresholds_path):
        with open(thresholds_path) as f:
            table = json.load(f)
    default_limit = threshold if threshold is not None else float(
        table.get("default", 1.25))
    per_op = {op: float(v) for op, v in table.get("ops", {}).items()}
    regressions = []
    print(f"\ncompare vs {compare_path} "
          f"(default threshold {default_limit:.2f}x, "
          f"{len(per_op)} per-op override(s) from {thresholds_path}):")
    for r in rows:
        base = prev_means.get(r["op"])
        if base is None:
            print(f"  {r['op']:<42} NEW (no previous row)")
            continue
        limit = per_op.get(r["op"], default_limit)
        ratio = r["mean_s"] / base if base > 0 else float("inf")
        marker = ""
        if ratio > limit:
            marker = f"  <-- REGRESSION (limit {limit:.2f}x)"
            regressions.append((r["op"], ratio, limit))
        print(f"  {r['op']:<42} {base:.3e}s -> {r['mean_s']:.3e}s "
              f"({ratio:.2f}x){marker}")
    for op in prev_means:
        if op not in {r["op"] for r in rows}:
            print(f"  {op:<42} DROPPED (no current row)")
    if env_mismatch:
        print("compare: environment mismatch — gate not judged "
              f"({len(regressions)} op(s) would have flagged)")
    elif regressions:
        names = ", ".join(f"{op} ({ratio:.2f}x > {limit:.2f}x)"
                          for op, ratio, limit in regressions)
        sys.exit(f"bench_snapshot.py: {len(regressions)} op(s) slowed past "
                 f"their threshold: {names}\n"
                 "(this gate is blocking; an expected slowdown lands with "
                 "[skip-bench-gate] in the commit message, which skips the "
                 "compare step in CI)")
    else:
        print("compare: no regressions past threshold (gate passed)")
