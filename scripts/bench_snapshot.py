#!/usr/bin/env python3
"""Run the hotpath microbenchmarks and snapshot them as BENCH_hotpath.json.

Usage:
    scripts/bench_snapshot.py [--out bench_out/BENCH_hotpath.json] [--skip-run]

Runs `cargo bench --bench hotpath` (which writes the machine-readable
series to bench_out/hotpath_raw.csv), converts it to a stable JSON
document (schema `sfw.bench/v1`), and asserts the dense-vs-factored
cells are present — the perf trajectory the ROADMAP's "make hot paths
measurably faster" goal is tracked against.  `--skip-run` converts an
existing hotpath_raw.csv (used by tests and by CI steps that already ran
the bench).
"""
import csv
import json
import os
import subprocess
import sys

out_path = "bench_out/BENCH_hotpath.json"
skip_run = False
args = sys.argv[1:]
while args:
    a = args.pop(0)
    if a == "--out":
        out_path = args.pop(0)
    elif a == "--skip-run":
        skip_run = True
    else:
        sys.exit(f"bench_snapshot.py: unknown arg '{a}' (known: --out, --skip-run)")

raw_path = "bench_out/hotpath_raw.csv"
if not skip_run:
    subprocess.run(["cargo", "bench", "--bench", "hotpath"], check=True)

if not os.path.exists(raw_path):
    sys.exit(f"bench_snapshot.py: {raw_path} missing (bench did not run?)")

rows = []
with open(raw_path, newline="") as f:
    for rec in csv.DictReader(f):
        rows.append({
            "op": rec["op"],
            "mean_s": float(rec["mean_s"]),
            "p50_s": float(rec["p50_s"]),
            "p90_s": float(rec["p90_s"]),
            "notes": rec["notes"],
        })

assert rows, f"{raw_path}: no benchmark rows"
ops = [r["op"] for r in rows]
for needed in ("lmo 196x196 dense operator",
               "lmo 196x196 factored operator k=64",
               "pnn grad m=256 factored k=16"):
    assert needed in ops, f"hotpath bench lost its '{needed}' cell (have: {ops})"

doc = {
    "schema": "sfw.bench/v1",
    "bench": "hotpath",
    "rows": rows,
}
os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
with open(out_path, "w") as f:
    json.dump(doc, f, indent=1, sort_keys=True)
    f.write("\n")
print(f"OK: {len(rows)} hotpath rows -> {out_path}")
