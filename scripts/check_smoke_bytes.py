#!/usr/bin/env python3
"""Assert the smoke-sweep artifact accounts comm bytes in every cell,
injected chaos events in every chaos cell, and the factored-downlink
saving on the scale cells.

Shared by scripts/ci.sh --smoke and .github/workflows/ci.yml so the
check cannot drift between the two.  Every smoke cell is a distributed
run, so zero bytes_up/bytes_down means the transport accounting broke;
every `chaos=flaky-net` cell runs under fault injection, so zero
injected events means the chaos layer silently stopped wrapping links;
and the sfw-dist scale cells (one dense, one factored, same seed/shape)
pin the representation's headline saving: the factored atoms-only
broadcast must be measurably below the dense X broadcast on
`bytes_down` while the (dense-gradient) uplink stays equal.
"""
import json
import sys

path = sys.argv[1] if len(sys.argv) > 1 else "bench_out/sweep_smoke.json"
cells = json.load(open(path))["cells"]
assert cells, f"{path}: smoke artifact has no cells"
bad = [c["axes"] for c in cells
       if c["counters"]["bytes_up"] <= 0 or c["counters"]["bytes_down"] <= 0]
assert not bad, f"cells without comm bytes: {bad}"

chaos_cells = [c for c in cells if c["axes"].get("chaos") == "flaky-net"]
assert chaos_cells, f"{path}: smoke grid lost its flaky-net chaos cells"
quiet = [c["axes"] for c in chaos_cells if sum(c["chaos"].values()) <= 0]
assert not quiet, f"chaos cells without injected events: {quiet}"
clean_noisy = [c["axes"] for c in cells
               if c["axes"].get("chaos") == "none" and sum(c["chaos"].values()) > 0]
assert not clean_noisy, f"clean cells with injected events: {clean_noisy}"

# --- factored-downlink scale cells -----------------------------------------
scale = [c for c in cells
         if c["axes"].get("algo") == "sfw-dist" and c["axes"].get("dims") == "48x32"]
by_repr = {c["axes"].get("repr"): c for c in scale}
assert "dense" in by_repr and "factored" in by_repr, (
    f"{path}: smoke grid lost its dense/factored scale cells (have "
    f"{sorted(by_repr)})")
dense, fact = by_repr["dense"], by_repr["factored"]
dd, fd = dense["counters"]["bytes_down"], fact["counters"]["bytes_down"]
assert fd * 4 < dd, (
    f"factored downlink {fd} B not measurably below dense {dd} B")
assert fact["counters"]["bytes_up"] == dense["counters"]["bytes_up"], (
    "uplink should be identical (dense gradients both ways)")
assert fact.get("rank", 0) > 0 and fact.get("peak_atoms", 0) > 0, (
    "factored scale cell lost its rank/peak_atoms accounting")

print(f"OK: {len(cells)} cells in {path}, bytes nonzero in all, "
      f"events nonzero in {len(chaos_cells)} chaos cell(s), "
      f"factored downlink {fd} B vs dense {dd} B")
