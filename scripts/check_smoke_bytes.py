#!/usr/bin/env python3
"""Assert the smoke-sweep artifact accounts comm bytes in every cell and
injected chaos events in every chaos cell.

Shared by scripts/ci.sh --smoke and .github/workflows/ci.yml so the
check cannot drift between the two.  Every smoke cell is a distributed
run, so zero bytes_up/bytes_down means the transport accounting broke;
every `chaos=flaky-net` cell runs under fault injection, so zero
injected events means the chaos layer silently stopped wrapping links.
"""
import json
import sys

path = sys.argv[1] if len(sys.argv) > 1 else "bench_out/sweep_smoke.json"
cells = json.load(open(path))["cells"]
assert cells, f"{path}: smoke artifact has no cells"
bad = [c["axes"] for c in cells
       if c["counters"]["bytes_up"] <= 0 or c["counters"]["bytes_down"] <= 0]
assert not bad, f"cells without comm bytes: {bad}"

chaos_cells = [c for c in cells if c["axes"].get("chaos") == "flaky-net"]
assert chaos_cells, f"{path}: smoke grid lost its flaky-net chaos cells"
quiet = [c["axes"] for c in chaos_cells if sum(c["chaos"].values()) <= 0]
assert not quiet, f"chaos cells without injected events: {quiet}"
clean_noisy = [c["axes"] for c in cells
               if c["axes"].get("chaos") == "none" and sum(c["chaos"].values()) > 0]
assert not clean_noisy, f"clean cells with injected events: {clean_noisy}"

print(f"OK: {len(cells)} cells in {path}, bytes nonzero in all, "
      f"events nonzero in {len(chaos_cells)} chaos cell(s)")
