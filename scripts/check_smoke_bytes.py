#!/usr/bin/env python3
"""Assert the smoke-sweep artifact accounts comm bytes in every cell.

Shared by scripts/ci.sh --smoke and .github/workflows/ci.yml so the
check cannot drift between the two.  Every smoke cell is a distributed
run, so zero bytes_up/bytes_down means the transport accounting broke.
"""
import json
import sys

path = sys.argv[1] if len(sys.argv) > 1 else "bench_out/sweep_smoke.json"
cells = json.load(open(path))["cells"]
assert cells, f"{path}: smoke artifact has no cells"
bad = [c["axes"] for c in cells
       if c["counters"]["bytes_up"] <= 0 or c["counters"]["bytes_down"] <= 0]
assert not bad, f"cells without comm bytes: {bad}"
print(f"OK: {len(cells)} cells in {path}, bytes_up/bytes_down nonzero in all")
