#!/usr/bin/env python3
"""Assert the smoke-sweep artifact accounts comm bytes in every cell,
injected chaos events in every chaos cell, the factored-downlink saving
on the scale cells, and the compressed-uplink saving on the codec cells.

Shared by scripts/ci.sh --smoke and .github/workflows/ci.yml so the
check cannot drift between the two.  Every smoke cell is a distributed
run, so zero bytes_up/bytes_down means the transport accounting broke;
every `chaos=flaky-net` cell runs under fault injection, so zero
injected events means the chaos layer silently stopped wrapping links;
the sfw-dist scale cells (one dense, one factored, same seed/shape)
pin the representation's headline saving: the factored atoms-only
broadcast must be measurably below the dense X broadcast on
`bytes_down` while the (dense-gradient) uplink stays equal; the 64x48
sfw-dist uplink cells (f32 vs int8, same seed/shape, both transports)
pin the codec's headline saving: >= 3x fewer `bytes_up` (the exact
frame ratio at 64x48 is ~3.67x) at matching final relative loss —
error feedback is what keeps the losses together — with identical
`bytes_down`; and the serial sfw gap cells (tol=0 vs tol=1000, same
seed/shape) pin dual-gap surfacing and `--tol` stopping: the tol=0
cell carries a finite, net-decreasing `gaps` column over its full
budget while the tol=1000 cell stops well short of it; and the 56x40
sfw-asyn threads cells (threads=1 vs threads=4, same seed/shape) pin
the linalg::kernels determinism contract: thread count is a pure
wall-clock knob, so the twins must report EXACTLY equal bytes_up,
bytes_down, and final relative loss.
"""
import json
import math
import sys

path = sys.argv[1] if len(sys.argv) > 1 else "bench_out/sweep_smoke.json"
cells = json.load(open(path))["cells"]
assert cells, f"{path}: smoke artifact has no cells"
# The gap cells run the serial solver (no transport), so the comm-bytes
# invariant covers every *distributed* cell, not literally all of them.
dist = [c for c in cells if c["axes"].get("algo") != "sfw"]
assert dist, f"{path}: smoke artifact lost its distributed cells"
bad = [c["axes"] for c in dist
       if c["counters"]["bytes_up"] <= 0 or c["counters"]["bytes_down"] <= 0]
assert not bad, f"cells without comm bytes: {bad}"

chaos_cells = [c for c in cells if c["axes"].get("chaos") == "flaky-net"]
assert chaos_cells, f"{path}: smoke grid lost its flaky-net chaos cells"
quiet = [c["axes"] for c in chaos_cells if sum(c["chaos"].values()) <= 0]
assert not quiet, f"chaos cells without injected events: {quiet}"
clean_noisy = [c["axes"] for c in cells
               if c["axes"].get("chaos") == "none" and sum(c["chaos"].values()) > 0]
assert not clean_noisy, f"clean cells with injected events: {clean_noisy}"

# --- factored-downlink scale cells -----------------------------------------
scale = [c for c in cells
         if c["axes"].get("algo") == "sfw-dist" and c["axes"].get("dims") == "48x32"]
by_repr = {c["axes"].get("repr"): c for c in scale}
assert "dense" in by_repr and "factored" in by_repr, (
    f"{path}: smoke grid lost its dense/factored scale cells (have "
    f"{sorted(by_repr)})")
dense, fact = by_repr["dense"], by_repr["factored"]
dd, fd = dense["counters"]["bytes_down"], fact["counters"]["bytes_down"]
assert fd * 4 < dd, (
    f"factored downlink {fd} B not measurably below dense {dd} B")
assert fact["counters"]["bytes_up"] == dense["counters"]["bytes_up"], (
    "uplink should be identical (dense gradients both ways)")
assert fact.get("rank", 0) > 0 and fact.get("peak_atoms", 0) > 0, (
    "factored scale cell lost its rank/peak_atoms accounting")

# --- compressed-uplink codec cells -----------------------------------------
# f32 vs int8 sfw-dist at 64x48, same seed, one pair per transport.  The
# int8 frame at 64x48 is (header + 4*64 + 64*48) vs f32's (header +
# 4*64*48): a 3.67x ratio, asserted conservatively at 3x.  Error
# feedback must keep the quantized run's convergence with the exact
# run's: final relative losses agree within UPLINK_REL_TOL (both runs
# reach ~0.1-0.3 rel loss in 20 iterations, so 0.15 absolute slack
# flags a genuinely diverged run, not quantization noise).
UPLINK_REL_TOL = 0.15
uplink = [c for c in cells
          if c["axes"].get("algo") == "sfw-dist" and c["axes"].get("dims") == "64x48"]
pairs = 0
for transport in ("local", "tcp"):
    by_codec = {c["axes"].get("uplink"): c for c in uplink
                if c["axes"].get("transport") == transport}
    assert "f32" in by_codec and "int8" in by_codec, (
        f"{path}: smoke grid lost its f32/int8 uplink cells on {transport} "
        f"(have {sorted(by_codec)})")
    f32c, i8c = by_codec["f32"], by_codec["int8"]
    f32_up = f32c["counters"]["bytes_up"]
    i8_up = i8c["counters"]["bytes_up"]
    assert i8_up * 3 <= f32_up, (
        f"{transport}: int8 uplink {i8_up} B not >= 3x below f32 {f32_up} B")
    assert i8c["counters"]["bytes_down"] == f32c["counters"]["bytes_down"], (
        f"{transport}: downlink must be codec-independent "
        f"({i8c['counters']['bytes_down']} vs {f32c['counters']['bytes_down']} B)")
    f32_rel, i8_rel = f32c["final_rel"], i8c["final_rel"]
    assert f32_rel is not None and i8_rel is not None, (
        f"{transport}: uplink cells lost their final_rel accounting")
    assert abs(i8_rel - f32_rel) <= UPLINK_REL_TOL, (
        f"{transport}: int8 final_rel {i8_rel:.4f} diverged from "
        f"f32 {f32_rel:.4f} (tol {UPLINK_REL_TOL}) — error feedback broke?")
    pairs += 1

# --- sparse-completion cells -------------------------------------------------
# Factored sfw-asyn on the 96x48 synthetic recommender, W in {1,2}.  The
# sparse path must produce a real low-rank iterate (nonzero rank and
# atom counts) and its uplink must stay atom-scale: each worker->master
# message carries one rank-one atom, O(rows + cols) floats, never a
# dense 96x48 gradient.  4x slack over one (u, v) pair still sits ~8x
# below the dense frame, so a silent densification trips the assert.
sparse = [c for c in cells if c["axes"].get("objective") == "sparse_completion"]
assert len(sparse) >= 2, (
    f"{path}: smoke grid lost its sparse_completion cells (have {len(sparse)})")
for c in sparse:
    rows, cols = (int(d) for d in c["axes"]["dims"].split("x"))
    assert c["axes"].get("repr") == "factored", f"sparse cell not factored: {c['axes']}"
    assert c.get("rank", 0) > 0 and c.get("peak_atoms", 0) > 0, (
        f"sparse cell lost its rank/peak_atoms accounting: {c['axes']}")
    up, msgs = c["counters"]["bytes_up"], c["counters"]["msgs_up"]
    assert msgs > 0, f"sparse cell sent no uplink messages: {c['axes']}"
    per_msg = up / msgs
    atom_scale = 4 * (rows + cols) * 4
    assert per_msg <= atom_scale, (
        f"sparse uplink {per_msg:.0f} B/msg exceeds atom scale {atom_scale} B "
        f"(dense frame would be {4 * rows * cols} B): {c['axes']}")

# --- dual-gap stopping cells -------------------------------------------------
# Serial sfw pair on ms_small, tol in {0, 1000}, same seed/budget.  The
# tol=0 cell (gap stopping disabled) must run its full 20-iteration
# budget and carry the gap column: a finite final `gap`, a `gaps` array
# aligned with `curve`, and a net decrease across its finite entries —
# the FW dual gap <grad F(X), X - S> is the paper's certificate and the
# quantity `--tol` stops on, so a gap column that vanished, went
# non-finite, or trends upward means the surfacing broke.  The tol=1000
# cell sets the tolerance far above the initial gap, so it must stop
# strictly short of the budget — the early-stop path, pinned end to end
# in the artifact.  Non-finite gaps arrive as JSON null (-> None).
GAP_BUDGET = 20


def finite(g):
    return isinstance(g, (int, float)) and math.isfinite(g)


gap_cells = [c for c in cells if c["axes"].get("algo") == "sfw"]
by_tol = {c["axes"].get("tol"): c for c in gap_cells}
assert "0" in by_tol and "1000" in by_tol, (
    f"{path}: smoke grid lost its tol=0/tol=1000 gap cells "
    f"(have {sorted(by_tol)})")
full, stopped = by_tol["0"], by_tol["1000"]
assert full["counters"]["iterations"] >= GAP_BUDGET, (
    f"tol=0 cell stopped early ({full['counters']['iterations']} < "
    f"{GAP_BUDGET} iterations) with gap stopping disabled")
assert len(full.get("gaps", [])) == len(full["curve"]), (
    f"tol=0 gaps column ({len(full.get('gaps', []))}) not aligned with "
    f"curve ({len(full['curve'])})")
assert finite(full.get("gap")), (
    f"tol=0 cell has no finite final gap (got {full.get('gap')})")
fgaps = [g for g in full["gaps"] if finite(g)]
assert fgaps, "tol=0 cell has no finite gap entries"
assert fgaps[-1] < fgaps[0], (
    f"tol=0 gap column not net-decreasing: first {fgaps[0]:.4e} -> "
    f"last {fgaps[-1]:.4e}")
assert stopped["counters"]["iterations"] < GAP_BUDGET, (
    f"tol=1000 cell ran its full budget "
    f"({stopped['counters']['iterations']} iterations) — --tol never fired")

# --- threaded-kernels determinism twins --------------------------------------
# sfw-asyn at 56x40 (dims distinct from every other smoke grid), W=2,
# threads in {1, 4}, same seed.  The kernels layer guarantees results
# are bit-identical in the pool size (fixed-size chunk partials combined
# in a fixed order), so the two cells must agree EXACTLY — equal is the
# assertion, not approximately-equal.  Any drift means a kernel's
# reduction order leaked thread count into the numbers.
threads_cells = [c for c in cells if c["axes"].get("dims") == "56x40"]
by_threads = {c["axes"].get("threads"): c for c in threads_cells}
assert "1" in by_threads and "4" in by_threads, (
    f"{path}: smoke grid lost its threads=1/threads=4 twin cells "
    f"(have {sorted(by_threads)})")
t1, t4 = by_threads["1"], by_threads["4"]
for key in ("bytes_up", "bytes_down", "msgs_up", "msgs_down", "iterations"):
    assert t1["counters"][key] == t4["counters"][key], (
        f"threads twins diverged on {key}: {t1['counters'][key]} (threads=1) "
        f"vs {t4['counters'][key]} (threads=4) — thread count must be a pure "
        "wall-clock knob")
t1_rel, t4_rel = t1["final_rel"], t4["final_rel"]
assert t1_rel is not None and t4_rel is not None, (
    "threads twin cells lost their final_rel accounting")
assert t1_rel == t4_rel, (
    f"threads twins diverged on final_rel: {t1_rel!r} (threads=1) vs "
    f"{t4_rel!r} (threads=4) — a kernel reduction leaked thread count")

print(f"OK: {len(cells)} cells in {path}, bytes nonzero in {len(dist)} "
      f"distributed cell(s), "
      f"events nonzero in {len(chaos_cells)} chaos cell(s), "
      f"factored downlink {fd} B vs dense {dd} B, "
      f"int8 uplink >= 3x under f32 at matching loss on {pairs} transport(s), "
      f"sparse uplink atom-scale on {len(sparse)} cell(s), "
      f"gap column decreasing {fgaps[0]:.3e} -> {fgaps[-1]:.3e} with "
      f"tol=1000 stopping at iter {stopped['counters']['iterations']}, "
      f"threads=1/4 twins bit-equal (rel {t1_rel})")
