#!/usr/bin/env python3
"""Self-test for the blocking bench gate (bench_snapshot.py --compare).

Synthesizes a hotpath_raw.csv and a previous snapshot in a temp dir and
asserts the gate (1) exits nonzero on a regression past threshold,
(2) passes when nothing slowed, and (3) honors per-op overrides from a
bench_thresholds.json-shaped table.  Run by scripts/ci.sh --bench and
the CI workflow before the real compare, so a gate that silently
stopped gating fails the build rather than waving regressions through.
Needs no cargo: the gate is exercised with --skip-run on synthetic CSV.
"""
import json
import os
import subprocess
import sys
import tempfile

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "bench_snapshot.py")

# the cells bench_snapshot.py itself insists on
REQUIRED = [
    "lmo 196x196 dense operator",
    "lmo 196x196 factored operator k=64",
    "pnn grad m=256 factored k=16",
]


def write_raw(d, means):
    os.makedirs(os.path.join(d, "bench_out"), exist_ok=True)
    with open(os.path.join(d, "bench_out", "hotpath_raw.csv"), "w") as f:
        f.write("op,mean_s,p50_s,p90_s,notes\n")
        for op, mean in means.items():
            f.write(f'"{op}",{mean:.9f},{mean:.9f},{mean:.9f},"synthetic"\n')


def write_prev(d, means):
    doc = {"schema": "sfw.bench/v1", "bench": "hotpath",
           "rows": [{"op": op, "mean_s": m, "p50_s": m, "p90_s": m,
                     "notes": ""} for op, m in means.items()]}
    with open(os.path.join(d, "prev.json"), "w") as f:
        json.dump(doc, f)


def run_gate(d, thresholds):
    tpath = os.path.join(d, "thresholds.json")
    with open(tpath, "w") as f:
        json.dump(thresholds, f)
    cmd = [sys.executable, SCRIPT, "--skip-run",
           "--compare", os.path.join(d, "prev.json"),
           "--thresholds", tpath,
           "--out", os.path.join(d, "bench_out", "BENCH_hotpath.json")]
    return subprocess.run(cmd, cwd=d, capture_output=True, text=True)


base = {op: 1e-3 for op in REQUIRED}
base["wire codec roundtrip (196+196 floats)"] = 1e-6

with tempfile.TemporaryDirectory() as d:
    write_prev(d, base)

    # 1) a 2x regression on one op must fail the gate and name the op
    cur = dict(base)
    cur[REQUIRED[0]] = 2e-3
    write_raw(d, cur)
    r = run_gate(d, {"default": 1.25, "ops": {}})
    assert r.returncode != 0, (
        f"gate passed a 2x regression:\n{r.stdout}\n{r.stderr}")
    assert REQUIRED[0] in (r.stdout + r.stderr), (
        f"regressing op not named in gate output:\n{r.stdout}\n{r.stderr}")

    # 2) unchanged timings must pass
    write_raw(d, base)
    r = run_gate(d, {"default": 1.25, "ops": {}})
    assert r.returncode == 0, (
        f"gate failed a clean run:\n{r.stdout}\n{r.stderr}")

    # 3) a per-op override loosens exactly that op; the default still
    #    catches the same slip without the override
    cur = dict(base)
    cur[REQUIRED[0]] = 1.4e-3
    write_raw(d, cur)
    r = run_gate(d, {"default": 1.25, "ops": {REQUIRED[0]: 1.5}})
    assert r.returncode == 0, (
        f"per-op threshold ignored:\n{r.stdout}\n{r.stderr}")
    r = run_gate(d, {"default": 1.25, "ops": {}})
    assert r.returncode != 0, (
        f"default threshold missed a 1.4x slip:\n{r.stdout}\n{r.stderr}")

print("OK: bench gate blocks regressions, passes clean runs, "
      "honors per-op thresholds")
