//! Compile-only stub of the `xla` PJRT binding.
//!
//! The real crate (xla-rs API: `PjRtClient`/`HloModuleProto`/`Literal`)
//! wraps the XLA C API and is supplied by the build image — it is not on
//! crates.io.  This stub mirrors exactly the API surface
//! `sfw::runtime` uses so that CI runners without an XLA toolchain can
//! still build the workspace and run every native-engine test: all
//! entry points return [`Error::Unavailable`], which the callers
//! already treat as "artifacts/PJRT not present — skip" (see
//! `rust/tests/pjrt_integration.rs`).
//!
//! Keep this in sync with the `xla::` call sites in
//! `rust/src/runtime/{mod,engine}.rs`; a missing item here is a CI
//! build break, never a silent behavior change.

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug)]
pub enum Error {
    /// The stub's only error: there is no PJRT runtime behind this crate.
    Unavailable,
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla stub: PJRT unavailable (built without the real xla binding)")
    }
}

impl std::error::Error for Error {}

/// Array element types the runtime names.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Shapes as far as the runtime inspects them (tuple vs not).
#[derive(Debug, Clone)]
pub enum Shape {
    Tuple(Vec<Shape>),
    Array,
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable)
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable)
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }

    pub fn execute_b<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }
}

pub struct Literal;

impl Literal {
    pub fn shape(&self) -> Result<Shape> {
        Err(Error::Unavailable)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable)
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(Error::Unavailable)
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<std::path::Path>>(_path: P) -> Result<Self> {
        Err(Error::Unavailable)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
