//! Convergence-rate checks against the paper's theorems (native engine;
//! deterministic seeds), driven through the unified `sfw::session` API.
//!
//! * Thm 1 / HL16: with the increasing batch schedule, the suboptimality
//!   h_k decays like O(1/k) — we check the empirical decay exponent.
//! * Thm 3/4: constant batch size converges to a NEIGHBORHOOD — larger
//!   batches give lower floors.
//! * SVA sanity: the naive singular-vector-averaging baseline plateaus
//!   far above SFW-asyn on the same problem/seed (the paper's motivating
//!   negative result).

use std::sync::Arc;

use sfw::data::matrix_sensing::{MatrixSensingData, MsParams};
use sfw::objective::MatrixSensing;
use sfw::runtime::Workload;
use sfw::session::{BatchSchedule, Report, ReprKind, StepMethod, TaskSpec, TrainSpec};
use sfw::util::rng::Rng;

fn ms(seed: u64, n: usize) -> TaskSpec {
    let mut rng = Rng::new(seed);
    // noiseless => F* ~ 0, so h_k ~ F(X_k); clean rate measurement
    let p = MsParams { d1: 12, d2: 12, rank: 2, n, noise_std: 0.0 };
    TaskSpec::Prebuilt(Workload::Ms(Arc::new(MatrixSensing::new(
        MatrixSensingData::generate(&p, &mut rng),
        1.0,
    ))))
}

#[test]
fn sfw_rate_is_at_least_one_over_k() {
    let r = TrainSpec::new(ms(400, 8_000))
        .algo("sfw")
        .iterations(256)
        .batch(BatchSchedule::sfw(0.25, 8_000))
        .eval_every(1)
        .seed(402)
        .power_iters(80)
        .run()
        .expect("train");
    let pts = r.points();
    // fit decay exponent on k in [16, 256]: log h_k vs log k
    let series: Vec<(f64, f64)> = pts
        .iter()
        .filter(|p| p.iteration >= 16 && p.loss > 1e-12)
        .map(|p| ((p.iteration as f64).ln(), p.loss.ln()))
        .collect();
    assert!(series.len() > 50);
    let n = series.len() as f64;
    let sx: f64 = series.iter().map(|p| p.0).sum();
    let sy: f64 = series.iter().map(|p| p.1).sum();
    let sxx: f64 = series.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = series.iter().map(|p| p.0 * p.1).sum();
    let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    // O(1/k) => slope <= -0.8 in practice (often steeper on noiseless MS)
    assert!(slope < -0.8, "empirical decay exponent {slope} too flat for O(1/k)");
}

#[test]
fn constant_batch_floor_shrinks_with_batch_size() {
    // Thm 3: residual error ~ 1/c * L D^2 — bigger constant batch, lower
    // floor.  Use a noiseless problem so the floor is purely stochastic.
    let task = ms(410, 6_000);
    let floor = |m: usize, seed: u64| {
        let r = TrainSpec::new(task.clone())
            .algo("sfw")
            .iterations(300)
            .batch(BatchSchedule::Constant(m))
            .eval_every(10)
            .seed(seed)
            .power_iters(80)
            .run()
            .expect("train");
        // average the tail to estimate the plateau
        let pts = r.points();
        let tail: Vec<f64> = pts.iter().rev().take(8).map(|p| p.loss).collect();
        tail.iter().sum::<f64>() / tail.len() as f64
    };
    let f_small = floor(8, 411);
    let f_large = floor(512, 412);
    assert!(
        f_large < 0.5 * f_small,
        "floor(512)={f_large} not clearly below floor(8)={f_small}"
    );
}

#[test]
fn sva_plateaus_while_sfw_asyn_converges() {
    // Noiseless problem, SMALL constant batches: each worker's LMO
    // direction is noisy, and averaging unit singular vectors (instead of
    // solving the LMO of the averaged gradient) has a systematic bias —
    // SVA stalls at a visibly higher floor with the same compute budget.
    let spec = TrainSpec::new(ms(420, 6_000))
        .iterations(600)
        .tau(8)
        .workers(4)
        .batch(BatchSchedule::Constant(32))
        .eval_every(50)
        .seed(421)
        .power_iters(60);
    let asyn = spec.clone().algo("sfw-asyn").run().expect("asyn");
    // SVA with identical compute budget
    let sva = spec.clone().algo("sva").run().expect("sva");
    // compare plateau (tail average), not a single noisy endpoint
    let tail = |r: &Report| {
        let pts = r.points();
        let t: Vec<f64> = pts.iter().rev().take(4).map(|p| p.loss).collect();
        t.iter().sum::<f64>() / t.len() as f64
    };
    let asyn_final = tail(&asyn);
    let sva_final = tail(&sva);
    assert!(
        asyn_final < 0.75 * sva_final,
        "SFW-asyn plateau {asyn_final} should sit clearly below SVA plateau {sva_final}"
    );
}

#[test]
fn tau_slowdown_is_bounded() {
    // Thm 1's (3 tau + 1) factor: larger tolerated staleness converges
    // slower per-iteration but must still converge.  Compare final losses
    // after the same iteration count.
    let task = ms(430, 6_000);
    let run = |tau: u64, seed: u64| {
        TrainSpec::new(task.clone())
            .algo("sfw-asyn")
            .iterations(150)
            .tau(tau)
            .workers(4)
            .batch(BatchSchedule::Constant(256))
            .eval_every(50)
            .seed(seed)
            .power_iters(60)
            .run()
            .expect("train")
            .points()
            .last()
            .unwrap()
            .loss
    };
    let tight = run(2, 431);
    let loose = run(64, 432);
    // both converge to a sane range (no divergence from staleness)
    assert!(tight < 0.05, "tau=2 final {tight}");
    assert!(loose < 0.15, "tau=64 final {loose} diverged");
}

#[test]
fn gap_decays_and_tol_stops_early() {
    // The FW dual gap g_k = <grad F(X_k), X_k - s_k> upper-bounds the
    // suboptimality on a convex problem, so on noiseless matrix sensing
    // it must decay toward zero alongside the loss — and `--tol` must
    // turn that decay into an early stop.
    let task = ms(440, 6_000);
    let budget = 200u64;
    let spec = TrainSpec::new(task)
        .algo("sfw")
        .iterations(budget)
        .batch(BatchSchedule::Constant(256))
        .eval_every(5)
        .seed(441)
        .power_iters(80);
    // tol = 0 disables gap stopping: full budget, decaying gap column.
    let full = spec.clone().run().expect("train");
    assert_eq!(full.snapshot().iterations, budget, "tol=0 must not stop early");
    let gaps: Vec<f64> = full
        .points()
        .iter()
        .map(|p| p.gap)
        .filter(|g| g.is_finite())
        .collect();
    assert!(gaps.len() > 10, "gap column missing from the trace");
    let (g0, gf) = (gaps[0], *gaps.last().unwrap());
    assert!(
        gf < 0.5 * g0,
        "gap did not decay: first finite {g0:.4e} -> last {gf:.4e}"
    );
    // A tolerance between the initial and final gap stops the same run
    // strictly inside the budget, and the report's final gap certifies it.
    let tol = (g0 * gf).sqrt();
    let stopped = spec.clone().tol(tol).run().expect("train");
    let iters = stopped.snapshot().iterations;
    assert!(iters < budget, "tol={tol:.4e} never fired ({iters} iterations)");
    let final_gap = stopped.final_gap().expect("gap-stopped run must report a gap");
    assert!(
        final_gap <= tol,
        "stopped at gap {final_gap:.4e} above tol {tol:.4e}"
    );
}

#[test]
fn line_search_is_no_worse_than_vanilla_same_seed() {
    // The golden-section policy only accepts a step if the sampled loss
    // does not increase, falling back to eta(k) otherwise — so with the
    // same seed it can only match or beat the vanilla schedule.
    let task = ms(450, 6_000);
    let run = |step: StepMethod| {
        TrainSpec::new(task.clone())
            .algo("sfw")
            .iterations(150)
            .batch(BatchSchedule::Constant(256))
            .eval_every(10)
            .seed(451)
            .power_iters(80)
            .step(step)
            .run()
            .expect("train")
            .final_loss()
    };
    let vanilla = run(StepMethod::Vanilla);
    let ls = run(StepMethod::LineSearch);
    assert!(
        ls <= vanilla * 1.01 + 1e-9,
        "line-search final loss {ls:.4e} above vanilla {vanilla:.4e}"
    );
}

#[test]
fn away_and_pairwise_match_loss_with_fewer_atoms() {
    // Away/pairwise steps shift (or drop) weight on existing atoms
    // instead of always adding a new one, so at the same budget and seed
    // they must land at a matching loss with a strictly smaller active
    // set — the whole point of the variants on a factored iterate.
    let task = ms(460, 6_000);
    let run = |step: StepMethod| {
        TrainSpec::new(task.clone())
            .algo("sfw")
            .repr(ReprKind::Factored)
            .iterations(150)
            .batch(BatchSchedule::Constant(256))
            .eval_every(10)
            .seed(461)
            .power_iters(80)
            .step(step)
            .run()
            .expect("train")
    };
    let vanilla = run(StepMethod::Vanilla);
    for step in [StepMethod::Away, StepMethod::Pairwise] {
        let variant = run(step);
        let (vr, xr) = (vanilla.final_relative(), variant.final_relative());
        assert!(
            xr <= vr * 1.15 + 1e-3,
            "{}: final rel {xr:.4e} not matching vanilla {vr:.4e}",
            step.label()
        );
        assert!(
            variant.final_rank < vanilla.final_rank,
            "{}: final_rank {} not strictly below vanilla {}",
            step.label(),
            variant.final_rank,
            vanilla.final_rank
        );
    }
}
