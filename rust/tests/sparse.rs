//! The sparse-completion contract (ISSUE PR 8): the synthetic
//! recommender trains end-to-end through the session layer without ever
//! materializing a dense X on the hot path, and the trained atom list
//! checkpoints and serves.
//!
//! * same-seed dense-vs-factored runs agree on the sparse objective
//!   (both take the O(nnz) COO gradient + sparse-operator LMO path);
//! * the acceptance pin: a factored run at 2000x400 / ~1% density —
//!   where the dense iterate is >= 10x the observed-entry footprint —
//!   completes, checkpoints through `sfw::model`, and the reloaded
//!   model answers per-user top-k queries bit-identically to the
//!   in-memory atom list, at O(atoms * cols) per query (no dense X,
//!   nothing scaling with nnz);
//! * the asynchronous uplink stays atom-scale per message on the sparse
//!   task (the sweep smoke artifact pins the same bound in CI);
//! * same-spec re-runs are bit-deterministic (generator + solver);
//! * malformed model files surface typed [`ModelError`]s, never panics.

use sfw::data::{RecParams, RecommenderData};
use sfw::linalg::{Mat, Repr};
use sfw::model::ModelError;
use sfw::session::{BatchSchedule, ReprKind, TaskSpec, TrainSpec};
use sfw::util::rng::Rng;

fn small_spec() -> TrainSpec {
    TrainSpec::new(TaskSpec::sparse_small())
        .algo("sfw")
        .iterations(25)
        .batch(BatchSchedule::Constant(32))
        .eval_every(5)
        .power_iters(30)
        .seed(11)
}

fn rel_frob_diff(a: &Mat, b: &Mat) -> f64 {
    let mut d = a.clone();
    d.axpy(-1.0, b);
    d.frob_norm() / (1.0 + a.frob_norm())
}

#[test]
fn sparse_session_agrees_dense_vs_factored_and_defaults_to_factored() {
    let spec = small_spec();
    // Auto resolves factored for sparse_completion
    assert_eq!(spec.resolved_repr(), Repr::Factored);
    assert!(spec.echo().contains("repr=factored"), "{}", spec.echo());
    let fact = spec.clone().run().unwrap();
    let dense = spec.clone().repr(ReprKind::Dense).run().unwrap();
    let rel = rel_frob_diff(&dense.x, &fact.x);
    assert!(rel < 2e-2, "dense vs factored iterate diverged (rel {rel})");
    let (dl, fl) = (dense.final_loss(), fact.final_loss());
    assert!((dl - fl).abs() < 2e-2 * (1.0 + dl.abs()), "final loss {dl} vs {fl}");
    assert!(fact.peak_atoms > 0 && fact.final_rank > 0, "factored run lost atom accounting");
    assert_eq!(dense.peak_atoms, 0, "dense run reported atoms");
    assert!(fact.factored.is_some(), "factored run lost its checkpointable atom list");
    assert!(dense.factored.is_none(), "dense run grew an atom list");
}

#[test]
fn sparse_session_is_deterministic_given_seed() {
    let a = small_spec().run().unwrap();
    let b = small_spec().run().unwrap();
    assert_eq!(a.x.data, b.x.data, "same-spec sparse runs diverged");
    let (sa, sb) = (a.snapshot(), b.snapshot());
    assert_eq!(sa.bytes_up, sb.bytes_up);
    assert_eq!(sa.grad_evals, sb.grad_evals);
}

#[test]
fn async_sparse_uplink_stays_atom_scale() {
    let report = TrainSpec::new(TaskSpec::sparse_small())
        .algo("sfw-asyn")
        .workers(2)
        .tau(2)
        .iterations(20)
        .batch(BatchSchedule::Constant(16))
        .eval_every(5)
        .power_iters(20)
        .seed(42)
        .run()
        .unwrap();
    let s = report.snapshot();
    assert!(s.msgs_up > 0, "no uplink traffic");
    let per_msg = s.bytes_up as f64 / s.msgs_up as f64;
    // one rank-one atom is O(rows + cols) floats; 4x slack still sits
    // well under the 4 * 96 * 48 B dense frame
    let atom_scale = (4 * (96 + 48) * 4) as f64;
    assert!(
        per_msg <= atom_scale,
        "sparse uplink {per_msg:.0} B/msg exceeds atom scale {atom_scale} B"
    );
}

/// The PR's acceptance pin: train factored at dims where a dense iterate
/// costs >= 10x the observed entries, checkpoint, reload, serve.
#[test]
fn factored_train_checkpoint_serve_at_sparse_scale() {
    let p = RecParams { rows: 2000, cols: 400, rank: 4, density: 0.01, ..RecParams::default() };

    // Footprint premise: the dense variable (rows * cols floats) must be
    // >= 10x the COO training set (3 words per observation).
    let probe = RecommenderData::generate(&p, &mut Rng::new(3));
    let obs = probe.train_nnz() + probe.ho_vals.len();
    assert!(
        p.rows * p.cols >= 10 * 3 * obs,
        "premise broke: dense {} floats vs {} observation words",
        p.rows * p.cols,
        3 * obs
    );

    let report = TrainSpec::new(TaskSpec::SparseCompletion(p.clone()))
        .algo("sfw-asyn")
        .workers(1)
        .tau(2)
        .iterations(40)
        .batch(BatchSchedule::Constant(64))
        .eval_every(10)
        .power_iters(30)
        .seed(3)
        .run()
        .unwrap();
    let rel = report.relative();
    let last_rel = rel.last().unwrap().2;
    assert!(last_rel < 0.9, "no progress on the 2000x400 recommender (rel {last_rel})");
    let model = report.factored.as_ref().expect("factored run keeps its atom list");
    assert!(model.atoms() > 0);
    assert_eq!((model.rows, model.cols), (2000, 400));

    // checkpoint -> load must be bit-identical, atom for atom
    let path = std::env::temp_dir().join(format!("sfw_ckpt_{}.json", std::process::id()));
    sfw::model::save(model, &path).unwrap();
    let loaded = sfw::model::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.atoms(), model.atoms(), "load re-compressed the checkpoint");

    // serving answers from the atom list alone — O(atoms * cols) per
    // user — and the reloaded model's predictions match the in-memory
    // ones bit for bit
    let mut live = Vec::new();
    let mut served = Vec::new();
    for user in [0usize, 7, 1999] {
        sfw::model::user_scores(model, user, &mut live).unwrap();
        sfw::model::user_scores(&loaded, user, &mut served).unwrap();
        assert_eq!(served.len(), 400);
        for (a, b) in live.iter().zip(served.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "user {user}: save/load drifted");
        }
        let top = sfw::model::top_k(&served, 10);
        assert_eq!(top.len(), 10);
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1, "user {user}: top-k not descending");
        }
    }
    assert!(matches!(
        sfw::model::user_scores(&loaded, 2000, &mut served),
        Err(ModelError::Query(_))
    ));
}

#[test]
fn malformed_model_files_error_cleanly() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("sfw_model_bad_{}.json", std::process::id()));

    std::fs::write(&path, "{\"format\":\"sfw.model/v1\",\"rows\":4").unwrap();
    assert!(matches!(sfw::model::load(&path), Err(ModelError::Parse(_))));

    std::fs::write(&path, r#"{"format":"sfw.model/v9","rows":2,"cols":2,"atoms":[]}"#).unwrap();
    assert!(matches!(sfw::model::load(&path), Err(ModelError::Format(_))));

    std::fs::write(
        &path,
        r#"{"format":"sfw.model/v1","rows":2,"cols":2,"atoms":[{"w":1,"u":[1],"v":[0,1]}]}"#,
    )
    .unwrap();
    assert!(matches!(sfw::model::load(&path), Err(ModelError::Format(_))));
    std::fs::remove_file(&path).ok();

    let missing = dir.join("sfw_model_that_does_not_exist.json");
    assert!(matches!(sfw::model::load(&missing), Err(ModelError::Io(_))));
}
