//! The session-API contract:
//!
//! * every registered algorithm runs end to end on a small
//!   matrix-sensing task over the local transport and produces a
//!   monotone-iteration loss trace;
//! * spec validation errors name the registry's valid algorithms;
//! * the launcher `Config`/CLI -> `TrainSpec` mapping round-trips,
//!   including `--section.key` overrides and bad-value errors.

use sfw::config::{ConfigError, TrainConfig};
use sfw::session::{
    registry, BatchSchedule, EngineKind, SessionError, StepMethod, TaskSpec, TrainSpec,
    Transport,
};
use sfw::util::cli::Args;

fn small_spec() -> TrainSpec {
    TrainSpec::new(TaskSpec::ms_small())
        .workers(2)
        .tau(4)
        .iterations(10)
        .epochs(1) // svrf-asyn: one outer epoch (6 inner iterations)
        .batch(BatchSchedule::Constant(16))
        .eval_every(2)
        .seed(7)
        .power_iters(20)
}

#[test]
fn every_registered_algo_runs_and_traces_monotonically() {
    for name in registry().names() {
        let r = small_spec()
            .algo(name)
            .run()
            .unwrap_or_else(|e| panic!("algo '{name}' failed: {e}"));
        let pts = r.points();
        assert!(pts.len() >= 2, "algo '{name}': trace too short ({} points)", pts.len());
        for w in pts.windows(2) {
            assert!(
                w[1].iteration >= w[0].iteration,
                "algo '{name}': trace iterations not monotone ({} then {})",
                w[0].iteration,
                w[1].iteration
            );
        }
        let s = r.snapshot();
        assert!(s.iterations > 0, "algo '{name}': no iterations counted");
        assert!(
            r.spec_echo.contains(&format!("algo={name}")),
            "algo '{name}': spec echo missing algo ({})",
            r.spec_echo
        );
        for p in &pts {
            assert!(p.loss.is_finite(), "algo '{name}': non-finite loss");
        }
    }
}

#[test]
fn unknown_algo_error_lists_valid_names() {
    let err = small_spec().algo("not-an-algo").run().unwrap_err();
    assert!(matches!(err, SessionError::UnknownAlgo { .. }), "{err}");
    let msg = err.to_string();
    assert!(msg.contains("not-an-algo"), "{msg}");
    for name in registry().names() {
        assert!(msg.contains(name), "error should list '{name}': {msg}");
    }
}

#[test]
fn tcp_bind_conflicts_surface_as_comms_errors_before_the_run() {
    // Occupy a port, then ask a TCP run to bind the same one: the
    // pre-bind in TrainSpec::run must fail as Comms, not mid-protocol.
    let holder = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = holder.local_addr().unwrap().to_string();
    let err = small_spec()
        .algo("sfw-asyn")
        .transport(Transport::Tcp)
        .tcp_bind(&addr)
        .run()
        .unwrap_err();
    assert!(matches!(err, SessionError::Comms(_)), "{err}");
    assert!(err.to_string().contains(&addr), "{err}");
}

#[test]
fn missing_pjrt_artifacts_surface_as_engine_errors_before_the_run() {
    let err = small_spec()
        .engine(EngineKind::Pjrt)
        .artifacts_dir("/nonexistent/sfw-artifacts")
        .run()
        .unwrap_err();
    assert!(matches!(err, SessionError::Engine(_)), "{err}");
}

#[test]
fn zero_scale_knobs_error_instead_of_panicking() {
    // workers=0 / eval_every=0 used to reach the protocols' divide/modulo.
    let err = small_spec().workers(0).run().unwrap_err();
    assert!(matches!(err, SessionError::InvalidSpec(_)), "{err}");
    let err = small_spec().eval_every(0).run().unwrap_err();
    assert!(matches!(err, SessionError::InvalidSpec(_)), "{err}");
}

#[test]
fn tcp_only_knobs_are_rejected_on_the_local_transport() {
    let err = small_spec().tcp_bind("127.0.0.1:7070").run().unwrap_err();
    assert!(matches!(err, SessionError::InvalidSpec(_)), "{err}");
    let err = small_spec().tcp_await(true).run().unwrap_err();
    assert!(matches!(err, SessionError::InvalidSpec(_)), "{err}");
}

#[test]
fn worker_side_rejects_algorithms_without_a_wire_protocol() {
    let err = small_spec().algo("sva").run_worker("127.0.0.1:1", 0).unwrap_err();
    let msg = err.to_string();
    assert!(
        matches!(err, SessionError::UnsupportedTransport { .. }),
        "expected UnsupportedTransport, got: {msg}"
    );
    // registry-driven listing of the solvers that DO speak TCP
    for supporter in ["sfw-asyn", "svrf-asyn", "sfw-dist"] {
        assert!(msg.contains(supporter), "error should list '{supporter}': {msg}");
    }
}

#[test]
fn registry_names_are_stable_and_complete() {
    let names = registry().names();
    for required in ["sfw", "sfw-asyn", "svrf-asyn", "sfw-dist", "sva", "dfw-power"] {
        assert!(names.contains(&required), "registry missing '{required}'");
    }
}

// ---------------------------------------------------------------------------
// Config -> TrainSpec mapping
// ---------------------------------------------------------------------------

fn load(cli: &str) -> Result<TrainConfig, ConfigError> {
    TrainConfig::load(&Args::parse_from(cli.split_whitespace().map(String::from)))
}

#[test]
fn config_maps_onto_spec_fields() {
    let cfg = load(
        "--task pnn --algo sfw-dist --engine pjrt --transport tcp \
         --workers 12 --tau 3 --iterations 77 --seed 5",
    )
    .unwrap();
    let spec = TrainSpec::from_config(&cfg).unwrap();
    assert_eq!(spec.task.name(), "pnn");
    assert_eq!(spec.algo, "sfw-dist");
    assert_eq!(spec.engine, EngineKind::Pjrt);
    assert_eq!(spec.transport, Transport::Tcp);
    assert_eq!(spec.workers, 12);
    assert_eq!(spec.tau, 3);
    assert_eq!(spec.iterations, 77);
    assert_eq!(spec.seed, 5);
    assert!(spec.echo().contains("transport=tcp"));
}

#[test]
fn multi_process_keys_map_onto_spec_fields() {
    let cfg = load(
        "--algo sfw-dist --transport tcp --tcp-bind 127.0.0.1:7070 --tcp-await --batch 64",
    )
    .unwrap();
    assert_eq!(cfg.tcp_bind, "127.0.0.1:7070");
    assert!(cfg.tcp_await); // bare boolean flag spelling
    assert_eq!(cfg.batch, 64);
    let spec = TrainSpec::from_config(&cfg).unwrap();
    assert_eq!(spec.tcp_bind.as_deref(), Some("127.0.0.1:7070"));
    assert!(spec.tcp_await);
    assert_eq!(spec.batch, Some(BatchSchedule::Constant(64)));

    // defaults: no bind, threads spawned in-process, theorem schedule
    let spec = TrainSpec::from_config(&load("").unwrap()).unwrap();
    assert_eq!(spec.tcp_bind, None);
    assert!(!spec.tcp_await);
    assert!(spec.batch.is_none());
}

#[test]
fn sectioned_cli_overrides_reach_the_spec() {
    let cfg = load("--train.workers 9 --train.tau 2 --data.ms-d 14").unwrap();
    let spec = TrainSpec::from_config(&cfg).unwrap();
    assert_eq!(spec.workers, 9);
    assert_eq!(spec.tau, 2);
    match spec.task {
        TaskSpec::MatrixSensing { d1, d2, .. } => {
            assert_eq!(d1, 14);
            assert_eq!(d2, 14);
        }
        _ => panic!("expected matrix_sensing task"),
    }
}

#[test]
fn config_file_sections_merge_with_cli() {
    let dir = std::env::temp_dir().join("sfw_session_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.ini");
    std::fs::write(
        &path,
        "algo = sva\n[train]\nworkers = 6\ntau = 5\n[data]\nms-n = 4321\n",
    )
    .unwrap();
    let cli = format!("--config {} --tau 9", path.display());
    let cfg = load(&cli).unwrap();
    assert_eq!(cfg.algo, "sva");
    assert_eq!(cfg.workers, 6); // from [train] section
    assert_eq!(cfg.tau, 9); // CLI beats file
    assert_eq!(cfg.ms_n, 4321); // from [data] section
    let spec = TrainSpec::from_config(&cfg).unwrap();
    assert_eq!(spec.algo, "sva");
    assert_eq!(spec.workers, 6);
}

#[test]
fn bad_values_surface_as_config_errors() {
    match load("--workers not-a-number") {
        Err(ConfigError::BadValue(key, value)) => {
            assert_eq!(key, "workers");
            assert_eq!(value, "not-a-number");
        }
        other => panic!("expected BadValue, got {other:?}"),
    }
    match load("--train.iterations nope") {
        Err(ConfigError::BadValue(key, _)) => assert_eq!(key, "iterations"),
        other => panic!("expected BadValue, got {other:?}"),
    }
}

#[test]
fn unknown_task_engine_transport_are_rejected() {
    let cfg = load("--task tabular").unwrap();
    assert!(matches!(TrainSpec::from_config(&cfg), Err(SessionError::UnknownTask(_))));
    let cfg = load("--engine tpu").unwrap();
    assert!(matches!(TrainSpec::from_config(&cfg), Err(SessionError::UnknownEngine(_))));
    let cfg = load("--transport carrier-pigeon").unwrap();
    assert!(matches!(
        TrainSpec::from_config(&cfg),
        Err(SessionError::UnknownTransport(_))
    ));
}

#[test]
fn tol_and_step_round_trip_to_the_spec() {
    let cfg = load("--tol 1e-3 --step line-search").unwrap();
    let spec = TrainSpec::from_config(&cfg).unwrap();
    assert!((spec.tol - 1e-3).abs() < 1e-12);
    assert_eq!(spec.step, StepMethod::LineSearch);
    assert!(spec.echo().contains("step=line-search"), "{}", spec.echo());
    assert!(spec.echo().contains("tol=0.001"), "{}", spec.echo());

    // defaults: vanilla schedule, gap stopping off, neither echoed
    let spec = TrainSpec::from_config(&load("").unwrap()).unwrap();
    assert_eq!(spec.step, StepMethod::Vanilla);
    assert_eq!(spec.tol, 0.0);
    assert!(!spec.echo().contains("step="), "{}", spec.echo());

    // an unknown step value is rejected with the full menu
    let cfg = load("--step exact").unwrap();
    let err = TrainSpec::from_config(&cfg).unwrap_err();
    assert!(matches!(err, SessionError::InvalidSpec(_)), "{err}");
    let msg = err.to_string();
    assert!(msg.contains("exact") && msg.contains("line-search"), "{msg}");
}

#[test]
fn step_policies_are_rejected_where_they_cannot_apply() {
    // away/pairwise maintain an active atom set: serial sfw only...
    let err = small_spec().algo("sfw-asyn").step(StepMethod::Away).run().unwrap_err();
    assert!(matches!(err, SessionError::InvalidSpec(_)), "{err}");
    assert!(err.to_string().contains("--algo sfw"), "{err}");
    // ...and only on the factored iterate (ms_small resolves dense)
    let err = small_spec().algo("sfw").step(StepMethod::Pairwise).run().unwrap_err();
    assert!(matches!(err, SessionError::InvalidSpec(_)), "{err}");
    assert!(err.to_string().contains("--repr factored"), "{err}");
    // the fixed-update baselines reject every non-vanilla policy
    let err = small_spec().algo("pgd").step(StepMethod::LineSearch).run().unwrap_err();
    assert!(matches!(err, SessionError::InvalidSpec(_)), "{err}");
    assert!(err.to_string().contains("fixed update rule"), "{err}");
    // a negative tolerance can never fire: reject instead of hanging
    let err = small_spec().tol(-1.0).run().unwrap_err();
    assert!(matches!(err, SessionError::InvalidSpec(_)), "{err}");
    assert!(err.to_string().contains("tol"), "{err}");
}

#[test]
fn spec_epochs_follow_config_or_derive_from_iterations() {
    let cfg = load("--iterations 300").unwrap();
    let spec = TrainSpec::from_config(&cfg).unwrap();
    // ceil(log2(300)) = 9
    assert_eq!(spec.epochs_or_derived(), 9);
    let cfg = load("--epochs 3").unwrap();
    let spec = TrainSpec::from_config(&cfg).unwrap();
    assert_eq!(spec.epochs_or_derived(), 3);
}
