//! Distributed-substrate integration: TCP transport end-to-end, straggler
//! resilience, and cross-algorithm comm accounting on the same workload —
//! all driven through the unified `sfw::session` API.

use std::sync::Arc;
use std::time::Duration;

use sfw::data::matrix_sensing::{MatrixSensingData, MsParams};
use sfw::linalg::nuclear_norm;
use sfw::objective::MatrixSensing;
use sfw::runtime::Workload;
use sfw::session::{BatchSchedule, Straggler, TaskSpec, TrainSpec, Transport};
use sfw::util::rng::Rng;

/// Shared-data task: dataset generation stays pinned to its own seed,
/// independent of the spec's algorithm seed.
fn ms(seed: u64, d: usize, n: usize) -> TaskSpec {
    let mut rng = Rng::new(seed);
    let p = MsParams { d1: d, d2: d, rank: 2, n, noise_std: 0.05 };
    TaskSpec::Prebuilt(Workload::Ms(Arc::new(MatrixSensing::new(
        MatrixSensingData::generate(&p, &mut rng),
        1.0,
    ))))
}

#[test]
fn tcp_transport_full_training_run() {
    let r = TrainSpec::new(ms(500, 10, 2_000))
        .algo("sfw-asyn")
        .transport(Transport::Tcp)
        .iterations(80)
        .tau(8)
        .workers(3)
        .batch(BatchSchedule::Constant(64))
        .eval_every(20)
        .seed(501)
        .power_iters(50)
        .run()
        .expect("tcp train");
    let pts = r.points();
    assert!(pts.last().unwrap().loss < 0.5 * pts.first().unwrap().loss);
    assert!(nuclear_norm(&r.x) <= 1.0 + 1e-3);
    let s = r.snapshot();
    assert_eq!(s.iterations, 80);
    assert!(s.bytes_up > 0 && s.bytes_down > 0);
}

#[test]
fn tcp_and_local_transport_count_comparable_traffic() {
    // Same protocol + same workload => same order of bytes (TCP adds a
    // 5-byte frame header per message; totals must agree within 25%).
    let spec = TrainSpec::new(ms(510, 8, 1_500))
        .algo("sfw-asyn")
        .iterations(60)
        .tau(8)
        .workers(2)
        .batch(BatchSchedule::Constant(32))
        .eval_every(30)
        .seed(511)
        .power_iters(40);
    let local = spec.clone().transport(Transport::Local).run().expect("local");
    let tcp = spec.clone().transport(Transport::Tcp).run().expect("tcp");
    let (l, t) = (local.snapshot(), tcp.snapshot());
    let rel = |a: u64, b: u64| (a as f64 - b as f64).abs() / (a as f64).max(1.0);
    // identical accepted-iteration count; message counts differ only by
    // scheduling nondeterminism
    assert_eq!(l.iterations, t.iterations);
    assert!(
        rel(l.bytes_up, t.bytes_up) < 0.25,
        "up bytes local {} vs tcp {}",
        l.bytes_up,
        t.bytes_up
    );
}

#[test]
fn asyn_beats_dist_wall_clock_with_stragglers() {
    // The headline behaviour on real threads: inject a heavy-tailed
    // straggler on every worker; the barrier in SFW-dist pays the max
    // delay every round, SFW-asyn only pays it on the straggling worker's
    // own updates.  Compare wall-clock to the same iteration count.
    let iters = 60;
    let spec = TrainSpec::new(ms(520, 10, 2_000))
        .iterations(iters)
        .tau(16)
        .workers(4)
        .batch(BatchSchedule::Constant(64))
        .eval_every(iters)
        .seed(521)
        .power_iters(40)
        .straggler(Straggler { unit: Duration::from_micros(50), p: 0.35 });
    let t0 = std::time::Instant::now();
    let _ = spec.clone().algo("sfw-asyn").run().expect("asyn");
    let asyn_time = t0.elapsed().as_secs_f64();

    let t1 = std::time::Instant::now();
    let _ = spec.clone().algo("sfw-dist").run().expect("dist");
    let dist_time = t1.elapsed().as_secs_f64();
    assert!(
        asyn_time < dist_time,
        "asyn {asyn_time:.3}s should beat dist {dist_time:.3}s under stragglers"
    );
}

#[test]
fn comm_cost_ordering_matches_paper() {
    // Per-master-iteration upload bytes: SFW-asyn O(D1+D2) << SFW-dist
    // O(W * D1*D2); DFW-power total grows superlinearly with T.
    let iters = 40u64;
    let spec = TrainSpec::new(ms(530, 16, 2_000))
        .iterations(iters)
        .tau(8)
        .workers(4)
        .batch(BatchSchedule::Constant(64))
        .eval_every(iters)
        .seed(531)
        .power_iters(40)
        .dfw_rounds(1, 0.5);
    let a = spec.clone().algo("sfw-asyn").run().expect("asyn").snapshot();
    let di = spec.clone().algo("sfw-dist").run().expect("dist").snapshot();
    let df = spec.clone().algo("dfw-power").run().expect("dfw").snapshot();
    // asyn upload per accepted iteration ~ 4(d1+d2) + header
    let asyn_up_per_iter = a.bytes_up as f64 / a.iterations as f64;
    let dist_up_per_iter = di.bytes_up as f64 / di.iterations as f64;
    assert!(
        asyn_up_per_iter * 4.0 < dist_up_per_iter,
        "asyn {asyn_up_per_iter} B/iter should be <<  dist {dist_up_per_iter} B/iter"
    );
    // DFW-power's power rounds grow with t => avg bytes/iter exceeds asyn's
    let dfw_up_per_iter = df.bytes_up as f64 / df.iterations as f64;
    assert!(dfw_up_per_iter > asyn_up_per_iter);
}

#[test]
fn svrf_asyn_and_serial_svrf_reach_similar_quality() {
    // Alg 5 must not lose quality vs its serial counterpart at equal
    // inner-iteration counts (same epochs => same N_t sequence).
    use sfw::algo::engine::NativeEngine;
    use sfw::algo::svrf::{run_svrf, SvrfOptions};
    use sfw::metrics::{Counters, LossTrace};

    let task = ms(550, 10, 3_000);
    let obj = match &task {
        TaskSpec::Prebuilt(w) => w.objective(),
        _ => unreachable!(),
    };
    let counters = Counters::new();
    let trace = LossTrace::new();
    let mut engine = NativeEngine::new(obj.clone(), 50, 551);
    run_svrf(
        &mut engine,
        &SvrfOptions {
            epochs: 3,
            batch: BatchSchedule::Linear { scale: 24.0, cap: 1_024 },
            eval_every: 10,
            seed: 552,
            repr: sfw::linalg::Repr::Dense,
            ..SvrfOptions::default()
        },
        &counters,
        &trace,
    );
    let serial_final = trace.points().last().unwrap().loss;

    let r = TrainSpec::new(task)
        .algo("svrf-asyn")
        .epochs(3)
        .tau(8)
        .workers(3)
        .batch(BatchSchedule::Linear { scale: 24.0, cap: 1_024 })
        .eval_every(10)
        .seed(552)
        .power_iters(50)
        .run()
        .expect("svrf-asyn");
    let asyn_final = r.points().last().unwrap().loss;
    // staleness may cost a constant factor but not an order of magnitude
    assert!(
        asyn_final < 10.0 * serial_final + 1e-3,
        "SVRF-asyn {asyn_final} vs serial SVRF {serial_final}"
    );
    assert_eq!(r.snapshot().iterations, 50); // 6 + 14 + 30
}

#[test]
fn workers_terminate_when_master_reaches_t() {
    // Liveness/cleanup: after T accepted updates every worker gets Stop
    // and joins — the run returning at all proves it, but also check no
    // pending messages are lost (counters consistent).
    let r = TrainSpec::new(ms(560, 8, 1_000))
        .algo("sfw-asyn")
        .iterations(25)
        .tau(4)
        .workers(6)
        .batch(BatchSchedule::Constant(16))
        .eval_every(25)
        .seed(561)
        .power_iters(30)
        .run()
        .expect("train");
    let s = r.snapshot();
    assert_eq!(s.iterations, 25);
    // every up-message was either accepted or dropped
    assert!(s.msgs_up >= s.iterations + s.dropped_updates);
    // every accepted/dropped update got a reply, plus W stop messages
    assert!(s.msgs_down >= s.iterations + s.dropped_updates);
}

#[test]
fn delay_gate_staleness_never_exceeds_tau() {
    // Instrument via counters: with tau large enough no drops occur; with
    // tau = 0 and several workers, drops must occur, but accepted
    // iterations still hit T (liveness).
    let run = |tau: u64| {
        TrainSpec::new(ms(540, 8, 1_000))
            .algo("sfw-asyn")
            .iterations(50)
            .tau(tau)
            .workers(4)
            .batch(BatchSchedule::Constant(16))
            .eval_every(50)
            .seed(541)
            .power_iters(30)
            .run()
            .expect("train")
    };
    let loose = run(1_000);
    assert_eq!(loose.snapshot().dropped_updates, 0);
    assert_eq!(loose.snapshot().iterations, 50);
    let tight = run(0);
    assert!(tight.snapshot().dropped_updates > 0);
    assert_eq!(tight.snapshot().iterations, 50);
}

#[test]
fn tcp_transport_is_rejected_for_local_only_solvers() {
    let err = TrainSpec::new(ms(570, 8, 500))
        .algo("sva")
        .transport(Transport::Tcp)
        .run()
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("sva") && msg.contains("Tcp"), "unexpected error: {msg}");
    // registry-driven: the error lists the algorithms that DO support tcp
    for supporter in ["sfw-asyn", "svrf-asyn", "sfw-dist"] {
        assert!(msg.contains(supporter), "error should list '{supporter}': {msg}");
    }
}

#[test]
fn svrf_asyn_runs_over_tcp_with_local_quality() {
    // Same seed, both transports: identical inner-iteration counts and
    // comparable convergence (async arrival order may differ, so this is
    // a quality bound, not bitwise equality).
    let spec = TrainSpec::new(ms(580, 8, 1_500))
        .algo("svrf-asyn")
        .epochs(3)
        .tau(8)
        .workers(3)
        .batch(BatchSchedule::Constant(32))
        .eval_every(10)
        .seed(581)
        .power_iters(40);
    let local = spec.clone().transport(Transport::Local).run().expect("local");
    let tcp = spec.clone().transport(Transport::Tcp).run().expect("tcp");
    for (name, r) in [("local", &local), ("tcp", &tcp)] {
        let pts = r.points();
        assert!(
            pts.last().unwrap().loss < 0.5 * pts.first().unwrap().loss,
            "{name}: no progress"
        );
        let s = r.snapshot();
        assert_eq!(s.iterations, 50, "{name}: 6 + 14 + 30 inner iterations"); // N_t sums
        assert!(s.bytes_up > 0 && s.bytes_down > 0, "{name}: comm not accounted");
    }
}

#[test]
fn sfw_dist_is_bit_identical_across_transports() {
    // SFW-dist reduces worker replies in rank order, so a fixed seed must
    // produce the same iterate over channels and over real sockets — and
    // since both transports charge exact frame sizes, the same byte
    // totals too.
    let spec = TrainSpec::new(ms(590, 8, 1_200))
        .algo("sfw-dist")
        .iterations(40)
        .workers(3)
        .batch(BatchSchedule::Constant(48))
        .eval_every(10)
        .seed(591)
        .power_iters(40);
    let local = spec.clone().transport(Transport::Local).run().expect("local");
    let tcp = spec.clone().transport(Transport::Tcp).run().expect("tcp");
    assert_eq!(local.x.data, tcp.x.data, "iterates diverged across transports");
    let (l, t) = (local.snapshot(), tcp.snapshot());
    assert_eq!(l.iterations, t.iterations);
    assert_eq!(l.bytes_up, t.bytes_up, "uplink byte accounting diverged");
    assert_eq!(l.bytes_down, t.bytes_down, "downlink byte accounting diverged");
    assert_eq!(local.final_loss(), tcp.final_loss());
}

#[test]
fn sfw_asyn_same_seed_tcp_matches_local_convergence() {
    let spec = TrainSpec::new(ms(600, 8, 1_200))
        .algo("sfw-asyn")
        .iterations(60)
        .tau(8)
        .workers(2)
        .batch(BatchSchedule::Constant(32))
        .eval_every(30)
        .seed(601)
        .power_iters(40);
    let local = spec.clone().transport(Transport::Local).run().expect("local");
    let tcp = spec.clone().transport(Transport::Tcp).run().expect("tcp");
    assert_eq!(local.snapshot().iterations, tcp.snapshot().iterations);
    for (name, r) in [("local", &local), ("tcp", &tcp)] {
        let pts = r.points();
        assert!(
            pts.last().unwrap().loss < 0.5 * pts.first().unwrap().loss,
            "{name}: no progress"
        );
    }
}

#[test]
fn multi_process_workers_over_loopback() {
    // The full multi-process path, exactly as a user would run it: the
    // master awaits external workers on an ephemeral loopback port, and
    // two real `sfw worker` processes (the launcher binary) join by rank.
    // Workers regenerate the dataset from the same task/seed flags.
    use std::process::{Command, Stdio};

    let (tx, rx) = std::sync::mpsc::channel::<std::net::SocketAddr>();
    let tx = std::sync::Mutex::new(tx);
    let spec = TrainSpec::new(TaskSpec::ms(8, 2, 400, 0.05))
        .algo("sfw-asyn")
        .transport(Transport::Tcp)
        .tcp_await(true)
        .bound_notify(move |addr| {
            let _ = tx.lock().unwrap().send(addr);
        })
        .iterations(20)
        .tau(4)
        .workers(2)
        .batch(BatchSchedule::Constant(16))
        .eval_every(10)
        .seed(42)
        .power_iters(20);
    let master = std::thread::spawn(move || spec.run().expect("master run"));
    let addr = rx
        .recv_timeout(Duration::from_secs(60))
        .expect("master never published its bound address");

    let bin = env!("CARGO_BIN_EXE_sfw");
    let mut children = Vec::new();
    for rank in 0..2 {
        let child = Command::new(bin)
            .args([
                "worker",
                "--connect",
                &addr.to_string(),
                "--rank",
                &rank.to_string(),
                "--algo",
                "sfw-asyn",
                "--task",
                "matrix_sensing",
                "--data.ms-d",
                "8",
                "--data.ms-rank",
                "2",
                "--data.ms-n",
                "400",
                "--data.ms-noise",
                "0.05",
                "--seed",
                "42",
                "--batch",
                "16",
                "--tau",
                "4",
                "--power-iters",
                "20",
            ])
            .stdout(Stdio::null())
            .spawn()
            .expect("spawn sfw worker process");
        children.push(child);
    }

    let report = master.join().expect("master thread");
    for mut child in children {
        let status = child.wait().expect("wait for worker process");
        assert!(status.success(), "worker process failed: {status}");
    }
    let s = report.snapshot();
    assert_eq!(s.iterations, 20);
    assert!(s.bytes_up > 0 && s.bytes_down > 0, "no wire traffic accounted");
    let pts = report.points();
    assert!(!pts.is_empty() && pts.last().unwrap().loss.is_finite());
}
