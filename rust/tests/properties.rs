//! Cross-module property tests (seeded mini-prop runner; see
//! `sfw::util::prop`).  These pin the system-level invariants the paper's
//! correctness rests on.

use std::sync::Arc;

use sfw::algo::engine::{NativeEngine, StepEngine};
use sfw::algo::init_rank_one;
use sfw::comms::{frame, GradCodec, Wire};
use sfw::coordinator::messages::{DistDown, DistUp, LogEntry, MasterMsg, UpdateMsg};
use sfw::coordinator::update_log::{replay, replay_after, UpdateLog};
use sfw::data::matrix_sensing::{MatrixSensingData, MsParams};
use sfw::linalg::{jacobi_svd, nuclear_ball_projection, nuclear_norm, Mat};
use sfw::objective::{MatrixSensing, Objective};
use sfw::prop_assert;
use sfw::util::prop::check;
use sfw::util::rng::Rng;

#[test]
fn prop_iterates_stay_in_nuclear_ball_under_any_update_sequence() {
    check("nuclear-ball-invariant", 600, 30, |rng| {
        let d1 = 2 + rng.next_below(8);
        let d2 = 2 + rng.next_below(8);
        let theta = 0.5 + rng.next_f32() * 2.0;
        let mut log = UpdateLog::new();
        let mut x = init_rank_one(d1, d2, theta, &mut rng.fork(9));
        for _ in 0..20 {
            let u = rng.unit_vector(d1);
            let v = rng.unit_vector(d2);
            log.append(u, v, theta);
        }
        replay(&mut x, &log.slice_from(0));
        let nn = nuclear_norm(&x);
        prop_assert!(
            nn <= theta as f64 * (1.0 + 1e-4),
            "||X||_* = {nn} > theta = {theta}"
        );
        Ok(())
    });
}

#[test]
fn prop_replay_after_is_idempotent() {
    check("replay-idempotent", 610, 30, |rng| {
        let d = 3 + rng.next_below(5);
        let theta = 1.0f32;
        let mut log = UpdateLog::new();
        for _ in 0..12 {
            let u = rng.unit_vector(d);
            let v = rng.unit_vector(d);
            log.append(u, v, theta);
        }
        let x0 = init_rank_one(d, d, theta, &mut rng.fork(3));
        // reference: single clean replay
        let mut x_ref = x0.clone();
        replay(&mut x_ref, &log.slice_from(0));
        // adversarial: overlapping slices with repeats
        let mut x = x0.clone();
        let mut t = 0u64;
        let cut1 = rng.next_below(12) as u64;
        let cut2 = rng.next_below(12) as u64;
        t = replay_after(&mut x, &log.slice_from(0.min(cut1)), t);
        t = replay_after(&mut x, &log.slice_from(cut1.min(t)), t);
        t = replay_after(&mut x, &log.slice_from(cut2.min(t)), t);
        t = replay_after(&mut x, &log.slice_from(0), t);
        prop_assert!(t == 12, "t = {t}");
        let mut diff = x.clone();
        diff.axpy(-1.0, &x_ref);
        prop_assert!(diff.frob_norm() < 1e-5, "idempotence violated: {}", diff.frob_norm());
        Ok(())
    });
}

/// encode -> decode through the real framing must be the identity.
fn roundtrip<W: Wire>(msg: &W) -> Result<W, String> {
    let f = frame(msg);
    let len = u32::from_le_bytes(f[..4].try_into().unwrap()) as usize;
    if len != f.len() - sfw::comms::FRAME_HEADER {
        return Err(format!("frame length prefix {len} vs payload {}", f.len() - 5));
    }
    W::decode(f[4], &f[sfw::comms::FRAME_HEADER..]).map_err(|e| format!("decode: {e}"))
}

/// The byte accounting every transport charges must equal the actual
/// encoded frame length — the paper's comm-cost numbers hang on this.
fn wire_bytes_exact<W: Wire>(msg: &W) -> Result<(), String> {
    let actual = frame(msg).len() as u64;
    if msg.wire_bytes() != actual {
        return Err(format!("wire_bytes {} vs encoded frame {actual}", msg.wire_bytes()));
    }
    Ok(())
}

#[test]
fn prop_every_wire_message_roundtrips_with_exact_byte_accounting() {
    check("wire-roundtrip", 620, 40, |rng| {
        let d1 = 1 + rng.next_below(40);
        let d2 = 1 + rng.next_below(40);

        // --- asyn protocol: UpdateMsg up, MasterMsg down -------------
        let upd = UpdateMsg::dense(
            rng.next_below(16) as u32,
            rng.next_u64() % 10_000,
            (0..d1).map(|_| rng.normal_f32()).collect(),
            (0..d2).map(|_| rng.normal_f32()).collect(),
            rng.normal_f32(),
            rng.normal(),
            rng.next_below(10_000) as u32,
            rng.normal(),
        );
        let rt = roundtrip(&upd)?;
        prop_assert!(rt.u == upd.u && rt.v == upd.v, "vectors corrupted");
        prop_assert!(rt.t_w == upd.t_w && rt.m == upd.m, "header corrupted");
        prop_assert!(rt.gap.to_bits() == upd.gap.to_bits(), "gap corrupted");
        wire_bytes_exact(&upd)?;

        // quantized uplink variants: quantization happens ONCE at
        // construction, so encode -> decode is the exact identity and
        // struct equality must hold through the real framing
        for codec in [GradCodec::Bf16, GradCodec::Int8] {
            let q = UpdateMsg::quantized(
                codec,
                upd.worker_id,
                upd.t_w,
                upd.u.clone(),
                upd.v.clone(),
                upd.sigma,
                upd.loss_sum,
                upd.m,
                upd.gap,
            );
            let rt = roundtrip(&q)?;
            prop_assert!(rt == q, "{} UpdateMsg not exact through the wire", codec.label());
            wire_bytes_exact(&q)?;
            // the shrink (int8's 8 scale bytes amortize from n >= 3)
            if d1 + d2 >= 8 {
                prop_assert!(
                    q.wire_bytes() < upd.wire_bytes(),
                    "{} UpdateMsg ({} B) no smaller than f32 ({} B)",
                    codec.label(),
                    q.wire_bytes(),
                    upd.wire_bytes()
                );
            }
        }

        let entries: Vec<LogEntry> = (1..=3)
            .map(|k| LogEntry {
                k,
                eta: rng.next_f32(),
                scale: -1.0,
                u: Arc::new((0..d1).map(|_| rng.normal_f32()).collect()),
                v: Arc::new((0..d2).map(|_| rng.normal_f32()).collect()),
            })
            .collect();
        for msg in [
            MasterMsg::Updates { t_m: 3, entries: entries.clone() },
            MasterMsg::UpdateW { t_m: 3, entries: entries.clone() },
        ] {
            match roundtrip(&msg)? {
                MasterMsg::Updates { t_m, entries: back }
                | MasterMsg::UpdateW { t_m, entries: back } => {
                    prop_assert!(t_m == 3, "t_m");
                    prop_assert!(back.len() == 3, "len");
                    for (a, b) in back.iter().zip(&entries) {
                        prop_assert!(*a.u == *b.u && *a.v == *b.v && a.k == b.k, "entry");
                    }
                }
                MasterMsg::Stop => return Err("variant flipped to Stop".into()),
            }
            wire_bytes_exact(&msg)?;
        }
        prop_assert!(
            matches!(roundtrip(&MasterMsg::Stop)?, MasterMsg::Stop),
            "Stop corrupted"
        );
        wire_bytes_exact(&MasterMsg::Stop)?;

        // --- dist protocol: DistUp up, DistDown down -----------------
        let x = Mat::randn(d1, d2, 1.0, &mut rng.fork(7));
        let down = DistDown::Compute {
            k: rng.next_u64() % 1_000,
            m_share: rng.next_below(512) as u32,
            x: Arc::new(x.clone()),
        };
        match roundtrip(&down)? {
            DistDown::Compute { x: back, .. } => {
                prop_assert!(*back == x, "dist iterate corrupted")
            }
            DistDown::Stop => return Err("dist variant flipped".into()),
        }
        wire_bytes_exact(&down)?;
        wire_bytes_exact(&DistDown::Stop)?;

        let up = DistUp::dense(
            rng.next_below(16) as u32,
            rng.next_u64() % 10_000,
            rng.normal(),
            Mat::randn(d1, d2, 1.0, &mut rng.fork(8)),
        );
        let rt = roundtrip(&up)?;
        prop_assert!(rt.grad == up.grad, "dist gradient corrupted");
        prop_assert!(
            rt.worker_id == up.worker_id && rt.k == up.k,
            "dist header corrupted"
        );
        wire_bytes_exact(&up)?;

        // quantized dense-gradient uplink: same exact-identity contract
        for codec in [GradCodec::Bf16, GradCodec::Int8] {
            let q = DistUp::quantized(codec, up.worker_id, up.k, up.loss_sum, up.grad.clone());
            let rt = roundtrip(&q)?;
            prop_assert!(rt == q, "{} DistUp not exact through the wire", codec.label());
            wire_bytes_exact(&q)?;
            // int8's per-row scale amortizes from cols >= 2; bf16 always
            if d2 >= 2 {
                prop_assert!(
                    q.wire_bytes() < up.wire_bytes(),
                    "{} DistUp ({} B) no smaller than f32 ({} B)",
                    codec.label(),
                    q.wire_bytes(),
                    up.wire_bytes()
                );
            }
        }

        // --- factored dist downlink: atoms instead of the dense X ----
        let n_entries = rng.next_below(4);
        let fdown = DistDown::ComputeFactored {
            k: rng.next_u64() % 1_000,
            m_share: rng.next_below(512) as u32,
            entries: (1..=n_entries as u64)
                .map(|k| LogEntry {
                    k,
                    eta: rng.next_f32(),
                    scale: -1.0,
                    u: Arc::new((0..d1).map(|_| rng.normal_f32()).collect()),
                    v: Arc::new((0..d2).map(|_| rng.normal_f32()).collect()),
                })
                .collect(),
        };
        match roundtrip(&fdown)? {
            DistDown::ComputeFactored { entries: back, .. } => {
                prop_assert!(back.len() == n_entries, "entry count corrupted");
                if let DistDown::ComputeFactored { entries, .. } = &fdown {
                    for (a, b) in back.iter().zip(entries) {
                        prop_assert!(
                            *a.u == *b.u && *a.v == *b.v && a.k == b.k,
                            "factored entry corrupted"
                        );
                    }
                }
            }
            _ => return Err("factored dist variant flipped".into()),
        }
        wire_bytes_exact(&fdown)?;
        // the factored frame is O(d1 + d2) per entry, never O(d1 * d2)
        prop_assert!(
            fdown.wire_bytes() <= 21 + n_entries as u64 * (28 + 4 * (d1 + d2) as u64),
            "factored downlink over budget"
        );
        Ok(())
    });
}

#[test]
fn wire_errors_classify_bad_tags_and_malformed_payloads() {
    use sfw::comms::{Dec, Enc, WireError};
    // a frame carrying any tag but the message's own is BadTag, and the
    // error names the offending tag byte
    let upd = UpdateMsg::dense(1, 2, vec![1.0], vec![2.0], 3.0, 4.0, 5, 6.0);
    let f = frame(&upd);
    let bad = upd.tag().wrapping_add(1);
    match UpdateMsg::decode(bad, &f[sfw::comms::FRAME_HEADER..]).err() {
        Some(WireError::BadTag(t)) => assert_eq!(t, bad),
        other => panic!("expected BadTag, got {other:?}"),
    }
    // a matrix header whose byte budget overflows usize is Malformed —
    // rejected by arithmetic, never attempted as an allocation
    let mut buf = Vec::new();
    let mut e = Enc(&mut buf);
    e.u32(u32::MAX);
    e.u32(u32::MAX);
    match Dec::new(&buf).mat().err() {
        Some(WireError::Malformed(what)) => assert!(what.contains("overflow"), "{what}"),
        other => panic!("expected Malformed, got {other:?}"),
    }
}

#[test]
fn quantized_frames_classify_truncation_and_trailing_bytes() {
    // Every uplink codec variant embeds its vector lengths in the
    // payload, so any strict prefix under-supplies a read and any
    // trailing byte trips the final length check — both must come back
    // as WireError (classification), never a panic or a silent accept.
    fn assert_classified<W: Wire>(what: &str, msg: &W) {
        let f = frame(msg);
        let tag = f[4];
        let payload = &f[sfw::comms::FRAME_HEADER..];
        for cut in 0..payload.len() {
            assert!(
                W::decode(tag, &payload[..cut]).is_err(),
                "{what}: decode accepted a {cut}-byte prefix of {} bytes",
                payload.len()
            );
        }
        let mut long = payload.to_vec();
        long.push(0);
        assert!(W::decode(tag, &long).is_err(), "{what}: decode accepted a trailing byte");
    }
    let mut rng = Rng::new(663);
    let u: Vec<f32> = (0..9).map(|_| rng.normal_f32()).collect();
    let v: Vec<f32> = (0..7).map(|_| rng.normal_f32()).collect();
    let grad = Mat::randn(5, 6, 1.0, &mut rng.fork(1));
    for codec in [GradCodec::F32, GradCodec::Bf16, GradCodec::Int8] {
        assert_classified(
            &format!("UpdateMsg/{}", codec.label()),
            &UpdateMsg::quantized(codec, 3, 11, u.clone(), v.clone(), 0.5, 1.5, 32, 0.25),
        );
        assert_classified(
            &format!("DistUp/{}", codec.label()),
            &DistUp::quantized(codec, 1, 4, 0.25, grad.clone()),
        );
    }
}

#[test]
fn prop_batch_schedules_honor_monotonicity_caps_and_floors() {
    // The theorem-bearing schedules: Increasing (SFW/SFW-asyn, Thm 1)
    // and Linear (SVRF-asyn, Thm 2) must be nondecreasing in k, clamped
    // to [1, cap]; Constant must be invariant in k.
    use sfw::algo::schedule::BatchSchedule;
    check("batch-schedule-shape", 670, 60, |rng| {
        let scale = rng.next_f64() * 4.0 + 1e-6;
        let cap = 1 + rng.next_below(5_000);
        for schedule in [
            BatchSchedule::Increasing { scale, cap },
            BatchSchedule::Linear { scale, cap },
        ] {
            let mut prev = 0usize;
            for k in 1..=200u64 {
                let m = schedule.m(k);
                prop_assert!(m >= 1, "{schedule:?}: m({k}) = {m} below floor");
                prop_assert!(m <= cap, "{schedule:?}: m({k}) = {m} above cap {cap}");
                prop_assert!(
                    m >= prev,
                    "{schedule:?}: m({k}) = {m} < m({}) = {prev} (not monotone)",
                    k - 1
                );
                prev = m;
            }
            // once the cap binds it stays bound
            if schedule.m(200) == cap {
                prop_assert!(schedule.m(10_000) == cap, "cap released");
            }
        }
        let m0 = 1 + rng.next_below(10_000);
        let constant = BatchSchedule::Constant(m0);
        for k in [1u64, 7, 100, 1 << 40] {
            prop_assert!(constant.m(k) == m0, "Constant varied at k={k}");
        }
        // the degenerate Constant(0) still floors at 1
        prop_assert!(BatchSchedule::Constant(0).m(1) == 1, "zero batch not floored");
        Ok(())
    });
}

#[test]
fn prop_asyn_schedule_is_tau_squared_cheaper_and_eta_bounded() {
    use sfw::algo::schedule::{eta, BatchSchedule};
    check("asyn-schedule-and-eta", 680, 40, |rng| {
        // eta_k = 2/(k+1): exactly the theorem value, in (0, 1], and
        // strictly decreasing
        for k in 1..=500u64 {
            let e = eta(k);
            let exact = 2.0 / (k as f32 + 1.0);
            prop_assert!((e - exact).abs() < 1e-7, "eta({k}) = {e} != {exact}");
            prop_assert!(e > 0.0 && e <= 1.0, "eta({k}) = {e} out of (0, 1]");
            if k > 1 {
                prop_assert!(e < eta(k - 1), "eta not decreasing at {k}");
            }
        }
        // SFW-asyn's batch is ~tau^2 smaller than SFW's at the same k
        // (Thm 1) wherever neither cap nor floor binds
        let tau = 2 + rng.next_below(7) as u64;
        let scale = 1.0 + rng.next_f64() * 3.0;
        let sfw = BatchSchedule::sfw(scale, usize::MAX);
        let asyn = BatchSchedule::sfw_asyn(scale, tau, usize::MAX);
        for k in [20u64, 100, 400] {
            let (a, b) = (sfw.m(k) as f64, asyn.m(k) as f64);
            let want = (tau * tau) as f64;
            prop_assert!(
                (a / b - want).abs() / want < 0.25,
                "tau={tau} k={k}: ratio {} vs tau^2 {want}",
                a / b
            );
        }
        Ok(())
    });
}

#[test]
fn prop_lmo_optimality_against_exact_svd() {
    // <G, -theta u v^T> from the power-iteration LMO must be within 1% of
    // the exact best rank-one value (-theta sigma_max).
    check("lmo-optimal", 640, 15, |rng| {
        let d1 = 4 + rng.next_below(12);
        let d2 = 4 + rng.next_below(12);
        let mut g = Mat::randn(d1, d2, 1.0, &mut rng.fork(1));
        // separation boost keeps 200 iters plenty
        let u = rng.unit_vector(d1);
        let v = rng.unit_vector(d2);
        for i in 0..d1 {
            for j in 0..d2 {
                *g.at_mut(i, j) += 3.0 * ((d1 * d2) as f32).sqrt() * u[i] * v[j];
            }
        }
        let s = sfw::linalg::power_iteration_rand(&g, rng, 200, 1e-12);
        let (_, sv, _) = jacobi_svd(&g);
        prop_assert!(
            (s.sigma - sv[0]).abs() / sv[0] < 1e-2,
            "power sigma {} vs svd {}",
            s.sigma,
            sv[0]
        );
        Ok(())
    });
}

#[test]
fn prop_projection_never_increases_distance_to_feasible_points() {
    check("projection-contraction", 650, 10, |rng| {
        let d = 4 + rng.next_below(5);
        let x = Mat::randn(d, d, 1.5, &mut rng.fork(2));
        let p = nuclear_ball_projection(&x, 1.0);
        prop_assert!(nuclear_norm(&p) <= 1.0 + 1e-3, "infeasible projection");
        // obtuseness: for feasible f, <x - p, f - p> <= 0
        for _ in 0..5 {
            let u = rng.unit_vector(d);
            let v = rng.unit_vector(d);
            let mut f = Mat::zeros(d, d);
            for i in 0..d {
                for j in 0..d {
                    *f.at_mut(i, j) = 0.9 * u[i] * v[j];
                }
            }
            let mut xp = x.clone();
            xp.axpy(-1.0, &p);
            let mut fp = f.clone();
            fp.axpy(-1.0, &p);
            let inner = xp.inner(&fp);
            prop_assert!(inner <= 1e-3, "obtuse-angle violated: {inner}");
        }
        Ok(())
    });
}

#[test]
fn prop_native_step_invariant_to_batch_permutation() {
    // grad_sum is a sum — permuting the index set cannot change the step.
    check("batch-permutation", 660, 10, |rng| {
        let mut data_rng = Rng::new(661);
        let p = MsParams { d1: 6, d2: 6, rank: 2, n: 500, noise_std: 0.05 };
        let obj: Arc<dyn Objective> = Arc::new(MatrixSensing::new(
            MatrixSensingData::generate(&p, &mut data_rng),
            1.0,
        ));
        let mut engine = NativeEngine::new(obj.clone(), 50, 662);
        let x = Mat::randn(6, 6, 0.2, &mut rng.fork(4));
        let mut idx: Vec<usize> = (0..64).map(|_| rng.next_below(500)).collect();
        let mut g1 = Mat::zeros(6, 6);
        let l1 = engine.grad_sum(&x, &idx, &mut g1);
        // reverse = a permutation
        idx.reverse();
        let mut g2 = Mat::zeros(6, 6);
        let l2 = engine.grad_sum(&x, &idx, &mut g2);
        let mut d = g1.clone();
        d.axpy(-1.0, &g2);
        prop_assert!(d.frob_norm() < 1e-4, "permutation changed gradient");
        prop_assert!((l1 - l2).abs() < 1e-6, "permutation changed loss");
        Ok(())
    });
}
