//! The `sfw lint` contract, fixture by fixture:
//!
//! * every file under `rust/src/lint/fixtures/` triggers exactly the
//!   rule it is named after (and nothing else);
//! * `clean.rs` — a file using every annotation mechanism correctly —
//!   triggers nothing while still exercising the suppression path;
//! * the real tree (`rust/src` + `rust/tests` under the repo config)
//!   is clean, which is the same gate `scripts/ci.sh` runs.

use sfw::lint::{
    cross_file_violations, lint_repo, scan_source, CrossFileInput, LintConfig, Rule, Violation,
};

/// The narrowed config the fixtures are written against: the fixture
/// directory itself is the "hot module", and the audited error enum is
/// the fixture-local `GhostError`.
fn fixture_cfg() -> LintConfig {
    LintConfig {
        hot_modules: vec!["/fixtures/".to_string()],
        error_enums: vec!["GhostError".to_string()],
        skip: Vec::new(),
        property_tests: vec!["properties.rs".to_string()],
    }
}

/// Run one fixture through the full per-file + cross-file pipeline with
/// an empty property-test corpus and no external variant uses.
fn lint_fixture(name: &str) -> (Vec<Violation>, usize) {
    let path = format!(
        "{}/rust/src/lint/fixtures/{name}",
        env!("CARGO_MANIFEST_DIR")
    );
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("cannot read fixture {path}: {e}"));
    let scan = scan_source(&path, &src, &fixture_cfg());
    let mut violations = scan.violations.clone();
    let suppressed = scan.suppressed.len();
    let input = CrossFileInput {
        scans: vec![scan],
        property_text: String::new(),
        test_uses: Vec::new(),
    };
    violations.extend(cross_file_violations(&input, std::slice::from_ref(&path)));
    (violations, suppressed)
}

/// Assert the fixture trips its own rule exactly once and no other.
fn assert_triggers_exactly(name: &str, rule: Rule) {
    let (violations, _) = lint_fixture(name);
    assert_eq!(
        violations.len(),
        1,
        "{name}: expected exactly one violation, got {violations:#?}"
    );
    assert_eq!(violations[0].rule, rule, "{name}: {violations:#?}");
}

#[test]
fn panic_free_fixture_triggers_its_rule() {
    assert_triggers_exactly("panic_free.rs", Rule::PanicFree);
}

#[test]
fn safety_comment_fixture_triggers_its_rule() {
    assert_triggers_exactly("safety_comment.rs", Rule::SafetyComment);
}

#[test]
fn wire_coverage_fixture_triggers_its_rule() {
    assert_triggers_exactly("wire_coverage.rs", Rule::WireCoverage);
}

#[test]
fn no_lock_across_io_fixture_triggers_its_rule() {
    assert_triggers_exactly("no_lock_across_io.rs", Rule::NoLockAcrossIo);
}

#[test]
fn bounded_channel_fixture_triggers_its_rule() {
    assert_triggers_exactly("bounded_channel.rs", Rule::BoundedChannelDepth);
}

#[test]
fn error_liveness_fixture_triggers_its_rule() {
    assert_triggers_exactly("error_liveness.rs", Rule::ErrorVariantLiveness);
    let (violations, _) = lint_fixture("error_liveness.rs");
    assert!(
        violations[0].message.contains("GhostError::Vanished"),
        "{violations:#?}"
    );
}

#[test]
fn reasonless_allow_is_a_bad_allow_and_still_suppresses() {
    // The finding under the allow is suppressed (one suppression, no
    // panic-free violation) — the actionable report is the allow itself.
    let (violations, suppressed) = lint_fixture("bad_allow.rs");
    assert_eq!(violations.len(), 1, "{violations:#?}");
    assert_eq!(violations[0].rule, Rule::BadAllow, "{violations:#?}");
    assert_eq!(suppressed, 1);
}

#[test]
fn clean_fixture_triggers_nothing_while_exercising_suppression() {
    let (violations, suppressed) = lint_fixture("clean.rs");
    assert!(violations.is_empty(), "{violations:#?}");
    assert_eq!(suppressed, 1, "the justified allow should register once");
}

#[test]
fn the_real_tree_is_clean_under_the_repo_config() {
    let root = env!("CARGO_MANIFEST_DIR");
    let report = lint_repo(
        &format!("{root}/rust/src"),
        &format!("{root}/rust/tests"),
        &LintConfig::repo(),
    )
    .expect("scan the repo tree");
    assert!(report.is_clean(), "\n{}", report.render_table());
    assert!(
        report.files_scanned > 40,
        "suspiciously few files scanned ({})",
        report.files_scanned
    );
    // the repo legitimately carries a handful of justified allows
    assert!(report.suppressed > 0, "expected at least one justified allow");
}

#[test]
fn report_table_and_json_name_every_finding() {
    let (violations, _) = lint_fixture("panic_free.rs");
    let report = sfw::lint::LintReport { files_scanned: 1, suppressed: 0, violations };
    let table = report.render_table();
    assert!(table.contains("panic-free"), "{table}");
    assert!(table.contains("panic_free.rs"), "{table}");
    let json = report.to_json().render();
    assert!(json.contains("\"sfw.lint/v1\""), "{json}");
    assert!(json.contains("\"panic-free\""), "{json}");
    assert!(!report.is_clean());
}
