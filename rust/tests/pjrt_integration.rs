//! Integration: the PJRT (AOT JAX/Pallas) engine must agree with the
//! native Rust engine on every module family, and SFW-asyn must train
//! end-to-end through the artifacts.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use std::sync::Arc;

use sfw::algo::engine::{NativeEngine, StepEngine};
use sfw::data::matrix_sensing::{MatrixSensingData, MsParams};
use sfw::data::pnn::{PnnData, PnnParams};
use sfw::linalg::{nuclear_norm, Mat};
use sfw::objective::{MatrixSensing, Objective, Pnn};
use sfw::runtime::{PjrtEngine, PjrtRuntime, Workload};
use sfw::util::rng::Rng;

fn runtime() -> Option<Arc<PjrtRuntime>> {
    match PjrtRuntime::new("artifacts") {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts` first): {e}");
            None
        }
    }
}

fn ms_objective(seed: u64, n: usize) -> Arc<MatrixSensing> {
    let mut rng = Rng::new(seed);
    let p = MsParams { d1: 30, d2: 30, rank: 3, n, noise_std: 0.1 };
    Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0))
}

fn pnn_objective(seed: u64, n: usize, d: usize) -> Arc<Pnn> {
    let mut rng = Rng::new(seed);
    let p = PnnParams { d, n, teacher_rank: 3, mixture_components: 6 };
    Arc::new(Pnn::new(PnnData::generate(&p, &mut rng), 1.0))
}

#[test]
fn ms_grad_pjrt_matches_native() {
    let Some(rt) = runtime() else { return };
    let obj = ms_objective(300, 2_000);
    let o: Arc<dyn Objective> = obj.clone();
    let mut native = NativeEngine::new(o.clone(), 64, 301);
    let mut pjrt = PjrtEngine::new(rt, Workload::Ms(obj.clone()), 301);
    let mut rng = Rng::new(302);
    for m in [5usize, 128, 200] {
        let x = Mat::randn(30, 30, 0.1, &mut rng);
        let idx: Vec<usize> = (0..m).map(|_| rng.next_below(2_000)).collect();
        let mut gn = Mat::zeros(30, 30);
        let ln = native.grad_sum(&x, &idx, &mut gn);
        let mut gp = Mat::zeros(30, 30);
        let lp = pjrt.grad_sum(&x, &idx, &mut gp);
        let mut d = gn.clone();
        d.axpy(-1.0, &gp);
        let rel = d.frob_norm() / gn.frob_norm().max(1e-12);
        assert!(rel < 1e-4, "m={m}: grad rel err {rel}");
        assert!(
            (ln - lp).abs() / ln.abs().max(1e-9) < 1e-4,
            "m={m}: loss {ln} vs {lp}"
        );
    }
}

#[test]
fn pnn_grad_pjrt_matches_native() {
    let Some(rt) = runtime() else { return };
    let d = rt.manifest().param_usize("pnn_d").unwrap();
    let obj = pnn_objective(310, 1_000, d);
    let o: Arc<dyn Objective> = obj.clone();
    let mut native = NativeEngine::new(o.clone(), 64, 311);
    let mut pjrt = PjrtEngine::new(rt, Workload::Pnn(obj.clone()), 311);
    let mut rng = Rng::new(312);
    let x = Mat::randn(d, d, 0.05, &mut rng);
    let idx: Vec<usize> = (0..100).map(|_| rng.next_below(1_000)).collect();
    let mut gn = Mat::zeros(d, d);
    let ln = native.grad_sum(&x, &idx, &mut gn);
    let mut gp = Mat::zeros(d, d);
    let lp = pjrt.grad_sum(&x, &idx, &mut gp);
    let mut diff = gn.clone();
    diff.axpy(-1.0, &gp);
    let rel = diff.frob_norm() / gn.frob_norm().max(1e-12);
    assert!(rel < 1e-4, "pnn grad rel err {rel}");
    assert!((ln - lp).abs() / ln.abs().max(1e-9) < 1e-4, "{ln} vs {lp}");
}

#[test]
fn lmo_pjrt_matches_native_sigma() {
    let Some(rt) = runtime() else { return };
    let obj = ms_objective(320, 500);
    let o: Arc<dyn Objective> = obj.clone();
    let mut native = NativeEngine::new(o.clone(), 200, 321);
    let mut pjrt = PjrtEngine::new(rt, Workload::Ms(obj.clone()), 321);
    let mut rng = Rng::new(322);
    // well-separated spectrum so 16 power iters suffice
    let u = rng.unit_vector(30);
    let v = rng.unit_vector(30);
    let mut g = Mat::randn(30, 30, 0.5, &mut rng);
    for i in 0..30 {
        for j in 0..30 {
            *g.at_mut(i, j) += 20.0 * u[i] * v[j];
        }
    }
    let sn = native.lmo(&g);
    let sp = pjrt.lmo(&g);
    assert!(
        (sn.sigma - sp.sigma).abs() / sn.sigma < 1e-3,
        "sigma {} vs {}",
        sn.sigma,
        sp.sigma
    );
    let align: f32 = sn.u.iter().zip(&sp.u).map(|(a, b)| a * b).sum();
    assert!(align.abs() > 0.999, "u misaligned: {align}");
}

#[test]
fn fused_step_pjrt_consistent_with_parts() {
    let Some(rt) = runtime() else { return };
    let obj = ms_objective(330, 1_000);
    let mut pjrt = PjrtEngine::new(rt, Workload::Ms(obj.clone()), 331);
    let mut rng = Rng::new(332);
    let x = Mat::randn(30, 30, 0.1, &mut rng);
    let idx: Vec<usize> = (0..128).map(|_| rng.next_below(1_000)).collect();
    let out = pjrt.step(&x, &idx);
    // loss from the fused module == loss from the grad module
    let mut g = Mat::zeros(30, 30);
    let loss2 = pjrt.grad_sum(&x, &idx, &mut g);
    assert!((out.loss_sum - loss2).abs() / loss2.abs().max(1e-9) < 1e-4);
    // sigma == u^T G v on the gradient from the grad module
    let mut gv = vec![0.0f32; 30];
    g.matvec(&out.v, &mut gv);
    let sigma2: f32 = out.u.iter().zip(&gv).map(|(a, b)| a * b).sum();
    assert!(
        (out.sigma - sigma2).abs() / out.sigma.abs().max(1e-9) < 1e-2,
        "sigma {} vs u^T G v {}",
        out.sigma,
        sigma2
    );
}

#[test]
fn sfw_asyn_trains_end_to_end_through_pjrt() {
    use sfw::session::{BatchSchedule, TaskSpec, TrainSpec};
    let Some(rt) = runtime() else { return };
    let obj = ms_objective(340, 4_000);
    let r = TrainSpec::new(TaskSpec::Prebuilt(Workload::Ms(obj)))
        .algo("sfw-asyn")
        .pjrt_runtime(rt)
        .iterations(60)
        .tau(8)
        .workers(2)
        .batch(BatchSchedule::Constant(128))
        .eval_every(10)
        .seed(341)
        .run()
        .expect("pjrt train");
    let pts = r.points();
    assert!(
        pts.last().unwrap().loss < 0.5 * pts.first().unwrap().loss,
        "PJRT e2e made no progress: {} -> {}",
        pts.first().unwrap().loss,
        pts.last().unwrap().loss
    );
    assert!(nuclear_norm(&r.x) <= 1.0 + 1e-3);
    assert_eq!(r.snapshot().iterations, 60);
}
