//! The factored-iterate contract (ROADMAP "Iterate representation"):
//!
//! * same-seed dense-vs-factored runs agree to f32 tolerance for EVERY
//!   registry solver, on every transport the solver supports;
//! * the factored sfw-dist downlink is measurably below the dense one
//!   (`bytes_down`), while the dense-gradient uplink stays identical;
//! * re-compression keeps the iterate within tolerance under a tight
//!   atom cap;
//! * `ReprKind::Auto` resolves per objective (pnn factored, ms dense)
//!   and the rank/peak-atom accounting lands in the `Report`.
//!
//! Deterministic worker counts are used where arrival order feeds the
//! float reduction (async/SVA/DFW run W = 1; sfw-dist reduces in rank
//! order, so W = 2 stays bit-deterministic).

use sfw::linalg::{FactoredMat, Iterate, Mat, Repr};
use sfw::session::{
    registry, BatchSchedule, EngineKind, Report, ReprKind, Solver, TaskSpec, TrainSpec,
    Transport,
};
use sfw::util::rng::Rng;

fn ms_task() -> TaskSpec {
    // non-square on purpose: catches row/col mixups in the factored path
    TaskSpec::MatrixSensing { d1: 10, d2: 8, rank: 2, n: 1_200, noise_std: 0.05 }
}

fn base_spec(algo: &str, workers: usize, transport: Transport) -> TrainSpec {
    TrainSpec::new(ms_task())
        .algo(algo)
        .workers(workers)
        .tau(4)
        .iterations(20)
        .epochs(2) // svrf-asyn: 6 + 14 = 20 inner iterations
        .batch(BatchSchedule::Constant(32))
        .eval_every(5)
        .power_iters(40)
        .seed(7)
        .transport(transport)
}

fn rel_frob_diff(a: &Mat, b: &Mat) -> f64 {
    let mut d = a.clone();
    d.axpy(-1.0, b);
    d.frob_norm() / (1.0 + a.frob_norm())
}

fn assert_reports_agree(what: &str, dense: &Report, fact: &Report) {
    let rel = rel_frob_diff(&dense.x, &fact.x);
    assert!(rel < 2e-2, "{what}: dense vs factored iterate diverged (rel {rel})");
    let dl = dense.final_loss();
    let fl = fact.final_loss();
    assert!(
        (dl - fl).abs() < 2e-2 * (1.0 + dl.abs()),
        "{what}: final loss {dl} vs {fl}"
    );
    // identical protocol traffic shape: same message counts both ways
    let (sd, sf) = (dense.snapshot(), fact.snapshot());
    assert_eq!(sd.iterations, sf.iterations, "{what}: iteration counts diverged");
    assert_eq!(sd.grad_evals, sf.grad_evals, "{what}: gradient counts diverged");
}

#[test]
fn every_registry_solver_agrees_dense_vs_factored_on_every_transport() {
    for solver in registry().iter() {
        let algo = solver.name();
        // deterministic worker counts (see module docs)
        let workers = if algo == "sfw-dist" { 2 } else { 1 };
        for &transport in solver.supported_transports() {
            let spec = base_spec(algo, workers, transport);
            let dense = spec.clone().repr(ReprKind::Dense).run().unwrap_or_else(|e| {
                panic!("{algo}/{transport:?} dense: {e}")
            });
            let fact = spec.clone().repr(ReprKind::Factored).run().unwrap_or_else(|e| {
                panic!("{algo}/{transport:?} factored: {e}")
            });
            let what = format!("{algo}/{transport:?}");
            assert_reports_agree(&what, &dense, &fact);
            assert_eq!(dense.peak_atoms, 0, "{what}: dense run reported atoms");
            assert!(fact.peak_atoms > 0, "{what}: factored run lost its atom accounting");
            assert!(fact.final_rank > 0, "{what}: factored run lost its rank");
            assert!(
                fact.spec_echo.contains("repr=factored"),
                "{what}: echo missing repr: {}",
                fact.spec_echo
            );
        }
    }
}

#[test]
fn factored_dist_downlink_beats_dense_on_both_transports() {
    for transport in [Transport::Local, Transport::Tcp] {
        let spec = base_spec("sfw-dist", 2, transport);
        let dense = spec.clone().repr(ReprKind::Dense).run().unwrap();
        let fact = spec.clone().repr(ReprKind::Factored).run().unwrap();
        let (sd, sf) = (dense.snapshot(), fact.snapshot());
        assert!(
            sf.bytes_down * 4 < sd.bytes_down,
            "{transport:?}: factored downlink {} B not measurably below dense {} B",
            sf.bytes_down,
            sd.bytes_down
        );
        // uplink ships dense partial gradients in both modes
        assert_eq!(sf.bytes_up, sd.bytes_up, "{transport:?}: uplink diverged");
        assert_eq!(sf.msgs_down, sd.msgs_down, "{transport:?}: message counts diverged");
    }
}

#[test]
fn factored_dist_is_deterministic_across_transports() {
    // Rank-order reduction + atoms-only broadcast: the factored run must
    // stay bit-identical local vs tcp, like the dense one (pinned by
    // tests/chaos.rs for dense).
    let run = |transport| {
        base_spec("sfw-dist", 2, transport)
            .repr(ReprKind::Factored)
            .run()
            .unwrap()
    };
    let local = run(Transport::Local);
    let tcp = run(Transport::Tcp);
    assert_eq!(local.x.data, tcp.x.data, "factored dist diverged across transports");
    let (sl, st) = (local.snapshot(), tcp.snapshot());
    assert_eq!(sl.bytes_down, st.bytes_down);
    assert_eq!(sl.bytes_up, st.bytes_up);
}

#[test]
fn pnn_task_agrees_and_defaults_to_factored() {
    let spec = TrainSpec::new(TaskSpec::pnn(10, 400))
        .algo("sfw")
        .iterations(15)
        .batch(BatchSchedule::Constant(32))
        .eval_every(5)
        .power_iters(30)
        .seed(9);
    // Auto resolves factored for pnn
    assert_eq!(spec.resolved_repr(), Repr::Factored);
    assert!(spec.echo().contains("repr=factored"), "{}", spec.echo());
    let auto = spec.clone().run().unwrap();
    let dense = spec.clone().repr(ReprKind::Dense).run().unwrap();
    assert!(auto.peak_atoms > 0);
    assert_eq!(dense.peak_atoms, 0);
    assert_reports_agree("pnn/sfw", &dense, &auto);
    // ms defaults dense; and auto stays dense on the PJRT engine, whose
    // artifacts take dense inputs (factored there would densify per step)
    assert_eq!(TrainSpec::new(ms_task()).resolved_repr(), Repr::Dense);
    assert_eq!(spec.clone().engine(EngineKind::Pjrt).resolved_repr(), Repr::Dense);
    assert_eq!(
        spec.engine(EngineKind::Pjrt).repr(ReprKind::Factored).resolved_repr(),
        Repr::Factored,
        "an explicit factored knob is honored on PJRT"
    );
}

#[test]
fn recompression_under_tight_cap_preserves_long_runs() {
    // Drive a factored iterate far past its cap with the FW recursion
    // and check it still matches the dense recursion — the SVD-merge
    // re-compression is lossless up to f32 round-off.
    let mut rng = Rng::new(31);
    let mut fact = FactoredMat::with_cap(9, 7, 0); // floored to min+8 = 15
    let mut dense = Mat::zeros(9, 7);
    for k in 1..=120u64 {
        let u = rng.unit_vector(9);
        let v = rng.unit_vector(7);
        let eta = 2.0 / (k as f32 + 1.0);
        fact.fw_rank_one_update(eta, -1.0, &u, &v);
        dense.fw_rank_one_update(eta, -1.0, &u, &v);
    }
    assert!(fact.atoms() <= fact.cap());
    assert!(fact.peak_atoms() > fact.cap());
    let rel = rel_frob_diff(&fact.to_dense(), &dense);
    assert!(rel < 1e-3, "re-compression drifted: {rel}");
    // the nuclear bound still certifies feasibility of the recursion
    assert!(fact.nuclear_norm_bound() <= 1.0 + 1e-3);
}

#[test]
fn operator_form_lmo_matches_dense_lmo() {
    // power_iteration over the FactoredMat LinOp lands on the same
    // leading pair as over its dense materialization.
    let mut rng = Rng::new(33);
    let mut f = FactoredMat::zeros(12, 9);
    for _ in 0..6 {
        f.push_atom(
            rng.normal_f32(),
            std::sync::Arc::new(rng.unit_vector(12)),
            std::sync::Arc::new(rng.unit_vector(9)),
        );
    }
    let d = f.to_dense();
    let v0 = rng.unit_vector(9);
    let sf = sfw::linalg::power_iteration(&f, &v0, 200, 1e-10);
    let sd = sfw::linalg::power_iteration(&d, &v0, 200, 1e-10);
    assert!(
        (sf.sigma - sd.sigma).abs() < 1e-3 * (1.0 + sd.sigma.abs()),
        "sigma {} vs {}",
        sf.sigma,
        sd.sigma
    );
    let align: f32 = sf.u.iter().zip(&sd.u).map(|(a, b)| a * b).sum();
    assert!(align.abs() > 0.999, "u misaligned: {align}");
}

#[test]
fn thread_count_is_bit_invariant_per_solver() {
    // The kernels determinism contract (linalg::kernels): fixed-size
    // chunk partials combined in a fixed order make --threads N
    // bit-identical to --threads 1.  One representative per solver
    // family — serial, async (W = 1), dist (W = 2, rank-order reduce).
    for (algo, workers) in [("sfw", 1), ("sfw-asyn", 1), ("sfw-dist", 2)] {
        let run = |threads| {
            base_spec(algo, workers, Transport::Local)
                .threads(threads)
                .run()
                .unwrap_or_else(|e| panic!("{algo} threads={threads}: {e}"))
        };
        let r1 = run(1);
        let r4 = run(4);
        assert_eq!(
            r1.x.data, r4.x.data,
            "{algo}: iterate diverged between --threads 1 and --threads 4"
        );
        let (s1, s4) = (r1.snapshot(), r4.snapshot());
        assert_eq!(s1.iterations, s4.iterations, "{algo}: iteration counts diverged");
        assert_eq!(s1.bytes_up, s4.bytes_up, "{algo}: uplink bytes diverged");
        assert_eq!(s1.bytes_down, s4.bytes_down, "{algo}: downlink bytes diverged");
        assert!(
            r4.spec_echo.contains("threads=4"),
            "{algo}: echo missing threads: {}",
            r4.spec_echo
        );
        assert!(!r1.spec_echo.contains("threads="), "{algo}: default echoed threads");
    }
}

#[test]
fn poisoned_atom_reaches_the_lmo_as_non_finite_output() {
    // A NaN atom coefficient must poison every linop product
    // (FactoredMat::apply's NaN contract — skips guard on `c == 0.0`,
    // which is false for NaN) so the power-iteration LMO emits a
    // non-finite triple that the master's `sane_rank_one` gate rejects
    // instead of silently folding a half-poisoned direction into X.
    let mut rng = Rng::new(37);
    let mut f = FactoredMat::zeros(12, 9);
    f.push_atom(
        0.8,
        std::sync::Arc::new(rng.unit_vector(12)),
        std::sync::Arc::new(rng.unit_vector(9)),
    );
    f.push_atom(
        f32::NAN,
        std::sync::Arc::new(vec![0.0f32; 12]),
        std::sync::Arc::new(vec![0.0f32; 9]),
    );
    let v0 = rng.unit_vector(9);
    let svd = sfw::linalg::power_iteration(&f, &v0, 50, 1e-10);
    assert!(!svd.sigma.is_finite(), "sigma survived a poisoned atom: {}", svd.sigma);
    assert!(
        svd.u.iter().any(|x| !x.is_finite()) || svd.v.iter().any(|x| !x.is_finite()),
        "LMO direction survived a poisoned atom"
    );
}

#[test]
fn iterate_snapshots_are_cheap_in_factored_mode() {
    // An evaluator snapshot of a factored iterate clones the atom list,
    // not a d1*d2 array: the Arcs are shared.
    let mut rng = Rng::new(35);
    let mut it = Iterate::init_rank_one(Repr::Factored, 40, 30, 1.0, &mut rng);
    for k in 1..=5u64 {
        let u = rng.unit_vector(40);
        let v = rng.unit_vector(30);
        it.fw_rank_one_update(2.0 / (k as f32 + 1.0), -1.0, &u, &v);
    }
    let snap = it.clone();
    assert_eq!(rel_frob_diff(&snap.to_dense(), &it.to_dense()), 0.0);
    assert_eq!(snap.peak_atoms(), it.peak_atoms());
}
