//! Chaos conformance suite: every distributed solver, on both
//! transports, must *survive* the named fault plans — same-seed
//! same-plan runs must replay with identical accounting where the
//! protocol schedule is deterministic, accepted staleness must never
//! exceed tau, corrupt frames must be counted and skipped, and no fault
//! plan may panic (or wedge) a master.  This is the end-to-end witness
//! for the robustness hardening the unit tests pin in `sfw::comms` and
//! `sfw::coordinator` — see the fault-model table in `sfw::chaos`.

use std::sync::Arc;
use std::time::Duration;

use sfw::chaos::{Crash, CrashMode, DelayModel, FaultPlan, RankPlan, Reorder};
use sfw::data::matrix_sensing::{MatrixSensingData, MsParams};
use sfw::objective::MatrixSensing;
use sfw::runtime::Workload;
use sfw::session::{BatchSchedule, Report, TaskSpec, TrainSpec, Transport};
use sfw::util::rng::Rng;

/// Shared-data task pinned to its own seed (independent of spec seed).
fn ms(seed: u64, d: usize, n: usize) -> TaskSpec {
    let mut rng = Rng::new(seed);
    let p = MsParams { d1: d, d2: d, rank: 2, n, noise_std: 0.05 };
    TaskSpec::Prebuilt(Workload::Ms(Arc::new(MatrixSensing::new(
        MatrixSensingData::generate(&p, &mut rng),
        1.0,
    ))))
}

const ALGOS: &[&str] = &["sfw-asyn", "svrf-asyn", "sfw-dist"];
const TRANSPORTS: &[Transport] = &[Transport::Local, Transport::Tcp];

/// A tiny spec every matrix cell shares: T=24 master iterations for the
/// plain solvers, epochs=2 (6 + 14 = 20 inner iterations) for svrf.
fn tiny(algo: &str, transport: Transport) -> TrainSpec {
    TrainSpec::new(ms(900, 8, 600))
        .algo(algo)
        .transport(transport)
        .iterations(24)
        .epochs(2)
        .tau(8)
        .workers(3)
        .batch(BatchSchedule::Constant(16))
        .eval_every(6)
        .seed(901)
        .power_iters(20)
}

/// Accepted master iterations each algo's tiny spec must complete.
fn expected_iterations(algo: &str) -> u64 {
    match algo {
        "svrf-asyn" => 20, // 6 + 14
        _ => 24,
    }
}

fn run(spec: TrainSpec) -> Report {
    let echo = spec.echo();
    spec.run().unwrap_or_else(|e| panic!("{echo}: {e}"))
}

#[test]
fn conformance_matrix_every_solver_survives_every_preset_on_both_transports() {
    for &algo in ALGOS {
        for &transport in TRANSPORTS {
            let clean = run(tiny(algo, transport).fault_plan(FaultPlan::clean(77)));
            assert_eq!(
                clean.chaos.events_total(),
                0,
                "{algo}/{transport:?}: the clean plan must inject nothing"
            );
            let clean_rel = clean.final_relative();
            assert!(clean_rel.is_finite());

            for plan in [
                FaultPlan::slow_tail(77),
                FaultPlan::flaky_net(77),
                FaultPlan::crash_one(77),
            ] {
                let name = plan.name.clone();
                let r = run(tiny(algo, transport).fault_plan(plan));
                let s = r.snapshot();
                // the run completes in full: the master reached its
                // iteration budget despite the faults (liveness)
                assert_eq!(
                    s.iterations,
                    expected_iterations(algo),
                    "{algo}/{transport:?}/{name}: run did not complete"
                );
                assert!(
                    r.chaos.events_total() > 0,
                    "{algo}/{transport:?}/{name}: plan injected nothing"
                );
                // and still reaches the clean run's ballpark: a bounded
                // slack on the clean relative loss, not a fresh target
                let rel = r.final_relative();
                assert!(
                    rel.is_finite() && rel <= clean_rel * 3.0 + 0.15,
                    "{algo}/{transport:?}/{name}: rel {rel} vs clean {clean_rel}"
                );
            }
        }
    }
}

#[test]
fn same_seed_same_plan_replays_identical_event_and_byte_accounting() {
    // sfw-dist's barrier schedule is deterministic, so a fixed
    // (seed, plan) must replay bit-identically: same iterate, same byte
    // totals, same injected-event counts — across repeated runs AND
    // across transports.  (The async solvers replay per-message fates
    // but their message COUNTS are scheduling-dependent, like msgs_up
    // always was; sfw-dist is where end-to-end identity is provable.)
    let spec = |transport| {
        tiny("sfw-dist", transport).fault_plan(FaultPlan::flaky_net(42))
    };
    let a = run(spec(Transport::Local));
    let b = run(spec(Transport::Local));
    let c = run(spec(Transport::Tcp));
    assert!(a.chaos.events_total() > 0, "flaky-net must inject events");
    assert_eq!(a.chaos, b.chaos, "event accounting diverged across identical runs");
    assert_eq!(a.chaos, c.chaos, "event accounting diverged across transports");
    // Compare counters field-by-field, EXCLUDING dropped_updates: the
    // barrier counts a stray (duplicated) frame only when it actually
    // recv()s it, and a duplicate of a final-round reply may or may not
    // be drained before the master exits — a master-side race, not an
    // injection nondeterminism.  Everything else is deterministic.
    let (sa, sb, sc) = (a.snapshot(), b.snapshot(), c.snapshot());
    for (that, what) in [(&sb, "identical runs"), (&sc, "transports")] {
        assert_eq!(sa.iterations, that.iterations, "iterations diverged across {what}");
        assert_eq!(sa.grad_evals, that.grad_evals, "grad_evals diverged across {what}");
        assert_eq!(sa.lmo_calls, that.lmo_calls, "lmo_calls diverged across {what}");
        assert_eq!(sa.bytes_up, that.bytes_up, "uplink bytes diverged across {what}");
        assert_eq!(sa.bytes_down, that.bytes_down, "downlink bytes diverged across {what}");
        assert_eq!(sa.msgs_up, that.msgs_up, "uplink msgs diverged across {what}");
        assert_eq!(sa.msgs_down, that.msgs_down, "downlink msgs diverged across {what}");
    }
    assert_eq!(a.x.data, b.x.data, "iterate diverged across identical runs");
    assert_eq!(a.x.data, c.x.data, "iterate diverged across transports");
}

#[test]
fn accepted_staleness_never_exceeds_tau_under_any_plan() {
    // "delay counters never exceed the configured tau": the delay gate
    // enforces it; max_accepted_delay makes it observable end to end.
    for &algo in &["sfw-asyn", "svrf-asyn"] {
        for plan in [FaultPlan::slow_tail(5), FaultPlan::flaky_net(5)] {
            let tau = 4;
            let r = run(tiny(algo, Transport::Local).tau(tau).fault_plan(plan.clone()));
            let s = r.snapshot();
            assert!(
                s.max_accepted_delay <= tau,
                "{algo}/{}: accepted delay {} exceeded tau {tau}",
                plan.name,
                s.max_accepted_delay
            );
        }
    }
}

#[test]
fn corrupt_frames_are_counted_and_skipped_never_panicking_the_master() {
    let mut plan = FaultPlan::clean(13);
    plan.name = "custom".into();
    plan.default_rank.corrupt_prob = 0.6;
    plan.retransmit = Duration::from_micros(50);
    for &algo in ALGOS {
        let r = run(tiny(algo, Transport::Local).fault_plan(plan.clone()));
        let corrupted = r.chaos.corrupt_delivered + r.chaos.corrupt_rejected;
        assert!(corrupted > 0, "{algo}: corruption never fired");
        assert_eq!(r.snapshot().iterations, expected_iterations(algo), "{algo}");
        assert!(r.final_loss().is_finite(), "{algo}: corruption poisoned the iterate");
    }
}

#[test]
fn single_worker_survives_heavy_corruption() {
    // Regression for the ping-pong wedge: with one worker, a rejected
    // update must still get a (resync) reply — silence would deadlock
    // both sides.  The record-based staleness gate plus the sanity-gate
    // resync reply keep W=1 live under heavy corruption.
    let mut plan = FaultPlan::clean(14);
    plan.default_rank.corrupt_prob = 0.5;
    plan.retransmit = Duration::from_micros(50);
    let r = run(
        tiny("sfw-asyn", Transport::Local)
            .workers(1)
            .iterations(15)
            .fault_plan(plan),
    );
    assert_eq!(r.snapshot().iterations, 15);
    assert!(r.chaos.corrupt_delivered + r.chaos.corrupt_rejected > 0);
}

#[test]
fn async_solvers_survive_a_permanently_halted_worker() {
    let mut plan = FaultPlan::clean(15);
    plan.name = "halt-0".into();
    plan.overrides.push((
        0,
        RankPlan {
            crash: Some(Crash { at_send: 2, mode: CrashMode::Halt }),
            ..RankPlan::default()
        },
    ));
    for &algo in &["sfw-asyn", "svrf-asyn"] {
        for &transport in TRANSPORTS {
            let r = run(tiny(algo, transport).fault_plan(plan.clone()));
            assert_eq!(r.chaos.crashes, 1, "{algo}/{transport:?}");
            assert_eq!(
                r.snapshot().iterations,
                expected_iterations(algo),
                "{algo}/{transport:?}: surviving workers did not finish the run"
            );
        }
    }
}

#[test]
fn halting_plans_are_rejected_for_the_synchronous_barrier() {
    let mut plan = FaultPlan::clean(16);
    plan.name = "halt-0".into();
    plan.overrides.push((
        0,
        RankPlan {
            crash: Some(Crash { at_send: 2, mode: CrashMode::Halt }),
            ..RankPlan::default()
        },
    ));
    let err = tiny("sfw-dist", Transport::Local).fault_plan(plan).run().unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("sfw-dist") && msg.contains("halt"), "{msg}");
    // registry-driven: the error names the loss-tolerant solvers
    assert!(msg.contains("sfw-asyn") && msg.contains("svrf-asyn"), "{msg}");
}

#[test]
fn chaos_is_rejected_where_it_cannot_inject() {
    // no comms links to wrap
    let err = tiny("sfw", Transport::Local)
        .fault_plan(FaultPlan::clean(1))
        .run()
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("sfw") && msg.contains("chaos applies to"), "{msg}");
    for supporter in ["sfw-asyn", "svrf-asyn", "sfw-dist"] {
        assert!(msg.contains(supporter), "error should list '{supporter}': {msg}");
    }
    // external worker processes are out of the wrapper's reach
    let err = tiny("sfw-asyn", Transport::Tcp)
        .tcp_await(true)
        .fault_plan(FaultPlan::clean(1))
        .run()
        .unwrap_err();
    assert!(err.to_string().contains("tcp-await"), "{err}");
}

#[test]
fn hostile_plan_cannot_panic_or_wedge_any_master() {
    // Everything at once, at rates far beyond the presets: the masters
    // must neither panic nor hang, and every run must still complete.
    let mut plan = FaultPlan::clean(17);
    plan.name = "hostile".into();
    plan.retransmit = Duration::from_micros(20);
    plan.default_rank = RankPlan {
        send_delay: DelayModel::Geometric { unit: Duration::from_micros(50), p: 0.5 },
        recv_delay: DelayModel::Fixed(Duration::from_micros(20)),
        drop_prob: 0.4,
        dup_prob: 0.4,
        corrupt_prob: 0.4,
        reorder: Some(Reorder { window: 2, prob: 0.4 }),
        crash: Some(Crash {
            at_send: 4,
            mode: CrashMode::Restart { stall: Duration::from_millis(5) },
        }),
        join_delay: Some(Duration::from_millis(2)),
    };
    for &algo in ALGOS {
        let r = run(tiny(algo, Transport::Local).fault_plan(plan.clone()));
        assert_eq!(r.snapshot().iterations, expected_iterations(algo), "{algo}");
        assert!(r.final_loss().is_finite(), "{algo}");
        let c = &r.chaos;
        assert!(
            c.drops > 0 && c.duplicates > 0 && c.crashes > 0 && c.late_joins > 0,
            "{algo}: hostile plan under-injected: {c:?}"
        );
    }
}

#[test]
fn queuing_sim_and_real_harness_agree_on_slow_tail_statistics() {
    // Appendix D's simulator and a real harness run under an equivalent
    // geometric slow-tail plan must tell the same story: both complete
    // exactly T accepted iterations; with a loose gate neither drops;
    // with tau = 0 and several workers both drop, at broadly similar
    // rates (the simulator is virtual-time, the harness wall-clock, so
    // only coarse agreement is meaningful).
    use sfw::algo::engine::NativeEngine;
    use sfw::sim::{simulate_asyn, QueuingParams};

    let p_geom = 0.3;
    let workers = 3;
    let iterations = 60u64;
    let task = ms(920, 8, 600);
    let obj = match &task {
        TaskSpec::Prebuilt(w) => w.objective(),
        _ => unreachable!(),
    };

    let sim = |tau: u64| {
        let prm = QueuingParams {
            workers,
            p: p_geom,
            iterations,
            tau,
            batch: BatchSchedule::Constant(16),
            eval_every: 30,
            seed: 921,
            ..Default::default()
        };
        let mut engines: Vec<NativeEngine> = (0..workers)
            .map(|w| NativeEngine::new(obj.clone(), 20, 922 + w as u64))
            .collect();
        simulate_asyn(obj.clone(), &mut engines, &prm)
    };
    let real = |tau: u64| {
        let mut plan = FaultPlan::clean(923);
        plan.name = "sim-equiv".into();
        plan.default_rank.send_delay =
            DelayModel::Geometric { unit: Duration::from_micros(100), p: p_geom };
        run(TrainSpec::new(task.clone())
            .algo("sfw-asyn")
            .iterations(iterations)
            .tau(tau)
            .workers(workers)
            .batch(BatchSchedule::Constant(16))
            .eval_every(30)
            .seed(921)
            .power_iters(20)
            .fault_plan(plan))
    };

    // loose gate: nobody drops, everyone finishes
    let s_loose = sim(1_000);
    let r_loose = real(1_000);
    assert_eq!(s_loose.counters.snapshot().iterations, iterations);
    assert_eq!(r_loose.snapshot().iterations, iterations);
    assert_eq!(s_loose.counters.snapshot().dropped_updates, 0);
    assert_eq!(r_loose.snapshot().dropped_updates, 0);

    // tau = 0: both must drop, at coarsely similar rates
    let s_tight = sim(0).counters.snapshot();
    let r_tight = real(0).snapshot();
    assert_eq!(s_tight.iterations, iterations);
    assert_eq!(r_tight.iterations, iterations);
    assert!(s_tight.dropped_updates > 0, "simulator saw no drops at tau=0");
    assert!(r_tight.dropped_updates > 0, "harness saw no drops at tau=0");
    let rate = |dropped: u64| dropped as f64 / (dropped + iterations) as f64;
    let (rs, rr) = (rate(s_tight.dropped_updates), rate(r_tight.dropped_updates));
    assert!(
        (rs - rr).abs() < 0.5,
        "drop rates diverged: sim {rs:.2} vs harness {rr:.2}"
    );
}

#[test]
fn chaos_events_surface_in_sweep_artifacts() {
    use sfw::sweep::{SweepRunner, SweepSpec};
    let base = tiny("sfw-asyn", Transport::Local).iterations(10).eval_every(5);
    let sweep = SweepSpec::new("chaos-cells", base)
        .algos(&["sfw-asyn", "sfw-dist"])
        .chaos_plans(&["none", "flaky-net"])
        .target(0.9);
    let result = SweepRunner::new().quiet(true).run(&sweep).unwrap();
    assert_eq!(result.cells.len(), 4);
    for cell in &result.cells {
        match cell.axis("chaos") {
            Some("none") => assert_eq!(cell.chaos.events_total(), 0, "{}", cell.id()),
            Some("flaky-net") => {
                assert!(cell.chaos.events_total() > 0, "{}: no events", cell.id())
            }
            other => panic!("unexpected chaos axis value {other:?}"),
        }
    }
    // the chaos block round-trips through the v1 JSON schema
    let back =
        sfw::sweep::SweepResult::from_json(&result.to_json().render()).unwrap();
    for (a, b) in result.cells.iter().zip(&back.cells) {
        assert_eq!(a.chaos, b.chaos);
    }
}
