//! The sweep-subsystem contract:
//!
//! * axis grids expand to the cartesian product, with identical cells
//!   deduplicated and expansion order stable;
//! * the `[sweep]` config section resolves through the same
//!   section-aware key machinery as `[train]`/`[data]`, and bad keys /
//!   values produce errors that list the valid axis names (mirroring the
//!   registry-driven errors pinned in `tests/session.rs`);
//! * a sweep runs end to end (sequentially and with `jobs > 1`) and its
//!   `SweepResult` round-trips through the `sfw.sweep/v1` JSON schema
//!   the CI smoke artifact uses.

use sfw::algo::schedule::BatchSchedule;
use sfw::config::Config;
use sfw::session::{TaskSpec, TrainSpec, Transport};
use sfw::sweep::{
    StragglerProfile, SweepError, SweepRunner, SweepSpec, AXIS_NAMES, SWEEP_KEYS,
};
use sfw::util::cli::Args;

fn tiny_base() -> TrainSpec {
    TrainSpec::new(TaskSpec::ms_small())
        .iterations(8)
        .batch(BatchSchedule::Constant(8))
        .eval_every(2)
        .power_iters(10)
        .seed(42)
}

fn args(s: &str) -> Args {
    Args::parse_from(s.split_whitespace().map(String::from))
}

// ---------------------------------------------------------------------------
// Grid expansion
// ---------------------------------------------------------------------------

#[test]
fn expansion_is_the_axis_product() {
    let sweep = SweepSpec::new("grid", tiny_base())
        .algos(&["sfw-dist", "sfw-asyn"])
        .workers(&[1, 2, 4])
        .taus(&[2, 8])
        .seeds(&[42, 43]);
    assert_eq!(sweep.product_size(), 24);
    let cells = sweep.expand().unwrap();
    assert_eq!(cells.len(), 24);
    // every cell carries every axis, in the canonical order
    for cell in &cells {
        let names: Vec<&str> = cell.axes.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(names, AXIS_NAMES);
    }
    // all ids distinct
    let mut ids: Vec<String> = cells.iter().map(|c| c.id()).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), 24);
}

#[test]
fn unset_axes_inherit_the_base_spec() {
    let base = tiny_base().workers(7).tau(3).transport(Transport::Local);
    let cells = SweepSpec::new("inherit", base).seeds(&[1, 2]).expand().unwrap();
    assert_eq!(cells.len(), 2);
    for c in &cells {
        assert_eq!(c.axis("workers"), Some("7"));
        assert_eq!(c.axis("tau"), Some("3"));
        assert_eq!(c.spec.workers, 7);
        assert_eq!(c.spec.tau, 3);
    }
    assert_eq!(cells[0].spec.seed, 1);
    assert_eq!(cells[1].spec.seed, 2);
}

#[test]
fn identical_cells_are_deduplicated() {
    let sweep = SweepSpec::new("dup", tiny_base())
        .workers(&[1, 2, 1, 1, 2])
        .seeds(&[9, 9]);
    assert_eq!(sweep.product_size(), 10);
    let cells = sweep.expand().unwrap();
    assert_eq!(cells.len(), 2, "5x2 grid with duplicates must collapse to 2 cells");
    assert_eq!(cells[0].axis("workers"), Some("1"));
    assert_eq!(cells[1].axis("workers"), Some("2"));
}

#[test]
fn cell_specs_reflect_their_axis_values() {
    let cells = SweepSpec::new("spec", tiny_base())
        .algos(&["sfw-asyn"])
        .batches(&[0, 32]) // 0 = the algorithm's theorem schedule
        .stragglers(&[
            StragglerProfile::None,
            StragglerProfile::Geometric { unit_us: 20, p: 0.25 },
        ])
        .expand()
        .unwrap();
    assert_eq!(cells.len(), 4);
    let auto = &cells[0];
    assert_eq!(auto.axis("batch"), Some("auto"));
    assert!(auto.spec.batch.is_none());
    assert_eq!(auto.axis("straggler"), Some("none"));
    assert!(auto.spec.straggler.is_none());
    let geo = &cells[1];
    assert_eq!(geo.axis("straggler"), Some("20us:0.25"));
    assert!(geo.spec.straggler.is_some());
    let constant = &cells[2];
    assert_eq!(constant.spec.batch, Some(BatchSchedule::Constant(32)));
}

// ---------------------------------------------------------------------------
// [sweep] config section
// ---------------------------------------------------------------------------

#[test]
fn sweep_section_resolves_from_file_and_cli() {
    let dir = std::env::temp_dir().join("sfw_sweep_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("sweep.ini");
    std::fs::write(
        &path,
        "[train]\niterations = 8\nseed = 7\n[data]\nms-n = 500\nms-d = 8\n\
         [sweep]\nname = grid\nworkers = 1,2\ntau = 2,4\nstraggler = none,20us:0.25\n",
    )
    .unwrap();
    let cli = format!("--config {} --sweep.tau 8", path.display());
    let sweep = SweepSpec::load(&args(&cli)).unwrap();
    assert_eq!(sweep.name, "grid");
    assert_eq!(sweep.base.iterations, 8); // [train] feeds the base spec
    assert_eq!(sweep.base.seed, 7);
    // load() prebuilds the dataset once so cells share it via Arc
    assert!(matches!(sweep.base.task, TaskSpec::Prebuilt(_)));
    assert_eq!(sweep.workers, vec![1, 2]);
    assert_eq!(sweep.taus, vec![8]); // CLI beats the file section
    assert_eq!(
        sweep.stragglers,
        vec![
            StragglerProfile::None,
            StragglerProfile::Geometric { unit_us: 20, p: 0.25 }
        ]
    );
    assert_eq!(sweep.expand().unwrap().len(), 4); // 2 workers x 1 tau x 2 stragglers
}

#[test]
fn unknown_sweep_key_error_lists_valid_names() {
    let file = Config::from_str("[sweep]\nworckers = 1,2\n").unwrap();
    let err = SweepSpec::from_sources(tiny_base(), &file, &args("")).unwrap_err();
    assert!(matches!(err, SweepError::UnknownKey { .. }));
    let msg = err.to_string();
    assert!(msg.contains("worckers"), "{msg}");
    for key in SWEEP_KEYS {
        assert!(msg.contains(key), "error should list valid key '{key}': {msg}");
    }
}

#[test]
fn misspelled_sweep_cli_flag_is_rejected_not_ignored() {
    // `--sweep.worker` (typo for `workers`) must error like the file
    // section does, not silently run a single base cell.
    let err =
        SweepSpec::from_sources(tiny_base(), &Config::new(), &args("--sweep.worker 1,3")).unwrap_err();
    let msg = err.to_string();
    assert!(matches!(err, SweepError::UnknownKey { .. }), "{msg}");
    assert!(msg.contains("worker"), "{msg}");
}

#[test]
fn valueless_sweep_cli_flag_is_rejected_not_ignored() {
    // `--sweep.workers` with the value forgotten parses as a boolean
    // flag; the axis must not be silently dropped.
    let err = SweepSpec::from_sources(
        tiny_base(),
        &Config::new(),
        &args("--sweep.workers --sweep.algos sfw-dist,sfw-asyn"),
    )
    .unwrap_err();
    match &err {
        SweepError::BadAxisValue { axis, .. } => assert_eq!(axis, "workers"),
        other => panic!("expected BadAxisValue, got {other:?}"),
    }
}

#[test]
fn bad_axis_values_name_axis_and_value() {
    for (cli, axis) in [
        ("--sweep.workers 1,two", "workers"),
        ("--sweep.tau -3", "tau"),
        ("--sweep.batch tiny", "batch"),
        ("--sweep.transport smoke-signals", "transport"),
        ("--sweep.straggler geometric", "straggler"),
        ("--sweep.chaos flakey-net", "chaos"),
        ("--sweep.seeds ,", "seeds"),
    ] {
        let err = SweepSpec::from_sources(tiny_base(), &Config::new(), &args(cli)).unwrap_err();
        match &err {
            SweepError::BadAxisValue { axis: a, .. } => {
                assert_eq!(a, axis, "wrong axis named for '{cli}': {err}")
            }
            other => panic!("expected BadAxisValue for '{cli}', got {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// End-to-end runs + JSON round-trip
// ---------------------------------------------------------------------------

#[test]
fn sweep_runs_and_json_round_trips() {
    let sweep = SweepSpec::new("e2e", tiny_base())
        .algos(&["sfw", "sfw-asyn"])
        .workers(&[1, 2])
        .target(0.9);
    let result = SweepRunner::new().quiet(true).run(&sweep).unwrap();
    assert_eq!(result.cells.len(), 4);
    for cell in &result.cells {
        assert!(cell.counters.iterations > 0, "{}: no iterations", cell.id());
        assert!(cell.wall.mean_s >= 0.0);
        assert!(!cell.curve.is_empty(), "{}: no curve", cell.id());
        assert!(cell.final_rel.is_finite());
    }

    let text = result.to_json().render();
    let back = sfw::sweep::SweepResult::from_json(&text).unwrap();
    assert_eq!(back.name, result.name);
    assert_eq!(back.target, result.target);
    assert_eq!(back.cells.len(), result.cells.len());
    for (a, b) in result.cells.iter().zip(&back.cells) {
        assert_eq!(a.axes, b.axes);
        assert_eq!(a.spec_echo, b.spec_echo);
        assert_eq!(a.final_rel, b.final_rel);
        assert_eq!(a.final_loss, b.final_loss);
        assert_eq!(a.time_to_target, b.time_to_target);
        assert_eq!(a.counters, b.counters);
        assert_eq!(a.curve, b.curve);
        assert_eq!(a.wall.n, b.wall.n);
        assert_eq!(a.wall.mean_s, b.wall.mean_s);
    }
    // the rendering itself is stable (CI diffs artifacts across runs)
    assert_eq!(text, back.to_json().render());
}

#[test]
fn parallel_jobs_match_sequential_grid() {
    let grid = |jobs| {
        SweepSpec::new("par", tiny_base())
            .algos(&["sfw-asyn"])
            .workers(&[1, 2])
            .seeds(&[42, 43])
            .jobs(jobs)
    };
    let seq = SweepRunner::new().quiet(true).run(&grid(1)).unwrap();
    let par = SweepRunner::new().quiet(true).run(&grid(3)).unwrap();
    assert_eq!(seq.cells.len(), 4);
    assert_eq!(par.cells.len(), 4);
    // same cells, same order, regardless of execution interleaving
    let ids = |r: &sfw::sweep::SweepResult| -> Vec<String> {
        r.cells.iter().map(|c| c.id()).collect()
    };
    assert_eq!(ids(&seq), ids(&par));
}

#[test]
fn smoke_scale_cells_pin_the_factored_downlink_saving() {
    // `sfw sweep --smoke` appends these two cells to the artifact;
    // scripts/check_smoke_bytes.py repeats this assertion on the JSON.
    let result = SweepRunner::new().quiet(true).run(&SweepSpec::smoke_scale()).unwrap();
    assert_eq!(result.cells.len(), 2);
    let dense = result.find(&[("repr", "dense")]).expect("dense scale cell");
    let fact = result.find(&[("repr", "factored")]).expect("factored scale cell");
    assert_eq!(dense.axis("dims"), Some("48x32"));
    // the factored downlink broadcasts atoms, not the 48x32 matrix
    assert!(
        fact.counters.bytes_down * 4 < dense.counters.bytes_down,
        "factored downlink {} B not measurably below dense {} B",
        fact.counters.bytes_down,
        dense.counters.bytes_down
    );
    // uplink unchanged: both ship dense partial gradients
    assert_eq!(fact.counters.bytes_up, dense.counters.bytes_up);
    // same-seed runs agree on convergence to f32-level tolerance
    assert!(
        (fact.final_loss - dense.final_loss).abs() < 1e-2 * (1.0 + dense.final_loss.abs()),
        "dense {} vs factored {} final loss",
        dense.final_loss,
        fact.final_loss
    );
    // representation accounting lands in the artifact
    assert!(fact.rank > 0 && fact.peak_atoms > 0);
    assert_eq!(dense.peak_atoms, 0);
    // and survives the JSON round-trip the CI check reads
    let back = sfw::sweep::SweepResult::from_json(&result.to_json().render()).unwrap();
    assert_eq!(back.cells[1].rank, result.cells[1].rank);
}

#[test]
fn smoke_gap_cells_pin_tol_stopping() {
    // `sfw sweep --smoke` appends this serial pair to the artifact;
    // scripts/check_smoke_bytes.py repeats these assertions on the JSON.
    let result = SweepRunner::new().quiet(true).run(&SweepSpec::smoke_gap()).unwrap();
    assert_eq!(result.cells.len(), 2);
    let full = result.find(&[("tol", "0")]).expect("tol=0 gap cell");
    let stopped = result.find(&[("tol", "1000")]).expect("tol=1000 gap cell");
    // gap stopping disabled: full budget, and the artifact carries a
    // finite, net-decreasing gap column aligned with the loss curve
    assert_eq!(full.counters.iterations, 20, "tol=0 cell stopped early");
    assert_eq!(full.gaps.len(), full.curve.len());
    assert!(full.gap.is_finite(), "tol=0 cell lost its final gap");
    let finite: Vec<f64> = full.gaps.iter().copied().filter(|g| g.is_finite()).collect();
    assert!(!finite.is_empty(), "tol=0 cell has no finite gap entries");
    assert!(
        finite.last().unwrap() < finite.first().unwrap(),
        "gap column not net-decreasing: {finite:?}"
    );
    // a tolerance far above the initial gap stops at the first estimate
    assert!(
        stopped.counters.iterations < 20,
        "tol=1000 never fired ({} iterations)",
        stopped.counters.iterations
    );
    assert!(
        stopped.gap.is_finite() && stopped.gap <= 1e3,
        "stopped cell's final gap {} does not certify the stop",
        stopped.gap
    );
    // the gap column survives the JSON round-trip the CI check reads
    // (non-finite entries render as null and come back as NaN)
    let back = sfw::sweep::SweepResult::from_json(&result.to_json().render()).unwrap();
    for (a, b) in result.cells.iter().zip(&back.cells) {
        assert_eq!(a.gap.is_finite(), b.gap.is_finite());
        if a.gap.is_finite() {
            assert_eq!(a.gap, b.gap);
        }
        assert_eq!(a.gaps.len(), b.gaps.len());
        for (x, y) in a.gaps.iter().zip(&b.gaps) {
            assert!(
                (x.is_nan() && y.is_nan()) || x == y,
                "gaps entry diverged in round-trip: {x} vs {y}"
            );
        }
    }
}

#[test]
fn smoke_sweep_contract() {
    // The CI pipeline depends on this exact shape (see ROADMAP "Sweeps &
    // CI" and "Chaos"): tiny deterministic grid, seed 42, W in {1, 2},
    // every TCP-capable distributed algorithm over BOTH transports, each
    // with and without the flaky-net chaos plan, and a written
    // sweep_smoke.json artifact with nonzero comm bytes everywhere plus
    // nonzero injected-event counts in the chaos cells.
    let sweep = SweepSpec::smoke();
    assert_eq!(sweep.name, "smoke");
    let cells = sweep.expand().unwrap();
    // 3 algos x W in {1,2} x {local, tcp} x {none, flaky-net}
    assert_eq!(cells.len(), 24);
    for cell in &cells {
        assert_eq!(cell.axis("seed"), Some("42"));
        assert!(matches!(cell.axis("workers"), Some("1") | Some("2")));
        assert!(matches!(
            cell.axis("algo"),
            Some("sfw-dist") | Some("sfw-asyn") | Some("svrf-asyn")
        ));
        assert!(matches!(cell.axis("transport"), Some("local") | Some("tcp")));
        assert!(matches!(cell.axis("chaos"), Some("none") | Some("flaky-net")));
    }
    let result = SweepRunner::new().quiet(true).run(&sweep).unwrap();
    // every cell is a distributed run: comm bytes must be accounted —
    // this is the assertion CI repeats on the uploaded artifact
    for cell in &result.cells {
        assert!(
            cell.counters.bytes_up > 0 && cell.counters.bytes_down > 0,
            "{}: comm bytes not accounted",
            cell.id()
        );
        // chaos cells must actually inject; clean cells must not
        match cell.axis("chaos") {
            Some("flaky-net") => assert!(
                cell.chaos.events_total() > 0,
                "{}: chaos cell injected nothing",
                cell.id()
            ),
            _ => assert_eq!(cell.chaos.events_total(), 0, "{}", cell.id()),
        }
    }
    let dir = std::env::temp_dir().join("sfw_sweep_smoke_test");
    let path = dir.join("sweep_smoke.json");
    result.write_json(path.to_str().unwrap()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let back = sfw::sweep::SweepResult::from_json(&text).unwrap();
    assert_eq!(back.cells.len(), 24);
    for (a, b) in result.cells.iter().zip(&back.cells) {
        assert_eq!(a.counters.bytes_up, b.counters.bytes_up);
        assert_eq!(a.counters.bytes_down, b.counters.bytes_down);
        assert_eq!(a.chaos, b.chaos);
    }
}
