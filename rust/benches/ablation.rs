//! Ablations for the design choices DESIGN.md calls out:
//!
//! 1. **tau sweep** — the staleness tolerance trades drop-rate against
//!    stale-gradient error (Thm 1's (3 tau + 1) factor): tiny tau wastes
//!    worker compute on dropped updates, huge tau admits noisy directions.
//! 2. **bucket padding** — the PJRT runtime pads batches to power-of-two
//!    buckets; measures the wasted-compute overhead vs an exact-shape
//!    execution at several batch sizes.
//! 3. **power-iteration depth** — LMO quality vs cost: iterations needed
//!    for the 1-SVD to stop limiting convergence.
//!
//! The tau and power-iteration grids are `sfw::sweep::SweepSpec`
//! declarations (single-axis sweeps over a shared base spec); bucket
//! padding stays a hand-timed engine micro-bench — it exercises a PJRT
//! engine call, not a training grid.
//!
//! Emits bench_out/ablation_*.csv.

use std::sync::Arc;

use sfw::algo::engine::StepEngine;
use sfw::benchkit::{bench_for, Table};
use sfw::experiments::build_ms;
use sfw::linalg::Mat;
use sfw::runtime::{PjrtEngine, PjrtRuntime, Workload};
use sfw::session::{BatchSchedule, TaskSpec, TrainSpec};
use sfw::sweep::{SweepRunner, SweepSpec};
use sfw::util::rng::Rng;

fn main() {
    tau_sweep();
    bucket_padding();
    power_iteration_depth();
}

fn tau_sweep() {
    let task = TaskSpec::Prebuilt(Workload::Ms(build_ms(42, 20_000)));
    let base = TrainSpec::new(task)
        .algo("sfw-asyn")
        .iterations(200)
        .workers(8)
        .batch(BatchSchedule::Constant(256))
        .eval_every(200)
        .seed(42)
        .power_iters(30);
    let sweep = SweepSpec::new("ablation_tau", base).taus(&[0, 1, 2, 4, 8, 16, 64]);
    let result = SweepRunner::new().quiet(true).run(&sweep).expect("sweep");

    let mut table = Table::new(
        "ablation: staleness tolerance tau (W=8, T=200, m=256)",
        &["tau", "final rel", "dropped", "drop %"],
    );
    let mut csv = Table::new("csv", &["tau", "rel", "dropped"]);
    for c in &result.cells {
        let tau = c.axis("tau").unwrap();
        let dropped = c.counters.dropped_updates;
        let total = c.counters.iterations + dropped;
        table.row(&[
            tau.into(),
            format!("{:.3e}", c.final_rel),
            dropped.to_string(),
            format!("{:.1}%", 100.0 * dropped as f64 / total as f64),
        ]);
        csv.row(&[tau.into(), format!("{:.5e}", c.final_rel), dropped.to_string()]);
    }
    table.print();
    csv.write_csv("bench_out/ablation_tau.csv").expect("csv");
    println!("Expected: drop%% falls monotonically with tau; final rel is flat-ish");
    println!("across moderate tau and degrades only at extreme staleness (Thm 1).");
}

fn bucket_padding() {
    let Ok(rt) = PjrtRuntime::new("artifacts") else {
        println!("(bucket_padding skipped — run `make artifacts`)");
        return;
    };
    let rt = Arc::new(rt);
    let ms = build_ms(1, 20_000);
    let mut engine = PjrtEngine::new(rt, Workload::Ms(ms.clone()), 5);
    let mut rng = Rng::new(6);
    let x = Mat::randn(30, 30, 0.1, &mut rng);
    let mut g = Mat::zeros(30, 30);
    let mut table = Table::new(
        "ablation: PJRT bucket padding overhead (ms_grad)",
        &["true batch", "bucket", "pad %", "mean time"],
    );
    for &m in &[64usize, 128, 129, 300, 512, 513, 1500, 2048] {
        let idx: Vec<usize> = (0..m).map(|_| rng.next_below(20_000)).collect();
        let bucket = [128usize, 512, 2048, 8192]
            .iter()
            .copied()
            .find(|&b| b >= m)
            .unwrap();
        let stats = bench_for(1, std::time::Duration::from_millis(300), || {
            let _ = engine.grad_sum(&x, &idx, &mut g);
        });
        table.row(&[
            m.to_string(),
            bucket.to_string(),
            format!("{:.0}%", 100.0 * (bucket - m) as f64 / bucket as f64),
            stats.mean_human(),
        ]);
    }
    table.print();
    println!("Expected: time tracks the BUCKET, not the true batch — the cost of");
    println!("shape-specialized AOT executables; worst case ~2x just past a bucket edge.");
}

fn power_iteration_depth() {
    let task = TaskSpec::Prebuilt(Workload::Ms(build_ms(7, 10_000)));
    let base = TrainSpec::new(task)
        .algo("sfw")
        .iterations(150)
        .batch(BatchSchedule::Constant(512))
        .eval_every(150)
        .seed(9);
    let sweep =
        SweepSpec::new("ablation_power_iters", base).power_iters(&[1, 2, 4, 8, 16, 64]);
    let result = SweepRunner::new().quiet(true).run(&sweep).expect("sweep");

    let mut table = Table::new(
        "ablation: power-iteration depth (serial SFW, T=150, m=512)",
        &["max iters", "final rel", "mean LMO iters used"],
    );
    let mut csv = Table::new("csv", &["iters", "rel"]);
    for c in &result.cells {
        let pi = c.axis("power_iters").unwrap();
        table.row(&[pi.into(), format!("{:.3e}", c.final_rel), format!("<= {pi}")]);
        csv.row(&[pi.into(), format!("{:.5e}", c.final_rel)]);
    }
    table.print();
    csv.write_csv("bench_out/ablation_power_iters.csv").expect("csv");
    println!("Expected: quality saturates by ~8-16 iterations — consistent with the");
    println!("paper solving the 1-SVD 'to a practical precision' (Appendix D).");
}
