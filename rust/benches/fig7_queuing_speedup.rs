//! Figure 7: queuing-model speedup over a single worker as W grows, for
//! p ∈ {0.1, 0.8}.
//!
//! Measure: virtual time to complete T = 500 master iterations at m = 512,
//! with the final relative loss reported alongside to show the runs reach
//! the SAME quality (staleness penalty is negligible at this batch size —
//! Thm 1's batch condition holds with room to spare), so the speedup is a
//! pure throughput ratio.  (The paper plots time-to-rel-err-0.002; with
//! equal terminal quality the two measures coincide, and the fixed-T form
//! is robust to single-seed noise-floor crossing jitter.)
//!
//! Expected shape: SFW-asyn tracks the ideal (almost-linear) line — the
//! paper's headline — while SFW-dist saturates, most visibly at p = 0.1.
//! Emits bench_out/fig7.csv.

use std::sync::Arc;

use sfw::algo::engine::NativeEngine;
use sfw::algo::schedule::BatchSchedule;
use sfw::benchkit::Table;
use sfw::experiments::{build_ms, relative};
use sfw::objective::Objective;
use sfw::sim::{simulate_asyn, simulate_dist, QueuingParams};

const ITERS: u64 = 500;
const BATCH: usize = 512;

/// (virtual time to finish, final rel loss)
fn run(o: &Arc<dyn Objective>, algo: &str, w: usize, p: f64, seed: u64) -> (f64, f64) {
    let prm = QueuingParams {
        workers: w,
        p,
        iterations: ITERS,
        tau: (2 * w) as u64,
        batch: BatchSchedule::Constant(BATCH),
        eval_every: ITERS,
        seed,
        ..Default::default()
    };
    let (vt, trace) = if algo == "asyn" {
        let mut engines: Vec<NativeEngine> = (0..w)
            .map(|i| NativeEngine::new(o.clone(), 30, seed ^ i as u64))
            .collect();
        let r = simulate_asyn(o.clone(), &mut engines, &prm);
        (r.virtual_time, r.trace.points())
    } else {
        let mut e1 = vec![NativeEngine::new(o.clone(), 30, seed ^ 0xFF)];
        let r = simulate_dist(o.clone(), &mut e1, &prm);
        (r.virtual_time, r.trace.points())
    };
    let rel = relative(&trace, o.f_star_hint()).last().unwrap().2;
    (vt, rel)
}

fn main() {
    let obj = build_ms(42, 20_000);
    let o: Arc<dyn Objective> = obj.clone();
    let workers = [1usize, 3, 5, 7, 9, 11, 13, 15];
    let mut csv = Table::new("csv", &["p", "algo", "W", "speedup", "final_rel"]);
    for &p in &[0.1f64, 0.8] {
        let mut table = Table::new(
            &format!("Fig 7 (p = {p}): speedup to complete T={ITERS} iters (m={BATCH})"),
            &["W", "dist speedup", "dist rel", "asyn speedup", "asyn rel", "ideal"],
        );
        let (base_d, _) = run(&o, "dist", 1, p, 42);
        let (base_a, _) = run(&o, "asyn", 1, p, 42);
        for &w in &workers {
            let (td, rd) = run(&o, "dist", w, p, 42);
            let (ta, ra) = run(&o, "asyn", w, p, 42);
            let (xd, xa) = (base_d / td, base_a / ta);
            table.row(&[
                w.to_string(),
                format!("{xd:.2}x"),
                format!("{rd:.2e}"),
                format!("{xa:.2}x"),
                format!("{ra:.2e}"),
                format!("{w}.00x"),
            ]);
            csv.row(&[
                format!("{p}"),
                "dist".into(),
                w.to_string(),
                format!("{xd:.3}"),
                format!("{rd:.3e}"),
            ]);
            csv.row(&[
                format!("{p}"),
                "asyn".into(),
                w.to_string(),
                format!("{xa:.3}"),
                format!("{ra:.3e}"),
            ]);
        }
        table.print();
    }
    csv.write_csv("bench_out/fig7.csv").expect("csv");
    println!("series written to bench_out/fig7.csv");
    println!("\nExpected shape: asyn tracks the ideal column (almost-linear speedup,");
    println!("paper Fig 7) with equal final rel loss; dist flattens, most at p=0.1.");
}
