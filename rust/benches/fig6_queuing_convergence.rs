//! Figure 6: relative loss vs SIMULATED time under the Appendix-D queuing
//! model — matrix sensing, staleness parameter p ∈ {0.1, 0.8}, SFW-dist vs
//! SFW-asyn, repeated over seeds (the paper shades ±1 std over 5 runs).
//!
//! Expected shape: at p = 0.1 (heavy-tailed workers) SFW-asyn's curve
//! reaches any loss level in a fraction of SFW-dist's virtual time; at
//! p = 0.8 the curves draw closer.  Emits bench_out/fig6.csv.

use std::sync::Arc;

use sfw::algo::engine::NativeEngine;
use sfw::algo::schedule::BatchSchedule;
use sfw::benchkit::Table;
use sfw::experiments::{build_ms, relative};
use sfw::objective::Objective;
use sfw::sim::{simulate_asyn, simulate_dist, QueuingParams};

fn main() {
    let obj = build_ms(42, 20_000);
    let o: Arc<dyn Objective> = obj.clone();
    let workers = 15usize;
    let iterations = 300u64;
    let repeats = 5;
    let mut csv = Table::new("csv", &["p", "algo", "seed", "vtime", "iter", "rel"]);
    let mut summary = Table::new(
        "Fig 6: virtual time to finish (mean ± std over 5 seeds)",
        &["p", "algo", "vtime mean", "vtime std", "final rel (mean)"],
    );
    for &p in &[0.1f64, 0.8] {
        for algo in ["dist", "asyn"] {
            let mut vtimes = Vec::new();
            let mut finals = Vec::new();
            for rep in 0..repeats {
                let seed = 42 + rep as u64;
                let prm = QueuingParams {
                    workers,
                    p,
                    iterations,
                    tau: 2 * workers as u64,
                    batch: BatchSchedule::Constant(128),
                    eval_every: 10,
                    seed,
                    ..Default::default()
                };
                let (trace, vt) = if algo == "asyn" {
                    let mut engines: Vec<NativeEngine> = (0..workers)
                        .map(|w| NativeEngine::new(o.clone(), 30, seed ^ w as u64))
                        .collect();
                    let r = simulate_asyn(o.clone(), &mut engines, &prm);
                    (r.trace.points(), r.virtual_time)
                } else {
                    let mut e1 = vec![NativeEngine::new(o.clone(), 30, seed ^ 0xFF)];
                    let r = simulate_dist(o.clone(), &mut e1, &prm);
                    (r.trace.points(), r.virtual_time)
                };
                let rel = relative(&trace, o.f_star_hint());
                for &(t, i, r) in &rel {
                    csv.row(&[
                        format!("{p}"),
                        algo.into(),
                        seed.to_string(),
                        format!("{t:.1}"),
                        i.to_string(),
                        format!("{r:.5e}"),
                    ]);
                }
                vtimes.push(vt);
                finals.push(rel.last().unwrap().2);
            }
            let mean = vtimes.iter().sum::<f64>() / repeats as f64;
            let var = vtimes.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / repeats as f64;
            let fmean = finals.iter().sum::<f64>() / repeats as f64;
            summary.row(&[
                format!("{p}"),
                algo.into(),
                format!("{mean:.0}"),
                format!("{:.0}", var.sqrt()),
                format!("{fmean:.3e}"),
            ]);
        }
    }
    summary.print();
    csv.write_csv("bench_out/fig6.csv").expect("csv");
    println!("series written to bench_out/fig6.csv");
    println!("\nExpected shape: asyn finishes T iterations in ~1/W of dist's virtual");
    println!("time at p=0.1; the gap narrows substantially at p=0.8 (paper App. D).");
}
