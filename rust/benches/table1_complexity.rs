//! Table 1: complexity comparison with FIXED batch size — number of
//! stochastic gradient evaluations and linear optimizations (1-SVDs) to
//! reach accuracy epsilon, SFW vs SFW-asyn (Theorems 3/4, Corollary 1).
//!
//! Theory (large-c reading, paper §4.1): SFW-asyn uses a batch tau^2
//! smaller, so it needs ~tau x MORE LMOs but ~tau x FEWER total gradient
//! evaluations — "a good trade-off ... where the stochastic gradient
//! evaluation will dominate".
//!
//! We measure both quantities by running to a fixed relative error and
//! reading the crossing iteration from the trace.  Emits
//! bench_out/table1.csv.

use sfw::benchkit::Table;
use sfw::experiments::build_ms;
use sfw::runtime::Workload;
use sfw::session::{BatchSchedule, Report, TaskSpec, TrainSpec};

const EPS: f64 = 0.05;
const C_SFW: usize = 2_048; // fixed batch c for plain SFW
const MAX_ITERS: u64 = 4_000;

/// iterations to reach EPS (from the relative-loss trace), or None.
fn iters_to_eps(r: &Report) -> Option<u64> {
    r.relative().iter().find(|(_, _, rel)| *rel <= EPS).map(|(_, i, _)| *i)
}

fn main() {
    let task = TaskSpec::Prebuilt(Workload::Ms(build_ms(42, 60_000)));
    let mut table = Table::new(
        &format!("Table 1: ops to reach rel err {EPS} (fixed batch, measured)"),
        &["algorithm", "tau", "batch c", "# lin. opt.", "# sto. grad.", "grad ratio", "lmo ratio"],
    );
    let mut csv = Table::new("csv", &["algo", "tau", "batch", "lmos", "grads"]);

    // --- plain SFW baseline ------------------------------------------------
    let sfw = TrainSpec::new(task.clone())
        .algo("sfw")
        .iterations(MAX_ITERS / 4)
        .batch(BatchSchedule::Constant(C_SFW))
        .eval_every(2)
        .seed(11)
        .power_iters(30)
        .run()
        .expect("train");
    let k_sfw = iters_to_eps(&sfw).expect("SFW never reached eps");
    let (lmo_sfw, grad_sfw) = (k_sfw, k_sfw * C_SFW as u64);
    table.row(&[
        "SFW".into(),
        "—".into(),
        C_SFW.to_string(),
        lmo_sfw.to_string(),
        grad_sfw.to_string(),
        "1.00".into(),
        "1.00".into(),
    ]);
    csv.row(&["sfw".into(), "0".into(), C_SFW.to_string(), lmo_sfw.to_string(), grad_sfw.to_string()]);

    // --- SFW-asyn at several tau --------------------------------------------
    for &tau in &[2u64, 4, 8] {
        let c_asyn = (C_SFW as u64 / (tau * tau)).max(1) as usize; // Thm 4: c/tau^2
        let r = TrainSpec::new(task.clone())
            .algo("sfw-asyn")
            .iterations(MAX_ITERS)
            .tau(tau)
            .workers(4)
            .batch(BatchSchedule::Constant(c_asyn))
            .eval_every(10)
            .seed(11)
            .power_iters(30)
            .run()
            .expect("train");
        match iters_to_eps(&r) {
            Some(k) => {
                let (lmo, grad) = (k, k * c_asyn as u64);
                table.row(&[
                    "SFW-asyn".into(),
                    tau.to_string(),
                    c_asyn.to_string(),
                    lmo.to_string(),
                    grad.to_string(),
                    format!("{:.2}", grad as f64 / grad_sfw as f64),
                    format!("{:.2}", lmo as f64 / lmo_sfw as f64),
                ]);
                csv.row(&[
                    "sfw-asyn".into(),
                    tau.to_string(),
                    c_asyn.to_string(),
                    lmo.to_string(),
                    grad.to_string(),
                ]);
            }
            None => table.row(&[
                "SFW-asyn".into(),
                tau.to_string(),
                c_asyn.to_string(),
                "> max".into(),
                "> max".into(),
                "—".into(),
                "—".into(),
            ]),
        }
    }
    table.print();
    csv.write_csv("bench_out/table1.csv").expect("csv");
    println!("series written to bench_out/table1.csv");
    println!("\nExpected shape (paper Table 1, large-c reading): as tau grows,");
    println!("'grad ratio' falls well below 1 (fewer total gradient evaluations)");
    println!("while 'lmo ratio' rises above 1 (more 1-SVDs) — the trade the");
    println!("paper argues is favorable when gradients dominate computation.");
}
