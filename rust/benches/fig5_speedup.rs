//! Figure 5: speedup over a single worker — time to reach a fixed
//! relative error (paper: 0.001 for matrix sensing, 0.02 for PNN) as the
//! worker count grows, SFW-dist vs SFW-asyn, with injected straggler
//! heterogeneity.
//!
//! Expected shape: SFW-asyn's speedup grows near-linearly in W and
//! consistently exceeds SFW-dist's, which saturates (barrier + dense
//! traffic).  Emits bench_out/fig5_<task>.csv.

use std::sync::Arc;
use std::time::Duration;

use sfw::algo::engine::NativeEngine;
use sfw::algo::schedule::BatchSchedule;
use sfw::benchkit::Table;
use sfw::coordinator::{run_asyn_local, run_dist, AsynOptions, DistOptions, Straggler};
use sfw::experiments::{build_ms, build_pnn, time_to_relative};
use sfw::objective::Objective;

fn straggler() -> Option<Straggler> {
    // sleep-dominated heterogeneity (see fig4_convergence.rs)
    Some(Straggler { unit: Duration::from_micros(20), p: 0.25 })
}

fn time_to(obj: &Arc<dyn Objective>, algo: &str, w: usize, iters: u64, batch: usize, tau: u64, target: f64) -> Option<f64> {
    let seed = 42u64;
    let f_star = obj.f_star_hint();
    let pts = match algo {
        "dist" => {
            let o2 = obj.clone();
            run_dist(
                obj.clone(),
                &DistOptions {
                    iterations: iters,
                    workers: w,
                    batch: BatchSchedule::Constant(batch),
                    eval_every: 5,
                    seed,
                    straggler: straggler(),
                },
                move |i| Box::new(NativeEngine::new(o2.clone(), 30, seed ^ 0x300u64.wrapping_add(i as u64))),
            )
            .trace
            .points()
        }
        _ => {
            let o2 = obj.clone();
            run_asyn_local(
                obj.clone(),
                &AsynOptions {
                    iterations: iters,
                    tau,
                    workers: w,
                    batch: BatchSchedule::Constant(batch), // same schedule both algos
                    eval_every: 5,
                    seed,
                    straggler: straggler(),
                    link_latency: None,
                },
                move |i| Box::new(NativeEngine::new(o2.clone(), 30, seed ^ 0x400 ^ i as u64)),
            )
            .trace
            .points()
        }
    };
    time_to_relative(&pts, f_star, target)
}

fn run_task(name: &str, obj: Arc<dyn Objective>, iters: u64, batch: usize, tau: u64, target: f64) {
    let workers = [1usize, 3, 7, 11, 15];
    let mut table = Table::new(
        &format!("Fig 5 ({name}): speedup to rel err {target} vs 1 worker"),
        &["W", "dist t(s)", "dist speedup", "asyn t(s)", "asyn speedup"],
    );
    let mut csv = Table::new("csv", &["algo", "W", "t", "speedup"]);
    let base_d = time_to(&obj, "dist", 1, iters, batch, tau, target);
    let base_a = time_to(&obj, "asyn", 1, iters, batch, tau, target);
    for &w in &workers {
        let td = time_to(&obj, "dist", w, iters, batch, tau, target);
        let ta = time_to(&obj, "asyn", w, iters, batch, tau, target);
        let sp = |base: Option<f64>, t: Option<f64>| match (base, t) {
            (Some(b), Some(t)) if t > 0.0 => format!("{:.2}x", b / t),
            _ => "—".into(),
        };
        let fmt = |t: Option<f64>| t.map(|x| format!("{x:.3}")).unwrap_or_else(|| "—".into());
        table.row(&[
            w.to_string(),
            fmt(td),
            sp(base_d, td),
            fmt(ta),
            sp(base_a, ta),
        ]);
        if let (Some(b), Some(t)) = (base_d, td) {
            csv.row(&["dist".into(), w.to_string(), format!("{t:.4}"), format!("{:.3}", b / t)]);
        }
        if let (Some(b), Some(t)) = (base_a, ta) {
            csv.row(&["asyn".into(), w.to_string(), format!("{t:.4}"), format!("{:.3}", b / t)]);
        }
    }
    table.print();
    let path = format!("bench_out/fig5_{name}.csv");
    csv.write_csv(&path).expect("csv");
    println!("series written to {path}");
}

fn main() {
    println!("== Fig 5: time-to-target speedups (straggler-injected threads) ==");
    let ms = build_ms(42, 20_000);
    run_task("matrix_sensing", ms, 500, 256, 8, 0.02);
    let pnn = build_pnn(43, 196, 8_000);
    run_task("pnn", pnn, 400, 256, 2, 0.65);
    println!("\nExpected shape: asyn speedup ~ linear in W and above dist at every W;");
    println!("dist saturates earlier on PNN (dense-gradient aggregation grows with D^2).");
}
