//! Figure 5: speedup over a single worker — time to reach a fixed
//! relative error (paper: 0.001 for matrix sensing, 0.02 for PNN) as the
//! worker count grows, SFW-dist vs SFW-asyn, with injected straggler
//! heterogeneity.
//!
//! The grid (algo x W) is a `sfw::sweep::SweepSpec` declaration; the
//! speedup columns divide each cell's time-to-target by its algorithm's
//! W=1 cell from the same sweep.
//!
//! Expected shape: SFW-asyn's speedup grows near-linearly in W and
//! consistently exceeds SFW-dist's, which saturates (barrier + dense
//! traffic).  Emits bench_out/fig5_<task>.csv.

use std::time::Duration;

use sfw::benchkit::Table;
use sfw::experiments::{build_ms, build_pnn};
use sfw::runtime::Workload;
use sfw::session::{BatchSchedule, Straggler, TaskSpec, TrainSpec};
use sfw::sweep::{SweepRunner, SweepSpec};

fn straggler() -> Straggler {
    // sleep-dominated heterogeneity (see fig4_convergence.rs)
    Straggler { unit: Duration::from_micros(20), p: 0.25 }
}

fn run_task(name: &str, task: TaskSpec, iters: u64, batch: usize, tau: u64, target: f64) {
    let base = TrainSpec::new(task)
        .iterations(iters)
        .tau(tau)
        .batch(BatchSchedule::Constant(batch)) // same schedule both algos
        .eval_every(5)
        .seed(42)
        .power_iters(30)
        .straggler(straggler());
    let workers = [1usize, 3, 7, 11, 15];
    let sweep = SweepSpec::new(&format!("fig5_{name}"), base)
        .algos(&["sfw-dist", "sfw-asyn"])
        .workers(&workers)
        .target(target);
    let result = SweepRunner::new().quiet(true).run(&sweep).expect("sweep");

    let tt = |algo: &str, w: usize| -> Option<f64> {
        result
            .find(&[("algo", algo), ("workers", &w.to_string())])
            .and_then(|c| c.time_to_target)
    };
    let base_d = tt("sfw-dist", 1);
    let base_a = tt("sfw-asyn", 1);

    let mut table = Table::new(
        &format!("Fig 5 ({name}): speedup to rel err {target} vs 1 worker"),
        &["W", "dist t(s)", "dist speedup", "asyn t(s)", "asyn speedup"],
    );
    let mut csv = Table::new("csv", &["algo", "W", "t", "speedup"]);
    for &w in &workers {
        let td = tt("sfw-dist", w);
        let ta = tt("sfw-asyn", w);
        let sp = |base: Option<f64>, t: Option<f64>| match (base, t) {
            (Some(b), Some(t)) if t > 0.0 => format!("{:.2}x", b / t),
            _ => "—".into(),
        };
        let fmt = |t: Option<f64>| t.map(|x| format!("{x:.3}")).unwrap_or_else(|| "—".into());
        table.row(&[
            w.to_string(),
            fmt(td),
            sp(base_d, td),
            fmt(ta),
            sp(base_a, ta),
        ]);
        if let (Some(b), Some(t)) = (base_d, td) {
            csv.row(&["dist".into(), w.to_string(), format!("{t:.4}"), format!("{:.3}", b / t)]);
        }
        if let (Some(b), Some(t)) = (base_a, ta) {
            csv.row(&["asyn".into(), w.to_string(), format!("{t:.4}"), format!("{:.3}", b / t)]);
        }
    }
    table.print();
    let path = format!("bench_out/fig5_{name}.csv");
    csv.write_csv(&path).expect("csv");
    println!("series written to {path}");
}

fn main() {
    println!("== Fig 5: time-to-target speedups (straggler-injected threads) ==");
    let ms = TaskSpec::Prebuilt(Workload::Ms(build_ms(42, 20_000)));
    run_task("matrix_sensing", ms, 500, 256, 8, 0.02);
    let pnn = TaskSpec::Prebuilt(Workload::Pnn(build_pnn(43, 196, 8_000)));
    run_task("pnn", pnn, 400, 256, 2, 0.65);
    println!("\nExpected shape: asyn speedup ~ linear in W and above dist at every W;");
    println!("dist saturates earlier on PNN (dense-gradient aggregation grows with D^2).");
}
