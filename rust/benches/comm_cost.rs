//! Communication-cost table (§3 "Communication Cost of SFW-asyn" + the
//! Related-Work comparison): measured bytes per master iteration for every
//! distributed algorithm in the repo, on both paper workloads.
//!
//! Expected shape:
//!   SFW-asyn, SVA        O(D1 + D2) upload per iteration
//!   SFW-dist             O(W * D1 * D2) per iteration, both directions
//!   DFW-power            O(t (D1 + D2)) at iteration t => grows with T
//! and the asyn/dist gap widens from matrix sensing (D^2 = 900) to PNN
//! (D^2 = 38 416 at the default 196; 614k at paper scale 784).

use sfw::benchkit::Table;
use sfw::experiments::{build_ms, build_pnn};
use sfw::runtime::Workload;
use sfw::session::{BatchSchedule, TaskSpec, TrainSpec};

fn main() {
    let workers = 4usize;
    let iters = 40u64;
    let mut table = Table::new(
        "measured communication per master iteration",
        &["task", "algorithm", "up B/iter", "down B/iter", "total B/iter", "dense grad B"],
    );
    let mut csv = Table::new("csv", &["task", "algo", "up", "down", "dense"]);

    for (task_name, workload) in [
        ("matrix_sensing 30x30", Workload::Ms(build_ms(42, 10_000))),
        ("pnn 196x196", Workload::Pnn(build_pnn(43, 196, 5_000))),
    ] {
        let (d1, d2) = workload.objective().dims();
        let dense = 4 * d1 * d2;
        let base = TrainSpec::new(TaskSpec::Prebuilt(workload))
            .iterations(iters)
            .tau(8)
            .workers(workers)
            .batch(BatchSchedule::Constant(128))
            .eval_every(iters)
            .seed(1)
            .power_iters(30)
            .dfw_rounds(1, 0.5);

        for (name, algo) in [
            ("SFW-asyn", "sfw-asyn"),
            ("SFW-dist", "sfw-dist"),
            ("SVA", "sva"),
            ("DFW-power", "dfw-power"),
        ] {
            let s = base.clone().algo(algo).run().expect("train").snapshot();
            let per = |b: u64| b / s.iterations.max(1);
            table.row(&[
                task_name.into(),
                name.into(),
                per(s.bytes_up).to_string(),
                per(s.bytes_down).to_string(),
                per(s.bytes_up + s.bytes_down).to_string(),
                dense.to_string(),
            ]);
            csv.row(&[
                task_name.into(),
                name.into(),
                per(s.bytes_up).to_string(),
                per(s.bytes_down).to_string(),
                dense.to_string(),
            ]);
        }
    }
    table.print();
    csv.write_csv("bench_out/comm_cost.csv").expect("csv");
    println!("series written to bench_out/comm_cost.csv");
    println!("\nExpected shape: SFW-asyn upload ~= 4(D1+D2)+hdr regardless of W;");
    println!("SFW-dist ~= W * dense both ways; DFW-power grows with T (O(T^2) total).");
    println!("Note: SFW-asyn's *download* per iteration is also O(D1+D2) amortized —");
    println!("each log entry is sent to each worker at most once (paper §3).");
}
