//! Communication-cost table (§3 "Communication Cost of SFW-asyn" + the
//! Related-Work comparison): measured bytes per master iteration for every
//! distributed algorithm in the repo, on both paper workloads.
//!
//! Expected shape:
//!   SFW-asyn, SVA        O(D1 + D2) upload per iteration
//!   SFW-dist             O(W * D1 * D2) per iteration, both directions
//!   DFW-power            O(t (D1 + D2)) at iteration t => grows with T
//! and the asyn/dist gap widens from matrix sensing (D^2 = 900) to PNN
//! (D^2 = 38 416 at the default 196; 614k at paper scale 784).

use std::sync::Arc;

use sfw::algo::engine::NativeEngine;
use sfw::algo::schedule::BatchSchedule;
use sfw::benchkit::Table;
use sfw::coordinator::dfw_power::{run_dfw_power, DfwOptions};
use sfw::coordinator::sva::{run_sva, SvaOptions};
use sfw::coordinator::{run_asyn_local, run_dist, AsynOptions, DistOptions};
use sfw::experiments::{build_ms, build_pnn};
use sfw::objective::Objective;

fn main() {
    let workers = 4usize;
    let iters = 40u64;
    let mut table = Table::new(
        "measured communication per master iteration",
        &["task", "algorithm", "up B/iter", "down B/iter", "total B/iter", "dense grad B"],
    );
    let mut csv = Table::new("csv", &["task", "algo", "up", "down", "dense"]);

    for (task, obj) in [
        ("matrix_sensing 30x30", build_ms(42, 10_000) as Arc<dyn Objective>),
        ("pnn 196x196", build_pnn(43, 196, 5_000) as Arc<dyn Objective>),
    ] {
        let (d1, d2) = obj.dims();
        let dense = 4 * d1 * d2;
        let batch = BatchSchedule::Constant(128);

        let o2 = obj.clone();
        let asyn = run_asyn_local(
            obj.clone(),
            &AsynOptions {
                iterations: iters,
                tau: 8,
                workers,
                batch: batch.clone(),
                eval_every: iters,
                seed: 1,
                straggler: None,
                link_latency: None,
            },
            move |w| Box::new(NativeEngine::new(o2.clone(), 30, 2 + w as u64)),
        );
        let o3 = obj.clone();
        let dist = run_dist(
            obj.clone(),
            &DistOptions {
                iterations: iters,
                workers,
                batch: batch.clone(),
                eval_every: iters,
                seed: 1,
                straggler: None,
            },
            move |w| Box::new(NativeEngine::new(o3.clone(), 30, 2u64.wrapping_add(w as u64))),
        );
        let o4 = obj.clone();
        let sva = run_sva(
            obj.clone(),
            &SvaOptions {
                iterations: iters,
                workers,
                batch: batch.clone(),
                eval_every: iters,
                seed: 1,
            },
            move |w| Box::new(NativeEngine::new(o4.clone(), 30, 2 + w as u64)),
        );
        let dfw = run_dfw_power(
            obj.clone(),
            &DfwOptions {
                iterations: iters,
                workers,
                rounds_base: 1,
                rounds_slope: 0.5,
                eval_every: iters,
                seed: 1,
            },
        );

        for (name, s) in [
            ("SFW-asyn", asyn.counters.snapshot()),
            ("SFW-dist", dist.counters.snapshot()),
            ("SVA", sva.counters.snapshot()),
            ("DFW-power", dfw.counters.snapshot()),
        ] {
            let per = |b: u64| b / s.iterations.max(1);
            table.row(&[
                task.into(),
                name.into(),
                per(s.bytes_up).to_string(),
                per(s.bytes_down).to_string(),
                per(s.bytes_up + s.bytes_down).to_string(),
                dense.to_string(),
            ]);
            csv.row(&[
                task.into(),
                name.into(),
                per(s.bytes_up).to_string(),
                per(s.bytes_down).to_string(),
                dense.to_string(),
            ]);
        }
    }
    table.print();
    csv.write_csv("bench_out/comm_cost.csv").expect("csv");
    println!("series written to bench_out/comm_cost.csv");
    println!("\nExpected shape: SFW-asyn upload ~= 4(D1+D2)+hdr regardless of W;");
    println!("SFW-dist ~= W * dense both ways; DFW-power grows with T (O(T^2) total).");
    println!("Note: SFW-asyn's *download* per iteration is also O(D1+D2) amortized —");
    println!("each log entry is sent to each worker at most once (paper §3).");
}
