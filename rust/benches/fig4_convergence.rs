//! Figure 4: convergence of the relative loss vs wall-clock time, on both
//! paper workloads (matrix sensing row 1, PNN row 2), SFW-dist vs
//! SFW-asyn, W ∈ {1, 7, 15} workers.
//!
//! The grid is a `sfw::sweep::SweepSpec` declaration — algo x workers
//! axes over a shared base spec — executed by `SweepRunner`; the cells'
//! stored relative-loss curves regenerate the figure's series.
//!
//! EC2's heterogeneous workers are emulated by injecting geometric
//! straggler delays on every worker (DESIGN.md §6).  Expected shape (the
//! paper's): SFW-asyn dominates SFW-dist at every W; both speed up with W
//! on matrix sensing; PNN speedups are muted because the dense-matrix
//! traffic of SFW-dist grows with D^2 (here that cost appears as the
//! serialized dense gradient aggregation at the barrier).
//!
//! Emits bench_out/fig4_<task>.csv with (algo, W, t, iter, rel_loss) rows.

use std::time::Duration;

use sfw::benchkit::Table;
use sfw::experiments::{build_ms, build_pnn};
use sfw::session::{BatchSchedule, Straggler, TaskSpec, TrainSpec};
use sfw::sweep::{SweepRunner, SweepSpec};

fn straggler() -> Straggler {
    // sleep-dominated heterogeneity: emulates EC2 worker skew and
    // parallelizes cleanly across threads (unlike CPU-bound compute on a
    // shared host), so wall-clock scaling reflects the protocol, not the
    // local core count
    Straggler { unit: Duration::from_micros(20), p: 0.25 }
}

fn run_task(name: &str, task: TaskSpec, iterations: u64, batch: usize, tau: u64, target: f64) {
    let base = TrainSpec::new(task)
        .iterations(iterations)
        .tau(tau)
        .batch(BatchSchedule::Constant(batch)) // same schedule both algos (wall-clock comparison)
        .eval_every(10)
        .seed(42)
        .power_iters(30)
        .straggler(straggler());
    let sweep = SweepSpec::new(&format!("fig4_{name}"), base)
        .algos(&["sfw-dist", "sfw-asyn"])
        .workers(&[1, 7, 15])
        .target(target);
    let result = SweepRunner::new().quiet(true).run(&sweep).expect("sweep");

    // summary: time to target per curve
    let mut table = Table::new(
        &format!("Fig 4 ({name}): time to rel loss {target}"),
        &["algo", "W", "t_target(s)", "final rel"],
    );
    let mut csv = Table::new("csv", &["algo", "W", "t", "iter", "rel"]);
    for c in &result.cells {
        let (algo, w) = (c.axis("algo").unwrap(), c.axis("workers").unwrap());
        let tt = c
            .time_to_target
            .map(|t| format!("{t:.3}"))
            .unwrap_or_else(|| "—".into());
        table.row(&[algo.into(), w.into(), tt, format!("{:.3e}", c.final_rel)]);
        for &(t, i, r) in &c.curve {
            csv.row(&[
                algo.into(),
                w.into(),
                format!("{t:.4}"),
                i.to_string(),
                format!("{r:.5e}"),
            ]);
        }
    }
    table.print();
    let path = format!("bench_out/fig4_{name}.csv");
    csv.write_csv(&path).expect("csv");
    println!("series written to {path}");
}

fn main() {
    println!("== Fig 4 row 1: matrix sensing (30x30, synthetic) ==");
    let ms = TaskSpec::Prebuilt(sfw::runtime::Workload::Ms(build_ms(42, 20_000)));
    run_task("matrix_sensing", ms, 300, 256, 8, 0.02);

    println!("\n== Fig 4 row 2: PNN (196x196 default; paper runs 784x784) ==");
    let pnn = TaskSpec::Prebuilt(sfw::runtime::Workload::Pnn(build_pnn(43, 196, 8_000)));
    run_task("pnn", pnn, 400, 256, 2, 0.65);

    println!("\nExpected shape (paper §5.2): clear speedups for both algos on");
    println!("matrix sensing with sfw-asyn ahead at every W; PNN speedups are");
    println!("marginal for both (the paper's own finding — large D1*D2 shifts the");
    println!("balance to compute/communication).  NOTE: on this single-host");
    println!("substitution equal batches make asyn do W x dist's gradient work,");
    println!("which understates asyn on PNN relative to a real cluster.");
}
