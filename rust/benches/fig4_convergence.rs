//! Figure 4: convergence of the relative loss vs wall-clock time, on both
//! paper workloads (matrix sensing row 1, PNN row 2), SFW-dist vs
//! SFW-asyn, W ∈ {1, 7, 15} workers.
//!
//! EC2's heterogeneous workers are emulated by injecting geometric
//! straggler delays on every worker (DESIGN.md §6).  Expected shape (the
//! paper's): SFW-asyn dominates SFW-dist at every W; both speed up with W
//! on matrix sensing; PNN speedups are muted because the dense-matrix
//! traffic of SFW-dist grows with D^2 (here that cost appears as the
//! serialized dense gradient aggregation at the barrier).
//!
//! Emits bench_out/fig4_<task>.csv with (algo, W, t, iter, rel_loss) rows.

use std::sync::Arc;
use std::time::Duration;

use sfw::algo::engine::NativeEngine;
use sfw::algo::schedule::BatchSchedule;
use sfw::benchkit::Table;
use sfw::coordinator::{run_asyn_local, run_dist, AsynOptions, DistOptions, Straggler};
use sfw::experiments::{build_ms, build_pnn, relative, time_to_relative};
use sfw::objective::Objective;

fn straggler() -> Option<Straggler> {
    // sleep-dominated heterogeneity: emulates EC2 worker skew and
    // parallelizes cleanly across threads (unlike CPU-bound compute on a
    // shared host), so wall-clock scaling reflects the protocol, not the
    // local core count
    Some(Straggler { unit: Duration::from_micros(20), p: 0.25 })
}

struct Curve {
    algo: &'static str,
    workers: usize,
    points: Vec<(f64, u64, f64)>,
}

fn run_task(
    name: &str,
    obj: Arc<dyn Objective>,
    iterations: u64,
    batch: usize,
    tau: u64,
    target: f64,
) {
    let seed = 42u64;
    let f_star = obj.f_star_hint();
    let mut curves: Vec<Curve> = Vec::new();
    for &w in &[1usize, 7, 15] {
        let o2 = obj.clone();
        let dist = run_dist(
            obj.clone(),
            &DistOptions {
                iterations,
                workers: w,
                batch: BatchSchedule::Constant(batch),
                eval_every: 10,
                seed,
                straggler: straggler(),
            },
            move |i| Box::new(NativeEngine::new(o2.clone(), 30, seed ^ 0x100u64.wrapping_add(i as u64))),
        );
        curves.push(Curve {
            algo: "sfw-dist",
            workers: w,
            points: relative(&dist.trace.points(), f_star),
        });
        let o3 = obj.clone();
        let asyn = run_asyn_local(
            obj.clone(),
            &AsynOptions {
                iterations,
                tau,
                workers: w,
                batch: BatchSchedule::Constant(batch), // same schedule both algos (wall-clock comparison)
                eval_every: 10,
                seed,
                straggler: straggler(),
                link_latency: None,
            },
            move |i| Box::new(NativeEngine::new(o3.clone(), 30, seed ^ 0x200 ^ i as u64)),
        );
        curves.push(Curve {
            algo: "sfw-asyn",
            workers: w,
            points: relative(&asyn.trace.points(), f_star),
        });
    }

    // summary: time to target per curve
    let mut table = Table::new(
        &format!("Fig 4 ({name}): time to rel loss {target}"),
        &["algo", "W", "t_target(s)", "final rel"],
    );
    let mut csv = Table::new("csv", &["algo", "W", "t", "iter", "rel"]);
    for c in &curves {
        let raw: Vec<sfw::metrics::TracePoint> = c
            .points
            .iter()
            .map(|&(t, i, r)| sfw::metrics::TracePoint { t, iteration: i, loss: r })
            .collect();
        let tt = time_to_relative(&raw, 0.0, target)
            .map(|t| format!("{t:.3}"))
            .unwrap_or_else(|| "—".into());
        table.row(&[
            c.algo.into(),
            c.workers.to_string(),
            tt,
            format!("{:.3e}", c.points.last().unwrap().2),
        ]);
        for &(t, i, r) in &c.points {
            csv.row(&[
                c.algo.into(),
                c.workers.to_string(),
                format!("{t:.4}"),
                i.to_string(),
                format!("{r:.5e}"),
            ]);
        }
    }
    table.print();
    let path = format!("bench_out/fig4_{name}.csv");
    csv.write_csv(&path).expect("csv");
    println!("series written to {path}");
}

fn main() {
    println!("== Fig 4 row 1: matrix sensing (30x30, synthetic) ==");
    let ms = build_ms(42, 20_000);
    run_task("matrix_sensing", ms, 300, 256, 8, 0.02);

    println!("\n== Fig 4 row 2: PNN (196x196 default; paper runs 784x784) ==");
    let pnn = build_pnn(43, 196, 8_000);
    run_task("pnn", pnn, 400, 256, 2, 0.65);

    println!("\nExpected shape (paper §5.2): clear speedups for both algos on");
    println!("matrix sensing with sfw-asyn ahead at every W; PNN speedups are");
    println!("marginal for both (the paper's own finding — large D1*D2 shifts the");
    println!("balance to compute/communication).  NOTE: on this single-host");
    println!("substitution equal batches make asyn do W x dist's gradient work,");
    println!("which understates asyn on PNN relative to a real cluster.");
}
