//! Hot-path microbenchmarks (§Perf): the per-operation costs that compose
//! a worker step and a master iteration, native vs PJRT (AOT JAX/Pallas),
//! plus the protocol-side costs (replay, codec, rank-one update) and the
//! dense-vs-factored iterate cells (operator-form LMO, factored loss and
//! gradient) that feed the `BENCH_hotpath.json` perf trajectory
//! (`scripts/bench_snapshot.py`).
//!
//! Used by the EXPERIMENTS.md §Perf iteration log.  Run with artifacts
//! built (`make artifacts`) to get the PJRT rows.  Writes the humanized
//! table to `bench_out/hotpath.csv` and the machine-readable numbers to
//! `bench_out/hotpath_raw.csv`.

use std::sync::Arc;
use std::time::Duration;

use sfw::algo::engine::{NativeEngine, StepEngine, StepOut};
use sfw::benchkit::{bench_for, humanize, Stats, Table};
use sfw::coordinator::update_log::{replay, UpdateLog};
use sfw::experiments::{build_ms, build_pnn};
use sfw::linalg::kernels;
use sfw::linalg::{power_iteration_rand, FactoredMat, Iterate, Mat, Svd1};
use sfw::objective::Objective;
use sfw::runtime::{PjrtEngine, PjrtRuntime, Workload};
use sfw::comms::{GradCodec, Wire};
use sfw::coordinator::messages::{DistUp, UpdateMsg};
use sfw::util::rng::Rng;

const BUDGET: Duration = Duration::from_millis(600);

/// Pool size the `threads=4` kernel rows run at (recorded in
/// `bench_out/hotpath_env.json` alongside the CPU features so
/// `bench_snapshot.py --compare` can flag cross-environment runs).
const BENCH_POOL_THREADS: usize = 4;

fn main() {
    let mut table = Table::new("hot-path microbenchmarks", &["op", "mean", "p50", "p90", "notes"]);
    let mut raw: Vec<(String, Stats, String)> = Vec::new();
    let mut rng = Rng::new(42);

    let ms = build_ms(1, 20_000);
    let ms_o: Arc<dyn Objective> = ms.clone();
    let pnn = build_pnn(2, 196, 5_000);
    let pnn_o: Arc<dyn Objective> = pnn.clone();

    let mut row = |name: &str, notes: &str, f: &mut dyn FnMut()| {
        let s = bench_for(2, BUDGET, f);
        table.row(&[
            name.into(),
            s.mean_human(),
            humanize(s.p50_s),
            humanize(s.p90_s),
            notes.into(),
        ]);
        raw.push((name.to_string(), s, notes.to_string()));
    };

    // ---- native gradient + LMO -------------------------------------------
    let mut nat_ms = NativeEngine::new(ms_o.clone(), 24, 3);
    let x_ms = Mat::randn(30, 30, 0.1, &mut rng);
    let idx_2048: Vec<usize> = (0..2_048).map(|_| rng.next_below(20_000)).collect();
    let idx_128: Vec<usize> = idx_2048[..128].to_vec();
    let mut g = Mat::zeros(30, 30);
    row("ms grad m=128 (native)", "30x30, sum-grad", &mut || {
        let _ = nat_ms.grad_sum(&x_ms, &idx_128, &mut g);
    });
    row("ms grad m=2048 (native)", "30x30", &mut || {
        let _ = nat_ms.grad_sum(&x_ms, &idx_2048, &mut g);
    });
    row("ms fused step m=2048 (native)", "grad + 24-iter power LMO", &mut || {
        let _ = nat_ms.step(&x_ms, &idx_2048);
    });

    let mut nat_pnn = NativeEngine::new(pnn_o.clone(), 24, 4);
    let x_pnn = Mat::randn(196, 196, 0.05, &mut rng);
    let idxp: Vec<usize> = (0..256).map(|_| rng.next_below(5_000)).collect();
    let mut gp = Mat::zeros(196, 196);
    row("pnn grad m=256 (native)", "196x196 quadratic fwd+bwd", &mut || {
        let _ = nat_pnn.grad_sum(&x_pnn, &idxp, &mut gp);
    });

    // ---- LMO scaling -------------------------------------------------------
    let g30 = Mat::randn(30, 30, 1.0, &mut rng);
    let g196 = Mat::randn(196, 196, 1.0, &mut rng);
    row("power-iter 1-SVD 30x30", "tol 1e-7", &mut || {
        let _ = power_iteration_rand(&g30, &mut rng, 100, 1e-7);
    });
    row("power-iter 1-SVD 196x196", "tol 1e-7", &mut || {
        let _ = power_iteration_rand(&g196, &mut rng, 100, 1e-7);
    });
    row("jacobi FULL SVD 30x30 (PGD's projection cost)", "why FW wins", &mut || {
        let _ = sfw::linalg::jacobi_svd(&g30);
    });

    // ---- dense vs factored iterate (operator-form LMO, loss, grad) -------
    let fact196 = {
        let mut f = FactoredMat::zeros(196, 196);
        for _ in 0..64 {
            f.push_atom(
                rng.normal_f32() * 0.1,
                Arc::new(rng.unit_vector(196)),
                Arc::new(rng.unit_vector(196)),
            );
        }
        f
    };
    row("lmo 196x196 dense operator", "power_iteration on Mat", &mut || {
        let _ = power_iteration_rand(&g196, &mut rng, 24, 1e-7);
    });
    row("lmo 196x196 factored operator k=64", "no dense X built", &mut || {
        let _ = power_iteration_rand(&fact196, &mut rng, 24, 1e-7);
    });
    let fact30 = {
        let mut f = FactoredMat::zeros(30, 30);
        for _ in 0..16 {
            f.push_atom(
                rng.normal_f32() * 0.1,
                Arc::new(rng.unit_vector(30)),
                Arc::new(rng.unit_vector(30)),
            );
        }
        f
    };
    let dense30 = fact30.to_dense();
    row("ms loss_full dense 30x30", "N=20k residuals", &mut || {
        let _ = ms_o.loss_full(&dense30);
    });
    row("ms loss_full factored 30x30 k=16", "factored inner products", &mut || {
        let _ = ms_o.loss_full_factored(&fact30);
    });
    let fact_pnn = {
        let mut f = FactoredMat::zeros(196, 196);
        for _ in 0..16 {
            f.push_atom(
                rng.normal_f32() * 0.1,
                Arc::new(rng.unit_vector(196)),
                Arc::new(rng.unit_vector(196)),
            );
        }
        f
    };
    let dense_pnn = fact_pnn.to_dense();
    row("pnn grad m=256 dense 196x196", "O(d^2) forward per sample", &mut || {
        let _ = pnn_o.grad_sum(&dense_pnn, &idxp, &mut gp);
    });
    row("pnn grad m=256 factored k=16", "O(k d) forward per sample", &mut || {
        let _ = pnn_o.grad_sum_factored(&fact_pnn, &idxp, &mut gp);
    });

    // ---- step_it densify fallback: fresh vs cached dense scratch ---------
    // Engines that inherit the trait-default `step_it` (the PJRT
    // artifacts take dense inputs) render a factored iterate into a
    // dense buffer every step.  Both rows run identical math through the
    // default fallback; the only difference is whether the engine caches
    // that O(d1*d2) buffer or allocates it fresh each call, so the delta
    // is exactly the per-step allocator traffic the cache removes.
    let idxp_32: Vec<usize> = idxp[..32].to_vec();
    let x_fact = Iterate::Factored(fact_pnn.clone());
    let mut fresh = DensifyEngine::new(NativeEngine::new(pnn_o.clone(), 24, 4), false);
    row("step_it densify 196x196 k=16 (fresh scratch)", "alloc per step", &mut || {
        let _ = fresh.step_it(&x_fact, &idxp_32);
    });
    let mut cached = DensifyEngine::new(NativeEngine::new(pnn_o.clone(), 24, 4), true);
    row("step_it densify 196x196 k=16 (cached scratch)", "alloc once", &mut || {
        let _ = cached.step_it(&x_fact, &idxp_32);
    });

    // ---- sparse completion (O(nnz) grad + COO-operator LMO) and serving ----
    let rec = {
        let mut r = Rng::new(7);
        let p = sfw::data::RecParams {
            rows: 2000,
            cols: 400,
            rank: 4,
            density: 0.01,
            ..Default::default()
        };
        sfw::data::RecommenderData::generate(&p, &mut r)
    };
    let nnz = rec.train_nnz();
    let sparse_o: Arc<dyn Objective> =
        Arc::new(sfw::objective::SparseCompletion::new(rec, 1.0));
    let fact_rec = {
        let mut f = FactoredMat::zeros(2000, 400);
        for _ in 0..8 {
            f.push_atom(
                rng.normal_f32() * 0.1,
                Arc::new(rng.unit_vector(2000)),
                Arc::new(rng.unit_vector(400)),
            );
        }
        f
    };
    let x_rec = sfw::linalg::Iterate::Factored(fact_rec.clone());
    let idx_s: Vec<usize> = (0..256).map(|_| rng.next_below(sparse_o.n())).collect();
    let sparse_notes = format!("2000x400, nnz={nnz}, no dense scatter");
    row("sparse grad m=256 (COO)", &sparse_notes, &mut || {
        let _ = sparse_o.grad_sum_sparse(&x_rec, &idx_s).unwrap();
    });
    let (g_coo, _) = sparse_o.grad_sum_sparse(&x_rec, &idx_s).unwrap();
    row("sparse LMO 2000x400 (COO operator)", "24 power iters, O(nnz k)", &mut || {
        let _ = power_iteration_rand(&g_coo, &mut rng, 24, 1e-7);
    });
    // serving: one user's top-k straight off the atom list, O(atoms * cols)
    let mut scores = Vec::new();
    row("serve top-k 2000x400 k=8", "user_scores + top_k(10)", &mut || {
        sfw::model::user_scores(&fact_rec, 17, &mut scores).unwrap();
        let _ = sfw::model::top_k(&scores, 10);
    });

    // ---- compute kernels (linalg::kernels: scalar vs SIMD, threads) -------
    // Paired rows differ ONLY in dispatch (force_scalar) or pool size
    // (set_pool_threads); results are bit-identical across all of them by
    // the kernels determinism contract, so the pairs time the same math.
    // The scalar-vs-simd deltas are environment-dependent and therefore
    // flagged, never gated, by bench_snapshot.py (see hotpath_env.json).
    let simd_notes = format!("dispatch: {}", kernels::cpu_features());
    let wa: Vec<f32> = (0..196 * 196).map(|_| rng.normal_f32()).collect();
    let wb: Vec<f32> = (0..196 * 196).map(|_| rng.normal_f32()).collect();
    let za: Vec<f32> = (0..2000 * 400).map(|_| rng.normal_f32()).collect();
    let zb: Vec<f32> = (0..2000 * 400).map(|_| rng.normal_f32()).collect();
    kernels::force_scalar(true);
    row("kernel dot 196x196 (scalar)", "38k elems", &mut || {
        let _ = kernels::dot64(&wa, &wb);
    });
    row("kernel dot 2000x400 (scalar)", "800k elems", &mut || {
        let _ = kernels::dot64(&za, &zb);
    });
    kernels::force_scalar(false);
    row("kernel dot 196x196 (simd)", &simd_notes, &mut || {
        let _ = kernels::dot64(&wa, &wb);
    });
    row("kernel dot 2000x400 (simd, threads=1)", &simd_notes, &mut || {
        let _ = kernels::dot64(&za, &zb);
    });
    kernels::set_pool_threads(BENCH_POOL_THREADS);
    row("kernel dot 2000x400 (simd, threads=4)", "800k elems >= pool threshold", &mut || {
        let _ = kernels::dot64(&za, &zb);
    });
    kernels::set_pool_threads(1);
    let mut yw = wa.clone();
    let mut yz = za.clone();
    kernels::force_scalar(true);
    row("kernel axpy 196x196 (scalar)", "mul_add", &mut || {
        kernels::axpy(&mut yw, 0.5, &wb);
    });
    row("kernel axpy 2000x400 (scalar)", "mul_add", &mut || {
        kernels::axpy(&mut yz, 0.5, &zb);
    });
    kernels::force_scalar(false);
    row("kernel axpy 196x196 (simd)", &simd_notes, &mut || {
        kernels::axpy(&mut yw, 0.5, &wb);
    });
    row("kernel axpy 2000x400 (simd)", &simd_notes, &mut || {
        kernels::axpy(&mut yz, 0.5, &zb);
    });
    let gd2000 = Mat::randn(2000, 400, 1.0, &mut rng);
    let x400 = rng.unit_vector(400);
    let x196 = rng.unit_vector(196);
    let mut y2000 = vec![0.0f32; 2000];
    let mut y196 = vec![0.0f32; 196];
    kernels::force_scalar(true);
    row("kernel matvec 196x196 (scalar)", "below pool threshold", &mut || {
        g196.matvec(&x196, &mut y196);
    });
    row("kernel matvec 2000x400 (scalar)", "row-chunked", &mut || {
        gd2000.matvec(&x400, &mut y2000);
    });
    kernels::force_scalar(false);
    row("kernel matvec 196x196 (simd)", &simd_notes, &mut || {
        g196.matvec(&x196, &mut y196);
    });
    row("kernel matvec 2000x400 (simd, threads=1)", &simd_notes, &mut || {
        gd2000.matvec(&x400, &mut y2000);
    });
    kernels::set_pool_threads(BENCH_POOL_THREADS);
    row("kernel matvec 2000x400 (simd, threads=4)", "16-row blocks", &mut || {
        gd2000.matvec(&x400, &mut y2000);
    });
    kernels::set_pool_threads(1);
    // factored apply on the LMO path: k * (rows + cols) = 153,600 at
    // k=64 on 2000x400, above the pool work threshold — the headline
    // threaded-kernels win (tightened in scripts/bench_thresholds.json)
    let fact_rec64 = {
        let mut f = FactoredMat::zeros(2000, 400);
        for _ in 0..64 {
            f.push_atom(
                rng.normal_f32() * 0.1,
                Arc::new(rng.unit_vector(2000)),
                Arc::new(rng.unit_vector(400)),
            );
        }
        f
    };
    kernels::force_scalar(true);
    row("lmo 196x196 factored operator k=64 (scalar)", "24 power iters", &mut || {
        let _ = power_iteration_rand(&fact196, &mut rng, 24, 1e-7);
    });
    row("lmo 2000x400 factored operator k=64 (scalar)", "24 power iters", &mut || {
        let _ = power_iteration_rand(&fact_rec64, &mut rng, 24, 1e-7);
    });
    kernels::force_scalar(false);
    row("lmo 2000x400 factored operator k=64", &simd_notes, &mut || {
        let _ = power_iteration_rand(&fact_rec64, &mut rng, 24, 1e-7);
    });
    kernels::set_pool_threads(BENCH_POOL_THREADS);
    row("lmo 2000x400 factored operator k=64 (threads=4)", "8-atom chunks", &mut || {
        let _ = power_iteration_rand(&fact_rec64, &mut rng, 24, 1e-7);
    });
    row("sparse grad m=256 (COO, threads=4)", "nnz below pool threshold; parity row", &mut || {
        let _ = sparse_o.grad_sum_sparse(&x_rec, &idx_s).unwrap();
    });
    kernels::set_pool_threads(1);

    // ---- protocol ops --------------------------------------------------------
    let mut x_upd = Mat::randn(196, 196, 0.1, &mut rng);
    let u: Vec<f32> = rng.unit_vector(196);
    let v: Vec<f32> = rng.unit_vector(196);
    row("fw_rank_one_update 196x196", "master per-iteration cost", &mut || {
        x_upd.fw_rank_one_update(0.01, -1.0, &u, &v);
    });
    let mut log = UpdateLog::new();
    for _ in 0..64 {
        log.append(rng.unit_vector(196), rng.unit_vector(196), 1.0);
    }
    let slice = log.slice_from(0);
    let mut x_rep = Mat::randn(196, 196, 0.1, &mut rng);
    row("replay 64 log entries 196x196", "worker catch-up", &mut || {
        replay(&mut x_rep, &slice);
    });
    let msg = UpdateMsg::dense(1, 100, u.clone(), v.clone(), 1.0, 0.5, 128, 0.25);
    let mut buf = Vec::new();
    row("wire codec roundtrip (196+196 floats)", "encode+decode", &mut || {
        buf.clear();
        msg.encode(&mut buf);
        let _ = UpdateMsg::decode(msg.tag(), &buf).unwrap();
    });
    // compressed dense-gradient uplink: quantize-at-construction + encode,
    // per codec — the sfw-dist worker's per-round wire cost
    let g_up = Mat::randn(196, 196, 1.0, &mut rng);
    for codec in [GradCodec::F32, GradCodec::Bf16, GradCodec::Int8] {
        let name = format!("dist uplink quantize+encode 196x196 {}", codec.label());
        let bytes = DistUp::quantized(codec, 1, 10, 0.5, g_up.clone()).wire_bytes();
        let notes = format!("{bytes} B/frame");
        row(&name, &notes, &mut || {
            buf.clear();
            DistUp::quantized(codec, 1, 10, 0.5, g_up.clone()).encode(&mut buf);
        });
    }

    // ---- PJRT (artifact) engines ----------------------------------------------
    match PjrtRuntime::new("artifacts") {
        Ok(rt) => {
            let rt = Arc::new(rt);
            let mut pj_ms = PjrtEngine::new(rt.clone(), Workload::Ms(ms.clone()), 5);
            // warm the executable cache outside the timed region
            let _ = pj_ms.step(&x_ms, &idx_128);
            row("ms grad m=128 (PJRT/Pallas)", "bucket 128", &mut || {
                let _ = pj_ms.grad_sum(&x_ms, &idx_128, &mut g);
            });
            row("ms grad m=2048 (PJRT/Pallas)", "bucket 2048", &mut || {
                let _ = pj_ms.grad_sum(&x_ms, &idx_2048, &mut g);
            });
            row("ms fused step m=2048 (PJRT/Pallas)", "grad+LMO, 1 call", &mut || {
                let _ = pj_ms.step(&x_ms, &idx_2048);
            });
            row("lmo 30x30 (PJRT/Pallas)", "16 power iters", &mut || {
                let _ = pj_ms.lmo(&g30);
            });
            let d = rt.manifest().param_usize("pnn_d").unwrap_or(196);
            if d == 196 {
                let mut pj_pnn = PjrtEngine::new(rt.clone(), Workload::Pnn(pnn.clone()), 6);
                let _ = pj_pnn.grad_sum(&x_pnn, &idxp, &mut gp);
                row("pnn grad m=256 (PJRT/Pallas)", "bucket 512", &mut || {
                    let _ = pj_pnn.grad_sum(&x_pnn, &idxp, &mut gp);
                });
            }
        }
        Err(e) => println!("(PJRT rows skipped: {e} — run `make artifacts`)"),
    }

    table.print();
    let _ = std::fs::create_dir_all("bench_out");
    table.write_csv("bench_out/hotpath.csv").expect("csv");
    // machine-readable twin for scripts/bench_snapshot.py (seconds, not
    // humanized strings)
    let mut out = String::from("op,mean_s,p50_s,p90_s,notes\n");
    for (name, s, notes) in &raw {
        out.push_str(&format!(
            "{:?},{:.9},{:.9},{:.9},{:?}\n",
            name, s.mean_s, s.p50_s, s.p90_s, notes
        ));
    }
    std::fs::write("bench_out/hotpath_raw.csv", out).expect("raw csv");
    // environment sidecar: bench_snapshot.py embeds it in the snapshot
    // and flags (never gates) comparisons across differing CPU features
    std::fs::write(
        "bench_out/hotpath_env.json",
        format!(
            "{{\"cpu_features\": \"{}\", \"pool_threads\": {}}}\n",
            kernels::cpu_features(),
            BENCH_POOL_THREADS
        ),
    )
    .expect("env json");
    println!("series written to bench_out/hotpath.csv and bench_out/hotpath_raw.csv");
}

/// Delegates the primitive ops to [`NativeEngine`] but inherits the
/// trait-default `step_it`, i.e. the densify-a-factored-iterate fallback
/// that dense-input engines (PJRT) hit every step.  With `cached` the
/// scratch pair hands out one long-lived buffer; without it the
/// stateless defaults allocate per call — the two bench rows above pin
/// the difference.
struct DensifyEngine {
    inner: NativeEngine,
    cached: bool,
    scratch: Mat,
}

impl DensifyEngine {
    fn new(inner: NativeEngine, cached: bool) -> Self {
        DensifyEngine { inner, cached, scratch: Mat::zeros(0, 0) }
    }
}

impl StepEngine for DensifyEngine {
    fn step(&mut self, x: &Mat, idx: &[usize]) -> StepOut {
        self.inner.step(x, idx)
    }

    fn grad_sum(&mut self, x: &Mat, idx: &[usize], out: &mut Mat) -> f64 {
        self.inner.grad_sum(x, idx, out)
    }

    fn lmo(&mut self, g: &Mat) -> Svd1 {
        self.inner.lmo(g)
    }

    fn objective(&self) -> &Arc<dyn sfw::objective::Objective> {
        self.inner.objective()
    }

    fn take_dense_scratch(&mut self) -> Mat {
        if self.cached {
            std::mem::replace(&mut self.scratch, Mat::zeros(0, 0))
        } else {
            Mat::zeros(0, 0)
        }
    }

    fn put_dense_scratch(&mut self, scratch: Mat) {
        if self.cached {
            self.scratch = scratch;
        }
    }
}
