//! Real TCP transport over localhost: length-prefixed little-endian frames,
//! one blocking std::net socket per worker (MPI-rank semantics; tokio is
//! not in the offline crate set).
//!
//! Frame layout: `[u32 payload_len][u8 tag][payload]`.
//! UpdateMsg payload: worker_id u32 | t_w u64 | sigma f32 | loss_sum f64 |
//!                    m u32 | ulen u32 | vlen u32 | u f32* | v f32*.
//! MasterMsg::Updates/UpdateW payload: t_m u64 | count u32 | entries,
//!   each: k u64 | eta f32 | scale f32 | ulen u32 | vlen u32 | u | v.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;

use crate::coordinator::messages::{LogEntry, MasterMsg, UpdateMsg};
use crate::metrics::Counters;
use crate::transport::{MasterLink, WorkerLink};

const TAG_UPDATE: u8 = 1;
const TAG_UPDATES: u8 = 2;
const TAG_STOP: u8 = 3;
const TAG_UPDATE_W: u8 = 4;

// ---------------------------------------------------------------- encoding

struct Enc(Vec<u8>);

impl Enc {
    fn new() -> Self {
        Enc(Vec::with_capacity(256))
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.f32(*x);
        }
    }
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> &'a [u8] {
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        s
    }
    fn u32(&mut self) -> u32 {
        u32::from_le_bytes(self.take(4).try_into().unwrap())
    }
    fn u64(&mut self) -> u64 {
        u64::from_le_bytes(self.take(8).try_into().unwrap())
    }
    fn f32(&mut self) -> f32 {
        f32::from_le_bytes(self.take(4).try_into().unwrap())
    }
    fn f64(&mut self) -> f64 {
        f64::from_le_bytes(self.take(8).try_into().unwrap())
    }
    fn f32s(&mut self) -> Vec<f32> {
        let n = self.u32() as usize;
        (0..n).map(|_| self.f32()).collect()
    }
}

pub fn encode_update(msg: &UpdateMsg) -> Vec<u8> {
    let mut e = Enc::new();
    e.u32(msg.worker_id);
    e.u64(msg.t_w);
    e.f32(msg.sigma);
    e.f64(msg.loss_sum);
    e.u32(msg.m);
    e.f32s(&msg.u);
    e.f32s(&msg.v);
    e.0
}

pub fn decode_update(buf: &[u8]) -> UpdateMsg {
    let mut d = Dec::new(buf);
    UpdateMsg {
        worker_id: d.u32(),
        t_w: d.u64(),
        sigma: d.f32(),
        loss_sum: d.f64(),
        m: d.u32(),
        u: d.f32s(),
        v: d.f32s(),
    }
}

pub fn encode_master(msg: &MasterMsg) -> (u8, Vec<u8>) {
    match msg {
        MasterMsg::Stop => (TAG_STOP, Vec::new()),
        MasterMsg::Updates { t_m, entries } => (TAG_UPDATES, encode_entries(*t_m, entries)),
        MasterMsg::UpdateW { t_m, entries } => (TAG_UPDATE_W, encode_entries(*t_m, entries)),
    }
}

fn encode_entries(t_m: u64, entries: &[LogEntry]) -> Vec<u8> {
    let mut e = Enc::new();
    e.u64(t_m);
    e.u32(entries.len() as u32);
    for le in entries {
        e.u64(le.k);
        e.f32(le.eta);
        e.f32(le.scale);
        e.f32s(&le.u);
        e.f32s(&le.v);
    }
    e.0
}

pub fn decode_master(tag: u8, buf: &[u8]) -> MasterMsg {
    match tag {
        TAG_STOP => MasterMsg::Stop,
        TAG_UPDATES | TAG_UPDATE_W => {
            let mut d = Dec::new(buf);
            let t_m = d.u64();
            let n = d.u32() as usize;
            let entries = (0..n)
                .map(|_| LogEntry {
                    k: d.u64(),
                    eta: d.f32(),
                    scale: d.f32(),
                    u: Arc::new(d.f32s()),
                    v: Arc::new(d.f32s()),
                })
                .collect();
            if tag == TAG_UPDATES {
                MasterMsg::Updates { t_m, entries }
            } else {
                MasterMsg::UpdateW { t_m, entries }
            }
        }
        t => panic!("bad master tag {t}"),
    }
}

fn write_frame(s: &mut TcpStream, tag: u8, payload: &[u8]) -> std::io::Result<u64> {
    let mut head = [0u8; 5];
    head[..4].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    head[4] = tag;
    s.write_all(&head)?;
    s.write_all(payload)?;
    Ok(5 + payload.len() as u64)
}

fn read_frame(s: &mut TcpStream) -> std::io::Result<(u8, Vec<u8>)> {
    let mut head = [0u8; 5];
    s.read_exact(&mut head)?;
    let len = u32::from_le_bytes(head[..4].try_into().unwrap()) as usize;
    let tag = head[4];
    let mut payload = vec![0u8; len];
    s.read_exact(&mut payload)?;
    Ok((tag, payload))
}

// ------------------------------------------------------------ master side

pub struct TcpMaster {
    /// Upstream demux: per-connection reader threads push decoded updates.
    rx: Receiver<UpdateMsg>,
    write_halves: Vec<TcpStream>,
    counters: Arc<Counters>,
}

/// Listen on `addr`, accept exactly `workers` connections.  Each worker
/// must send its id as the first frame (TAG_UPDATE with empty vectors and
/// worker_id set) — connection order is not identity.
pub fn tcp_master(addr: &str, workers: usize, counters: Arc<Counters>) -> std::io::Result<(TcpMaster, std::net::SocketAddr)> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let (tx, rx) = channel::<UpdateMsg>();
    let mut write_halves: Vec<Option<TcpStream>> = (0..workers).map(|_| None).collect();
    for _ in 0..workers {
        let (mut stream, _) = listener.accept()?;
        stream.set_nodelay(true)?;
        // hello frame identifies the worker
        let (tag, payload) = read_frame(&mut stream)?;
        assert_eq!(tag, TAG_UPDATE, "expected hello frame");
        let hello = decode_update(&payload);
        let id = hello.worker_id as usize;
        assert!(id < workers, "worker id {id} out of range");
        write_halves[id] = Some(stream.try_clone()?);
        let tx = tx.clone();
        let counters_r = counters.clone();
        std::thread::spawn(move || loop {
            match read_frame(&mut stream) {
                Ok((TAG_UPDATE, payload)) => {
                    counters_r.add_up(5 + payload.len() as u64);
                    if tx.send(decode_update(&payload)).is_err() {
                        return;
                    }
                }
                Ok((tag, _)) => panic!("unexpected tag {tag} from worker"),
                Err(_) => return,
            }
        });
    }
    let write_halves = write_halves.into_iter().map(Option::unwrap).collect();
    Ok((TcpMaster { rx, write_halves, counters }, local))
}

impl MasterLink for TcpMaster {
    fn recv(&mut self) -> Option<UpdateMsg> {
        self.rx.recv().ok()
    }

    fn send_to(&mut self, w: usize, msg: MasterMsg) {
        let (tag, payload) = encode_master(&msg);
        if let Ok(n) = write_frame(&mut self.write_halves[w], tag, &payload) {
            self.counters.add_down(n);
        }
    }

    fn workers(&self) -> usize {
        self.write_halves.len()
    }
}

// ------------------------------------------------------------ worker side

pub struct TcpWorker {
    stream: TcpStream,
    /// Held for symmetry with the local transport (upload bytes are
    /// counted once, master-side, to keep totals transport-invariant).
    #[allow(dead_code)]
    counters: Arc<Counters>,
}

/// Connect to the master and send the identifying hello frame.
pub fn tcp_worker(addr: &str, worker_id: u32, counters: Arc<Counters>) -> std::io::Result<TcpWorker> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let hello = UpdateMsg {
        worker_id,
        t_w: 0,
        u: Vec::new(),
        v: Vec::new(),
        sigma: 0.0,
        loss_sum: 0.0,
        m: 0,
    };
    write_frame(&mut stream, TAG_UPDATE, &encode_update(&hello))?;
    Ok(TcpWorker { stream, counters })
}

impl WorkerLink for TcpWorker {
    fn send(&mut self, msg: UpdateMsg) {
        let payload = encode_update(&msg);
        if let Ok(n) = write_frame(&mut self.stream, TAG_UPDATE, &payload) {
            // counted master-side too; count once (master side) to keep
            // totals identical to the local transport: skip here.
            let _ = n;
        }
    }

    fn recv(&mut self) -> Option<MasterMsg> {
        match read_frame(&mut self.stream) {
            Ok((tag, payload)) => Some(decode_master(tag, &payload)),
            Err(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upd() -> UpdateMsg {
        UpdateMsg {
            worker_id: 3,
            t_w: 17,
            u: vec![1.0, -2.5, 3.25],
            v: vec![0.5, 4.0],
            sigma: 6.5,
            loss_sum: 2.25,
            m: 99,
        }
    }

    #[test]
    fn update_codec_roundtrip() {
        let m = upd();
        let d = decode_update(&encode_update(&m));
        assert_eq!(d.worker_id, 3);
        assert_eq!(d.t_w, 17);
        assert_eq!(d.u, m.u);
        assert_eq!(d.v, m.v);
        assert_eq!(d.sigma, 6.5);
        assert_eq!(d.loss_sum, 2.25);
        assert_eq!(d.m, 99);
    }

    #[test]
    fn master_codec_roundtrip() {
        let e = LogEntry {
            k: 5,
            eta: 0.25,
            scale: -1.0,
            u: Arc::new(vec![1.0, 2.0]),
            v: Arc::new(vec![3.0]),
        };
        let msg = MasterMsg::Updates { t_m: 5, entries: vec![e] };
        let (tag, payload) = encode_master(&msg);
        match decode_master(tag, &payload) {
            MasterMsg::Updates { t_m, entries } => {
                assert_eq!(t_m, 5);
                assert_eq!(entries.len(), 1);
                assert_eq!(entries[0].k, 5);
                assert_eq!(*entries[0].u, vec![1.0, 2.0]);
                assert_eq!(*entries[0].v, vec![3.0]);
            }
            _ => panic!("wrong variant"),
        }
        let (tag, payload) = encode_master(&MasterMsg::Stop);
        assert!(matches!(decode_master(tag, &payload), MasterMsg::Stop));
    }

    #[test]
    fn tcp_end_to_end_roundtrip() {
        let counters = Arc::new(Counters::new());
        let cm = counters.clone();
        let handle = std::thread::spawn(move || {
            let (mut master, _) = tcp_master("127.0.0.1:41999", 2, cm).unwrap();
            // receive one real update from each worker
            let mut seen = Vec::new();
            for _ in 0..2 {
                let u = master.recv().unwrap();
                seen.push(u.worker_id);
                master.send_to(u.worker_id as usize, MasterMsg::Stop);
            }
            seen.sort();
            assert_eq!(seen, vec![0, 1]);
        });
        std::thread::sleep(std::time::Duration::from_millis(100));
        let mut hs = Vec::new();
        for id in 0..2u32 {
            let counters = counters.clone();
            hs.push(std::thread::spawn(move || {
                let mut w = tcp_worker("127.0.0.1:41999", id, counters).unwrap();
                let mut msg = upd();
                msg.worker_id = id;
                w.send(msg);
                assert!(matches!(w.recv(), Some(MasterMsg::Stop)));
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        handle.join().unwrap();
        let s = counters.snapshot();
        assert_eq!(s.msgs_up, 2);
        assert_eq!(s.msgs_down, 2);
        assert!(s.bytes_up > 0 && s.bytes_down > 0);
    }
}
