//! Transports between master and workers.
//!
//! The coordinator is transport-generic over two small traits so the same
//! master/worker logic runs over:
//!  * [`local`] — in-process mpsc channels with byte-accurate accounting
//!    (the default experimental substrate; message sizes are computed with
//!    the same `wire_bytes()` the TCP framing actually produces), and
//!  * [`tcp`] — real length-prefixed TCP sockets over localhost
//!    (std::net; tokio is not in the offline crate set), exercising true
//!    serialization, framing and kernel socket queues.

pub mod local;
pub mod tcp;

use crate::coordinator::messages::{MasterMsg, UpdateMsg};

/// Master-side endpoint: receive any worker's update, reply to one worker.
pub trait MasterLink: Send {
    /// Block until some worker's update arrives. `None` = all workers gone.
    fn recv(&mut self) -> Option<UpdateMsg>;
    /// Send a reply to worker `w`.
    fn send_to(&mut self, w: usize, msg: MasterMsg);
    /// Number of workers attached.
    fn workers(&self) -> usize;
}

/// Worker-side endpoint.
pub trait WorkerLink: Send {
    fn send(&mut self, msg: UpdateMsg);
    /// Block until the master replies. `None` = master gone.
    fn recv(&mut self) -> Option<MasterMsg>;
}
