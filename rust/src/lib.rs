//! # sfw-asyn
//!
//! Production reproduction of **"Communication-Efficient Asynchronous
//! Stochastic Frank-Wolfe over Nuclear-norm Balls"** (Zhuo, Lei, Dimakis,
//! Caramanis, 2019) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's system contribution: an asynchronous
//!   master–slave coordinator whose wire protocol is rank-one update
//!   vectors (O(D1+D2) per message), with a bounded-staleness delay gate,
//!   plus every baseline the paper compares against and the Appendix-D
//!   queuing-model simulator.
//! * **runtime** — PJRT CPU client executing AOT artifacts built once from
//!   `python/compile` (L2 JAX graphs calling L1 Pallas kernels); Python is
//!   never on the request path.
//!
//! Entry points: the `sfw` binary (see `main.rs`), `examples/`, and the
//! benches under `rust/benches/` which regenerate every table and figure
//! of the paper's evaluation.

pub mod algo;
pub mod benchkit;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod metrics;
pub mod objective;
pub mod runtime;
pub mod sim;
pub mod transport;
pub mod util;
