//! # sfw-asyn
//!
//! Production reproduction of **"Communication-Efficient Asynchronous
//! Stochastic Frank-Wolfe over Nuclear-norm Balls"** (Zhuo, Lei, Dimakis,
//! Caramanis, 2019) as a three-layer Rust + JAX + Pallas stack.
//!
//! ## Entry point: the session API
//!
//! All training — the paper's SFW-asyn and every baseline it is evaluated
//! against — goes through one composable builder:
//!
//! ```no_run
//! use sfw::session::{TaskSpec, TrainSpec, Transport};
//!
//! let report = TrainSpec::new(TaskSpec::ms(30, 3, 20_000, 0.1))
//!     .algo("sfw-asyn")        // any name in session::registry().names()
//!     .workers(8)
//!     .tau(8)
//!     .iterations(300)
//!     .transport(Transport::Local) // or Transport::Tcp: real sockets
//!     .run()
//!     .expect("train");
//! println!("{}", report.spec_echo);
//! println!("final rel loss {:.3e}", report.final_relative());
//! ```
//!
//! [`session::TrainSpec`] owns the shared wiring (objective construction,
//! native/PJRT engine factories, counters + loss trace + off-thread
//! evaluator, transport selection); each algorithm is a
//! [`session::Solver`] in the central [`session::registry`].  New
//! baseline, transport or sweep = one registry entry, not another copy of
//! the plumbing.
//!
//! ## Layers
//!
//! * **L3 ([`coordinator`])** — the paper's system contribution: an
//!   asynchronous master–slave protocol whose wire format is rank-one
//!   update vectors (O(D1+D2) per message) with a bounded-staleness delay
//!   gate, plus every baseline the paper compares against and the
//!   Appendix-D queuing-model simulator ([`sim`]).
//! * **[`comms`]** — the protocol-generic comms layer: `Wire` framed
//!   codecs with derived byte accounting, and the local-channel / TCP
//!   link endpoints every coordinator runs over (in-process or
//!   multi-process via `sfw worker`).
//! * **[`runtime`]** — PJRT CPU client executing AOT artifacts built once
//!   from `python/compile` (L2 JAX graphs calling L1 Pallas kernels);
//!   Python is never on the request path.
//!
//! Binaries: the `sfw` launcher (see `main.rs`), `examples/`, and the
//! benches under `rust/benches/` which regenerate every table and figure
//! of the paper's evaluation — all driving [`session::TrainSpec`].

pub mod algo;
pub mod benchkit;
pub mod chaos;
pub mod comms;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod lint;
pub mod metrics;
pub mod model;
pub mod objective;
pub mod runtime;
pub mod session;
pub mod sim;
pub mod sweep;
pub mod util;
