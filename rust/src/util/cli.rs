//! Minimal CLI argument parser (`clap` is not in the offline crate set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments; typed getters with defaults and error messages that name the
//! offending flag.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

pub const BOOL_SENTINEL: &str = "\u{1}true";

impl Args {
    /// Parse from an explicit token list (tests) — `--k v`, `--k=v`, `--flag`.
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut args = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.flags.insert(stripped.to_string(), v);
                } else {
                    args.flags.insert(stripped.to_string(), BOOL_SENTINEL.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the process arguments after the subcommand position.
    pub fn parse_env(skip: usize) -> Args {
        Args::parse_from(std::env::args().skip(skip))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// All flag keys given on the command line (boolean flags included) —
    /// lets callers reject misspelled `--section.key` flags instead of
    /// silently ignoring them.
    pub fn flag_keys(&self) -> impl Iterator<Item = &String> {
        self.flags.keys()
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        match self.flags.get(key) {
            Some(v) if v != BOOL_SENTINEL => v.clone(),
            _ => default.to_string(),
        }
    }

    pub fn get_opt(&self, key: &str) -> Option<String> {
        self.flags.get(key).filter(|v| *v != BOOL_SENTINEL).cloned()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get_parsed(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get_parsed(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get_parsed(key).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get_parsed(key).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        match self.flags.get(key).map(|s| s.as_str()) {
            Some(BOOL_SENTINEL) | Some("true") | Some("1") => true,
            Some("false") | Some("0") => false,
            Some(_) | None => self.flags.contains_key(key),
        }
    }

    /// Comma-separated list, e.g. `--workers 1,3,7,15`.
    pub fn get_list_usize(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get_opt(key) {
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{key}: bad integer '{s}'"))
                })
                .collect(),
            None => default.to_vec(),
        }
    }

    fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.flags.get(key).and_then(|v| {
            if v == BOOL_SENTINEL {
                return None;
            }
            match v.parse() {
                Ok(x) => Some(x),
                Err(_) => panic!("--{key}: cannot parse '{v}'"),
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_key_value_and_equals() {
        let a = parse("--workers 8 --tau=4 train");
        assert_eq!(a.get_usize("workers", 0), 8);
        assert_eq!(a.get_usize("tau", 0), 4);
        assert_eq!(a.positional(), &["train".to_string()]);
    }

    #[test]
    fn bool_flags() {
        let a = parse("--verbose --workers 2");
        assert!(a.get_bool("verbose"));
        assert!(!a.get_bool("quiet"));
        assert_eq!(a.get_usize("workers", 0), 2);
    }

    #[test]
    fn bool_flag_before_another_flag() {
        let a = parse("--verbose --quiet");
        assert!(a.get_bool("verbose") && a.get_bool("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("");
        assert_eq!(a.get_str("name", "dflt"), "dflt");
        assert_eq!(a.get_f64("eta", 0.5), 0.5);
    }

    #[test]
    fn lists() {
        let a = parse("--workers 1,3,7,15");
        assert_eq!(a.get_list_usize("workers", &[]), vec![1, 3, 7, 15]);
        assert_eq!(a.get_list_usize("absent", &[2]), vec![2]);
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn bad_number_panics_with_flag_name() {
        let a = parse("--workers abc");
        a.get_usize("workers", 0);
    }
}
