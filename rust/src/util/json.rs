//! Minimal JSON value type with a recursive-descent parser and a
//! deterministic compact renderer (serde is not in the offline crate
//! set).  Used by `sfw::sweep` for the machine-readable
//! `bench_out/sweep_*.json` results the CI trajectory tracking consumes.
//!
//! Scope: the full JSON grammar minus exotica nobody writes by hand —
//! numbers are `f64` (integers round-trip exactly up to 2^53), `\uXXXX`
//! escapes outside the BMP are not paired into surrogates.  Object key
//! order is preserved, so render(parse(x)) is stable.

use std::fmt::Write as _;

/// A parsed JSON value.  Objects keep insertion order (deterministic
/// output beats hash-order for diffable artifacts).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match; objects we build never repeat keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Convenience: `get(key)` then `as_f64`, erroring with the key name.
    pub fn f64_field(&self, key: &str) -> Result<f64, String> {
        self.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing number '{key}'"))
    }

    pub fn u64_field(&self, key: &str) -> Result<u64, String> {
        self.get(key).and_then(Json::as_u64).ok_or_else(|| format!("missing integer '{key}'"))
    }

    pub fn str_field(&self, key: &str) -> Result<&str, String> {
        self.get(key).and_then(Json::as_str).ok_or_else(|| format!("missing string '{key}'"))
    }

    /// Compact single-line rendering.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // Rust's shortest round-trip float formatting; integral
                    // values print without a fraction ("42", not "42.0").
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => render_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_string(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing characters at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn eat_lit(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.eat_lit("null", Json::Null),
            Some(b't') => self.eat_lit("true", Json::Bool(true)),
            Some(b'f') => self.eat_lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(b) => Err(format!("unexpected '{}' at byte {}", b as char, self.pos)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.pos;
            // fast path: run of plain bytes
            while let Some(&b) = self.bytes.get(self.pos) {
                if b == b'"' || b == b'\\' {
                    break;
                }
                self.pos += 1;
            }
            s.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .map_err(|_| "invalid utf-8 in string".to_string())?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape '{hex}'"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                _ => return Err("unterminated string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E') | Some(b'+') | Some(b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{text}' at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].str_field("b").unwrap(), "x");
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn render_parse_round_trips() {
        let v = Json::Obj(vec![
            ("name".into(), Json::Str("smoke \"q\"".into())),
            ("n".into(), Json::Num(42.0)),
            ("mean".into(), Json::Num(0.12345678901234567)),
            ("cells".into(), Json::Arr(vec![Json::Bool(false), Json::Null])),
        ]);
        let text = v.render();
        assert_eq!(Json::parse(&text).unwrap(), v);
        // deterministic: rendering twice is identical
        assert_eq!(text, Json::parse(&text).unwrap().render());
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::Num(0.5).render(), "0.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }
}
