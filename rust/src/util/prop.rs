//! Miniature property-based test runner (`proptest` is not in the offline
//! crate set).  Seeded + iterated: a property is checked against `n`
//! pseudo-random cases; the failing case's seed is printed so it can be
//! replayed deterministically.  No shrinking — cases are kept small by
//! construction instead.

use crate::util::rng::Rng;

/// Run `prop` on `n` cases derived from `seed`.  Panics (with the case
/// seed) on the first failing case.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, seed: u64, n: usize, mut prop: F) {
    for case in 0..n {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {case_seed:#x}): {msg}");
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 1, 25, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 2, 10, |rng| {
            let x = rng.next_f64();
            prop_assert!(x < 0.5, "x = {x}");
            Ok(())
        });
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut first = Vec::new();
        check("record", 3, 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check("record", 3, 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
