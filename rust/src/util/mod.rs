//! Dependency-free utilities: PRNG, CLI parsing, property-test runner.

pub mod cli;
pub mod prop;
pub mod rng;

pub use cli::Args;
pub use rng::Rng;
