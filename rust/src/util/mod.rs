//! Dependency-free utilities: PRNG, CLI parsing, property-test runner,
//! minimal JSON (for the sweep result artifacts).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

pub use cli::Args;
pub use json::Json;
pub use rng::Rng;
