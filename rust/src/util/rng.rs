//! Deterministic, dependency-free PRNG for the coordinator and simulators.
//!
//! The offline crate set has no `rand` (only `rand_core`), so we ship a
//! small, well-known generator: xoshiro256++ seeded through SplitMix64
//! (Blackman & Vigna).  Everything downstream — minibatch sampling,
//! queuing-model delays, synthetic data — draws from this, which makes
//! every experiment in EXPERIMENTS.md reproducible from a single seed.

/// xoshiro256++ with SplitMix64 seeding; cached Box-Muller normal.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).  Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (cached second draw).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // avoid log(0)
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.cached_normal = Some(r * s);
        r * c
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Geometric on {1, 2, ...}: number of Bernoulli(p) trials to first
    /// success (Assumption 3's compute-time model uses t = C * geometric(p)).
    pub fn geometric(&mut self, p: f64) -> u64 {
        debug_assert!(p > 0.0 && p <= 1.0);
        if p >= 1.0 {
            return 1;
        }
        let u = loop {
            let u = self.next_f64();
            if u < 1.0 {
                break u;
            }
        };
        ((1.0 - u).ln() / (1.0 - p).ln()).ceil().max(1.0) as u64
    }

    /// Sample `k` indices from [0, n) WITH replacement (matches the i.i.d.
    /// minibatch model of the analysis).
    pub fn sample_indices(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        out.clear();
        out.reserve(k);
        for _ in 0..k {
            out.push(self.next_below(n));
        }
    }

    /// Random unit vector (for LMO power-iteration restarts).
    pub fn unit_vector(&mut self, d: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; d];
        self.fill_unit_vector(&mut v);
        v
    }

    /// [`Rng::unit_vector`] into a caller-owned buffer — same draws, same
    /// rounding, no allocation (the per-step LMO restart path).
    pub fn fill_unit_vector(&mut self, v: &mut [f32]) {
        for x in v.iter_mut() {
            *x = self.normal_f32();
        }
        let n = v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32;
        let n = if n == 0.0 { 1.0 } else { n };
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn next_below_covers_range_uniformly() {
        let mut r = Rng::new(2);
        let mut hist = [0usize; 10];
        for _ in 0..100_000 {
            hist[r.next_below(10)] += 1;
        }
        for h in hist {
            assert!((h as f64 - 10_000.0).abs() < 600.0, "{h}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var {m2}");
    }

    #[test]
    fn geometric_mean_is_one_over_p() {
        let mut r = Rng::new(4);
        for &p in &[0.1, 0.5, 0.8] {
            let n = 50_000;
            let s: u64 = (0..n).map(|_| r.geometric(p)).sum();
            let mean = s as f64 / n as f64;
            assert!((mean - 1.0 / p).abs() < 0.15 / p, "p={p} mean={mean}");
        }
    }

    #[test]
    fn geometric_p1_is_deterministic() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(r.geometric(1.0), 1);
        }
    }

    #[test]
    fn unit_vector_has_unit_norm() {
        let mut r = Rng::new(6);
        for d in [1, 3, 30, 784] {
            let v = r.unit_vector(d);
            let n: f64 = v.iter().map(|x| (*x as f64).powi(2)).sum();
            assert!((n.sqrt() - 1.0).abs() < 1e-4);
        }
    }
}
