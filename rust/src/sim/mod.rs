//! Discrete-event simulators (Appendix D): algorithm execution is real
//! (actual gradients, actual LMOs, actual iterates), only TIME is virtual,
//! drawn from the queuing model of Assumption 3.

pub mod queuing;

pub use queuing::{simulate_asyn, simulate_dist, QueuingParams, SimResult};
