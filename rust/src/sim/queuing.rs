//! Appendix-D queuing-model simulation of SFW-dist vs SFW-asyn.
//!
//! Time model (Assumption 3): a task that takes C units in expectation
//! finishes in `C * Geometric(p)` units — p = 1 is a perfectly uniform
//! cluster, small p a heavy-tailed one.  Following the paper: one
//! "unit" is one D1*D2 operation, each stochastic gradient evaluation
//! costs 1 unit, the 1-SVD costs `lmo_units` (10 by default; the paper
//! notes 5/10/20 makes marginal difference), and communication is free —
//! "implicitly favoring sfw-dist".
//!
//! The simulation executes the REAL algorithm — real minibatch gradients,
//! real power-iteration LMOs, real staleness — serially in virtual-time
//! order, so the produced loss-vs-time curves (Fig 6) and speedups (Fig 7)
//! are exact algorithm trajectories, not approximations.

use std::sync::Arc;

use crate::algo::engine::StepEngine;
use crate::algo::schedule::{eta, BatchSchedule};
use crate::algo::sfw::init_rank_one;
use crate::coordinator::update_log::UpdateLog;
use crate::linalg::Mat;
use crate::metrics::{Counters, LossTrace};
use crate::objective::Objective;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct QueuingParams {
    pub workers: usize,
    /// Geometric distribution parameter p (Assumption 3).
    pub p: f64,
    /// Expected 1-SVD cost in units (paper: 10).
    pub lmo_units: f64,
    /// Master iterations T.
    pub iterations: u64,
    /// Staleness tolerance (SFW-asyn only).
    pub tau: u64,
    pub batch: BatchSchedule,
    pub eval_every: u64,
    pub seed: u64,
}

impl Default for QueuingParams {
    fn default() -> Self {
        QueuingParams {
            workers: 4,
            p: 0.1,
            lmo_units: 10.0,
            iterations: 300,
            tau: 8,
            batch: BatchSchedule::Constant(128),
            eval_every: 10,
            seed: 0,
        }
    }
}

pub struct SimResult {
    pub x: Mat,
    pub counters: Counters,
    /// Loss vs VIRTUAL time (units of D1*D2 operations).
    pub trace: LossTrace,
    pub virtual_time: f64,
}

/// Draw a task completion time: C expected units under Geometric(p).
fn task_time(c_units: f64, p: f64, rng: &mut Rng) -> f64 {
    c_units * rng.geometric(p) as f64
}

/// Simulate SFW-asyn under the queuing model (event-driven, exact
/// Algorithm-3 semantics: per-worker stale iterates + delay gate).
pub fn simulate_asyn<E: StepEngine>(
    obj: Arc<dyn Objective>,
    engines: &mut [E],
    prm: &QueuingParams,
) -> SimResult {
    assert_eq!(engines.len(), prm.workers);
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let n = obj.n();
    let counters = Counters::new();
    let trace = LossTrace::new();
    let mut rng = Rng::new(prm.seed);
    let mut log = UpdateLog::new();
    let x0 = init_rank_one(d1, d2, theta, &mut Rng::new(prm.seed ^ 0x1));
    let mut x_master = x0.clone();
    trace.record_at(0.0, 0, obj.loss_full(&x_master));

    // Per-worker state: local iterate, sync point, pending completion.
    struct Wstate {
        x: Mat,
        t_w: u64,
        done_at: f64,
        // the update being computed (filled at assignment)
        pending: Option<(Vec<f32>, Vec<f32>, usize)>,
        rng: Rng,
    }
    let mut ws: Vec<Wstate> = (0..prm.workers)
        .map(|w| Wstate {
            x: x0.clone(),
            t_w: 0,
            done_at: 0.0,
            pending: None,
            rng: rng.fork(w as u64 + 1),
        })
        .collect();

    // assign initial tasks
    let mut idx: Vec<usize> = Vec::new();
    for w in 0..prm.workers {
        let m = prm.batch.m(1);
        ws[w].rng.sample_indices(n, m, &mut idx);
        let out = engines[w].step(&ws[w].x, &idx);
        counters.add_grad_evals(m as u64);
        counters.add_lmo();
        let c = m as f64 + prm.lmo_units;
        ws[w].done_at = task_time(c, prm.p, &mut ws[w].rng);
        ws[w].pending = Some((out.u, out.v, m));
    }

    let mut now = 0.0f64;
    while log.t_m() < prm.iterations {
        // next completion
        let w = (0..prm.workers)
            .min_by(|&a, &b| ws[a].done_at.partial_cmp(&ws[b].done_at).unwrap())
            .unwrap();
        now = ws[w].done_at;
        let (u, v, m_used) = ws[w].pending.take().unwrap();
        let _ = m_used;
        let t_m = log.t_m();
        let delay = t_m - ws[w].t_w;
        if delay > prm.tau {
            counters.add_dropped();
        } else {
            let e = log.append(u, v, theta);
            x_master.fw_rank_one_update(e.eta, e.scale, &e.u, &e.v);
            counters.add_iteration();
            let t_m = log.t_m();
            counters.add_up((4 * (d1 + d2)) as u64);
            if t_m % prm.eval_every == 0 || t_m == prm.iterations {
                trace.record_at(now, t_m, obj.loss_full(&x_master));
            }
        }
        // catch the worker up (comm free in this model, but counted)
        let slice = log.slice_from(ws[w].t_w);
        counters.add_down(slice.iter().map(|e| e.wire_bytes()).sum());
        crate::coordinator::update_log::replay(&mut ws[w].x, &slice);
        ws[w].t_w = log.t_m();
        // next assignment
        let m = prm.batch.m(ws[w].t_w.max(1));
        ws[w].rng.sample_indices(n, m, &mut idx);
        let out = engines[w].step(&ws[w].x, &idx);
        counters.add_grad_evals(m as u64);
        counters.add_lmo();
        let c = m as f64 + prm.lmo_units;
        ws[w].done_at = now + task_time(c, prm.p, &mut ws[w].rng);
        ws[w].pending = Some((out.u, out.v, m));
    }
    trace.record_at(now, log.t_m(), obj.loss_full(&x_master));
    SimResult { x: x_master, counters, trace, virtual_time: now }
}

/// Simulate SFW-dist (Algorithm 1) under the queuing model: iteration time
/// = max over workers of (m/W gradient units * geometric) + master LMO.
pub fn simulate_dist<E: StepEngine>(
    obj: Arc<dyn Objective>,
    engines: &mut [E],
    prm: &QueuingParams,
) -> SimResult {
    let workers = prm.workers;
    assert!(!engines.is_empty());
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let n = obj.n();
    let counters = Counters::new();
    let trace = LossTrace::new();
    let mut rng = Rng::new(prm.seed);
    let mut wrngs: Vec<Rng> = (0..workers).map(|w| rng.fork(w as u64 + 1)).collect();
    let mut x = init_rank_one(d1, d2, theta, &mut Rng::new(prm.seed ^ 0x1));
    trace.record_at(0.0, 0, obj.loss_full(&x));

    let mut now = 0.0f64;
    let mut idx: Vec<usize> = Vec::new();
    let mut grad = Mat::zeros(d1, d2);
    let mut part = Mat::zeros(d1, d2);
    for k in 1..=prm.iterations {
        let m = prm.batch.m(k).max(workers);
        let share = m / workers;
        // all workers compute in parallel; barrier at the max completion
        let mut round = 0.0f64;
        grad.fill(0.0);
        for w in 0..workers {
            wrngs[w].sample_indices(n, share, &mut idx);
            let _ = engines[0].grad_sum(&x, &idx, &mut part);
            grad.axpy(1.0, &part);
            counters.add_grad_evals(share as u64);
            let t = task_time(share as f64, prm.p, &mut wrngs[w]);
            round = round.max(t);
            counters.add_up((4 * d1 * d2) as u64); // dense gradient upload
            counters.add_down((4 * d1 * d2) as u64); // dense X broadcast
        }
        // master 1-SVD (deterministic cost at the master)
        let s = engines[0].lmo(&grad);
        counters.add_lmo();
        counters.add_iteration();
        now += round + prm.lmo_units;
        x.fw_rank_one_update(eta(k), -theta, &s.u, &s.v);
        if k % prm.eval_every == 0 || k == prm.iterations {
            trace.record_at(now, k, obj.loss_full(&x));
        }
    }
    SimResult { x, counters, trace, virtual_time: now }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::NativeEngine;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::nuclear_norm;
    use crate::objective::MatrixSensing;

    fn obj(seed: u64) -> Arc<dyn Objective> {
        let mut rng = Rng::new(seed);
        let p = MsParams { d1: 8, d2: 8, rank: 2, n: 1_000, noise_std: 0.05 };
        Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0))
    }

    fn engines(obj: &Arc<dyn Objective>, n: usize, seed: u64) -> Vec<NativeEngine> {
        (0..n)
            .map(|w| NativeEngine::new(obj.clone(), 50, seed + w as u64))
            .collect()
    }

    #[test]
    fn asyn_sim_converges_and_tracks_virtual_time() {
        let o = obj(150);
        let prm = QueuingParams {
            workers: 4,
            p: 0.5,
            iterations: 120,
            tau: 8,
            batch: BatchSchedule::Constant(64),
            eval_every: 20,
            seed: 151,
            ..Default::default()
        };
        let mut es = engines(&o, 4, 152);
        let r = simulate_asyn(o.clone(), &mut es, &prm);
        let pts = r.trace.points();
        assert!(pts.last().unwrap().loss < 0.5 * pts.first().unwrap().loss);
        assert!(r.virtual_time > 0.0);
        assert!(nuclear_norm(&r.x) <= 1.0 + 1e-3);
        assert_eq!(r.counters.snapshot().iterations, 120);
    }

    #[test]
    fn dist_sim_converges() {
        let o = obj(153);
        let prm = QueuingParams {
            workers: 4,
            p: 0.5,
            iterations: 120,
            batch: BatchSchedule::Constant(64),
            eval_every: 20,
            seed: 154,
            ..Default::default()
        };
        let mut es = engines(&o, 1, 155);
        let r = simulate_dist(o.clone(), &mut es, &prm);
        let pts = r.trace.points();
        assert!(pts.last().unwrap().loss < 0.5 * pts.first().unwrap().loss);
        assert_eq!(r.counters.snapshot().iterations, 120);
    }

    #[test]
    fn asyn_faster_than_dist_with_stragglers() {
        // The paper's core claim (Fig 6/7): with heavy-tailed workers
        // (small p) asyn reaches the same iteration count in less virtual
        // time than the barrier-synchronized baseline.
        let o = obj(156);
        let base = QueuingParams {
            workers: 8,
            p: 0.1,
            iterations: 100,
            tau: 16,
            batch: BatchSchedule::Constant(64),
            eval_every: 50,
            seed: 157,
            ..Default::default()
        };
        let mut ea = engines(&o, 8, 158);
        let ra = simulate_asyn(o.clone(), &mut ea, &base);
        let mut ed = engines(&o, 1, 159);
        let rd = simulate_dist(o.clone(), &mut ed, &base);
        assert!(
            ra.virtual_time < rd.virtual_time,
            "asyn {} vs dist {} (virtual units)",
            ra.virtual_time,
            rd.virtual_time
        );
    }

    #[test]
    fn uniform_cluster_shrinks_the_gap() {
        // p -> 1: deterministic workers; dist's barrier costs nothing
        // extra, so the asyn/dist ratio must be much closer to 1.
        let o = obj(160);
        let mk = |p: f64, seed: u64| QueuingParams {
            workers: 4,
            p,
            iterations: 80,
            tau: 16,
            batch: BatchSchedule::Constant(64),
            eval_every: 40,
            seed,
            ..Default::default()
        };
        let ratio = |p: f64| {
            let mut ea = engines(&o, 4, 161);
            let ra = simulate_asyn(o.clone(), &mut ea, &mk(p, 162));
            let mut ed = engines(&o, 1, 163);
            let rd = simulate_dist(o.clone(), &mut ed, &mk(p, 164));
            rd.virtual_time / ra.virtual_time
        };
        let gain_tail = ratio(0.1);
        let gain_uniform = ratio(1.0);
        assert!(
            gain_tail > gain_uniform,
            "straggler speedup {gain_tail} should exceed uniform {gain_uniform}"
        );
    }
}
