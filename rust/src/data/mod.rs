//! Synthetic datasets for the paper's two workloads (§5.1).
//!
//! * Matrix sensing — exactly the paper's recipe: ground truth
//!   `X* = U V^T / ||U V^T||_*` with `U, V ∈ R^{30x3}` uniform(0,1)
//!   entries, N standard-normal sensing matrices `A_i`, responses
//!   `y_i = <A_i, X*> + eps`, eps ~ N(0, 0.1^2).
//! * PNN "MNIST-like" — substitution for MNIST (no network access; see
//!   DESIGN.md §6): feature vectors in [0,1]^D from a mixture model,
//!   binary labels from a planted low-rank quadratic teacher, which keeps
//!   the objective realizable and the communication-dominance regime
//!   (D^2 ≈ 614k parameters at D = 784) identical to the paper's.

//! * Recommender — sparse matrix completion at "millions of users" shape
//!   (the paper's §1 motivation): planted low-rank ground truth observed
//!   through a power-law per-row mask with a train/holdout split; only
//!   observed entries are materialized, so memory is O(nnz).

pub mod matrix_sensing;
pub mod pnn;
pub mod recommender;

pub use matrix_sensing::MatrixSensingData;
pub use pnn::PnnData;
pub use recommender::{RecParams, RecommenderData};
