//! Synthetic recommender dataset for sparse matrix completion.
//!
//! The paper's motivating workload ("millions of users, heavy traffic",
//! §1) is nuclear-norm-constrained completion of a sparsely observed
//! ratings matrix.  This generator plants a low-rank ground truth
//! `X* = sum_k s_k u_k v_k^T` (unit factors, geometrically decaying
//! weights normalized so `||X*||_* ~= 1` — random unit vectors are
//! near-orthogonal, so the weight sum is a tight nuclear-norm proxy) and
//! reveals a power-law-skewed subset of its entries: row `i` draws a
//! number of observed columns proportional to `(i + 1)^-alpha`, matching
//! the head-heavy user-activity profiles of real recommender logs.
//! Observations carry Gaussian noise scaled RELATIVE to the entry RMS
//! (`noise` is a fraction, not an absolute sigma — planted entries have
//! magnitude ~ 1/sqrt(d1 d2), so an absolute knob would be unusable) and
//! are split into train/holdout at the observation level.
//!
//! Only observed entries are ever materialized: generation is
//! O(nnz * rank + rows * cols) time but O(nnz) memory for the data
//! itself, so dims can grow past what a dense `Mat` could hold.

use crate::util::rng::Rng;

/// Generation parameters for the synthetic recommender.
#[derive(Clone, Debug)]
pub struct RecParams {
    /// Users (d1).
    pub rows: usize,
    /// Items (d2).
    pub cols: usize,
    /// Planted rank of the ground truth.
    pub rank: usize,
    /// Target fraction of `rows * cols` entries observed (train + holdout).
    pub density: f64,
    /// Power-law exponent of the per-row observation counts.
    pub alpha: f64,
    /// Fraction of observations held out of training.
    pub holdout: f64,
    /// Observation noise as a fraction of the clean-entry RMS.
    pub noise: f64,
}

impl Default for RecParams {
    fn default() -> Self {
        RecParams {
            rows: 400,
            cols: 120,
            rank: 4,
            density: 0.05,
            alpha: 1.1,
            holdout: 0.1,
            noise: 0.05,
        }
    }
}

/// Observed-entries recommender instance: minimize
///   F(X) = (1/N) sum_{(i,j) in train} (X_ij - A_ij)^2
///   s.t. ||X||_* <= theta.
///
/// Training observations are stored as row-sorted parallel COO arrays
/// plus a CSR `row_ptr`, so both "component t" indexing (the minibatch
/// sampler draws t in [0, N)) and per-row scans (serving's exclude-seen)
/// are O(1)/O(row nnz).
pub struct RecommenderData {
    pub rows: usize,
    pub cols: usize,
    /// Train observations, sorted by (row, col).
    pub tr_rows: Vec<u32>,
    pub tr_cols: Vec<u32>,
    pub tr_vals: Vec<f32>,
    /// CSR offsets into the `tr_*` arrays, length `rows + 1`.
    pub row_ptr: Vec<usize>,
    /// Holdout observations (never trained on).
    pub ho_rows: Vec<u32>,
    pub ho_cols: Vec<u32>,
    pub ho_vals: Vec<f32>,
    /// Mean squared observation noise over the train split (loss at X*).
    pub f_star_hint: f64,
}

impl RecommenderData {
    pub fn generate(p: &RecParams, rng: &mut Rng) -> Self {
        assert!(p.rows > 0 && p.cols > 0 && p.rank > 0, "degenerate recommender dims");
        assert!(p.density > 0.0 && p.density <= 1.0, "density must be in (0, 1]");
        assert!((0.0..1.0).contains(&p.holdout), "holdout must be in [0, 1)");

        // Planted X* = sum_k s_k u_k v_k^T with unit factors and weights
        // summing to 1 (geometric decay keeps a dominant direction, like
        // real rating matrices' strong first factor).
        let us: Vec<Vec<f32>> = (0..p.rank).map(|_| rng.unit_vector(p.rows)).collect();
        let vs: Vec<Vec<f32>> = (0..p.rank).map(|_| rng.unit_vector(p.cols)).collect();
        let mut s: Vec<f64> = (0..p.rank).map(|k| 0.7f64.powi(k as i32)).collect();
        let ssum: f64 = s.iter().sum();
        s.iter_mut().for_each(|x| *x /= ssum);
        let entry = |i: usize, j: usize| -> f64 {
            let mut acc = 0.0f64;
            for k in 0..p.rank {
                acc += s[k] * us[k][i] as f64 * vs[k][j] as f64;
            }
            acc
        };
        // Clean-entry RMS: ||X*||_F / sqrt(d1 d2) with ||X*||_F^2 ~= sum
        // s_k^2 (near-orthogonal unit atoms) — the noise scale reference.
        let frob2: f64 = s.iter().map(|x| x * x).sum();
        let rms = (frob2 / (p.rows as f64 * p.cols as f64)).sqrt();
        let sigma = p.noise * rms;

        // Power-law per-row observation counts: n_i ~ (i + 1)^-alpha,
        // scaled to the target density, clamped to [1, cols].
        let weights: Vec<f64> = (0..p.rows).map(|i| ((i + 1) as f64).powf(-p.alpha)).collect();
        let wsum: f64 = weights.iter().sum();
        let target = p.density * p.rows as f64 * p.cols as f64;
        let counts: Vec<usize> = weights
            .iter()
            .map(|w| ((target * w / wsum).round() as usize).clamp(1, p.cols))
            .collect();

        let mut tr_rows = Vec::new();
        let mut tr_cols = Vec::new();
        let mut tr_vals = Vec::new();
        let mut ho_rows = Vec::new();
        let mut ho_cols = Vec::new();
        let mut ho_vals = Vec::new();
        let mut row_ptr = Vec::with_capacity(p.rows + 1);
        row_ptr.push(0usize);
        let mut noise_sq = 0.0f64;
        // Partial Fisher-Yates scratch, rebuilt per row: distinct columns
        // without rejection loops even at n_i near cols.
        let mut scratch: Vec<u32> = (0..p.cols as u32).collect();
        let mut picked: Vec<u32> = Vec::new();
        for i in 0..p.rows {
            let ni = counts[i];
            for (c, x) in scratch.iter_mut().enumerate() {
                *x = c as u32;
            }
            picked.clear();
            for t in 0..ni {
                let r = t + rng.next_below(p.cols - t);
                scratch.swap(t, r);
                picked.push(scratch[t]);
            }
            picked.sort_unstable();
            // First pick always trains so no user row is train-empty.
            for (t, &j) in picked.iter().enumerate() {
                let eps = rng.normal() * sigma;
                let a = (entry(i, j as usize) + eps) as f32;
                if t > 0 && rng.next_f64() < p.holdout {
                    ho_rows.push(i as u32);
                    ho_cols.push(j);
                    ho_vals.push(a);
                } else {
                    tr_rows.push(i as u32);
                    tr_cols.push(j);
                    tr_vals.push(a);
                    noise_sq += eps * eps;
                }
            }
            row_ptr.push(tr_rows.len());
        }
        let f_star_hint = noise_sq / tr_vals.len().max(1) as f64;
        RecommenderData {
            rows: p.rows,
            cols: p.cols,
            tr_rows,
            tr_cols,
            tr_vals,
            row_ptr,
            ho_rows,
            ho_cols,
            ho_vals,
            f_star_hint,
        }
    }

    /// Train observation count N (the objective's component count).
    pub fn train_nnz(&self) -> usize {
        self.tr_vals.len()
    }

    /// Observed training component t as `(row, col, value)`.
    #[inline]
    pub fn triple(&self, t: usize) -> (usize, usize, f32) {
        (self.tr_rows[t] as usize, self.tr_cols[t] as usize, self.tr_vals[t])
    }

    /// Train columns observed for `row` (sorted; serving's exclude-seen).
    pub fn observed_cols(&self, row: usize) -> &[u32] {
        &self.tr_cols[self.row_ptr[row]..self.row_ptr[row + 1]]
    }

    /// Full train objective against a dense X (tests / small dims).
    pub fn loss_full(&self, x: &crate::linalg::Mat) -> f64 {
        assert_eq!((x.rows, x.cols), (self.rows, self.cols));
        let mut acc = 0.0f64;
        for t in 0..self.train_nnz() {
            let (i, j, a) = self.triple(t);
            let r = x.at(i, j) - a;
            acc += (r as f64).powi(2);
        }
        acc / self.train_nnz().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> RecommenderData {
        let p = RecParams { rows: 60, cols: 24, rank: 3, ..RecParams::default() };
        RecommenderData::generate(&p, &mut Rng::new(77))
    }

    #[test]
    fn deterministic_given_seed() {
        let p = RecParams { rows: 40, cols: 16, ..RecParams::default() };
        let a = RecommenderData::generate(&p, &mut Rng::new(9));
        let b = RecommenderData::generate(&p, &mut Rng::new(9));
        assert_eq!(a.tr_rows, b.tr_rows);
        assert_eq!(a.tr_cols, b.tr_cols);
        assert_eq!(a.tr_vals, b.tr_vals);
        assert_eq!(a.ho_vals, b.ho_vals);
        assert_eq!(a.row_ptr, b.row_ptr);
    }

    #[test]
    fn power_law_head_heavier_than_tail() {
        let d = small();
        let head: usize = (0..6).map(|i| d.observed_cols(i).len()).sum();
        let tail: usize = (54..60).map(|i| d.observed_cols(i).len()).sum();
        assert!(head > tail, "head {head} not heavier than tail {tail}");
    }

    #[test]
    fn every_row_trains_and_cols_are_sorted_distinct() {
        let d = small();
        for i in 0..d.rows {
            let cols = d.observed_cols(i);
            assert!(!cols.is_empty(), "row {i} train-empty");
            for w in cols.windows(2) {
                assert!(w[0] < w[1], "row {i}: cols not sorted-distinct");
            }
        }
    }

    #[test]
    fn holdout_split_roughly_matches_fraction() {
        let p = RecParams { rows: 200, cols: 40, holdout: 0.25, ..RecParams::default() };
        let d = RecommenderData::generate(&p, &mut Rng::new(12));
        let total = d.train_nnz() + d.ho_vals.len();
        let frac = d.ho_vals.len() as f64 / total as f64;
        assert!((frac - 0.25).abs() < 0.07, "holdout fraction {frac}");
    }

    #[test]
    fn density_within_factor_of_target() {
        // The min-one-per-row clamp inflates small grids above the
        // target, so pin a factor-of-two band rather than a tight abs.
        let d = small();
        let total = (d.train_nnz() + d.ho_vals.len()) as f64;
        let density = total / (d.rows * d.cols) as f64;
        assert!(density > 0.025 && density < 0.1, "density {density}");
    }
}
