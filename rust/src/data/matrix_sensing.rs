//! Matrix-sensing synthetic dataset (paper §5.1, first task).

use crate::linalg::{nuclear_norm, Mat};
use crate::util::rng::Rng;

/// Matrix sensing instance: minimize
///   F(X) = (1/N) sum_i (<A_i, X> - y_i)^2   s.t.  ||X||_* <= theta.
///
/// Sensing matrices are stored flattened: `af` is (N, D1*D2) row-major, so
/// <A_i, X> = af.row(i) . vec(X) — the same layout the AOT artifacts use.
pub struct MatrixSensingData {
    pub d1: usize,
    pub d2: usize,
    pub n: usize,
    /// (N, D1*D2) flattened sensing matrices.
    pub af: Mat,
    /// Responses, length N.
    pub y: Vec<f32>,
    /// Ground-truth X* (nuclear norm 1), for relative-error reporting.
    pub x_star: Mat,
    /// F(X*) (nonzero under observation noise) — used for rel. loss.
    pub f_star_hint: f64,
}

/// Generation parameters (defaults = the paper's §5.1 settings).
#[derive(Clone, Debug)]
pub struct MsParams {
    pub d1: usize,
    pub d2: usize,
    pub rank: usize,
    pub n: usize,
    pub noise_std: f32,
}

impl Default for MsParams {
    fn default() -> Self {
        MsParams { d1: 30, d2: 30, rank: 3, n: 90_000, noise_std: 0.1 }
    }
}

impl MatrixSensingData {
    pub fn generate(p: &MsParams, rng: &mut Rng) -> Self {
        // X* = U V^T / ||U V^T||_*, U, V ~ U[0,1]^{d x r}  (paper recipe)
        let u = Mat::rand_uniform(p.d1, p.rank, rng);
        let v = Mat::rand_uniform(p.d2, p.rank, rng);
        let mut x_star = u.matmul(&v.transpose());
        let nn = nuclear_norm(&x_star) as f32;
        x_star.scale(1.0 / nn);

        let k = p.d1 * p.d2;
        let mut af = Mat::zeros(p.n, k);
        let mut y = vec![0.0f32; p.n];
        let xs = &x_star.data;
        let mut loss_at_star = 0.0f64;
        for i in 0..p.n {
            let row = af.row_mut(i);
            let mut dot = 0.0f64;
            for (a, &x) in row.iter_mut().zip(xs.iter()) {
                let g = rng.normal_f32();
                *a = g;
                dot += g as f64 * x as f64;
            }
            let eps = rng.normal_f32() * p.noise_std;
            y[i] = dot as f32 + eps;
            loss_at_star += (eps as f64).powi(2);
        }
        let f_star_hint = loss_at_star / p.n as f64;
        MatrixSensingData { d1: p.d1, d2: p.d2, n: p.n, af, y, x_star, f_star_hint }
    }

    /// Full objective F(X) = (1/N) sum residual^2.
    pub fn loss_full(&self, x: &Mat) -> f64 {
        assert_eq!((x.rows, x.cols), (self.d1, self.d2));
        let xf = &x.data;
        let mut acc = 0.0f64;
        for i in 0..self.n {
            let r = crate::linalg::dot(self.af.row(i), xf) - self.y[i];
            acc += (r as f64).powi(2);
        }
        acc / self.n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> (MatrixSensingData, Rng) {
        let mut rng = Rng::new(100);
        let p = MsParams { d1: 8, d2: 6, rank: 2, n: 500, noise_std: 0.05 };
        (MatrixSensingData::generate(&p, &mut rng), rng)
    }

    #[test]
    fn ground_truth_on_nuclear_sphere() {
        let (d, _) = small();
        assert!((nuclear_norm(&d.x_star) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn responses_match_ground_truth_up_to_noise() {
        let (d, _) = small();
        // F(X*) should be about noise_std^2
        let l = d.loss_full(&d.x_star);
        assert!((l - 0.0025).abs() < 0.0015, "loss at X*: {l}");
        assert!((l - d.f_star_hint).abs() < 1e-9);
    }

    #[test]
    fn loss_at_zero_larger_than_at_star() {
        let (d, _) = small();
        let zero = Mat::zeros(8, 6);
        assert!(d.loss_full(&zero) > 5.0 * d.loss_full(&d.x_star));
    }

    #[test]
    fn deterministic_given_seed() {
        let p = MsParams { d1: 4, d2: 4, rank: 1, n: 50, noise_std: 0.1 };
        let a = MatrixSensingData::generate(&p, &mut Rng::new(7));
        let b = MatrixSensingData::generate(&p, &mut Rng::new(7));
        assert_eq!(a.af.data, b.af.data);
        assert_eq!(a.y, b.y);
    }
}
