//! PNN "MNIST-like" synthetic dataset (substitution for MNIST; DESIGN.md §6).
//!
//! The paper trains a two-layer polynomial (quadratic-activation) network
//! with smooth hinge loss on MNIST, binarized 0-4 vs 5-9, pixels scaled to
//! [0,1].  Offline we plant a low-rank quadratic teacher: features come
//! from a K-component mixture over [0,1]^D (MNIST-like: nonnegative,
//! strongly correlated coordinates), labels are
//! `y = sign(a^T X_t a - b)` with a rank-r teacher X_t, so the objective is
//! realizable by exactly the model class being trained and the
//! loss-vs-time behaviour (the experiment's subject) is comparable.

use crate::linalg::{nuclear_norm, Mat};
use crate::util::rng::Rng;

pub struct PnnData {
    pub d: usize,
    pub n: usize,
    /// (N, D) feature rows in [0, 1].
    pub a: Mat,
    /// Labels in {-1, +1}.
    pub y: Vec<f32>,
    /// Planted teacher (nuclear norm 1), for diagnostics.
    pub x_teacher: Mat,
}

#[derive(Clone, Debug)]
pub struct PnnParams {
    pub d: usize,
    pub n: usize,
    pub teacher_rank: usize,
    pub mixture_components: usize,
}

impl Default for PnnParams {
    fn default() -> Self {
        // Full paper scale is d = 784 (28x28), n = 60_000; the default here
        // matches the default AOT artifact dim (196 = 14x14) for CI speed.
        PnnParams { d: 196, n: 60_000, teacher_rank: 4, mixture_components: 10 }
    }
}

impl PnnData {
    pub fn generate(p: &PnnParams, rng: &mut Rng) -> Self {
        // Teacher: X_t = sum_r u_r v_r^T, normalized to unit nuclear norm.
        let u = Mat::randn(p.d, p.teacher_rank, 1.0, rng);
        let v = Mat::randn(p.d, p.teacher_rank, 1.0, rng);
        let mut x_t = u.matmul(&v.transpose());
        let nn = nuclear_norm(&x_t) as f32;
        x_t.scale(1.0 / nn);

        // Mixture centers in [0,1]^D ("digit prototypes").
        let centers: Vec<Vec<f32>> = (0..p.mixture_components)
            .map(|_| (0..p.d).map(|_| rng.next_f32()).collect())
            .collect();

        let mut a = Mat::zeros(p.n, p.d);
        let mut scores = vec![0.0f64; p.n];
        let mut w = vec![0.0f32; p.d];
        for i in 0..p.n {
            let c = &centers[rng.next_below(p.mixture_components)];
            let row = a.row_mut(i);
            for (x, &cj) in row.iter_mut().zip(c.iter()) {
                // jittered prototype, clamped to [0,1] like scaled pixels
                *x = (cj + 0.25 * rng.normal_f32()).clamp(0.0, 1.0);
            }
            // score = a^T X_t a
            x_t.matvec(row, &mut w[..p.d]);
            scores[i] = crate::linalg::dot(row, &w) as f64;
        }
        // Threshold at the median score => balanced classes, like the
        // paper's 0-4 vs 5-9 split (~49/51).
        let mut sorted = scores.clone();
        sorted.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let thresh = sorted[p.n / 2];
        let y = scores
            .iter()
            .map(|&s| if s > thresh { 1.0 } else { -1.0 })
            .collect();
        PnnData { d: p.d, n: p.n, a, y, x_teacher: x_t }
    }

    /// Smooth hinge value (continuous version; see kernels/ref.py).
    #[inline]
    pub fn smooth_hinge(ty: f32) -> f32 {
        if ty <= 0.0 {
            0.5 - ty
        } else if ty <= 1.0 {
            0.5 * (1.0 - ty) * (1.0 - ty)
        } else {
            0.0
        }
    }

    /// d(smooth hinge)/d(ty).
    #[inline]
    pub fn smooth_hinge_dt(ty: f32) -> f32 {
        if ty <= 0.0 {
            -1.0
        } else if ty <= 1.0 {
            -(1.0 - ty)
        } else {
            0.0
        }
    }

    /// Full objective F(X) = (1/N) sum s-hinge(y_i * a_i^T X a_i).
    pub fn loss_full(&self, x: &Mat) -> f64 {
        assert_eq!((x.rows, x.cols), (self.d, self.d));
        let mut w = vec![0.0f32; self.d];
        let mut acc = 0.0f64;
        for i in 0..self.n {
            let row = self.a.row(i);
            x.matvec(row, &mut w);
            let z = crate::linalg::dot(row, &w);
            acc += Self::smooth_hinge(self.y[i] * z) as f64;
        }
        acc / self.n as f64
    }

    /// 0/1 classification accuracy of sign(a^T X a) vs labels.
    pub fn accuracy(&self, x: &Mat) -> f64 {
        let mut w = vec![0.0f32; self.d];
        let mut correct = 0usize;
        for i in 0..self.n {
            let row = self.a.row(i);
            x.matvec(row, &mut w);
            let z = crate::linalg::dot(row, &w);
            if z * self.y[i] > 0.0 {
                correct += 1;
            }
        }
        correct as f64 / self.n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> PnnData {
        let p = PnnParams { d: 12, n: 400, teacher_rank: 2, mixture_components: 4 };
        PnnData::generate(&p, &mut Rng::new(200))
    }

    #[test]
    fn features_in_unit_box_labels_pm1() {
        let d = small();
        assert!(d.a.data.iter().all(|&x| (0.0..=1.0).contains(&x)));
        assert!(d.y.iter().all(|&y| y == 1.0 || y == -1.0));
    }

    #[test]
    fn classes_balanced() {
        let d = small();
        let pos = d.y.iter().filter(|&&y| y > 0.0).count();
        let frac = pos as f64 / d.n as f64;
        assert!((frac - 0.5).abs() < 0.02, "{frac}");
    }

    #[test]
    fn teacher_has_unit_nuclear_norm_and_separates() {
        let d = small();
        assert!((nuclear_norm(&d.x_teacher) - 1.0).abs() < 1e-4);
        // scaled teacher should beat chance clearly (labels are threshold
        // of the teacher score, so sign agreement is high by construction
        // modulo the median shift)
        let acc = d.accuracy(&d.x_teacher);
        assert!(acc > 0.6, "teacher accuracy {acc}");
    }

    #[test]
    fn smooth_hinge_continuous_and_convex_pieces() {
        let f = PnnData::smooth_hinge;
        assert!((f(0.0) - 0.5).abs() < 1e-7);
        assert!((f(-1e-6) - f(1e-6)).abs() < 1e-5);
        assert!((f(1.0) - 0.0).abs() < 1e-7);
        assert_eq!(f(2.0), 0.0);
        let g = PnnData::smooth_hinge_dt;
        assert_eq!(g(-1.0), -1.0);
        assert!((g(0.5) + 0.5).abs() < 1e-7);
        assert_eq!(g(1.5), 0.0);
    }

    #[test]
    fn loss_at_teacher_below_loss_at_zero() {
        let d = small();
        let mut scaled = d.x_teacher.clone();
        scaled.scale(1.0); // theta = 1 feasible point
        let zero = Mat::zeros(d.d, d.d);
        assert!(d.loss_full(&scaled) < d.loss_full(&zero));
    }
}
