//! [`GradCodec`] — the compressed dense-uplink codec family
//! (`--uplink f32 | bf16 | int8`).
//!
//! The sfw-dist downlink already ships atoms only in factored mode, so
//! the dense gradient **uplink** is the last O(d1·d2) wire cost per
//! round.  Bellet et al. (arXiv:1404.2644) show distributed FW tolerates
//! aggressively compressed exchanges when the update structure is
//! preserved; this module supplies the two standard lossy encodings plus
//! the exact baseline:
//!
//! * `f32`  — the uncompressed baseline (4 B/entry, bit-exact wire
//!   layout identical to the pre-codec protocol);
//! * `bf16` — truncate each f32 to its upper 16 bits (2 B/entry,
//!   ~2–3 significant decimal digits, NaN-preserving, idempotent);
//! * `int8` — per-row scaled quantization `q = round(x / s)` with
//!   `s = max|row| / 127` (1 B/entry plus one f32 scale per row).
//!
//! Lossy codecs pair with the per-worker error-feedback accumulator
//! ([`crate::linalg::ErrorFeedback`]): the quantization residual is
//! added into the next round's gradient instead of being lost, which is
//! what keeps the vanilla-SFW convergence rate (see the `sfw::comms`
//! module docs for the full uplink contract).
//!
//! Quantization is **idempotent at the message layer**: the
//! `DistUp`/`UpdateMsg` constructors quantize once and store the
//! *dequantized* values together with the scales, so `encode -> decode`
//! is the identity on the struct, local and TCP transports deliver
//! bit-identical gradients, and the round-trip property tests can pin
//! exact equality (`rust/tests/properties.rs`).
//!
//! Non-finite handling: a NaN-poisoned gradient (the desync signal of
//! the sfw-dist worker) stays detectable under every codec — bf16
//! truncation preserves NaN bit patterns, and an int8 row containing a
//! non-finite value gets scale = NaN, which dequantizes the whole row to
//! NaN.  The master's finite gate therefore drops poisoned replies
//! without any codec-specific special-casing.

/// Uplink gradient codec, selected per run by `TrainSpec::uplink`
/// (`--uplink`) and carried inside each quantized wire message so the
/// decoder is self-describing (the frame tag picks the variant).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradCodec {
    /// Uncompressed f32 entries (the default; exact).
    F32,
    /// Upper-16-bit truncation of each f32 (half the bytes).
    Bf16,
    /// Per-row scaled int8 (a quarter of the bytes plus one scale/row).
    Int8,
}

impl GradCodec {
    /// All codecs, registration order (drives docs and sweep axes).
    pub const ALL: &'static [GradCodec] = &[GradCodec::F32, GradCodec::Bf16, GradCodec::Int8];

    /// The accepted-label listing for error messages.
    pub const VALID: &'static str = "f32 | bf16 | int8";

    /// Axis/flag label (round-trips through [`GradCodec::parse`]).
    pub fn label(self) -> &'static str {
        match self {
            GradCodec::F32 => "f32",
            GradCodec::Bf16 => "bf16",
            GradCodec::Int8 => "int8",
        }
    }

    /// Parse a `--uplink` / sweep-axis value.
    pub fn parse(s: &str) -> Option<GradCodec> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" => Some(GradCodec::F32),
            "bf16" => Some(GradCodec::Bf16),
            "int8" => Some(GradCodec::Int8),
            _ => None,
        }
    }

    /// Whether the codec discards precision (and therefore wants the
    /// error-feedback accumulator on gradient paths).
    pub fn is_lossy(self) -> bool {
        !matches!(self, GradCodec::F32)
    }
}

impl Default for GradCodec {
    fn default() -> Self {
        GradCodec::F32
    }
}

/// Truncate one f32 to bf16 precision (upper 16 bits, no rounding).
/// Idempotent and NaN-preserving: the quiet-NaN payload bits live in the
/// kept half, so `bf16_truncate(NaN)` is still NaN.
pub fn bf16_truncate(x: f32) -> f32 {
    f32::from_bits(x.to_bits() & 0xFFFF_0000)
}

/// The 16 wire bits of a bf16-truncated value.
pub fn bf16_bits(x: f32) -> u16 {
    (x.to_bits() >> 16) as u16
}

/// Rebuild the f32 a bf16 wire value denotes.
pub fn bf16_from_bits(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Per-slice int8 scale: `max|x| / 127`.  Returns NaN when the slice
/// contains a non-finite entry (dequantizing then poisons the whole
/// slice, keeping NaN-poisoned gradients detectable), and 0.0 for an
/// all-zero slice (every entry quantizes and dequantizes to 0.0).
pub fn int8_scale(xs: &[f32]) -> f32 {
    let mut max = 0.0f32;
    for &x in xs {
        if !x.is_finite() {
            return f32::NAN;
        }
        max = max.max(x.abs());
    }
    max / 127.0
}

/// Quantize one value against a scale; 0 when the scale is unusable
/// (NaN or zero), which pairs with [`int8_dequant`]'s poisoning/zeroing.
pub fn int8_quant(x: f32, s: f32) -> i8 {
    if s.is_finite() && s > 0.0 {
        // clamp guards fp drift at the extremes; round() makes the
        // quantizer exact on already-dequantized inputs (idempotency)
        (x / s).round().clamp(-127.0, 127.0) as i8
    } else {
        0
    }
}

/// Dequantize one value: `s * q` (NaN scale poisons, zero scale zeroes).
pub fn int8_dequant(q: i8, s: f32) -> f32 {
    s * q as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn labels_round_trip_and_reject_junk() {
        for &c in GradCodec::ALL {
            assert_eq!(GradCodec::parse(c.label()), Some(c));
        }
        assert_eq!(GradCodec::parse(" BF16 "), Some(GradCodec::Bf16));
        assert_eq!(GradCodec::parse("fp32"), None);
        assert_eq!(GradCodec::default(), GradCodec::F32);
        assert!(!GradCodec::F32.is_lossy());
        assert!(GradCodec::Bf16.is_lossy() && GradCodec::Int8.is_lossy());
    }

    #[test]
    fn bf16_truncation_is_idempotent_bounded_and_nan_preserving() {
        let mut rng = Rng::new(50);
        for _ in 0..500 {
            let x = rng.normal_f32() * 10f32.powi(rng.next_below(7) as i32 - 3);
            let t = bf16_truncate(x);
            assert_eq!(bf16_truncate(t), t, "not idempotent at {x}");
            assert_eq!(bf16_from_bits(bf16_bits(t)), t, "wire bits lossy at {x}");
            // truncation error is below one ulp of the 8-bit mantissa
            assert!((x - t).abs() <= x.abs() / 256.0, "{x} -> {t}");
        }
        assert!(bf16_truncate(f32::NAN).is_nan());
        assert_eq!(bf16_truncate(f32::INFINITY), f32::INFINITY);
        assert_eq!(bf16_truncate(0.0), 0.0);
    }

    #[test]
    fn int8_quantizer_is_idempotent_on_dequantized_values() {
        let mut rng = Rng::new(51);
        for _ in 0..200 {
            let n = 1 + rng.next_below(40);
            let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            let s = int8_scale(&xs);
            for &x in &xs {
                let q = int8_quant(x, s);
                let dq = int8_dequant(q, s);
                // error bound: half a quantization step
                assert!((x - dq).abs() <= s * 0.5 + 1e-12, "{x} -> {dq} (s={s})");
                // idempotency: re-quantizing the dequantized value is exact
                assert_eq!(int8_quant(dq, s), q, "drift at x={x} s={s}");
                assert_eq!(int8_dequant(int8_quant(dq, s), s), dq);
            }
        }
    }

    #[test]
    fn int8_scale_poisons_non_finite_and_zeroes_empty_rows() {
        assert!(int8_scale(&[1.0, f32::NAN, 2.0]).is_nan());
        assert!(int8_scale(&[f32::INFINITY]).is_nan());
        let s = int8_scale(&[0.0, 0.0]);
        assert_eq!(s, 0.0);
        assert_eq!(int8_quant(0.0, s), 0);
        assert_eq!(int8_dequant(0, s), 0.0);
        // NaN scale: q pins to 0, dequant poisons
        assert_eq!(int8_quant(123.0, f32::NAN), 0);
        assert!(int8_dequant(0, f32::NAN).is_nan());
    }
}
