//! In-process transport: std::sync::mpsc channels with byte-accurate
//! accounting (every message is charged its derived
//! [`Wire::wire_bytes`] — exactly what the TCP framing puts on a real
//! socket) and optional injected latency to emulate heterogeneous
//! cluster links.  Generic over the protocol's `(Up, Down)` message
//! pair, so every coordinator runs over it unchanged.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::comms::{MasterLink, Wire, WorkerLink};
use crate::metrics::Counters;

pub struct LocalMaster<Up, Down> {
    rx: Receiver<Up>,
    txs: Vec<Sender<Down>>,
    counters: Arc<Counters>,
}

pub struct LocalWorker<Up, Down> {
    tx: Sender<Up>,
    rx: Receiver<Down>,
    counters: Arc<Counters>,
    /// Fixed one-way latency injected on send (None = none).
    pub latency: Option<Duration>,
}

/// Build a master endpoint + `workers` worker endpoints sharing `counters`.
pub fn local_links<Up: Wire, Down: Wire>(
    workers: usize,
    counters: Arc<Counters>,
    latency: Option<Duration>,
) -> (LocalMaster<Up, Down>, Vec<LocalWorker<Up, Down>>) {
    // lint: allow(bounded-channel-depth): depth <= W — each worker has at
    // most one un-answered update in flight (it blocks on recv after send)
    let (up_tx, up_rx) = channel::<Up>();
    let mut txs = Vec::with_capacity(workers);
    let mut wlinks = Vec::with_capacity(workers);
    for _ in 0..workers {
        // lint: allow(bounded-channel-depth): depth <= 1 — the master sends
        // one reply per update received from this worker
        let (down_tx, down_rx) = channel::<Down>();
        txs.push(down_tx);
        wlinks.push(LocalWorker {
            tx: up_tx.clone(),
            rx: down_rx,
            counters: counters.clone(),
            latency,
        });
    }
    (LocalMaster { rx: up_rx, txs, counters }, wlinks)
}

impl<Up: Wire, Down: Wire> MasterLink<Up, Down> for LocalMaster<Up, Down> {
    fn recv(&mut self) -> Option<Up> {
        self.rx.recv().ok()
    }

    fn send_to(&mut self, w: usize, msg: Down) {
        self.counters.add_down(msg.wire_bytes());
        // worker may have exited already; dropping the message then is fine
        let _ = self.txs[w].send(msg);
    }

    fn workers(&self) -> usize {
        self.txs.len()
    }
}

impl<Up: Wire, Down: Wire> WorkerLink<Up, Down> for LocalWorker<Up, Down> {
    fn send(&mut self, msg: Up) {
        if let Some(lat) = self.latency {
            std::thread::sleep(lat);
        }
        self.counters.add_up(msg.wire_bytes());
        let _ = self.tx.send(msg);
    }

    fn recv(&mut self) -> Option<Down> {
        self.rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::FRAME_HEADER;
    use crate::coordinator::messages::{MasterMsg, UpdateMsg};

    fn upd(w: u32, d: usize) -> UpdateMsg {
        UpdateMsg::dense(w, 0, vec![0.0; d], vec![0.0; d], 1.0, 0.0, 8, 0.0)
    }

    #[test]
    fn roundtrip_and_accounting() {
        let counters = Arc::new(Counters::new());
        let (mut master, mut workers) =
            local_links::<UpdateMsg, MasterMsg>(2, counters.clone(), None);
        let msg = upd(1, 10);
        let up_bytes = msg.wire_bytes();
        workers[1].send(msg);
        let got = master.recv().unwrap();
        assert_eq!(got.worker_id, 1);
        master.send_to(1, MasterMsg::Stop);
        assert!(matches!(workers[1].recv(), Some(MasterMsg::Stop)));
        let s = counters.snapshot();
        assert_eq!(s.bytes_up, up_bytes);
        // Stop is an empty payload: exactly one frame header on the wire.
        assert_eq!(s.bytes_down, FRAME_HEADER as u64);
        assert_eq!(s.msgs_up, 1);
        assert_eq!(s.msgs_down, 1);
    }

    #[test]
    fn master_recv_none_when_workers_dropped() {
        let counters = Arc::new(Counters::new());
        let (mut master, workers) = local_links::<UpdateMsg, MasterMsg>(1, counters, None);
        drop(workers);
        assert!(master.recv().is_none());
    }

    #[test]
    fn send_to_dead_worker_does_not_panic() {
        let counters = Arc::new(Counters::new());
        let (mut master, workers) = local_links::<UpdateMsg, MasterMsg>(1, counters, None);
        drop(workers);
        master.send_to(0, MasterMsg::Stop);
    }
}
