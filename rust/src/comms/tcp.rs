//! Real TCP transport: length-prefixed frames over blocking std::net
//! sockets, one connection per worker rank (MPI-rank semantics; tokio is
//! not in the offline crate set).  Generic over the protocol's
//! `(Up, Down)` message pair — the same endpoints carry SFW-asyn,
//! SVRF-asyn and SFW-dist, in-process or across processes/hosts.
//!
//! Connection handshake: the worker's first frame is a transport-level
//! hello ([`TAG_HELLO`] + rank u32) — connection order is not identity.
//!
//! Accounting convention: uplink bytes are counted once, master-side (by
//! the per-connection reader threads), and downlink bytes at
//! [`MasterLink::send_to`]; [`TcpWorker`] counts nothing.  The master's
//! [`Counters`] therefore hold the complete both-direction totals even
//! when workers are external processes, and the totals equal the local
//! transport's because both charge exact frame sizes.
//!
//! Every endpoint reuses its encode and decode buffers across messages
//! ([`frame_into`] + [`read_frame_into`]), so steady-state traffic —
//! including the dense sfw-dist gradient uplink — allocates nothing per
//! frame.
//!
//! [`Counters`]: crate::metrics::Counters

use std::io::{Read, Write};
use std::marker::PhantomData;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::comms::{
    frame_into, MasterLink, Wire, WireError, WorkerLink, FRAME_HEADER, MAX_FRAME_LEN, TAG_HELLO,
};
use crate::metrics::Counters;

/// Read one frame into `payload` (reusing its allocation), returning the
/// tag.  Each reader — the per-connection master threads and the worker
/// recv loop — owns one such buffer for the connection's lifetime.
fn read_frame_into(s: &mut TcpStream, payload: &mut Vec<u8>) -> std::io::Result<u8> {
    let mut head = [0u8; FRAME_HEADER];
    s.read_exact(&mut head)?;
    let len = u32::from_le_bytes([head[0], head[1], head[2], head[3]]) as usize;
    // reject a corrupt length prefix BEFORE allocating for it
    if len > MAX_FRAME_LEN {
        return Err(io_invalid(format!(
            "frame payload length {len} exceeds MAX_FRAME_LEN {MAX_FRAME_LEN}"
        )));
    }
    payload.clear();
    payload.resize(len, 0);
    s.read_exact(payload)?;
    Ok(head[4])
}

fn hello_frame(rank: u32) -> Vec<u8> {
    let mut buf = vec![0u8; FRAME_HEADER];
    buf.extend_from_slice(&rank.to_le_bytes());
    buf[..4].copy_from_slice(&4u32.to_le_bytes());
    buf[4] = TAG_HELLO;
    buf
}

fn decode_hello(tag: u8, payload: &[u8]) -> Result<usize, WireError> {
    if tag != TAG_HELLO {
        return Err(WireError::BadTag(tag));
    }
    if payload.len() != 4 {
        return Err(WireError::Malformed("hello payload must be a u32 rank"));
    }
    Ok(u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) as usize)
}

fn io_invalid<E: Into<Box<dyn std::error::Error + Send + Sync>>>(e: E) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e)
}

// ------------------------------------------------------------ master side

pub struct TcpMaster<Up, Down> {
    /// Upstream demux: per-connection reader threads push decoded
    /// messages (and charge their frame bytes) as they arrive.
    rx: Receiver<Up>,
    write_halves: Vec<TcpStream>,
    counters: Arc<Counters>,
    /// Reused downlink encode buffer (see module docs).
    scratch: Vec<u8>,
    _down: PhantomData<fn(Down)>,
}

/// How long the accept loop waits for a freshly-connected client's
/// hello frame before rejecting it as a silent stray (half-open client,
/// health check).  Overridable via [`tcp_master_on_with`]: chaos/CI
/// tests shrink it so a silent stray costs milliseconds, saturated CI
/// hosts can grow it.
pub const DEFAULT_HELLO_TIMEOUT: Duration = Duration::from_secs(10);

/// [`tcp_master_on_with`] with [`DEFAULT_HELLO_TIMEOUT`].
pub fn tcp_master_on<Up: Wire, Down: Wire>(
    listener: TcpListener,
    workers: usize,
    counters: Arc<Counters>,
) -> std::io::Result<TcpMaster<Up, Down>> {
    tcp_master_on_with(listener, workers, counters, DEFAULT_HELLO_TIMEOUT)
}

/// Accept `workers` valid worker connections on an **already-bound**
/// listener.  Binding first (and handing the listener here) is what lets
/// callers learn the port of an ephemeral bind before any worker
/// connects — there is no drop-and-rebind race.
///
/// A stray or misbehaving connection (port scanner, bad hello frame,
/// out-of-range or duplicate rank) is logged and dropped; the accept
/// loop keeps waiting for the remaining valid workers rather than
/// aborting the run.  `hello_timeout` bounds how long a silent stray
/// can stall acceptance.
pub fn tcp_master_on_with<Up: Wire, Down: Wire>(
    listener: TcpListener,
    workers: usize,
    counters: Arc<Counters>,
    hello_timeout: Duration,
) -> std::io::Result<TcpMaster<Up, Down>> {
    // lint: allow(bounded-channel-depth): depth <= W — the per-worker reader
    // threads fan in here, and each remote worker blocks for its reply
    // before framing another update
    let (tx, rx) = channel::<Up>();
    let mut write_halves: Vec<Option<TcpStream>> = (0..workers).map(|_| None).collect();
    let mut accepted = 0;
    while accepted < workers {
        let (mut stream, peer) = match listener.accept() {
            Ok(conn) => conn,
            // a connection reset before accept (port scanner RST) is not
            // a master failure — keep accepting
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::ConnectionAborted
                        | std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                eprintln!("comms: transient accept error: {e}");
                continue;
            }
            Err(e) => return Err(e),
        };
        let _ = stream.set_nodelay(true);
        // A silent stray connection (half-open client, health check) must
        // not stall acceptance of the real workers: the hello must arrive
        // promptly.  The timeout is cleared once the worker is validated —
        // protocol reads may legitimately block for minutes.
        let _ = stream.set_read_timeout(Some(hello_timeout));
        let mut hello = Vec::new();
        let rank = match read_frame_into(&mut stream, &mut hello) {
            Ok(tag) => match decode_hello(tag, &hello) {
                Ok(rank) if rank < workers && write_halves[rank].is_none() => rank,
                Ok(rank) => {
                    eprintln!("comms: rejecting {peer}: rank {rank} out of range or duplicate");
                    continue;
                }
                Err(e) => {
                    eprintln!("comms: rejecting {peer}: bad hello: {e}");
                    continue;
                }
            },
            Err(e) => {
                eprintln!("comms: rejecting {peer}: {e}");
                continue;
            }
        };
        let _ = stream.set_read_timeout(None);
        write_halves[rank] = Some(stream.try_clone()?);
        let tx = tx.clone();
        let counters = counters.clone();
        std::thread::spawn(move || {
            let mut payload = Vec::new();
            loop {
                match read_frame_into(&mut stream, &mut payload) {
                    Ok(tag) => {
                        let bytes = (FRAME_HEADER + payload.len()) as u64;
                        match Up::decode(tag, &payload) {
                            Ok(msg) => {
                                counters.add_up(bytes);
                                if tx.send(msg).is_err() {
                                    return;
                                }
                            }
                            Err(e) => {
                                eprintln!("comms: closing worker {rank}: {e}");
                                return;
                            }
                        }
                    }
                    Err(_) => return,
                }
            }
        });
        accepted += 1;
    }
    // The accept loop only exits once every rank slot is filled, but a
    // logic slip here must surface as an error, not a panic mid-accept.
    let write_halves: Vec<TcpStream> = write_halves.into_iter().flatten().collect();
    if write_halves.len() != workers {
        return Err(io_invalid("accept loop exited with unfilled worker rank slots"));
    }
    Ok(TcpMaster { rx, write_halves, counters, scratch: Vec::new(), _down: PhantomData })
}

/// Bind `addr` and accept exactly `workers` connections.  Returns the
/// resolved local address (useful with an ephemeral `:0` bind).
pub fn tcp_master<Up: Wire, Down: Wire>(
    addr: &str,
    workers: usize,
    counters: Arc<Counters>,
) -> std::io::Result<(TcpMaster<Up, Down>, SocketAddr)> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    Ok((tcp_master_on(listener, workers, counters)?, local))
}

impl<Up: Wire, Down: Wire> MasterLink<Up, Down> for TcpMaster<Up, Down> {
    fn recv(&mut self) -> Option<Up> {
        self.rx.recv().ok()
    }

    fn send_to(&mut self, w: usize, msg: Down) {
        frame_into(&mut self.scratch, &msg);
        if self.write_halves[w].write_all(&self.scratch).is_ok() {
            self.counters.add_down(self.scratch.len() as u64);
        }
    }

    fn workers(&self) -> usize {
        self.write_halves.len()
    }
}

// ------------------------------------------------------------ worker side

pub struct TcpWorker<Up, Down> {
    stream: TcpStream,
    /// Reused uplink encode buffer (see module docs).
    scratch: Vec<u8>,
    /// Reused downlink decode buffer.
    payload: Vec<u8>,
    _proto: PhantomData<fn(Up) -> Down>,
}

/// Connect to the master and send the identifying hello frame.
pub fn tcp_worker<Up: Wire, Down: Wire>(
    addr: &str,
    rank: u32,
) -> std::io::Result<TcpWorker<Up, Down>> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    stream.write_all(&hello_frame(rank))?;
    Ok(TcpWorker { stream, scratch: Vec::new(), payload: Vec::new(), _proto: PhantomData })
}

/// [`tcp_worker`], retrying until `timeout` — for external worker
/// processes started before (or racing) the master's bind.
pub fn connect_retry<Up: Wire, Down: Wire>(
    addr: &str,
    rank: u32,
    timeout: Duration,
) -> std::io::Result<TcpWorker<Up, Down>> {
    let deadline = Instant::now() + timeout;
    loop {
        match tcp_worker(addr, rank) {
            Ok(w) => return Ok(w),
            Err(e) if Instant::now() >= deadline => return Err(e),
            Err(_) => std::thread::sleep(Duration::from_millis(50)),
        }
    }
}

impl<Up: Wire, Down: Wire> WorkerLink<Up, Down> for TcpWorker<Up, Down> {
    fn send(&mut self, msg: Up) {
        // Uplink bytes are counted once, master-side (see module docs).
        frame_into(&mut self.scratch, &msg);
        let _ = self.stream.write_all(&self.scratch);
    }

    fn recv(&mut self) -> Option<Down> {
        let tag = read_frame_into(&mut self.stream, &mut self.payload).ok()?;
        match Down::decode(tag, &self.payload) {
            Ok(m) => Some(m),
            Err(e) => {
                eprintln!("comms: bad frame from master: {e}");
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::{DistDown, DistUp, MasterMsg, UpdateMsg};
    use crate::linalg::Mat;

    fn upd(id: u32) -> UpdateMsg {
        UpdateMsg::dense(id, 17, vec![1.0, -2.5, 3.25], vec![0.5, 4.0], 6.5, 2.25, 99, 0.5)
    }

    #[test]
    fn tcp_end_to_end_roundtrip_with_rank_mapping() {
        let counters = Arc::new(Counters::new());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let cm = counters.clone();
        let handle = std::thread::spawn(move || {
            let mut master = tcp_master_on::<UpdateMsg, MasterMsg>(listener, 2, cm).unwrap();
            let mut seen = Vec::new();
            for _ in 0..2 {
                let u = master.recv().unwrap();
                seen.push(u.worker_id);
                master.send_to(u.worker_id as usize, MasterMsg::Stop);
            }
            seen.sort();
            assert_eq!(seen, vec![0, 1]);
        });
        let mut hs = Vec::new();
        for id in 0..2u32 {
            hs.push(std::thread::spawn(move || {
                let mut w =
                    tcp_worker::<UpdateMsg, MasterMsg>(&addr.to_string(), id).unwrap();
                w.send(upd(id));
                assert!(matches!(w.recv(), Some(MasterMsg::Stop)));
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        handle.join().unwrap();
        let s = counters.snapshot();
        assert_eq!(s.msgs_up, 2);
        assert_eq!(s.msgs_down, 2);
        // both directions charge exact frame sizes
        assert_eq!(s.bytes_up, 2 * upd(0).wire_bytes());
        assert_eq!(s.bytes_down, 2 * MasterMsg::Stop.wire_bytes());
    }

    #[test]
    fn dist_protocol_crosses_the_same_wire() {
        let counters = Arc::new(Counters::new());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut master =
                tcp_master_on::<DistUp, DistDown>(listener, 1, counters).unwrap();
            master.send_to(
                0,
                DistDown::Compute {
                    k: 3,
                    m_share: 8,
                    x: Arc::new(Mat::from_vec(1, 2, vec![1.0, 2.0])),
                },
            );
            let up = master.recv().unwrap();
            assert_eq!(up.worker_id, 0);
            assert_eq!(up.k, 3);
            assert_eq!(up.grad.data, vec![0.5, -0.5]);
            master.send_to(0, DistDown::Stop);
        });
        let mut w = tcp_worker::<DistUp, DistDown>(&addr.to_string(), 0).unwrap();
        match w.recv() {
            Some(DistDown::Compute { k, m_share, x }) => {
                assert_eq!((k, m_share), (3, 8));
                assert_eq!(x.data, vec![1.0, 2.0]);
            }
            other => panic!("expected Compute, got {other:?}"),
        }
        w.send(DistUp::dense(0, 3, 1.0, Mat::from_vec(1, 2, vec![0.5, -0.5])));
        assert!(matches!(w.recv(), Some(DistDown::Stop)));
        handle.join().unwrap();
    }

    #[test]
    fn stray_and_bad_rank_connections_are_skipped_not_fatal() {
        // A port scanner (connect + close, no hello) and a worker with an
        // out-of-range rank must not abort the master: it keeps accepting
        // until a valid worker arrives and then runs the protocol.
        let counters = Arc::new(Counters::new());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut master = tcp_master_on::<UpdateMsg, MasterMsg>(listener, 1, counters).unwrap();
            let u = master.recv().unwrap();
            assert_eq!(u.worker_id, 0);
            master.send_to(0, MasterMsg::Stop);
        });
        drop(TcpStream::connect(addr).unwrap()); // stray: no hello
        let bad = tcp_worker::<UpdateMsg, MasterMsg>(&addr.to_string(), 9).unwrap();
        let mut w = tcp_worker::<UpdateMsg, MasterMsg>(&addr.to_string(), 0).unwrap();
        w.send(upd(0));
        assert!(matches!(w.recv(), Some(MasterMsg::Stop)));
        drop(bad);
        handle.join().unwrap();
    }

    #[test]
    fn hello_timeout_knob_unsticks_a_silent_stray() {
        // A connected-but-silent client (half-open peer) must only stall
        // acceptance for the configured hello timeout — the knob exists
        // so tests like this one pay milliseconds, not the 10s default.
        let counters = Arc::new(Counters::new());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let master = std::thread::spawn(move || {
            tcp_master_on_with::<UpdateMsg, MasterMsg>(
                listener,
                1,
                counters,
                Duration::from_millis(100),
            )
            .unwrap()
        });
        let _silent = TcpStream::connect(addr).unwrap(); // never says hello
        std::thread::sleep(Duration::from_millis(20));
        let start = Instant::now();
        let _w = tcp_worker::<UpdateMsg, MasterMsg>(&addr.to_string(), 0).unwrap();
        let m = master.join().unwrap();
        assert_eq!(m.workers(), 1);
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "silent stray stalled acceptance for {:?}",
            start.elapsed()
        );
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        let counters = Arc::new(Counters::new());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let evil = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // claims a ~4 GiB payload; master must reject, not allocate
            let mut head = u32::MAX.to_le_bytes().to_vec();
            head.push(TAG_HELLO);
            let _ = s.write_all(&head);
            s
        });
        // master rejects the frame and keeps accepting; a valid worker
        // then completes the handshake.
        let master = std::thread::spawn(move || {
            tcp_master_on::<UpdateMsg, MasterMsg>(listener, 1, counters).unwrap()
        });
        let _s = evil.join().unwrap();
        let _w = tcp_worker::<UpdateMsg, MasterMsg>(&addr.to_string(), 0).unwrap();
        let m = master.join().unwrap();
        assert_eq!(m.workers(), 1);
    }
}
