//! `sfw::comms` — the protocol-generic communication layer.
//!
//! Every distributed algorithm in the repo speaks a small typed protocol
//! (the paper's rank-one `{u, v, t_w}` exchange for SFW-asyn/SVRF-asyn,
//! the dense — or, in factored-iterate mode, atoms-only
//! (`DistDown::ComputeFactored`, see [`crate::linalg::FactoredMat`] and
//! the `sfw::session` factored quickstart) — broadcast/reduce round of
//! SFW-dist).  This module factors what is common to all of them:
//!
//! * [`Wire`] — encode/decode of one protocol message to a
//!   length-prefixed frame (`[u32 payload_len][u8 tag][payload]`).
//!   `wire_bytes()` is **derived from the actual encoded length**, never
//!   hand-counted, so the byte accounting the paper's comm-cost claims
//!   rest on is pinned to the real framing by construction (and by the
//!   round-trip property tests in `rust/tests/properties.rs`).
//! * [`MasterLink`] / [`WorkerLink`] — the generic endpoints a protocol
//!   master/worker runs against.  Byte/message accounting happens *in
//!   the link* ([`metrics::Counters`]), not at protocol call-sites, so
//!   every transport reports identical totals for identical traffic.
//! * [`local`] — in-process mpsc channels charging exact frame sizes
//!   (the default experimental substrate, with optional injected link
//!   latency).
//! * [`tcp`] — real blocking std::net sockets over the same frames
//!   (tokio is not in the offline crate set), one connection per worker
//!   rank, usable in-process, cross-process and cross-host.
//!
//! # Multi-process quickstart (master + two workers on loopback)
//!
//! ```text
//! # terminal 1 — master: bind a fixed port, don't spawn local workers
//! sfw train --algo sfw-asyn --transport tcp --workers 2 \
//!           --tcp-bind 127.0.0.1:7070 --tcp-await true \
//!           --task matrix_sensing --seed 42 --batch 64
//!
//! # terminals 2 & 3 — one process per worker rank, same spec flags
//! sfw worker --connect 127.0.0.1:7070 --rank 0 --algo sfw-asyn \
//!            --task matrix_sensing --seed 42 --batch 64
//! sfw worker --connect 127.0.0.1:7070 --rank 1 --algo sfw-asyn \
//!            --task matrix_sensing --seed 42 --batch 64
//! ```
//!
//! The spec fields that shape the data and the schedules (task + `[data]`
//! keys, `--seed`, `--batch`/`--tau`) must match across the processes:
//! workers regenerate the dataset and the batch schedule locally from
//! them — shipping the data is exactly what the paper's protocol avoids.
//!
//! # Compressed uplink codec + error feedback
//!
//! [`GradCodec`] (`--uplink f32 | bf16 | int8`, default `f32`) selects
//! how the dense payloads of the **uplink** messages — sfw-dist's
//! per-round partial gradient (`DistUp`) and the async protocols'
//! rank-one `{u, v}` pair (`UpdateMsg`) — are laid out on the wire.
//! `bf16` truncates each f32 to 16 bits; `int8` ships one scale per
//! gradient row (per vector for `UpdateMsg`) plus 1 byte per entry.
//! Each codec is its own frame tag with a closed-form `wire_bytes()`,
//! pinned to the real encoding by the round-trip property tests.  The
//! contract call-sites rely on:
//!
//! * **Quantize once, at construction.**  The message constructors
//!   (`DistUp::quantized`, `UpdateMsg::quantized`) quantize and store
//!   the *dequantized* values plus the scales, so `encode -> decode` is
//!   the identity, and local-channel and TCP deliveries are
//!   bit-identical — receivers never see codec-dependent values.
//! * **Error feedback on gradients, not atoms.**  Workers on the
//!   sfw-dist gradient path carry the quantization residual into the
//!   next round via [`crate::linalg::ErrorFeedback`] (compensate →
//!   quantize → absorb), which preserves the convergence rate.  The
//!   async `{u, v}` atoms are unit-normalized directions gated by the
//!   master's sanity check; they are quantized plainly (no feedback),
//!   and the ~1/254-per-entry error stays far inside that gate.
//! * **Poison survives compression.**  bf16 truncation preserves NaN;
//!   an int8 row with a non-finite entry gets scale = NaN and
//!   dequantizes to NaN — so the master's finite gate catches poisoned
//!   gradients under every codec, with no special-casing.
//!
//! # Fault injection
//!
//! [`crate::chaos`] wraps any [`WorkerLink`] in a deterministic, seeded
//! fault layer (delays, drops, duplicates, reordering, bit corruption,
//! crashes, late joins) behind these same traits — see its fault-model
//! table for the semantics and replay guarantees, and
//! `rust/tests/chaos.rs` for the per-solver conformance matrix.
//!
//! # Static guarantees
//!
//! This module is a `sfw lint` hot module ([`crate::lint`] has the rule
//! table and the allow grammar): non-test code here must be panic-free
//! (decode errors are [`WireError`] values, never unwraps), every
//! `Wire` implementor must appear in the round-trip property tests, and
//! no mutex guard may be held across a `send`/`recv`.  CI runs the pass
//! on every push.
//!
//! [`metrics::Counters`]: crate::metrics::Counters

pub mod codec;
pub mod grad_codec;
pub mod local;
pub mod tcp;

pub use codec::{Dec, Enc};
pub use grad_codec::GradCodec;
pub use local::{local_links, LocalMaster, LocalWorker};
pub use tcp::{
    connect_retry, tcp_master, tcp_master_on, tcp_master_on_with, tcp_worker, TcpMaster,
    TcpWorker, DEFAULT_HELLO_TIMEOUT,
};

/// Length-prefixed frame header size: `[u32 payload_len][u8 tag]`.
pub const FRAME_HEADER: usize = 5;

/// Upper bound on a single frame payload (256 MiB — a dense f32 matrix
/// up to ~8190x8190; today's workloads are <= 784x784).  The TCP reader
/// rejects larger length prefixes *before* allocating, so a corrupt
/// peer cannot force a multi-GiB allocation.  Bump if workloads grow.
pub const MAX_FRAME_LEN: usize = 1 << 28;

/// Frame tag reserved for the transport-level hello (the worker-rank
/// announcement `tcp` sends on connect).  Protocol tags must stay below
/// this value.
pub const TAG_HELLO: u8 = 0xF0;

/// Decode failures of a framed message.  Surfaced as errors (never
/// panics) so a corrupt peer cannot crash the coordinator.
#[derive(Debug, thiserror::Error)]
pub enum WireError {
    #[error("unknown frame tag {0}")]
    BadTag(u8),
    #[error("frame truncated: needed {need} more byte(s), {have} left")]
    Truncated { need: usize, have: usize },
    #[error("frame has {0} trailing byte(s)")]
    Trailing(usize),
    #[error("malformed frame: {0}")]
    Malformed(&'static str),
}

/// One protocol message that can cross a transport boundary.
///
/// Implementations define the payload layout (via [`Enc`]/[`Dec`]) and a
/// per-variant `tag`; the frame header itself is owned by this module
/// ([`frame`]), so every protocol shares one framing and one notion of
/// message size.
pub trait Wire: Sized + Send + 'static {
    /// Frame tag identifying the message variant within its protocol
    /// (must be `< TAG_HELLO`).
    fn tag(&self) -> u8;

    /// Append the frame payload (everything after the header) to `buf`.
    fn encode(&self, buf: &mut Vec<u8>);

    /// Rebuild a message from its frame tag + payload.
    fn decode(tag: u8, payload: &[u8]) -> Result<Self, WireError>;

    /// Exact on-the-wire size of this message: frame header plus the
    /// encoded payload length.  This is what every transport charges to
    /// [`Counters`], which is why local-channel byte totals equal real
    /// TCP byte totals.  The default derives it by encoding; messages on
    /// hot accounting paths may override with an O(1) closed form, but
    /// any override MUST be pinned equal to the actual encoding by a
    /// round-trip property test (`tests/properties.rs` does this for
    /// every protocol message).
    ///
    /// [`Counters`]: crate::metrics::Counters
    fn wire_bytes(&self) -> u64 {
        let mut buf = Vec::with_capacity(64);
        self.encode(&mut buf);
        (FRAME_HEADER + buf.len()) as u64
    }
}

/// Serialize a message into one complete frame (header + payload),
/// reusing `buf`'s allocation (cleared first).  This is the hot-path
/// spelling: the TCP endpoints keep one scratch buffer per send
/// direction, so steady-state framing allocates nothing.
///
/// Panics (sender-side, with the real cause named) if the payload
/// exceeds [`MAX_FRAME_LEN`]: shipping it would only get the frame
/// rejected by the receiver as corrupt — and a >= 4 GiB payload would
/// silently truncate the u32 length prefix and desynchronize the stream.
pub fn frame_into<W: Wire>(buf: &mut Vec<u8>, msg: &W) {
    buf.clear();
    buf.resize(FRAME_HEADER, 0);
    msg.encode(buf);
    let payload = buf.len() - FRAME_HEADER;
    assert!(
        payload <= MAX_FRAME_LEN,
        "frame payload of {payload} bytes exceeds comms::MAX_FRAME_LEN ({MAX_FRAME_LEN}); \
         bump the limit for this workload size"
    );
    buf[..4].copy_from_slice(&(payload as u32).to_le_bytes());
    buf[4] = msg.tag();
}

/// Serialize a message into one freshly-allocated frame (see
/// [`frame_into`] for the buffer-pooled hot-path form and the panic
/// contract).
pub fn frame<W: Wire>(msg: &W) -> Vec<u8> {
    let mut buf = Vec::with_capacity(FRAME_HEADER + 64);
    frame_into(&mut buf, msg);
    buf
}

/// Master-side endpoint of a `(Up, Down)` protocol: receive any worker's
/// message, reply to one worker by rank.
pub trait MasterLink<Up: Wire, Down: Wire>: Send {
    /// Block until some worker's message arrives.  `None` = all workers
    /// disconnected.
    fn recv(&mut self) -> Option<Up>;
    /// Send a reply to worker rank `w` (accounted as downlink traffic).
    fn send_to(&mut self, w: usize, msg: Down);
    /// Number of worker ranks attached.
    fn workers(&self) -> usize;
}

/// Worker-side endpoint of a `(Up, Down)` protocol.
pub trait WorkerLink<Up: Wire, Down: Wire>: Send {
    fn send(&mut self, msg: Up);
    /// Block until the master replies.  `None` = master gone.
    fn recv(&mut self) -> Option<Down>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::{MasterMsg, UpdateMsg};

    #[test]
    fn frame_layout_is_len_tag_payload() {
        let f = frame(&MasterMsg::Stop);
        assert_eq!(f.len(), FRAME_HEADER);
        assert_eq!(u32::from_le_bytes(f[..4].try_into().unwrap()), 0);
        assert_eq!(f[4], MasterMsg::Stop.tag());
    }

    #[test]
    fn wire_bytes_is_the_frame_length() {
        let m = UpdateMsg::dense(1, 7, vec![1.0; 13], vec![2.0; 9], 0.5, 1.25, 64, 0.75);
        assert_eq!(m.wire_bytes(), frame(&m).len() as u64);
        assert_eq!(MasterMsg::Stop.wire_bytes(), FRAME_HEADER as u64);
    }

    #[test]
    fn frame_into_reuses_the_buffer_and_matches_frame() {
        let m = UpdateMsg::quantized(
            GradCodec::Int8,
            1,
            7,
            vec![0.25; 13],
            vec![-0.5; 9],
            0.5,
            1.25,
            64,
            0.75,
        );
        let mut buf = Vec::new();
        frame_into(&mut buf, &m);
        assert_eq!(buf, frame(&m));
        let cap = buf.capacity();
        // a second, smaller frame reuses the allocation
        frame_into(&mut buf, &MasterMsg::Stop);
        assert_eq!(buf, frame(&MasterMsg::Stop));
        assert_eq!(buf.capacity(), cap);
    }
}
