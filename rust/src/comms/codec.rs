//! Binary codec primitives shared by every protocol's
//! [`Wire`](crate::comms::Wire) implementation: little-endian scalar
//! writers/readers over a plain byte buffer, plus the vector/matrix
//! composites the protocols actually ship.

use crate::comms::WireError;
use crate::linalg::Mat;

/// Decode one little-endian f32 from an exact 4-byte chunk (the chunk
/// size is guaranteed by `chunks_exact(4)` at the call sites).
fn le_f32(c: &[u8]) -> f32 {
    f32::from_le_bytes([c[0], c[1], c[2], c[3]])
}

/// Appends little-endian fields to a frame payload buffer.
pub struct Enc<'a>(pub &'a mut Vec<u8>);

impl Enc<'_> {
    pub fn u16(&mut self, v: u16) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    pub fn f32(&mut self, v: f32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    pub fn f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    /// Length-prefixed f32 vector.
    pub fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.f32(*x);
        }
    }
    /// Dense row-major matrix: rows, cols, then the f32 entries.
    pub fn mat(&mut self, m: &Mat) {
        self.u32(m.rows as u32);
        self.u32(m.cols as u32);
        for x in &m.data {
            self.f32(*x);
        }
    }
    /// Raw bytes, no length prefix (the length is fixed by surrounding
    /// fields — e.g. the int8 entry block of a quantized gradient).
    pub fn raw(&mut self, bytes: &[u8]) {
        self.0.extend_from_slice(bytes);
    }
}

/// Cursor over a frame payload.  Every read is bounds-checked so a
/// truncated or corrupt frame surfaces as a [`WireError`], never a panic.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or(WireError::Malformed("length overflow"))?;
        if end > self.buf.len() {
            return Err(WireError::Truncated { need: n, have: self.buf.len() - self.pos });
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    /// Bounds-checked 4-byte read as an array (the panic-free spelling
    /// of `take(4)?.try_into().unwrap()`).
    fn take4(&mut self) -> Result<[u8; 4], WireError> {
        let s = self.take(4)?;
        Ok([s[0], s[1], s[2], s[3]])
    }

    fn take8(&mut self) -> Result<[u8; 8], WireError> {
        let s = self.take(8)?;
        Ok([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]])
    }

    pub fn u16(&mut self) -> Result<u16, WireError> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }
    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take4()?))
    }
    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take8()?))
    }
    pub fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take4()?))
    }
    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take8()?))
    }

    /// Length-prefixed f32 vector.
    pub fn f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let n = self.u32()? as usize;
        let nb = n.checked_mul(4).ok_or(WireError::Malformed("vector length overflow"))?;
        let bytes = self.take(nb)?;
        Ok(bytes.chunks_exact(4).map(le_f32).collect())
    }

    /// Dense row-major matrix (see [`Enc::mat`]).
    pub fn mat(&mut self) -> Result<Mat, WireError> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let nb = rows
            .checked_mul(cols)
            .and_then(|n| n.checked_mul(4))
            .ok_or(WireError::Malformed("matrix dims overflow"))?;
        let bytes = self.take(nb)?;
        let data = bytes.chunks_exact(4).map(le_f32).collect();
        Ok(Mat::from_vec(rows, cols, data))
    }

    /// Raw byte block of a known length (see [`Enc::raw`]).
    pub fn raw(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        self.take(n)
    }

    /// Assert the payload was consumed exactly.
    pub fn finish(self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::Trailing(self.buf.len() - self.pos));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_vectors_round_trip() {
        let mut buf = Vec::new();
        let mut e = Enc(&mut buf);
        e.u16(60_000);
        e.u32(7);
        e.u64(1 << 40);
        e.f32(-2.5);
        e.f64(0.125);
        e.f32s(&[1.0, 2.0, 3.0]);
        e.raw(&[9, 8, 7]);
        let mut d = Dec::new(&buf);
        assert_eq!(d.u16().unwrap(), 60_000);
        assert_eq!(d.u32().unwrap(), 7);
        assert_eq!(d.u64().unwrap(), 1 << 40);
        assert_eq!(d.f32().unwrap(), -2.5);
        assert_eq!(d.f64().unwrap(), 0.125);
        assert_eq!(d.f32s().unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(d.raw(3).unwrap(), &[9, 8, 7]);
        d.finish().unwrap();
    }

    #[test]
    fn matrices_round_trip() {
        let m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mut buf = Vec::new();
        Enc(&mut buf).mat(&m);
        let mut d = Dec::new(&buf);
        assert_eq!(d.mat().unwrap(), m);
        d.finish().unwrap();
    }

    #[test]
    fn truncated_and_trailing_payloads_error() {
        let mut buf = Vec::new();
        Enc(&mut buf).u64(9);
        assert!(matches!(Dec::new(&buf[..5]).u64(), Err(WireError::Truncated { .. })));
        let mut d = Dec::new(&buf);
        d.u32().unwrap();
        assert!(matches!(d.finish(), Err(WireError::Trailing(4))));
        // vector length prefix pointing past the buffer
        let mut buf = Vec::new();
        Enc(&mut buf).u32(1_000);
        assert!(matches!(Dec::new(&buf).f32s(), Err(WireError::Truncated { .. })));
    }
}
