//! [`TrainSpec`]: the builder every training entrypoint goes through.
//!
//! A spec fully describes one run — task, algorithm, scale knobs,
//! transport, engine — and `run()` resolves it against the solver
//! [`registry`](crate::session::registry::registry): objective + engine
//! factory construction happen in [`RunCtx`], the solver does only the
//! algorithm, and the caller gets a uniform [`Report`].

use std::sync::Arc;
use std::time::Duration;

use crate::algo::schedule::{BatchSchedule, StepMethod};
use crate::chaos::FaultPlan;
use crate::comms::GradCodec;
use crate::config::TrainConfig;
use crate::coordinator::worker::Straggler;
use crate::linalg::Repr;
use crate::runtime::PjrtRuntime;
use crate::session::registry::registry;
use crate::session::{EngineKind, Report, ReprKind, RunCtx, SessionError, TaskSpec, Transport};

/// Declarative description of one training run.  Construct with
/// [`TrainSpec::new`], chain setters, finish with [`TrainSpec::run`].
#[derive(Clone)]
pub struct TrainSpec {
    pub task: TaskSpec,
    /// Registry name: `sfw | sfw-asyn | svrf-asyn | sfw-dist | sva |
    /// dfw-power | pgd` (see `registry().names()`).
    pub algo: String,
    pub workers: usize,
    /// Compute-kernel thread budget: the process-wide
    /// [`crate::linalg::kernels`] pool size the hot loops (power
    /// iteration, factored apply, sparse gradient) stripe across.
    /// Deterministic by construction — any value produces bit-identical
    /// results to `threads = 1` (the kernels determinism contract) — so
    /// it is purely a wall-clock knob.  Workers share one pool per
    /// process.
    pub threads: usize,
    /// Staleness tolerance tau of the asynchronous delay gate.
    pub tau: u64,
    /// Master iterations T (for `svrf-asyn` see [`TrainSpec::epochs`]).
    pub iterations: u64,
    /// SVRF-asyn outer epochs; `None` derives `ceil(log2(T))` from
    /// `iterations` (matching the historical launcher behaviour).
    pub epochs: Option<u32>,
    /// Explicit batch schedule; `None` picks the algorithm's theorem
    /// schedule from `batch_scale`/`batch_cap`/`tau`.
    pub batch: Option<BatchSchedule>,
    pub batch_scale: f64,
    pub batch_cap: usize,
    pub power_iters: usize,
    /// Iterate representation: dense, factored, or `Auto` (per-objective
    /// default — see [`ReprKind`] and the module-doc quickstart).
    pub repr: ReprKind,
    /// Uplink gradient codec (`f32 | bf16 | int8`): compresses the
    /// worker->master payloads of the link-based solvers — sfw-dist's
    /// dense partial gradients (with per-worker error feedback) and the
    /// async protocols' rank-one atoms.  See the `sfw::comms` module
    /// docs for the codec contract and the `sfw::session` quickstart.
    pub uplink: GradCodec,
    /// Nuclear-ball radius for generated tasks (ignored for
    /// [`TaskSpec::Prebuilt`], whose objective carries its own theta).
    pub theta: f32,
    pub seed: u64,
    pub eval_every: u64,
    pub engine: EngineKind,
    pub artifacts_dir: String,
    /// Pre-built PJRT runtime to share with the caller (e.g. for
    /// artifact-based evaluation after training); `None` loads the
    /// artifacts from `artifacts_dir` when `engine` is `Pjrt`.
    pub pjrt_runtime: Option<Arc<PjrtRuntime>>,
    pub transport: Transport,
    /// TCP only: explicit master bind address (`host:port`); `None`
    /// binds a loopback ephemeral port.
    pub tcp_bind: Option<String>,
    /// TCP only: spawn no local worker threads; await `workers` external
    /// `sfw worker --connect ... --rank R` processes instead.
    pub tcp_await: bool,
    /// Observer for the bound TCP master address (fires after bind,
    /// before workers are awaited) — multi-process orchestration/tests.
    pub bound_notify: Option<crate::session::BoundNotify>,
    pub straggler: Option<Straggler>,
    /// Injected one-way link latency (local transport only).
    pub link_latency: Option<Duration>,
    /// Deterministic fault-injection plan wrapping every worker link
    /// (see [`crate::chaos`]); applies to the link-based solvers on
    /// both transports, with in-process workers.
    pub fault_plan: Option<FaultPlan>,
    /// DFW-power rounds at FW iteration t: `base + slope * t`.
    pub dfw_rounds_base: u64,
    pub dfw_rounds_slope: f64,
    /// Dual-gap stopping tolerance: the run ends early once the solver's
    /// per-iteration FW dual-gap estimate `g_k = <grad f(X_k), X_k - s_k>`
    /// falls to this value (0 disables, the default).  Honored by every
    /// registry solver; the async masters stop on the uplinked worker
    /// gap (stale by at most tau), PGD pays one extra power iteration
    /// per step to estimate it.
    pub tol: f64,
    /// Step-size policy (`vanilla | analytic | line-search | armijo |
    /// away | pairwise`).  `away`/`pairwise` maintain an active atom set
    /// and require `--algo sfw` with a factored iterate; the others work
    /// on every solver (distributed masters run a probe-minibatch line
    /// search).
    pub step: StepMethod,
}

impl TrainSpec {
    pub fn new(task: TaskSpec) -> Self {
        TrainSpec {
            task,
            algo: "sfw-asyn".into(),
            workers: 4,
            threads: 1,
            tau: 8,
            iterations: 300,
            epochs: None,
            batch: None,
            batch_scale: 0.5,
            batch_cap: 10_000,
            power_iters: 24,
            repr: ReprKind::Auto,
            uplink: GradCodec::F32,
            theta: 1.0,
            seed: 42,
            eval_every: 10,
            engine: EngineKind::Native,
            artifacts_dir: "artifacts".into(),
            pjrt_runtime: None,
            transport: Transport::Local,
            tcp_bind: None,
            tcp_await: false,
            bound_notify: None,
            straggler: None,
            link_latency: None,
            fault_plan: None,
            dfw_rounds_base: 1,
            dfw_rounds_slope: 0.5,
            tol: 0.0,
            step: StepMethod::Vanilla,
        }
    }

    pub fn algo(mut self, name: &str) -> Self {
        self.algo = name.to_string();
        self
    }
    pub fn workers(mut self, w: usize) -> Self {
        self.workers = w;
        self
    }
    /// Compute-kernel thread budget (see the `threads` field).
    pub fn threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }
    pub fn tau(mut self, tau: u64) -> Self {
        self.tau = tau;
        self
    }
    pub fn iterations(mut self, t: u64) -> Self {
        self.iterations = t;
        self
    }
    pub fn epochs(mut self, e: u32) -> Self {
        self.epochs = Some(e);
        self
    }
    pub fn batch(mut self, b: BatchSchedule) -> Self {
        self.batch = Some(b);
        self
    }
    pub fn batch_scale(mut self, s: f64) -> Self {
        self.batch_scale = s;
        self
    }
    pub fn batch_cap(mut self, cap: usize) -> Self {
        self.batch_cap = cap;
        self
    }
    pub fn power_iters(mut self, p: usize) -> Self {
        self.power_iters = p;
        self
    }
    pub fn repr(mut self, r: ReprKind) -> Self {
        self.repr = r;
        self
    }
    pub fn uplink(mut self, c: GradCodec) -> Self {
        self.uplink = c;
        self
    }
    pub fn theta(mut self, theta: f32) -> Self {
        self.theta = theta;
        self
    }
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    pub fn eval_every(mut self, e: u64) -> Self {
        self.eval_every = e;
        self
    }
    pub fn engine(mut self, e: EngineKind) -> Self {
        self.engine = e;
        self
    }
    pub fn artifacts_dir(mut self, dir: &str) -> Self {
        self.artifacts_dir = dir.to_string();
        self
    }
    /// Share an already-loaded PJRT runtime (implies `EngineKind::Pjrt`).
    pub fn pjrt_runtime(mut self, rt: Arc<PjrtRuntime>) -> Self {
        self.pjrt_runtime = Some(rt);
        self.engine = EngineKind::Pjrt;
        self
    }
    pub fn transport(mut self, t: Transport) -> Self {
        self.transport = t;
        self
    }
    /// Bind the TCP master at an explicit `host:port`.
    pub fn tcp_bind(mut self, addr: &str) -> Self {
        self.tcp_bind = Some(addr.to_string());
        self
    }
    /// Await external `sfw worker` processes instead of spawning threads.
    pub fn tcp_await(mut self, await_external: bool) -> Self {
        self.tcp_await = await_external;
        self
    }
    /// Observe the bound TCP master address (multi-process orchestration).
    pub fn bound_notify(
        mut self,
        f: impl Fn(std::net::SocketAddr) + Send + Sync + 'static,
    ) -> Self {
        self.bound_notify = Some(Arc::new(f));
        self
    }
    pub fn straggler(mut self, s: Straggler) -> Self {
        self.straggler = Some(s);
        self
    }
    pub fn maybe_straggler(mut self, s: Option<Straggler>) -> Self {
        self.straggler = s;
        self
    }
    pub fn link_latency(mut self, l: Duration) -> Self {
        self.link_latency = Some(l);
        self
    }
    /// Subject the run to a deterministic fault-injection plan
    /// (see [`crate::chaos`]).
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }
    pub fn maybe_fault_plan(mut self, plan: Option<FaultPlan>) -> Self {
        self.fault_plan = plan;
        self
    }
    pub fn dfw_rounds(mut self, base: u64, slope: f64) -> Self {
        self.dfw_rounds_base = base;
        self.dfw_rounds_slope = slope;
        self
    }
    /// Stop once the dual-gap estimate falls to `tol` (0 disables).
    pub fn tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }
    /// Step-size policy (see [`StepMethod`]).
    pub fn step(mut self, s: StepMethod) -> Self {
        self.step = s;
        self
    }

    /// Generate the task's dataset now and pin it as
    /// [`TaskSpec::Prebuilt`], so clones of this spec (sweep cells,
    /// repeated runs) share one workload via `Arc` instead of
    /// regenerating it inside every timed `run()`.  Call only after the
    /// data-shaping fields (task dims, `seed`, `theta`) are final; a
    /// later `seed` change then varies only algorithm randomness, not
    /// the dataset.  No-op for an already-prebuilt task.
    pub fn prebuilt(mut self) -> Self {
        if !matches!(self.task, TaskSpec::Prebuilt(_)) {
            let (_, workload) = crate::session::ctx::build_task(&self);
            self.task = TaskSpec::Prebuilt(workload);
        }
        self
    }

    /// SVRF-asyn epoch count: explicit, or derived from `iterations`.
    pub fn epochs_or_derived(&self) -> u32 {
        self.epochs
            .unwrap_or_else(|| (self.iterations as f64).log2().ceil().max(1.0) as u32)
    }

    /// The concrete iterate representation this spec runs with:
    /// `ReprKind::Auto` resolves per objective — `pnn` factored,
    /// `matrix_sensing` dense (see [`ReprKind`]) — except on the PJRT
    /// engine, whose artifacts take dense inputs: a factored iterate
    /// there would be densified on every step, so `Auto` stays dense
    /// (explicit `Factored` is honored and pays the densify).
    pub fn resolved_repr(&self) -> Repr {
        match self.repr {
            ReprKind::Dense => Repr::Dense,
            ReprKind::Factored => Repr::Factored,
            ReprKind::Auto => match (self.task.name(), self.engine) {
                // sparse_completion never reaches PJRT (RunCtx rejects
                // the pairing), so it resolves factored before the
                // engine default is consulted.
                ("sparse_completion", _) => Repr::Factored,
                (_, EngineKind::Pjrt) => Repr::Dense,
                ("pnn", _) => Repr::Factored,
                _ => Repr::Dense,
            },
        }
    }

    /// One-line summary used for logs and `Report::spec_echo`.
    pub fn echo(&self) -> String {
        let mut echo = format!(
            "task={} algo={} engine={} transport={} repr={} workers={} tau={} T={} seed={}",
            self.task.name(),
            self.algo,
            match self.engine {
                EngineKind::Native => "native",
                EngineKind::Pjrt => "pjrt",
            },
            match self.transport {
                Transport::Local => "local",
                Transport::Tcp => "tcp",
            },
            self.resolved_repr().label(),
            self.workers,
            self.tau,
            self.iterations,
            self.seed
        );
        if self.uplink != GradCodec::F32 {
            echo.push_str(&format!(" uplink={}", self.uplink.label()));
        }
        if self.step != StepMethod::Vanilla {
            echo.push_str(&format!(" step={}", self.step.label()));
        }
        if self.tol > 0.0 {
            echo.push_str(&format!(" tol={}", self.tol));
        }
        if self.threads != 1 {
            echo.push_str(&format!(" threads={}", self.threads));
        }
        if let Some(plan) = &self.fault_plan {
            echo.push_str(&format!(" chaos={}@{}", plan.name, plan.seed));
        }
        echo
    }

    /// Resolve the spec and run it: registry lookup, transport validation,
    /// objective + engine wiring, then the solver.
    pub fn run(&self) -> Result<Report, SessionError> {
        // Scale knobs the protocols divide/modulo by must be positive —
        // caught here so a bad cell is a SessionError, not a worker panic.
        if self.workers == 0 {
            return Err(SessionError::InvalidSpec("workers must be >= 1".into()));
        }
        if self.threads == 0 {
            return Err(SessionError::InvalidSpec("threads must be >= 1".into()));
        }
        if self.eval_every == 0 {
            return Err(SessionError::InvalidSpec("eval-every must be >= 1".into()));
        }
        if !self.tol.is_finite() || self.tol < 0.0 {
            return Err(SessionError::InvalidSpec(format!(
                "tol must be a finite value >= 0 (got {})",
                self.tol
            )));
        }
        // Away/pairwise steps drop and rescale existing atoms — they need
        // the serial solver's persistent factored active set.  The masters
        // only ever see one atom at a time, and a dense iterate has no
        // atom list to shrink.
        if self.step.needs_active_set() {
            if self.algo != "sfw" {
                return Err(SessionError::InvalidSpec(format!(
                    "step '{}' maintains an active atom set and only runs on --algo sfw (got '{}')",
                    self.step.label(),
                    self.algo
                )));
            }
            if self.resolved_repr() != Repr::Factored {
                return Err(SessionError::InvalidSpec(format!(
                    "step '{}' needs the factored iterate's atom set; add --repr factored",
                    self.step.label()
                )));
            }
        }
        // A step policy silently ignored would misreport the run (same
        // principle as the compressed-uplink gate below): the baselines
        // with fixed update rules reject non-vanilla policies outright.
        if self.step != StepMethod::Vanilla
            && matches!(self.algo.as_str(), "pgd" | "sva" | "dfw-power")
        {
            return Err(SessionError::InvalidSpec(format!(
                "algorithm '{}' has a fixed update rule; --step applies to: \
                 sfw | sfw-asyn | svrf-asyn | sfw-dist",
                self.algo
            )));
        }
        // Latency injection is implemented by the in-process links only;
        // real sockets have real latency.  Reject rather than silently
        // measure a zero-latency TCP run.
        if self.link_latency.is_some() && self.transport == Transport::Tcp {
            return Err(SessionError::InvalidSpec(
                "link-latency injection only applies to the local transport".into(),
            ));
        }
        // The multi-process knobs only mean something on a real wire.
        if (self.tcp_bind.is_some() || self.tcp_await) && self.transport != Transport::Tcp {
            return Err(SessionError::InvalidSpec(
                "tcp-bind/tcp-await require the tcp transport".into(),
            ));
        }
        let reg = registry();
        let solver = reg.get(&self.algo).ok_or_else(|| SessionError::UnknownAlgo {
            name: self.algo.clone(),
            valid: reg.names().join(" | "),
        })?;
        if !solver.supported_transports().contains(&self.transport) {
            return Err(unsupported_transport(&self.algo, self.transport));
        }
        // A compressed uplink silently ignored would fake a byte win;
        // reject it on solvers without a compressible uplink path.
        if self.uplink != GradCodec::F32 && !solver.compressible_uplink() {
            return Err(SessionError::InvalidSpec(format!(
                "algorithm '{}' has no compressible uplink (--uplink {} applies to: {})",
                self.algo,
                self.uplink.label(),
                reg.iter()
                    .filter(|s| s.compressible_uplink())
                    .map(|s| s.name())
                    .collect::<Vec<_>>()
                    .join(" | ")
            )));
        }
        if let Some(plan) = &self.fault_plan {
            // Chaos wraps the in-process worker links; external
            // `sfw worker` processes are out of its reach, and a plan
            // the user thinks is active but isn't would be worse than
            // an error.
            if self.tcp_await {
                return Err(SessionError::InvalidSpec(
                    "chaos fault injection wraps in-process worker links; it cannot reach \
                     external --tcp-await worker processes"
                        .into(),
                ));
            }
            // Exactly the solvers with framed wire protocols run over
            // links — the same capability that makes them TCP-capable.
            if !solver.supported_transports().contains(&Transport::Tcp) {
                return Err(SessionError::InvalidSpec(format!(
                    "algorithm '{}' has no comms links to inject faults into \
                     (chaos applies to: {})",
                    self.algo,
                    registry().supporting(Transport::Tcp).join(" | ")
                )));
            }
            // A permanently-halted worker deadlocks a synchronous
            // barrier (documented liveness caveat of Algorithm 1);
            // only loss-tolerant solvers accept halting plans.
            if plan.has_halt() && !solver.tolerates_worker_loss() {
                return Err(SessionError::InvalidSpec(format!(
                    "fault plan '{}' halts a worker, and '{}' cannot outlive one \
                     (its barrier waits forever); use a Restart crash or one of: {}",
                    plan.name,
                    self.algo,
                    registry()
                        .iter()
                        .filter(|s| s.tolerates_worker_loss())
                        .map(|s| s.name())
                        .collect::<Vec<_>>()
                        .join(" | ")
                )));
            }
        }
        let ctx = RunCtx::new(self)?;
        // Pre-bind the TCP master listener so ordinary bind failures
        // (port in use, privileged port) are a SessionError, not a panic
        // inside the infallible solver.
        if self.transport == Transport::Tcp {
            let bind = self.tcp_bind.as_deref().unwrap_or("127.0.0.1:0");
            let listener = std::net::TcpListener::bind(bind)
                .map_err(|e| SessionError::Comms(format!("cannot bind {bind}: {e}")))?;
            ctx.set_tcp_listener(listener);
        }
        Ok(solver.run(&ctx))
    }

    /// Run this spec's algorithm **worker-side** against a remote master
    /// at `connect`, as worker rank `rank` — the `sfw worker` subcommand.
    /// The spec's data-shaping fields (task, seed, batch/tau) must match
    /// the master's: workers regenerate the dataset and schedules
    /// locally instead of receiving them over the wire.
    pub fn run_worker(&self, connect: &str, rank: u32) -> Result<(), SessionError> {
        let reg = registry();
        let solver = reg.get(&self.algo).ok_or_else(|| SessionError::UnknownAlgo {
            name: self.algo.clone(),
            valid: reg.names().join(" | "),
        })?;
        if !solver.supported_transports().contains(&Transport::Tcp) {
            return Err(unsupported_transport(&self.algo, Transport::Tcp));
        }
        let ctx = RunCtx::new(self)?;
        solver.run_worker(&ctx, connect, rank)
    }

    /// Map a launcher [`TrainConfig`] (config file + CLI overrides) onto a
    /// spec, so every algo x task x engine x transport combination is
    /// reachable from `sfw train` and from config files.
    pub fn from_config(cfg: &TrainConfig) -> Result<TrainSpec, SessionError> {
        let task = match cfg.task.as_str() {
            "matrix_sensing" => TaskSpec::MatrixSensing {
                d1: cfg.ms_d,
                d2: cfg.ms_d,
                rank: cfg.ms_rank,
                n: cfg.ms_n,
                noise_std: cfg.ms_noise,
            },
            "pnn" => TaskSpec::Pnn { d: cfg.pnn_d, n: cfg.pnn_n },
            "sparse_completion" => TaskSpec::SparseCompletion(crate::data::RecParams {
                rows: cfg.rec_rows,
                cols: cfg.rec_cols,
                rank: cfg.rec_rank,
                density: cfg.rec_density,
                alpha: cfg.rec_alpha,
                holdout: cfg.rec_holdout,
                noise: cfg.rec_noise,
            }),
            t => return Err(SessionError::UnknownTask(t.to_string())),
        };
        let engine = match cfg.engine.as_str() {
            "native" => EngineKind::Native,
            "pjrt" => EngineKind::Pjrt,
            e => return Err(SessionError::UnknownEngine(e.to_string())),
        };
        let transport = match cfg.transport.as_str() {
            "local" => Transport::Local,
            "tcp" => Transport::Tcp,
            t => return Err(SessionError::UnknownTransport(t.to_string())),
        };
        let repr = ReprKind::parse(&cfg.repr).ok_or_else(|| {
            SessionError::InvalidSpec(format!(
                "unknown repr '{}' (valid: auto | dense | factored)",
                cfg.repr
            ))
        })?;
        let uplink = GradCodec::parse(&cfg.uplink).ok_or_else(|| {
            SessionError::InvalidSpec(format!(
                "unknown uplink '{}' (valid: {})",
                cfg.uplink,
                GradCodec::VALID
            ))
        })?;
        let step = StepMethod::parse(&cfg.step).ok_or_else(|| {
            SessionError::InvalidSpec(format!(
                "unknown step '{}' (valid: {})",
                cfg.step,
                StepMethod::VALID.join(" | ")
            ))
        })?;
        let mut spec = TrainSpec::new(task)
            .repr(repr)
            .uplink(uplink)
            .step(step)
            .tol(cfg.tol)
            .algo(&cfg.algo)
            .workers(cfg.workers)
            .threads(cfg.threads)
            .tau(cfg.tau)
            .iterations(cfg.iterations)
            .batch_scale(cfg.batch_scale)
            .batch_cap(cfg.batch_cap)
            .power_iters(cfg.power_iters)
            .theta(cfg.theta)
            .seed(cfg.seed)
            .eval_every(cfg.eval_every)
            .engine(engine)
            .artifacts_dir(&cfg.artifacts_dir)
            .transport(transport)
            .tcp_await(cfg.tcp_await);
        if cfg.epochs > 0 {
            spec = spec.epochs(cfg.epochs);
        }
        if cfg.batch > 0 {
            spec = spec.batch(BatchSchedule::Constant(cfg.batch));
        }
        if !cfg.tcp_bind.is_empty() {
            spec = spec.tcp_bind(&cfg.tcp_bind);
        }
        Ok(spec)
    }
}

/// The registry-driven `UnsupportedTransport` error: names the
/// algorithms that *do* support the requested transport (same style as
/// the unknown-algo error).
fn unsupported_transport(algo: &str, transport: Transport) -> SessionError {
    let names = registry().supporting(transport);
    SessionError::UnsupportedTransport {
        algo: algo.to_string(),
        transport,
        supported: if names.is_empty() { "none".into() } else { names.join(" | ") },
    }
}
