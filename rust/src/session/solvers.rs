//! [`Solver`](crate::session::Solver) implementations for the full
//! algorithm family: the paper's SFW-asyn (Algorithm 3) and SVRF-asyn
//! (Algorithm 5), the synchronous SFW-dist baseline (Algorithm 1), the
//! serial SFW reference, and the prior-art baselines the paper compares
//! against (SVA, Zheng et al.'s DFW-power, PGD).
//!
//! Solvers translate the resolved spec into the protocol options and run
//! the coordinator machinery; all shared wiring (objective, engines,
//! transport, report shape) lives in [`RunCtx`] and `session::harness`.
//! The three solvers with framed wire protocols (sfw-asyn, svrf-asyn,
//! sfw-dist) advertise `Transport::Tcp` in `supported_transports()` and
//! implement the worker side of their protocol for external `sfw worker`
//! processes.

use std::sync::Arc;

use crate::algo::pgd::{run_pgd, PgdOptions};
use crate::algo::schedule::BatchSchedule;
use crate::algo::sfw::{run_sfw, SfwOptions};
use crate::coordinator::dfw_power::{run_dfw_power_impl, DfwOptions};
use crate::coordinator::messages::{DistDown, DistUp, MasterMsg, UpdateMsg};
use crate::coordinator::runner::AsynOptions;
use crate::coordinator::sva::{run_sva_impl, SvaOptions};
use crate::coordinator::svrf_asyn::{run_svrf_worker, SvrfAsynOptions};
use crate::coordinator::sync::{run_dist_worker, DistOptions};
use crate::coordinator::worker::WorkerOptions;
use crate::metrics::{Counters, LossTrace};
use crate::session::harness::{self, TransportOpts};
use crate::session::{Report, RunCtx, SessionError, Solver, Transport};

const LOCAL_AND_TCP: &[Transport] = &[Transport::Local, Transport::Tcp];

/// Serial Stochastic Frank-Wolfe (Hazan & Luo 2016).
pub struct SfwSolver;

impl Solver for SfwSolver {
    fn name(&self) -> &'static str {
        "sfw"
    }

    fn run(&self, ctx: &RunCtx) -> Report {
        let spec = &ctx.spec;
        let counters = Arc::new(Counters::new());
        let trace = Arc::new(LossTrace::new());
        let mut engine = ctx.make_engine(0);
        let opts = SfwOptions {
            iterations: spec.iterations,
            batch: ctx.batch_or(|| BatchSchedule::sfw(spec.batch_scale, spec.batch_cap)),
            eval_every: spec.eval_every,
            seed: spec.seed,
            repr: spec.resolved_repr(),
            tol: spec.tol,
            step: spec.step,
        };
        let x = run_sfw(engine.as_mut(), &opts, &counters, &trace);
        ctx.report_it(x, counters, trace)
    }
}

/// SFW-asyn (Algorithm 3): the paper's asynchronous rank-one protocol.
pub struct AsynSolver;

impl AsynSolver {
    fn protocol_opts(ctx: &RunCtx) -> AsynOptions {
        let spec = &ctx.spec;
        AsynOptions {
            iterations: spec.iterations,
            tau: spec.tau,
            batch: ctx
                .batch_or(|| BatchSchedule::sfw_asyn(spec.batch_scale, spec.tau, spec.batch_cap)),
            eval_every: spec.eval_every,
            seed: spec.seed,
            straggler: spec.straggler,
            repr: spec.resolved_repr(),
            uplink: spec.uplink,
            tol: spec.tol,
            step: spec.step,
        }
    }
}

impl Solver for AsynSolver {
    fn name(&self) -> &'static str {
        "sfw-asyn"
    }

    fn supported_transports(&self) -> &'static [Transport] {
        LOCAL_AND_TCP
    }

    fn tolerates_worker_loss(&self) -> bool {
        true // the master never waits for a specific worker
    }

    fn compressible_uplink(&self) -> bool {
        true // rank-one {u, v} atoms, plainly quantized
    }

    fn run(&self, ctx: &RunCtx) -> Report {
        let opts = Self::protocol_opts(ctx);
        let t = TransportOpts::from_ctx(ctx);
        let r = harness::run_asyn(ctx.obj.clone(), &opts, t, |w| ctx.make_engine(w));
        let mut report = ctx.report(r.x, r.counters, r.trace);
        report.final_rank = r.rank;
        report.peak_atoms = r.peak_atoms;
        report.chaos = r.chaos.snapshot();
        report
    }

    fn run_worker(&self, ctx: &RunCtx, connect: &str, rank: u32) -> Result<(), SessionError> {
        let opts = Self::protocol_opts(ctx);
        let wopts = WorkerOptions {
            worker_id: rank,
            batch: opts.batch,
            seed: opts.seed,
            straggler: opts.straggler,
            repr: opts.repr,
            uplink: opts.uplink,
        };
        let counters = Counters::new(); // process-local telemetry only
        let mut engine = ctx.make_engine(rank as usize);
        let mut link = harness::connect_worker::<UpdateMsg, MasterMsg>(connect, rank)?;
        crate::coordinator::worker::run_worker(&mut link, engine.as_mut(), &wopts, &counters);
        Ok(())
    }
}

/// SVRF-asyn (Algorithm 5): variance-reduced asynchronous FW.
pub struct SvrfAsynSolver;

impl SvrfAsynSolver {
    fn protocol_opts(ctx: &RunCtx) -> SvrfAsynOptions {
        let spec = &ctx.spec;
        SvrfAsynOptions {
            epochs: spec.epochs_or_derived(),
            tau: spec.tau,
            batch: ctx.batch_or(|| BatchSchedule::svrf_asyn(spec.tau, spec.batch_cap)),
            eval_every: spec.eval_every,
            seed: spec.seed,
            repr: spec.resolved_repr(),
            uplink: spec.uplink,
            tol: spec.tol,
            step: spec.step,
        }
    }
}

impl Solver for SvrfAsynSolver {
    fn name(&self) -> &'static str {
        "svrf-asyn"
    }

    fn supported_transports(&self) -> &'static [Transport] {
        LOCAL_AND_TCP
    }

    fn tolerates_worker_loss(&self) -> bool {
        true // same asynchronous master loop as sfw-asyn
    }

    fn compressible_uplink(&self) -> bool {
        true // rank-one {u, v} atoms, plainly quantized
    }

    fn run(&self, ctx: &RunCtx) -> Report {
        let opts = Self::protocol_opts(ctx);
        let t = TransportOpts::from_ctx(ctx);
        let r = harness::run_svrf_asyn(ctx.obj.clone(), &opts, t, |w| ctx.make_engine(w));
        let mut report = ctx.report(r.x, r.counters, r.trace);
        report.final_rank = r.rank;
        report.peak_atoms = r.peak_atoms;
        report.chaos = r.chaos.snapshot();
        report
    }

    fn run_worker(&self, ctx: &RunCtx, connect: &str, rank: u32) -> Result<(), SessionError> {
        let opts = Self::protocol_opts(ctx);
        let counters = Counters::new();
        let mut engine = ctx.make_engine(rank as usize);
        let mut link = harness::connect_worker::<UpdateMsg, MasterMsg>(connect, rank)?;
        run_svrf_worker(
            &mut link,
            engine.as_mut(),
            rank,
            &opts.batch,
            opts.seed,
            &counters,
            opts.repr,
            opts.uplink,
        );
        Ok(())
    }
}

/// SFW-dist (Algorithm 1): the synchronous distributed baseline.
pub struct DistSolver;

impl DistSolver {
    fn protocol_opts(ctx: &RunCtx) -> DistOptions {
        let spec = &ctx.spec;
        DistOptions {
            iterations: spec.iterations,
            batch: ctx.batch_or(|| BatchSchedule::sfw(spec.batch_scale, spec.batch_cap)),
            eval_every: spec.eval_every,
            seed: spec.seed,
            straggler: spec.straggler,
            repr: spec.resolved_repr(),
            uplink: spec.uplink,
            tol: spec.tol,
            step: spec.step,
        }
    }
}

impl Solver for DistSolver {
    fn name(&self) -> &'static str {
        "sfw-dist"
    }

    fn supported_transports(&self) -> &'static [Transport] {
        LOCAL_AND_TCP
    }

    fn compressible_uplink(&self) -> bool {
        true // dense partial gradients, with per-worker error feedback
    }

    fn run(&self, ctx: &RunCtx) -> Report {
        let opts = Self::protocol_opts(ctx);
        let t = TransportOpts::from_ctx(ctx);
        let r = harness::run_dist(ctx.obj.clone(), &opts, t, |w| ctx.make_engine(w));
        let mut report = ctx.report(r.x, r.counters, r.trace);
        report.final_rank = r.rank;
        report.peak_atoms = r.peak_atoms;
        report.chaos = r.chaos.snapshot();
        report
    }

    fn run_worker(&self, ctx: &RunCtx, connect: &str, rank: u32) -> Result<(), SessionError> {
        let opts = Self::protocol_opts(ctx);
        let counters = Counters::new();
        let mut engine = ctx.make_engine(rank as usize);
        let mut link = harness::connect_worker::<DistUp, DistDown>(connect, rank)?;
        run_dist_worker(
            &mut link,
            engine.as_mut(),
            rank,
            opts.seed,
            opts.straggler,
            &counters,
            opts.repr,
            opts.uplink,
        );
        Ok(())
    }
}

/// Singular Vector Averaging — the paper's motivating negative baseline.
pub struct SvaSolver;

impl Solver for SvaSolver {
    fn name(&self) -> &'static str {
        "sva"
    }

    fn run(&self, ctx: &RunCtx) -> Report {
        let spec = &ctx.spec;
        let opts = SvaOptions {
            iterations: spec.iterations,
            workers: spec.workers,
            batch: ctx.batch_or(|| BatchSchedule::sfw(spec.batch_scale, spec.batch_cap)),
            eval_every: spec.eval_every,
            seed: spec.seed,
            repr: spec.resolved_repr(),
            tol: spec.tol,
        };
        let r = run_sva_impl(ctx.obj.clone(), &opts, |w| ctx.make_engine(w));
        let mut report = ctx.report(r.x, r.counters, r.trace);
        report.final_rank = r.rank;
        report.peak_atoms = r.peak_atoms;
        report
    }
}

/// Zheng et al. 2018 distributed-power-iteration DFW (prior art).
pub struct DfwPowerSolver;

impl Solver for DfwPowerSolver {
    fn name(&self) -> &'static str {
        "dfw-power"
    }

    fn run(&self, ctx: &RunCtx) -> Report {
        let spec = &ctx.spec;
        let opts = DfwOptions {
            iterations: spec.iterations,
            workers: spec.workers,
            rounds_base: spec.dfw_rounds_base,
            rounds_slope: spec.dfw_rounds_slope,
            eval_every: spec.eval_every,
            seed: spec.seed,
            repr: spec.resolved_repr(),
            tol: spec.tol,
        };
        let r = run_dfw_power_impl(ctx.obj.clone(), &opts);
        let mut report = ctx.report(r.x, r.counters, r.trace);
        report.final_rank = r.rank;
        report.peak_atoms = r.peak_atoms;
        report
    }
}

/// Projected Gradient Descent baseline (full-SVD projection per step).
pub struct PgdSolver;

impl Solver for PgdSolver {
    fn name(&self) -> &'static str {
        "pgd"
    }

    fn run(&self, ctx: &RunCtx) -> Report {
        let spec = &ctx.spec;
        let counters = Arc::new(Counters::new());
        let trace = Arc::new(LossTrace::new());
        let mut engine = ctx.make_engine(0);
        let opts = PgdOptions {
            iterations: spec.iterations,
            batch: ctx.batch_or(|| BatchSchedule::Constant(spec.batch_cap.min(1024))),
            gamma: 0.05,
            eval_every: spec.eval_every,
            seed: spec.seed,
            repr: spec.resolved_repr(),
            tol: spec.tol,
        };
        let x = run_pgd(engine.as_mut(), &opts, &counters, &trace);
        ctx.report_it(x, counters, trace)
    }
}
