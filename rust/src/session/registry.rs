//! The [`Solver`] trait and the central algorithm registry.
//!
//! Every algorithm variant is one registry entry; `sfw train --algo X`,
//! `sfw worker`, the benches, the examples and the test matrix all
//! dispatch through [`registry`].  Adding an algorithm = implement
//! [`Solver`], push it in `build_registry`, done.

use std::sync::OnceLock;

use crate::session::solvers;
use crate::session::{Report, RunCtx, SessionError, Transport};

/// One training algorithm behind the unified session API.
pub trait Solver: Send + Sync {
    /// Registry name (`sfw-asyn`, `sfw-dist`, ...).
    fn name(&self) -> &'static str;

    /// Transports this solver's protocol runs over.  Every solver runs
    /// in-process; solvers whose protocol is framed for the wire
    /// (see [`crate::comms::Wire`]) also list [`Transport::Tcp`].
    fn supported_transports(&self) -> &'static [Transport] {
        &[Transport::Local]
    }

    /// Whether the protocol keeps making progress when a worker dies
    /// permanently mid-run.  True for the asynchronous solvers (the
    /// master never waits for a specific worker); false for the
    /// synchronous barrier, whose round blocks on every rank.  Gates
    /// which chaos [`FaultPlan`](crate::chaos::FaultPlan)s a spec
    /// accepts (`CrashMode::Halt` requires loss tolerance).
    fn tolerates_worker_loss(&self) -> bool {
        false
    }

    /// Whether the solver's worker->master path honors a compressed
    /// uplink codec (`TrainSpec::uplink` / `--uplink`): true for the
    /// link-based protocols that construct quantized wire messages
    /// (sfw-dist gradients with error feedback, the async rank-one
    /// atoms).  Solvers without a wire uplink keep the default; a lossy
    /// codec on them is rejected at spec validation rather than
    /// silently ignored.
    fn compressible_uplink(&self) -> bool {
        false
    }

    /// Run the algorithm against fully-resolved wiring.  Infallible:
    /// everything that can fail happens in `RunCtx::new`.
    fn run(&self, ctx: &RunCtx) -> Report;

    /// Run this solver's *worker side* against a remote master at
    /// `connect` as rank `rank` (the `sfw worker` subcommand).  Only
    /// meaningful for solvers that support [`Transport::Tcp`].
    fn run_worker(&self, ctx: &RunCtx, connect: &str, rank: u32) -> Result<(), SessionError> {
        let _ = (ctx, connect, rank);
        Err(SessionError::InvalidSpec(format!(
            "algorithm '{}' has no remote worker protocol",
            self.name()
        )))
    }
}

pub struct Registry {
    solvers: Vec<Box<dyn Solver>>,
}

impl Registry {
    pub fn get(&self, name: &str) -> Option<&dyn Solver> {
        self.solvers.iter().find(|s| s.name() == name).map(|s| s.as_ref())
    }

    /// All registered algorithm names, registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.solvers.iter().map(|s| s.name()).collect()
    }

    /// Names of the solvers supporting transport `t`, registration order
    /// (drives the `UnsupportedTransport` error and the capability docs).
    pub fn supporting(&self, t: Transport) -> Vec<&'static str> {
        self.iter()
            .filter(|s| s.supported_transports().contains(&t))
            .map(|s| s.name())
            .collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &dyn Solver> {
        self.solvers.iter().map(|s| s.as_ref())
    }
}

/// The process-wide solver registry (built once, immutable).
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Registry {
        solvers: vec![
            Box::new(solvers::SfwSolver),
            Box::new(solvers::AsynSolver),
            Box::new(solvers::SvrfAsynSolver),
            Box::new(solvers::DistSolver),
            Box::new(solvers::SvaSolver),
            Box::new(solvers::DfwPowerSolver),
            Box::new(solvers::PgdSolver),
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_contains_the_paper_family() {
        let names = registry().names();
        for required in ["sfw", "sfw-asyn", "svrf-asyn", "sfw-dist", "sva", "dfw-power"] {
            assert!(names.contains(&required), "missing solver '{required}'");
        }
    }

    #[test]
    fn lookup_and_transport_capabilities() {
        let reg = registry();
        for algo in ["sfw-asyn", "svrf-asyn", "sfw-dist"] {
            assert!(
                reg.get(algo).unwrap().supported_transports().contains(&Transport::Tcp),
                "'{algo}' must support TCP"
            );
        }
        assert!(!reg.get("sva").unwrap().supported_transports().contains(&Transport::Tcp));
        assert!(reg.get("nope").is_none());
        // registry-driven capability listing, registration order
        assert_eq!(reg.supporting(Transport::Tcp), vec!["sfw-asyn", "svrf-asyn", "sfw-dist"]);
        assert_eq!(reg.supporting(Transport::Local).len(), reg.names().len());
        // the compressible-uplink capability is exactly the wire solvers
        let compressible: Vec<&str> =
            reg.iter().filter(|s| s.compressible_uplink()).map(|s| s.name()).collect();
        assert_eq!(compressible, vec!["sfw-asyn", "svrf-asyn", "sfw-dist"]);
    }
}
