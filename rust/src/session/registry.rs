//! The [`Solver`] trait and the central algorithm registry.
//!
//! Every algorithm variant is one registry entry; `sfw train --algo X`,
//! the benches, the examples and the test matrix all dispatch through
//! [`registry`].  Adding an algorithm = implement [`Solver`], push it in
//! `build_registry`, done.

use std::sync::OnceLock;

use crate::session::solvers;
use crate::session::{Report, RunCtx};

/// One training algorithm behind the unified session API.
pub trait Solver: Send + Sync {
    /// Registry name (`sfw-asyn`, `sfw-dist`, ...).
    fn name(&self) -> &'static str;
    /// Whether the solver's protocol runs over real TCP sockets.
    /// Default: local in-process transport only.
    fn supports_tcp(&self) -> bool {
        false
    }
    /// Run the algorithm against fully-resolved wiring.  Infallible:
    /// everything that can fail happens in `RunCtx::new`.
    fn run(&self, ctx: &RunCtx) -> Report;
}

pub struct Registry {
    solvers: Vec<Box<dyn Solver>>,
}

impl Registry {
    pub fn get(&self, name: &str) -> Option<&dyn Solver> {
        self.solvers.iter().find(|s| s.name() == name).map(|s| s.as_ref())
    }

    /// All registered algorithm names, registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.solvers.iter().map(|s| s.name()).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &dyn Solver> {
        self.solvers.iter().map(|s| s.as_ref())
    }
}

/// The process-wide solver registry (built once, immutable).
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Registry {
        solvers: vec![
            Box::new(solvers::SfwSolver),
            Box::new(solvers::AsynSolver),
            Box::new(solvers::SvrfAsynSolver),
            Box::new(solvers::DistSolver),
            Box::new(solvers::SvaSolver),
            Box::new(solvers::DfwPowerSolver),
            Box::new(solvers::PgdSolver),
        ],
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_contains_the_paper_family() {
        let names = registry().names();
        for required in ["sfw", "sfw-asyn", "svrf-asyn", "sfw-dist", "sva", "dfw-power"] {
            assert!(names.contains(&required), "missing solver '{required}'");
        }
    }

    #[test]
    fn lookup_and_tcp_support() {
        assert!(registry().get("sfw-asyn").unwrap().supports_tcp());
        assert!(!registry().get("sva").unwrap().supports_tcp());
        assert!(registry().get("nope").is_none());
    }
}
