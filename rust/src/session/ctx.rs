//! [`RunCtx`]: the resolved wiring a [`Solver`](crate::session::Solver)
//! runs against — objective, engine factory, and the spec echo.
//!
//! Everything fallible (task generation, PJRT runtime construction)
//! happens here, before the solver starts; solvers themselves are
//! infallible.

use std::net::TcpListener;
use std::sync::{Arc, Mutex};

use crate::algo::engine::{NativeEngine, StepEngine};
use crate::algo::schedule::BatchSchedule;
use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
use crate::data::pnn::{PnnData, PnnParams};
use crate::data::recommender::RecommenderData;
use crate::linalg::{Iterate, Mat};
use crate::metrics::{Counters, LossTrace};
use crate::objective::{MatrixSensing, Objective, Pnn, SparseCompletion};
use crate::runtime::{PjrtEngine, PjrtRuntime, Workload};
use crate::session::spec::TrainSpec;
use crate::session::{EngineKind, Report, SessionError, TaskSpec};
use crate::util::rng::Rng;

type EngineFactory = Box<dyn FnMut(usize) -> Box<dyn StepEngine> + Send>;

/// Lock a context mutex, treating poisoning as recoverable: both slots
/// hold plain owned data (a factory closure, an optional listener) whose
/// invariants cannot be left half-updated by a panicking holder.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

pub struct RunCtx {
    pub obj: Arc<dyn Objective>,
    pub spec: TrainSpec,
    engines: Mutex<EngineFactory>,
    /// TCP master listener, pre-bound by `TrainSpec::run` so bind
    /// failures (port in use, privileged port) surface as
    /// `SessionError::Comms` before the solver starts.  Taken once by
    /// the harness.
    tcp_listener: Mutex<Option<TcpListener>>,
}

impl RunCtx {
    /// Build objective + engine factory from a spec.  Dataset generation
    /// is seeded by `spec.seed`; [`TaskSpec::Prebuilt`] reuses the given
    /// workload verbatim (shared data across runs).
    pub fn new(spec: &TrainSpec) -> Result<RunCtx, SessionError> {
        // One kernel pool per process, shared by every worker thread
        // (master-side and `run_worker` processes alike).  Concurrent
        // runs racing on the budget are benign: kernel results are
        // thread-count-invariant by construction, so the budget only
        // moves wall-clock, never numbers.
        crate::linalg::kernels::set_pool_threads(spec.threads);
        let (obj, workload) = build_task(spec);
        let engines = build_engine_factory(spec, obj.clone(), workload)?;
        Ok(RunCtx {
            obj,
            spec: spec.clone(),
            engines: Mutex::new(engines),
            tcp_listener: Mutex::new(None),
        })
    }

    pub(crate) fn set_tcp_listener(&self, listener: TcpListener) {
        *lock_ignore_poison(&self.tcp_listener) = Some(listener);
    }

    pub(crate) fn take_tcp_listener(&self) -> Option<TcpListener> {
        lock_ignore_poison(&self.tcp_listener).take()
    }

    /// Build worker `w`'s compute engine (native math or PJRT artifacts).
    pub fn make_engine(&self, w: usize) -> Box<dyn StepEngine> {
        (lock_ignore_poison(&self.engines))(w)
    }

    /// The spec's explicit batch schedule, or the algorithm's default.
    pub fn batch_or(&self, default: impl FnOnce() -> BatchSchedule) -> BatchSchedule {
        self.spec.batch.clone().unwrap_or_else(default)
    }

    /// Wrap a finished run into the uniform [`Report`].  Solvers that
    /// ran over chaos-wrapped links overwrite `report.chaos` with their
    /// run's snapshot, and solvers whose harness already extracted the
    /// representation stats overwrite `final_rank`/`peak_atoms`.
    pub fn report(&self, x: Mat, counters: Arc<Counters>, trace: Arc<LossTrace>) -> Report {
        let final_rank = crate::linalg::dense_rank(&x);
        Report {
            x,
            final_rank,
            peak_atoms: 0,
            factored: None,
            counters,
            trace,
            chaos: crate::chaos::ChaosSnapshot::default(),
            spec_echo: self.spec.echo(),
            f_star: self.obj.f_star_hint(),
        }
    }

    /// [`RunCtx::report`] from a final [`Iterate`]: extracts the rank
    /// and peak-atom stats — and keeps the atom list itself (the
    /// checkpointable model) — before densifying.
    pub fn report_it(
        &self,
        x: Iterate,
        counters: Arc<Counters>,
        trace: Arc<LossTrace>,
    ) -> Report {
        let (final_rank, peak_atoms) = (x.rank(), x.peak_atoms());
        let factored = match &x {
            Iterate::Factored(f) => Some(f.clone()),
            Iterate::Dense(_) => None,
        };
        let mut report = self.report(x.into_dense(), counters, trace);
        report.final_rank = final_rank;
        report.peak_atoms = peak_atoms;
        report.factored = factored;
        report
    }
}

pub(crate) fn build_task(spec: &TrainSpec) -> (Arc<dyn Objective>, Workload) {
    let mut rng = Rng::new(spec.seed);
    match &spec.task {
        TaskSpec::MatrixSensing { d1, d2, rank, n, noise_std } => {
            let p = MsParams { d1: *d1, d2: *d2, rank: *rank, n: *n, noise_std: *noise_std };
            let obj = Arc::new(MatrixSensing::new(
                MatrixSensingData::generate(&p, &mut rng),
                spec.theta,
            ));
            (obj.clone() as Arc<dyn Objective>, Workload::Ms(obj))
        }
        TaskSpec::Pnn { d, n } => {
            let p = PnnParams { d: *d, n: *n, ..Default::default() };
            let obj = Arc::new(Pnn::new(PnnData::generate(&p, &mut rng), spec.theta));
            (obj.clone() as Arc<dyn Objective>, Workload::Pnn(obj))
        }
        TaskSpec::SparseCompletion(p) => {
            let obj = Arc::new(SparseCompletion::new(
                RecommenderData::generate(p, &mut rng),
                spec.theta,
            ));
            (obj.clone() as Arc<dyn Objective>, Workload::Sparse(obj))
        }
        TaskSpec::Prebuilt(w) => (w.objective(), w.clone()),
    }
}

fn build_engine_factory(
    spec: &TrainSpec,
    obj: Arc<dyn Objective>,
    workload: Workload,
) -> Result<EngineFactory, SessionError> {
    let seed = spec.seed;
    let power_iters = spec.power_iters;
    match spec.engine {
        EngineKind::Native => Ok(Box::new(move |w| {
            Box::new(NativeEngine::new(obj.clone(), power_iters, seed ^ 0xE ^ w as u64))
        })),
        EngineKind::Pjrt => {
            if matches!(workload, Workload::Sparse(_)) {
                return Err(SessionError::Engine(
                    "sparse_completion has no AOT artifacts; use --engine native".into(),
                ));
            }
            let rt = match &spec.pjrt_runtime {
                Some(rt) => rt.clone(),
                None => Arc::new(
                    PjrtRuntime::new(&spec.artifacts_dir)
                        .map_err(|e| SessionError::Engine(format!("PJRT runtime: {e}")))?,
                ),
            };
            Ok(Box::new(move |w| {
                Box::new(PjrtEngine::new(rt.clone(), workload.clone(), seed ^ 0xE ^ w as u64))
            }))
        }
    }
}
