//! Transport harness: wire a protocol master + workers over the chosen
//! transport and run one training job end to end.
//!
//! [`run_over`] is the single wiring point for every `(Up, Down)`
//! protocol: it builds the [`comms`] endpoints (in-process channels or
//! TCP), runs the master on the caller thread and the workers on scoped
//! threads — or, with [`TransportOpts::await_external`], awaits external
//! `sfw worker` processes instead of spawning threads (mirroring one MPI
//! rank per process).  The protocol-specific entry points
//! ([`run_asyn`], [`run_svrf_asyn`], [`run_dist`]) are thin closures
//! over their coordinator loops.
//!
//! TCP runs bind the listener **once** and hand it to the accept loop
//! ([`comms::tcp_master_on`]), so an ephemeral-port address is known
//! before any worker connects — no drop-and-rebind race.
//!
//! [`comms`]: crate::comms

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use crate::algo::engine::StepEngine;
use crate::chaos::{ChaosCounters, ChaosInject};
use crate::comms::{local_links, tcp_master_on, tcp_worker, MasterLink, Wire, WorkerLink};
use crate::coordinator::eval::Evaluator;
use crate::coordinator::master::{run_master, MasterOptions};
use crate::coordinator::messages::{DistDown, DistUp, MasterMsg, UpdateMsg};
use crate::coordinator::runner::{AsynOptions, RunResult};
use crate::coordinator::svrf_asyn::{run_svrf_master, run_svrf_worker, SvrfAsynOptions};
use crate::coordinator::sync::{run_dist_master, run_dist_worker, DistOptions};
use crate::coordinator::worker::{run_worker, WorkerOptions};
use crate::linalg::Iterate;
use crate::metrics::{Counters, LossTrace};
use crate::objective::Objective;
use crate::session::spec::TrainSpec;
use crate::session::{RunCtx, SessionError, Transport};

/// How (and at what scale) to wire master and workers — everything about
/// a run that is *not* protocol state.
pub(crate) struct TransportOpts {
    pub transport: Transport,
    pub workers: usize,
    /// TCP bind address (`None` = loopback ephemeral).
    pub bind: Option<String>,
    /// TCP only: spawn no worker threads; await `workers` external
    /// `sfw worker` processes instead.
    pub await_external: bool,
    /// Injected one-way link latency (local transport only).
    pub link_latency: Option<Duration>,
    /// Observer for the bound TCP address (multi-process orchestration).
    pub bound_notify: Option<crate::session::BoundNotify>,
    /// Pre-bound TCP master listener (from `TrainSpec::run`'s pre-flight
    /// bind); `None` makes the harness bind `bind` itself.
    pub listener: Option<TcpListener>,
    /// Fault injection: when set, every worker link (local channel or
    /// TCP socket alike) is wrapped in its scripted
    /// [`ChaosWorker`](crate::chaos::ChaosWorker) layer.  The protocol
    /// entry points fill in the per-protocol corruption guard before
    /// handing this to [`run_over`].
    pub chaos: Option<ChaosInject>,
}

impl TransportOpts {
    pub(crate) fn from_ctx(ctx: &RunCtx) -> TransportOpts {
        let spec: &TrainSpec = &ctx.spec;
        TransportOpts {
            transport: spec.transport,
            workers: spec.workers,
            bind: spec.tcp_bind.clone(),
            await_external: spec.tcp_await,
            link_latency: spec.link_latency,
            bound_notify: spec.bound_notify.clone(),
            listener: ctx.take_tcp_listener(),
            chaos: spec.fault_plan.clone().map(ChaosInject::new),
        }
    }

    /// In-process transport at `workers` scale (unit tests).
    #[cfg(test)]
    pub(crate) fn local(workers: usize) -> TransportOpts {
        TransportOpts {
            transport: Transport::Local,
            workers,
            bind: None,
            await_external: false,
            link_latency: None,
            bound_notify: None,
            listener: None,
            chaos: None,
        }
    }
}

/// Wrap one worker's endpoint in its fault layer (pass-through when no
/// plan is installed).
fn chaos_wrap<Up: Wire, Down: Wire>(
    chaos: &Option<ChaosInject>,
    rank: usize,
    inner: Box<dyn WorkerLink<Up, Down>>,
) -> Box<dyn WorkerLink<Up, Down>> {
    match chaos {
        Some(inject) => inject.wrap(rank, inner),
        None => inner,
    }
}

/// One worker's job, handed its protocol endpoint by the harness.
pub(crate) type WorkerJob<Up, Down> = Box<dyn FnOnce(Box<dyn WorkerLink<Up, Down>>) + Send>;

/// Run `master` against `t.workers` workers over the selected transport.
/// The master runs on the caller thread; in-process workers run on
/// scoped threads (joined before returning).  Generic in the master's
/// return value (the protocol loops return their final [`Iterate`]).
pub(crate) fn run_over<Up, Down, R, M, F>(
    mut t: TransportOpts,
    counters: &Arc<Counters>,
    master: M,
    mut make_worker: F,
) -> R
where
    Up: Wire,
    Down: Wire,
    M: FnOnce(Box<dyn MasterLink<Up, Down>>) -> R,
    F: FnMut(usize) -> WorkerJob<Up, Down>,
{
    match t.transport {
        Transport::Local => {
            let (ml, wls) = local_links::<Up, Down>(t.workers, counters.clone(), t.link_latency);
            std::thread::scope(|s| {
                for (w, wl) in wls.into_iter().enumerate() {
                    let job = make_worker(w);
                    let link = chaos_wrap(&t.chaos, w, Box::new(wl) as Box<dyn WorkerLink<Up, Down>>);
                    s.spawn(move || job(link));
                }
                master(Box::new(ml))
            })
        }
        Transport::Tcp => {
            // Normally pre-bound by `TrainSpec::run` (bind errors surface
            // there as SessionError); the fallback serves direct harness
            // callers such as unit tests.
            let listener = t.listener.take().unwrap_or_else(|| {
                let bind = t.bind.as_deref().unwrap_or("127.0.0.1:0");
                TcpListener::bind(bind)
                    // lint: allow(panic-free): the harness is infallible by
                    // design — `TrainSpec::run` pre-binds and surfaces bind
                    // failures as SessionError; this fallback serves direct
                    // test callers only.
                    .unwrap_or_else(|e| panic!("comms: cannot bind {bind}: {e}"))
            });
            // lint: allow(panic-free): local_addr on a freshly-bound listener
            // fails only on OS descriptor corruption; no error channel here.
            let addr = listener.local_addr().expect("listener address");
            if let Some(notify) = &t.bound_notify {
                notify(addr);
            }
            std::thread::scope(|s| {
                if t.await_external {
                    println!(
                        "sfw: master listening on {addr}; awaiting {} external worker(s) \
                         (`sfw worker --connect {addr} --rank <r>` with a matching spec)",
                        t.workers
                    );
                } else {
                    for w in 0..t.workers {
                        let job = make_worker(w);
                        let chaos = t.chaos.clone();
                        s.spawn(move || {
                            let wl = tcp_worker::<Up, Down>(&addr.to_string(), w as u32)
                                // lint: allow(panic-free): in-process worker
                                // threads have no error channel; a loopback
                                // connect to our own live listener failing
                                // means the run is unrecoverable anyway.
                                .unwrap_or_else(|e| panic!("worker {w}: connect {addr}: {e}"));
                            job(chaos_wrap(&chaos, w, Box::new(wl)));
                        });
                    }
                }
                let ml = tcp_master_on::<Up, Down>(listener, t.workers, counters.clone())
                    // lint: allow(panic-free): the scoped worker threads are
                    // already spawned; there is no path to unwind them cleanly
                    // besides propagating a panic through the scope.
                    .unwrap_or_else(|e| panic!("comms: master setup failed: {e}"));
                master(Box::new(ml))
            })
        }
    }
}

/// Connect an external worker process to a remote master (used by the
/// solvers' `run_worker` entry points behind `sfw worker`).  Retries
/// briefly so workers may be launched before the master binds.
pub(crate) fn connect_worker<Up: Wire, Down: Wire>(
    addr: &str,
    rank: u32,
) -> Result<crate::comms::TcpWorker<Up, Down>, SessionError> {
    crate::comms::connect_retry(addr, rank, Duration::from_secs(30)).map_err(|e| {
        SessionError::Comms(format!("worker {rank}: cannot reach master at {addr}: {e}"))
    })
}

/// Run SFW-asyn (Algorithm 3) over the requested transport.
/// `make_engine(w)` builds worker w's compute engine.
pub(crate) fn run_asyn<F>(
    obj: Arc<dyn Objective>,
    opts: &AsynOptions,
    mut t: TransportOpts,
    mut make_engine: F,
) -> RunResult
where
    F: FnMut(usize) -> Box<dyn StepEngine>,
{
    let chaos = install_chaos_guard(&mut t, UpdateMsg::CORRUPT_GUARD);
    let counters = Arc::new(Counters::new());
    let trace = Arc::new(LossTrace::new());
    let evaluator = Evaluator::new(obj.clone(), trace.clone());
    let mopts = MasterOptions {
        iterations: opts.iterations,
        tau: opts.tau,
        eval_every: opts.eval_every,
        seed: opts.seed,
        repr: opts.repr,
        tol: opts.tol,
        step: opts.step,
    };
    let x = run_over(
        t,
        &counters,
        |mut ml: Box<dyn MasterLink<UpdateMsg, MasterMsg>>| {
            run_master(&mut *ml, &obj, &mopts, &counters, &trace, &evaluator)
        },
        |w| {
            let mut engine = make_engine(w);
            let counters = counters.clone();
            let wopts = WorkerOptions {
                worker_id: w as u32,
                batch: opts.batch.clone(),
                seed: opts.seed,
                straggler: opts.straggler,
                repr: opts.repr,
                uplink: opts.uplink,
            };
            let job: WorkerJob<UpdateMsg, MasterMsg> = Box::new(move |mut wl| {
                run_worker(&mut *wl, engine.as_mut(), &wopts, &counters)
            });
            job
        },
    );
    evaluator.finish();
    finish_result(x, counters, trace, chaos)
}

/// Fold the master's final [`Iterate`] into the dense-reporting
/// [`RunResult`], extracting the representation stats first.
fn finish_result(
    x: Iterate,
    counters: Arc<Counters>,
    trace: Arc<LossTrace>,
    chaos: Arc<ChaosCounters>,
) -> RunResult {
    let (rank, peak_atoms) = (x.rank(), x.peak_atoms());
    RunResult { x: x.into_dense(), rank, peak_atoms, counters, trace, chaos }
}

/// Run SVRF-asyn (Algorithm 5) over the requested transport.
pub(crate) fn run_svrf_asyn<F>(
    obj: Arc<dyn Objective>,
    opts: &SvrfAsynOptions,
    mut t: TransportOpts,
    mut make_engine: F,
) -> RunResult
where
    F: FnMut(usize) -> Box<dyn StepEngine>,
{
    let chaos = install_chaos_guard(&mut t, UpdateMsg::CORRUPT_GUARD);
    let counters = Arc::new(Counters::new());
    let trace = Arc::new(LossTrace::new());
    let evaluator = Evaluator::new(obj.clone(), trace.clone());
    let x = run_over(
        t,
        &counters,
        |mut ml: Box<dyn MasterLink<UpdateMsg, MasterMsg>>| {
            run_svrf_master(&mut *ml, &obj, opts, &counters, &trace, &evaluator)
        },
        |w| {
            let mut engine = make_engine(w);
            let counters = counters.clone();
            let batch = opts.batch.clone();
            let seed = opts.seed;
            let repr = opts.repr;
            let uplink = opts.uplink;
            let job: WorkerJob<UpdateMsg, MasterMsg> = Box::new(move |mut wl| {
                run_svrf_worker(
                    &mut *wl,
                    engine.as_mut(),
                    w as u32,
                    &batch,
                    seed,
                    &counters,
                    repr,
                    uplink,
                )
            });
            job
        },
    );
    evaluator.finish();
    finish_result(x, counters, trace, chaos)
}

/// Run SFW-dist (Algorithm 1) over the requested transport.
pub(crate) fn run_dist<F>(
    obj: Arc<dyn Objective>,
    opts: &DistOptions,
    mut t: TransportOpts,
    mut make_engine: F,
) -> RunResult
where
    F: FnMut(usize) -> Box<dyn StepEngine>,
{
    let chaos = install_chaos_guard(&mut t, DistUp::CORRUPT_GUARD);
    let counters = Arc::new(Counters::new());
    let trace = Arc::new(LossTrace::new());
    let evaluator = Evaluator::new(obj.clone(), trace.clone());
    // Worker 0's engine type is also instantiated at the master for the
    // LMO (the historical `make_engine(usize::MAX)` convention).
    let mut master_engine = make_engine(usize::MAX);
    let x = run_over(
        t,
        &counters,
        |mut ml: Box<dyn MasterLink<DistUp, DistDown>>| {
            run_dist_master(
                &mut *ml,
                &obj,
                opts,
                master_engine.as_mut(),
                &counters,
                &trace,
                &evaluator,
            )
        },
        |w| {
            let mut engine = make_engine(w);
            let counters = counters.clone();
            let seed = opts.seed;
            let straggler = opts.straggler;
            let repr = opts.repr;
            let uplink = opts.uplink;
            let job: WorkerJob<DistUp, DistDown> = Box::new(move |mut wl| {
                run_dist_worker(
                    &mut *wl,
                    engine.as_mut(),
                    w as u32,
                    seed,
                    straggler,
                    &counters,
                    repr,
                    uplink,
                )
            });
            job
        },
    );
    evaluator.finish();
    finish_result(x, counters, trace, chaos)
}

/// Set the protocol's corruption guard on the injection config (if any)
/// and return the run's chaos counters (zeros when chaos is off).
fn install_chaos_guard(t: &mut TransportOpts, guard: usize) -> Arc<ChaosCounters> {
    match &mut t.chaos {
        Some(inject) => {
            inject.guard = guard;
            inject.counters.clone()
        }
        None => Arc::new(ChaosCounters::new()),
    }
}
