//! Transport harnesses: wire master + workers over the chosen transport
//! and run one training job end to end (threads for workers, caller
//! thread for the master — mirroring one MPI rank per process).
//!
//! This is the wiring that used to be duplicated across the 0.2
//! `coordinator::runner::{run_asyn_local, run_asyn_tcp}` and
//! `coordinator::svrf_asyn::run_svrf_asyn_local` entry points (removed);
//! the transport is a parameter here and solvers are the only callers.

use std::sync::Arc;
use std::time::Duration;

use crate::algo::engine::StepEngine;
use crate::coordinator::eval::Evaluator;
use crate::coordinator::master::{run_master, MasterOptions};
use crate::coordinator::runner::{AsynOptions, RunResult};
use crate::coordinator::svrf_asyn::{run_svrf_master, run_svrf_worker, SvrfAsynOptions};
use crate::coordinator::worker::{run_worker, WorkerOptions};
use crate::metrics::{Counters, LossTrace};
use crate::objective::Objective;
use crate::session::Transport;
use crate::transport::local::local_links;

/// Run SFW-asyn (Algorithm 3) over the requested transport.
/// `make_engine(w)` builds worker w's compute engine.
pub(crate) fn run_asyn<F>(
    obj: Arc<dyn Objective>,
    opts: &AsynOptions,
    transport: Transport,
    make_engine: F,
) -> RunResult
where
    F: FnMut(usize) -> Box<dyn StepEngine>,
{
    match transport {
        Transport::Local => run_asyn_over_local(obj, opts, make_engine),
        Transport::Tcp => run_asyn_over_tcp(obj, opts, make_engine),
    }
}

/// In-process mpsc transport with byte-accurate accounting.
fn run_asyn_over_local<F>(
    obj: Arc<dyn Objective>,
    opts: &AsynOptions,
    mut make_engine: F,
) -> RunResult
where
    F: FnMut(usize) -> Box<dyn StepEngine>,
{
    let counters = Arc::new(Counters::new());
    let trace = Arc::new(LossTrace::new());
    let (mut mlink, wlinks) = local_links(opts.workers, counters.clone(), opts.link_latency);
    let evaluator = Evaluator::new(obj.clone(), trace.clone());

    let mut handles = Vec::new();
    for (w, mut wlink) in wlinks.into_iter().enumerate() {
        let mut engine = make_engine(w);
        let counters = counters.clone();
        let wopts = WorkerOptions {
            worker_id: w as u32,
            batch: opts.batch.clone(),
            seed: opts.seed,
            straggler: opts.straggler,
        };
        handles.push(std::thread::spawn(move || {
            run_worker(&mut wlink, engine.as_mut(), &wopts, &counters);
        }));
    }

    let mopts = MasterOptions {
        iterations: opts.iterations,
        tau: opts.tau,
        eval_every: opts.eval_every,
        seed: opts.seed,
    };
    let x = run_master(&mut mlink, &obj, &mopts, &counters, &trace, &evaluator);
    for h in handles {
        let _ = h.join();
    }
    evaluator.finish();
    RunResult { x, counters, trace }
}

/// Real localhost TCP sockets (same protocol, true serialization + kernel
/// queues).  Master binds an ephemeral port.
fn run_asyn_over_tcp<F>(
    obj: Arc<dyn Objective>,
    opts: &AsynOptions,
    mut make_engine: F,
) -> RunResult
where
    F: FnMut(usize) -> Box<dyn StepEngine>,
{
    use crate::transport::tcp::{tcp_master, tcp_worker};
    let counters = Arc::new(Counters::new());
    let trace = Arc::new(LossTrace::new());
    let evaluator = Evaluator::new(obj.clone(), trace.clone());

    // Bind first on an ephemeral port, then hand the resolved address to
    // the workers.
    let workers = opts.workers;
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let counters_m = counters.clone();
    let master_thread = {
        let obj = obj.clone();
        let trace = trace.clone();
        let mopts = MasterOptions {
            iterations: opts.iterations,
            tau: opts.tau,
            eval_every: opts.eval_every,
            seed: opts.seed,
        };
        std::thread::spawn(move || {
            // accept() inside tcp_master blocks until all workers connect;
            // publish the address before constructing it.
            let listener_addr = "127.0.0.1:0";
            let (mut mlink, addr) = {
                // Bind manually to learn the port before accepting.
                let l = std::net::TcpListener::bind(listener_addr).unwrap();
                let addr = l.local_addr().unwrap();
                drop(l); // tcp_master re-binds; tiny race acceptable on loopback
                addr_tx.send(addr).unwrap();
                let (m, a) = tcp_master(&addr.to_string(), workers, counters_m.clone()).unwrap();
                (m, a)
            };
            let _ = addr;
            let x = run_master(&mut mlink, &obj, &mopts, &counters_m, &trace, &evaluator);
            evaluator.finish();
            x
        })
    };
    let addr = addr_rx.recv().unwrap();
    // workers connect (retry briefly while master rebinds)
    let mut handles = Vec::new();
    for w in 0..opts.workers {
        let mut engine = make_engine(w);
        let counters = counters.clone();
        let wopts = WorkerOptions {
            worker_id: w as u32,
            batch: opts.batch.clone(),
            seed: opts.seed,
            straggler: opts.straggler,
        };
        handles.push(std::thread::spawn(move || {
            let mut link = {
                let mut tries = 0;
                loop {
                    match tcp_worker(&addr.to_string(), w as u32, counters.clone()) {
                        Ok(l) => break l,
                        Err(e) if tries < 50 => {
                            tries += 1;
                            std::thread::sleep(Duration::from_millis(20));
                            let _ = e;
                        }
                        Err(e) => panic!("worker {w} cannot connect: {e}"),
                    }
                }
            };
            run_worker(&mut link, engine.as_mut(), &wopts, &counters);
        }));
    }
    let x = master_thread.join().unwrap();
    for h in handles {
        let _ = h.join();
    }
    RunResult { x, counters, trace }
}

/// Run SVRF-asyn (Algorithm 5) over the in-process transport.
pub(crate) fn run_svrf_asyn<F>(
    obj: Arc<dyn Objective>,
    opts: &SvrfAsynOptions,
    mut make_engine: F,
) -> RunResult
where
    F: FnMut(usize) -> Box<dyn StepEngine>,
{
    let counters = Arc::new(Counters::new());
    let trace = Arc::new(LossTrace::new());
    let (mut mlink, wlinks) = local_links(opts.workers, counters.clone(), None);
    let evaluator = Evaluator::new(obj.clone(), trace.clone());

    let mut handles = Vec::new();
    for (w, mut wlink) in wlinks.into_iter().enumerate() {
        let mut engine = make_engine(w);
        let counters = counters.clone();
        let batch = opts.batch.clone();
        let seed = opts.seed;
        handles.push(std::thread::spawn(move || {
            run_svrf_worker(&mut wlink, engine.as_mut(), w as u32, &batch, seed, &counters);
        }));
    }
    let x = run_svrf_master(&mut mlink, &obj, opts, &counters, &trace, &evaluator);
    for h in handles {
        let _ = h.join();
    }
    evaluator.finish();
    RunResult { x, counters, trace }
}
