//! `sfw::session` — the unified training entrypoint.
//!
//! Every algorithm the repo implements (the paper's SFW-asyn plus the full
//! baseline family it is evaluated against) runs behind one composable
//! API:
//!
//! * [`Solver`] — the trait all algorithm variants implement
//!   (`name()` + `run(&RunCtx) -> Report`), registered in [`registry`];
//! * [`TrainSpec`] — a builder owning all the shared wiring: objective
//!   construction, engine factories (native math or PJRT artifacts),
//!   transport selection (in-process channels vs localhost TCP),
//!   counters/trace/evaluator setup, and schedule defaults;
//! * [`Report`] — the uniform result: final iterate, counters, loss trace
//!   and the relative-loss / time-to-target accessors of `experiments`.
//!
//! ```no_run
//! use sfw::session::{TaskSpec, TrainSpec, Transport};
//!
//! let report = TrainSpec::new(TaskSpec::ms(30, 3, 20_000, 0.1))
//!     .algo("sfw-asyn")
//!     .workers(8)
//!     .tau(8)
//!     .iterations(300)
//!     .transport(Transport::Local)
//!     .run()
//!     .expect("train");
//! println!("final rel loss {:.3e}", report.final_relative());
//! ```
//!
//! Adding a new algorithm, transport or workload is a registry entry plus
//! a `Solver` impl — not a seventh copy of the counters/trace/engine
//! plumbing.  Grids over specs are first-class too: see [`crate::sweep`].
//!
//! Everything fallible happens before a solver starts ([`SessionError`]
//! from spec validation and wiring); solvers themselves are infallible.
//! That split is machine-checked: this module is a `sfw lint` hot module
//! ([`crate::lint`]), so non-test code here must be panic-free and every
//! `SessionError` variant must stay both constructed and matched.
//!
//! # Multi-process training (TCP)
//!
//! Every solver that lists `Transport::Tcp` in its
//! `supported_transports()` (sfw-asyn, svrf-asyn, sfw-dist) also runs
//! with workers in **separate processes**: the master binds with
//! `tcp_bind`/`tcp_await` and each rank joins via `sfw worker`:
//!
//! ```text
//! sfw train  --algo sfw-asyn --transport tcp --workers 2 \
//!            --tcp-bind 127.0.0.1:7070 --tcp-await true --seed 42 --batch 64
//! sfw worker --connect 127.0.0.1:7070 --rank 0 --algo sfw-asyn --seed 42 --batch 64
//! sfw worker --connect 127.0.0.1:7070 --rank 1 --algo sfw-asyn --seed 42 --batch 64
//! ```
//!
//! Workers regenerate the dataset and schedules from the spec (task +
//! seed + batch/tau must match the master); only protocol messages cross
//! the wire — see [`crate::comms`] for the framing and byte accounting.
//!
//! # Factored-iterate quickstart
//!
//! Every solver can hold its iterate as a rank-one atom list
//! ([`crate::linalg::FactoredMat`]) instead of a dense matrix:
//!
//! ```text
//! sfw train --task matrix_sensing --algo sfw-dist --workers 4 --repr factored
//! ```
//!
//! or `TrainSpec::repr(ReprKind::Factored)` from code.  The default is `auto`:
//! `pnn` runs factored (matvec-dominated forward pass — O(k d) per
//! sample instead of O(d^2)), `matrix_sensing` runs dense, and any
//! PJRT-engine run stays dense (the AOT artifacts take dense inputs, so
//! a factored iterate would be densified every step).  Prefer
//! `factored` when (a) the matrix shape is large relative to the
//! iteration count, so O((d1+d2)*k) beats O(d1*d2) on memory and
//! snapshot cost, or (b) the run is `sfw-dist`, whose downlink then
//! broadcasts only atoms-since-last-round
//! ([`DistDown::ComputeFactored`](crate::coordinator::messages::DistDown))
//! instead of the dense X — the `bytes_down` column collapses from
//! O(d1*d2) to O(d1+d2) per round.  Same-seed dense-vs-factored runs
//! agree to f32 tolerance on every solver (`rust/tests/factored.rs`);
//! `Report::{final_rank, peak_atoms}` and the sweep `rank` column
//! surface the representation's size.
//!
//! # Compressed-uplink quickstart (`--uplink int8`)
//!
//! The factored downlink leaves sfw-dist's dense gradient **uplink** as
//! the remaining O(d1*d2) wire cost.  [`GradCodec`] compresses it:
//!
//! ```text
//! sfw train --task matrix_sensing --algo sfw-dist --workers 4 --uplink int8
//! ```
//!
//! or `TrainSpec::uplink(GradCodec::Int8)` from code.  `int8` ships one
//! f32 scale per gradient row plus 1 byte per entry (~4x fewer uplink
//! bytes; ~3.7x as a frame ratio at 64x48), `bf16` halves the bytes
//! with no scales.  Workers carry the quantization residual forward
//! with per-worker error feedback ([`crate::linalg::ErrorFeedback`]),
//! so same-seed `f32` and `int8` runs converge to matching final
//! relative loss — the smoke sweep's `check_smoke_bytes.py` asserts
//! both the byte win and the loss agreement on every CI push.  The
//! async solvers accept the codec too (their rank-one `{u, v}` atoms
//! are quantized plainly); solvers without a wire uplink reject lossy
//! codecs at spec validation.  See [`crate::comms`] for the wire
//! contract.
//!
//! # Gap stopping and step policies (`--tol`, `--step`)
//!
//! Every registry solver tracks the Frank-Wolfe dual gap
//! `g_k = <grad f(X_k), X_k - s_k>` (a certified upper bound on
//! `f(X_k) - f*` for convex objectives — see [`crate::algo`]) and stops
//! early once it falls to `--tol`:
//!
//! ```text
//! sfw train --task matrix_sensing --algo sfw --tol 1e-3
//! sfw train --task matrix_sensing --algo sfw --step line-search
//! sfw train --task matrix_sensing --algo sfw --repr factored --step away
//! ```
//!
//! or `TrainSpec::tol(1e-3)` / `TrainSpec::step(StepMethod::LineSearch)`
//! from code.  The gap rides the trace (`Report::final_gap`, the sweep
//! `gap` column) and, for the async solvers, the worker uplink — the
//! master stops on a boundedly-stale minibatch gap.  `--step` picks the
//! step-size rule from [`crate::algo::schedule`]: `vanilla` (the
//! 2/(k+2) default), `analytic`/`line-search`/`armijo` (minibatch line
//! searches, valid on sfw | sfw-asyn | svrf-asyn | sfw-dist), and
//! `away`/`pairwise` (serial `--algo sfw --repr factored` only — the
//! active-set steps need the atom list).  Solvers with a fixed update
//! rule (pgd, sva, dfw-power) reject non-vanilla policies at spec
//! validation but still honor `--tol`.
//!
//! # Threaded-kernels quickstart (`--threads`)
//!
//! Every hot linear-algebra loop (dense matvecs, factored atom
//! application, the sparse COO gradient, the reductions behind
//! `frob_norm`/`inner`) routes through
//! [`crate::linalg::kernels`] — runtime-dispatched AVX2+FMA SIMD plus a
//! repo-native scoped thread pool.  `--threads N` sizes the pool:
//!
//! ```text
//! sfw train --task matrix_sensing --algo sfw-asyn --workers 4 --threads 8
//! sfw sweep --sweep.threads 1,2,4,8 --sweep.algos sfw-asyn --name threads
//! ```
//!
//! or `TrainSpec::threads(8)` from code (default 1; one pool per
//! process, shared by all worker threads, sized once at `RunCtx`
//! construction).  The kernels determinism contract makes this a pure
//! wall-clock knob: fixed-size chunk partials combined in a fixed
//! order mean `--threads N` is **bit-identical** to `--threads 1` for
//! every N — and to the pre-kernels scalar path — so changing it never
//! perturbs a result, only its speed (pinned by `rust/tests/factored.rs`
//! and the smoke sweep's threads twins).  The echo line appends
//! ` threads=N` when N != 1, and sweeps carry a `threads` axis column.
//!
//! # Train → checkpoint → serve quickstart (sparse completion)
//!
//! The `sparse_completion` task trains on the synthetic recommender
//! ([`crate::data::RecommenderData`]): only observed entries exist, so
//! gradients are O(nnz) and the iterate should stay factored (the
//! `auto` repr resolves it that way; the PJRT engine is rejected — no
//! AOT artifacts take sparse inputs).  A trained atom list checkpoints
//! as a versioned `sfw.model/v1` JSON document and serves top-k
//! queries at O(atoms * d2) per user, independent of nnz
//! ([`crate::model`]):
//!
//! ```text
//! sfw train --task sparse_completion --algo sfw-asyn --workers 4 \
//!           --rec-rows 20000 --rec-cols 2000 --rec-density 0.01 \
//!           --checkpoint model.json
//! sfw serve --model model.json --user 17 --topk 5
//! sfw serve --model model.json --queries users.txt --topk 10
//! ```
//!
//! `--queries` takes one user id per line; both modes end with a
//! request/latency report ([`crate::metrics::ServeStats`]).  From code:
//! train with [`TaskSpec::sparse`], save `report.factored` via
//! [`crate::model::save`], answer with [`crate::model::user_scores`] +
//! [`crate::model::top_k`].  The save→load→serve round trip is
//! bit-identical (pinned by `rust/tests/sparse.rs`).

pub mod ctx;
pub(crate) mod harness;
pub mod registry;
pub mod solvers;
pub mod spec;

pub use ctx::RunCtx;
pub use registry::{registry, Registry, Solver};
pub use spec::TrainSpec;

// Re-exported so spec construction needs only `use sfw::session::*`.
pub use crate::algo::schedule::{BatchSchedule, StepMethod};
pub use crate::chaos::{ChaosSnapshot, FaultPlan};
pub use crate::comms::GradCodec;
pub use crate::coordinator::worker::Straggler;
pub use crate::linalg::Repr;

use std::sync::Arc;

use crate::experiments;
use crate::linalg::Mat;
use crate::metrics::{CounterSnapshot, Counters, LossTrace, TracePoint};
use crate::runtime::Workload;

/// Iterate-representation knob of a [`TrainSpec`]: the concrete
/// [`Repr`] or `Auto`, which resolves per objective — `pnn` runs
/// factored (its forward pass is matvec-dominated, where the atom form
/// is O(k d) instead of O(d^2)), `matrix_sensing` runs dense (its
/// residuals contract against dense sensing rows) — and always dense on
/// the PJRT engine (artifacts take dense inputs).  See the factored
/// quickstart in this module's docs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReprKind {
    Auto,
    Dense,
    Factored,
}

impl ReprKind {
    pub fn label(&self) -> &'static str {
        match self {
            ReprKind::Auto => "auto",
            ReprKind::Dense => "dense",
            ReprKind::Factored => "factored",
        }
    }

    /// Parse a CLI/config value (`auto | dense | factored`).
    pub fn parse(s: &str) -> Option<ReprKind> {
        match s {
            "auto" => Some(ReprKind::Auto),
            "dense" => Some(ReprKind::Dense),
            "factored" => Some(ReprKind::Factored),
            _ => None,
        }
    }
}

/// Callback observing the bound TCP master address of a run (fires after
/// bind, before workers connect) — multi-process orchestration and tests.
pub type BoundNotify = Arc<dyn Fn(std::net::SocketAddr) + Send + Sync>;

/// Wire substrate between master and workers (see [`crate::comms`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    /// In-process mpsc channels with byte-accurate accounting (default).
    Local,
    /// Real TCP sockets: true serialization + kernel queues.  Supported
    /// by every solver with a framed protocol — `sfw-asyn`, `svrf-asyn`
    /// and `sfw-dist` (see `registry().supporting(Transport::Tcp)`) —
    /// and, with [`TrainSpec`]'s `tcp_bind`/`tcp_await` options plus the
    /// `sfw worker` subcommand, across processes and hosts.
    Tcp,
}

/// Which compute engine backs each worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust math (`algo::engine::NativeEngine`).
    Native,
    /// AOT JAX/Pallas artifacts through the PJRT CPU client
    /// (`runtime::PjrtEngine`); needs `TrainSpec::artifacts_dir`.
    Pjrt,
}

/// What objective to train on.  Generated tasks derive their data from
/// `TrainSpec::seed`; [`TaskSpec::Prebuilt`] shares one dataset across
/// many runs (the benches' comparability requirement).
#[derive(Clone)]
pub enum TaskSpec {
    MatrixSensing { d1: usize, d2: usize, rank: usize, n: usize, noise_std: f32 },
    Pnn { d: usize, n: usize },
    /// Sparse matrix completion on the synthetic recommender
    /// ([`crate::data::RecParams`]): O(nnz) gradients, factored-iterate
    /// hot path, native engine only.
    SparseCompletion(crate::data::RecParams),
    /// A pre-built workload (e.g. from `experiments::build_ms`), reused
    /// verbatim — `TrainSpec::theta`/data fields are ignored for it.
    Prebuilt(Workload),
}

impl TaskSpec {
    /// Square matrix-sensing task (paper §5.1 uses d=30, rank=3, noise 0.1).
    pub fn ms(d: usize, rank: usize, n: usize, noise_std: f32) -> Self {
        TaskSpec::MatrixSensing { d1: d, d2: d, rank, n, noise_std }
    }

    /// PNN task at feature dim `d` (paper: 784; artifacts default 196).
    pub fn pnn(d: usize, n: usize) -> Self {
        TaskSpec::Pnn { d, n }
    }

    /// Tiny matrix-sensing problem for smoke tests and CI.
    pub fn ms_small() -> Self {
        TaskSpec::ms(8, 2, 400, 0.05)
    }

    /// Sparse-completion task at `rows x cols` with the generator's
    /// default mask shape (power-law alpha, holdout, noise).
    pub fn sparse(rows: usize, cols: usize, rank: usize, density: f64) -> Self {
        TaskSpec::SparseCompletion(crate::data::RecParams {
            rows,
            cols,
            rank,
            density,
            ..crate::data::RecParams::default()
        })
    }

    /// Small sparse-completion problem for smoke tests and CI: 96x48 at
    /// ~8% observed, where the dense iterate is already >10x the
    /// observed-entry footprint.
    pub fn sparse_small() -> Self {
        TaskSpec::sparse(96, 48, 2, 0.08)
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskSpec::MatrixSensing { .. } => "matrix_sensing",
            TaskSpec::Pnn { .. } => "pnn",
            TaskSpec::SparseCompletion(_) => "sparse_completion",
            TaskSpec::Prebuilt(Workload::Ms(_)) => "matrix_sensing",
            TaskSpec::Prebuilt(Workload::Pnn(_)) => "pnn",
            TaskSpec::Prebuilt(Workload::Sparse(_)) => "sparse_completion",
        }
    }

    /// (D1, D2) of the matrix variable this task trains.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            TaskSpec::MatrixSensing { d1, d2, .. } => (*d1, *d2),
            TaskSpec::Pnn { d, .. } => (*d, *d),
            TaskSpec::SparseCompletion(p) => (p.rows, p.cols),
            TaskSpec::Prebuilt(w) => w.objective().dims(),
        }
    }
}

/// Errors surfaced by spec validation and wiring (never by the hot loop).
#[derive(Debug, thiserror::Error)]
pub enum SessionError {
    #[error("unknown algorithm '{name}' (valid: {valid})")]
    UnknownAlgo { name: String, valid: String },
    #[error("unknown task '{0}' (valid: matrix_sensing | pnn | sparse_completion)")]
    UnknownTask(String),
    #[error("unknown engine '{0}' (valid: native | pjrt)")]
    UnknownEngine(String),
    #[error("unknown transport '{0}' (valid: local | tcp)")]
    UnknownTransport(String),
    #[error("algorithm '{algo}' does not support transport {transport:?} (supported by: {supported})")]
    UnsupportedTransport { algo: String, transport: Transport, supported: String },
    #[error("invalid spec: {0}")]
    InvalidSpec(String),
    #[error("engine setup: {0}")]
    Engine(String),
    #[error("comms: {0}")]
    Comms(String),
}

/// Uniform result of one training run.
pub struct Report {
    /// Final iterate X_T (densified for reporting regardless of the
    /// run's representation).
    pub x: Mat,
    /// Final-iterate rank: the atom count for factored runs, the
    /// numerical rank (small problems) or dimension bound for dense.
    pub final_rank: usize,
    /// Peak atom count held by the run's iterate (0 for dense runs).
    pub peak_atoms: usize,
    /// The final iterate's atom list, kept alongside the densified `x`
    /// for factored runs — what `sfw train --checkpoint` saves as an
    /// `sfw.model/v1` document ([`crate::model`]).  `None` for dense
    /// runs (checkpointing those re-factorizes through an exact SVD).
    pub factored: Option<crate::linalg::FactoredMat>,
    pub counters: Arc<Counters>,
    pub trace: Arc<LossTrace>,
    /// Injected-fault accounting of the run — all zeros unless the spec
    /// carried a [`FaultPlan`] (see [`crate::chaos`]).
    pub chaos: ChaosSnapshot,
    /// One-line echo of the resolved spec (task/algo/engine/transport/...).
    pub spec_echo: String,
    /// F* estimate of the objective (for relative-loss reporting).
    pub f_star: f64,
}

impl std::fmt::Debug for Report {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Report")
            .field("spec_echo", &self.spec_echo)
            .field("trace_points", &self.trace.points().len())
            .field("final_rank", &self.final_rank)
            .field("peak_atoms", &self.peak_atoms)
            .field("counters", &self.counters.snapshot())
            .field("chaos", &self.chaos)
            .finish_non_exhaustive()
    }
}

impl Report {
    pub fn points(&self) -> Vec<TracePoint> {
        self.trace.points()
    }

    pub fn snapshot(&self) -> CounterSnapshot {
        self.counters.snapshot()
    }

    /// Relative-loss curve (t, iteration, (F - F*)/(F_0 - F*)).
    pub fn relative(&self) -> Vec<(f64, u64, f64)> {
        experiments::relative(&self.trace.points(), self.f_star)
    }

    /// First timestamp at which the relative loss reaches `target`
    /// (Figures 5/7's time-to-target).
    pub fn time_to_relative(&self, target: f64) -> Option<f64> {
        experiments::time_to_relative(&self.trace.points(), self.f_star, target)
    }

    /// Relative loss of the last trace point (1.0 if the trace is empty).
    pub fn final_relative(&self) -> f64 {
        self.relative().last().map(|&(_, _, r)| r).unwrap_or(1.0)
    }

    /// Raw loss of the last trace point.
    pub fn final_loss(&self) -> f64 {
        self.trace.points().last().map(|p| p.loss).unwrap_or(f64::NAN)
    }

    /// Last recorded finite dual-gap estimate — the quantity `--tol`
    /// stops on.  `None` when no trace point carries one (gap-less
    /// solver, or the run never reached a gap-bearing snapshot).
    pub fn final_gap(&self) -> Option<f64> {
        self.trace.final_gap()
    }
}
