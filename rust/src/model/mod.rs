//! Model checkpointing and atom-list serving: the train→checkpoint→serve
//! decoupling of the repo's recommender story.
//!
//! A trained factored iterate IS the model — `X = sum_k w_k u_k v_k^T` —
//! so a checkpoint is just the atom list, written as the versioned
//! `sfw.model/v1` JSON document through [`crate::util::json`]
//! (deterministic rendering; f32 values round-trip bit-exactly through
//! the f64 JSON numbers).  Loading never re-compresses: the cap is set
//! to the stored atom count, so save→load→predict is bit-identical.
//!
//! Serving ([`user_scores`] / [`top_k`]) answers per-user top-k queries
//! straight from the atoms at O(atoms * d2) per user — independent of
//! the training set's nnz, and no dense X is ever materialized.  The
//! `sfw serve` subcommand in `main.rs` drives these with a
//! [`crate::metrics::ServeStats`] request/latency report.

use std::path::Path;
use std::sync::Arc;

use crate::linalg::FactoredMat;
use crate::util::json::Json;

/// Version tag every checkpoint carries (and every load verifies).
pub const MODEL_FORMAT: &str = "sfw.model/v1";

#[derive(Debug, thiserror::Error)]
pub enum ModelError {
    #[error("model io: {0}")]
    Io(#[from] std::io::Error),
    #[error("model parse: {0}")]
    Parse(String),
    #[error("model format: {0}")]
    Format(String),
    #[error("query: {0}")]
    Query(String),
}

/// Serialize the atom list as an `sfw.model/v1` JSON value.
pub fn to_json(x: &FactoredMat) -> Json {
    let mut atoms = Vec::with_capacity(x.atoms());
    for k in 0..x.atoms() {
        let (w, u, v) = x.atom(k);
        atoms.push(Json::Obj(vec![
            ("w".into(), Json::Num(w as f64)),
            ("u".into(), Json::Arr(u.iter().map(|&x| Json::Num(x as f64)).collect())),
            ("v".into(), Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())),
        ]));
    }
    Json::Obj(vec![
        ("format".into(), Json::Str(MODEL_FORMAT.into())),
        ("rows".into(), Json::Num(x.rows as f64)),
        ("cols".into(), Json::Num(x.cols as f64)),
        ("atoms".into(), Json::Arr(atoms)),
    ])
}

fn f32_arr(v: &Json, key: &str, want_len: usize, atom: usize) -> Result<Vec<f32>, ModelError> {
    let arr = v
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| ModelError::Format(format!("atom {atom}: missing array '{key}'")))?;
    if arr.len() != want_len {
        return Err(ModelError::Format(format!(
            "atom {atom}: '{key}' has length {} (want {want_len})",
            arr.len()
        )));
    }
    arr.iter()
        .map(|x| {
            x.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| ModelError::Format(format!("atom {atom}: non-number in '{key}'")))
        })
        .collect()
}

/// Rebuild the factored model from an `sfw.model/v1` JSON value.  The
/// atom cap is pinned to the stored count so loading never triggers a
/// re-compression — predictions are bit-identical to the saved model's.
pub fn from_json(v: &Json) -> Result<FactoredMat, ModelError> {
    let format = v.str_field("format").map_err(ModelError::Format)?;
    if format != MODEL_FORMAT {
        return Err(ModelError::Format(format!(
            "unsupported format '{format}' (want '{MODEL_FORMAT}')"
        )));
    }
    let rows = v.get("rows").and_then(Json::as_usize);
    let cols = v.get("cols").and_then(Json::as_usize);
    let (rows, cols) = match (rows, cols) {
        (Some(r), Some(c)) if r > 0 && c > 0 => (r, c),
        _ => return Err(ModelError::Format("missing/invalid 'rows'/'cols'".into())),
    };
    let atoms = v
        .get("atoms")
        .and_then(Json::as_arr)
        .ok_or_else(|| ModelError::Format("missing array 'atoms'".into()))?;
    let mut f = FactoredMat::with_cap(rows, cols, atoms.len());
    for (k, a) in atoms.iter().enumerate() {
        let w = a
            .get("w")
            .and_then(Json::as_f64)
            .ok_or_else(|| ModelError::Format(format!("atom {k}: missing number 'w'")))?
            as f32;
        let u = f32_arr(a, "u", rows, k)?;
        let v = f32_arr(a, "v", cols, k)?;
        f.push_atom(w, Arc::new(u), Arc::new(v));
    }
    Ok(f)
}

/// Write a checkpoint (compact single-line JSON + trailing newline).
pub fn save(x: &FactoredMat, path: &Path) -> Result<(), ModelError> {
    let mut text = to_json(x).render();
    text.push('\n');
    std::fs::write(path, text)?;
    Ok(())
}

/// Read and validate a checkpoint.
pub fn load(path: &Path) -> Result<FactoredMat, ModelError> {
    let text = std::fs::read_to_string(path)?;
    let v = Json::parse(&text).map_err(ModelError::Parse)?;
    from_json(&v)
}

/// Row `user` of the model — the serving scores — computed from the atom
/// list as `s = sum_k (w_k u_k[user]) v_k`: O(atoms * cols), independent
/// of how many observations trained the model.
pub fn user_scores(model: &FactoredMat, user: usize, out: &mut Vec<f32>) -> Result<(), ModelError> {
    if user >= model.rows {
        return Err(ModelError::Query(format!(
            "user {user} out of range (model has {} rows)",
            model.rows
        )));
    }
    out.clear();
    out.resize(model.cols, 0.0);
    for k in 0..model.atoms() {
        let (w, u, v) = model.atom(k);
        let c = w * u[user];
        if c == 0.0 {
            continue;
        }
        for (s, &vj) in out.iter_mut().zip(v.iter()) {
            *s += c * vj;
        }
    }
    Ok(())
}

/// Indices of the `k` largest scores, descending; ties break toward the
/// lower item index so results are deterministic.  Non-finite scores
/// sort below every finite score — a NaN in the score vector must never
/// outrank a real prediction (the old `unwrap_or(Equal)` comparator let
/// a NaN's index order carry it into the top-k).
pub fn top_k(scores: &[f32], k: usize) -> Vec<(usize, f32)> {
    top_k_excluding(scores, k, |_| false)
}

/// [`top_k`] over the scores whose index is NOT excluded — serving's
/// `--exclude-seen` (drop the columns the user already interacted with)
/// without allocating a masked copy of the score vector.
pub fn top_k_excluding(
    scores: &[f32],
    k: usize,
    mut exclude: impl FnMut(usize) -> bool,
) -> Vec<(usize, f32)> {
    let mut order: Vec<usize> = (0..scores.len()).filter(|&i| !exclude(i)).collect();
    order.sort_unstable_by(|&a, &b| {
        let (fa, fb) = (scores[a].is_finite(), scores[b].is_finite());
        fb.cmp(&fa) // finite beats non-finite
            .then_with(|| {
                scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
            })
            .then(a.cmp(&b))
    });
    order.truncate(k);
    order.into_iter().map(|i| (i, scores[i])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_model(seed: u64, d1: usize, d2: usize, k: usize) -> FactoredMat {
        let mut rng = Rng::new(seed);
        let mut f = FactoredMat::zeros(d1, d2);
        for _ in 0..k {
            f.push_atom(
                rng.normal_f32(),
                Arc::new(rng.unit_vector(d1)),
                Arc::new(rng.unit_vector(d2)),
            );
        }
        f
    }

    #[test]
    fn json_round_trip_is_bit_identical() {
        let m = random_model(500, 9, 7, 5);
        let back = from_json(&to_json(&m)).unwrap();
        assert_eq!((back.rows, back.cols, back.atoms()), (9, 7, 5));
        for k in 0..m.atoms() {
            let (w0, u0, v0) = m.atom(k);
            let (w1, u1, v1) = back.atom(k);
            assert_eq!(w0.to_bits(), w1.to_bits());
            for (a, b) in u0.iter().zip(u1.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for (a, b) in v0.iter().zip(v1.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn load_does_not_recompress_wide_models() {
        // More atoms than the default cap would allow: load must keep
        // them all (cap pinned to the stored count).
        let mut rng = Rng::new(501);
        let mut m = FactoredMat::with_cap(4, 3, 64);
        for _ in 0..40 {
            m.push_atom(
                rng.normal_f32(),
                Arc::new(rng.unit_vector(4)),
                Arc::new(rng.unit_vector(3)),
            );
        }
        let back = from_json(&to_json(&m)).unwrap();
        assert_eq!(back.atoms(), 40);
    }

    #[test]
    fn top_k_orders_descending_with_stable_ties() {
        let scores = [0.5f32, 2.0, -1.0, 2.0, 0.0];
        let got = top_k(&scores, 3);
        assert_eq!(got.iter().map(|x| x.0).collect::<Vec<_>>(), vec![1, 3, 0]);
        assert_eq!(top_k(&scores, 0).len(), 0);
        assert_eq!(top_k(&scores, 99).len(), 5);
    }

    #[test]
    fn top_k_sinks_non_finite_scores() {
        // NaN (idx 0) and +inf (idx 2) must rank below every finite
        // score; among themselves they fall back to index order.
        let scores = [f32::NAN, 1.0, f32::INFINITY, -2.0];
        let got = top_k(&scores, 4);
        assert_eq!(got.iter().map(|x| x.0).collect::<Vec<_>>(), vec![1, 3, 0, 2]);
        // and a NaN never squeezes a finite score out of a short top-k
        assert_eq!(top_k(&scores, 2).iter().map(|x| x.0).collect::<Vec<_>>(), vec![1, 3]);
    }

    #[test]
    fn top_k_excluding_skips_indices() {
        let scores = [0.5f32, 2.0, -1.0, 2.0, 0.0];
        let got = top_k_excluding(&scores, 3, |i| i == 1 || i == 4);
        assert_eq!(got.iter().map(|x| x.0).collect::<Vec<_>>(), vec![3, 0, 2]);
    }

    #[test]
    fn user_scores_match_entry() {
        let m = random_model(502, 6, 5, 4);
        let mut s = Vec::new();
        user_scores(&m, 2, &mut s).unwrap();
        for j in 0..5 {
            assert!((s[j] - m.entry(2, j)).abs() < 1e-6);
        }
        assert!(matches!(user_scores(&m, 6, &mut s), Err(ModelError::Query(_))));
    }

    #[test]
    fn malformed_documents_are_rejected() {
        // truncated JSON
        assert!(matches!(
            Json::parse("{\"format\":\"sfw.model/v1\",\"rows\":4")
                .map_err(ModelError::Parse)
                .and_then(|v| from_json(&v)),
            Err(ModelError::Parse(_))
        ));
        // wrong format tag
        let bad = Json::parse(r#"{"format":"sfw.model/v2","rows":2,"cols":2,"atoms":[]}"#).unwrap();
        assert!(matches!(from_json(&bad), Err(ModelError::Format(_))));
        // missing dims
        let bad = Json::parse(r#"{"format":"sfw.model/v1","atoms":[]}"#).unwrap();
        assert!(matches!(from_json(&bad), Err(ModelError::Format(_))));
        // atom factor of the wrong length
        let bad = Json::parse(
            r#"{"format":"sfw.model/v1","rows":2,"cols":2,"atoms":[{"w":1,"u":[1],"v":[0,1]}]}"#,
        )
        .unwrap();
        assert!(matches!(from_json(&bad), Err(ModelError::Format(_))));
        // non-number inside a factor
        let bad = Json::parse(
            r#"{"format":"sfw.model/v1","rows":1,"cols":1,"atoms":[{"w":1,"u":["x"],"v":[1]}]}"#,
        )
        .unwrap();
        assert!(matches!(from_json(&bad), Err(ModelError::Format(_))));
    }
}
