//! [`SweepSpec`]: declarative grids over [`TrainSpec`]s.
//!
//! A sweep declares *axes* — lists of values for `algo`, `workers`,
//! `tau`, `batch`, `power_iters`, `transport`, `straggler`, `seed` — and
//! [`SweepSpec::expand`] takes their cartesian product, instantiating one
//! [`TrainSpec`] per cell from the shared base spec.  Axes left empty
//! inherit the base spec's value (a one-point axis), so a sweep is only
//! ever as big as what it varies.  Identical cells (duplicated axis
//! values) are deduplicated, preserving first-occurrence order.

use std::collections::BTreeSet;
use std::time::Duration;

use crate::algo::schedule::{BatchSchedule, StepMethod};
use crate::chaos::{FaultPlan, DEFAULT_CHAOS_SEED};
use crate::comms::GradCodec;
use crate::coordinator::worker::Straggler;
use crate::session::{ReprKind, TaskSpec, TrainSpec, Transport};
use crate::sweep::SweepError;

/// The fixed axis order: every cell id and result row lists axis values
/// in this order, and `[sweep]` config keys resolve against these names.
pub const AXIS_NAMES: &[&str] = &[
    "algo", "objective", "dims", "repr", "uplink", "workers", "threads", "tau", "batch", "step",
    "tol", "power_iters", "transport", "straggler", "chaos", "seed",
];

/// Map an `objective` axis value onto the named objective's small
/// canonical task (the `dims` axis can then resize it).  Like `dims`,
/// the axis regenerates the dataset per cell.
pub(crate) fn objective_task(name: &str) -> Result<TaskSpec, SweepError> {
    match name {
        "matrix_sensing" => Ok(TaskSpec::ms_small()),
        "pnn" => Ok(TaskSpec::pnn(8, 400)),
        "sparse_completion" => Ok(TaskSpec::sparse_small()),
        other => Err(SweepError::BadAxisValue {
            axis: "objective".into(),
            value: other.to_string(),
            expected: "matrix_sensing | pnn | sparse_completion".into(),
        }),
    }
}

/// Parse a `dims` axis value `"D1xD2"` (e.g. `"48x32"`).
pub(crate) fn parse_dims(s: &str) -> Result<(usize, usize), SweepError> {
    let bad = || SweepError::BadAxisValue {
        axis: "dims".into(),
        value: s.to_string(),
        expected: "'<d1>x<d2>' with both positive (e.g. 48x32)".into(),
    };
    let (a, b) = s.split_once('x').ok_or_else(bad)?;
    let d1: usize = a.trim().parse().map_err(|_| bad())?;
    let d2: usize = b.trim().parse().map_err(|_| bad())?;
    if d1 == 0 || d2 == 0 {
        return Err(bad());
    }
    Ok((d1, d2))
}

/// Label of a task's matrix shape in the `dims` axis encoding.
pub(crate) fn dims_label(task: &TaskSpec) -> String {
    let (d1, d2) = task.dims();
    format!("{d1}x{d2}")
}

/// Worker-heterogeneity profile, the sweep-axis form of
/// [`Straggler`] (named, parseable, comparable).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StragglerProfile {
    /// Homogeneous workers.
    None,
    /// Geometric straggling: per unit of work, sleep
    /// `unit_us * (Geom(p) - 1)` microseconds (see [`Straggler`]).
    Geometric { unit_us: u64, p: f64 },
}

impl StragglerProfile {
    /// Parse `"none"` or `"<unit_us>us:<p>"` (e.g. `"20us:0.25"`).
    pub fn parse(s: &str) -> Result<Self, SweepError> {
        let bad = || SweepError::BadAxisValue {
            axis: "straggler".into(),
            value: s.to_string(),
            expected: "'none' or '<unit_us>us:<p>' with 0 < p <= 1 (e.g. 20us:0.25)".into(),
        };
        if s.eq_ignore_ascii_case("none") {
            return Ok(StragglerProfile::None);
        }
        let (unit, p) = s.split_once(':').ok_or_else(bad)?;
        let unit_us: u64 = unit.strip_suffix("us").ok_or_else(bad)?.parse().map_err(|_| bad())?;
        let p: f64 = p.parse().map_err(|_| bad())?;
        // p = 0 is rejected rather than mapped to None: Rng::geometric
        // requires p > 0, and "geometric with p = 0" has no finite mean.
        if !(p > 0.0 && p <= 1.0) {
            return Err(bad());
        }
        Ok(StragglerProfile::Geometric { unit_us, p })
    }

    pub fn from_straggler(s: Option<Straggler>) -> Self {
        match s {
            None => StragglerProfile::None,
            Some(s) => StragglerProfile::Geometric {
                unit_us: s.unit.as_micros() as u64,
                p: s.p,
            },
        }
    }

    pub fn to_straggler(self) -> Option<Straggler> {
        match self {
            StragglerProfile::None => None,
            StragglerProfile::Geometric { unit_us, p } => {
                Some(Straggler { unit: Duration::from_micros(unit_us), p })
            }
        }
    }

    /// Axis-value label (round-trips through [`StragglerProfile::parse`]).
    pub fn label(&self) -> String {
        match self {
            StragglerProfile::None => "none".into(),
            StragglerProfile::Geometric { unit_us, p } => format!("{unit_us}us:{p}"),
        }
    }
}

/// Canonical `axis=value/...` id over ordered axis pairs — the ONE
/// encoding shared by [`Cell`] and
/// [`CellResult`](crate::sweep::CellResult), so expansion-time ids and
/// result-time ids always correspond.
pub(crate) fn axes_id(axes: &[(String, String)]) -> String {
    axes.iter()
        .map(|(k, v)| format!("{k}={v}"))
        .collect::<Vec<_>>()
        .join("/")
}

/// Value of one axis (by [`AXIS_NAMES`] name) in an ordered pair list.
pub(crate) fn axis_value<'a>(axes: &'a [(String, String)], name: &str) -> Option<&'a str> {
    axes.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

/// The chaos axis's bad-value error — ONE constructor shared by the
/// `[sweep]` resolver and `expand`, so the accepted-name listing cannot
/// drift from [`FaultPlan::PRESETS`] (membership itself is delegated to
/// [`FaultPlan::preset`]).
pub(crate) fn bad_chaos_axis(value: &str) -> SweepError {
    SweepError::BadAxisValue {
        axis: "chaos".into(),
        value: value.to_string(),
        expected: format!("none | {}", FaultPlan::PRESETS.join(" | ")),
    }
}

/// One expanded grid cell: the axis values that identify it plus the
/// fully-resolved [`TrainSpec`] to run.
#[derive(Clone)]
pub struct Cell {
    /// `(axis, value)` pairs in [`AXIS_NAMES`] order.
    pub axes: Vec<(String, String)>,
    pub spec: TrainSpec,
}

impl Cell {
    /// Canonical id, e.g. `algo=sfw-asyn/workers=2/tau=8/.../seed=42`.
    pub fn id(&self) -> String {
        axes_id(&self.axes)
    }

    /// Value of one axis (`AXIS_NAMES` member) in this cell.
    pub fn axis(&self, name: &str) -> Option<&str> {
        axis_value(&self.axes, name)
    }
}

/// Batch-axis value: a constant size, or 0 = the algorithm's theorem
/// schedule (clears any explicit base schedule for that cell).
pub const BATCH_AUTO: usize = 0;

/// Declarative grid over [`TrainSpec`]s.  Construct with
/// [`SweepSpec::new`], set axes with the builder methods, expand with
/// [`SweepSpec::expand`] or hand it to a
/// [`SweepRunner`](crate::sweep::SweepRunner).
#[derive(Clone)]
pub struct SweepSpec {
    pub name: String,
    /// Shared base: every cell starts from a clone of this spec.  Give
    /// it a `TaskSpec::Prebuilt` workload (and/or a shared
    /// `pjrt_runtime`) to reuse one dataset/runtime across all cells —
    /// the benches' comparability requirement — instead of regenerating
    /// per cell inside the timed run.
    pub base: TrainSpec,
    /// Axes; an empty vec = inherit the base spec's value.
    pub algos: Vec<String>,
    /// Objectives (`matrix_sensing | pnn | sparse_completion`) — each
    /// value swaps in that objective's small canonical task, so it
    /// regenerates the dataset per cell and is incompatible with a
    /// [`TaskSpec::Prebuilt`] base (rejected by `expand`), like `dims`.
    pub objectives: Vec<String>,
    /// Matrix shapes `"D1xD2"` — regenerates the dataset per cell, so it
    /// is incompatible with a [`TaskSpec::Prebuilt`] base (rejected by
    /// `expand`).
    pub dims: Vec<String>,
    /// Iterate representations (`auto | dense | factored`); cell labels
    /// carry the RESOLVED value, so `auto` never appears in artifacts.
    pub reprs: Vec<String>,
    /// Uplink codecs (`f32 | bf16 | int8`) for the worker->master path.
    /// Empty = inherit the base spec's codec.
    pub uplinks: Vec<String>,
    pub workers: Vec<usize>,
    /// Kernel-pool thread counts (>= 1; see `linalg::kernels`).  The
    /// determinism contract makes this a pure wall-clock axis: every
    /// value of `threads` produces bit-identical results, which the
    /// smoke sweep asserts.  Empty = inherit the base spec's count.
    pub threads: Vec<usize>,
    pub taus: Vec<u64>,
    /// Constant batch sizes ([`BATCH_AUTO`] = theorem schedule).  Empty =
    /// inherit the base spec's schedule verbatim.
    pub batches: Vec<usize>,
    /// Step-size policies ([`StepMethod::VALID`] names).  Empty = inherit
    /// the base spec's policy; cell labels carry the resolved label.
    pub steps: Vec<String>,
    /// Dual-gap stopping tolerances (0 = run to the iteration budget).
    /// Empty = inherit the base spec's `tol`.
    pub tols: Vec<f64>,
    pub power_iters: Vec<usize>,
    pub transports: Vec<Transport>,
    pub stragglers: Vec<StragglerProfile>,
    /// Chaos fault-plan presets ([`FaultPlan::PRESETS`]) or `"none"`
    /// (no injection).  Empty = inherit the base spec's plan verbatim.
    /// Preset cells derive their plan seed from the base plan (when
    /// set) or [`DEFAULT_CHAOS_SEED`], so a chaos axis stays replayable.
    pub chaos: Vec<String>,
    pub seeds: Vec<u64>,
    /// Timed repetitions per cell (same spec re-run; wall-clock stats).
    pub repeats: usize,
    /// Concurrent cells (each run already owns its worker threads).
    pub jobs: usize,
    /// Relative-loss target for time-to-target extraction (Figs 5/7).
    pub target: Option<f64>,
}

impl SweepSpec {
    pub fn new(name: &str, base: TrainSpec) -> Self {
        SweepSpec {
            name: name.to_string(),
            base,
            algos: Vec::new(),
            objectives: Vec::new(),
            dims: Vec::new(),
            reprs: Vec::new(),
            uplinks: Vec::new(),
            workers: Vec::new(),
            threads: Vec::new(),
            taus: Vec::new(),
            batches: Vec::new(),
            steps: Vec::new(),
            tols: Vec::new(),
            power_iters: Vec::new(),
            transports: Vec::new(),
            stragglers: Vec::new(),
            chaos: Vec::new(),
            seeds: Vec::new(),
            repeats: 1,
            jobs: 1,
            target: None,
        }
    }

    pub fn algos(mut self, names: &[&str]) -> Self {
        self.algos = names.iter().map(|s| s.to_string()).collect();
        self
    }
    pub fn objectives(mut self, names: &[&str]) -> Self {
        self.objectives = names.iter().map(|s| s.to_string()).collect();
        self
    }
    pub fn dims_axis(mut self, dims: &[&str]) -> Self {
        self.dims = dims.iter().map(|s| s.to_string()).collect();
        self
    }
    pub fn reprs(mut self, reprs: &[&str]) -> Self {
        self.reprs = reprs.iter().map(|s| s.to_string()).collect();
        self
    }
    pub fn uplinks(mut self, cs: &[&str]) -> Self {
        self.uplinks = cs.iter().map(|s| s.to_string()).collect();
        self
    }
    pub fn workers(mut self, ws: &[usize]) -> Self {
        self.workers = ws.to_vec();
        self
    }
    pub fn threads(mut self, ts: &[usize]) -> Self {
        self.threads = ts.to_vec();
        self
    }
    pub fn taus(mut self, taus: &[u64]) -> Self {
        self.taus = taus.to_vec();
        self
    }
    pub fn batches(mut self, batches: &[usize]) -> Self {
        self.batches = batches.to_vec();
        self
    }
    pub fn steps(mut self, ss: &[&str]) -> Self {
        self.steps = ss.iter().map(|s| s.to_string()).collect();
        self
    }
    pub fn tols(mut self, ts: &[f64]) -> Self {
        self.tols = ts.to_vec();
        self
    }
    pub fn power_iters(mut self, pi: &[usize]) -> Self {
        self.power_iters = pi.to_vec();
        self
    }
    pub fn transports(mut self, ts: &[Transport]) -> Self {
        self.transports = ts.to_vec();
        self
    }
    pub fn stragglers(mut self, ss: &[StragglerProfile]) -> Self {
        self.stragglers = ss.to_vec();
        self
    }
    pub fn chaos_plans(mut self, plans: &[&str]) -> Self {
        self.chaos = plans.iter().map(|s| s.to_string()).collect();
        self
    }
    pub fn seeds(mut self, seeds: &[u64]) -> Self {
        self.seeds = seeds.to_vec();
        self
    }
    pub fn repeats(mut self, r: usize) -> Self {
        self.repeats = r.max(1);
        self
    }
    pub fn jobs(mut self, j: usize) -> Self {
        self.jobs = j.max(1);
        self
    }
    pub fn target(mut self, t: f64) -> Self {
        self.target = Some(t);
        self
    }

    /// The number of cells `expand` yields before dedup (axis product).
    pub fn product_size(&self) -> usize {
        let len = |n: usize| n.max(1);
        len(self.algos.len())
            * len(self.objectives.len())
            * len(self.dims.len())
            * len(self.reprs.len())
            * len(self.uplinks.len())
            * len(self.workers.len())
            * len(self.threads.len())
            * len(self.taus.len())
            * len(self.batches.len())
            * len(self.steps.len())
            * len(self.tols.len())
            * len(self.power_iters.len())
            * len(self.transports.len())
            * len(self.stragglers.len())
            * len(self.chaos.len())
            * len(self.seeds.len())
    }

    /// Expand the axes into the deduplicated cartesian product of cells.
    pub fn expand(&self) -> Result<Vec<Cell>, SweepError> {
        let base = &self.base;
        let algos: Vec<String> =
            if self.algos.is_empty() { vec![base.algo.clone()] } else { self.algos.clone() };
        // The dims and objective axes regenerate the dataset per cell,
        // which a prebuilt base (one shared workload) cannot do.
        if !self.dims.is_empty() && matches!(base.task, TaskSpec::Prebuilt(_)) {
            return Err(SweepError::BadAxisValue {
                axis: "dims".into(),
                value: self.dims.join(","),
                expected: "a non-prebuilt base task (the dims axis regenerates the dataset)"
                    .into(),
            });
        }
        if !self.objectives.is_empty() && matches!(base.task, TaskSpec::Prebuilt(_)) {
            return Err(SweepError::BadAxisValue {
                axis: "objective".into(),
                value: self.objectives.join(","),
                expected:
                    "a non-prebuilt base task (the objective axis regenerates the dataset)"
                        .into(),
            });
        }
        // Validate objective names up front; `None` = inherit base task.
        let objective_axis: Vec<Option<String>> = if self.objectives.is_empty() {
            vec![None]
        } else {
            self.objectives
                .iter()
                .map(|s| objective_task(s).map(|_| Some(s.clone())))
                .collect::<Result<_, _>>()?
        };
        // `None` = inherit the base task's shape (labelled from it).
        let dims_axis: Vec<Option<(usize, usize)>> = if self.dims.is_empty() {
            vec![None]
        } else {
            self.dims
                .iter()
                .map(|s| parse_dims(s).map(Some))
                .collect::<Result<_, _>>()?
        };
        // `None` = inherit the base spec's repr knob.
        let repr_axis: Vec<Option<ReprKind>> = if self.reprs.is_empty() {
            vec![None]
        } else {
            self.reprs
                .iter()
                .map(|s| {
                    ReprKind::parse(s).map(Some).ok_or_else(|| SweepError::BadAxisValue {
                        axis: "repr".into(),
                        value: s.clone(),
                        expected: "auto | dense | factored".into(),
                    })
                })
                .collect::<Result<_, _>>()?
        };
        // `None` = inherit the base spec's uplink codec.
        let uplink_axis: Vec<Option<GradCodec>> = if self.uplinks.is_empty() {
            vec![None]
        } else {
            self.uplinks
                .iter()
                .map(|s| {
                    GradCodec::parse(s).map(Some).ok_or_else(|| SweepError::BadAxisValue {
                        axis: "uplink".into(),
                        value: s.clone(),
                        expected: GradCodec::VALID.into(),
                    })
                })
                .collect::<Result<_, _>>()?
        };
        let workers =
            if self.workers.is_empty() { vec![base.workers] } else { self.workers.clone() };
        let threads_axis: Vec<usize> = if self.threads.is_empty() {
            vec![base.threads]
        } else {
            for &t in &self.threads {
                if t == 0 {
                    return Err(SweepError::BadAxisValue {
                        axis: "threads".into(),
                        value: "0".into(),
                        expected: "a kernel-pool thread count >= 1".into(),
                    });
                }
            }
            self.threads.clone()
        };
        let taus = if self.taus.is_empty() { vec![base.tau] } else { self.taus.clone() };
        // The batch axis carries Option<usize>: None = inherit the base
        // schedule verbatim, Some(0) = theorem default, Some(m) = Constant(m).
        let batches: Vec<Option<usize>> = if self.batches.is_empty() {
            vec![None]
        } else {
            self.batches.iter().map(|&b| Some(b)).collect()
        };
        // `None` = inherit the base spec's step policy / tolerance.
        let step_axis: Vec<Option<StepMethod>> = if self.steps.is_empty() {
            vec![None]
        } else {
            self.steps
                .iter()
                .map(|s| {
                    StepMethod::parse(s).map(Some).ok_or_else(|| SweepError::BadAxisValue {
                        axis: "step".into(),
                        value: s.clone(),
                        expected: StepMethod::VALID.join(" | "),
                    })
                })
                .collect::<Result<_, _>>()?
        };
        let tol_axis: Vec<Option<f64>> = if self.tols.is_empty() {
            vec![None]
        } else {
            self.tols
                .iter()
                .map(|&t| {
                    if t.is_finite() && t >= 0.0 {
                        Ok(Some(t))
                    } else {
                        Err(SweepError::BadAxisValue {
                            axis: "tol".into(),
                            value: t.to_string(),
                            expected: "a finite tolerance >= 0 (0 disables gap stopping)".into(),
                        })
                    }
                })
                .collect::<Result<_, _>>()?
        };
        let power_iters = if self.power_iters.is_empty() {
            vec![base.power_iters]
        } else {
            self.power_iters.clone()
        };
        let transports = if self.transports.is_empty() {
            vec![base.transport]
        } else {
            self.transports.clone()
        };
        let stragglers = if self.stragglers.is_empty() {
            vec![StragglerProfile::from_straggler(base.straggler)]
        } else {
            self.stragglers.clone()
        };
        // The chaos axis carries plan labels; `None` = inherit the base
        // spec's plan verbatim (labelled by its name, or "none").
        let chaos_seed = base.fault_plan.as_ref().map(|p| p.seed).unwrap_or(DEFAULT_CHAOS_SEED);
        let chaos_axis: Vec<Option<String>> = if self.chaos.is_empty() {
            vec![None]
        } else {
            self.chaos.iter().map(|c| Some(c.clone())).collect()
        };
        let base_chaos_label = base
            .fault_plan
            .as_ref()
            .map(|p| p.name.clone())
            .unwrap_or_else(|| "none".to_string());
        let seeds = if self.seeds.is_empty() { vec![base.seed] } else { self.seeds.clone() };

        let base_batch_label = match &base.batch {
            None => "auto".to_string(),
            Some(BatchSchedule::Constant(m)) => m.to_string(),
            Some(_) => "base".to_string(), // non-constant explicit schedule
        };

        let mut cells = Vec::new();
        let mut seen = BTreeSet::new();
        for algo in &algos {
            for objective in &objective_axis {
            for (&dims, &repr) in dims_axis
                .iter()
                .flat_map(|d| repr_axis.iter().map(move |r| (d, r)))
            {
            for &uplk in &uplink_axis {
            // threads rides the workers loop level (same trick as
            // dims x repr) to keep the nesting flat
            for (&w, &th) in workers
                .iter()
                .flat_map(|w| threads_axis.iter().map(move |t| (w, t)))
            {
                for &tau in &taus {
                    for &batch in &batches {
                        // step/tol ride the power_iters loop level (same
                        // trick as dims x repr) to keep the nesting flat
                        let power_iters_ref = &power_iters;
                        for (stepv, tolv, &pi) in step_axis.iter().flat_map(|s| {
                            tol_axis.iter().flat_map(move |t| {
                                power_iters_ref.iter().map(move |p| (s, t, p))
                            })
                        }) {
                            for &transport in &transports {
                                for &straggler in &stragglers {
                                    for chaos in &chaos_axis {
                                        for &seed in &seeds {
                                            let batch_label = match batch {
                                                None => base_batch_label.clone(),
                                                Some(BATCH_AUTO) => "auto".to_string(),
                                                Some(m) => m.to_string(),
                                            };
                                            let transport_label = match transport {
                                                Transport::Local => "local",
                                                Transport::Tcp => "tcp",
                                            };
                                            // resolve the cell's fault plan
                                            // (axis value, or inherit base)
                                            let (chaos_label, fault_plan) = match chaos {
                                                None => {
                                                    (base_chaos_label.clone(),
                                                     base.fault_plan.clone())
                                                }
                                                Some(name) if name == "none" => {
                                                    ("none".to_string(), None)
                                                }
                                                Some(name) => {
                                                    let plan =
                                                        FaultPlan::preset(name, chaos_seed)
                                                            .map_err(|_| bad_chaos_axis(name))?;
                                                    (name.clone(), Some(plan))
                                                }
                                            };
                                            let mut spec = base
                                                .clone()
                                                .algo(algo)
                                                .workers(w)
                                                .threads(th)
                                                .tau(tau)
                                                .power_iters(pi)
                                                .transport(transport)
                                                .maybe_straggler(straggler.to_straggler())
                                                .maybe_fault_plan(fault_plan)
                                                .seed(seed);
                                            if let Some(name) = objective {
                                                spec.task = objective_task(name)?;
                                            }
                                            if let Some((d1, d2)) = dims {
                                                spec.task = retask(&spec.task, d1, d2)?;
                                            }
                                            if let Some(r) = repr {
                                                spec.repr = r;
                                            }
                                            if let Some(c) = uplk {
                                                spec.uplink = c;
                                            }
                                            if let Some(s) = stepv {
                                                spec.step = *s;
                                            }
                                            if let Some(t) = tolv {
                                                spec.tol = *t;
                                            }
                                            match batch {
                                                None => {} // keep base schedule
                                                Some(BATCH_AUTO) => spec.batch = None,
                                                Some(m) => {
                                                    spec = spec.batch(BatchSchedule::Constant(m))
                                                }
                                            }
                                            let axes = vec![
                                                ("algo".to_string(), algo.clone()),
                                                (
                                                    "objective".to_string(),
                                                    // resolved from the cell's
                                                    // task, so inherited cells
                                                    // are labelled too
                                                    spec.task.name().to_string(),
                                                ),
                                                ("dims".to_string(), dims_label(&spec.task)),
                                                (
                                                    "repr".to_string(),
                                                    // resolved, never "auto"
                                                    spec.resolved_repr().label().to_string(),
                                                ),
                                                (
                                                    "uplink".to_string(),
                                                    spec.uplink.label().to_string(),
                                                ),
                                                ("workers".to_string(), w.to_string()),
                                                ("threads".to_string(), th.to_string()),
                                                ("tau".to_string(), tau.to_string()),
                                                ("batch".to_string(), batch_label),
                                                (
                                                    "step".to_string(),
                                                    // resolved from the cell's
                                                    // spec, like repr
                                                    spec.step.label().to_string(),
                                                ),
                                                ("tol".to_string(), format!("{}", spec.tol)),
                                                ("power_iters".to_string(), pi.to_string()),
                                                (
                                                    "transport".to_string(),
                                                    transport_label.to_string(),
                                                ),
                                                ("straggler".to_string(), straggler.label()),
                                                ("chaos".to_string(), chaos_label),
                                                ("seed".to_string(), seed.to_string()),
                                            ];
                                            let cell = Cell { axes, spec };
                                            if seen.insert(cell.id()) {
                                                cells.push(cell);
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
            }
            }
            }
        }
        Ok(cells)
    }
}

/// Apply a `dims` axis value to a generated task (prebuilt bases were
/// rejected before expansion).
fn retask(task: &TaskSpec, d1: usize, d2: usize) -> Result<TaskSpec, SweepError> {
    match task {
        TaskSpec::MatrixSensing { rank, n, noise_std, .. } => Ok(TaskSpec::MatrixSensing {
            d1,
            d2,
            rank: *rank,
            n: *n,
            noise_std: *noise_std,
        }),
        TaskSpec::Pnn { n, .. } => {
            if d1 != d2 {
                return Err(SweepError::BadAxisValue {
                    axis: "dims".into(),
                    value: format!("{d1}x{d2}"),
                    expected: "a square shape for the pnn task (DxD)".into(),
                });
            }
            Ok(TaskSpec::Pnn { d: d1, n: *n })
        }
        TaskSpec::SparseCompletion(p) => {
            Ok(TaskSpec::SparseCompletion(crate::data::RecParams {
                rows: d1,
                cols: d2,
                ..p.clone()
            }))
        }
        TaskSpec::Prebuilt(_) => unreachable!("prebuilt bases rejected before expansion"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::TaskSpec;

    fn base() -> TrainSpec {
        TrainSpec::new(TaskSpec::ms_small()).iterations(10).seed(1)
    }

    #[test]
    fn empty_axes_yield_one_base_cell() {
        let cells = SweepSpec::new("t", base()).expand().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].axis("algo"), Some("sfw-asyn"));
        assert_eq!(cells[0].axis("seed"), Some("1"));
        assert_eq!(cells[0].axes.len(), AXIS_NAMES.len());
    }

    #[test]
    fn product_counts_multiply() {
        let s = SweepSpec::new("t", base())
            .algos(&["sfw-dist", "sfw-asyn"])
            .workers(&[1, 2, 4])
            .seeds(&[1, 2]);
        assert_eq!(s.product_size(), 12);
        assert_eq!(s.expand().unwrap().len(), 12);
    }

    #[test]
    fn duplicate_axis_values_dedup() {
        let s = SweepSpec::new("t", base()).workers(&[1, 2, 1, 2, 1]).taus(&[4, 4]);
        assert_eq!(s.product_size(), 10);
        let cells = s.expand().unwrap();
        assert_eq!(cells.len(), 2);
        // first-occurrence order preserved
        assert_eq!(cells[0].axis("workers"), Some("1"));
        assert_eq!(cells[1].axis("workers"), Some("2"));
    }

    #[test]
    fn batch_axis_zero_clears_explicit_schedule() {
        let b = base().batch(BatchSchedule::Constant(64));
        let cells = SweepSpec::new("t", b).batches(&[BATCH_AUTO, 32]).expand().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].axis("batch"), Some("auto"));
        assert!(cells[0].spec.batch.is_none());
        assert_eq!(cells[1].spec.batch, Some(BatchSchedule::Constant(32)));
    }

    #[test]
    fn step_and_tol_axes_expand_and_label() {
        let cells = SweepSpec::new("t", base().algo("sfw"))
            .steps(&["vanilla", "line-search"])
            .tols(&[0.0, 1e-3])
            .expand()
            .unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].axis("step"), Some("vanilla"));
        assert_eq!(cells[0].axis("tol"), Some("0"));
        assert_eq!(cells[1].axis("tol"), Some("0.001"));
        assert_eq!(cells[2].axis("step"), Some("line-search"));
        assert_eq!(cells[2].spec.step, StepMethod::LineSearch);
        assert_eq!(cells[1].spec.tol, 1e-3);
        // an unset axis inherits the base spec and still labels the cell
        let cells = SweepSpec::new("t", base()).expand().unwrap();
        assert_eq!(cells[0].axis("step"), Some("vanilla"));
        assert_eq!(cells[0].axis("tol"), Some("0"));
        // bad values name the axis and list the menu / constraint
        let err = SweepSpec::new("t", base()).steps(&["exact"]).expand().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("step") && msg.contains("pairwise"), "{msg}");
        let err = SweepSpec::new("t", base()).tols(&[f64::NAN]).expand().unwrap_err();
        assert!(err.to_string().contains("tol"), "{err}");
    }

    #[test]
    fn dims_and_repr_axes_expand() {
        let cells = SweepSpec::new("t", base())
            .dims_axis(&["8x8", "12x6"])
            .reprs(&["dense", "factored"])
            .expand()
            .unwrap();
        assert_eq!(cells.len(), 4);
        // dims outer, repr inner, expansion order stable
        assert_eq!(cells[0].axis("dims"), Some("8x8"));
        assert_eq!(cells[0].axis("repr"), Some("dense"));
        assert_eq!(cells[1].axis("repr"), Some("factored"));
        assert_eq!(cells[2].axis("dims"), Some("12x6"));
        // dims rewrites the generated task shape
        assert_eq!(cells[2].spec.task.dims(), (12, 6));
        assert_eq!(cells[0].spec.task.dims(), (8, 8));
        // repr axis sets the spec knob
        assert!(matches!(cells[1].spec.repr, ReprKind::Factored));
    }

    #[test]
    fn objective_axis_retasks_and_labels_cells() {
        let cells = SweepSpec::new("t", base())
            .objectives(&["matrix_sensing", "sparse_completion"])
            .expand()
            .unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].axis("objective"), Some("matrix_sensing"));
        assert_eq!(cells[1].axis("objective"), Some("sparse_completion"));
        assert!(matches!(cells[1].spec.task, TaskSpec::SparseCompletion(_)));
        // sparse cells resolve factored under auto
        assert_eq!(cells[1].axis("repr"), Some("factored"));
        // an unset axis labels the cell from the base task
        let cells = SweepSpec::new("t", base()).expand().unwrap();
        assert_eq!(cells[0].axis("objective"), Some("matrix_sensing"));
        // bad names error up front; prebuilt bases are rejected
        let err =
            SweepSpec::new("t", base()).objectives(&["ridge"]).expand().unwrap_err();
        assert!(err.to_string().contains("sparse_completion"), "{err}");
        let err = SweepSpec::new("t", base().prebuilt())
            .objectives(&["pnn"])
            .expand()
            .unwrap_err();
        assert!(err.to_string().contains("objective"), "{err}");
        // the dims axis resizes a sparse task
        let cells = SweepSpec::new("t", base())
            .objectives(&["sparse_completion"])
            .dims_axis(&["64x24"])
            .expand()
            .unwrap();
        assert_eq!(cells[0].spec.task.dims(), (64, 24));
    }

    #[test]
    fn threads_axis_expands_and_rejects_zero() {
        let cells = SweepSpec::new("t", base()).threads(&[1, 4]).expand().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].axis("threads"), Some("1"));
        assert_eq!(cells[1].axis("threads"), Some("4"));
        assert_eq!(cells[1].spec.threads, 4);
        // unset axis inherits the base count and still labels the cell
        let cells = SweepSpec::new("t", base()).expand().unwrap();
        assert_eq!(cells[0].axis("threads"), Some("1"));
        // 0 would panic inside the run; reject it at expansion time
        let err = SweepSpec::new("t", base()).threads(&[0]).expand().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("threads") && msg.contains(">= 1"), "{msg}");
    }

    #[test]
    fn uplink_axis_expands_and_rejects_bad_values() {
        let cells = SweepSpec::new("t", base()).uplinks(&["f32", "int8"]).expand().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].axis("uplink"), Some("f32"));
        assert_eq!(cells[1].axis("uplink"), Some("int8"));
        assert!(matches!(cells[1].spec.uplink, GradCodec::Int8));
        // unset axis inherits the base codec and still labels the cell
        let cells = SweepSpec::new("t", base()).expand().unwrap();
        assert_eq!(cells[0].axis("uplink"), Some("f32"));
        // a bad codec names the axis and lists the valid values
        let err = SweepSpec::new("t", base()).uplinks(&["int4"]).expand().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("uplink") && msg.contains("int8"), "{msg}");
    }

    #[test]
    fn repr_auto_resolves_in_labels_and_dedups() {
        // matrix-sensing base: auto resolves to dense, so auto + dense
        // collapse to one cell and "auto" never reaches an artifact.
        let cells =
            SweepSpec::new("t", base()).reprs(&["auto", "dense"]).expand().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].axis("repr"), Some("dense"));
    }

    #[test]
    fn dims_axis_rejects_prebuilt_base_and_bad_values() {
        let err = SweepSpec::new("t", base().prebuilt())
            .dims_axis(&["8x8"])
            .expand()
            .unwrap_err();
        assert!(err.to_string().contains("dims"), "{err}");
        for bad in ["8", "0x4", "4x0", "x", "axb"] {
            assert!(parse_dims(bad).is_err(), "parse_dims accepted '{bad}'");
        }
        assert_eq!(parse_dims("48x32").unwrap(), (48, 32));
        // pnn requires a square shape
        let pnn_base = TrainSpec::new(TaskSpec::pnn(8, 100)).iterations(2);
        let err = SweepSpec::new("t", pnn_base)
            .dims_axis(&["8x6"])
            .expand()
            .unwrap_err();
        assert!(err.to_string().contains("square"), "{err}");
    }

    #[test]
    fn straggler_profile_round_trips() {
        for s in ["none", "20us:0.25", "100us:0.5"] {
            assert_eq!(StragglerProfile::parse(s).unwrap().label(), s);
        }
        assert!(StragglerProfile::parse("20ms:0.25").is_err());
        assert!(StragglerProfile::parse("20us:1.5").is_err());
        assert!(StragglerProfile::parse("20us:0").is_err(), "geometric p=0 must be rejected");
        let p = StragglerProfile::parse("20us:0.25").unwrap();
        let back = StragglerProfile::from_straggler(p.to_straggler());
        assert_eq!(p, back);
    }

    #[test]
    fn chaos_axis_resolves_presets_and_none() {
        let cells = SweepSpec::new("t", base())
            .chaos_plans(&["none", "flaky-net"])
            .expand()
            .unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].axis("chaos"), Some("none"));
        assert!(cells[0].spec.fault_plan.is_none());
        assert_eq!(cells[1].axis("chaos"), Some("flaky-net"));
        assert_eq!(cells[1].spec.fault_plan.as_ref().unwrap().name, "flaky-net");
        // unset axis inherits the base plan and labels it by name
        let with_base = base().fault_plan(FaultPlan::slow_tail(3));
        let cells = SweepSpec::new("t", with_base).workers(&[2]).expand().unwrap();
        assert_eq!(cells[0].axis("chaos"), Some("slow-tail"));
        assert_eq!(cells[0].spec.fault_plan.as_ref().unwrap().seed, 3);
        // a bad preset names the axis and lists the valid values
        let err = SweepSpec::new("t", base()).chaos_plans(&["flakey"]).expand().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("chaos") && msg.contains("flaky-net"), "{msg}");
    }

    #[test]
    fn cell_ids_are_canonical() {
        let cells = SweepSpec::new("t", base()).workers(&[3]).expand().unwrap();
        let id = cells[0].id();
        assert!(id.contains("workers=3"), "{id}");
        assert!(id.starts_with("algo="), "{id}");
    }
}
