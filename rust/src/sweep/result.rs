//! [`SweepResult`]: the collected outcome of one sweep — one
//! [`CellResult`] per grid cell with wall-clock [`Stats`], convergence
//! metrics, counters and (for figure regeneration) the relative-loss
//! curve — plus the aligned-table, CSV and JSON emitters.
//!
//! The JSON schema (`bench_out/sweep_<name>.json`) is stable and
//! round-trips through [`SweepResult::from_json`], so the repo's
//! `BENCH_*.json` trajectory tracking and CI artifacts can consume it.

use crate::benchkit::{sig, Stats, Table};
use crate::chaos::ChaosSnapshot;
use crate::metrics::CounterSnapshot;
use crate::sweep::SweepError;
use crate::util::json::Json;

/// Result of one grid cell (over `repeats` runs of the same spec).
#[derive(Debug, Clone)]
pub struct CellResult {
    /// `(axis, value)` pairs in [`crate::sweep::AXIS_NAMES`] order.
    pub axes: Vec<(String, String)>,
    /// `TrainSpec::echo()` of the resolved spec.
    pub spec_echo: String,
    /// Wall-clock seconds per repeat of `TrainSpec::run()` — includes
    /// the run's wiring (dataset generation for generated tasks, PJRT
    /// artifact loading), not just the solve.  Sweeps that must exclude
    /// that setup share it across cells via the base spec: a
    /// `TaskSpec::Prebuilt` workload and/or `TrainSpec::pjrt_runtime`
    /// are cloned (`Arc`) into every cell.
    pub wall: Stats,
    /// Relative loss of the last trace point (last repeat).
    pub final_rel: f64,
    /// Raw loss of the last trace point (last repeat).
    pub final_loss: f64,
    /// Last finite dual-gap estimate of the last repeat
    /// (`Report::final_gap`); NaN when the run recorded none.
    pub gap: f64,
    /// Per-curve-point dual-gap estimates of the last repeat, aligned
    /// with `curve` (NaN entries where a snapshot carried no gap —
    /// e.g. the t=0 init point).
    pub gaps: Vec<f64>,
    /// First time the relative loss reached the sweep's target, if set.
    pub time_to_target: Option<f64>,
    /// Final-iterate rank of the last repeat (`Report::final_rank`).
    pub rank: u64,
    /// Peak atom count of the last repeat (`Report::peak_atoms`; 0 for
    /// dense-representation cells).
    pub peak_atoms: u64,
    /// Counter snapshot of the last repeat.
    pub counters: CounterSnapshot,
    /// Injected-fault accounting of the last repeat (zeros when the
    /// cell ran without a chaos plan).
    pub chaos: ChaosSnapshot,
    /// Relative-loss curve `(t, iteration, rel)` of the last repeat.
    pub curve: Vec<(f64, u64, f64)>,
}

impl CellResult {
    /// Value of one axis in this cell.
    pub fn axis(&self, name: &str) -> Option<&str> {
        crate::sweep::grid::axis_value(&self.axes, name)
    }

    /// Canonical cell id (`axis=value/...`), matching `Cell::id`.
    pub fn id(&self) -> String {
        crate::sweep::grid::axes_id(&self.axes)
    }

    fn to_json(&self) -> Json {
        let axes = Json::Obj(
            self.axes.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect(),
        );
        let c = &self.counters;
        let counters = Json::Obj(vec![
            ("grad_evals".into(), Json::Num(c.grad_evals as f64)),
            ("lmo_calls".into(), Json::Num(c.lmo_calls as f64)),
            ("iterations".into(), Json::Num(c.iterations as f64)),
            ("dropped_updates".into(), Json::Num(c.dropped_updates as f64)),
            ("max_accepted_delay".into(), Json::Num(c.max_accepted_delay as f64)),
            ("bytes_up".into(), Json::Num(c.bytes_up as f64)),
            ("bytes_down".into(), Json::Num(c.bytes_down as f64)),
            ("msgs_up".into(), Json::Num(c.msgs_up as f64)),
            ("msgs_down".into(), Json::Num(c.msgs_down as f64)),
        ]);
        let h = &self.chaos;
        let chaos = Json::Obj(vec![
            ("delays".into(), Json::Num(h.delays as f64)),
            ("delay_ns".into(), Json::Num(h.delay_ns as f64)),
            ("drops".into(), Json::Num(h.drops as f64)),
            ("duplicates".into(), Json::Num(h.duplicates as f64)),
            ("corrupt_delivered".into(), Json::Num(h.corrupt_delivered as f64)),
            ("corrupt_rejected".into(), Json::Num(h.corrupt_rejected as f64)),
            ("reorders".into(), Json::Num(h.reorders as f64)),
            ("crashes".into(), Json::Num(h.crashes as f64)),
            ("late_joins".into(), Json::Num(h.late_joins as f64)),
        ]);
        let w = &self.wall;
        let wall = Json::Obj(vec![
            ("n".into(), Json::Num(w.n as f64)),
            ("mean_s".into(), Json::Num(w.mean_s)),
            ("std_s".into(), Json::Num(w.std_s)),
            ("min_s".into(), Json::Num(w.min_s)),
            ("p50_s".into(), Json::Num(w.p50_s)),
            ("p90_s".into(), Json::Num(w.p90_s)),
            ("max_s".into(), Json::Num(w.max_s)),
        ]);
        let curve = Json::Arr(
            self.curve
                .iter()
                .map(|&(t, i, r)| {
                    Json::Arr(vec![Json::Num(t), Json::Num(i as f64), Json::Num(r)])
                })
                .collect(),
        );
        Json::Obj(vec![
            ("axes".into(), axes),
            ("spec_echo".into(), Json::Str(self.spec_echo.clone())),
            ("wall".into(), wall),
            ("final_rel".into(), Json::Num(self.final_rel)),
            ("final_loss".into(), Json::Num(self.final_loss)),
            ("gap".into(), Json::Num(self.gap)),
            (
                "gaps".into(),
                Json::Arr(self.gaps.iter().map(|&g| Json::Num(g)).collect()),
            ),
            (
                "time_to_target".into(),
                self.time_to_target.map(Json::Num).unwrap_or(Json::Null),
            ),
            ("rank".into(), Json::Num(self.rank as f64)),
            ("peak_atoms".into(), Json::Num(self.peak_atoms as f64)),
            ("counters".into(), counters),
            ("chaos".into(), chaos),
            ("curve".into(), curve),
        ])
    }

    fn from_json(v: &Json) -> Result<CellResult, String> {
        let axes = match v.get("axes") {
            Some(Json::Obj(fields)) => fields
                .iter()
                .map(|(k, val)| {
                    val.as_str()
                        .map(|s| (k.clone(), s.to_string()))
                        .ok_or_else(|| format!("axis '{k}' is not a string"))
                })
                .collect::<Result<Vec<_>, _>>()?,
            _ => return Err("missing object 'axes'".into()),
        };
        let w = v.get("wall").ok_or("missing object 'wall'")?;
        let wall = Stats {
            n: w.u64_field("n")? as usize,
            mean_s: w.f64_field("mean_s")?,
            std_s: w.f64_field("std_s")?,
            min_s: w.f64_field("min_s")?,
            p50_s: w.f64_field("p50_s")?,
            p90_s: w.f64_field("p90_s")?,
            max_s: w.f64_field("max_s")?,
        };
        let c = v.get("counters").ok_or("missing object 'counters'")?;
        let counters = CounterSnapshot {
            grad_evals: c.u64_field("grad_evals")?,
            lmo_calls: c.u64_field("lmo_calls")?,
            iterations: c.u64_field("iterations")?,
            dropped_updates: c.u64_field("dropped_updates")?,
            // absent in pre-chaos artifacts: default 0 rather than reject
            max_accepted_delay: c.u64_field("max_accepted_delay").unwrap_or(0),
            bytes_up: c.u64_field("bytes_up")?,
            bytes_down: c.u64_field("bytes_down")?,
            msgs_up: c.u64_field("msgs_up")?,
            msgs_down: c.u64_field("msgs_down")?,
        };
        // chaos block is absent in pre-chaos artifacts: default zeros
        let chaos = match v.get("chaos") {
            None => ChaosSnapshot::default(),
            Some(h) => ChaosSnapshot {
                delays: h.u64_field("delays")?,
                delay_ns: h.u64_field("delay_ns")?,
                drops: h.u64_field("drops")?,
                duplicates: h.u64_field("duplicates")?,
                corrupt_delivered: h.u64_field("corrupt_delivered")?,
                corrupt_rejected: h.u64_field("corrupt_rejected")?,
                reorders: h.u64_field("reorders")?,
                crashes: h.u64_field("crashes")?,
                late_joins: h.u64_field("late_joins")?,
            },
        };
        let curve = match v.get("curve") {
            Some(Json::Arr(pts)) => pts
                .iter()
                .map(|p| {
                    let p = p.as_arr().filter(|p| p.len() == 3).ok_or("bad curve point")?;
                    Ok((
                        f64_or_nan(&p[0], "curve t")?,
                        p[1].as_u64().ok_or("bad curve iteration")?,
                        f64_or_nan(&p[2], "curve rel")?,
                    ))
                })
                .collect::<Result<Vec<_>, String>>()?,
            _ => return Err("missing array 'curve'".into()),
        };
        let time_to_target = match v.get("time_to_target") {
            Some(Json::Null) | None => None,
            Some(t) => Some(t.as_f64().ok_or("bad 'time_to_target'")?),
        };
        // gap fields are absent in pre-gap artifacts: default NaN (the
        // same value a gap-less run writes) rather than reject.
        let gap = match v.get("gap") {
            None => f64::NAN,
            Some(g) => f64_or_nan(g, "gap")?,
        };
        let gaps = match v.get("gaps") {
            None => vec![f64::NAN; curve.len()],
            Some(Json::Arr(gs)) => gs
                .iter()
                .map(|g| f64_or_nan(g, "gaps entry"))
                .collect::<Result<Vec<_>, _>>()?,
            Some(_) => return Err("bad array 'gaps'".into()),
        };
        Ok(CellResult {
            axes,
            spec_echo: v.str_field("spec_echo")?.to_string(),
            wall,
            final_rel: num_field_or_nan(v, "final_rel")?,
            final_loss: num_field_or_nan(v, "final_loss")?,
            gap,
            gaps,
            time_to_target,
            // absent in pre-factored artifacts: default 0 rather than reject
            rank: v.get("rank").and_then(Json::as_u64).unwrap_or(0),
            peak_atoms: v.get("peak_atoms").and_then(Json::as_u64).unwrap_or(0),
            counters,
            chaos,
            curve,
        })
    }
}

/// JSON has no NaN/Inf: the renderer emits `null` for non-finite values
/// (util::json), so metric fields that can legitimately be non-finite
/// (empty trace -> NaN loss) must parse `null` back to NaN rather than
/// reject the artifact the sweep itself wrote.
fn f64_or_nan(v: &Json, what: &str) -> Result<f64, String> {
    match v {
        Json::Null => Ok(f64::NAN),
        _ => v.as_f64().ok_or_else(|| format!("bad {what}")),
    }
}

fn num_field_or_nan(v: &Json, key: &str) -> Result<f64, String> {
    match v.get(key) {
        None => Err(format!("missing number '{key}'")),
        Some(x) => f64_or_nan(x, key),
    }
}

/// The collected results of one sweep, cells in expansion order.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub name: String,
    /// Relative-loss target the per-cell `time_to_target` refers to.
    pub target: Option<f64>,
    pub cells: Vec<CellResult>,
}

impl SweepResult {
    /// First cell whose axes match every `(axis, value)` pair in `want`.
    pub fn find(&self, want: &[(&str, &str)]) -> Option<&CellResult> {
        self.cells
            .iter()
            .find(|c| want.iter().all(|(k, v)| c.axis(k) == Some(*v)))
    }

    /// All cells passing `pred`, expansion order.
    pub fn cells_where<'a>(
        &'a self,
        pred: impl Fn(&CellResult) -> bool + 'a,
    ) -> impl Iterator<Item = &'a CellResult> {
        self.cells.iter().filter(move |c| pred(c))
    }

    /// Aligned summary table: one row per cell, axes then metrics
    /// (including the comm-cost columns — the paper's headline metric
    /// must show up in artifacts, not only in the JSON counters).
    pub fn table(&self) -> Table {
        let mut headers: Vec<&str> = self
            .cells
            .first()
            .map(|c| c.axes.iter().map(|(k, _)| k.as_str()).collect())
            .unwrap_or_default();
        headers.extend([
            "mean t(s)", "final rel", "gap", "t_target(s)", "dropped", "up B", "down B",
            "rank", "faults",
        ]);
        let mut t = Table::new(&format!("sweep '{}' ({} cells)", self.name, self.cells.len()), &headers);
        for c in &self.cells {
            let mut row: Vec<String> = c.axes.iter().map(|(_, v)| v.clone()).collect();
            row.push(format!("{:.3}", c.wall.mean_s));
            row.push(sig(c.final_rel, 3));
            row.push(if c.gap.is_finite() { sig(c.gap, 3) } else { "—".into() });
            row.push(
                c.time_to_target
                    .map(|x| format!("{x:.3}"))
                    .unwrap_or_else(|| "—".into()),
            );
            row.push(c.counters.dropped_updates.to_string());
            row.push(c.counters.bytes_up.to_string());
            row.push(c.counters.bytes_down.to_string());
            row.push(c.rank.to_string());
            row.push(c.chaos.events_total().to_string());
            t.row(&row);
        }
        t
    }

    /// Write the summary table as CSV (axes + metric columns).
    pub fn write_csv(&self, path: &str) -> Result<(), SweepError> {
        self.table().write_csv(path)?;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("schema".into(), Json::Str("sfw.sweep/v1".into())),
            ("name".into(), Json::Str(self.name.clone())),
            (
                "target".into(),
                self.target.map(Json::Num).unwrap_or(Json::Null),
            ),
            (
                "cells".into(),
                Json::Arr(self.cells.iter().map(CellResult::to_json).collect()),
            ),
        ])
    }

    /// Parse a `sfw.sweep/v1` JSON document back into a result.
    pub fn from_json(text: &str) -> Result<SweepResult, SweepError> {
        let v = Json::parse(text).map_err(SweepError::Json)?;
        let parse = || -> Result<SweepResult, String> {
            match v.get("schema").and_then(Json::as_str) {
                Some("sfw.sweep/v1") => {}
                other => return Err(format!("unknown sweep schema {other:?}")),
            }
            let target = match v.get("target") {
                Some(Json::Null) | None => None,
                Some(t) => Some(t.as_f64().ok_or("bad 'target'")?),
            };
            let cells = match v.get("cells") {
                Some(Json::Arr(cells)) => cells
                    .iter()
                    .map(CellResult::from_json)
                    .collect::<Result<Vec<_>, _>>()?,
                _ => return Err("missing array 'cells'".into()),
            };
            Ok(SweepResult {
                name: v.str_field("name")?.to_string(),
                target,
                cells,
            })
        };
        parse().map_err(SweepError::Json)
    }

    /// Write the machine-readable JSON artifact (creates parent dirs).
    pub fn write_json(&self, path: &str) -> Result<(), SweepError> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().render())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample_cell(algo: &str, w: usize) -> CellResult {
        CellResult {
            axes: vec![
                ("algo".into(), algo.into()),
                ("workers".into(), w.to_string()),
                ("tau".into(), "8".into()),
                ("batch".into(), "256".into()),
                ("power_iters".into(), "24".into()),
                ("transport".into(), "local".into()),
                ("straggler".into(), "none".into()),
                ("chaos".into(), "flaky-net".into()),
                ("seed".into(), "42".into()),
            ],
            spec_echo: format!("task=matrix_sensing algo={algo} workers={w}"),
            wall: Stats::from_samples(vec![0.5, 0.7, 0.6]),
            final_rel: 0.0123,
            final_loss: 0.456,
            gap: 0.031,
            gaps: vec![f64::NAN, 0.12, 0.031],
            time_to_target: if w > 1 { Some(0.25) } else { None },
            rank: 7,
            peak_atoms: 21,
            counters: CounterSnapshot {
                grad_evals: 1000,
                lmo_calls: 10,
                iterations: 100,
                dropped_updates: 3,
                max_accepted_delay: 5,
                bytes_up: 4096,
                bytes_down: 8192,
                msgs_up: 100,
                msgs_down: 100,
            },
            chaos: ChaosSnapshot {
                delays: 7,
                delay_ns: 1_500_000,
                drops: 2,
                duplicates: 1,
                corrupt_delivered: 1,
                corrupt_rejected: 1,
                reorders: 1,
                crashes: 0,
                late_joins: 0,
            },
            curve: vec![(0.0, 0, 1.0), (0.5, 50, 0.2), (1.0, 100, 0.0123)],
        }
    }

    #[test]
    fn json_round_trip_preserves_everything() {
        let res = SweepResult {
            name: "unit".into(),
            target: Some(0.02),
            cells: vec![sample_cell("sfw-asyn", 1), sample_cell("sfw-dist", 4)],
        };
        let text = res.to_json().render();
        let back = SweepResult::from_json(&text).unwrap();
        assert_eq!(back.name, res.name);
        assert_eq!(back.target, res.target);
        assert_eq!(back.cells.len(), 2);
        for (a, b) in res.cells.iter().zip(&back.cells) {
            assert_eq!(a.axes, b.axes);
            assert_eq!(a.spec_echo, b.spec_echo);
            assert_eq!(a.final_rel, b.final_rel);
            assert_eq!(a.gap, b.gap);
            // NaN gap entries render as null and parse back to NaN
            assert_eq!(a.gaps.len(), b.gaps.len());
            for (ga, gb) in a.gaps.iter().zip(&b.gaps) {
                assert!(ga == gb || (ga.is_nan() && gb.is_nan()));
            }
            assert_eq!(a.time_to_target, b.time_to_target);
            assert_eq!((a.rank, a.peak_atoms), (b.rank, b.peak_atoms));
            assert_eq!(a.counters, b.counters);
            assert_eq!(a.chaos, b.chaos);
            assert_eq!(a.curve, b.curve);
            assert_eq!(a.wall.n, b.wall.n);
            assert_eq!(a.wall.mean_s, b.wall.mean_s);
            assert_eq!(a.wall.p90_s, b.wall.p90_s);
        }
    }

    #[test]
    fn pre_factored_artifacts_default_rank_to_zero() {
        // Artifacts written before the rank column existed must parse.
        let res = SweepResult {
            name: "old".into(),
            target: None,
            cells: vec![sample_cell("sfw-asyn", 1)],
        };
        let mut doc = res.to_json();
        if let Json::Obj(top) = &mut doc {
            if let Some((_, Json::Arr(cells))) = top.iter_mut().find(|(k, _)| k == "cells") {
                for cell in cells {
                    if let Json::Obj(fields) = cell {
                        fields.retain(|(k, _)| k != "rank" && k != "peak_atoms");
                    }
                }
            }
        }
        let back = SweepResult::from_json(&doc.render()).unwrap();
        assert_eq!(back.cells[0].rank, 0);
        assert_eq!(back.cells[0].peak_atoms, 0);
    }

    #[test]
    fn pre_chaos_artifacts_still_parse() {
        // A v1 artifact written before the chaos layer existed has no
        // "chaos" object and no max_accepted_delay counter; it must
        // parse with zeros, not be rejected.  Build one by surgically
        // removing those fields from a freshly-rendered document.
        let res = SweepResult {
            name: "old".into(),
            target: None,
            cells: vec![sample_cell("sfw-asyn", 1)],
        };
        let mut doc = res.to_json();
        if let Json::Obj(top) = &mut doc {
            if let Some((_, Json::Arr(cells))) = top.iter_mut().find(|(k, _)| k == "cells") {
                for cell in cells {
                    if let Json::Obj(fields) = cell {
                        fields.retain(|(k, _)| k != "chaos");
                        if let Some((_, Json::Obj(counters))) =
                            fields.iter_mut().find(|(k, _)| k == "counters")
                        {
                            counters.retain(|(k, _)| k != "max_accepted_delay");
                        }
                    }
                }
            }
        }
        let back = SweepResult::from_json(&doc.render()).unwrap();
        assert_eq!(back.cells[0].counters.max_accepted_delay, 0);
        assert_eq!(back.cells[0].chaos, ChaosSnapshot::default());
        // everything else survived
        assert_eq!(back.cells[0].counters.bytes_up, res.cells[0].counters.bytes_up);
    }

    #[test]
    fn pre_gap_artifacts_default_gap_to_nan() {
        // Artifacts written before the gap column existed must parse,
        // with a NaN gap (what a gap-less run writes) and NaN-filled
        // gaps aligned to the curve.
        let res = SweepResult {
            name: "old".into(),
            target: None,
            cells: vec![sample_cell("sfw-asyn", 1)],
        };
        let mut doc = res.to_json();
        if let Json::Obj(top) = &mut doc {
            if let Some((_, Json::Arr(cells))) = top.iter_mut().find(|(k, _)| k == "cells") {
                for cell in cells {
                    if let Json::Obj(fields) = cell {
                        fields.retain(|(k, _)| k != "gap" && k != "gaps");
                    }
                }
            }
        }
        let back = SweepResult::from_json(&doc.render()).unwrap();
        assert!(back.cells[0].gap.is_nan());
        assert_eq!(back.cells[0].gaps.len(), back.cells[0].curve.len());
        assert!(back.cells[0].gaps.iter().all(|g| g.is_nan()));
    }

    #[test]
    fn find_matches_on_axes() {
        let res = SweepResult {
            name: "unit".into(),
            target: None,
            cells: vec![sample_cell("sfw-asyn", 1), sample_cell("sfw-asyn", 4)],
        };
        let c = res.find(&[("algo", "sfw-asyn"), ("workers", "4")]).unwrap();
        assert_eq!(c.axis("workers"), Some("4"));
        assert!(res.find(&[("algo", "pgd")]).is_none());
        assert_eq!(res.cells_where(|c| c.axis("algo") == Some("sfw-asyn")).count(), 2);
    }

    #[test]
    fn non_finite_metrics_survive_the_round_trip() {
        // An empty trace (e.g. iterations=0) leaves final_loss = NaN; the
        // renderer writes null and the parser must accept its own output.
        let mut cell = sample_cell("sfw", 1);
        cell.final_loss = f64::NAN;
        cell.final_rel = f64::INFINITY;
        let res = SweepResult { name: "nan".into(), target: None, cells: vec![cell] };
        let back = SweepResult::from_json(&res.to_json().render()).unwrap();
        assert!(back.cells[0].final_loss.is_nan());
        assert!(back.cells[0].final_rel.is_nan()); // Inf renders as null too
    }

    #[test]
    fn bad_schema_is_rejected() {
        assert!(SweepResult::from_json("{\"schema\":\"other/v9\"}").is_err());
        assert!(SweepResult::from_json("not json").is_err());
    }

    #[test]
    fn table_has_axis_and_metric_columns() {
        let res = SweepResult {
            name: "unit".into(),
            target: Some(0.1),
            cells: vec![sample_cell("sfw-asyn", 2)],
        };
        // Table::row asserts the width matches the headers; print smoke.
        res.table().print();
    }
}
