//! `sfw::sweep` — grid sweeps over [`TrainSpec`]s.
//!
//! The paper's headline results (Figs 4–7, Table 1) are all *grids*:
//! algorithm x worker count x tau x batch schedule x straggler profile.
//! This module makes those grids first-class, layered on the session
//! API:
//!
//! * [`SweepSpec`] — declares axes over a shared base [`TrainSpec`] and
//!   expands them into a deduplicated cartesian product of cells;
//! * [`SweepRunner`] — executes the cells (sequentially or `jobs` at a
//!   time) and collects the uniform reports;
//! * [`SweepResult`] — per-cell wall-clock [`Stats`], convergence
//!   metrics, counters and relative-loss curves, with aligned-table,
//!   CSV and machine-readable JSON emitters (the
//!   `bench_out/sweep_<name>.json` artifact CI uploads).
//!
//! ```no_run
//! use sfw::session::{TaskSpec, TrainSpec};
//! use sfw::sweep::{SweepRunner, SweepSpec};
//!
//! let base = TrainSpec::new(TaskSpec::ms(30, 3, 20_000, 0.1)).iterations(300);
//! let sweep = SweepSpec::new("speedup", base)
//!     .algos(&["sfw-dist", "sfw-asyn"])
//!     .workers(&[1, 3, 7, 15])
//!     .target(0.02);
//! let result = SweepRunner::new().run(&sweep).expect("sweep");
//! result.table().print();
//! result.write_json("bench_out/sweep_speedup.json").expect("json");
//! ```
//!
//! The `sfw sweep` subcommand and the `[sweep]` config section expose
//! the same thing from the CLI; `rust/benches/{fig4_convergence,
//! fig5_speedup, ablation}.rs` are thin [`SweepSpec`] declarations.
//!
//! [`TrainSpec`]: crate::session::TrainSpec
//! [`Stats`]: crate::benchkit::Stats

pub mod config;
pub mod grid;
pub mod result;
pub mod runner;

pub use config::SWEEP_KEYS;
pub use grid::{Cell, StragglerProfile, SweepSpec, AXIS_NAMES, BATCH_AUTO};
pub use result::{CellResult, SweepResult};
pub use runner::SweepRunner;

use crate::config::ConfigError;
use crate::session::SessionError;

/// Errors surfaced by sweep declaration, expansion and execution.
#[derive(Debug, thiserror::Error)]
pub enum SweepError {
    #[error("unknown [sweep] key '{key}' (valid: {valid})")]
    UnknownKey { key: String, valid: String },
    #[error("[sweep] {axis} = '{value}': expected {expected}")]
    BadAxisValue { axis: String, value: String, expected: String },
    #[error("cell {cell}: {source}")]
    Cell { cell: String, source: SessionError },
    #[error(transparent)]
    Session(#[from] SessionError),
    #[error(transparent)]
    Config(#[from] ConfigError),
    #[error(transparent)]
    Chaos(#[from] crate::chaos::ChaosError),
    #[error("sweep json: {0}")]
    Json(String),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}
