//! [`SweepRunner`]: executes an expanded [`SweepSpec`], sequentially or
//! on a small thread pool (`jobs` cells in flight; each cell's run
//! already owns its worker threads, so the cap is a *cell* cap, not a
//! thread cap), and collects the uniform [`Report`]s into a
//! [`SweepResult`] with per-cell wall-clock [`Stats`].
//!
//! [`Report`]: crate::session::Report
//! [`Stats`]: crate::benchkit::Stats

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::benchkit::Stats;
use crate::sweep::grid::{Cell, SweepSpec};
use crate::sweep::result::{CellResult, SweepResult};
use crate::sweep::SweepError;

/// Executes sweeps.  Construct with [`SweepRunner::new`]; `quiet(true)`
/// suppresses the per-cell progress lines (unit tests).
#[derive(Default)]
pub struct SweepRunner {
    quiet: bool,
}

impl SweepRunner {
    pub fn new() -> Self {
        SweepRunner::default()
    }

    pub fn quiet(mut self, q: bool) -> Self {
        self.quiet = q;
        self
    }

    /// Expand and run every cell of `spec` (`spec.jobs` cells in flight),
    /// preserving expansion order in the result.  The first failing cell
    /// aborts the sweep with its error.
    pub fn run(&self, spec: &SweepSpec) -> Result<SweepResult, SweepError> {
        let cells = spec.expand()?;
        let total = cells.len();
        if !self.quiet {
            println!(
                "sweep '{}': {} cells x {} repeat(s), {} job(s)",
                spec.name,
                total,
                spec.repeats,
                spec.jobs.min(total.max(1))
            );
        }
        let mut slots: Vec<Option<CellResult>> = Vec::new();
        slots.resize_with(total, || None);
        let results = Mutex::new(slots);
        let next = AtomicUsize::new(0);
        let first_err: Mutex<Option<SweepError>> = Mutex::new(None);

        let worker = |cells: &[Cell]| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= cells.len() || first_err.lock().unwrap().is_some() {
                return;
            }
            match run_cell(&cells[i], spec, self.quiet, i, cells.len()) {
                Ok(r) => results.lock().unwrap()[i] = Some(r),
                Err(e) => {
                    let mut slot = first_err.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(e);
                    }
                    return;
                }
            }
        };

        let jobs = spec.jobs.max(1).min(total.max(1));
        if jobs <= 1 {
            worker(&cells);
        } else {
            std::thread::scope(|scope| {
                for _ in 0..jobs {
                    scope.spawn(|| worker(&cells));
                }
            });
        }

        if let Some(e) = first_err.into_inner().unwrap() {
            return Err(e);
        }
        let cells = results
            .into_inner()
            .unwrap()
            .into_iter()
            .collect::<Option<Vec<_>>>()
            .expect("every cell ran or the sweep errored");
        Ok(SweepResult { name: spec.name.clone(), target: spec.target, cells })
    }
}

fn run_cell(
    cell: &Cell,
    spec: &SweepSpec,
    quiet: bool,
    index: usize,
    total: usize,
) -> Result<CellResult, SweepError> {
    let mut samples = Vec::with_capacity(spec.repeats);
    let mut last = None;
    for _ in 0..spec.repeats.max(1) {
        let t = Instant::now();
        let report = cell.spec.run().map_err(|e| SweepError::Cell {
            cell: cell.id(),
            source: e,
        })?;
        samples.push(t.elapsed().as_secs_f64());
        last = Some(report);
    }
    let report = last.expect("repeats >= 1");
    let wall = Stats::from_samples(samples);
    let result = CellResult {
        axes: cell.axes.clone(),
        spec_echo: report.spec_echo.clone(),
        wall,
        final_rel: report.final_relative(),
        final_loss: report.final_loss(),
        gap: report.final_gap().unwrap_or(f64::NAN),
        gaps: report.points().iter().map(|p| p.gap).collect(),
        time_to_target: spec.target.and_then(|t| report.time_to_relative(t)),
        rank: report.final_rank as u64,
        peak_atoms: report.peak_atoms as u64,
        counters: report.snapshot(),
        chaos: report.chaos,
        curve: report.relative(),
    };
    if !quiet {
        println!(
            "  [{}/{}] {}  t={:.3}s rel={:.3e}",
            index + 1,
            total,
            cell.id(),
            result.wall.mean_s,
            result.final_rel
        );
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::schedule::BatchSchedule;
    use crate::session::{TaskSpec, TrainSpec};

    fn tiny_base() -> TrainSpec {
        TrainSpec::new(TaskSpec::ms_small())
            .iterations(8)
            .batch(BatchSchedule::Constant(8))
            .eval_every(2)
            .power_iters(10)
            .seed(5)
    }

    #[test]
    fn sequential_sweep_preserves_expansion_order() {
        let spec = SweepSpec::new("unit", tiny_base())
            .algos(&["sfw", "sfw-asyn"])
            .workers(&[1, 2])
            .target(0.9);
        let res = SweepRunner::new().quiet(true).run(&spec).unwrap();
        assert_eq!(res.cells.len(), 4);
        let order: Vec<_> = res
            .cells
            .iter()
            .map(|c| (c.axis("algo").unwrap().to_string(), c.axis("workers").unwrap().to_string()))
            .collect();
        assert_eq!(
            order,
            [("sfw", "1"), ("sfw", "2"), ("sfw-asyn", "1"), ("sfw-asyn", "2")]
                .map(|(a, w)| (a.to_string(), w.to_string()))
        );
        for c in &res.cells {
            assert!(c.wall.n == 1 && c.wall.mean_s >= 0.0);
            assert!(c.counters.iterations > 0, "{}: no iterations", c.id());
            assert!(!c.curve.is_empty());
            // the gap column is aligned with the curve, and every solver
            // here reports a finite final gap
            assert_eq!(c.gaps.len(), c.curve.len(), "{}", c.id());
            assert!(c.gap.is_finite(), "{}: no final gap", c.id());
        }
    }

    #[test]
    fn parallel_jobs_fill_every_slot() {
        let spec = SweepSpec::new("unit-par", tiny_base())
            .algos(&["sfw-asyn"])
            .workers(&[1, 2])
            .seeds(&[5, 6])
            .jobs(2);
        let res = SweepRunner::new().quiet(true).run(&spec).unwrap();
        assert_eq!(res.cells.len(), 4);
        for c in &res.cells {
            assert!(c.counters.iterations > 0);
        }
    }

    #[test]
    fn unknown_algo_fails_with_cell_context() {
        let spec = SweepSpec::new("unit-bad", tiny_base()).algos(&["definitely-not"]);
        let err = SweepRunner::new().quiet(true).run(&spec).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("definitely-not"), "{msg}");
        assert!(msg.contains("algo=definitely-not"), "cell id missing: {msg}");
    }

    #[test]
    fn repeats_feed_wall_stats() {
        let spec = SweepSpec::new("unit-rep", tiny_base()).repeats(3);
        let res = SweepRunner::new().quiet(true).run(&spec).unwrap();
        assert_eq!(res.cells.len(), 1);
        assert_eq!(res.cells[0].wall.n, 3);
    }
}
