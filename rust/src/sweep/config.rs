//! `[sweep]` configuration: resolve a [`SweepSpec`] from the same
//! INI-subset config file + CLI overrides the launcher uses, reusing the
//! section-aware key resolution of `config::TrainConfig`.
//!
//! The base [`TrainSpec`] comes from the ordinary `[train]`/`[data]`
//! keys; the `[sweep]` section declares the axes.  Axis keys are only
//! accepted in their sectioned spelling (`sweep.workers = 1,3,7` in the
//! file, `--sweep.workers 1,3,7` on the CLI) because the flat spellings
//! (`--workers`) already belong to `[train]`; the sweep-owned scalars
//! (`name`, `repeats`, `jobs`, `target`) also accept the flat spelling.
//! A key in the `[sweep]` section that is not a known axis is an error
//! that lists the valid names — same contract as the solver registry.

use std::str::FromStr;

use crate::comms::GradCodec;
use crate::config::{Config, TrainConfig};
use crate::session::{ReprKind, TrainSpec, Transport};
use crate::sweep::grid::{parse_dims, StragglerProfile, SweepSpec};
use crate::sweep::SweepError;

/// Keys the `[sweep]` section accepts (axes + run knobs).
pub const SWEEP_KEYS: &[&str] = &[
    "name", "algos", "objective", "dims", "repr", "uplink", "workers", "threads", "tau", "batch",
    "step", "tol", "power-iters", "transport", "straggler", "chaos", "seeds", "repeats", "jobs",
    "target",
];

impl SweepSpec {
    /// Build a sweep from CLI args + optional `--config` file: base spec
    /// from the `[train]`/`[data]` keys, axes from `[sweep]`.  The file
    /// is parsed once and shared between both resolutions.
    pub fn load(args: &crate::util::cli::Args) -> Result<SweepSpec, SweepError> {
        let file = match args.get_opt("config") {
            Some(path) => Config::from_file(path)?,
            None => Config::new(),
        };
        let train = TrainConfig::resolve(file.clone(), args)?;
        // The `[chaos]`/`--chaos.*` section configures the BASE plan
        // (cells inherit it unless a `chaos` axis overrides per cell).
        let base = TrainSpec::from_config(&train)?
            .maybe_fault_plan(crate::chaos::config::resolve(&file, args)?);
        let mut spec = SweepSpec::from_sources(base, &file, args)?;
        // Prebuild the dataset once: every cell (and repeat) shares the
        // workload via Arc instead of regenerating it inside the timed
        // run — a `seeds` axis then varies algorithm randomness only.
        // A `dims` or `objective` axis regenerates the dataset per
        // cell, so it keeps the generated task instead.
        if spec.dims.is_empty() && spec.objectives.is_empty() {
            spec.base = spec.base.prebuilt();
        }
        Ok(spec)
    }

    /// Resolve the `[sweep]` section of `file` + `--sweep.*` CLI
    /// overrides against `base`.  Exposed separately for tests.
    pub fn from_sources(
        base: TrainSpec,
        file: &Config,
        args: &crate::util::cli::Args,
    ) -> Result<SweepSpec, SweepError> {
        // Reject misspelled keys in BOTH sources: the file's [sweep]
        // section and `--sweep.*` CLI flags.
        for key in file.keys().chain(args.flag_keys()) {
            if let Some(suffix) = key.strip_prefix("sweep.") {
                if !SWEEP_KEYS.contains(&suffix) {
                    return Err(SweepError::UnknownKey {
                        key: suffix.to_string(),
                        valid: SWEEP_KEYS.join(" | "),
                    });
                }
                // A valueless `--sweep.key` parses as a boolean flag and
                // would otherwise drop the axis silently.
                if args.has(key) && args.get_opt(key).is_none() {
                    return Err(SweepError::BadAxisValue {
                        axis: suffix.to_string(),
                        value: String::new(),
                        expected: format!("a value (--sweep.{suffix} <value>)"),
                    });
                }
            }
        }
        // CLI `--sweep.key` beats the file's `[sweep]` section.
        let get = |key: &str| -> Option<String> {
            args.get_opt(&format!("sweep.{key}"))
                .or_else(|| file.get_opt(&format!("sweep.{key}")))
        };
        // Sweep-owned scalars additionally accept the flat CLI spelling.
        let get_scalar = |key: &str| get(key).or_else(|| args.get_opt(key));

        let mut spec = SweepSpec::new(&get_scalar("name").unwrap_or_else(|| "sweep".into()), base);
        if let Some(v) = get("algos") {
            spec.algos = split_list("algos", &v)?
                .into_iter()
                .map(|s| s.to_string())
                .collect();
        }
        if let Some(v) = get("objective") {
            spec.objectives = split_list("objective", &v)?
                .into_iter()
                .map(|s| crate::sweep::grid::objective_task(s).map(|_| s.to_string()))
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = get("dims") {
            spec.dims = split_list("dims", &v)?
                .into_iter()
                .map(|s| parse_dims(s).map(|_| s.to_string()))
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = get("repr") {
            spec.reprs = split_list("repr", &v)?
                .into_iter()
                .map(|s| {
                    ReprKind::parse(s).map(|_| s.to_string()).ok_or_else(|| {
                        SweepError::BadAxisValue {
                            axis: "repr".into(),
                            value: s.to_string(),
                            expected: "auto | dense | factored".into(),
                        }
                    })
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = get("uplink") {
            spec.uplinks = split_list("uplink", &v)?
                .into_iter()
                .map(|s| {
                    GradCodec::parse(s).map(|_| s.to_string()).ok_or_else(|| {
                        SweepError::BadAxisValue {
                            axis: "uplink".into(),
                            value: s.to_string(),
                            expected: GradCodec::VALID.into(),
                        }
                    })
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = get("workers") {
            spec.workers = parse_list("workers", &v, "comma-separated worker counts")?;
        }
        if let Some(v) = get("threads") {
            spec.threads =
                parse_list("threads", &v, "comma-separated kernel thread counts (>= 1)")?;
        }
        if let Some(v) = get("tau") {
            spec.taus = parse_list("tau", &v, "comma-separated staleness bounds")?;
        }
        if let Some(v) = get("batch") {
            spec.batches = split_list("batch", &v)?
                .into_iter()
                .map(|s| {
                    if s.eq_ignore_ascii_case("auto") {
                        Ok(crate::sweep::grid::BATCH_AUTO)
                    } else {
                        parse_one("batch", s, "batch sizes or 'auto'")
                    }
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = get("step") {
            spec.steps = split_list("step", &v)?
                .into_iter()
                .map(|s| {
                    crate::algo::schedule::StepMethod::parse(s)
                        .map(|_| s.to_string())
                        .ok_or_else(|| SweepError::BadAxisValue {
                            axis: "step".into(),
                            value: s.to_string(),
                            expected: crate::algo::schedule::StepMethod::VALID.join(" | "),
                        })
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = get("tol") {
            spec.tols =
                parse_list("tol", &v, "comma-separated dual-gap tolerances (0 disables)")?;
        }
        if let Some(v) = get("power-iters") {
            spec.power_iters = parse_list("power-iters", &v, "comma-separated iteration counts")?;
        }
        if let Some(v) = get("transport") {
            spec.transports = split_list("transport", &v)?
                .into_iter()
                .map(|s| match s {
                    "local" => Ok(Transport::Local),
                    "tcp" => Ok(Transport::Tcp),
                    other => Err(SweepError::BadAxisValue {
                        axis: "transport".into(),
                        value: other.to_string(),
                        expected: "local | tcp".into(),
                    }),
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = get("straggler") {
            spec.stragglers = split_list("straggler", &v)?
                .into_iter()
                .map(StragglerProfile::parse)
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = get("chaos") {
            spec.chaos = split_list("chaos", &v)?
                .into_iter()
                .map(|s| {
                    // validate names at resolution time (expand would
                    // catch them too, but here the user gets the error
                    // before any cell runs); membership is delegated to
                    // FaultPlan::preset so the list cannot drift
                    if s != "none" {
                        crate::chaos::FaultPlan::preset(s, 0)
                            .map_err(|_| crate::sweep::grid::bad_chaos_axis(s))?;
                    }
                    Ok(s.to_string())
                })
                .collect::<Result<_, _>>()?;
        }
        if let Some(v) = get("seeds") {
            spec.seeds = parse_list("seeds", &v, "comma-separated seeds")?;
        }
        if let Some(v) = get_scalar("repeats") {
            spec.repeats = parse_one::<usize>("repeats", &v, "a repeat count")?.max(1);
        }
        if let Some(v) = get_scalar("jobs") {
            spec.jobs = parse_one::<usize>("jobs", &v, "a concurrency cap")?.max(1);
        }
        if let Some(v) = get_scalar("target") {
            if !v.eq_ignore_ascii_case("none") {
                spec.target = Some(parse_one("target", &v, "a relative-loss target or 'none'")?);
            }
        }
        Ok(spec)
    }

    /// The CI smoke sweep: a tiny deterministic grid (seed 42, W in
    /// {1, 2}, every TCP-capable distributed algorithm, local AND tcp
    /// transports, with and without the `flaky-net` chaos plan) on the
    /// small matrix-sensing task.  `sfw sweep --smoke` runs it and
    /// writes `bench_out/sweep_smoke.json` — the artifact the CI
    /// pipeline uploads and asserts nonzero `bytes_up`/`bytes_down` on
    /// every cell plus nonzero injected-event counts on the chaos cells
    /// (`scripts/check_smoke_bytes.py`; see `.github/workflows/ci.yml`
    /// and ROADMAP "Sweeps & CI"/"Chaos").
    pub fn smoke() -> SweepSpec {
        use crate::algo::schedule::BatchSchedule;
        use crate::session::TaskSpec;
        let base = TrainSpec::new(TaskSpec::ms_small())
            .iterations(20)
            .epochs(2) // svrf-asyn cells: 6 + 14 = 20 inner iterations
            .batch(BatchSchedule::Constant(16))
            .eval_every(5)
            .power_iters(20)
            .seed(42);
        SweepSpec::new("smoke", base)
            .algos(&["sfw-dist", "sfw-asyn", "svrf-asyn"])
            .workers(&[1, 2])
            .taus(&[2])
            .transports(&[Transport::Local, Transport::Tcp])
            .chaos_plans(&["none", "flaky-net"])
            .target(0.5)
    }

    /// The CI scale cells that ride along with [`SweepSpec::smoke`]
    /// (`sfw sweep --smoke` merges both into one `sweep_smoke.json`):
    /// one larger non-square matrix-sensing shape, sfw-dist, W = 2,
    /// dense vs factored.  `scripts/check_smoke_bytes.py` asserts the
    /// factored cell's `bytes_down` is measurably below the dense
    /// cell's — the representation's headline saving, pinned in the
    /// artifact.
    pub fn smoke_scale() -> SweepSpec {
        use crate::algo::schedule::BatchSchedule;
        use crate::session::TaskSpec;
        let base = TrainSpec::new(TaskSpec::MatrixSensing {
            d1: 48,
            d2: 32,
            rank: 3,
            n: 600,
            noise_std: 0.05,
        })
        .iterations(20)
        .batch(BatchSchedule::Constant(16))
        .eval_every(5)
        .power_iters(20)
        .seed(42);
        SweepSpec::new("smoke-scale", base)
            .algos(&["sfw-dist"])
            .workers(&[2])
            .taus(&[2])
            .transports(&[Transport::Local])
            .reprs(&["dense", "factored"])
            .target(0.5)
    }

    /// The CI compressed-uplink cells that ride along with
    /// [`SweepSpec::smoke`] and [`SweepSpec::smoke_scale`] in one
    /// `sweep_smoke.json`: a 64x48 matrix-sensing shape (distinct from
    /// the scale pair's 48x32, so cell ids cannot collide), sfw-dist,
    /// W = 2, f32 vs int8 uplink on BOTH transports.
    /// `scripts/check_smoke_bytes.py` asserts the int8 cells' `bytes_up`
    /// is >= 3x below the f32 cells' (expected frame ratio at 64x48:
    /// ~3.67x) at matching final relative loss — error feedback is what
    /// keeps the losses together — with equal `bytes_down`, per
    /// transport.
    pub fn smoke_uplink() -> SweepSpec {
        use crate::algo::schedule::BatchSchedule;
        use crate::session::TaskSpec;
        let base = TrainSpec::new(TaskSpec::MatrixSensing {
            d1: 64,
            d2: 48,
            rank: 3,
            n: 600,
            noise_std: 0.05,
        })
        .iterations(20)
        .batch(BatchSchedule::Constant(16))
        .eval_every(5)
        .power_iters(20)
        .seed(42);
        SweepSpec::new("smoke-uplink", base)
            .algos(&["sfw-dist"])
            .workers(&[2])
            .taus(&[2])
            .transports(&[Transport::Local, Transport::Tcp])
            .uplinks(&["f32", "int8"])
            .target(0.5)
    }
}

impl SweepSpec {
    /// The CI dual-gap cells that ride along with the other smoke grids
    /// in one `sweep_smoke.json`: serial sfw on the small matrix-sensing
    /// task, `tol` in {0, 1e3}.  The tol=0 cell runs its full iteration
    /// budget and carries a finite, net-decreasing `gap` column;
    /// the tol=1e3 cell's gap is under the (huge) tolerance from the
    /// first measurement, so it must stop early — well below the
    /// iteration budget.  `scripts/check_smoke_bytes.py` asserts both,
    /// pinning the gap metric and the `--tol` stopping path in the CI
    /// artifact.
    pub fn smoke_gap() -> SweepSpec {
        use crate::algo::schedule::BatchSchedule;
        use crate::session::TaskSpec;
        let base = TrainSpec::new(TaskSpec::ms_small())
            .algo("sfw")
            .iterations(20)
            .batch(BatchSchedule::Constant(16))
            .eval_every(5)
            .power_iters(20)
            .seed(42);
        SweepSpec::new("smoke-gap", base).tols(&[0.0, 1e3]).target(0.5)
    }

    /// The CI sparse-completion cells that ride along with the other
    /// smoke grids in one `sweep_smoke.json`: the small synthetic
    /// recommender (96x48, power-law mask), sfw-asyn, factored iterate,
    /// W in {1, 2}.  `scripts/check_smoke_bytes.py` asserts the cells
    /// report a nonzero rank/atom count and that their uplink bytes are
    /// atom-scale — O((rows + cols) * iters), nowhere near a dense
    /// gradient per update — pinning the O(nnz) sparse path end to end.
    pub fn smoke_sparse() -> SweepSpec {
        use crate::algo::schedule::BatchSchedule;
        use crate::session::TaskSpec;
        let base = TrainSpec::new(TaskSpec::sparse_small())
            .iterations(20)
            .batch(BatchSchedule::Constant(16))
            .eval_every(5)
            .power_iters(20)
            .seed(42);
        SweepSpec::new("smoke-sparse", base)
            .algos(&["sfw-asyn"])
            .workers(&[1, 2])
            .taus(&[2])
            .transports(&[Transport::Local])
            .reprs(&["factored"])
            .target(0.5)
    }

    /// The CI threaded-kernels cells that ride along with the other
    /// smoke grids in one `sweep_smoke.json`: a 56x40 matrix-sensing
    /// shape (distinct from every other smoke grid's dims, so cell ids
    /// cannot collide and `check_smoke_bytes.py` can filter on it),
    /// sfw-asyn, W = 2, `threads` in {1, 4}.
    /// `scripts/check_smoke_bytes.py` asserts the two cells report
    /// EXACTLY equal `bytes_up`, `bytes_down`, and final relative loss —
    /// the kernels determinism contract (thread count is a pure
    /// wall-clock knob) pinned in the CI artifact.
    pub fn smoke_threads() -> SweepSpec {
        use crate::algo::schedule::BatchSchedule;
        use crate::session::TaskSpec;
        let base = TrainSpec::new(TaskSpec::MatrixSensing {
            d1: 56,
            d2: 40,
            rank: 3,
            n: 600,
            noise_std: 0.05,
        })
        .iterations(20)
        .batch(BatchSchedule::Constant(16))
        .eval_every(5)
        .power_iters(20)
        .seed(42);
        SweepSpec::new("smoke-threads", base)
            .algos(&["sfw-asyn"])
            .workers(&[2])
            .taus(&[2])
            .transports(&[Transport::Local])
            .threads(&[1, 4])
            .target(0.5)
    }
}

fn split_list<'a>(axis: &str, v: &'a str) -> Result<Vec<&'a str>, SweepError> {
    let items: Vec<&str> = v.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    if items.is_empty() {
        return Err(SweepError::BadAxisValue {
            axis: axis.to_string(),
            value: v.to_string(),
            expected: "a non-empty comma-separated list".into(),
        });
    }
    Ok(items)
}

fn parse_one<T: FromStr>(axis: &str, v: &str, expected: &str) -> Result<T, SweepError> {
    v.trim().parse().map_err(|_| SweepError::BadAxisValue {
        axis: axis.to_string(),
        value: v.trim().to_string(),
        expected: expected.to_string(),
    })
}

fn parse_list<T: FromStr>(axis: &str, v: &str, expected: &str) -> Result<Vec<T>, SweepError> {
    split_list(axis, v)?
        .into_iter()
        .map(|s| parse_one(axis, s, expected))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::TaskSpec;
    use crate::util::cli::Args;

    fn args(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    fn base() -> TrainSpec {
        TrainSpec::new(TaskSpec::ms_small())
    }

    #[test]
    fn cli_axes_resolve() {
        let a = args("--sweep.workers 1,3,7 --sweep.algos sfw-dist,sfw-asyn --sweep.target 0.02");
        let s = SweepSpec::from_sources(base(), &Config::new(), &a).unwrap();
        assert_eq!(s.workers, vec![1, 3, 7]);
        assert_eq!(s.algos, vec!["sfw-dist", "sfw-asyn"]);
        assert_eq!(s.target, Some(0.02));
        assert_eq!(s.product_size(), 6);
    }

    #[test]
    fn file_section_resolves_and_cli_wins() {
        let file = Config::from_str("[sweep]\nworkers = 1,2\ntau = 4,8\nname = grid\n").unwrap();
        let a = args("--sweep.workers 9");
        let s = SweepSpec::from_sources(base(), &file, &a).unwrap();
        assert_eq!(s.workers, vec![9]); // CLI beats file
        assert_eq!(s.taus, vec![4, 8]);
        assert_eq!(s.name, "grid");
    }

    #[test]
    fn unknown_sweep_key_lists_valid_names() {
        let file = Config::from_str("[sweep]\nworkerz = 1,2\n").unwrap();
        let err = SweepSpec::from_sources(base(), &file, &args("")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("workerz"), "{msg}");
        for key in SWEEP_KEYS {
            assert!(msg.contains(key), "error should list '{key}': {msg}");
        }
    }

    #[test]
    fn bad_axis_values_name_the_axis() {
        let a = args("--sweep.workers 1,x,3");
        let err = SweepSpec::from_sources(base(), &Config::new(), &a).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("workers") && msg.contains("'x'"), "{msg}");

        let a = args("--sweep.transport carrier-pigeon");
        let err = SweepSpec::from_sources(base(), &Config::new(), &a).unwrap_err();
        assert!(err.to_string().contains("local | tcp"));

        let a = args("--sweep.straggler 20:0.25");
        let err = SweepSpec::from_sources(base(), &Config::new(), &a).unwrap_err();
        assert!(err.to_string().contains("unit_us"), "{err}");
    }

    #[test]
    fn batch_axis_accepts_auto() {
        let a = args("--sweep.batch auto,64");
        let s = SweepSpec::from_sources(base(), &Config::new(), &a).unwrap();
        assert_eq!(s.batches, vec![0, 64]);
    }

    #[test]
    fn scalars_accept_flat_spelling() {
        let a = args("--jobs 4 --repeats 2 --name nightly");
        let s = SweepSpec::from_sources(base(), &Config::new(), &a).unwrap();
        assert_eq!(s.jobs, 4);
        assert_eq!(s.repeats, 2);
        assert_eq!(s.name, "nightly");
    }

    #[test]
    fn smoke_grid_is_tiny_and_deterministic() {
        let s = SweepSpec::smoke();
        assert_eq!(s.name, "smoke");
        assert_eq!(s.base.seed, 42);
        let cells = s.expand().unwrap();
        // 3 algos x W in {1,2} x 2 transports x {none, flaky-net}
        assert_eq!(cells.len(), 24);
        for c in &cells {
            assert_eq!(c.axis("seed"), Some("42"));
        }
        for algo in ["sfw-dist", "sfw-asyn", "svrf-asyn"] {
            // one tcp cell per TCP-capable solver, pinning the wire path
            assert!(
                cells.iter().any(|c| c.axis("algo") == Some(algo)
                    && c.axis("transport") == Some("tcp")),
                "smoke grid must include a tcp cell for '{algo}'"
            );
            // and one flaky-net chaos cell per solver, pinning injection
            let chaos = cells
                .iter()
                .find(|c| c.axis("algo") == Some(algo) && c.axis("chaos") == Some("flaky-net"))
                .unwrap_or_else(|| panic!("smoke grid must include a flaky-net cell for '{algo}'"));
            assert_eq!(chaos.spec.fault_plan.as_ref().unwrap().name, "flaky-net");
        }
    }

    #[test]
    fn smoke_scale_grid_is_the_dense_vs_factored_pair() {
        let cells = SweepSpec::smoke_scale().expand().unwrap();
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert_eq!(c.axis("algo"), Some("sfw-dist"));
            assert_eq!(c.axis("dims"), Some("48x32"));
            assert_eq!(c.axis("workers"), Some("2"));
            assert_eq!(c.axis("seed"), Some("42"));
        }
        assert_eq!(cells[0].axis("repr"), Some("dense"));
        assert_eq!(cells[1].axis("repr"), Some("factored"));
        assert!(matches!(cells[1].spec.repr, crate::session::ReprKind::Factored));
    }

    #[test]
    fn uplink_key_resolves_and_rejects_bad_codecs() {
        let a = args("--sweep.uplink f32,int8");
        let s = SweepSpec::from_sources(base(), &Config::new(), &a).unwrap();
        assert_eq!(s.uplinks, vec!["f32", "int8"]);
        let err = SweepSpec::from_sources(base(), &Config::new(), &args("--sweep.uplink fp8"))
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("uplink") && msg.contains("bf16"), "{msg}");
    }

    #[test]
    fn smoke_uplink_grid_is_the_f32_vs_int8_quad() {
        let cells = SweepSpec::smoke_uplink().expand().unwrap();
        assert_eq!(cells.len(), 4);
        for c in &cells {
            assert_eq!(c.axis("algo"), Some("sfw-dist"));
            assert_eq!(c.axis("dims"), Some("64x48"));
            assert_eq!(c.axis("workers"), Some("2"));
            assert_eq!(c.axis("seed"), Some("42"));
        }
        for transport in ["local", "tcp"] {
            for uplink in ["f32", "int8"] {
                assert!(
                    cells.iter().any(|c| c.axis("transport") == Some(transport)
                        && c.axis("uplink") == Some(uplink)),
                    "missing {transport}/{uplink} uplink smoke cell"
                );
            }
        }
    }

    #[test]
    fn smoke_sparse_grid_is_the_factored_worker_pair() {
        let cells = SweepSpec::smoke_sparse().expand().unwrap();
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert_eq!(c.axis("algo"), Some("sfw-asyn"));
            assert_eq!(c.axis("objective"), Some("sparse_completion"));
            assert_eq!(c.axis("dims"), Some("96x48"));
            assert_eq!(c.axis("repr"), Some("factored"));
            assert_eq!(c.axis("seed"), Some("42"));
        }
        assert_eq!(cells[0].axis("workers"), Some("1"));
        assert_eq!(cells[1].axis("workers"), Some("2"));
    }

    #[test]
    fn threads_key_resolves_and_rejects_bad_values() {
        let a = args("--sweep.threads 1,4");
        let s = SweepSpec::from_sources(base(), &Config::new(), &a).unwrap();
        assert_eq!(s.threads, vec![1, 4]);
        let err = SweepSpec::from_sources(base(), &Config::new(), &args("--sweep.threads many"))
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("threads") && msg.contains("'many'"), "{msg}");
    }

    #[test]
    fn smoke_threads_grid_is_the_determinism_twin_pair() {
        let cells = SweepSpec::smoke_threads().expand().unwrap();
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert_eq!(c.axis("algo"), Some("sfw-asyn"));
            assert_eq!(c.axis("dims"), Some("56x40"));
            assert_eq!(c.axis("workers"), Some("2"));
            assert_eq!(c.axis("seed"), Some("42"));
        }
        assert_eq!(cells[0].axis("threads"), Some("1"));
        assert_eq!(cells[1].axis("threads"), Some("4"));
        assert_eq!(cells[1].spec.threads, 4);
    }

    #[test]
    fn step_and_tol_keys_resolve_and_reject_bad_values() {
        let a = args("--sweep.step vanilla,away --sweep.tol 0,0.001");
        let s = SweepSpec::from_sources(base(), &Config::new(), &a).unwrap();
        assert_eq!(s.steps, vec!["vanilla", "away"]);
        assert_eq!(s.tols, vec![0.0, 0.001]);
        let err = SweepSpec::from_sources(base(), &Config::new(), &args("--sweep.step exact"))
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("step") && msg.contains("line-search"), "{msg}");
        let err = SweepSpec::from_sources(base(), &Config::new(), &args("--sweep.tol soon"))
            .unwrap_err();
        assert!(err.to_string().contains("tol"), "{err}");
    }

    #[test]
    fn smoke_gap_grid_is_the_tol_pair() {
        let cells = SweepSpec::smoke_gap().expand().unwrap();
        assert_eq!(cells.len(), 2);
        for c in &cells {
            assert_eq!(c.axis("algo"), Some("sfw"));
            assert_eq!(c.axis("seed"), Some("42"));
        }
        assert_eq!(cells[0].axis("tol"), Some("0"));
        assert_eq!(cells[1].axis("tol"), Some("1000"));
        assert_eq!(cells[1].spec.tol, 1e3);
    }

    #[test]
    fn objective_key_resolves_and_skips_prebuilding() {
        let a = args("--sweep.objective matrix_sensing,sparse_completion");
        let s = SweepSpec::from_sources(base(), &Config::new(), &a).unwrap();
        assert_eq!(s.objectives, vec!["matrix_sensing", "sparse_completion"]);
        let err =
            SweepSpec::from_sources(base(), &Config::new(), &args("--sweep.objective lasso"))
                .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("objective") && msg.contains("sparse_completion"), "{msg}");
        // an objective axis keeps a generated base task (per-cell data)
        let small = "--data.ms-n 300 --data.ms-d 8 --data.ms-rank 2";
        let s = SweepSpec::load(&args(&format!("{small} --sweep.objective sparse_completion")))
            .unwrap();
        assert!(!matches!(s.base.task, crate::session::TaskSpec::Prebuilt(_)));
    }

    #[test]
    fn dims_and_repr_keys_resolve_from_cli() {
        let a = args("--sweep.dims 8x8,16x12 --sweep.repr dense,factored");
        let s = SweepSpec::from_sources(base(), &Config::new(), &a).unwrap();
        assert_eq!(s.dims, vec!["8x8", "16x12"]);
        assert_eq!(s.reprs, vec!["dense", "factored"]);
        assert_eq!(s.product_size(), 4);
        // bad values name the axis
        let err = SweepSpec::from_sources(base(), &Config::new(), &args("--sweep.dims 8by8"))
            .unwrap_err();
        assert!(err.to_string().contains("dims"), "{err}");
        let err = SweepSpec::from_sources(base(), &Config::new(), &args("--sweep.repr sparse"))
            .unwrap_err();
        assert!(err.to_string().contains("factored"), "{err}");
    }

    #[test]
    fn dims_axis_skips_prebuilding_the_base() {
        let small = "--data.ms-n 300 --data.ms-d 8 --data.ms-rank 2";
        let s = SweepSpec::load(&args(&format!("{small} --sweep.dims 8x8,10x6"))).unwrap();
        assert!(
            !matches!(s.base.task, crate::session::TaskSpec::Prebuilt(_)),
            "dims axis must keep a generated task"
        );
        let s = SweepSpec::load(&args(small)).unwrap();
        assert!(matches!(s.base.task, crate::session::TaskSpec::Prebuilt(_)));
    }

    #[test]
    fn chaos_axis_resolves_and_rejects_bad_presets() {
        let a = args("--sweep.chaos none,flaky-net,crash-1");
        let s = SweepSpec::from_sources(base(), &Config::new(), &a).unwrap();
        assert_eq!(s.chaos, vec!["none", "flaky-net", "crash-1"]);
        let a = args("--sweep.chaos clean,flakey-net");
        let err = SweepSpec::from_sources(base(), &Config::new(), &a).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("chaos") && msg.contains("flakey-net"), "{msg}");
        assert!(msg.contains("flaky-net") && msg.contains("crash-1"), "{msg}");
    }

    #[test]
    fn chaos_section_feeds_the_base_spec() {
        // [chaos] (or --chaos.*) sets the BASE plan the cells inherit.
        let small = "--data.ms-n 300 --data.ms-d 8 --data.ms-rank 2";
        let a = args(&format!("{small} --chaos.plan slow-tail --chaos.seed 11"));
        let s = SweepSpec::load(&a).unwrap();
        let plan = s.base.fault_plan.as_ref().unwrap();
        assert_eq!(plan.name, "slow-tail");
        assert_eq!(plan.seed, 11);
        // a chaos-axis preset cell derives its seed from the base plan
        let s2 = SweepSpec::load(&args(&format!(
            "{small} --chaos.plan slow-tail --chaos.seed 11 --sweep.chaos flaky-net"
        )))
        .unwrap();
        let cells = s2.expand().unwrap();
        assert_eq!(cells[0].spec.fault_plan.as_ref().unwrap().seed, 11);
        // unknown --chaos.* keys error through the sweep loader too
        assert!(SweepSpec::load(&args("--chaos.plann clean")).is_err());
    }
}
