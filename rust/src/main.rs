//! `sfw` — the launcher binary.
//!
//! Subcommands:
//!   train     run one training job and print the loss trace + counters.
//!             The CLI/config file maps onto a `sfw::session::TrainSpec`,
//!             so EVERY registered algorithm x task x engine x transport
//!             combination is reachable from here (see
//!             `sfw::session::registry()` for the algorithm list).
//!             `--transport tcp --tcp-bind HOST:PORT --tcp-await true`
//!             makes the master await external worker processes.
//!   worker    join a remote master as one worker rank over TCP:
//!             `sfw worker --connect HOST:PORT --rank R` plus the same
//!             task/seed/batch flags the master was started with (the
//!             dataset and schedules are regenerated locally from them).
//!   sweep     expand a `[sweep]` axis grid over TrainSpecs, run every
//!             cell, print the summary table and write
//!             bench_out/sweep_<name>.{json,csv} (`--smoke` runs the
//!             tiny deterministic CI grid).
//!   serve     answer per-user top-k prediction queries from a model
//!             checkpoint written by `sfw train --checkpoint` — scores
//!             straight off the atom list, O(atoms * cols) per user, no
//!             dense X; `--user U` for one query or `--queries FILE`
//!             (one user id per line) for a batch — bad ids are
//!             reported and counted, never fatal to the batch — then a
//!             request/latency/error report.  `--exclude-seen` (with
//!             the training run's --rec-*/--seed flags) drops each
//!             user's already-observed columns from their top-k.
//!   simulate  queuing-model simulation (Appendix D)
//!   info      show the artifact manifest and PJRT platform
//!   lint      repo-native static analysis (panic-freedom, SAFETY
//!             comments, wire coverage, lock discipline, error-variant
//!             liveness — see sfw::lint for the rule table); prints a
//!             table, writes bench_out/lint_report.json, exits nonzero
//!             on violations
//!
//! Examples:
//!   sfw train --task matrix_sensing --algo sfw-asyn --workers 8 --tau 8
//!   sfw train --task pnn --algo sfw-dist --engine pjrt --iterations 100
//!   sfw train --algo sfw-asyn --transport tcp --workers 4
//!   sfw train --algo svrf-asyn --transport tcp --workers 2 \
//!             --tcp-bind 127.0.0.1:7070 --tcp-await true --seed 42 --batch 64
//!   sfw worker --connect 127.0.0.1:7070 --rank 0 --algo svrf-asyn --seed 42 --batch 64
//!   sfw train --config run.ini --train.workers 16
//!   sfw train --algo sfw-asyn --workers 4 --chaos.plan flaky-net --chaos.seed 7
//!   sfw train --algo sfw-asyn --workers 4 --threads 8   # kernel pool; bit-identical to --threads 1
//!   sfw sweep --smoke
//!   sfw sweep --sweep.algos sfw-dist,sfw-asyn --sweep.workers 1,3,7,15 \
//!             --sweep.target 0.02 --name speedup
//!   sfw sweep --sweep.chaos none,slow-tail,flaky-net --sweep.algos sfw-asyn --name chaos
//!   sfw sweep --config run.ini --sweep.tau 0,2,8,64 --jobs 2
//!   sfw train --task sparse_completion --algo sfw-asyn --workers 4 \
//!             --rec-rows 20000 --rec-cols 2000 --rec-density 0.01 \
//!             --checkpoint model.json
//!   sfw serve --model model.json --user 17 --topk 5
//!   sfw serve --model model.json --queries users.txt --topk 10
//!   sfw simulate --p 0.1 --workers 15 --iterations 500
//!   sfw info --artifacts-dir artifacts

use sfw::algo::engine::NativeEngine;
use sfw::algo::schedule::BatchSchedule;
use sfw::config::{Config, TrainConfig};
use sfw::session::{registry, Report, TrainSpec};
use sfw::sim::{simulate_asyn, simulate_dist, QueuingParams};
use sfw::sweep::{SweepRunner, SweepSpec};
use sfw::util::cli::Args;

/// Parse the `--config` file once (empty config when absent) so both the
/// `[train]`/`[data]` resolution and the `[chaos]` section read the same
/// document.
fn load_config_file(args: &Args) -> anyhow::Result<Config> {
    Ok(match args.get_opt("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::new(),
    })
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let cmd = argv.get(1).map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse_env(2);
    match cmd {
        "train" => cmd_train(&args),
        "worker" => cmd_worker(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args),
        "simulate" => cmd_simulate(&args),
        "info" => cmd_info(&args),
        "lint" => cmd_lint(&args),
        _ => {
            eprintln!(
                "usage: sfw <train|worker|sweep|serve|simulate|info|lint> [--flags]\n\
                 see rust/src/main.rs header for examples"
            );
            Ok(())
        }
    }
}

fn print_result(report: &Report) {
    println!("\n#  t(s)      iter   loss          rel         gap");
    let pts = report.points();
    let rel = report.relative();
    for (p, (_, _, r)) in pts.iter().zip(rel.iter()) {
        let gap = if p.gap.is_finite() { format!("{:.4e}", p.gap) } else { "—".into() };
        println!("  {:<9.3} {:<6} {:<13.6e} {:<11.4e} {gap}", p.t, p.iteration, p.loss, r);
    }
    let s = report.snapshot();
    println!(
        "\ncounters: iters={} grads={} lmos={} dropped={} max-delay={} up={}B/{}msg down={}B/{}msg",
        s.iterations,
        s.grad_evals,
        s.lmo_calls,
        s.dropped_updates,
        s.max_accepted_delay,
        s.bytes_up,
        s.msgs_up,
        s.bytes_down,
        s.msgs_down
    );
    println!(
        "iterate:  rank={} peak-atoms={}",
        report.final_rank, report.peak_atoms
    );
    let c = &report.chaos;
    if c.events_total() > 0 {
        println!(
            "chaos:    delays={} ({:.1}ms) drops={} dups={} corrupt={}+{} reorders={} \
             crashes={} late-joins={}",
            c.delays,
            c.delay_ns as f64 / 1e6,
            c.drops,
            c.duplicates,
            c.corrupt_delivered,
            c.corrupt_rejected,
            c.reorders,
            c.crashes,
            c.late_joins
        );
    }
}

/// `sfw train`: a thin Config/CLI -> `TrainSpec` mapping; all wiring
/// (objective, engines, transport, metrics) lives in `sfw::session`.
fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let file = load_config_file(args)?;
    let cfg = TrainConfig::resolve(file.clone(), args)?;
    let mut spec = TrainSpec::from_config(&cfg)?;
    // `[chaos]` section / --chaos.* keys install a fault plan
    spec = spec.maybe_fault_plan(sfw::chaos::config::resolve(&file, args)?);
    println!("{}", spec.echo());
    match spec.run() {
        Ok(report) => {
            print_result(&report);
            if let Some(path) = args.get_opt("checkpoint") {
                checkpoint(&report, &path)?;
            }
            Ok(())
        }
        Err(e) => anyhow::bail!(
            "{e}\nregistered algorithms: {}",
            registry().names().join(", ")
        ),
    }
}

/// Write the trained model as a `sfw.model/v1` atom-list file.  Factored
/// runs save their atom list verbatim; dense runs re-factorize the final
/// iterate through an exact SVD first (cutting components below 1e-6 of
/// the leading singular value).
fn checkpoint(report: &Report, path: &str) -> anyhow::Result<()> {
    let f = match &report.factored {
        Some(f) => f.clone(),
        None => {
            let (u, s, v) = sfw::linalg::jacobi_svd(&report.x);
            let cutoff = 1e-6 * s.first().copied().unwrap_or(0.0);
            sfw::linalg::FactoredMat::from_svd(&u, &s, &v, cutoff)
        }
    };
    sfw::model::save(&f, path)?;
    println!("checkpoint: {} atoms ({}x{}) -> {path}", f.atoms(), f.rows, f.cols);
    Ok(())
}

/// `sfw serve`: answer top-k prediction queries from a checkpoint.  Each
/// query scores one user's row of X = sum_i w_i u_i v_i^T directly off
/// the atom list — O(atoms * cols) per query, independent of the training
/// set size, no dense materialization.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    sfw::chaos::reject_chaos_keys("serve", &Config::new(), args)?;
    let path = args
        .get_opt("model")
        .ok_or_else(|| anyhow::anyhow!("sfw serve: --model <checkpoint.json> is required"))?;
    let topk = args.get_usize("topk", 10);
    let model = sfw::model::load(&path)?;
    println!(
        "model: {}x{} rank<={} atoms ({path})",
        model.rows,
        model.cols,
        model.atoms()
    );
    let stats = sfw::metrics::ServeStats::new();
    let users: Vec<usize> = if let Some(user) = args.get_opt("user") {
        // a single explicit --user query has nothing to continue past:
        // a bad value is still a hard error
        vec![user
            .parse()
            .map_err(|_| anyhow::anyhow!("sfw serve: --user must be a row index"))?]
    } else if let Some(qfile) = args.get_opt("queries") {
        let text = std::fs::read_to_string(&qfile)
            .map_err(|e| anyhow::anyhow!("sfw serve: cannot read {qfile}: {e}"))?;
        let mut users = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            // a malformed line must not abort the rest of the batch
            match line.parse() {
                Ok(u) => users.push(u),
                Err(_) => {
                    stats.record_error();
                    eprintln!("{qfile}:{}: bad user id '{line}' (skipped)", lineno + 1);
                }
            }
        }
        users
    } else {
        anyhow::bail!("sfw serve: give --user <row> or --queries <file>");
    };
    // --exclude-seen drops the columns a user already interacted with
    // from their top-k.  The observation mask is a pure function of the
    // rec-* params + --seed, so serving regenerates it from the same
    // flags the training run used (the checkpoint stores only atoms).
    let seen: Option<sfw::data::RecommenderData> = if args.get_bool("exclude-seen") {
        let file = load_config_file(args)?;
        let cfg = TrainConfig::resolve(file, args)?;
        let spec = TrainSpec::from_config(&cfg)?;
        match &spec.task {
            sfw::session::TaskSpec::SparseCompletion(p) => {
                let data = sfw::data::RecommenderData::generate(
                    p,
                    &mut sfw::util::rng::Rng::new(spec.seed),
                );
                if (data.rows, data.cols) != (model.rows, model.cols) {
                    anyhow::bail!(
                        "sfw serve: --exclude-seen mask is {}x{} but the model is {}x{} \
                         (pass the same --rec-* / --seed flags the training run used)",
                        data.rows,
                        data.cols,
                        model.rows,
                        model.cols
                    );
                }
                Some(data)
            }
            _ => anyhow::bail!(
                "sfw serve: --exclude-seen needs the training task: add \
                 --task sparse_completion plus the --rec-* / --seed flags used to train"
            ),
        }
    } else {
        None
    };
    let mut scores = Vec::new();
    for &user in &users {
        let t0 = std::time::Instant::now();
        // One bad id (out-of-range row, typo in the queries file) must
        // not abort the rest of the batch: report it, count it, move on.
        match sfw::model::user_scores(&model, user, &mut scores) {
            Ok(()) => {
                let top = match &seen {
                    Some(data) => {
                        let cols = data.observed_cols(user);
                        sfw::model::top_k_excluding(&scores, topk, |j| {
                            cols.binary_search(&(j as u32)).is_ok()
                        })
                    }
                    None => sfw::model::top_k(&scores, topk),
                };
                stats.record(t0.elapsed());
                let rendered: Vec<String> =
                    top.iter().map(|(j, s)| format!("{j}:{s:.4}")).collect();
                println!("user {user:<8} top{topk}: {}", rendered.join(" "));
            }
            Err(e) => {
                stats.record_error();
                eprintln!("user {user:<8} error: {e}");
            }
        }
    }
    let s = stats.snapshot();
    println!(
        "\nserve: requests={} errors={} mean={:.1}us max={:.1}us",
        s.requests, s.errors, s.mean_us, s.max_us
    );
    Ok(())
}

/// `sfw worker`: the worker side of a multi-process TCP run.  Builds the
/// same spec the master was configured with (task/seed/batch must match —
/// the dataset is regenerated locally, never shipped), connects to
/// `--connect` as `--rank`, and serves gradient/LMO work until the
/// master sends Stop.
fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    let connect = args
        .get_opt("connect")
        .ok_or_else(|| anyhow::anyhow!("sfw worker: --connect HOST:PORT is required"))?;
    let rank: u32 = args
        .get_opt("rank")
        .ok_or_else(|| anyhow::anyhow!("sfw worker: --rank <R> is required"))?
        .parse()
        .map_err(|_| anyhow::anyhow!("sfw worker: --rank must be a non-negative integer"))?;
    let file = load_config_file(args)?;
    // chaos is configured on the master (it wraps in-process links); a
    // plan on the worker command would silently do nothing
    sfw::chaos::reject_chaos_keys("worker", &file, args)?;
    let cfg = TrainConfig::resolve(file, args)?;
    let mut spec = TrainSpec::from_config(&cfg)?;
    spec.transport = sfw::session::Transport::Tcp;
    spec.tcp_bind = None; // bind options belong to the master
    spec.tcp_await = false;
    println!("worker rank {rank} -> {connect} ({})", spec.echo());
    spec.run_worker(&connect, rank)?;
    println!("worker rank {rank}: master finished; exiting");
    Ok(())
}

/// `sfw sweep`: expand + run a `[sweep]` grid and emit the artifacts.
/// `--smoke` runs the fixed CI grid (seed 42, W in {1,2}); otherwise the
/// grid comes from `--sweep.*` keys / the config file's `[sweep]` section
/// over the usual `[train]`/`[data]` base.
fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let spec = if args.get_bool("smoke") {
        // The smoke grid is fixed by contract (CI compares artifacts
        // across runs); grid-shaping flags must fail loudly, not be
        // ignored.
        if let Some(key) = args.flag_keys().find(|k| {
            k.starts_with("sweep.")
                || k.starts_with("chaos.")
                || matches!(k.as_str(), "config" | "name" | "target")
        }) {
            anyhow::bail!("--{key} does not apply to --smoke (the grid is fixed; drop --smoke)");
        }
        let mut spec = SweepSpec::smoke();
        // Execution knobs (not grid shape) still apply to the smoke grid.
        if args.has("jobs") {
            let jobs = args.get_usize("jobs", spec.jobs);
            spec = spec.jobs(jobs);
        }
        if args.has("repeats") {
            let repeats = args.get_usize("repeats", spec.repeats);
            spec = spec.repeats(repeats);
        }
        spec
    } else {
        // --jobs/--repeats/--sweep.* resolve inside SweepSpec::load.
        SweepSpec::load(args)?
    };
    let mut result = SweepRunner::new().run(&spec)?;
    if args.get_bool("smoke") {
        // The scale cells (larger shape, dense vs factored sfw-dist)
        // ride along in the same artifact; check_smoke_bytes.py asserts
        // the factored downlink win on them.
        let scale = SweepRunner::new().run(&SweepSpec::smoke_scale())?;
        result.cells.extend(scale.cells);
        // So do the compressed-uplink cells (64x48 sfw-dist, f32 vs int8
        // on both transports); check_smoke_bytes.py asserts the >= 3x
        // uplink byte win at matching final relative loss on them.
        let uplink = SweepRunner::new().run(&SweepSpec::smoke_uplink())?;
        result.cells.extend(uplink.cells);
        // And the sparse-completion cells (96x48 recommender, factored
        // sfw-asyn, W in {1,2}); check_smoke_bytes.py asserts nonzero
        // rank/atom counts and atom-scale uplink bytes on them.
        let sparse = SweepRunner::new().run(&SweepSpec::smoke_sparse())?;
        result.cells.extend(sparse.cells);
        // And the dual-gap cells (serial sfw, tol in {0, 1e3});
        // check_smoke_bytes.py asserts a finite net-decreasing gap
        // column on the tol=0 cell and an early gap-stop on the other.
        let gap = SweepRunner::new().run(&SweepSpec::smoke_gap())?;
        result.cells.extend(gap.cells);
        // And the threaded-kernels twins (56x40 sfw-asyn, threads 1 vs
        // 4); check_smoke_bytes.py asserts exactly equal bytes and final
        // loss between them — the kernels determinism contract in CI.
        let threads = SweepRunner::new().run(&SweepSpec::smoke_threads())?;
        result.cells.extend(threads.cells);
    }
    result.table().print();
    let out_dir = args.get_str("out-dir", "bench_out");
    let json_path = format!("{out_dir}/sweep_{}.json", spec.name);
    let csv_path = format!("{out_dir}/sweep_{}.csv", spec.name);
    result.write_json(&json_path)?;
    result.write_csv(&csv_path)?;
    println!("\nsweep '{}': {} cells -> {json_path}, {csv_path}", spec.name, result.cells.len());
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let file = load_config_file(args)?;
    sfw::chaos::reject_chaos_keys("simulate", &file, args)?;
    let cfg = TrainConfig::resolve(file, args)?;
    // The simulator always drives native engines; the spec is only used
    // to build the objective from the task fields.
    let spec = TrainSpec::from_config(&cfg)?.engine(sfw::session::EngineKind::Native);
    let p = args.get_f64("p", 0.1);
    let obj = sfw::session::RunCtx::new(&spec)?.obj;
    let prm = QueuingParams {
        workers: cfg.workers,
        p,
        iterations: cfg.iterations,
        tau: cfg.tau,
        batch: BatchSchedule::Constant(args.get_usize("batch", 128)),
        eval_every: cfg.eval_every,
        seed: cfg.seed,
        ..Default::default()
    };
    println!("queuing sim: W={} p={} T={} tau={}", prm.workers, p, prm.iterations, prm.tau);
    let mut engines: Vec<NativeEngine> = (0..cfg.workers)
        .map(|w| NativeEngine::new(obj.clone(), cfg.power_iters, cfg.seed ^ w as u64))
        .collect();
    let ra = simulate_asyn(obj.clone(), &mut engines, &prm);
    let mut e1 = vec![NativeEngine::new(obj.clone(), cfg.power_iters, cfg.seed ^ 0xFF)];
    let rd = simulate_dist(obj.clone(), &mut e1, &prm);
    println!(
        "SFW-asyn: {} virtual units, final loss {:.4e}",
        ra.virtual_time,
        ra.trace.points().last().unwrap().loss
    );
    println!(
        "SFW-dist: {} virtual units, final loss {:.4e}",
        rd.virtual_time,
        rd.trace.points().last().unwrap().loss
    );
    println!("asyn/dist virtual-time speedup: {:.2}x", rd.virtual_time / ra.virtual_time);
    Ok(())
}

/// `sfw lint`: the repo-native static-analysis gate (see `sfw::lint`).
/// Scans `--src` (default rust/src) with the repo rule set, feeds the
/// cross-file rules from `--tests` (default rust/tests), prints the
/// table, writes the JSON artifact, and fails on any violation.
fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    sfw::chaos::reject_chaos_keys("lint", &Config::new(), args)?;
    let src = args.get_str("src", "rust/src");
    let tests = args.get_str("tests", "rust/tests");
    let out = args.get_str("out", "bench_out/lint_report.json");
    let cfg = sfw::lint::LintConfig::repo();
    let report = sfw::lint::lint_repo(&src, &tests, &cfg)
        .map_err(|e| anyhow::anyhow!("sfw lint: cannot scan {src}: {e}"))?;
    print!("{}", report.render_table());
    report.write_json(&out)?;
    println!("lint report -> {out}");
    if report.is_clean() {
        Ok(())
    } else {
        anyhow::bail!(
            "sfw lint: {} violation(s) — annotate with `// lint: allow(<rule>): <reason>` \
             only where the invariant genuinely holds",
            report.violations.len()
        )
    }
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    sfw::chaos::reject_chaos_keys("info", &Config::new(), args)?;
    let dir = args.get_str("artifacts-dir", "artifacts");
    let rt = sfw::runtime::PjrtRuntime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let m = rt.manifest();
    println!("artifact dir : {}", dir);
    for (k, v) in &m.params {
        println!("  param  {k} = {v}");
    }
    for (name, file) in &m.modules {
        println!("  module {name} ({file})");
    }
    Ok(())
}
