//! `sfw` — the launcher binary.
//!
//! Subcommands:
//!   train     run one training job (task x algorithm x engine) and print
//!             the loss trace + counters
//!   simulate  queuing-model simulation (Appendix D)
//!   info      show the artifact manifest and PJRT platform
//!
//! Examples:
//!   sfw train --task matrix_sensing --algo sfw-asyn --workers 8 --tau 8
//!   sfw train --task pnn --algo sfw-dist --engine pjrt --iterations 100
//!   sfw simulate --p 0.1 --workers 15 --iterations 500
//!   sfw info --artifacts-dir artifacts

use std::sync::Arc;

use sfw::algo::engine::{NativeEngine, StepEngine};
use sfw::algo::schedule::BatchSchedule;
use sfw::algo::sfw::{run_sfw, SfwOptions};
use sfw::config::TrainConfig;
use sfw::coordinator::{
    run_asyn_local, run_dist, run_svrf_asyn_local, AsynOptions, DistOptions, RunResult,
    SvrfAsynOptions,
};
use sfw::coordinator::sva::{run_sva, SvaOptions};
use sfw::coordinator::dfw_power::{run_dfw_power, DfwOptions};
use sfw::data::matrix_sensing::{MatrixSensingData, MsParams};
use sfw::data::pnn::{PnnData, PnnParams};
use sfw::metrics::{Counters, LossTrace};
use sfw::objective::{MatrixSensing, Objective, Pnn};
use sfw::runtime::{PjrtEngine, PjrtRuntime, Workload};
use sfw::sim::{simulate_asyn, simulate_dist, QueuingParams};
use sfw::util::cli::Args;
use sfw::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let cmd = argv.get(1).map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse_env(2);
    match cmd {
        "train" => cmd_train(&args),
        "simulate" => cmd_simulate(&args),
        "info" => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: sfw <train|simulate|info> [--flags]\n\
                 see rust/src/main.rs header for examples"
            );
            Ok(())
        }
    }
}

/// Build the objective + optional PJRT runtime described by the config.
fn build_objective(cfg: &TrainConfig) -> (Arc<dyn Objective>, Option<Workload>) {
    let mut rng = Rng::new(cfg.seed);
    match cfg.task.as_str() {
        "matrix_sensing" => {
            let p = MsParams {
                d1: cfg.ms_d,
                d2: cfg.ms_d,
                rank: cfg.ms_rank,
                n: cfg.ms_n,
                noise_std: cfg.ms_noise,
            };
            let data = MatrixSensingData::generate(&p, &mut rng);
            let obj = Arc::new(MatrixSensing::new(data, cfg.theta));
            (obj.clone(), Some(Workload::Ms(obj)))
        }
        "pnn" => {
            let p = PnnParams {
                d: cfg.pnn_d,
                n: cfg.pnn_n,
                ..Default::default()
            };
            let data = PnnData::generate(&p, &mut rng);
            let obj = Arc::new(Pnn::new(data, cfg.theta));
            (obj.clone(), Some(Workload::Pnn(obj)))
        }
        t => panic!("unknown task '{t}' (matrix_sensing | pnn)"),
    }
}

/// Engine factory honoring `--engine native|pjrt`.
fn engine_factory(
    cfg: &TrainConfig,
    obj: Arc<dyn Objective>,
    workload: Option<Workload>,
) -> Box<dyn FnMut(usize) -> Box<dyn StepEngine>> {
    let seed = cfg.seed;
    let power_iters = cfg.power_iters;
    match cfg.engine.as_str() {
        "native" => Box::new(move |w| {
            Box::new(NativeEngine::new(obj.clone(), power_iters, seed ^ 0xE ^ w as u64))
        }),
        "pjrt" => {
            let rt = Arc::new(
                PjrtRuntime::new(&cfg.artifacts_dir).expect("PJRT runtime (run `make artifacts`?)"),
            );
            let workload = workload.expect("pjrt engine needs a workload");
            Box::new(move |w| {
                Box::new(PjrtEngine::new(rt.clone(), workload.clone(), seed ^ 0xE ^ w as u64))
            })
        }
        e => panic!("unknown engine '{e}' (native | pjrt)"),
    }
}

fn print_result(obj: &Arc<dyn Objective>, trace: &LossTrace, counters: &Counters) {
    println!("\n#  t(s)      iter   loss          rel");
    let pts = trace.points();
    let f0 = pts.first().map(|p| p.loss).unwrap_or(1.0);
    let fs = obj.f_star_hint();
    for p in &pts {
        let rel = (p.loss - fs) / (f0 - fs).max(1e-30);
        println!("  {:<9.3} {:<6} {:<13.6e} {:.4e}", p.t, p.iteration, p.loss, rel);
    }
    let s = counters.snapshot();
    println!(
        "\ncounters: iters={} grads={} lmos={} dropped={} up={}B/{}msg down={}B/{}msg",
        s.iterations,
        s.grad_evals,
        s.lmo_calls,
        s.dropped_updates,
        s.bytes_up,
        s.msgs_up,
        s.bytes_down,
        s.msgs_down
    );
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = TrainConfig::load(args)?;
    println!(
        "task={} algo={} engine={} workers={} tau={} T={} seed={}",
        cfg.task, cfg.algo, cfg.engine, cfg.workers, cfg.tau, cfg.iterations, cfg.seed
    );
    let (obj, workload) = build_objective(&cfg);
    let mut make_engine = engine_factory(&cfg, obj.clone(), workload);
    let scale = cfg.batch_scale;
    let result: RunResult = match cfg.algo.as_str() {
        "sfw" => {
            let counters = Arc::new(Counters::new());
            let trace = Arc::new(LossTrace::new());
            let mut engine = make_engine(0);
            let opts = SfwOptions {
                iterations: cfg.iterations,
                batch: BatchSchedule::sfw(scale, cfg.batch_cap),
                eval_every: cfg.eval_every,
                seed: cfg.seed,
            };
            let x = run_sfw(engine.as_mut(), &opts, &counters, &trace);
            RunResult { x, counters, trace }
        }
        "sfw-asyn" => {
            let opts = AsynOptions {
                iterations: cfg.iterations,
                tau: cfg.tau,
                workers: cfg.workers,
                batch: BatchSchedule::sfw_asyn(scale, cfg.tau, cfg.batch_cap),
                eval_every: cfg.eval_every,
                seed: cfg.seed,
                straggler: None,
                link_latency: None,
            };
            run_asyn_local(obj.clone(), &opts, |w| make_engine(w))
        }
        "sfw-dist" => {
            let opts = DistOptions {
                iterations: cfg.iterations,
                workers: cfg.workers,
                batch: BatchSchedule::sfw(scale, cfg.batch_cap),
                eval_every: cfg.eval_every,
                seed: cfg.seed,
                straggler: None,
            };
            run_dist(obj.clone(), &opts, |w| make_engine(w))
        }
        "svrf-asyn" => {
            let opts = SvrfAsynOptions {
                epochs: (cfg.iterations as f64).log2().ceil().max(1.0) as u32,
                tau: cfg.tau,
                workers: cfg.workers,
                batch: BatchSchedule::svrf_asyn(cfg.tau, cfg.batch_cap),
                eval_every: cfg.eval_every,
                seed: cfg.seed,
            };
            run_svrf_asyn_local(obj.clone(), &opts, |w| make_engine(w))
        }
        "sva" => {
            let opts = SvaOptions {
                iterations: cfg.iterations,
                workers: cfg.workers,
                batch: BatchSchedule::sfw(scale, cfg.batch_cap),
                eval_every: cfg.eval_every,
                seed: cfg.seed,
            };
            run_sva(obj.clone(), &opts, |w| make_engine(w))
        }
        "dfw-power" => {
            let opts = DfwOptions {
                iterations: cfg.iterations,
                workers: cfg.workers,
                eval_every: cfg.eval_every,
                seed: cfg.seed,
                ..Default::default()
            };
            run_dfw_power(obj.clone(), &opts)
        }
        "pgd" => {
            let counters = Arc::new(Counters::new());
            let trace = Arc::new(LossTrace::new());
            let mut engine = make_engine(0);
            let opts = sfw::algo::pgd::PgdOptions {
                iterations: cfg.iterations,
                batch: BatchSchedule::Constant(cfg.batch_cap.min(1024)),
                gamma: 0.05,
                eval_every: cfg.eval_every,
                seed: cfg.seed,
            };
            let x = sfw::algo::pgd::run_pgd(engine.as_mut(), &opts, &counters, &trace);
            RunResult { x, counters, trace }
        }
        a => panic!("unknown algo '{a}'"),
    };
    print_result(&obj, &result.trace, &result.counters);
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let cfg = TrainConfig::load(args)?;
    let p = args.get_f64("p", 0.1);
    let (obj, _) = build_objective(&cfg);
    let prm = QueuingParams {
        workers: cfg.workers,
        p,
        iterations: cfg.iterations,
        tau: cfg.tau,
        batch: BatchSchedule::Constant(args.get_usize("batch", 128)),
        eval_every: cfg.eval_every,
        seed: cfg.seed,
        ..Default::default()
    };
    println!("queuing sim: W={} p={} T={} tau={}", prm.workers, p, prm.iterations, prm.tau);
    let mut engines: Vec<NativeEngine> = (0..cfg.workers)
        .map(|w| NativeEngine::new(obj.clone(), cfg.power_iters, cfg.seed ^ w as u64))
        .collect();
    let ra = simulate_asyn(obj.clone(), &mut engines, &prm);
    let mut e1 = vec![NativeEngine::new(obj.clone(), cfg.power_iters, cfg.seed ^ 0xFF)];
    let rd = simulate_dist(obj.clone(), &mut e1, &prm);
    println!(
        "SFW-asyn: {} virtual units, final loss {:.4e}",
        ra.virtual_time,
        ra.trace.points().last().unwrap().loss
    );
    println!(
        "SFW-dist: {} virtual units, final loss {:.4e}",
        rd.virtual_time,
        rd.trace.points().last().unwrap().loss
    );
    println!("asyn/dist virtual-time speedup: {:.2}x", rd.virtual_time / ra.virtual_time);
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_str("artifacts-dir", "artifacts");
    let rt = PjrtRuntime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let m = rt.manifest();
    println!("artifact dir : {}", dir);
    for (k, v) in &m.params {
        println!("  param  {k} = {v}");
    }
    for (name, file) in &m.modules {
        println!("  module {name} ({file})");
    }
    Ok(())
}
