//! Fixture: a Mutex guard held across a channel send — a blocked peer
//! would keep the lock pinned indefinitely.  Must trigger exactly
//! `no-lock-across-io`.

use std::sync::mpsc::Sender;
use std::sync::Mutex;

pub fn publish(state: &Mutex<u64>, tx: &Sender<u64>) {
    let Ok(guard) = state.lock() else { return };
    let _ = tx.send(*guard);
}
