//! Fixture: a `Wire` implementor that never appears in the round-trip
//! property tests (the test feeds an empty property corpus).  Must
//! trigger exactly `wire-coverage`.

use crate::comms::{Wire, WireError};

pub struct GhostMsg {
    pub rank: u32,
}

impl Wire for GhostMsg {
    fn tag(&self) -> u8 {
        0x7F
    }

    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.rank.to_le_bytes());
    }

    fn decode(_tag: u8, payload: &[u8]) -> Result<Self, WireError> {
        if payload.len() != 4 {
            return Err(WireError::Malformed("ghost payload must be a u32"));
        }
        Ok(GhostMsg { rank: u32::from_le_bytes([payload[0], payload[1], payload[2], payload[3]]) })
    }
}
