//! Fixture: an allow comment without its mandatory reason.  The allow
//! still suppresses the panic-free finding underneath it, so the file
//! must trigger exactly `bad-allow`.

pub fn newest_entry(entries: &[u64]) -> u64 {
    // lint: allow(panic-free)
    *entries.last().unwrap()
}
