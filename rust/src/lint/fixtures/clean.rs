//! Fixture: every annotation mechanism used correctly — a justified
//! allow, a SAFETY comment, and a guard dropped before I/O.  Must
//! trigger no rule at all, even under the hot-module test config.

use std::sync::mpsc::Sender;
use std::sync::Mutex;

pub struct RawHandle(*mut u8);

// SAFETY: the pointer is only ever dereferenced by the thread that owns
// the handle's session; sending the handle moves that ownership whole.
unsafe impl Send for RawHandle {}

pub fn first_worker(ranks: &[u32]) -> u32 {
    // lint: allow(panic-free): callers validate rank lists at spec time,
    // so an empty list cannot reach this helper.
    *ranks.first().expect("validated non-empty")
}

pub fn publish(state: &Mutex<u64>, tx: &Sender<u64>) {
    let snapshot = {
        let Ok(guard) = state.lock() else { return };
        *guard
    };
    let _ = tx.send(snapshot);
}
