//! Fixture: an unbounded `mpsc::channel()` constructed on a protocol
//! hot path — a slow consumer would let the queue grow without limit
//! instead of exerting backpressure.  The import line is inert (no call
//! parens); only the construction trips the rule.

use std::sync::mpsc::{channel, Receiver, Sender};

pub fn build_queue() -> (Sender<u32>, Receiver<u32>) {
    channel::<u32>()
}
