//! Fixture: an `unsafe` impl with no adjacent `// SAFETY:` argument.
//! Must trigger exactly `safety-comment`.

pub struct RawHandle(*mut u8);

unsafe impl Send for RawHandle {}
