//! Fixture: a bare panic path in (what the test config treats as) a
//! protocol hot module.  Must trigger exactly `panic-free`.

pub fn first_worker(ranks: &[u32]) -> u32 {
    *ranks.first().unwrap()
}
