//! Fixture: an audited error enum with a variant nothing constructs or
//! matches (the test config audits `GhostError`).  Must trigger exactly
//! `error-variant-liveness`.

#[derive(Debug)]
pub enum GhostError {
    Vanished(String),
}
