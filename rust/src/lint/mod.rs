//! `sfw lint` — the repo-native static-analysis pass.
//!
//! The paper's central claim (asynchronous SFW keeps the vanilla rate
//! while tolerating stragglers) rests on the master/worker protocols
//! never wedging or panicking under adversarial timing.  The chaos
//! conformance suite enforces that *dynamically*; this module is the
//! *static* gate: a dependency-free line/token scanner over `rust/src`
//! that machine-checks the invariants the protocol layer is written
//! against, so regressions fail `scripts/ci.sh` on every container —
//! unlike clippy/rustfmt, which the style pass skips when absent.
//!
//! Run it as `cargo run --release -- lint`: prints a human table,
//! writes `bench_out/lint_report.json` (schema `sfw.lint/v1`), and
//! exits nonzero on any violation.
//!
//! # Rules
//!
//! | rule | scope | checks |
//! |------|-------|--------|
//! | `panic-free` | non-test code of the hot modules (`comms`, `coordinator`, `chaos`, `session`, `algo`) | no `.unwrap()` / `.expect(` / `panic!` / `unreachable!` / `todo!` / `unimplemented!` — a master or worker must degrade, never abort |
//! | `safety-comment` | all of `rust/src` | every `unsafe` block/impl has a `// SAFETY:` comment on the same line or within the preceding 6 lines |
//! | `wire-coverage` | all of `rust/src` | every `impl Wire for T` type is named in the wire round-trip property tests (`rust/tests/properties.rs`) |
//! | `no-lock-across-io` | non-test code of the hot modules | no `send(` / `recv(` while a `Mutex` guard bound earlier in the same scope is live (a blocked peer would hold the lock indefinitely) |
//! | `bounded-channel-depth` | non-test code of the hot modules | no unbounded `mpsc::channel()` construction — a protocol queue either uses `sync_channel` with an explicit depth or carries an allow stating the protocol invariant that bounds it |
//! | `error-variant-liveness` | `WireError` / `SessionError` | every variant is both constructed and matched somewhere in `rust/src` + `rust/tests` (`#[from]` / `#[error(transparent)]` count as constructed) |
//! | `bad-allow` | everywhere, including tests | every allow comment names a known rule and carries a reason |
//!
//! # Suppression grammar
//!
//! A finding is suppressed only by an adjacent allow comment with a
//! mandatory reason (the rule name is one of the table above):
//!
//! ```text
//! lint: allow(panic-free): <why this invariant makes the panic unreachable>
//! ```
//!
//! written as a plain `//` comment either trailing the offending line or
//! on its own line(s) directly above it (doc comments are prose and are
//! never parsed as allows).  An allow with an unknown rule name or a
//! missing reason is itself a `bad-allow` violation — it still
//! suppresses its target so the actionable finding is the allow itself,
//! not a duplicate report of what it covers.
//!
//! # Heuristics, honestly
//!
//! The scanner is token-level by design (no syn/proc-macro in the
//! offline crate set) — see [`scan`] for the exact lexing rules.  Known
//! blind spots: `#[cfg(test)]` detection is brace-depth based (an
//! attribute and its `{` must be within the same item header), pattern
//! vs construction classification of `Enum::Variant` looks at `=>`
//! position and a 3-line `matches!` window, and guard tracking keys on
//! `let` + `.lock()` on one line.  Every blind spot fails *loud* (a
//! false violation you annotate) rather than silent (a missed one).

pub mod report;
pub mod scan;

pub use report::LintReport;
pub use scan::{scan_source, scan_test_uses, FileScan};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// The enforced rules.  [`Rule::BadAllow`] is the meta-rule for
/// malformed suppression comments.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    PanicFree,
    SafetyComment,
    WireCoverage,
    NoLockAcrossIo,
    BoundedChannelDepth,
    ErrorVariantLiveness,
    BadAllow,
}

impl Rule {
    pub const ALL: [Rule; 7] = [
        Rule::PanicFree,
        Rule::SafetyComment,
        Rule::WireCoverage,
        Rule::NoLockAcrossIo,
        Rule::BoundedChannelDepth,
        Rule::ErrorVariantLiveness,
        Rule::BadAllow,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Rule::PanicFree => "panic-free",
            Rule::SafetyComment => "safety-comment",
            Rule::WireCoverage => "wire-coverage",
            Rule::NoLockAcrossIo => "no-lock-across-io",
            Rule::BoundedChannelDepth => "bounded-channel-depth",
            Rule::ErrorVariantLiveness => "error-variant-liveness",
            Rule::BadAllow => "bad-allow",
        }
    }

    /// The allow-grammar lookup ([`Rule::BadAllow`] cannot be allowed).
    pub fn from_name(name: &str) -> Option<Rule> {
        Rule::ALL[..6].iter().copied().find(|r| r.name() == name)
    }
}

/// One finding.
#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: Rule,
    pub path: String,
    pub line: usize,
    pub message: String,
}

impl Violation {
    pub fn new(rule: Rule, path: &str, line: usize, message: String) -> Violation {
        Violation { rule, path: path.to_string(), line, message }
    }
}

/// What to scan and how.  [`LintConfig::repo`] is the configuration the
/// `sfw lint` subcommand and CI run; tests build narrower ones to drive
/// single fixtures through single rules.
#[derive(Debug, Clone)]
pub struct LintConfig {
    /// Path substrings marking the protocol/solver hot modules
    /// (`panic-free` and `no-lock-across-io` scope).
    pub hot_modules: Vec<String>,
    /// Enums whose variants the liveness rule audits.
    pub error_enums: Vec<String>,
    /// Path substrings excluded from the walk (the rule fixtures are
    /// deliberate violations).
    pub skip: Vec<String>,
    /// File names (under the tests root) whose content satisfies
    /// `wire-coverage` by naming the implementing type.
    pub property_tests: Vec<String>,
}

impl LintConfig {
    pub fn repo() -> LintConfig {
        LintConfig {
            hot_modules: ["comms", "coordinator", "chaos", "session", "algo"]
                .iter()
                .map(|m| format!("/{m}/"))
                .collect(),
            error_enums: vec!["WireError".to_string(), "SessionError".to_string()],
            skip: vec!["lint/fixtures".to_string()],
            property_tests: vec!["properties.rs".to_string()],
        }
    }

    /// Is `path` inside a hot module?  Matched on `/<module>/` path
    /// segments, with a virtual leading slash so `comms/mod.rs` given
    /// relative to the src root still matches.
    pub fn is_hot(&self, path: &str) -> bool {
        let slashed = format!("/{}", path.replace('\\', "/"));
        self.hot_modules.iter().any(|m| slashed.contains(m.as_str()))
    }
}

/// Aggregated inputs for the cross-file rules.
#[derive(Default)]
pub struct CrossFileInput {
    pub scans: Vec<FileScan>,
    /// Concatenated content of the wire round-trip property tests.
    pub property_text: String,
    /// `Enum::Variant` uses collected from test files.
    pub test_uses: Vec<scan::VariantUse>,
}

/// Evaluate `wire-coverage` and `error-variant-liveness` over every
/// file's facts.
pub fn cross_file_violations(input: &CrossFileInput, paths: &[String]) -> Vec<Violation> {
    let mut out = Vec::new();
    // wire-coverage: the implementing type must be named in the
    // round-trip property tests
    for (scan, path) in input.scans.iter().zip(paths) {
        for (ty, line) in &scan.wire_impls {
            if !input.property_text.contains(ty.as_str()) {
                out.push(Violation::new(
                    Rule::WireCoverage,
                    path,
                    *line,
                    format!("`{ty}` implements Wire but never appears in the round-trip property tests"),
                ));
            }
        }
    }
    // error-variant-liveness: constructed AND matched somewhere
    let mut constructed: HashMap<(String, String), bool> = HashMap::new();
    let mut matched: HashMap<(String, String), bool> = HashMap::new();
    let all_uses = input
        .scans
        .iter()
        .flat_map(|s| s.uses.iter())
        .chain(input.test_uses.iter());
    for u in all_uses {
        let key = (u.enum_name.clone(), u.variant.clone());
        if u.matched {
            matched.insert(key, true);
        } else {
            constructed.insert(key, true);
        }
    }
    for scan in &input.scans {
        for v in &scan.variants {
            if v.allowed {
                continue;
            }
            let key = (v.enum_name.clone(), v.variant.clone());
            let is_constructed =
                v.constructed_via_attr || constructed.contains_key(&key);
            let is_matched = matched.contains_key(&key);
            let missing = match (is_constructed, is_matched) {
                (true, true) => continue,
                (false, true) => "never constructed",
                (true, false) => "never matched",
                (false, false) => "never constructed nor matched",
            };
            out.push(Violation::new(
                Rule::ErrorVariantLiveness,
                &v.path,
                v.line,
                format!("{}::{} is {missing} (dead error surface)", v.enum_name, v.variant),
            ));
        }
    }
    out
}

/// Recursively collect `.rs` files under `root`, sorted for determinism,
/// minus the configured skip list.
fn walk_rs(root: &Path, skip: &[String], out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(root)?.map(|e| e.map(|e| e.path())).collect::<Result<_, _>>()?;
    entries.sort();
    for p in entries {
        let norm = p.to_string_lossy().replace('\\', "/");
        if skip.iter().any(|s| norm.contains(s.as_str())) {
            continue;
        }
        if p.is_dir() {
            walk_rs(&p, skip, out)?;
        } else if norm.ends_with(".rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint the tree: per-file rules over every `.rs` file under
/// `src_root`, cross-file rules fed by the property tests and the
/// variant uses under `tests_root`.
pub fn lint_repo(src_root: &str, tests_root: &str, cfg: &LintConfig) -> std::io::Result<LintReport> {
    let mut files = Vec::new();
    walk_rs(Path::new(src_root), &cfg.skip, &mut files)?;

    let mut input = CrossFileInput::default();
    let mut paths = Vec::new();
    let mut violations = Vec::new();
    let mut suppressed = 0usize;
    for f in &files {
        let src = std::fs::read_to_string(f)?;
        let label = f.to_string_lossy().replace('\\', "/");
        let scan = scan_source(&label, &src, cfg);
        violations.extend(scan.violations.iter().cloned());
        suppressed += scan.suppressed.len();
        paths.push(label);
        input.scans.push(scan);
    }

    let mut test_files = Vec::new();
    if Path::new(tests_root).is_dir() {
        walk_rs(Path::new(tests_root), &cfg.skip, &mut test_files)?;
    }
    for f in &test_files {
        let src = std::fs::read_to_string(f)?;
        let name = f.file_name().map(|n| n.to_string_lossy().to_string()).unwrap_or_default();
        if cfg.property_tests.iter().any(|p| *p == name) {
            input.property_text.push_str(&src);
        }
        input.test_uses.extend(scan_test_uses(&src, cfg));
    }

    violations.extend(cross_file_violations(&input, &paths));
    violations.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    Ok(LintReport {
        files_scanned: files.len() + test_files.len(),
        suppressed,
        violations,
    })
}
