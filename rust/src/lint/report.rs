//! Lint output: the human table `sfw lint` prints and the
//! machine-readable `bench_out/lint_report.json` artifact (schema
//! `sfw.lint/v1`) the CI lint job uploads.

use crate::lint::{Rule, Violation};
use crate::util::json::Json;

/// The result of one lint run over the tree.
pub struct LintReport {
    /// `.rs` files scanned (src + tests, minus the fixture skip list).
    pub files_scanned: usize,
    /// Findings suppressed by allow comments.
    pub suppressed: usize,
    /// Findings that survive suppression, sorted by (path, line).
    pub violations: Vec<Violation>,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    fn count(&self, rule: Rule) -> usize {
        self.violations.iter().filter(|v| v.rule == rule).count()
    }

    /// The human table: per-rule counts, then every finding with its
    /// clickable `path:line` location.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str("rule                      violations\n");
        for rule in Rule::ALL {
            out.push_str(&format!("{:<25} {}\n", rule.name(), self.count(rule)));
        }
        if !self.violations.is_empty() {
            out.push('\n');
            for v in &self.violations {
                out.push_str(&format!(
                    "{}:{}  [{}]  {}\n",
                    v.path,
                    v.line,
                    v.rule.name(),
                    v.message
                ));
            }
        }
        out.push_str(&format!(
            "\n{} file(s) scanned, {} finding(s) suppressed by allows, {} violation(s)\n",
            self.files_scanned,
            self.suppressed,
            self.violations.len()
        ));
        out
    }

    pub fn to_json(&self) -> Json {
        let rules = Rule::ALL
            .iter()
            .map(|r| (r.name().to_string(), Json::Num(self.count(*r) as f64)))
            .collect();
        let violations = self
            .violations
            .iter()
            .map(|v| {
                Json::Obj(vec![
                    ("rule".to_string(), Json::Str(v.rule.name().to_string())),
                    ("path".to_string(), Json::Str(v.path.clone())),
                    ("line".to_string(), Json::Num(v.line as f64)),
                    ("message".to_string(), Json::Str(v.message.clone())),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("schema".to_string(), Json::Str("sfw.lint/v1".to_string())),
            ("files_scanned".to_string(), Json::Num(self.files_scanned as f64)),
            ("suppressed".to_string(), Json::Num(self.suppressed as f64)),
            ("counts".to_string(), Json::Obj(rules)),
            ("violations".to_string(), Json::Arr(violations)),
        ])
    }

    /// Write the JSON artifact (creates parent dirs).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut text = self.to_json().render();
        text.push('\n');
        std::fs::write(path, text)
    }
}
