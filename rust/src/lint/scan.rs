//! The line scanner behind [`sfw::lint`](crate::lint): splits each
//! source line into code and comment text (string/char literals, raw
//! strings, block comments and multi-line strings are tracked across
//! lines), gates out `#[cfg(test)]` items by brace depth, parses
//! `// lint: allow(<rule>): <reason>` comments, and evaluates the
//! per-file rules while collecting the cross-file facts (`Wire` impls,
//! error-enum variant declarations and uses).
//!
//! The scanner is deliberately token-level, not a parser: every rule it
//! enforces keys on constructs this repo writes one way (see the rule
//! table in the [module docs](crate::lint)).  Where a heuristic has a
//! known blind spot it is documented on the rule that uses it.

use crate::lint::{LintConfig, Rule, Violation};

/// How many preceding lines may separate a `// SAFETY:` comment from its
/// `unsafe` token (comment blocks and split statements both fit).
const SAFETY_WINDOW: usize = 6;

/// How many preceding lines count as "inside a `matches!` context" when
/// classifying an `Enum::Variant` occurrence as a pattern (multi-line
/// `assert!(matches!(...))` calls put the pattern 1–3 lines below the
/// macro name).
const MATCH_WINDOW: usize = 3;

/// Everything the scanner learned about one file.
#[derive(Debug, Default)]
pub struct FileScan {
    pub violations: Vec<Violation>,
    /// `(line, rule)` of findings suppressed by an allow comment.
    pub suppressed: Vec<(usize, Rule)>,
    /// `(type name, line)` of every un-allowed `impl Wire for <type>`
    /// outside tests.
    pub wire_impls: Vec<(String, usize)>,
    /// Variant declarations of the configured error enums.
    pub variants: Vec<VariantDecl>,
    /// `Enum::Variant` occurrences (patterns and constructions).
    pub uses: Vec<VariantUse>,
}

#[derive(Debug)]
pub struct VariantDecl {
    pub enum_name: String,
    pub variant: String,
    pub path: String,
    pub line: usize,
    /// `#[from]` / `#[error(transparent)]` conversions construct the
    /// variant implicitly.
    pub constructed_via_attr: bool,
    /// An allow at the declaration line suppresses the liveness rule.
    pub allowed: bool,
}

#[derive(Debug)]
pub struct VariantUse {
    pub enum_name: String,
    pub variant: String,
    /// true = pattern position (match arm, `matches!`, `if let`),
    /// false = construction.
    pub matched: bool,
}

/// Lexer state carried across lines.
enum Mode {
    Normal,
    /// Inside a `"..."` string literal (may span lines).
    Str,
    /// Inside a raw string; payload is the `#` count of the delimiter.
    RawStr(usize),
    /// Inside nested `/* ... */` comments; payload is the nesting depth.
    Block(usize),
}

/// One parsed allow comment.
struct Allow {
    rule: Option<Rule>,
    reason_ok: bool,
    raw_rule: String,
    line: usize,
}

/// Split one line into (code, comment, is_doc_comment) under the carried
/// lexer `mode`.  Comment text covers `//` line comments and `/* */`
/// contents; string-literal contents are dropped from both so quoted
/// braces and rule-token spellings are inert.
fn split_line(line: &str, mode: &mut Mode) -> (String, String, bool) {
    let mut code = String::new();
    let mut comment = String::new();
    let mut is_doc = false;
    let chars: Vec<char> = line.chars().collect();
    let n = chars.len();
    let mut i = 0;
    while i < n {
        match mode {
            Mode::Str => {
                if chars[i] == '\\' {
                    i += 2;
                } else {
                    if chars[i] == '"' {
                        *mode = Mode::Normal;
                        code.push('"');
                    }
                    i += 1;
                }
                continue;
            }
            Mode::RawStr(hashes) => {
                if chars[i] == '"'
                    && chars[i + 1..].iter().take_while(|c| **c == '#').count() >= *hashes
                {
                    let h = *hashes;
                    *mode = Mode::Normal;
                    code.push('"');
                    i += 1 + h;
                } else {
                    i += 1;
                }
                continue;
            }
            Mode::Block(depth) => {
                if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    *depth -= 1;
                    if *depth == 0 {
                        *mode = Mode::Normal;
                    }
                    i += 2;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    *depth += 1;
                    i += 2;
                } else {
                    comment.push(chars[i]);
                    i += 1;
                }
                continue;
            }
            Mode::Normal => {}
        }
        let c = chars[i];
        match c {
            '"' => {
                code.push('"');
                *mode = Mode::Str;
                i += 1;
            }
            'r' if i + 1 < n && (chars[i + 1] == '"' || chars[i + 1] == '#') => {
                // raw string candidate: r", r#", r##"...
                let hashes = chars[i + 1..].iter().take_while(|c| **c == '#').count();
                if i + 1 + hashes < n && chars[i + 1 + hashes] == '"' {
                    code.push('"');
                    *mode = Mode::RawStr(hashes);
                    i += 2 + hashes;
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            '\'' => {
                // char literal vs lifetime: a lifetime has no closing quote
                if i + 1 < n && chars[i + 1] == '\\' {
                    let mut j = i + 2;
                    while j < n && chars[j] != '\'' {
                        j += 1;
                    }
                    i = j + 1;
                } else if i + 2 < n && chars[i + 2] == '\'' {
                    i += 3; // plain char literal like '{'
                } else {
                    code.push(c); // lifetime; keep scanning normally
                    i += 1;
                }
            }
            '/' if i + 1 < n && chars[i + 1] == '/' => {
                is_doc = matches!(chars.get(i + 2), Some('/') | Some('!'));
                comment.extend(&chars[i..]);
                break;
            }
            '/' if i + 1 < n && chars[i + 1] == '*' => {
                *mode = Mode::Block(1);
                i += 2;
            }
            _ => {
                code.push(c);
                i += 1;
            }
        }
    }
    (code, comment, is_doc)
}

/// Panic-path tokens the panic-free rule rejects.  `.unwrap_or*` /
/// `.expect_err` do not match: `.unwrap()` requires the closing paren
/// and `.expect(` the opening one right after the name.
const PANIC_TOKENS: &[&str] = &[
    ".unwrap()",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
    "Option::unwrap",
    "Result::unwrap",
];

fn boundary_before(code: &str, at: usize) -> bool {
    at == 0
        || !code[..at]
            .chars()
            .next_back()
            .is_some_and(|p| p.is_alphanumeric() || p == '_')
}

/// Find a panic token in stripped code, honoring the word boundary on
/// the left (so an identifier like `dont_panic` is inert).
fn find_panic_token(code: &str) -> Option<&'static str> {
    for tok in PANIC_TOKENS {
        let mut from = 0;
        while let Some(pos) = code[from..].find(tok) {
            let at = from + pos;
            if boundary_before(code, at) {
                return Some(tok);
            }
            from = at + tok.len();
        }
    }
    None
}

/// Find an unbounded-channel constructor in stripped code: a bare
/// `channel()` / `channel::<T>()` call.  The left word boundary keeps
/// `sync_channel(` (the bounded constructor) inert, and requiring the
/// `(` / `::<` right after the name keeps `use ...::{channel, ...}`
/// imports and prose mentions inert.
fn finds_unbounded_channel(code: &str) -> bool {
    for tok in ["channel()", "channel::<"] {
        let mut from = 0;
        while let Some(pos) = code[from..].find(tok) {
            let at = from + pos;
            if boundary_before(code, at) {
                return true;
            }
            from = at + tok.len();
        }
    }
    false
}

/// True when `word` occurs in `code` delimited by non-identifier chars.
fn has_word(code: &str, word: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let at = from + pos;
        let after = code[at + word.len()..].chars().next();
        if boundary_before(code, at) && !after.is_some_and(|p| p.is_alphanumeric() || p == '_') {
            return true;
        }
        from = at + word.len();
    }
    false
}

/// Parse every `lint: allow(<rule>): <reason>` occurrence in a comment.
fn parse_allows(comment: &str, line: usize) -> Vec<Allow> {
    let marker = "lint: allow(";
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = comment[from..].find(marker) {
        let start = from + pos + marker.len();
        let rest = &comment[start..];
        let Some(close) = rest.find(')') else {
            from = start;
            continue;
        };
        let raw_rule = rest[..close].trim().to_string();
        let after = rest[close + 1..].trim_start();
        let reason_ok = after.strip_prefix(':').is_some_and(|r| !r.trim().is_empty());
        out.push(Allow { rule: Rule::from_name(&raw_rule), reason_ok, raw_rule, line });
        from = start + close;
    }
    out
}

/// Collect `Enum::Variant` occurrences from one stripped code line,
/// classifying pattern position vs construction.  Left of a `=>` (or
/// inside a `matches!` / `if let` / `while let` context, looking back
/// [`MATCH_WINDOW`] lines for multi-line `matches!` calls) is a
/// pattern; anything else is a construction.
fn collect_uses(
    code: &str,
    code_history: &[String],
    cfg: &LintConfig,
    uses: &mut Vec<VariantUse>,
) {
    for name in &cfg.error_enums {
        let needle = format!("{name}::");
        let mut from = 0;
        while let Some(pos) = code[from..].find(needle.as_str()) {
            let at = from + pos;
            let variant: String = code[at + needle.len()..]
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            from = at + needle.len();
            if variant.is_empty() {
                continue;
            }
            let matched = match code.find("=>") {
                Some(arrow) => at < arrow,
                None => {
                    code.contains("matches!")
                        || code.contains("if let")
                        || code.contains("while let")
                        || code_history
                            .iter()
                            .rev()
                            .take(MATCH_WINDOW)
                            .any(|c| c.contains("matches!("))
                }
            };
            uses.push(VariantUse { enum_name: name.clone(), variant, matched });
        }
    }
}

/// Scan one file's source text.  `path` is used for labels and for the
/// hot-module decision.
pub fn scan_source(path: &str, src: &str, cfg: &LintConfig) -> FileScan {
    let hot = cfg.is_hot(path);
    let mut scan = FileScan::default();
    let mut mode = Mode::Normal;

    // brace-depth bookkeeping
    let mut depth: i64 = 0;
    let mut test_gates: Vec<i64> = Vec::new(); // depths of #[cfg(test)] items
    let mut pending_cfg_test = false;

    // allows on comment-only lines apply to the next code line; allows
    // with trailing code apply to their own line
    let mut pending_allows: Vec<Allow> = Vec::new();

    // mutex-guard scopes for no-lock-across-io
    let mut guard_depths: Vec<i64> = Vec::new();

    // enum-body bookkeeping for error-variant-liveness
    let mut in_enum: Option<(String, i64)> = None;
    let mut pending_from_attr = false;

    // lookback windows for SAFETY comments and multi-line matches!
    let mut comment_history: Vec<String> = Vec::new();
    let mut code_history: Vec<String> = Vec::new();

    for (idx, raw_line) in src.lines().enumerate() {
        let line_no = idx + 1;
        let (code, comment, is_doc) = split_line(raw_line, &mut mode);
        let code_is_empty = code.trim().is_empty();
        let in_test = !test_gates.is_empty();

        // ---- allow comments (plain //, not doc prose) ---------------
        if !is_doc {
            pending_allows.extend(parse_allows(&comment, line_no));
        }
        let active: Vec<Allow> =
            if code_is_empty { Vec::new() } else { std::mem::take(&mut pending_allows) };
        // a malformed allow is itself a violation, even in test code —
        // the grammar is the contract the whole tool hangs off
        for a in &active {
            if a.rule.is_none() {
                scan.violations.push(Violation::new(
                    Rule::BadAllow,
                    path,
                    a.line,
                    format!("unknown lint rule '{}' in allow comment", a.raw_rule),
                ));
            } else if !a.reason_ok {
                scan.violations.push(Violation::new(
                    Rule::BadAllow,
                    path,
                    a.line,
                    format!("allow({}) is missing its mandatory ': <reason>'", a.raw_rule),
                ));
            }
        }
        // even a reason-less allow suppresses its rule: the bad-allow
        // violation above already fails the run, and double-reporting
        // the suppressed finding would obscure the actual fix (add the
        // reason or remove the allow)
        let allowed = |rule: Rule, scan: &mut FileScan| -> bool {
            let hit = active.iter().any(|a| a.rule == Some(rule));
            if hit {
                scan.suppressed.push((line_no, rule));
            }
            hit
        };

        // ---- cfg(test) gating ---------------------------------------
        if code.contains("#[cfg(test)]") {
            pending_cfg_test = true;
        }
        if pending_cfg_test && code.contains('{') {
            test_gates.push(depth);
            pending_cfg_test = false;
        }

        // ---- per-file rules (non-test code only) --------------------
        if !in_test && !code_is_empty {
            if hot {
                if let Some(tok) = find_panic_token(&code) {
                    if !allowed(Rule::PanicFree, &mut scan) {
                        scan.violations.push(Violation::new(
                            Rule::PanicFree,
                            path,
                            line_no,
                            format!("`{tok}` on a non-test path of a protocol hot module"),
                        ));
                    }
                }
                // no-lock-across-io: a guard bound on an earlier line of
                // this scope is still live when send(/recv( runs
                if !guard_depths.is_empty()
                    && (code.contains(".send(") || code.contains(".recv("))
                    && !allowed(Rule::NoLockAcrossIo, &mut scan)
                {
                    scan.violations.push(Violation::new(
                        Rule::NoLockAcrossIo,
                        path,
                        line_no,
                        "send/recv while a Mutex guard bound in this scope is live".to_string(),
                    ));
                }
                if finds_unbounded_channel(&code)
                    && !allowed(Rule::BoundedChannelDepth, &mut scan)
                {
                    scan.violations.push(Violation::new(
                        Rule::BoundedChannelDepth,
                        path,
                        line_no,
                        "unbounded `mpsc::channel()` on a protocol path; use `sync_channel` \
                         with an explicit depth or allow with the invariant that bounds it"
                            .to_string(),
                    ));
                }
            }
            if has_word(&code, "unsafe") {
                let nearby = comment.contains("SAFETY:")
                    || comment_history
                        .iter()
                        .rev()
                        .take(SAFETY_WINDOW)
                        .any(|c| c.contains("SAFETY:"));
                if !nearby && !allowed(Rule::SafetyComment, &mut scan) {
                    scan.violations.push(Violation::new(
                        Rule::SafetyComment,
                        path,
                        line_no,
                        "`unsafe` without an adjacent `// SAFETY:` justification".to_string(),
                    ));
                }
            }
        }

        // ---- cross-file facts ---------------------------------------
        if !in_test && !code_is_empty {
            if let Some(rest) = code.split("impl Wire for ").nth(1) {
                let ty: String = rest
                    .trim_start()
                    .chars()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect();
                if !ty.is_empty() && !allowed(Rule::WireCoverage, &mut scan) {
                    scan.wire_impls.push((ty, line_no));
                }
            }
            if in_enum.is_none() {
                for name in &cfg.error_enums {
                    if has_word(&code, "enum") && has_word(&code, name) && code.contains('{') {
                        in_enum = Some((name.clone(), depth));
                        pending_from_attr = false;
                    }
                }
            }
            if let Some((enum_name, enum_depth)) = &in_enum {
                // variant lines sit exactly one level inside the body
                let trimmed = code.trim();
                if depth == *enum_depth + 1 && !trimmed.starts_with('{') {
                    if trimmed.starts_with('#') {
                        if code.contains("#[from]") || code.contains("transparent") {
                            pending_from_attr = true;
                        }
                    } else {
                        let ident: String = trimmed
                            .chars()
                            .take_while(|c| c.is_alphanumeric() || *c == '_')
                            .collect();
                        if ident.chars().next().is_some_and(|c| c.is_uppercase()) {
                            let via_attr = pending_from_attr
                                || code.contains("#[from]")
                                || code.contains("transparent");
                            let lv_allowed = allowed(Rule::ErrorVariantLiveness, &mut scan);
                            scan.variants.push(VariantDecl {
                                enum_name: enum_name.clone(),
                                variant: ident,
                                path: path.to_string(),
                                line: line_no,
                                constructed_via_attr: via_attr,
                                allowed: lv_allowed,
                            });
                            pending_from_attr = false;
                        }
                    }
                }
            }
        }
        collect_uses(&code, &code_history, cfg, &mut scan.uses);

        // ---- depth bookkeeping (after rule evaluation) --------------
        // guards bound on this line live at the depth the line STARTS at
        if code.contains("let ") && code.contains(".lock()") {
            guard_depths.push(depth);
        }
        depth += code.matches('{').count() as i64 - code.matches('}').count() as i64;
        while test_gates.last().is_some_and(|g| depth <= *g) {
            test_gates.pop();
        }
        // a guard bound at depth g dies when its enclosing block closes
        // (depth drops below g); this over-approximates guards that are
        // really statement temporaries, which fails loud, not silent
        while guard_depths.last().is_some_and(|g| depth < *g) {
            guard_depths.pop();
        }
        if in_enum.as_ref().is_some_and(|(_, d)| depth <= *d) {
            in_enum = None;
        }

        // doc comments are prose (they may *mention* SAFETY:); only
        // plain // comments count for the SAFETY lookback
        comment_history.push(if is_doc { String::new() } else { comment });
        code_history.push(code);
    }
    scan
}

/// Collect `Enum::Variant` uses from a test file.  Tests are exempt from
/// the per-file rules, but they count for error-variant liveness (a
/// variant matched only by a conformance test is still matched).
pub fn scan_test_uses(src: &str, cfg: &LintConfig) -> Vec<VariantUse> {
    let mut mode = Mode::Normal;
    let mut uses = Vec::new();
    let mut code_history: Vec<String> = Vec::new();
    for line in src.lines() {
        let (code, _, _) = split_line(line, &mut mode);
        collect_uses(&code, &code_history, cfg, &mut uses);
        code_history.push(code);
    }
    uses
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LintConfig {
        LintConfig::repo()
    }

    #[test]
    fn strings_and_comments_are_inert() {
        let src = r#"
fn f() {
    let s = "contains .unwrap() and panic! and unsafe";
    println!("{s}");
}
"#;
        let scan = scan_source("rust/src/comms/x.rs", src, &cfg());
        assert!(scan.violations.is_empty(), "{:?}", scan.violations);
    }

    #[test]
    fn cfg_test_blocks_are_exempt() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap() }\n}\n";
        let scan = scan_source("rust/src/comms/x.rs", src, &cfg());
        assert!(scan.violations.is_empty(), "{:?}", scan.violations);
    }

    #[test]
    fn panic_token_in_hot_module_is_flagged_and_allow_suppresses() {
        let bad = "fn f() { x.unwrap(); }\n";
        let scan = scan_source("rust/src/comms/x.rs", bad, &cfg());
        assert_eq!(scan.violations.len(), 1);
        assert_eq!(scan.violations[0].rule, Rule::PanicFree);
        let ok = "// lint: allow(panic-free): invariant documented here\nfn f() { x.unwrap(); }\n";
        let scan = scan_source("rust/src/comms/x.rs", ok, &cfg());
        assert!(scan.violations.is_empty(), "{:?}", scan.violations);
        assert_eq!(scan.suppressed.len(), 1);
    }

    #[test]
    fn cold_modules_skip_panic_free_but_not_safety() {
        let src = "fn f() { x.unwrap(); }\nunsafe impl Send for X {}\n";
        let scan = scan_source("rust/src/runtime/x.rs", src, &cfg());
        assert_eq!(scan.violations.len(), 1);
        assert_eq!(scan.violations[0].rule, Rule::SafetyComment);
    }

    #[test]
    fn missing_allow_reason_is_a_violation_but_still_suppresses() {
        let src = "// lint: allow(panic-free)\nfn f() { x.unwrap(); }\n";
        let scan = scan_source("rust/src/comms/x.rs", src, &cfg());
        assert_eq!(scan.violations.len(), 1, "{:?}", scan.violations);
        assert_eq!(scan.violations[0].rule, Rule::BadAllow);
    }

    #[test]
    fn unbounded_channel_is_flagged_only_in_hot_modules_and_sync_channel_is_inert() {
        let bad = "fn f() { let (tx, rx) = channel::<u32>(); }\n";
        let scan = scan_source("rust/src/comms/x.rs", bad, &cfg());
        assert_eq!(scan.violations.len(), 1, "{:?}", scan.violations);
        assert_eq!(scan.violations[0].rule, Rule::BoundedChannelDepth);
        // cold module: same construction is fine
        let scan = scan_source("rust/src/runtime/x.rs", bad, &cfg());
        assert!(scan.violations.is_empty(), "{:?}", scan.violations);
        // bounded constructor and bare import are inert even in hot code
        let ok = "use std::sync::mpsc::{channel, sync_channel};\n\
                  fn f() { let (tx, rx) = sync_channel::<u32>(8); }\n";
        let scan = scan_source("rust/src/comms/x.rs", ok, &cfg());
        assert!(scan.violations.is_empty(), "{:?}", scan.violations);
        // an allow with a reason suppresses and registers
        let allowed =
            "// lint: allow(bounded-channel-depth): depth <= W by protocol\n\
             fn f() { let (tx, rx) = channel::<u32>(); }\n";
        let scan = scan_source("rust/src/comms/x.rs", allowed, &cfg());
        assert!(scan.violations.is_empty(), "{:?}", scan.violations);
        assert_eq!(scan.suppressed.len(), 1);
    }

    #[test]
    fn multiline_matches_context_classifies_patterns() {
        let src = "fn t() {\n    assert!(matches!(\n        err,\n        SessionError::Comms(_)\n    ));\n}\n";
        let uses = scan_test_uses(src, &cfg());
        assert_eq!(uses.len(), 1);
        assert!(uses[0].matched);
    }
}
