//! `PjrtEngine`: the production `StepEngine` that executes the AOT
//! JAX/Pallas artifacts via PJRT (one fused gradient->LMO module call per
//! worker step — Python-free request path).
//!
//! Minibatches are gathered into a contiguous padded buffer for the
//! smallest artifact bucket that fits; padding rows are all-zero (with
//! y = 0), which the kernels treat as exact no-ops because every module
//! returns SUM gradients/losses (see python/compile/kernels/ref.py).

use std::sync::Arc;

use crate::algo::engine::{StepEngine, StepOut};
use crate::linalg::{Mat, Svd1};
use crate::objective::{MatrixSensing, Objective, Pnn, SparseCompletion};
use crate::runtime::{literal_f32, PjrtRuntime};
use crate::util::rng::Rng;

/// Which workload family the engine drives (decides artifact names and
/// row-gather layout).  `Sparse` has no AOT artifacts — its O(nnz) hot
/// path is native-only, and the session wiring rejects `engine=pjrt`
/// for it before a `PjrtEngine` is ever built — so the artifact-layout
/// accessors below panic on it rather than invent a dense gather.
#[derive(Clone)]
pub enum Workload {
    Ms(Arc<MatrixSensing>),
    Pnn(Arc<Pnn>),
    Sparse(Arc<SparseCompletion>),
}

impl Workload {
    /// The objective behind the workload (shared by the session wiring).
    pub fn objective(&self) -> Arc<dyn Objective> {
        match self {
            Workload::Ms(o) => o.clone(),
            Workload::Pnn(o) => o.clone(),
            Workload::Sparse(o) => o.clone(),
        }
    }

    fn feature_row(&self, i: usize) -> &[f32] {
        match self {
            Workload::Ms(o) => o.data.af.row(i),
            Workload::Pnn(o) => o.data.a.row(i),
            Workload::Sparse(_) => panic!("sparse completion has no AOT artifacts"),
        }
    }

    fn label(&self, i: usize) -> f32 {
        match self {
            Workload::Ms(o) => o.data.y[i],
            Workload::Pnn(o) => o.data.y[i],
            Workload::Sparse(_) => panic!("sparse completion has no AOT artifacts"),
        }
    }

    fn prefix(&self) -> &'static str {
        match self {
            Workload::Ms(_) => "ms",
            Workload::Pnn(_) => "pnn",
            Workload::Sparse(_) => panic!("sparse completion has no AOT artifacts"),
        }
    }

    fn row_len(&self) -> usize {
        match self {
            Workload::Ms(o) => o.data.d1 * o.data.d2,
            Workload::Pnn(o) => o.data.d,
            Workload::Sparse(_) => panic!("sparse completion has no AOT artifacts"),
        }
    }
}

pub struct PjrtEngine {
    rt: Arc<PjrtRuntime>,
    workload: Workload,
    obj: Arc<dyn Objective>,
    rng: Rng,
    /// Reused gather buffers (allocation-free hot path after warmup).
    feat_buf: Vec<f32>,
    y_buf: Vec<f32>,
    bucket_key: String,
    /// Device-resident (padded) dataset for the gather-based `*_stepi_*`
    /// modules: uploaded once, reused every step.  `None` until the first
    /// step; falls back to the upload-per-call path when the dataset
    /// exceeds the artifact's baked `*_n_max`.
    resident: Option<(xla::PjRtBuffer, xla::PjRtBuffer)>,
    use_resident: bool,
    idx_buf: Vec<i32>,
    /// Dense render buffer for the `step_it`/`grad_sum_it` fallbacks
    /// (the AOT artifacts take dense inputs, so a factored run densifies
    /// EVERY step) — cached here so the per-step O(d1 * d2) allocation
    /// happens once, not per iteration.
    dense_scratch: Mat,
}

// SAFETY: PJRT buffers/executables are thread-safe per the PJRT C API
// contract (jax drives TfrtCpuClient concurrently from many threads); the
// `xla` wrappers are !Send only because they hold raw pointers.  Each
// engine is owned by exactly one worker thread.
unsafe impl Send for PjrtEngine {}

impl PjrtEngine {
    pub fn new(rt: Arc<PjrtRuntime>, workload: Workload, seed: u64) -> Self {
        let obj = workload.objective();
        let bucket_key = format!("{}_buckets", workload.prefix());
        PjrtEngine {
            rt,
            workload,
            obj,
            rng: Rng::new(seed),
            feat_buf: Vec::new(),
            y_buf: Vec::new(),
            bucket_key,
            resident: None,
            use_resident: true,
            idx_buf: Vec::new(),
            dense_scratch: Mat::zeros(0, 0),
        }
    }

    /// Disable the device-resident gather path (upload the batch per call).
    pub fn without_resident_dataset(mut self) -> Self {
        self.use_resident = false;
        self
    }

    /// Upload the padded dataset once: N_max + 1 rows, last row zero
    /// (the padding target for idx), y = 0 there.
    fn ensure_resident(&mut self) -> Option<()> {
        if self.resident.is_some() {
            return Some(());
        }
        if !self.use_resident {
            return None;
        }
        let n_max_key = format!("{}_n_max", self.workload.prefix());
        let n_max = self.rt.manifest().param_usize(&n_max_key).ok()?;
        let n = self.obj.n();
        if n > n_max {
            self.use_resident = false; // dataset too big for the artifact
            return None;
        }
        let k = self.workload.row_len();
        let mut feats = vec![0.0f32; (n_max + 1) * k];
        let mut ys = vec![0.0f32; n_max + 1];
        for i in 0..n {
            feats[i * k..(i + 1) * k].copy_from_slice(self.workload.feature_row(i));
            ys[i] = self.workload.label(i);
        }
        let fb = self.rt.upload_f32(&feats, &[n_max + 1, k]).ok()?;
        let yb = self.rt.upload_f32(&ys, &[n_max + 1]).ok()?;
        self.resident = Some((fb, yb));
        Some(())
    }

    /// Gather-free step through the `*_stepi_*` module (device-resident
    /// dataset; per-call upload = idx + x + v0, a few KB).
    fn step_resident(&mut self, x: &Mat, idx: &[usize]) -> Option<StepOut> {
        self.ensure_resident()?;
        let b = self.rt.manifest().bucket_for(&self.bucket_key, idx.len()).ok()?;
        if idx.len() > b {
            return None;
        }
        let n_max_key = format!("{}_n_max", self.workload.prefix());
        let pad_row = self.rt.manifest().param_usize(&n_max_key).ok()? as i32;
        self.idx_buf.clear();
        self.idx_buf.extend(idx.iter().map(|&i| i as i32));
        self.idx_buf.resize(b, pad_row);
        let (_, d2) = self.x_dims();
        let v0 = self.rng.unit_vector(d2);
        let name = format!("{}_stepi_m{}", self.workload.prefix(), b);
        let idx_b = self.rt.upload_i32(&self.idx_buf, &[b]).ok()?;
        let x_dims: Vec<usize> = match &self.workload {
            Workload::Ms(_) => vec![x.rows * x.cols],
            Workload::Pnn(_) => vec![x.rows, x.cols],
            Workload::Sparse(_) => panic!("sparse completion has no AOT artifacts"),
        };
        let x_b = self.rt.upload_f32(&x.data, &x_dims).ok()?;
        let v0_b = self.rt.upload_f32(&v0, &[d2]).ok()?;
        let (fb, yb) = self.resident.as_ref().unwrap();
        let out = self
            .rt
            .run_f32_buffers(&name, &[fb, yb, &idx_b, &x_b, &v0_b])
            .ok()?;
        debug_assert_eq!(out.len(), 4);
        Some(StepOut {
            u: out[0].clone(),
            v: out[1].clone(),
            sigma: out[2][0],
            loss_sum: out[3][0] as f64,
            m: idx.len(),
            // The AOT artifacts return (u, v, sigma, loss) only — no
            // <G, X> comes back, so there is no gap estimate.  NaN means
            // exactly that to every consumer: --tol never fires (the
            // stop guards on is_finite) and the step policies fall back
            // to their gradient-free fits.
            gap: f64::NAN,
        })
    }

    /// Gather + zero-pad the minibatch rows into the reused buffers;
    /// returns the bucket size used.
    fn gather(&mut self, idx: &[usize]) -> usize {
        let b = self
            .rt
            .manifest()
            .bucket_for(&self.bucket_key, idx.len())
            .expect("manifest buckets");
        assert!(
            idx.len() <= b,
            "batch {} exceeds largest artifact bucket {b}; cap the schedule",
            idx.len()
        );
        let k = self.workload.row_len();
        self.feat_buf.clear();
        self.feat_buf.resize(b * k, 0.0);
        self.y_buf.clear();
        self.y_buf.resize(b, 0.0);
        for (slot, &i) in idx.iter().enumerate() {
            self.feat_buf[slot * k..(slot + 1) * k].copy_from_slice(self.workload.feature_row(i));
            self.y_buf[slot] = self.workload.label(i);
        }
        b
    }

    fn x_dims(&self) -> (usize, usize) {
        self.obj.dims()
    }

    /// Flatten X in the layout each module family expects: MS modules take
    /// vec(X) (K,), PNN modules take X (D, D).
    fn x_literal(&self, x: &Mat) -> anyhow::Result<xla::Literal> {
        match &self.workload {
            Workload::Ms(_) => literal_f32(&x.data, &[(x.rows * x.cols) as i64]),
            Workload::Pnn(_) => literal_f32(&x.data, &[x.rows as i64, x.cols as i64]),
            Workload::Sparse(_) => panic!("sparse completion has no AOT artifacts"),
        }
    }
}

impl StepEngine for PjrtEngine {
    fn step(&mut self, x: &Mat, idx: &[usize]) -> StepOut {
        // fast path: device-resident dataset + i32 index upload
        if let Some(out) = self.step_resident(x, idx) {
            return out;
        }
        let b = self.gather(idx);
        let k = self.workload.row_len();
        let (_, d2) = self.x_dims();
        let v0 = self.rng.unit_vector(d2);
        let name = format!("{}_step_m{}", self.workload.prefix(), b);
        let feats = literal_f32(&self.feat_buf, &[b as i64, k as i64]).expect("feat literal");
        let y = literal_f32(&self.y_buf, &[b as i64]).expect("y literal");
        let xl = self.x_literal(x).expect("x literal");
        let v0l = literal_f32(&v0, &[d2 as i64]).expect("v0 literal");
        let out = self
            .rt
            .run_f32(&name, &[feats, y, xl, v0l])
            .unwrap_or_else(|e| panic!("PJRT {name}: {e}"));
        debug_assert_eq!(out.len(), 4, "{name} must return (u, v, sigma, loss)");
        StepOut {
            u: out[0].clone(),
            v: out[1].clone(),
            sigma: out[2][0],
            loss_sum: out[3][0] as f64,
            m: idx.len(),
            gap: f64::NAN, // see step_resident: the artifacts ship no <G, X>
        }
    }

    fn grad_sum(&mut self, x: &Mat, idx: &[usize], out: &mut Mat) -> f64 {
        let b = self.gather(idx);
        let k = self.workload.row_len();
        let name = format!("{}_grad_m{}", self.workload.prefix(), b);
        let feats = literal_f32(&self.feat_buf, &[b as i64, k as i64]).expect("feat literal");
        let y = literal_f32(&self.y_buf, &[b as i64]).expect("y literal");
        let xl = self.x_literal(x).expect("x literal");
        let res = self
            .rt
            .run_f32(&name, &[feats, y, xl])
            .unwrap_or_else(|e| panic!("PJRT {name}: {e}"));
        debug_assert_eq!(res.len(), 2);
        out.data.copy_from_slice(&res[0]);
        res[1][0] as f64
    }

    fn lmo(&mut self, g: &Mat) -> Svd1 {
        let name = format!("lmo_{}", self.workload.prefix());
        let v0 = self.rng.unit_vector(g.cols);
        let gl = literal_f32(&g.data, &[g.rows as i64, g.cols as i64]).expect("g literal");
        let v0l = literal_f32(&v0, &[g.cols as i64]).expect("v0 literal");
        let out = self
            .rt
            .run_f32(&name, &[gl, v0l])
            .unwrap_or_else(|e| panic!("PJRT {name}: {e}"));
        debug_assert_eq!(out.len(), 3);
        Svd1 {
            u: out[0].clone(),
            v: out[1].clone(),
            sigma: out[2][0],
            iters: self.rt.manifest().param_usize("power_iters").unwrap_or(0),
        }
    }

    fn objective(&self) -> &Arc<dyn Objective> {
        &self.obj
    }

    // Cached dense render buffer: factored runs hit the `step_it`
    // fallback every iteration (the artifacts take dense inputs), and
    // without this pair each one would allocate a fresh d1 x d2 matrix.
    fn take_dense_scratch(&mut self) -> Mat {
        std::mem::replace(&mut self.dense_scratch, Mat::zeros(0, 0))
    }

    fn put_dense_scratch(&mut self, scratch: Mat) {
        self.dense_scratch = scratch;
    }
}

/// Chunked full-objective evaluation through the `*_loss_m*` artifacts
/// (used by the e2e example to keep even evaluation Python-free and
/// XLA-accelerated).
pub fn loss_full_pjrt(rt: &PjrtRuntime, workload: &Workload, x: &Mat) -> anyhow::Result<f64> {
    let prefix = workload.prefix();
    let buckets = rt.manifest().param_list(&format!("{prefix}_buckets"))?;
    let chunk = *buckets.iter().max().unwrap();
    let name = format!("{prefix}_loss_m{chunk}");
    let obj = workload.objective();
    let n = obj.n();
    let k = workload.row_len();
    let x_dims: Vec<i64> = match workload {
        Workload::Ms(_) => vec![(x.rows * x.cols) as i64],
        Workload::Pnn(_) => vec![x.rows as i64, x.cols as i64],
        Workload::Sparse(_) => panic!("sparse completion has no AOT artifacts"),
    };
    let mut total = 0.0f64;
    let mut feat = vec![0.0f32; chunk * k];
    let mut yv = vec![0.0f32; chunk];
    let mut i = 0usize;
    while i < n {
        let take = chunk.min(n - i);
        feat.iter_mut().for_each(|v| *v = 0.0);
        yv.iter_mut().for_each(|v| *v = 0.0);
        for s in 0..take {
            feat[s * k..(s + 1) * k].copy_from_slice(workload.feature_row(i + s));
            yv[s] = workload.label(i + s);
        }
        let out = rt.run_f32(
            &name,
            &[
                literal_f32(&feat, &[chunk as i64, k as i64])?,
                literal_f32(&yv, &[chunk as i64])?,
                literal_f32(&x.data, &x_dims)?,
            ],
        )?;
        total += out[0][0] as f64;
        i += take;
    }
    Ok(total / n as f64)
}
