//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client from
//! the L3 hot path.  After `make artifacts`, the Rust binary is fully
//! self-contained — Python never runs at request time.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax >= 0.5
//! serializes protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod engine;
pub mod manifest;

pub use engine::{loss_full_pjrt, PjrtEngine, Workload};
pub use manifest::Manifest;

use std::collections::HashMap;
use std::sync::Mutex;

/// Shared PJRT state: one CPU client + a lazily compiled executable cache.
///
/// The runtime is `Arc`-shared across worker threads (the session engine
/// factory clones one runtime into every `PjrtEngine`), so it needs both
/// `Send` and `Sync`; the safety arguments live on the impls below.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: Mutex<HashMap<String, &'static xla::PjRtLoadedExecutable>>,
}

// SAFETY: every field is movable across threads.  `manifest` and `exes`
// are plain owned data; `client` wraps a PJRT C-API client pointer that
// the `xla` crate marks `!Send` only because it is a raw pointer — the
// PJRT contract imposes no thread affinity on clients.
unsafe impl Send for PjrtRuntime {}
// SAFETY: shared access is thread-safe.  The PJRT C API requires clients,
// loaded executables and buffers to tolerate concurrent
// `Execute`/`BufferFromHostBuffer` calls (jax itself drives TfrtCpuClient
// from many threads), and the only interior-mutable field, `exes`, is
// behind a `Mutex`.
unsafe impl Sync for PjrtRuntime {}

impl PjrtRuntime {
    /// Create a CPU PJRT client and index the artifact directory.
    pub fn new(artifacts_dir: &str) -> anyhow::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime { client, manifest, exes: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling and caching on first use) the executable for a
    /// manifest module.  The leak is intentional: executables live for the
    /// process lifetime and handing out `&'static` keeps the hot path free
    /// of locks and refcounts after warmup.
    pub fn executable(&self, name: &str) -> anyhow::Result<&'static xla::PjRtLoadedExecutable> {
        if let Some(e) = self.exes.lock().unwrap().get(name) {
            return Ok(e);
        }
        let path = self.manifest.module_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe: &'static _ = Box::leak(Box::new(self.client.compile(&comp)?));
        self.exes.lock().unwrap().insert(name.to_string(), exe);
        Ok(exe)
    }

    /// Execute a module on f32 literals, returning the flattened tuple of
    /// f32 output vectors.
    pub fn run_f32(
        &self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        // Multi-output modules come back as a tuple; single-output modules
        // as a bare array (the "hlo"-dialect lowering does not wrap them).
        let parts = match lit.shape()? {
            xla::Shape::Tuple(_) => lit.to_tuple()?,
            _ => vec![lit],
        };
        parts
            .iter()
            .map(|p| Ok(p.to_vec::<f32>()?))
            .collect::<anyhow::Result<Vec<_>>>()
    }
}

impl PjrtRuntime {
    /// Upload a host f32 array as a device-resident buffer (done ONCE per
    /// dataset by the gather-based engine path).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    /// Upload a host i32 array (per-call index vectors — a few KB).
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(data, dims, None)?)
    }

    /// Execute a module on pre-uploaded device buffers (zero large host
    /// copies on the hot path), returning the flattened f32 output tuple.
    pub fn run_f32_buffers(
        &self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        let exe = self.executable(name)?;
        let result = exe.execute_b::<&xla::PjRtBuffer>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        let parts = match lit.shape()? {
            xla::Shape::Tuple(_) => lit.to_tuple()?,
            _ => vec![lit],
        };
        parts
            .iter()
            .map(|p| Ok(p.to_vec::<f32>()?))
            .collect::<anyhow::Result<Vec<_>>>()
    }
}

/// Build an f32 literal of the given dims from a flat slice.
///
/// Uses `create_from_shape_and_untyped_data` — ONE host copy.  The naive
/// `Literal::vec1(..).reshape(..)` costs two full copies (vec1 copies,
/// reshape materializes a second literal), which dominated the PJRT hot
/// path for large batches (EXPERIMENTS.md §Perf: 8.3 ms of a 10 ms call
/// for a 7.4 MB batch).
pub fn literal_f32(data: &[f32], dims: &[i64]) -> anyhow::Result<xla::Literal> {
    let dims_usize: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
    debug_assert_eq!(dims_usize.iter().product::<usize>(), data.len());
    // SAFETY: reinterpreting `&[f32]` as `&[u8]` of 4x the length stays
    // inside the same allocation, and u8 has no alignment or validity
    // requirements; the borrow keeps `data` alive for the slice's lifetime.
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &dims_usize,
        bytes,
    )?)
}
