//! Artifact manifest parsing (`artifacts/manifest.txt`, written by
//! `python/compile/aot.py`): `param k v` lines carry the shape globals
//! (dims, buckets, power iterations), `module <name> file=... inputs=...`
//! lines index the HLO text files.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub params: BTreeMap<String, String>,
    /// module name -> file name
    pub modules: BTreeMap<String, String>,
}

#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("malformed manifest line {0}: '{1}'")]
    Malformed(usize, String),
    #[error("missing param '{0}'")]
    MissingParam(String),
    #[error("missing module '{0}' (available: {1})")]
    MissingModule(String, String),
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, ManifestError> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.txt"))?;
        let mut params = BTreeMap::new();
        let mut modules = BTreeMap::new();
        for (no, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            match it.next() {
                Some("param") => {
                    let k = it
                        .next()
                        .ok_or_else(|| ManifestError::Malformed(no + 1, line.into()))?;
                    let v = it
                        .next()
                        .ok_or_else(|| ManifestError::Malformed(no + 1, line.into()))?;
                    params.insert(k.to_string(), v.to_string());
                }
                Some("module") => {
                    let name = it
                        .next()
                        .ok_or_else(|| ManifestError::Malformed(no + 1, line.into()))?;
                    let file = it
                        .find(|tok| tok.starts_with("file="))
                        .map(|tok| tok.trim_start_matches("file=").to_string())
                        .unwrap_or_else(|| format!("{name}.hlo.txt"));
                    modules.insert(name.to_string(), file);
                }
                _ => return Err(ManifestError::Malformed(no + 1, line.into())),
            }
        }
        Ok(Manifest { dir, params, modules })
    }

    pub fn param_usize(&self, key: &str) -> Result<usize, ManifestError> {
        self.params
            .get(key)
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| ManifestError::MissingParam(key.to_string()))
    }

    pub fn param_list(&self, key: &str) -> Result<Vec<usize>, ManifestError> {
        let v = self
            .params
            .get(key)
            .ok_or_else(|| ManifestError::MissingParam(key.to_string()))?;
        Ok(v.split(',').filter_map(|s| s.parse().ok()).collect())
    }

    pub fn module_path(&self, name: &str) -> Result<PathBuf, ManifestError> {
        let file = self.modules.get(name).ok_or_else(|| {
            ManifestError::MissingModule(
                name.to_string(),
                self.modules.keys().cloned().collect::<Vec<_>>().join(","),
            )
        })?;
        Ok(self.dir.join(file))
    }

    /// Smallest bucket >= m from `key` (e.g. "ms_buckets"); falls back to
    /// the largest bucket when m exceeds all (callers split such batches).
    pub fn bucket_for(&self, key: &str, m: usize) -> Result<usize, ManifestError> {
        let mut buckets = self.param_list(key)?;
        buckets.sort_unstable();
        Ok(*buckets
            .iter()
            .find(|&&b| b >= m)
            .unwrap_or(buckets.last().expect("empty bucket list")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, text: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), text).unwrap();
    }

    #[test]
    fn parses_params_and_modules() {
        let dir = std::env::temp_dir().join("sfw_manifest_test1");
        write_manifest(
            &dir,
            "# comment\nparam ms_d1 30\nparam ms_buckets 128,512,2048\nmodule ms_step_m128 file=ms_step_m128.hlo.txt inputs=128x900,128,900,30\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.param_usize("ms_d1").unwrap(), 30);
        assert_eq!(m.param_list("ms_buckets").unwrap(), vec![128, 512, 2048]);
        assert!(m
            .module_path("ms_step_m128")
            .unwrap()
            .ends_with("ms_step_m128.hlo.txt"));
        assert!(m.module_path("nope").is_err());
    }

    #[test]
    fn bucket_selection() {
        let dir = std::env::temp_dir().join("sfw_manifest_test2");
        write_manifest(&dir, "param b 128,512,2048\n");
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.bucket_for("b", 1).unwrap(), 128);
        assert_eq!(m.bucket_for("b", 128).unwrap(), 128);
        assert_eq!(m.bucket_for("b", 129).unwrap(), 512);
        assert_eq!(m.bucket_for("b", 4000).unwrap(), 2048); // clamp to max
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("sfw_manifest_test3");
        write_manifest(&dir, "bogus line here\n");
        assert!(Manifest::load(&dir).is_err());
    }
}
