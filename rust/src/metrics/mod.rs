//! Metrics: atomic counters for the quantities the paper reports
//! (stochastic gradient evaluations, linear-optimization calls — Table 1 —
//! and communication bytes — §3 "Communication Cost of SFW-asyn"), plus a
//! time-stamped loss trace used to regenerate Figures 4–7.
//!
//! Byte/message counters are charged centrally by the
//! [`crate::comms`] link endpoints (never at protocol call-sites), with
//! sizes derived from the actual frame encoding, so totals are identical
//! across the local and TCP transports for identical traffic.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Shared, thread-safe experiment counters.
#[derive(Debug, Default)]
pub struct Counters {
    /// # stochastic gradient evaluations (one per sampled index, Table 1).
    pub grad_evals: AtomicU64,
    /// # linear optimizations / 1-SVDs (Table 1).
    pub lmo_calls: AtomicU64,
    /// Master iterations completed (t_m).
    pub iterations: AtomicU64,
    /// Updates dropped by the delay gate (t_m - t_w > tau).
    pub dropped_updates: AtomicU64,
    /// Largest staleness t_m - t_w among ACCEPTED updates.  The delay
    /// gate guarantees this never exceeds tau; the chaos conformance
    /// suite asserts exactly that under every fault plan.
    pub max_accepted_delay: AtomicU64,
    /// Bytes worker -> master.
    pub bytes_up: AtomicU64,
    /// Bytes master -> worker.
    pub bytes_down: AtomicU64,
    /// Messages worker -> master.
    pub msgs_up: AtomicU64,
    /// Messages master -> worker.
    pub msgs_down: AtomicU64,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_grad_evals(&self, n: u64) {
        self.grad_evals.fetch_add(n, Ordering::Relaxed);
    }
    pub fn add_lmo(&self) {
        self.lmo_calls.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add_iteration(&self) {
        self.iterations.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add_dropped(&self) {
        self.dropped_updates.fetch_add(1, Ordering::Relaxed);
    }
    /// Record the staleness of an accepted update.
    pub fn note_accepted_delay(&self, delay: u64) {
        self.max_accepted_delay.fetch_max(delay, Ordering::Relaxed);
    }
    pub fn add_up(&self, bytes: u64) {
        self.bytes_up.fetch_add(bytes, Ordering::Relaxed);
        self.msgs_up.fetch_add(1, Ordering::Relaxed);
    }
    pub fn add_down(&self, bytes: u64) {
        self.bytes_down.fetch_add(bytes, Ordering::Relaxed);
        self.msgs_down.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            grad_evals: self.grad_evals.load(Ordering::Relaxed),
            lmo_calls: self.lmo_calls.load(Ordering::Relaxed),
            iterations: self.iterations.load(Ordering::Relaxed),
            dropped_updates: self.dropped_updates.load(Ordering::Relaxed),
            max_accepted_delay: self.max_accepted_delay.load(Ordering::Relaxed),
            bytes_up: self.bytes_up.load(Ordering::Relaxed),
            bytes_down: self.bytes_down.load(Ordering::Relaxed),
            msgs_up: self.msgs_up.load(Ordering::Relaxed),
            msgs_down: self.msgs_down.load(Ordering::Relaxed),
        }
    }
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub grad_evals: u64,
    pub lmo_calls: u64,
    pub iterations: u64,
    pub dropped_updates: u64,
    pub max_accepted_delay: u64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    pub msgs_up: u64,
    pub msgs_down: u64,
}

impl CounterSnapshot {
    pub fn total_bytes(&self) -> u64 {
        self.bytes_up + self.bytes_down
    }
}

/// Serving-side request/latency counters (the `sfw serve` report).
/// Latencies accumulate in nanoseconds; the snapshot reports
/// microseconds, the natural unit of an O(atoms * d2) score pass.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub requests: AtomicU64,
    pub total_ns: AtomicU64,
    pub max_ns: AtomicU64,
    /// Queries that failed (bad user id, score error) — counted, not
    /// fatal: a batch keeps serving past individual failures.
    pub errors: AtomicU64,
}

impl ServeStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge one answered query.
    pub fn record(&self, elapsed: std::time::Duration) {
        let ns = elapsed.as_nanos().min(u64::MAX as u128) as u64;
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.total_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Charge one failed query.
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> ServeSnapshot {
        let requests = self.requests.load(Ordering::Relaxed);
        let total_ns = self.total_ns.load(Ordering::Relaxed);
        ServeSnapshot {
            requests,
            mean_us: if requests == 0 {
                0.0
            } else {
                total_ns as f64 / requests as f64 / 1_000.0
            },
            max_us: self.max_ns.load(Ordering::Relaxed) as f64 / 1_000.0,
            errors: self.errors.load(Ordering::Relaxed),
        }
    }
}

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServeSnapshot {
    pub requests: u64,
    pub mean_us: f64,
    pub max_us: f64,
    pub errors: u64,
}

/// One point of a convergence curve: (time, master iteration, loss, gap).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TracePoint {
    /// Seconds since trace start (wall clock) OR simulated time units.
    pub t: f64,
    pub iteration: u64,
    pub loss: f64,
    /// Minibatch FW dual-gap estimate at this iterate (NaN when the
    /// recording path has no gap in hand — e.g. the k=0 init point or
    /// solvers without an LMO-bearing step).
    pub gap: f64,
}

/// Thread-safe, time-stamped loss trace.
#[derive(Debug)]
pub struct LossTrace {
    start: Instant,
    points: Mutex<Vec<TracePoint>>,
}

impl Default for LossTrace {
    fn default() -> Self {
        Self::new()
    }
}

impl LossTrace {
    pub fn new() -> Self {
        LossTrace { start: Instant::now(), points: Mutex::new(Vec::new()) }
    }

    /// Seconds since trace start (for snapshot timestamping).
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Record with wall-clock timestamp (no gap in hand).
    pub fn record(&self, iteration: u64, loss: f64) {
        self.record_gap(iteration, loss, f64::NAN);
    }

    /// Record with wall-clock timestamp and a dual-gap estimate.
    pub fn record_gap(&self, iteration: u64, loss: f64, gap: f64) {
        let t = self.start.elapsed().as_secs_f64();
        self.points.lock().unwrap().push(TracePoint { t, iteration, loss, gap });
    }

    /// Record with an explicit (e.g. simulated) timestamp.
    pub fn record_at(&self, t: f64, iteration: u64, loss: f64) {
        self.record_at_gap(t, iteration, loss, f64::NAN);
    }

    /// Record with explicit timestamp and a dual-gap estimate.
    pub fn record_at_gap(&self, t: f64, iteration: u64, loss: f64, gap: f64) {
        self.points.lock().unwrap().push(TracePoint { t, iteration, loss, gap });
    }

    pub fn points(&self) -> Vec<TracePoint> {
        self.points.lock().unwrap().clone()
    }

    /// Last recorded finite gap (the stopping-quantity readout); None if
    /// no point carries one.
    pub fn final_gap(&self) -> Option<f64> {
        self.points
            .lock()
            .unwrap()
            .iter()
            .rev()
            .find(|p| p.gap.is_finite())
            .map(|p| p.gap)
    }

    /// First time at which the loss reaches `target` (for Fig 5/7 speedups).
    pub fn time_to_target(&self, target: f64) -> Option<f64> {
        self.points
            .lock()
            .unwrap()
            .iter()
            .find(|p| p.loss <= target)
            .map(|p| p.t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counters_accumulate_across_threads() {
        let c = Arc::new(Counters::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.add_grad_evals(2);
                        c.add_lmo();
                        c.add_up(10);
                        c.add_down(20);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let s = c.snapshot();
        assert_eq!(s.grad_evals, 8000);
        assert_eq!(s.lmo_calls, 4000);
        assert_eq!(s.bytes_up, 40_000);
        assert_eq!(s.bytes_down, 80_000);
        assert_eq!(s.msgs_up, 4000);
        assert_eq!(s.msgs_down, 4000);
        assert_eq!(s.total_bytes(), 120_000);
    }

    #[test]
    fn serve_stats_accumulate() {
        let s = ServeStats::new();
        assert_eq!(s.snapshot(), ServeSnapshot::default());
        s.record(std::time::Duration::from_micros(10));
        s.record(std::time::Duration::from_micros(30));
        let snap = s.snapshot();
        assert_eq!(snap.requests, 2);
        assert!((snap.mean_us - 20.0).abs() < 1e-9);
        assert!((snap.max_us - 30.0).abs() < 1e-9);
    }

    #[test]
    fn trace_time_to_target() {
        let t = LossTrace::new();
        t.record_at(1.0, 1, 0.5);
        t.record_at(2.0, 2, 0.1);
        t.record_at(3.0, 3, 0.05);
        assert_eq!(t.time_to_target(0.1), Some(2.0));
        assert_eq!(t.time_to_target(0.01), None);
        assert_eq!(t.points().len(), 3);
    }

    #[test]
    fn trace_final_gap_skips_gapless_points() {
        let t = LossTrace::new();
        assert_eq!(t.final_gap(), None);
        t.record_at(0.0, 0, 1.0); // init point, no gap
        assert_eq!(t.final_gap(), None);
        t.record_at_gap(1.0, 1, 0.5, 0.8);
        t.record_at_gap(2.0, 2, 0.2, 0.3);
        t.record_at(3.0, 3, 0.1); // gapless tail point
        assert_eq!(t.final_gap(), Some(0.3));
        assert!(t.points()[0].gap.is_nan());
    }

    #[test]
    fn serve_stats_count_errors() {
        let s = ServeStats::new();
        s.record(std::time::Duration::from_micros(5));
        s.record_error();
        s.record_error();
        let snap = s.snapshot();
        assert_eq!(snap.requests, 1);
        assert_eq!(snap.errors, 2);
    }
}
