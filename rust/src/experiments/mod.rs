//! Shared experiment harness used by `examples/` and `rust/benches/`:
//! standard workload builders (paper §5.1 parameters, scaled for CI),
//! relative-loss helpers and time-to-target extraction (Figures 5/7).

use std::sync::Arc;

use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
use crate::data::pnn::{PnnData, PnnParams};
use crate::metrics::TracePoint;
use crate::objective::{MatrixSensing, Objective, Pnn};
use crate::util::rng::Rng;

/// Paper-shaped matrix-sensing objective (30x30, rank 3, noise 0.1).
/// `n` scales the sample count (paper: 90 000; benches default smaller).
pub fn build_ms(seed: u64, n: usize) -> Arc<MatrixSensing> {
    let mut rng = Rng::new(seed);
    let p = MsParams { d1: 30, d2: 30, rank: 3, n, noise_std: 0.1 };
    Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0))
}

/// PNN objective at feature dim `d` (paper: 784; artifacts default 196).
pub fn build_pnn(seed: u64, d: usize, n: usize) -> Arc<Pnn> {
    let mut rng = Rng::new(seed);
    let p = PnnParams { d, n, teacher_rank: 4, mixture_components: 10 };
    Arc::new(Pnn::new(PnnData::generate(&p, &mut rng), 1.0))
}

/// Relative loss à la the paper's figures: (F - F*) / (F_0 - F*).
pub fn relative(points: &[TracePoint], f_star: f64) -> Vec<(f64, u64, f64)> {
    let f0 = points.first().map(|p| p.loss).unwrap_or(1.0);
    let denom = (f0 - f_star).max(1e-30);
    points
        .iter()
        .map(|p| (p.t, p.iteration, ((p.loss - f_star) / denom).max(0.0)))
        .collect()
}

/// First timestamp at which the relative loss reaches `target`.
pub fn time_to_relative(points: &[TracePoint], f_star: f64, target: f64) -> Option<f64> {
    relative(points, f_star)
        .iter()
        .find(|(_, _, r)| *r <= target)
        .map(|(t, _, _)| *t)
}

/// F* estimate for an objective (noise floor for MS; 0 fallback).
pub fn f_star(obj: &Arc<dyn Objective>) -> f64 {
    obj.f_star_hint()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relative_normalizes_first_point_to_one() {
        let pts = vec![
            TracePoint { t: 0.0, iteration: 0, loss: 2.0, gap: f64::NAN },
            TracePoint { t: 1.0, iteration: 10, loss: 1.0, gap: f64::NAN },
            TracePoint { t: 2.0, iteration: 20, loss: 0.5, gap: f64::NAN },
        ];
        let rel = relative(&pts, 0.5);
        assert!((rel[0].2 - 1.0).abs() < 1e-12);
        assert!((rel[1].2 - (0.5 / 1.5)).abs() < 1e-12);
        assert!(rel[2].2.abs() < 1e-12);
    }

    #[test]
    fn time_to_relative_finds_crossing() {
        let pts = vec![
            TracePoint { t: 0.0, iteration: 0, loss: 1.0, gap: f64::NAN },
            TracePoint { t: 5.0, iteration: 10, loss: 0.1, gap: f64::NAN },
            TracePoint { t: 9.0, iteration: 20, loss: 0.01, gap: f64::NAN },
        ];
        assert_eq!(time_to_relative(&pts, 0.0, 0.05), Some(9.0));
        assert_eq!(time_to_relative(&pts, 0.0, 1e-9), None);
    }

    #[test]
    fn builders_produce_paper_dims() {
        let ms = build_ms(1, 500);
        assert_eq!(ms.data.d1, 30);
        assert_eq!(ms.data.d2, 30);
        let pnn = build_pnn(2, 16, 300);
        assert_eq!(pnn.data.d, 16);
    }
}
