//! Projected Gradient Descent baseline.
//!
//! The paper's introduction motivates FW by contrasting against PGD, whose
//! projection needs a FULL SVD per iteration — O(D1 D2 min(D1,D2)) vs the
//! LMO's O(D1 D2).  We implement it honestly (minibatch gradient + exact
//! nuclear-ball projection via Jacobi SVD) so the `hotpath` bench can show
//! the per-iteration cost gap on the paper's own workloads.

use std::sync::Arc;

use crate::algo::engine::StepEngine;
use crate::algo::schedule::BatchSchedule;
use crate::linalg::{
    factored_nuclear_projection, nuclear_ball_projection, Iterate, Mat, Repr,
};
use crate::metrics::{Counters, LossTrace};
use crate::util::rng::Rng;

pub struct PgdOptions {
    pub iterations: u64,
    pub batch: BatchSchedule,
    /// Constant gradient step size gamma.
    pub gamma: f32,
    pub eval_every: u64,
    pub seed: u64,
    /// Iterate representation.  Factored-mode PGD takes its atoms
    /// straight from the projection's SVD (which it computes anyway), so
    /// the iterate's rank is visible for free.
    pub repr: Repr,
    /// FW dual-gap stopping tolerance (0 disables).  PGD itself never
    /// runs an LMO, so honoring `tol` buys one power iteration per step
    /// to estimate the same gap the FW solvers stop on — charged to the
    /// LMO counter for honest Table-1 accounting.
    pub tol: f64,
}

impl Default for PgdOptions {
    fn default() -> Self {
        PgdOptions {
            iterations: 200,
            batch: BatchSchedule::Constant(256),
            gamma: 0.05,
            eval_every: 10,
            seed: 0,
            repr: Repr::Dense,
            tol: 0.0,
        }
    }
}

/// Run minibatch PGD: X <- Proj_{||.||_* <= theta}(X - gamma * grad).
pub fn run_pgd<E: StepEngine + ?Sized>(
    engine: &mut E,
    opts: &PgdOptions,
    counters: &Counters,
    trace: &LossTrace,
) -> Iterate {
    let obj: Arc<dyn crate::objective::Objective> = engine.objective().clone();
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let n = obj.n();
    let mut rng = Rng::new(opts.seed);
    let mut x = Iterate::init_rank_one(opts.repr, d1, d2, theta, &mut rng);
    let mut g = Mat::zeros(d1, d2);
    let mut idx = Vec::new();
    let mut peak = x.peak_atoms();

    trace.record(0, obj.loss_full_it(&x));
    for k in 1..=opts.iterations {
        let m = opts.batch.m(k);
        rng.sample_indices(n, m, &mut idx);
        let _ = engine.grad_sum_it(&x, &idx, &mut g);
        counters.add_grad_evals(m as u64);
        counters.add_iteration();
        // Gap-based stopping: PGD has no LMO of its own, so a positive
        // tol pays one power iteration on the batch gradient to estimate
        // the FW dual gap the other solvers stop on.
        let gap = if opts.tol > 0.0 {
            let gx = x.inner_flat(&g.data);
            let s = engine.lmo(&g);
            counters.add_lmo();
            (gx + theta as f64 * s.sigma as f64) / m as f64
        } else {
            f64::NAN
        };
        // gradient step on the dense form (the projection needs a full
        // SVD of it anyway), then project back — into atoms when the
        // run is factored
        let mut xd = x.into_dense();
        xd.axpy(-opts.gamma / m as f32, &g);
        x = match opts.repr {
            Repr::Dense => Iterate::Dense(nuclear_ball_projection(&xd, theta)),
            Repr::Factored => {
                let f = factored_nuclear_projection(&xd, theta);
                peak = peak.max(f.peak_atoms());
                Iterate::Factored(f)
            }
        };
        let stop = opts.tol > 0.0 && gap.is_finite() && gap <= opts.tol;
        if stop || k % opts.eval_every == 0 || k == opts.iterations {
            trace.record_gap(k, obj.loss_full_it(&x), gap);
        }
        if stop {
            break;
        }
    }
    if let Iterate::Factored(f) = &mut x {
        // surface the run-wide peak, not just the final projection's
        f.note_peak(peak);
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::NativeEngine;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::nuclear_norm;
    use crate::objective::MatrixSensing;

    #[test]
    fn pgd_converges_and_stays_feasible() {
        let mut rng = Rng::new(60);
        let p = MsParams { d1: 8, d2: 8, rank: 2, n: 1_000, noise_std: 0.05 };
        let obj = Arc::new(MatrixSensing::new(
            MatrixSensingData::generate(&p, &mut rng),
            1.0,
        ));
        let mut engine = NativeEngine::new(obj.clone(), 50, 61);
        let counters = Counters::new();
        let trace = LossTrace::new();
        let opts = PgdOptions {
            iterations: 100,
            batch: BatchSchedule::Constant(128),
            gamma: 0.1,
            eval_every: 20,
            seed: 62,
            repr: Repr::Dense,
            tol: 0.0,
        };
        let x = run_pgd(&mut engine, &opts, &counters, &trace);
        let pts = trace.points();
        assert!(pts.last().unwrap().loss < 0.5 * pts.first().unwrap().loss);
        assert!(nuclear_norm(&x.to_dense()) <= 1.0 + 1e-3);
        // PGD performs no LMO calls — the comparison axis of the paper
        assert_eq!(counters.snapshot().lmo_calls, 0);
    }

    #[test]
    fn factored_pgd_tracks_dense_pgd() {
        let mut rng = Rng::new(63);
        let p = MsParams { d1: 7, d2: 5, rank: 2, n: 800, noise_std: 0.05 };
        let obj = Arc::new(MatrixSensing::new(
            MatrixSensingData::generate(&p, &mut rng),
            1.0,
        ));
        let run = |repr: Repr| {
            let mut engine = NativeEngine::new(obj.clone(), 50, 64);
            let counters = Counters::new();
            let trace = LossTrace::new();
            let opts = PgdOptions {
                iterations: 40,
                batch: BatchSchedule::Constant(64),
                gamma: 0.1,
                eval_every: 10,
                seed: 65,
                repr,
                tol: 0.0,
            };
            run_pgd(&mut engine, &opts, &counters, &trace)
        };
        let dense = run(Repr::Dense).into_dense();
        let fact_it = run(Repr::Factored);
        let peak = fact_it.peak_atoms();
        let fact = fact_it.into_dense();
        let mut d = dense.clone();
        d.axpy(-1.0, &fact);
        let rel = d.frob_norm() / (1.0 + dense.frob_norm());
        assert!(rel < 1e-2, "factored PGD diverged from dense: {rel}");
        assert!(peak >= 1);
    }
}
