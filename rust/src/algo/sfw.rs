//! Serial Stochastic Frank-Wolfe (Hazan & Luo 2016) — the single-machine
//! reference every distributed variant is compared against (Fig 4/5's
//! "1 worker" lines, Table 1's SFW column).

use std::sync::Arc;

use crate::algo::engine::StepEngine;
use crate::algo::schedule::{eta, select_eta, BatchSchedule, StepMethod};
use crate::linalg::{dot, Iterate, Mat, Repr};
use crate::metrics::{Counters, LossTrace};
use crate::util::rng::Rng;

/// Options for a serial SFW run.
pub struct SfwOptions {
    pub iterations: u64,
    pub batch: BatchSchedule,
    /// Evaluate F(X) every this many iterations (full-data pass).
    pub eval_every: u64,
    pub seed: u64,
    /// Iterate representation (dense reference or factored atoms).
    pub repr: Repr,
    /// Stop once the minibatch dual-gap estimate falls to `tol`
    /// (0 disables — run all `iterations`).
    pub tol: f64,
    /// Step-size / direction policy (see [`StepMethod`]).
    pub step: StepMethod,
}

impl Default for SfwOptions {
    fn default() -> Self {
        SfwOptions {
            iterations: 200,
            batch: BatchSchedule::sfw(0.05, 10_000),
            eval_every: 10,
            seed: 0,
            repr: Repr::Dense,
            tol: 0.0,
            step: StepMethod::Vanilla,
        }
    }
}

/// Initial iterate: random rank-one `u v^T` on the nuclear sphere of radius
/// theta (the paper initializes `||X_0||_* = 1`).
pub fn init_rank_one(d1: usize, d2: usize, theta: f32, rng: &mut Rng) -> Mat {
    let u = rng.unit_vector(d1);
    let v = rng.unit_vector(d2);
    let mut x = Mat::zeros(d1, d2);
    for i in 0..d1 {
        for j in 0..d2 {
            *x.at_mut(i, j) = theta * u[i] * v[j];
        }
    }
    x
}

/// Run serial SFW; returns the final iterate (dense or factored per
/// `opts.repr`).  Every LMO, gradient evaluation and loss point is
/// recorded in `counters` / `trace`; each recorded point carries the
/// minibatch dual-gap estimate at the pre-step iterate, and a positive
/// `opts.tol` stops the run once that estimate reaches it.
pub fn run_sfw<E: StepEngine + ?Sized>(
    engine: &mut E,
    opts: &SfwOptions,
    counters: &Counters,
    trace: &LossTrace,
) -> Iterate {
    let obj: Arc<dyn crate::objective::Objective> = engine.objective().clone();
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let n = obj.n();
    let mut rng = Rng::new(opts.seed);
    let mut x = Iterate::init_rank_one(opts.repr, d1, d2, theta, &mut rng);
    let mut idx = Vec::new();
    // Away/pairwise steps need the gradient matrix itself (per-atom
    // scores), not just the fused step's LMO pair.
    let mut g = if opts.step.needs_active_set() {
        Mat::zeros(d1, d2)
    } else {
        Mat::zeros(0, 0)
    };

    trace.record(0, obj.loss_full_it(&x));
    for k in 1..=opts.iterations {
        let m = opts.batch.m(k);
        rng.sample_indices(n, m, &mut idx);
        let gap = if opts.step.needs_active_set() && x.factored_mut().is_some() {
            active_set_step(engine, &obj, opts.step, k, theta, &mut x, &idx, &mut g)
        } else {
            let out = engine.step_it(&x, &idx);
            let step_eta = if opts.step == StepMethod::Vanilla {
                eta(k)
            } else {
                // phi(eta) = batch SUM loss at the blended trial point;
                // phi'(0) = <G_sum, S - X> = -(m * mean gap).
                let slope0 = -(out.gap * m as f64);
                select_eta(opts.step, k, out.loss_sum, slope0, 1.0, &mut |e| {
                    let mut trial = x.clone();
                    trial.fw_rank_one_update(e, -theta, &out.u, &out.v);
                    obj.loss_batch_it(&trial, &idx)
                })
            };
            // X <- (1 - eta) X + eta * (-theta u v^T)
            x.fw_rank_one_update(step_eta, -theta, &out.u, &out.v);
            out.gap
        };
        counters.add_grad_evals(m as u64);
        counters.add_lmo();
        counters.add_iteration();
        let stop = opts.tol > 0.0 && gap.is_finite() && gap <= opts.tol;
        if stop || k % opts.eval_every == 0 || k == opts.iterations {
            trace.record_gap(k, obj.loss_full_it(&x), gap);
        }
        if stop {
            break;
        }
    }
    x
}

/// One away-steps / pairwise FW iteration over the factored active set
/// (Ding & Udell, arXiv:1808.05274, adapted to the stochastic setting:
/// all inner products run against the minibatch SUM-gradient).  Returns
/// the standard FW mean-gap estimate `(<G, X> + theta sigma) / m` — the
/// stopping/reporting quantity is the same whichever direction is taken.
#[allow(clippy::too_many_arguments)]
fn active_set_step<E: StepEngine + ?Sized>(
    engine: &mut E,
    obj: &Arc<dyn crate::objective::Objective>,
    method: StepMethod,
    k: u64,
    theta: f32,
    x: &mut Iterate,
    idx: &[usize],
    g: &mut Mat,
) -> f64 {
    let m = idx.len();
    let loss0 = engine.grad_sum_it(x, idx, g);
    let s = engine.lmo(g);
    let gx = x.inner_flat(&g.data);
    // Standard FW gap: <G, X - S> with S = -theta u v^T.
    let gap_fw_sum = gx + theta as f64 * s.sigma as f64;
    let (su, sv) = (Arc::new(s.u), Arc::new(s.v));

    // Away atom: the active vertex V_i = sign(w_i) theta u_i v_i^T that
    // the gradient most wants to LEAVE (max <G, V_i>).
    let mut away: Option<(usize, f64, f32)> = None; // (atom, <G,V_i>, alpha_i)
    if let Some(f) = x.factored_mut() {
        let mut gv = vec![0.0f32; f.rows];
        for i in 0..f.atoms() {
            let (w, u, v) = f.atom(i);
            if w == 0.0 {
                continue;
            }
            g.matvec(v, &mut gv);
            let ugv = dot(u, &gv) as f64;
            let sign = if w < 0.0 { -1.0 } else { 1.0 };
            let score = sign * theta as f64 * ugv;
            let alpha = (w.abs() / theta).min(1.0);
            if away.as_ref().map(|(_, best, _)| score > *best).unwrap_or(true) {
                away = Some((i, score, alpha));
            }
        }
    }

    match (method, away) {
        (StepMethod::Pairwise, Some((a, score_a, alpha_a))) if alpha_a > 0.0 => {
            // Shift mass from V_a onto S; phi'(0) = <G, S - V_a>.
            let slope0 = -(theta as f64 * s.sigma as f64) - score_a;
            let step_eta =
                select_eta(method, k, loss0, slope0, alpha_a, &mut |e| {
                    let mut trial = x.clone();
                    if let Some(tf) = trial.factored_mut() {
                        tf.pairwise_update(a, e, -theta, su.clone(), sv.clone());
                    }
                    obj.loss_batch_it(&trial, idx)
                });
            if let Some(f) = x.factored_mut() {
                f.pairwise_update(a, step_eta, -theta, su, sv);
            }
        }
        (StepMethod::Away, Some((a, score_a, alpha_a)))
            if score_a - gx > gap_fw_sum && alpha_a > 0.0 && alpha_a < 1.0 =>
        {
            // Away direction d = X - V_a dominates; phi'(0) = <G, X - V_a>
            // = gx - score_a.  The boundary step alpha/(1-alpha) may
            // exceed 1; select_eta clamps to (0, 1], which stays feasible.
            let eta_max = alpha_a / (1.0 - alpha_a);
            let slope0 = gx - score_a;
            let step_eta = select_eta(method, k, loss0, slope0, eta_max, &mut |e| {
                let mut trial = x.clone();
                if let Some(tf) = trial.factored_mut() {
                    tf.away_update(a, e, theta);
                }
                obj.loss_batch_it(&trial, idx)
            });
            if let Some(f) = x.factored_mut() {
                f.away_update(a, step_eta, theta);
            }
        }
        _ => {
            // Standard FW step, line-search sized along X -> S.
            let step_eta = select_eta(method, k, loss0, -gap_fw_sum, 1.0, &mut |e| {
                let mut trial = x.clone();
                trial.fw_update_arc(e, -theta, &su, &sv);
                obj.loss_batch_it(&trial, idx)
            });
            x.fw_update_arc(step_eta, -theta, &su, &sv);
        }
    }
    gap_fw_sum / m.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::NativeEngine;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::nuclear_norm;
    use crate::objective::MatrixSensing;

    fn small_ms(seed: u64) -> Arc<dyn crate::objective::Objective> {
        let mut rng = Rng::new(seed);
        let p = MsParams { d1: 10, d2: 10, rank: 2, n: 2_000, noise_std: 0.05 };
        Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0))
    }

    #[test]
    fn init_is_on_nuclear_sphere() {
        let mut rng = Rng::new(50);
        let x = init_rank_one(7, 5, 2.0, &mut rng);
        assert!((nuclear_norm(&x) - 2.0).abs() < 1e-4);
    }

    #[test]
    fn sfw_decreases_loss_and_stays_feasible() {
        let obj = small_ms(51);
        let mut engine = NativeEngine::new(obj.clone(), 60, 52);
        let counters = Counters::new();
        let trace = LossTrace::new();
        let opts = SfwOptions {
            iterations: 120,
            batch: BatchSchedule::sfw(0.05, 2_000),
            eval_every: 20,
            seed: 53,
            repr: crate::linalg::Repr::Dense,
            ..SfwOptions::default()
        };
        let x = run_sfw(&mut engine, &opts, &counters, &trace);
        let pts = trace.points();
        let first = pts.first().unwrap().loss;
        let last = pts.last().unwrap().loss;
        assert!(
            last < 0.3 * first,
            "SFW failed to make progress: {first} -> {last}"
        );
        // iterates stay in the nuclear ball (convex combination of feasible pts)
        assert!(nuclear_norm(&x.to_dense()) <= 1.0 + 1e-3);
        let s = counters.snapshot();
        assert_eq!(s.lmo_calls, 120);
        assert_eq!(s.iterations, 120);
        assert!(s.grad_evals > 0);
    }

    #[test]
    fn constant_batch_converges_to_neighborhood() {
        // Thm 3: fixed batch => converges to a noise floor, still useful.
        let obj = small_ms(54);
        let mut engine = NativeEngine::new(obj.clone(), 60, 55);
        let counters = Counters::new();
        let trace = LossTrace::new();
        let opts = SfwOptions {
            iterations: 150,
            batch: BatchSchedule::Constant(128),
            eval_every: 25,
            seed: 56,
            repr: crate::linalg::Repr::Dense,
            ..SfwOptions::default()
        };
        run_sfw(&mut engine, &opts, &counters, &trace);
        let pts = trace.points();
        assert!(pts.last().unwrap().loss < 0.5 * pts.first().unwrap().loss);
        assert_eq!(counters.snapshot().grad_evals, 150 * 128);
    }

    #[test]
    fn tol_stops_run_early_and_records_final_gap() {
        let obj = small_ms(57);
        let mut engine = NativeEngine::new(obj.clone(), 60, 58);
        let counters = Counters::new();
        let trace = LossTrace::new();
        // A huge tolerance is met by the very first gap estimate, so the
        // run must stop at k = 1 regardless of the 100-iteration budget.
        let opts = SfwOptions {
            iterations: 100,
            batch: BatchSchedule::Constant(64),
            eval_every: 10,
            seed: 59,
            tol: 1e6,
            ..SfwOptions::default()
        };
        run_sfw(&mut engine, &opts, &counters, &trace);
        assert_eq!(counters.snapshot().iterations, 1);
        let pts = trace.points();
        let last = pts.last().unwrap();
        assert_eq!(last.iteration, 1);
        assert!(last.gap.is_finite() && last.gap <= 1e6);
        assert_eq!(trace.final_gap(), Some(last.gap));
        // tol = 0 disables stopping entirely
        let counters2 = Counters::new();
        let trace2 = LossTrace::new();
        let opts2 = SfwOptions { iterations: 20, tol: 0.0, ..opts };
        let mut engine2 = NativeEngine::new(obj, 60, 58);
        run_sfw(&mut engine2, &opts2, &counters2, &trace2);
        assert_eq!(counters2.snapshot().iterations, 20);
    }

    #[test]
    fn away_and_pairwise_converge_and_stay_feasible() {
        use crate::algo::schedule::StepMethod;
        for step in [StepMethod::Away, StepMethod::Pairwise] {
            let obj = small_ms(60);
            let mut engine = NativeEngine::new(obj.clone(), 60, 61);
            let counters = Counters::new();
            let trace = LossTrace::new();
            let opts = SfwOptions {
                iterations: 100,
                batch: BatchSchedule::Constant(128),
                eval_every: 20,
                seed: 62,
                repr: crate::linalg::Repr::Factored,
                step,
                ..SfwOptions::default()
            };
            let x = run_sfw(&mut engine, &opts, &counters, &trace);
            let pts = trace.points();
            assert!(
                pts.last().unwrap().loss < 0.5 * pts.first().unwrap().loss,
                "{:?} failed to make progress",
                step
            );
            // feasibility by construction: the atom-list convex mass
            // never exceeds theta
            assert!(nuclear_norm(&x.to_dense()) <= 1.0 + 1e-3, "{:?} left the ball", step);
            assert_eq!(counters.snapshot().lmo_calls, 100);
        }
    }
}
