//! Serial Stochastic Frank-Wolfe (Hazan & Luo 2016) — the single-machine
//! reference every distributed variant is compared against (Fig 4/5's
//! "1 worker" lines, Table 1's SFW column).

use std::sync::Arc;

use crate::algo::engine::StepEngine;
use crate::algo::schedule::{eta, BatchSchedule};
use crate::linalg::{Iterate, Mat, Repr};
use crate::metrics::{Counters, LossTrace};
use crate::util::rng::Rng;

/// Options for a serial SFW run.
pub struct SfwOptions {
    pub iterations: u64,
    pub batch: BatchSchedule,
    /// Evaluate F(X) every this many iterations (full-data pass).
    pub eval_every: u64,
    pub seed: u64,
    /// Iterate representation (dense reference or factored atoms).
    pub repr: Repr,
}

impl Default for SfwOptions {
    fn default() -> Self {
        SfwOptions {
            iterations: 200,
            batch: BatchSchedule::sfw(0.05, 10_000),
            eval_every: 10,
            seed: 0,
            repr: Repr::Dense,
        }
    }
}

/// Initial iterate: random rank-one `u v^T` on the nuclear sphere of radius
/// theta (the paper initializes `||X_0||_* = 1`).
pub fn init_rank_one(d1: usize, d2: usize, theta: f32, rng: &mut Rng) -> Mat {
    let u = rng.unit_vector(d1);
    let v = rng.unit_vector(d2);
    let mut x = Mat::zeros(d1, d2);
    for i in 0..d1 {
        for j in 0..d2 {
            *x.at_mut(i, j) = theta * u[i] * v[j];
        }
    }
    x
}

/// Run serial SFW; returns the final iterate (dense or factored per
/// `opts.repr`).  Every LMO, gradient evaluation and loss point is
/// recorded in `counters` / `trace`.
pub fn run_sfw<E: StepEngine + ?Sized>(
    engine: &mut E,
    opts: &SfwOptions,
    counters: &Counters,
    trace: &LossTrace,
) -> Iterate {
    let obj: Arc<dyn crate::objective::Objective> = engine.objective().clone();
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let n = obj.n();
    let mut rng = Rng::new(opts.seed);
    let mut x = Iterate::init_rank_one(opts.repr, d1, d2, theta, &mut rng);
    let mut idx = Vec::new();

    trace.record(0, obj.loss_full_it(&x));
    for k in 1..=opts.iterations {
        let m = opts.batch.m(k);
        rng.sample_indices(n, m, &mut idx);
        let out = engine.step_it(&x, &idx);
        counters.add_grad_evals(m as u64);
        counters.add_lmo();
        counters.add_iteration();
        // X <- (1 - eta) X + eta * (-theta u v^T)
        x.fw_rank_one_update(eta(k), -theta, &out.u, &out.v);
        if k % opts.eval_every == 0 || k == opts.iterations {
            trace.record(k, obj.loss_full_it(&x));
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::NativeEngine;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::nuclear_norm;
    use crate::objective::MatrixSensing;

    fn small_ms(seed: u64) -> Arc<dyn crate::objective::Objective> {
        let mut rng = Rng::new(seed);
        let p = MsParams { d1: 10, d2: 10, rank: 2, n: 2_000, noise_std: 0.05 };
        Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0))
    }

    #[test]
    fn init_is_on_nuclear_sphere() {
        let mut rng = Rng::new(50);
        let x = init_rank_one(7, 5, 2.0, &mut rng);
        assert!((nuclear_norm(&x) - 2.0).abs() < 1e-4);
    }

    #[test]
    fn sfw_decreases_loss_and_stays_feasible() {
        let obj = small_ms(51);
        let mut engine = NativeEngine::new(obj.clone(), 60, 52);
        let counters = Counters::new();
        let trace = LossTrace::new();
        let opts = SfwOptions {
            iterations: 120,
            batch: BatchSchedule::sfw(0.05, 2_000),
            eval_every: 20,
            seed: 53,
            repr: crate::linalg::Repr::Dense,
        };
        let x = run_sfw(&mut engine, &opts, &counters, &trace);
        let pts = trace.points();
        let first = pts.first().unwrap().loss;
        let last = pts.last().unwrap().loss;
        assert!(
            last < 0.3 * first,
            "SFW failed to make progress: {first} -> {last}"
        );
        // iterates stay in the nuclear ball (convex combination of feasible pts)
        assert!(nuclear_norm(&x.to_dense()) <= 1.0 + 1e-3);
        let s = counters.snapshot();
        assert_eq!(s.lmo_calls, 120);
        assert_eq!(s.iterations, 120);
        assert!(s.grad_evals > 0);
    }

    #[test]
    fn constant_batch_converges_to_neighborhood() {
        // Thm 3: fixed batch => converges to a noise floor, still useful.
        let obj = small_ms(54);
        let mut engine = NativeEngine::new(obj.clone(), 60, 55);
        let counters = Counters::new();
        let trace = LossTrace::new();
        let opts = SfwOptions {
            iterations: 150,
            batch: BatchSchedule::Constant(128),
            eval_every: 25,
            seed: 56,
            repr: crate::linalg::Repr::Dense,
        };
        run_sfw(&mut engine, &opts, &counters, &trace);
        let pts = trace.points();
        assert!(pts.last().unwrap().loss < 0.5 * pts.first().unwrap().loss);
        assert_eq!(counters.snapshot().grad_evals, 150 * 128);
    }
}
