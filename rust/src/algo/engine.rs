//! The per-worker compute engine abstraction.
//!
//! A `StepEngine` produces the two quantities Algorithm 3's workers need:
//! minibatch SUM-gradients and the nuclear-ball LMO (leading singular pair
//! of a gradient).  Two interchangeable implementations exist:
//!
//! * [`NativeEngine`] — pure-Rust math (linalg::power_iteration), used by
//!   baselines, tests and the queuing simulator;
//! * `runtime::PjrtEngine` — executes the AOT JAX/Pallas artifacts through
//!   the PJRT CPU client (the production hot path; Python-free).
//!
//! Integration tests pin the two to agree to f32 tolerance.

use std::sync::Arc;

use crate::linalg::{power_iteration, Iterate, Mat, Svd1};
use crate::objective::Objective;
use crate::util::rng::Rng;

/// Output of one fused worker step: LMO direction is `-theta * u v^T`.
#[derive(Clone, Debug)]
pub struct StepOut {
    pub u: Vec<f32>,
    pub v: Vec<f32>,
    pub sigma: f32,
    /// SUM of component losses over the minibatch (divide by m for mean).
    pub loss_sum: f64,
    /// True (un-padded) minibatch size.
    pub m: usize,
    /// Minibatch estimate of the FW dual gap
    /// `g = <grad F(X), X - S> = (<G_sum, X> + theta * sigma) / m`
    /// (`S = -theta u v^T` is the LMO direction, so `<G_sum, S> =
    /// -theta sigma` is already in hand) — nearly free on top of the
    /// fused step, and the paper's (Thms 1–4) stopping quantity.
    pub gap: f64,
}

/// Minibatch-mean FW dual-gap estimate from the SUM-gradient quantities
/// one fused step produces: `(<G_sum, X> + theta * sigma_sum) / m`.
/// Non-negative up to the power iteration's slight sigma underestimate.
#[inline]
pub fn mean_gap(grad_dot_x: f64, theta: f32, sigma: f32, m: usize) -> f64 {
    (grad_dot_x + theta as f64 * sigma as f64) / m.max(1) as f64
}

pub trait StepEngine: Send {
    /// Fused minibatch-gradient + LMO at `x` over sample indices `idx`.
    fn step(&mut self, x: &Mat, idx: &[usize]) -> StepOut;
    /// Minibatch SUM-gradient only (SVRF building block); returns loss_sum.
    fn grad_sum(&mut self, x: &Mat, idx: &[usize], out: &mut Mat) -> f64;
    /// LMO on an explicit gradient matrix.
    fn lmo(&mut self, g: &Mat) -> Svd1;
    /// Objective handle (dims, theta, loss evaluation).
    fn objective(&self) -> &Arc<dyn Objective>;

    /// Hand out (and take back) the dense render buffer the default
    /// `_it` fallbacks materialize a factored iterate into.  The default
    /// pair keeps no state — every call starts from an empty `Mat` and
    /// drops it — so engines that hit the fallback every step (the PJRT
    /// artifacts take dense inputs) override these with a cached buffer
    /// and the per-step O(d1 * d2) allocation disappears.
    fn take_dense_scratch(&mut self) -> Mat {
        Mat::zeros(0, 0)
    }
    fn put_dense_scratch(&mut self, _scratch: Mat) {}

    /// [`StepEngine::step`] against either iterate representation.  The
    /// default densifies a factored iterate into the engine's dense
    /// scratch (correct for any engine — the PJRT artifacts take dense
    /// inputs); `NativeEngine` overrides the whole method to evaluate
    /// the factored form directly.
    fn step_it(&mut self, x: &Iterate, idx: &[usize]) -> StepOut {
        match x {
            Iterate::Dense(m) => self.step(m, idx),
            Iterate::Factored(f) => {
                let mut dense = self.take_dense_scratch();
                f.write_dense_into(&mut dense);
                let out = self.step(&dense, idx);
                self.put_dense_scratch(dense);
                out
            }
        }
    }

    /// [`StepEngine::grad_sum`] against either iterate representation.
    fn grad_sum_it(&mut self, x: &Iterate, idx: &[usize], out: &mut Mat) -> f64 {
        match x {
            Iterate::Dense(m) => self.grad_sum(m, idx, out),
            Iterate::Factored(f) => {
                let mut dense = self.take_dense_scratch();
                f.write_dense_into(&mut dense);
                let loss = self.grad_sum(&dense, idx, out);
                self.put_dense_scratch(dense);
                loss
            }
        }
    }
}

/// Pure-Rust engine: exact mirror of the AOT artifact semantics.
pub struct NativeEngine {
    pub obj: Arc<dyn Objective>,
    pub power_iters: usize,
    pub tol: f64,
    rng: Rng,
    scratch: Mat,
    /// Power-iteration restart buffer, reused across calls so the fused
    /// gradient->LMO step allocates only its (u, v) outputs.
    v0: Vec<f32>,
}

impl NativeEngine {
    pub fn new(obj: Arc<dyn Objective>, power_iters: usize, seed: u64) -> Self {
        let (_, d2) = obj.dims();
        NativeEngine {
            obj,
            power_iters,
            tol: 1e-7,
            rng: Rng::new(seed),
            // Allocated on first dense use: sparse objectives route the
            // fused step through the COO gradient operator and never
            // need an O(d1 * d2) scratch, so completion dims can grow
            // past what a dense gradient buffer could hold.
            scratch: Mat::zeros(0, 0),
            v0: vec![0.0; d2],
        }
    }

    fn ensure_scratch(&mut self) {
        if self.scratch.rows == 0 {
            let (d1, d2) = self.obj.dims();
            self.scratch = Mat::zeros(d1, d2);
        }
    }

    /// LMO on the (already-filled) gradient scratch.
    fn lmo_on_scratch(&mut self) -> Svd1 {
        self.rng.fill_unit_vector(&mut self.v0);
        power_iteration(&self.scratch, &self.v0, self.power_iters, self.tol)
    }
}

impl StepEngine for NativeEngine {
    fn step(&mut self, x: &Mat, idx: &[usize]) -> StepOut {
        self.ensure_scratch();
        let loss_sum = self.obj.grad_sum(x, idx, &mut self.scratch);
        let gx = self.scratch.inner(x);
        let s = self.lmo_on_scratch();
        let gap = mean_gap(gx, self.obj.theta(), s.sigma, idx.len());
        StepOut { u: s.u, v: s.v, sigma: s.sigma, loss_sum, m: idx.len(), gap }
    }

    fn grad_sum(&mut self, x: &Mat, idx: &[usize], out: &mut Mat) -> f64 {
        self.obj.grad_sum(x, idx, out)
    }

    fn lmo(&mut self, g: &Mat) -> Svd1 {
        debug_assert_eq!(g.cols, self.v0.len());
        self.rng.fill_unit_vector(&mut self.v0);
        power_iteration(g, &self.v0, self.power_iters, self.tol)
    }

    /// Factored iterates are evaluated directly (factored inner
    /// products in the objective) — no dense X is ever built.  Sparse
    /// objectives go further: the whole fused step runs against the COO
    /// gradient operator, O(nnz) to build and O(nnz * k) in the LMO,
    /// touching nothing of size d1 * d2.
    fn step_it(&mut self, x: &Iterate, idx: &[usize]) -> StepOut {
        if let Some((g, loss_sum)) = self.obj.grad_sum_sparse(x, idx) {
            // <G, X> over the COO support only — O(nnz) via the entry
            // oracle, never touching a dense X.
            let gx: f64 = match x {
                Iterate::Dense(m) => g
                    .triples()
                    .map(|(i, j, v)| v as f64 * m.at(i, j) as f64)
                    .sum(),
                Iterate::Factored(f) => g
                    .triples()
                    .map(|(i, j, v)| v as f64 * f.entry(i, j) as f64)
                    .sum(),
            };
            self.rng.fill_unit_vector(&mut self.v0);
            let s = power_iteration(&g, &self.v0, self.power_iters, self.tol);
            let gap = mean_gap(gx, self.obj.theta(), s.sigma, idx.len());
            return StepOut { u: s.u, v: s.v, sigma: s.sigma, loss_sum, m: idx.len(), gap };
        }
        self.ensure_scratch();
        let loss_sum = self.obj.grad_sum_it(x, idx, &mut self.scratch);
        let gx = x.inner_flat(&self.scratch.data);
        let s = self.lmo_on_scratch();
        let gap = mean_gap(gx, self.obj.theta(), s.sigma, idx.len());
        StepOut { u: s.u, v: s.v, sigma: s.sigma, loss_sum, m: idx.len(), gap }
    }

    fn grad_sum_it(&mut self, x: &Iterate, idx: &[usize], out: &mut Mat) -> f64 {
        self.obj.grad_sum_it(x, idx, out)
    }

    fn objective(&self) -> &Arc<dyn Objective> {
        &self.obj
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::jacobi_svd;
    use crate::objective::MatrixSensing;

    fn engine() -> NativeEngine {
        let mut rng = Rng::new(40);
        let p = MsParams { d1: 6, d2: 5, rank: 2, n: 300, noise_std: 0.05 };
        let obj = Arc::new(MatrixSensing::new(
            MatrixSensingData::generate(&p, &mut rng),
            1.0,
        ));
        NativeEngine::new(obj, 100, 41)
    }

    #[test]
    fn step_matches_grad_plus_exact_svd() {
        let mut e = engine();
        let mut rng = Rng::new(42);
        let x = Mat::randn(6, 5, 0.2, &mut rng);
        let idx: Vec<usize> = (0..128).map(|_| rng.next_below(300)).collect();
        let out = e.step(&x, &idx);
        let mut g = Mat::zeros(6, 5);
        let loss = e.grad_sum(&x, &idx, &mut g);
        assert!((loss - out.loss_sum).abs() < 1e-9);
        let (_, s, _) = jacobi_svd(&g);
        assert!(
            (out.sigma - s[0]).abs() / s[0] < 1e-3,
            "sigma {} vs exact {}",
            out.sigma,
            s[0]
        );
        assert_eq!(out.m, 128);
    }

    #[test]
    fn sparse_step_matches_dense_gradient_lmo() {
        use crate::data::recommender::{RecParams, RecommenderData};
        use crate::linalg::FactoredMat;
        use crate::objective::SparseCompletion;
        let mut rng = Rng::new(44);
        let p = RecParams { rows: 18, cols: 10, rank: 2, density: 0.25, ..RecParams::default() };
        let obj: Arc<dyn Objective> =
            Arc::new(SparseCompletion::new(RecommenderData::generate(&p, &mut rng), 1.0));
        let mut f = FactoredMat::zeros(18, 10);
        for _ in 0..3 {
            f.push_atom(
                0.3 * rng.normal_f32(),
                Arc::new(rng.unit_vector(18)),
                Arc::new(rng.unit_vector(10)),
            );
        }
        let idx: Vec<usize> = (0..40).map(|_| rng.next_below(obj.n())).collect();
        // Same seed -> identical v0 draws, so the sparse-operator LMO
        // and the dense-scratch LMO see the same restart vector.
        let mut sparse_eng = NativeEngine::new(obj.clone(), 200, 45);
        let out = sparse_eng.step_it(&Iterate::Factored(f.clone()), &idx);
        let mut dense_eng = NativeEngine::new(obj.clone(), 200, 45);
        let mut g = Mat::zeros(18, 10);
        let loss = obj.grad_sum_factored(&f, &idx, &mut g);
        let s = dense_eng.lmo(&g);
        assert!((out.loss_sum - loss).abs() < 1e-6 * (1.0 + loss.abs()));
        assert!(
            (out.sigma - s.sigma).abs() < 1e-3 * (1.0 + s.sigma.abs()),
            "sigma {} vs {}",
            out.sigma,
            s.sigma
        );
        assert_eq!(out.m, 40);
        // Gap from the COO support matches the dense-gradient formula.
        let want = (g.inner(&f.to_dense()) + obj.theta() as f64 * s.sigma as f64) / 40.0;
        assert!(
            (out.gap - want).abs() < 1e-3 * (1.0 + want.abs()),
            "sparse gap {} vs dense {}",
            out.gap,
            want
        );
    }

    #[test]
    fn step_gap_matches_manual_inner_products() {
        let mut e = engine();
        let mut rng = Rng::new(46);
        let x = Mat::randn(6, 5, 0.2, &mut rng);
        let idx: Vec<usize> = (0..96).map(|_| rng.next_below(300)).collect();
        let out = e.step(&x, &idx);
        let mut g = Mat::zeros(6, 5);
        e.grad_sum(&x, &idx, &mut g);
        let want = (g.inner(&x) + e.obj.theta() as f64 * out.sigma as f64) / idx.len() as f64;
        assert!(
            (out.gap - want).abs() < 1e-9 * (1.0 + want.abs()),
            "gap {} vs manual {}",
            out.gap,
            want
        );
        // The gap is non-negative up to the power iteration's slight
        // sigma underestimate: sigma <= sigma_max, and <G, X> >= -theta
        // sigma_max on the theta-ball.
        assert!(out.gap > -1e-4, "gap {} unexpectedly negative", out.gap);
        // Factored iterate through step_it agrees: same seed -> same v0.
        let mut e2 = engine();
        let mut f = crate::linalg::FactoredMat::zeros(6, 5);
        let mut rx = Rng::new(47);
        f.push_atom(0.4, Arc::new(rx.unit_vector(6)), Arc::new(rx.unit_vector(5)));
        let fi = Iterate::Factored(f.clone());
        let out_f = e2.step_it(&fi, &idx);
        let mut e3 = engine();
        let out_d = e3.step_it(&Iterate::Dense(f.to_dense()), &idx);
        assert!(
            (out_f.gap - out_d.gap).abs() < 1e-4 * (1.0 + out_d.gap.abs()),
            "factored gap {} vs dense gap {}",
            out_f.gap,
            out_d.gap
        );
    }

    #[test]
    fn lmo_direction_maximizes_inner_product() {
        let mut e = engine();
        let mut rng = Rng::new(43);
        let g = Mat::randn(6, 5, 1.0, &mut rng);
        let s = e.lmo(&g);
        let mut best = 0.0f64;
        for i in 0..6 {
            for j in 0..5 {
                best += g.at(i, j) as f64 * s.u[i] as f64 * s.v[j] as f64;
            }
        }
        // u^T G v == sigma, and no random rank-one direction beats it
        assert!((best - s.sigma as f64).abs() < 1e-4);
        for _ in 0..20 {
            let a = rng.unit_vector(6);
            let b = rng.unit_vector(5);
            let mut c = 0.0f64;
            for i in 0..6 {
                for j in 0..5 {
                    c += g.at(i, j) as f64 * a[i] as f64 * b[j] as f64;
                }
            }
            assert!(c <= best + 1e-3);
        }
    }
}
