//! Serial algorithm family: SFW (Hazan & Luo), SVRF, PGD baseline, plus
//! the engine abstraction and the theorem schedules shared with the
//! distributed coordinator.

pub mod engine;
pub mod pgd;
pub mod schedule;
pub mod sfw;
pub mod svrf;

pub use engine::{NativeEngine, StepEngine, StepOut};
pub use schedule::{eta, svrf_epoch_len, BatchSchedule};
pub use sfw::{init_rank_one, run_sfw, SfwOptions};
