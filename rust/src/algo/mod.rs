//! Serial algorithm family: SFW (Hazan & Luo), SVRF, PGD baseline, plus
//! the engine abstraction and the theorem schedules shared with the
//! distributed coordinator.
//!
//! # Dual gap
//!
//! Every fused step estimates the Frank-Wolfe dual gap
//!
//! ```text
//! g_k = <grad F(X_k), X_k - s_k>,   s_k = argmin_{||S||_* <= theta} <grad F(X_k), S>
//! ```
//!
//! nearly for free: the LMO already computes `<grad, s_k> = -theta *
//! sigma`, so only the extra inner product `<grad, X_k>` is paid (see
//! [`StepOut::gap`] and [`engine::mean_gap`]).  On a convex objective
//! the gap upper-bounds the suboptimality `F(X_k) - F*`, which makes it
//! the principled stopping certificate: `TrainSpec::tol` ends any
//! registry solver's run once the estimate falls to the tolerance, and
//! the trace/sweep layers surface it as the `gap` column.
//!
//! # Step-size menu
//!
//! [`schedule::StepMethod`] selects how far to move along the LMO
//! direction each iteration (the `--step` knob):
//!
//! * `vanilla` — the theorem schedule `eta(k) = 2/(k+2)`;
//! * `analytic` — one-point quadratic fit along the segment, using the
//!   gap as the directional derivative;
//! * `line-search` — derivative-free golden-section search on a
//!   sampled minibatch loss;
//! * `armijo` — backtracking from the step cap until sufficient
//!   decrease;
//! * `away` / `pairwise` — away-step and pairwise Frank-Wolfe over the
//!   factored iterate's atom list (the active set): weight is shifted
//!   off (or dropped from) the worst active atom instead of always
//!   adding a new one, which caps rank while keeping every iterate a
//!   convex combination of atoms — feasible on the nuclear ball by
//!   construction.  Serial `sfw` + `--repr factored` only.
//!
//! All policies clamp to the feasible segment and fall back to the
//! vanilla schedule when their fit degenerates (non-finite slope, no
//! decrease found), so a policy can never diverge the run.

pub mod engine;
pub mod pgd;
pub mod schedule;
pub mod sfw;
pub mod svrf;

pub use engine::{mean_gap, NativeEngine, StepEngine, StepOut};
pub use schedule::{eta, select_eta, svrf_epoch_len, BatchSchedule, StepMethod};
pub use sfw::{init_rank_one, run_sfw, SfwOptions};
