//! Step-size and batch-size schedules from the paper's theorems.
//!
//! * eta_k = 2/(k+1)                                  (Thms 1–4)
//! * SFW (Hazan & Luo):        m_k = (G(k+1)/(L D))^2             (Thm 1 of HL16)
//! * SFW-asyn (Thm 1):         m_k = (G(k+1)/(tau L D))^2         — tau^2 smaller
//! * constant batch (Thm 3/4): m   = (G c/(L D))^2, resp. /tau^2
//! * SVRF-asyn (Thm 2):        m_k = 96(k+1)/tau, N_t = 2^{t+3}-2
//!
//! In practice G, L, D are unknown; the implementation exposes the scale
//! `(G/(L D))^2` as a single tunable (`scale`) with the paper's caps
//! (10 000 for matrix sensing, 3 000 for PNN — §5.1) applied on top.

/// Frank-Wolfe step size eta_k = 2 / (k + 1), k >= 1.
#[inline]
pub fn eta(k: u64) -> f32 {
    2.0 / (k as f32 + 1.0)
}

/// Minibatch-size schedule.
#[derive(Clone, Debug, PartialEq)]
pub enum BatchSchedule {
    /// m_k = clamp(ceil(scale * (k+1)^2), 1, cap) — the increasing schedule
    /// of SFW / SFW-asyn (for asyn, fold 1/tau^2 into `scale`).
    Increasing { scale: f64, cap: usize },
    /// m_k = m — Thm 3/4 constant batch.
    Constant(usize),
    /// m_k = clamp(ceil(scale * (k+1)), 1, cap) — SVRF inner schedule.
    Linear { scale: f64, cap: usize },
}

impl BatchSchedule {
    /// Paper SFW schedule with unit-free scale (G/(LD))^2 =: s.
    pub fn sfw(scale: f64, cap: usize) -> Self {
        BatchSchedule::Increasing { scale, cap }
    }

    /// Paper SFW-asyn schedule: tau^2 smaller than SFW's (Thm 1).
    pub fn sfw_asyn(scale: f64, tau: u64, cap: usize) -> Self {
        let t = (tau.max(1) as f64).powi(2);
        BatchSchedule::Increasing { scale: scale / t, cap }
    }

    /// SVRF-asyn inner schedule m_k = 96 (k+1) / tau (Thm 2).
    pub fn svrf_asyn(tau: u64, cap: usize) -> Self {
        BatchSchedule::Linear { scale: 96.0 / tau.max(1) as f64, cap }
    }

    /// Batch size at master iteration k (1-based).
    pub fn m(&self, k: u64) -> usize {
        match *self {
            BatchSchedule::Increasing { scale, cap } => {
                let v = (scale * ((k + 1) as f64).powi(2)).ceil() as usize;
                v.clamp(1, cap)
            }
            BatchSchedule::Constant(m) => m.max(1),
            BatchSchedule::Linear { scale, cap } => {
                let v = (scale * (k + 1) as f64).ceil() as usize;
                v.clamp(1, cap)
            }
        }
    }
}

/// SVRF outer-epoch length N_t = 2^{t+3} - 2 (Thm 2 / Hazan & Luo).
#[inline]
pub fn svrf_epoch_len(t: u32) -> u64 {
    (1u64 << (t + 3)) - 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eta_follows_two_over_kplus1() {
        assert_eq!(eta(1), 1.0);
        assert_eq!(eta(3), 0.5);
        assert!((eta(999) - 0.002).abs() < 1e-6);
    }

    #[test]
    fn increasing_schedule_is_quadratic_then_capped() {
        let s = BatchSchedule::sfw(1.0, 10_000);
        assert_eq!(s.m(1), 4);
        assert_eq!(s.m(9), 100);
        assert_eq!(s.m(99), 10_000);
        assert_eq!(s.m(1000), 10_000); // cap
    }

    #[test]
    fn asyn_schedule_is_tau_squared_smaller() {
        let tau = 4u64;
        let sfw = BatchSchedule::sfw(1.0, usize::MAX);
        let asyn = BatchSchedule::sfw_asyn(1.0, tau, usize::MAX);
        // skip tiny k where integer ceil dominates the ratio
        for k in [10u64, 50, 200] {
            let r = sfw.m(k) as f64 / asyn.m(k) as f64;
            // integer ceil wobble allowed
            assert!(
                (r - tau.pow(2) as f64).abs() / tau.pow(2) as f64 <= 0.25,
                "k={k}: ratio {r}"
            );
        }
    }

    #[test]
    fn constant_schedule_never_changes() {
        let s = BatchSchedule::Constant(64);
        for k in [1u64, 5, 1000] {
            assert_eq!(s.m(k), 64);
        }
    }

    #[test]
    fn linear_schedule_matches_svrf_formula() {
        let s = BatchSchedule::svrf_asyn(4, usize::MAX);
        assert_eq!(s.m(1), 48); // 96*2/4
        assert_eq!(s.m(9), 240); // 96*10/4
    }

    #[test]
    fn epoch_lengths_match_theorem2() {
        assert_eq!(svrf_epoch_len(0), 6);
        assert_eq!(svrf_epoch_len(1), 14);
        assert_eq!(svrf_epoch_len(2), 30);
    }

    #[test]
    fn batch_at_least_one() {
        let s = BatchSchedule::sfw_asyn(1e-6, 100, 10);
        assert_eq!(s.m(1), 1);
    }
}
