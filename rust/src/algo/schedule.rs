//! Step-size and batch-size schedules from the paper's theorems.
//!
//! * eta_k = 2/(k+1)                                  (Thms 1–4)
//! * SFW (Hazan & Luo):        m_k = (G(k+1)/(L D))^2             (Thm 1 of HL16)
//! * SFW-asyn (Thm 1):         m_k = (G(k+1)/(tau L D))^2         — tau^2 smaller
//! * constant batch (Thm 3/4): m   = (G c/(L D))^2, resp. /tau^2
//! * SVRF-asyn (Thm 2):        m_k = 96(k+1)/tau, N_t = 2^{t+3}-2
//!
//! In practice G, L, D are unknown; the implementation exposes the scale
//! `(G/(L D))^2` as a single tunable (`scale`) with the paper's caps
//! (10 000 for matrix sensing, 3 000 for PNN — §5.1) applied on top.

/// Frank-Wolfe step size eta_k = 2 / (k + 1), k >= 1.
#[inline]
pub fn eta(k: u64) -> f32 {
    2.0 / (k as f32 + 1.0)
}

/// Step policy of one FW iteration: how far to move (`Vanilla`,
/// `Analytic`, `LineSearch`, `Armijo` pick the step size along the
/// standard FW direction) and — for the serial solvers — which direction
/// family to move in (`Away` / `Pairwise` additionally reweight or drop
/// atoms of the factored active set, both sized by exact line search).
///
/// * `vanilla`     — eta_k = 2/(k+1) (Thms 1–4; the paper's schedule).
/// * `analytic`    — quadratic-fit exact step: fit phi(eta) = F((1-eta)X
///   + eta S) from phi(0), phi'(0) = -gap and one probe; exact for the
///   quadratic objectives (matrix sensing, completion), clamped to (0, 1].
/// * `line-search` — derivative-free golden-section minimization of the
///   minibatch objective over eta in [0, 1].
/// * `armijo`      — backtracking from eta = 1 until the sufficient
///   decrease phi(eta) <= phi(0) - c eta gap holds (c = 0.1).
/// * `away`        — away-step FW (Ding & Udell): when the away atom's
///   gap dominates, move mass off the worst active atom (dropping it at
///   the boundary step) instead of adding a new one.
/// * `pairwise`    — pairwise FW: shift mass directly from the worst
///   active atom onto the new LMO atom.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StepMethod {
    #[default]
    Vanilla,
    Analytic,
    LineSearch,
    Armijo,
    Away,
    Pairwise,
}

impl StepMethod {
    /// Accepted `--step` spellings, in menu order.
    pub const VALID: &'static [&'static str] =
        &["vanilla", "analytic", "line-search", "armijo", "away", "pairwise"];

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "vanilla" => Some(StepMethod::Vanilla),
            "analytic" => Some(StepMethod::Analytic),
            "line-search" => Some(StepMethod::LineSearch),
            "armijo" => Some(StepMethod::Armijo),
            "away" => Some(StepMethod::Away),
            "pairwise" => Some(StepMethod::Pairwise),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            StepMethod::Vanilla => "vanilla",
            StepMethod::Analytic => "analytic",
            StepMethod::LineSearch => "line-search",
            StepMethod::Armijo => "armijo",
            StepMethod::Away => "away",
            StepMethod::Pairwise => "pairwise",
        }
    }

    /// Away/pairwise steps mutate the factored active set — serial-only
    /// and factored-only; the masters reject them at spec validation.
    pub fn needs_active_set(&self) -> bool {
        matches!(self, StepMethod::Away | StepMethod::Pairwise)
    }
}

/// Pick the step size along a descent segment by evaluating the 1-D
/// restriction `phi(eta)` (a batch objective at the blended point) at a
/// handful of trial steps.  `loss0 = phi(0)`; `slope0 = phi'(0)` (the
/// negated FW gap — pass NaN when no gap estimate is in hand and the
/// gradient-free fits take over).  `eta_max` caps the feasible step
/// (1.0 for the standard FW segment; the away/pairwise boundary
/// otherwise).  Every branch falls back to `min(eta(k), eta_max)` when
/// its fit degenerates, so the policy can never stall or overshoot.
pub fn select_eta(
    method: StepMethod,
    k: u64,
    loss0: f64,
    slope0: f64,
    eta_max: f32,
    phi: &mut dyn FnMut(f32) -> f64,
) -> f32 {
    let cap = if eta_max.is_finite() && eta_max > 0.0 { eta_max.min(1.0) } else { 1.0 };
    let fallback = eta(k).min(cap);
    match method {
        StepMethod::Vanilla => fallback,
        StepMethod::Analytic => {
            // Quadratic fit phi(eta) ~= loss0 + slope0 eta + q eta^2 from
            // one probe at the fallback step; minimizer -slope0 / (2q).
            let probe = fallback.max(1e-3);
            let lp = phi(probe);
            let slope = if slope0.is_finite() {
                slope0
            } else {
                // no gap estimate: secant slope from a short probe
                let h = (probe * 0.25).max(1e-4);
                (phi(h) - loss0) / h as f64
            };
            let q = (lp - loss0 - slope * probe as f64) / (probe as f64).powi(2);
            if !(q.is_finite() && q > 0.0) || !slope.is_finite() || slope >= 0.0 {
                return fallback;
            }
            let star = (-slope / (2.0 * q)) as f32;
            if star.is_finite() && star > 0.0 {
                star.min(cap)
            } else {
                fallback
            }
        }
        StepMethod::LineSearch | StepMethod::Away | StepMethod::Pairwise => {
            // Golden-section search on [0, cap] — derivative-free, ~1e-2
            // relative bracket after 12 shrinks, one batch pass each.
            const INVPHI: f32 = 0.618_034;
            let (mut a, mut b) = (0.0f32, cap);
            let mut c = b - INVPHI * (b - a);
            let mut d = a + INVPHI * (b - a);
            let (mut fc, mut fd) = (phi(c), phi(d));
            for _ in 0..12 {
                if fc <= fd {
                    b = d;
                    d = c;
                    fd = fc;
                    c = b - INVPHI * (b - a);
                    fc = phi(c);
                } else {
                    a = c;
                    c = d;
                    fc = fd;
                    d = a + INVPHI * (b - a);
                    fd = phi(d);
                }
            }
            let star = 0.5 * (a + b);
            let fs = phi(star);
            if fs.is_finite() && fs <= loss0 {
                star.clamp(0.0, cap)
            } else {
                fallback
            }
        }
        StepMethod::Armijo => {
            let slope = if slope0.is_finite() && slope0 < 0.0 {
                slope0
            } else {
                let h = 1e-3f32.min(cap);
                let s = (phi(h) - loss0) / h as f64;
                if s.is_finite() && s < 0.0 {
                    s
                } else {
                    return fallback;
                }
            };
            const C: f64 = 0.1;
            let mut step = cap;
            for _ in 0..20 {
                if phi(step) <= loss0 + C * slope * step as f64 {
                    return step;
                }
                step *= 0.5;
            }
            fallback
        }
    }
}

/// Minibatch-size schedule.
#[derive(Clone, Debug, PartialEq)]
pub enum BatchSchedule {
    /// m_k = clamp(ceil(scale * (k+1)^2), 1, cap) — the increasing schedule
    /// of SFW / SFW-asyn (for asyn, fold 1/tau^2 into `scale`).
    Increasing { scale: f64, cap: usize },
    /// m_k = m — Thm 3/4 constant batch.
    Constant(usize),
    /// m_k = clamp(ceil(scale * (k+1)), 1, cap) — SVRF inner schedule.
    Linear { scale: f64, cap: usize },
}

impl BatchSchedule {
    /// Paper SFW schedule with unit-free scale (G/(LD))^2 =: s.
    pub fn sfw(scale: f64, cap: usize) -> Self {
        BatchSchedule::Increasing { scale, cap }
    }

    /// Paper SFW-asyn schedule: tau^2 smaller than SFW's (Thm 1).
    pub fn sfw_asyn(scale: f64, tau: u64, cap: usize) -> Self {
        let t = (tau.max(1) as f64).powi(2);
        BatchSchedule::Increasing { scale: scale / t, cap }
    }

    /// SVRF-asyn inner schedule m_k = 96 (k+1) / tau (Thm 2).
    pub fn svrf_asyn(tau: u64, cap: usize) -> Self {
        BatchSchedule::Linear { scale: 96.0 / tau.max(1) as f64, cap }
    }

    /// Batch size at master iteration k (1-based).
    pub fn m(&self, k: u64) -> usize {
        match *self {
            BatchSchedule::Increasing { scale, cap } => {
                let v = (scale * ((k + 1) as f64).powi(2)).ceil() as usize;
                v.clamp(1, cap)
            }
            BatchSchedule::Constant(m) => m.max(1),
            BatchSchedule::Linear { scale, cap } => {
                let v = (scale * (k + 1) as f64).ceil() as usize;
                v.clamp(1, cap)
            }
        }
    }
}

/// SVRF outer-epoch length N_t = 2^{t+3} - 2 (Thm 2 / Hazan & Luo).
#[inline]
pub fn svrf_epoch_len(t: u32) -> u64 {
    (1u64 << (t + 3)) - 2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eta_follows_two_over_kplus1() {
        assert_eq!(eta(1), 1.0);
        assert_eq!(eta(3), 0.5);
        assert!((eta(999) - 0.002).abs() < 1e-6);
    }

    #[test]
    fn increasing_schedule_is_quadratic_then_capped() {
        let s = BatchSchedule::sfw(1.0, 10_000);
        assert_eq!(s.m(1), 4);
        assert_eq!(s.m(9), 100);
        assert_eq!(s.m(99), 10_000);
        assert_eq!(s.m(1000), 10_000); // cap
    }

    #[test]
    fn asyn_schedule_is_tau_squared_smaller() {
        let tau = 4u64;
        let sfw = BatchSchedule::sfw(1.0, usize::MAX);
        let asyn = BatchSchedule::sfw_asyn(1.0, tau, usize::MAX);
        // skip tiny k where integer ceil dominates the ratio
        for k in [10u64, 50, 200] {
            let r = sfw.m(k) as f64 / asyn.m(k) as f64;
            // integer ceil wobble allowed
            assert!(
                (r - tau.pow(2) as f64).abs() / tau.pow(2) as f64 <= 0.25,
                "k={k}: ratio {r}"
            );
        }
    }

    #[test]
    fn constant_schedule_never_changes() {
        let s = BatchSchedule::Constant(64);
        for k in [1u64, 5, 1000] {
            assert_eq!(s.m(k), 64);
        }
    }

    #[test]
    fn linear_schedule_matches_svrf_formula() {
        let s = BatchSchedule::svrf_asyn(4, usize::MAX);
        assert_eq!(s.m(1), 48); // 96*2/4
        assert_eq!(s.m(9), 240); // 96*10/4
    }

    #[test]
    fn epoch_lengths_match_theorem2() {
        assert_eq!(svrf_epoch_len(0), 6);
        assert_eq!(svrf_epoch_len(1), 14);
        assert_eq!(svrf_epoch_len(2), 30);
    }

    #[test]
    fn batch_at_least_one() {
        let s = BatchSchedule::sfw_asyn(1e-6, 100, 10);
        assert_eq!(s.m(1), 1);
    }

    #[test]
    fn step_method_parse_round_trips_and_rejects_unknown() {
        for name in StepMethod::VALID {
            let m = StepMethod::parse(name).expect("every VALID entry parses");
            assert_eq!(m.label(), *name);
        }
        assert!(StepMethod::parse("exact").is_none());
        assert!(StepMethod::parse("").is_none());
        assert_eq!(StepMethod::default(), StepMethod::Vanilla);
        assert!(StepMethod::Away.needs_active_set());
        assert!(StepMethod::Pairwise.needs_active_set());
        assert!(!StepMethod::LineSearch.needs_active_set());
    }

    /// On a known 1-D quadratic phi(eta) = (eta - t)^2 + c every
    /// non-vanilla policy must land at (or near, or before) the true
    /// minimizer, and never above phi(0).
    #[test]
    fn select_eta_finds_quadratic_minimizer() {
        let t = 0.3f32;
        let quad = move |e: f32| ((e - t) as f64).powi(2) + 0.5;
        let loss0 = quad(0.0);
        let slope0 = -2.0 * t as f64; // phi'(0)
        let mut phi = quad;
        let ana = select_eta(StepMethod::Analytic, 5, loss0, slope0, 1.0, &mut phi);
        assert!((ana - t).abs() < 1e-3, "analytic step {ana} vs {t}");
        let mut phi = quad;
        let ls = select_eta(StepMethod::LineSearch, 5, loss0, slope0, 1.0, &mut phi);
        assert!((ls - t).abs() < 0.02, "line-search step {ls} vs {t}");
        let mut phi = quad;
        let ar = select_eta(StepMethod::Armijo, 5, loss0, slope0, 1.0, &mut phi);
        assert!(quad(ar) <= loss0, "armijo must not increase phi");
        // vanilla ignores phi entirely
        let mut phi = quad;
        assert_eq!(select_eta(StepMethod::Vanilla, 3, loss0, slope0, 1.0, &mut phi), eta(3));
    }

    #[test]
    fn select_eta_respects_eta_max_and_degenerate_fits() {
        // minimizer at 0.8 but the feasible boundary is 0.25
        let quad = |e: f32| ((e - 0.8) as f64).powi(2);
        let mut phi = quad;
        let s = select_eta(StepMethod::LineSearch, 4, quad(0.0), -1.6, 0.25, &mut phi);
        assert!(s <= 0.25 + 1e-6, "clamped step {s}");
        // an uphill segment (positive slope) falls back to eta(k)
        let uphill = |e: f32| e as f64;
        let mut phi = uphill;
        let s = select_eta(StepMethod::Analytic, 4, 0.0, 1.0, 1.0, &mut phi);
        assert_eq!(s, eta(4));
        let mut phi = uphill;
        let s = select_eta(StepMethod::Armijo, 4, 0.0, 1.0, 1.0, &mut phi);
        assert_eq!(s, eta(4));
    }
}
