//! Serial Stochastic Variance-Reduced Frank-Wolfe (Hazan & Luo 2016),
//! the base algorithm of the paper's Theorem 2 / Algorithms 4–5 extension.
//!
//! Epoch t: snapshot W, compute the full gradient ∇F(W) once, then run
//! N_t = 2^{t+3} - 2 inner FW iterations with the variance-reduced gradient
//!   ∇~ = (1/m) Σ_{i∈S} [∇f_i(X) - ∇f_i(W)] + ∇F(W).

use std::sync::Arc;

use crate::algo::engine::StepEngine;
use crate::algo::schedule::{eta, select_eta, svrf_epoch_len, BatchSchedule, StepMethod};
use crate::linalg::{Iterate, Mat, Repr};
use crate::metrics::{Counters, LossTrace};
use crate::util::rng::Rng;

pub struct SvrfOptions {
    pub epochs: u32,
    pub batch: BatchSchedule,
    pub eval_every: u64,
    pub seed: u64,
    /// Iterate representation (dense reference or factored atoms).
    pub repr: Repr,
    /// Stop once the VR-gradient dual-gap estimate falls to `tol`
    /// (0 disables).
    pub tol: f64,
    /// Step-size policy along the FW segment (away/pairwise are
    /// rejected upstream — SVRF has no persistent active-set bookkeeping).
    pub step: StepMethod,
}

impl Default for SvrfOptions {
    fn default() -> Self {
        SvrfOptions {
            epochs: 4,
            batch: BatchSchedule::Linear { scale: 96.0, cap: 4096 },
            eval_every: 10,
            seed: 0,
            repr: Repr::Dense,
            tol: 0.0,
            step: StepMethod::Vanilla,
        }
    }
}

/// Compute the full gradient at `w` in chunks (counts N gradient evals).
pub fn full_gradient<E: StepEngine + ?Sized>(
    engine: &mut E,
    w: &Iterate,
    counters: &Counters,
    out: &mut Mat,
) {
    let obj = engine.objective().clone();
    let n = obj.n();
    let all: Vec<usize> = (0..n).collect();
    let _ = engine.grad_sum_it(w, &all, out);
    out.scale(1.0 / n as f32);
    counters.add_grad_evals(n as u64);
}

pub fn run_svrf<E: StepEngine + ?Sized>(
    engine: &mut E,
    opts: &SvrfOptions,
    counters: &Counters,
    trace: &LossTrace,
) -> Iterate {
    let obj: Arc<dyn crate::objective::Objective> = engine.objective().clone();
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let n = obj.n();
    let mut rng = Rng::new(opts.seed);
    let mut x = Iterate::init_rank_one(opts.repr, d1, d2, theta, &mut rng);

    let mut full_g = Mat::zeros(d1, d2);
    let mut gx = Mat::zeros(d1, d2);
    let mut gw = Mat::zeros(d1, d2);
    let mut idx = Vec::new();
    let mut global_k = 0u64;

    trace.record(0, obj.loss_full_it(&x));
    'outer: for t in 0..opts.epochs {
        let w = x.clone();
        full_gradient(engine, &w, counters, &mut full_g);
        let nt = svrf_epoch_len(t);
        for k in 1..=nt {
            let m = opts.batch.m(k);
            rng.sample_indices(n, m, &mut idx);
            // VR gradient: (grad_sum(X) - grad_sum(W))/m + full_g
            let lx = engine.grad_sum_it(&x, &idx, &mut gx);
            let _ = engine.grad_sum_it(&w, &idx, &mut gw);
            counters.add_grad_evals(2 * m as u64);
            gx.axpy(-1.0, &gw);
            gx.scale(1.0 / m as f32);
            gx.axpy(1.0, &full_g);
            let s = engine.lmo(&gx);
            counters.add_lmo();
            counters.add_iteration();
            // gx is a MEAN gradient, so the gap estimate needs no /m.
            let gap = x.inner_flat(&gx.data) + theta as f64 * s.sigma as f64;
            let step_eta = if opts.step == StepMethod::Vanilla {
                eta(k)
            } else {
                // phi in batch-SUM units: slope = m * phi'(0)/m = -m*gap.
                let slope0 = -(gap * m as f64);
                select_eta(opts.step, k, lx, slope0, 1.0, &mut |e| {
                    let mut trial = x.clone();
                    trial.fw_rank_one_update(e, -theta, &s.u, &s.v);
                    obj.loss_batch_it(&trial, &idx)
                })
            };
            x.fw_rank_one_update(step_eta, -theta, &s.u, &s.v);
            global_k += 1;
            let stop = opts.tol > 0.0 && gap.is_finite() && gap <= opts.tol;
            if stop || global_k % opts.eval_every == 0 {
                trace.record_gap(global_k, obj.loss_full_it(&x), gap);
            }
            if stop {
                break 'outer;
            }
        }
        trace.record(global_k, obj.loss_full_it(&x));
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::NativeEngine;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::nuclear_norm;
    use crate::objective::{MatrixSensing, Objective};

    #[test]
    fn svrf_converges_on_small_sensing() {
        let mut rng = Rng::new(70);
        let p = MsParams { d1: 8, d2: 8, rank: 2, n: 1_500, noise_std: 0.05 };
        let obj = Arc::new(MatrixSensing::new(
            MatrixSensingData::generate(&p, &mut rng),
            1.0,
        ));
        let mut engine = NativeEngine::new(obj.clone(), 60, 71);
        let counters = Counters::new();
        let trace = LossTrace::new();
        let opts = SvrfOptions {
            epochs: 3,
            batch: BatchSchedule::Linear { scale: 24.0, cap: 1_500 },
            eval_every: 10,
            seed: 72,
            ..SvrfOptions::default()
        };
        let x = run_svrf(&mut engine, &opts, &counters, &trace);
        let pts = trace.points();
        assert!(
            pts.last().unwrap().loss < 0.3 * pts.first().unwrap().loss,
            "{} -> {}",
            pts.first().unwrap().loss,
            pts.last().unwrap().loss
        );
        assert!(nuclear_norm(&x.to_dense()) <= 1.0 + 1e-3);
        // inner iterations = N_0 + N_1 + N_2 = 6 + 14 + 30
        assert_eq!(counters.snapshot().lmo_calls, 50);
    }

    #[test]
    fn full_gradient_matches_mean_of_components() {
        let mut rng = Rng::new(73);
        let p = MsParams { d1: 4, d2: 4, rank: 1, n: 120, noise_std: 0.1 };
        let obj = Arc::new(MatrixSensing::new(
            MatrixSensingData::generate(&p, &mut rng),
            1.0,
        ));
        let mut engine = NativeEngine::new(obj.clone(), 30, 74);
        let counters = Counters::new();
        let x = Mat::randn(4, 4, 0.2, &mut rng);
        let mut fg = Mat::zeros(4, 4);
        full_gradient(&mut engine, &Iterate::Dense(x.clone()), &counters, &mut fg);
        let idx: Vec<usize> = (0..120).collect();
        let mut gs = Mat::zeros(4, 4);
        obj.grad_sum(&x, &idx, &mut gs);
        gs.scale(1.0 / 120.0);
        let mut d = fg.clone();
        d.axpy(-1.0, &gs);
        assert!(d.frob_norm() < 1e-6);
        assert_eq!(counters.snapshot().grad_evals, 120);
    }
}
