//! The `Objective` abstraction the whole algorithm family runs against:
//! problem (1) of the paper, `min_{||X||_* <= theta} (1/N) sum_i f_i(X)`.
//!
//! Implementations provide minibatch SUM-gradients over explicit index sets
//! (the worker-side computation of Algorithms 1–3) and full-objective
//! evaluation (the master-side reporting path).  Both paper workloads have
//! native Rust implementations here; the PJRT/AOT path in `runtime/` must
//! agree with these to f32 tolerance (enforced by integration tests).
//!
//! Every evaluation also exists against the factored iterate
//! ([`crate::linalg::FactoredMat`]): residuals/forward passes go through
//! factored inner products (`X` applied atom by atom) instead of a dense
//! materialization, and the `_it` dispatchers pick the path from the
//! [`Iterate`] variant.  Dense-vs-factored agreement to f32 tolerance is
//! pinned by `rust/tests/factored.rs`.

//! ## Sparse objectives
//!
//! [`SparseCompletion`] is the first objective whose gradient is sparse:
//! the minibatch SUM-gradient of matrix completion is nonzero only at
//! the sampled observed entries.  Such objectives additionally override
//! [`Objective::grad_sum_sparse`] to hand the engine the gradient as
//! [`CooMat`] triples — O(nnz) to build from factored dot products, and
//! O(nnz * k) for the operator-form power-iteration LMO — so neither the
//! gradient nor the iterate is ever densified.  The dense `grad_sum`
//! path stays implemented (scatter into the dense accumulator) for the
//! SVRF variance-reduction buffers and for agreement tests.

use std::sync::Arc;

use crate::data::{MatrixSensingData, PnnData, RecommenderData};
use crate::linalg::{CooMat, FactoredMat, Iterate, LinOp, Mat};

pub trait Objective: Send + Sync {
    /// (D1, D2) of the matrix variable.
    fn dims(&self) -> (usize, usize);
    /// Number of component functions N.
    fn n(&self) -> usize;
    /// Nuclear-ball radius theta.
    fn theta(&self) -> f32;
    /// Accumulate the SUM gradient of the sampled components into `out`
    /// (which is zeroed first); returns the SUM loss over the batch.
    /// Divide both by `idx.len()` for the minibatch mean.
    fn grad_sum(&self, x: &Mat, idx: &[usize], out: &mut Mat) -> f64;
    /// Full objective F(X).
    fn loss_full(&self, x: &Mat) -> f64;
    /// [`Objective::grad_sum`] against a factored iterate.  The default
    /// densifies; the paper workloads override it with factored inner
    /// products (no dense X is ever built).  The gradient itself stays a
    /// dense accumulator — it is a SUM over the minibatch, generally
    /// full-rank, and feeds the LMO.
    fn grad_sum_factored(&self, x: &FactoredMat, idx: &[usize], out: &mut Mat) -> f64 {
        self.grad_sum(&x.to_dense(), idx, out)
    }
    /// [`Objective::loss_full`] against a factored iterate (default
    /// densifies; workloads override with factored inner products).
    fn loss_full_factored(&self, x: &FactoredMat) -> f64 {
        self.loss_full(&x.to_dense())
    }
    /// Representation-dispatching gradient.
    fn grad_sum_it(&self, x: &Iterate, idx: &[usize], out: &mut Mat) -> f64 {
        match x {
            Iterate::Dense(m) => self.grad_sum(m, idx, out),
            Iterate::Factored(f) => self.grad_sum_factored(f, idx, out),
        }
    }
    /// Representation-dispatching full objective.
    fn loss_full_it(&self, x: &Iterate) -> f64 {
        match x {
            Iterate::Dense(m) => self.loss_full(m),
            Iterate::Factored(f) => self.loss_full_factored(f),
        }
    }
    /// SUM loss over the sampled components only — the phi(eta) oracle
    /// line-search step policies evaluate at trial iterates.  The default
    /// rides the gradient path and throws the gradient away; workloads
    /// override with a gradient-free pass (same residual/forward-pass
    /// loop, none of the accumulator work).
    fn loss_batch_it(&self, x: &Iterate, idx: &[usize]) -> f64 {
        let (d1, d2) = self.dims();
        let mut sink = Mat::zeros(d1, d2);
        self.grad_sum_it(x, idx, &mut sink)
    }
    /// Sparse fused-step support: when the minibatch SUM-gradient is
    /// nonzero only at O(|idx|) coordinates, return it as COO triples
    /// plus the batch SUM loss and the engine runs the power-iteration
    /// LMO against the sparse operator at O(nnz * k) instead of filling
    /// a dense scratch.  `None` (the default) keeps the dense path.
    fn grad_sum_sparse(&self, x: &Iterate, idx: &[usize]) -> Option<(CooMat, f64)> {
        let _ = (x, idx);
        None
    }
    /// Best known objective value (for relative-error reporting).
    fn f_star_hint(&self) -> f64 {
        0.0
    }
    /// Human-readable name for logs/benches.
    fn name(&self) -> &'static str;
}

/// Atom-response cache entries kept before the map is cleared wholesale.
/// Small: entries are only reused while their atoms survive recompression,
/// and each holds an O(n) vector.
const AV_CACHE_MAX: usize = 128;

/// Matrix sensing with nuclear-ball radius theta (paper uses theta = 1).
pub struct MatrixSensing {
    pub data: MatrixSensingData,
    pub theta: f32,
    /// Per-atom response vectors `c[i] = u^T A_i v` keyed by the factor
    /// Arcs' addresses.  The cached key Arcs are stored alongside the
    /// value, so a live entry pins its factors' allocations — an address
    /// can never be recycled into a colliding key while its entry exists.
    #[allow(clippy::type_complexity)]
    av_cache: std::sync::Mutex<
        std::collections::HashMap<(usize, usize), (Arc<Vec<f32>>, Arc<Vec<f32>>, Arc<Vec<f32>>)>,
    >,
}

impl MatrixSensing {
    pub fn new(data: MatrixSensingData, theta: f32) -> Self {
        MatrixSensing { data, theta, av_cache: std::sync::Mutex::new(Default::default()) }
    }

    /// `c[i] = u^T A_i v` over all N samples, cached by factor identity:
    /// FW atoms persist across iterations (only their weights rescale,
    /// and the update log shares the Arcs outright), so repeated
    /// full-loss evaluations pay the O(N * d1 * d2) pass once per atom.
    fn atom_response(&self, u: &Arc<Vec<f32>>, v: &Arc<Vec<f32>>) -> Arc<Vec<f32>> {
        let key = (Arc::as_ptr(u) as usize, Arc::as_ptr(v) as usize);
        if let Ok(map) = self.av_cache.lock() {
            if let Some((_, _, c)) = map.get(&key) {
                return c.clone();
            }
        }
        let d2 = self.data.d2;
        let mut c = vec![0.0f32; self.data.n];
        for (i, ci) in c.iter_mut().enumerate() {
            let row = self.data.af.row(i);
            let mut s = 0.0f64;
            for (r, &ur) in u.iter().enumerate() {
                if ur != 0.0 {
                    s += ur as f64 * crate::linalg::dot(&row[r * d2..(r + 1) * d2], v) as f64;
                }
            }
            *ci = s as f32;
        }
        let c = Arc::new(c);
        if let Ok(mut map) = self.av_cache.lock() {
            if map.len() >= AV_CACHE_MAX {
                map.clear();
            }
            map.insert(key, (u.clone(), v.clone(), c.clone()));
        }
        c
    }
}

impl Objective for MatrixSensing {
    fn dims(&self) -> (usize, usize) {
        (self.data.d1, self.data.d2)
    }
    fn n(&self) -> usize {
        self.data.n
    }
    fn theta(&self) -> f32 {
        self.theta
    }

    fn grad_sum(&self, x: &Mat, idx: &[usize], out: &mut Mat) -> f64 {
        debug_assert_eq!((x.rows, x.cols), (self.data.d1, self.data.d2));
        out.fill(0.0);
        let xf = &x.data;
        let g = &mut out.data;
        let mut loss = 0.0f64;
        for &i in idx {
            let row = self.data.af.row(i);
            let r = crate::linalg::dot(row, xf) - self.data.y[i];
            loss += (r as f64).powi(2);
            let c = 2.0 * r;
            for (gk, &ak) in g.iter_mut().zip(row.iter()) {
                *gk += c * ak;
            }
        }
        loss
    }

    fn loss_full(&self, x: &Mat) -> f64 {
        self.data.loss_full(x)
    }

    /// Residuals via the factored inner product `<A_i, X> =
    /// sum_j w_j u_j^T A_i v_j` — no dense X materialized.
    fn grad_sum_factored(&self, x: &FactoredMat, idx: &[usize], out: &mut Mat) -> f64 {
        debug_assert_eq!((x.rows, x.cols), (self.data.d1, self.data.d2));
        out.fill(0.0);
        let g = &mut out.data;
        let mut loss = 0.0f64;
        for &i in idx {
            let row = self.data.af.row(i);
            let r = x.inner_flat(row) - self.data.y[i];
            loss += (r as f64).powi(2);
            let c = 2.0 * r;
            for (gk, &ak) in g.iter_mut().zip(row.iter()) {
                *gk += c * ak;
            }
        }
        loss
    }

    /// Exact low-rank evaluation through the per-atom response caches:
    /// combine `w_k * c_k[i]` instead of re-touching every `A_i` for
    /// every atom — O(N * atoms) once the caches are warm, plus one
    /// O(N * d1 * d2) pass per atom not seen before.
    fn loss_full_factored(&self, x: &FactoredMat) -> f64 {
        debug_assert_eq!((x.rows, x.cols), (self.data.d1, self.data.d2));
        let mut pred = vec![0.0f64; self.data.n];
        for k in 0..x.atoms() {
            let (w, u, v) = x.atom(k);
            if w == 0.0 {
                continue;
            }
            let c = self.atom_response(u, v);
            for (p, &ci) in pred.iter_mut().zip(c.iter()) {
                *p += w as f64 * ci as f64;
            }
        }
        let mut acc = 0.0f64;
        for (p, &yi) in pred.iter().zip(self.data.y.iter()) {
            let r = p - yi as f64;
            acc += r * r;
        }
        acc / self.data.n as f64
    }

    /// Gradient-free batch loss: one residual per sample, no `g`
    /// accumulation — the cheap phi oracle for line searches.
    fn loss_batch_it(&self, x: &Iterate, idx: &[usize]) -> f64 {
        let mut loss = 0.0f64;
        for &i in idx {
            let row = self.data.af.row(i);
            let r = match x {
                Iterate::Dense(m) => crate::linalg::dot(row, &m.data) - self.data.y[i],
                Iterate::Factored(f) => f.inner_flat(row) - self.data.y[i],
            };
            loss += (r as f64).powi(2);
        }
        loss
    }

    fn f_star_hint(&self) -> f64 {
        self.data.f_star_hint
    }

    fn name(&self) -> &'static str {
        "matrix_sensing"
    }
}

/// Two-layer quadratic-activation PNN with smooth hinge loss.
pub struct Pnn {
    pub data: PnnData,
    pub theta: f32,
}

impl Pnn {
    pub fn new(data: PnnData, theta: f32) -> Self {
        Pnn { data, theta }
    }
}

impl Objective for Pnn {
    fn dims(&self) -> (usize, usize) {
        (self.data.d, self.data.d)
    }
    fn n(&self) -> usize {
        self.data.n
    }
    fn theta(&self) -> f32 {
        self.theta
    }

    fn grad_sum(&self, x: &Mat, idx: &[usize], out: &mut Mat) -> f64 {
        let d = self.data.d;
        debug_assert_eq!((x.rows, x.cols), (d, d));
        out.fill(0.0);
        let mut w = vec![0.0f32; d];
        let mut loss = 0.0f64;
        for &i in idx {
            let a = self.data.a.row(i);
            let yi = self.data.y[i];
            x.matvec(a, &mut w);
            let z = crate::linalg::dot(a, &w);
            let ty = yi * z;
            loss += PnnData::smooth_hinge(ty) as f64;
            let g = PnnData::smooth_hinge_dt(ty) * yi;
            if g == 0.0 {
                continue;
            }
            // out += g * a a^T
            for (r, &ar) in a.iter().enumerate() {
                let c = g * ar;
                if c == 0.0 {
                    continue;
                }
                let row = out.row_mut(r);
                for (o, &ac) in row.iter_mut().zip(a.iter()) {
                    *o += c * ac;
                }
            }
        }
        loss
    }

    fn loss_full(&self, x: &Mat) -> f64 {
        self.data.loss_full(x)
    }

    /// Forward pass `a^T X a` through the factored matvec — O(k d) per
    /// sample instead of O(d^2).  (The win is scoped to the forward
    /// pass: the `g a a^T` accumulation below stays O(d^2) whenever the
    /// hinge is active, same as the dense path.)
    fn grad_sum_factored(&self, x: &FactoredMat, idx: &[usize], out: &mut Mat) -> f64 {
        let d = self.data.d;
        debug_assert_eq!((x.rows, x.cols), (d, d));
        out.fill(0.0);
        let mut w = vec![0.0f32; d];
        let mut loss = 0.0f64;
        for &i in idx {
            let a = self.data.a.row(i);
            let yi = self.data.y[i];
            x.apply(a, &mut w);
            let z = crate::linalg::dot(a, &w);
            let ty = yi * z;
            loss += PnnData::smooth_hinge(ty) as f64;
            let g = PnnData::smooth_hinge_dt(ty) * yi;
            if g == 0.0 {
                continue;
            }
            for (r, &ar) in a.iter().enumerate() {
                let c = g * ar;
                if c == 0.0 {
                    continue;
                }
                let row = out.row_mut(r);
                for (o, &ac) in row.iter_mut().zip(a.iter()) {
                    *o += c * ac;
                }
            }
        }
        loss
    }

    fn loss_full_factored(&self, x: &FactoredMat) -> f64 {
        let d = self.data.d;
        debug_assert_eq!((x.rows, x.cols), (d, d));
        let mut w = vec![0.0f32; d];
        let mut acc = 0.0f64;
        for i in 0..self.data.n {
            let a = self.data.a.row(i);
            x.apply(a, &mut w);
            let z = crate::linalg::dot(a, &w);
            acc += PnnData::smooth_hinge(self.data.y[i] * z) as f64;
        }
        acc / self.data.n as f64
    }

    /// Gradient-free batch loss: the forward pass alone, skipping the
    /// O(d^2) `g a a^T` accumulation entirely.
    fn loss_batch_it(&self, x: &Iterate, idx: &[usize]) -> f64 {
        let d = self.data.d;
        let mut w = vec![0.0f32; d];
        let mut loss = 0.0f64;
        for &i in idx {
            let a = self.data.a.row(i);
            x.apply(a, &mut w);
            let z = crate::linalg::dot(a, &w);
            loss += PnnData::smooth_hinge(self.data.y[i] * z) as f64;
        }
        loss
    }

    fn name(&self) -> &'static str {
        "pnn"
    }
}

/// Sparse matrix completion over observed entries (the synthetic
/// recommender workload):
///   F(X) = (1/N) sum_{(i,j) in train} (X_ij - A_ij)^2,
///   s.t. ||X||_* <= theta.
///
/// Component t is one observed entry; its gradient is the single-entry
/// matrix `2 (X_ij - A_ij) e_i e_j^T`, so a minibatch SUM-gradient has
/// at most |batch| nonzeros.  With a factored iterate every residual is
/// an O(atoms) dot product ([`FactoredMat::entry`]) — no quantity in the
/// hot path ever scales with d1 * d2.
pub struct SparseCompletion {
    pub data: RecommenderData,
    pub theta: f32,
}

impl SparseCompletion {
    pub fn new(data: RecommenderData, theta: f32) -> Self {
        SparseCompletion { data, theta }
    }

    /// Residual of observed component `t`: `(i, j, X_ij - A_ij)` against
    /// either iterate representation.
    #[inline]
    fn residual_it(&self, x: &Iterate, t: usize) -> (usize, usize, f32) {
        let (i, j, a) = self.data.triple(t);
        let xij = match x {
            Iterate::Dense(m) => m.at(i, j),
            Iterate::Factored(f) => f.entry(i, j),
        };
        (i, j, xij - a)
    }
}

impl Objective for SparseCompletion {
    fn dims(&self) -> (usize, usize) {
        (self.data.rows, self.data.cols)
    }
    fn n(&self) -> usize {
        self.data.train_nnz()
    }
    fn theta(&self) -> f32 {
        self.theta
    }

    /// Dense scatter path (SVRF accumulators, agreement tests): O(nnz)
    /// work after the O(d1 * d2) zero-fill of `out`.
    fn grad_sum(&self, x: &Mat, idx: &[usize], out: &mut Mat) -> f64 {
        debug_assert_eq!((x.rows, x.cols), (self.data.rows, self.data.cols));
        out.fill(0.0);
        let mut loss = 0.0f64;
        for &t in idx {
            let (i, j, a) = self.data.triple(t);
            let r = x.at(i, j) - a;
            loss += (r as f64).powi(2);
            *out.at_mut(i, j) += 2.0 * r;
        }
        loss
    }

    fn loss_full(&self, x: &Mat) -> f64 {
        self.data.loss_full(x)
    }

    fn grad_sum_factored(&self, x: &FactoredMat, idx: &[usize], out: &mut Mat) -> f64 {
        debug_assert_eq!((x.rows, x.cols), (self.data.rows, self.data.cols));
        out.fill(0.0);
        let mut loss = 0.0f64;
        for &t in idx {
            let (i, j, a) = self.data.triple(t);
            let r = x.entry(i, j) - a;
            loss += (r as f64).powi(2);
            *out.at_mut(i, j) += 2.0 * r;
        }
        loss
    }

    fn loss_full_factored(&self, x: &FactoredMat) -> f64 {
        debug_assert_eq!((x.rows, x.cols), (self.data.rows, self.data.cols));
        let n = self.data.train_nnz();
        let mut acc = 0.0f64;
        for t in 0..n {
            let (i, j, a) = self.data.triple(t);
            let r = x.entry(i, j) - a;
            acc += (r as f64).powi(2);
        }
        acc / n.max(1) as f64
    }

    /// The O(nnz) fused-step path: residuals via factored dot products,
    /// gradient handed over as COO triples for the sparse-operator LMO.
    fn grad_sum_sparse(&self, x: &Iterate, idx: &[usize]) -> Option<(CooMat, f64)> {
        let (d1, d2) = self.dims();
        let mut g = CooMat::with_capacity(d1, d2, idx.len());
        let mut loss = 0.0f64;
        for &t in idx {
            let (i, j, r) = self.residual_it(x, t);
            loss += (r as f64).powi(2);
            g.push(i, j, 2.0 * r);
        }
        Some((g, loss))
    }

    /// Gradient-free batch loss: residuals through the entry oracle, no
    /// COO build and no dense scatter.
    fn loss_batch_it(&self, x: &Iterate, idx: &[usize]) -> f64 {
        let mut loss = 0.0f64;
        for &t in idx {
            let (_, _, r) = self.residual_it(x, t);
            loss += (r as f64).powi(2);
        }
        loss
    }

    fn f_star_hint(&self) -> f64 {
        self.data.f_star_hint
    }

    fn name(&self) -> &'static str {
        "sparse_completion"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix_sensing::MsParams;
    use crate::data::pnn::PnnParams;
    use crate::util::rng::Rng;

    fn fd_check<O: Objective>(obj: &O, x: &Mat, idx: &[usize], probes: &[(usize, usize)]) {
        let (d1, d2) = obj.dims();
        let mut g = Mat::zeros(d1, d2);
        let loss0 = obj.grad_sum(x, idx, &mut g);
        let _ = loss0;
        let eps = 1e-3f32;
        for &(i, j) in probes {
            let mut xp = x.clone();
            *xp.at_mut(i, j) += eps;
            let mut xm = x.clone();
            *xm.at_mut(i, j) -= eps;
            let mut scratch = Mat::zeros(d1, d2);
            let lp = obj.grad_sum(&xp, idx, &mut scratch);
            let lm = obj.grad_sum(&xm, idx, &mut scratch);
            let fd = (lp - lm) / (2.0 * eps as f64);
            let an = g.at(i, j) as f64;
            assert!(
                (fd - an).abs() < 2e-1 * (1.0 + an.abs()),
                "{} ({i},{j}): fd {fd} vs analytic {an}",
                obj.name()
            );
        }
    }

    #[test]
    fn ms_grad_is_true_gradient() {
        let mut rng = Rng::new(31);
        let p = MsParams { d1: 5, d2: 4, rank: 2, n: 200, noise_std: 0.1 };
        let obj = MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0);
        let x = Mat::randn(5, 4, 0.2, &mut rng);
        let idx: Vec<usize> = (0..64).map(|_| rng.next_below(200)).collect();
        fd_check(&obj, &x, &idx, &[(0, 0), (2, 3), (4, 1)]);
    }

    #[test]
    fn pnn_grad_is_true_gradient() {
        let mut rng = Rng::new(32);
        let p = PnnParams { d: 6, n: 200, teacher_rank: 2, mixture_components: 3 };
        let obj = Pnn::new(PnnData::generate(&p, &mut rng), 1.0);
        let x = Mat::randn(6, 6, 0.1, &mut rng);
        let idx: Vec<usize> = (0..64).map(|_| rng.next_below(200)).collect();
        fd_check(&obj, &x, &idx, &[(0, 0), (1, 4), (5, 5)]);
    }

    #[test]
    fn factored_grad_and_loss_match_dense_paths() {
        use crate::linalg::FactoredMat;
        use std::sync::Arc as StdArc;
        let mut rng = Rng::new(34);
        let ms_p = MsParams { d1: 6, d2: 5, rank: 2, n: 250, noise_std: 0.1 };
        let ms = MatrixSensing::new(MatrixSensingData::generate(&ms_p, &mut rng), 1.0);
        let pnn_p = PnnParams { d: 7, n: 250, teacher_rank: 2, mixture_components: 3 };
        let pnn = Pnn::new(PnnData::generate(&pnn_p, &mut rng), 1.0);
        let objs: [&dyn Objective; 2] = [&ms, &pnn];
        for obj in objs {
            let (d1, d2) = obj.dims();
            let mut f = FactoredMat::zeros(d1, d2);
            for _ in 0..5 {
                f.push_atom(
                    0.4 * rng.normal_f32(),
                    StdArc::new(rng.unit_vector(d1)),
                    StdArc::new(rng.unit_vector(d2)),
                );
            }
            let dense = f.to_dense();
            let idx: Vec<usize> = (0..48).map(|_| rng.next_below(250)).collect();
            let mut gd = Mat::zeros(d1, d2);
            let mut gf = Mat::zeros(d1, d2);
            let ld = obj.grad_sum(&dense, &idx, &mut gd);
            let lf = obj.grad_sum_factored(&f, &idx, &mut gf);
            assert!(
                (ld - lf).abs() < 1e-4 * (1.0 + ld.abs()),
                "{}: batch loss {ld} vs {lf}",
                obj.name()
            );
            let mut diff = gd.clone();
            diff.axpy(-1.0, &gf);
            assert!(
                diff.frob_norm() < 1e-4 * (1.0 + gd.frob_norm()),
                "{}: grad diff {}",
                obj.name(),
                diff.frob_norm()
            );
            let full_d = obj.loss_full(&dense);
            let full_f = obj.loss_full_factored(&f);
            assert!(
                (full_d - full_f).abs() < 1e-5 * (1.0 + full_d.abs()),
                "{}: full loss {full_d} vs {full_f}",
                obj.name()
            );
        }
    }

    #[test]
    fn ms_cached_factored_loss_stays_exact_as_atoms_evolve() {
        use crate::linalg::FactoredMat;
        let mut rng = Rng::new(35);
        let p = MsParams { d1: 6, d2: 5, rank: 2, n: 300, noise_std: 0.1 };
        let obj = MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0);
        let mut f = FactoredMat::zeros(6, 5);
        for k in 1..=8u64 {
            let eta = 2.0 / (k as f32 + 1.0);
            let (u, v) = (rng.unit_vector(6), rng.unit_vector(5));
            f.fw_rank_one_update(eta, -1.0, &u, &v);
            let want = obj.loss_full(&f.to_dense());
            // cold cache (new atom) then warm cache must both match
            for _ in 0..2 {
                let got = obj.loss_full_factored(&f);
                assert!(
                    (got - want).abs() < 1e-5 * (1.0 + want.abs()),
                    "iter {k}: cached {got} vs dense {want}"
                );
            }
        }
    }

    #[test]
    fn sparse_completion_gradient_paths_agree() {
        use crate::data::recommender::{RecParams, RecommenderData};
        use crate::linalg::FactoredMat;
        use std::sync::Arc as StdArc;
        let mut rng = Rng::new(36);
        let p = RecParams { rows: 20, cols: 12, rank: 2, density: 0.2, ..RecParams::default() };
        let obj = SparseCompletion::new(RecommenderData::generate(&p, &mut rng), 1.0);
        let (d1, d2) = obj.dims();
        let mut f = FactoredMat::zeros(d1, d2);
        for _ in 0..4 {
            f.push_atom(
                0.3 * rng.normal_f32(),
                StdArc::new(rng.unit_vector(d1)),
                StdArc::new(rng.unit_vector(d2)),
            );
        }
        let dense = f.to_dense();
        let idx: Vec<usize> = (0..32).map(|_| rng.next_below(obj.n())).collect();
        fd_check(&obj, &dense, &idx, &[(0, 0), (7, 3), (19, 11)]);
        let mut gd = Mat::zeros(d1, d2);
        let mut gf = Mat::zeros(d1, d2);
        let ld = obj.grad_sum(&dense, &idx, &mut gd);
        let lf = obj.grad_sum_factored(&f, &idx, &mut gf);
        assert!((ld - lf).abs() < 1e-4 * (1.0 + ld.abs()), "batch loss {ld} vs {lf}");
        let mut diff = gd.clone();
        diff.axpy(-1.0, &gf);
        assert!(diff.frob_norm() < 1e-4 * (1.0 + gd.frob_norm()));
        // the COO fused-step gradient is the same matrix again
        let (coo, ls) = obj
            .grad_sum_sparse(&Iterate::Factored(f.clone()), &idx)
            .expect("sparse objective must provide the sparse path");
        assert!(coo.nnz() <= idx.len());
        assert!((ls - ld).abs() < 1e-4 * (1.0 + ld.abs()));
        let mut cdiff = coo.to_dense();
        cdiff.axpy(-1.0, &gd);
        assert!(cdiff.frob_norm() < 1e-4 * (1.0 + gd.frob_norm()));
        // full losses agree across representations
        let full_d = obj.loss_full(&dense);
        let full_f = obj.loss_full_factored(&f);
        assert!((full_d - full_f).abs() < 1e-5 * (1.0 + full_d.abs()));
    }

    #[test]
    fn full_batch_grad_sum_equals_loss_full_consistency() {
        // grad_sum over ALL indices must return N * loss_full as its loss.
        let mut rng = Rng::new(33);
        let p = MsParams { d1: 4, d2: 4, rank: 1, n: 100, noise_std: 0.1 };
        let obj = MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0);
        let x = Mat::randn(4, 4, 0.3, &mut rng);
        let idx: Vec<usize> = (0..100).collect();
        let mut g = Mat::zeros(4, 4);
        let loss_sum = obj.grad_sum(&x, &idx, &mut g);
        assert!((loss_sum / 100.0 - obj.loss_full(&x)).abs() < 1e-6);
    }

    #[test]
    fn loss_batch_it_matches_grad_sum_loss_on_every_objective() {
        use crate::data::recommender::{RecParams, RecommenderData};
        use crate::linalg::FactoredMat;
        use std::sync::Arc as StdArc;
        let mut rng = Rng::new(37);
        let ms_p = MsParams { d1: 6, d2: 5, rank: 2, n: 250, noise_std: 0.1 };
        let ms = MatrixSensing::new(MatrixSensingData::generate(&ms_p, &mut rng), 1.0);
        let pnn_p = PnnParams { d: 6, n: 250, teacher_rank: 2, mixture_components: 3 };
        let pnn = Pnn::new(PnnData::generate(&pnn_p, &mut rng), 1.0);
        let rec_p =
            RecParams { rows: 18, cols: 10, rank: 2, density: 0.25, ..RecParams::default() };
        let sc = SparseCompletion::new(RecommenderData::generate(&rec_p, &mut rng), 1.0);
        let objs: [&dyn Objective; 3] = [&ms, &pnn, &sc];
        for obj in objs {
            let (d1, d2) = obj.dims();
            let mut f = FactoredMat::zeros(d1, d2);
            for _ in 0..4 {
                f.push_atom(
                    0.3 * rng.normal_f32(),
                    StdArc::new(rng.unit_vector(d1)),
                    StdArc::new(rng.unit_vector(d2)),
                );
            }
            let idx: Vec<usize> = (0..40).map(|_| rng.next_below(obj.n())).collect();
            for x in [Iterate::Dense(f.to_dense()), Iterate::Factored(f.clone())] {
                let mut sink = Mat::zeros(d1, d2);
                let want = obj.grad_sum_it(&x, &idx, &mut sink);
                let got = obj.loss_batch_it(&x, &idx);
                assert!(
                    (got - want).abs() < 1e-4 * (1.0 + want.abs()),
                    "{} ({:?}): batch loss {got} vs grad-path {want}",
                    obj.name(),
                    x.repr()
                );
            }
        }
    }
}
