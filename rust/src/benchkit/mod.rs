//! Micro/macro benchmark harness (`criterion` is not in the offline crate
//! set).  Provides warmed-up, repeated timing with mean/σ/percentiles and
//! aligned table/CSV printers used by every `rust/benches/*` target to
//! regenerate the paper's tables and figures.

use std::time::{Duration, Instant};

/// Timing statistics over repeated runs.
#[derive(Debug, Clone)]
pub struct Stats {
    pub n: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p90_s: f64,
    pub max_s: f64,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let pct = |p: f64| samples[(((n - 1) as f64) * p).round() as usize];
        Stats {
            n,
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: samples[0],
            p50_s: pct(0.5),
            p90_s: pct(0.9),
            max_s: samples[n - 1],
        }
    }

    pub fn mean_human(&self) -> String {
        humanize(self.mean_s)
    }
}

/// Format seconds into an appropriate unit.
pub fn humanize(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let samples = (0..iters.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    Stats::from_samples(samples)
}

/// Time `f` adaptively: run until `budget` elapsed (at least 3 iters).
pub fn bench_for<F: FnMut()>(warmup: usize, budget: Duration, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < 3 || start.elapsed() < budget {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if samples.len() > 10_000 {
            break;
        }
    }
    Stats::from_samples(samples)
}

/// Aligned table printer (also emits CSV alongside when `csv_path` given).
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!("{:<width$}  ", c, width = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }

    /// Write rows as CSV (headers first) — benches drop these in bench_out/.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        std::fs::write(path, out)
    }
}

/// Format a float with fixed significant digits for table cells.
pub fn sig(x: f64, digits: usize) -> String {
    if x == 0.0 || !x.is_finite() {
        return format!("{x}");
    }
    let mag = x.abs().log10().floor() as i32;
    let dec = (digits as i32 - 1 - mag).max(0) as usize;
    format!("{:.*}", dec, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean_s - 3.0).abs() < 1e-12);
        assert!((s.p50_s - 3.0).abs() < 1e-12);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 5.0);
        assert!((s.std_s - (2.0f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn bench_measures_work() {
        let mut acc = 0u64;
        let s = bench(1, 5, || {
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
        });
        assert_eq!(s.n, 5);
        assert!(s.mean_s >= 0.0);
        assert!(acc > 0);
    }

    #[test]
    fn humanize_units() {
        assert!(humanize(5e-9).contains("ns"));
        assert!(humanize(5e-6).contains("µs"));
        assert!(humanize(5e-3).contains("ms"));
        assert!(humanize(5.0).contains(" s"));
    }

    #[test]
    fn table_roundtrip_csv() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let path = std::env::temp_dir().join("sfw_bench_test.csv");
        t.write_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }

    #[test]
    fn sig_digits() {
        assert_eq!(sig(1234.5, 3), "1234"); // round-half-even
        assert_eq!(sig(0.012345, 3), "0.0123");
    }
}
