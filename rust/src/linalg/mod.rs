//! Dense + factored linear-algebra substrate (no external BLAS in the
//! offline build).
//!
//! `kernels` — the SIMD (AVX2+FMA, runtime-dispatched) + scoped-thread
//! compute kernels every hot loop routes through, deterministic by
//! construction: results are bit-identical across SIMD width and thread
//! count (see the [`kernels`] module docs for the contract);
//! `mat` — row-major f32 matrices with allocation-free hot-loop ops;
//! `op` — the [`LinOp`] implicit-operator trait the LMO runs against;
//! `factored` — [`FactoredMat`], the iterate as a rank-one atom list
//! (O((d1+d2)*k) memory/bytes instead of O(d1*d2); see the ROADMAP's
//! "Iterate representation" section);
//! `feedback` — [`ErrorFeedback`], the per-worker quantization-residual
//! accumulator for the compressed gradient uplink
//! ([`crate::comms::GradCodec`]);
//! `iterate` — [`Iterate`]/[`Repr`], the dense-or-factored iterate every
//! solver threads through (chosen per run by `TrainSpec::repr`);
//! `sparse` — [`CooMat`], COO triples behind [`LinOp`]: the O(nnz)
//! minibatch gradient of sparse matrix completion, so the LMO never
//! densifies it;
//! `svd` — operator-form power-iteration 1-SVD (the FW LMO) + one-sided
//! Jacobi full SVD;
//! `project` — simplex / l1 / nuclear-ball Euclidean projections (PGD
//! baseline; FW famously avoids these).
//!
//! The wire-level counterpart of the factored form lives in
//! [`crate::coordinator::messages`] (`DistDown::ComputeFactored`
//! broadcasts atoms instead of the dense X) and
//! [`crate::coordinator::update_log`] (log entries ARE the atoms).

pub mod factored;
pub mod feedback;
pub mod iterate;
pub mod kernels;
pub mod mat;
pub mod op;
pub mod project;
pub mod sparse;
pub mod svd;

pub use factored::FactoredMat;
pub use feedback::ErrorFeedback;
pub use iterate::{dense_rank, Iterate, Repr};
pub use mat::{dot, norm2, normalize, Mat};
pub use op::LinOp;
pub use sparse::CooMat;
pub use project::{
    factored_nuclear_projection, l1_projection, nuclear_ball_projection, simplex_projection,
};
pub use svd::{
    jacobi_svd, nuclear_norm, numerical_rank, power_iteration, power_iteration_rand, Svd1,
};
