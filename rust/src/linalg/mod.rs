//! Dense linear-algebra substrate (no external BLAS in the offline build).
//!
//! `mat` — row-major f32 matrices with allocation-free hot-loop ops;
//! `svd` — power-iteration 1-SVD (the FW LMO) + one-sided Jacobi full SVD;
//! `project` — simplex / l1 / nuclear-ball Euclidean projections (PGD
//! baseline; FW famously avoids these).

pub mod mat;
pub mod project;
pub mod svd;

pub use mat::{dot, norm2, normalize, Mat};
pub use project::{l1_projection, nuclear_ball_projection, simplex_projection};
pub use svd::{jacobi_svd, nuclear_norm, power_iteration, power_iteration_rand, Svd1};
