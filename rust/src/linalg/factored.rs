//! [`FactoredMat`]: the Frank-Wolfe iterate in factored (atom-list) form.
//!
//! FW over the nuclear ball only ever moves along rank-one atoms:
//! `X_k = (1 - eta_k) X_{k-1} + eta_k * scale_k * u_k v_k^T` (Eqn 6).
//! Instead of a dense `d1 x d2` array, this type stores the atoms
//! themselves — `X = sum_i w_i * u_i v_i^T` — which cuts iterate memory,
//! snapshot cost and broadcast bytes from `O(d1*d2)` to `O((d1+d2)*k)`,
//! where `k` is the atom count.  The factors are `Arc`'d so a worker
//! replaying the master's update-log slice shares the log entries'
//! vectors outright: the log entries ARE the atoms (see
//! [`crate::coordinator::update_log`]).
//!
//! A re-compression pass keeps `k` bounded: negligible-weight atoms are
//! dropped eagerly, and when the list exceeds its cap the iterate is
//! re-factorized through an exact SVD (rank <= min(d1, d2) always, so
//! this merges redundant directions without losing the iterate beyond
//! f32 round-off — pinned by a property test).

use std::sync::Arc;

use super::kernels;
use super::mat::{dot, norm2, Mat};
use super::op::LinOp;
use super::svd::jacobi_svd;

/// Relative weight threshold below which an atom is dropped eagerly.
const DROP_REL: f32 = 1e-9;
/// Relative singular-value threshold of the SVD re-factorization.
const SVD_REL: f32 = 1e-7;
/// Atoms per reduction block in the chunked `apply`/`tapply`/`apply_dot`
/// paths: fixed-size blocks whose zeroed partials are combined in block
/// order, so the partition depends only on the atom count — never the
/// thread budget (the kernels determinism contract).
const ATOM_CHUNK: usize = 8;

/// A matrix held as a weighted sum of rank-one atoms
/// `X = sum_i w_i u_i v_i^T`.
#[derive(Clone, Debug)]
pub struct FactoredMat {
    pub rows: usize,
    pub cols: usize,
    w: Vec<f32>,
    us: Vec<Arc<Vec<f32>>>,
    vs: Vec<Arc<Vec<f32>>>,
    cap: usize,
    peak: usize,
}

impl FactoredMat {
    /// Empty (zero) matrix with the default atom cap
    /// `2 * min(rows, cols) + 16` — large enough that the SVD
    /// re-factorization (which can return up to `min(rows, cols)` atoms)
    /// always relieves the pressure.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self::with_cap(rows, cols, 2 * rows.min(cols) + 16)
    }

    /// Empty matrix with an explicit atom cap.  Caps below
    /// `min(rows, cols) + 8` are raised to it: re-compression is exact
    /// (SVD), so a cap under the true max rank could thrash.
    pub fn with_cap(rows: usize, cols: usize, cap: usize) -> Self {
        FactoredMat {
            rows,
            cols,
            w: Vec::new(),
            us: Vec::new(),
            vs: Vec::new(),
            cap: cap.max(rows.min(cols) + 8),
            peak: 0,
        }
    }

    /// Build `U diag(s) V^T` as an atom list from an SVD triple
    /// (columns of `u`/`v`; `s` sorted descending, `jacobi_svd`'s
    /// contract), skipping singular values `<= cutoff`.  The ONE
    /// SVD-to-atoms conversion — used by both the re-compression pass
    /// and the factored nuclear projection.
    pub fn from_svd(u: &Mat, s: &[f32], v: &Mat, cutoff: f32) -> FactoredMat {
        let mut f = FactoredMat::zeros(u.rows, v.rows);
        for (k, &sk) in s.iter().enumerate() {
            if sk <= cutoff {
                break; // descending order: nothing larger follows
            }
            let uk: Vec<f32> = (0..u.rows).map(|i| u.at(i, k)).collect();
            let vk: Vec<f32> = (0..v.rows).map(|i| v.at(i, k)).collect();
            f.push_atom(sk, Arc::new(uk), Arc::new(vk));
        }
        f
    }

    /// Current atom count (an upper bound on the rank).
    pub fn atoms(&self) -> usize {
        self.w.len()
    }

    /// Largest atom count ever held (before re-compression ran).
    pub fn peak_atoms(&self) -> usize {
        self.peak
    }

    /// Raise the recorded peak (callers that rebuild the factored form
    /// from scratch each step carry the run-wide peak through this).
    pub fn note_peak(&mut self, peak: usize) {
        self.peak = self.peak.max(peak);
    }

    /// Atom cap the re-compression pass maintains.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Read-only view of atom `i` as `(w_i, u_i, v_i)` — the
    /// checkpoint serializer and per-atom caches walk the list through
    /// this instead of reaching into the private storage.
    pub fn atom(&self, i: usize) -> (f32, &Arc<Vec<f32>>, &Arc<Vec<f32>>) {
        (self.w[i], &self.us[i], &self.vs[i])
    }

    /// Single entry `X[i][j] = sum_k w_k u_k[i] v_k[j]` — O(atoms), the
    /// sparse matrix-completion residual and the per-user serving score.
    #[inline]
    pub fn entry(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        let mut acc = 0.0f64;
        for ((&w, u), v) in self.w.iter().zip(&self.us).zip(&self.vs) {
            acc += w as f64 * u[i] as f64 * v[j] as f64;
        }
        acc as f32
    }

    /// Append one atom `w * u v^T` (shared factors), re-compressing when
    /// the cap is exceeded.
    pub fn push_atom(&mut self, w: f32, u: Arc<Vec<f32>>, v: Arc<Vec<f32>>) {
        debug_assert_eq!(u.len(), self.rows);
        debug_assert_eq!(v.len(), self.cols);
        self.w.push(w);
        self.us.push(u);
        self.vs.push(v);
        self.peak = self.peak.max(self.w.len());
        if self.w.len() > self.cap {
            self.recompress();
        }
    }

    /// Scale every atom weight (the `(1 - eta)` shrink of Eqn 6 is O(k)
    /// here instead of O(d1*d2)).
    pub fn scale_weights(&mut self, s: f32) {
        self.w.iter_mut().for_each(|w| *w *= s);
    }

    /// The FW iterate recursion
    /// `X <- (1 - eta) X + eta * scale * u v^T` on the factored form.
    pub fn fw_rank_one_update(&mut self, eta: f32, scale: f32, u: &[f32], v: &[f32]) {
        self.fw_update_arc(eta, scale, Arc::new(u.to_vec()), Arc::new(v.to_vec()));
    }

    /// [`FactoredMat::fw_rank_one_update`] with shared factors (no copy —
    /// the path update-log replay takes).
    pub fn fw_update_arc(&mut self, eta: f32, scale: f32, u: Arc<Vec<f32>>, v: Arc<Vec<f32>>) {
        self.scale_weights(1.0 - eta);
        self.push_atom(eta * scale, u, v);
    }

    /// Materialize the dense matrix (evaluation / reporting only; the
    /// hot paths stay on the factored form).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        self.write_dense_into(&mut m);
        m
    }

    /// Materialize into a caller-owned buffer, resizing it only when the
    /// shape changed — the allocation-free spelling of
    /// [`FactoredMat::to_dense`] for engines that densify every step
    /// (the default [`crate::algo::StepEngine::step_it`] path).
    pub fn write_dense_into(&self, out: &mut Mat) {
        if out.rows != self.rows || out.cols != self.cols {
            *out = Mat::zeros(self.rows, self.cols);
        } else {
            out.fill(0.0);
        }
        for ((&w, u), v) in self.w.iter().zip(&self.us).zip(&self.vs) {
            if w == 0.0 {
                continue;
            }
            for (r, &ur) in u.iter().enumerate() {
                let c = w * ur;
                if c == 0.0 {
                    continue;
                }
                let row = out.row_mut(r);
                for (x, &vc) in row.iter_mut().zip(v.iter()) {
                    *x += c * vc;
                }
            }
        }
    }

    /// Away step over the active set (Ding & Udell 1808.05274): with
    /// atom `i` standing for the vertex `V_i = sign(w_i) theta u_i v_i^T`
    /// at convex weight `alpha_i = |w_i| / theta`, move
    /// `X <- (1 + eta) X - eta V_i` — all weights inflate by `(1 + eta)`
    /// and atom `i` loses one `eta`-unit of vertex mass.  Feasibility is
    /// the caller's clamp `eta <= alpha_i / (1 - alpha_i)`: under it the
    /// total convex mass stays <= 1, so `nuclear_norm_bound() <= theta`
    /// by construction.  An atom driven to (numerically) zero weight is
    /// dropped from the active set outright — the boundary step.
    pub fn away_update(&mut self, i: usize, eta: f32, theta: f32) {
        debug_assert!(i < self.w.len());
        debug_assert!(theta > 0.0);
        let sign = if self.w[i] < 0.0 { -1.0 } else { 1.0 };
        self.scale_weights(1.0 + eta);
        self.w[i] -= eta * sign * theta;
        if self.w[i].abs() <= 1e-6 * theta {
            self.drop_atom(i);
        }
    }

    /// Pairwise FW step: shift `eta` units of vertex mass from active
    /// atom `i` directly onto the new LMO atom `scale * u v^T`
    /// (`scale = -theta` over the nuclear ball), leaving every other
    /// weight untouched.  Total convex mass is conserved, so feasibility
    /// holds by construction under the caller's clamp
    /// `eta <= alpha_i = |w_i| / |scale|`.  Atom `i` is dropped when the
    /// step empties it.
    pub fn pairwise_update(
        &mut self,
        i: usize,
        eta: f32,
        scale: f32,
        u: Arc<Vec<f32>>,
        v: Arc<Vec<f32>>,
    ) {
        debug_assert!(i < self.w.len());
        debug_assert!(scale != 0.0);
        let sign = if self.w[i] < 0.0 { -1.0 } else { 1.0 };
        self.w[i] -= eta * sign * scale.abs();
        if self.w[i].abs() <= 1e-6 * scale.abs() {
            self.drop_atom(i);
        }
        self.push_atom(eta * scale, u, v);
    }

    /// Remove atom `i`, preserving the order of the survivors (the atom
    /// list is small — O(cap) shift beats disturbing checkpoint order).
    fn drop_atom(&mut self, i: usize) {
        self.w.remove(i);
        self.us.remove(i);
        self.vs.remove(i);
    }

    /// `<mat(a), X>` for a row-major flattened `a` of length
    /// `rows * cols`: `sum_i w_i * u_i^T mat(a) v_i`, computed atom by
    /// atom without materializing X (the matrix-sensing residual).
    /// Atom-chunked f64 partials above the kernels work threshold
    /// (`O(k * rows * cols)` is the heaviest per-sample loop); the
    /// `w == 0.0` skip is false for NaN, so poisoned weights propagate.
    pub fn inner_flat(&self, a: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), self.rows * self.cols);
        let k = self.w.len();
        let block_acc = |lo: usize, hi: usize| {
            let mut acc = 0.0f64;
            for i in lo..hi {
                let w = self.w[i];
                if w == 0.0 {
                    continue;
                }
                let (u, v) = (&self.us[i], &self.vs[i]);
                let mut s = 0.0f64;
                for (r, &ur) in u.iter().enumerate() {
                    if ur != 0.0 {
                        s += ur as f64 * dot(&a[r * self.cols..(r + 1) * self.cols], v) as f64;
                    }
                }
                acc += w as f64 * s;
            }
            acc
        };
        let nblocks = if k * self.rows * self.cols >= kernels::PAR_MIN_WORK {
            k.div_ceil(ATOM_CHUNK)
        } else {
            1
        };
        if nblocks <= 1 {
            return block_acc(0, k) as f32;
        }
        kernels::Pool::map_chunks(nblocks, |b| {
            block_acc(b * ATOM_CHUNK, ((b + 1) * ATOM_CHUNK).min(k))
        })
        .into_iter()
        .sum::<f64>() as f32
    }

    /// Number of [`ATOM_CHUNK`] blocks the chunked `LinOp` paths use:
    /// 1 (serial, direct accumulation) while `k * (rows + cols)` is
    /// below [`kernels::PAR_MIN_WORK`], else `ceil(k / ATOM_CHUNK)`.  A
    /// function of the problem size ONLY — never the thread budget —
    /// which is what keeps `--threads N` bit-identical to `--threads 1`.
    fn atom_blocks(&self, k: usize) -> usize {
        if k * (self.rows + self.cols) >= kernels::PAR_MIN_WORK {
            k.div_ceil(ATOM_CHUNK)
        } else {
            1
        }
    }

    /// Upper bound on the nuclear norm: `sum_i |w_i| ||u_i|| ||v_i||`
    /// (exact when the atoms are orthogonal; always >= `||X||_*` by the
    /// triangle inequality).  O(k (d1 + d2)) — no SVD.
    pub fn nuclear_norm_bound(&self) -> f64 {
        self.w
            .iter()
            .zip(&self.us)
            .zip(&self.vs)
            .map(|((&w, u), v)| (w.abs() as f64) * norm2(u) * norm2(v))
            .sum()
    }

    /// Re-compression: drop negligible-weight atoms, then (if still over
    /// the cap) re-factorize exactly through an SVD of the materialized
    /// matrix — the rank is at most `min(rows, cols)`, so this merges
    /// redundant directions losslessly up to f32 round-off.
    pub fn recompress(&mut self) {
        let wmax = self.w.iter().fold(0.0f32, |m, w| m.max(w.abs()));
        let thresh = DROP_REL * wmax;
        if wmax > 0.0 && self.w.iter().any(|w| w.abs() <= thresh) {
            let ws = std::mem::take(&mut self.w);
            let us = std::mem::take(&mut self.us);
            let vs = std::mem::take(&mut self.vs);
            for ((w, u), v) in ws.into_iter().zip(us).zip(vs) {
                if w.abs() > thresh {
                    self.w.push(w);
                    self.us.push(u);
                    self.vs.push(v);
                }
            }
        }
        if self.w.len() <= self.cap {
            return;
        }
        let (u, s, v) = jacobi_svd(&self.to_dense());
        let s0 = s.first().copied().unwrap_or(0.0);
        let rebuilt = FactoredMat::from_svd(&u, &s, &v, SVD_REL * s0);
        self.w = rebuilt.w;
        self.us = rebuilt.us;
        self.vs = rebuilt.vs;
    }
}

impl LinOp for FactoredMat {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }

    /// `y = X x = sum_i w_i u_i (v_i . x)` — O(k (d1 + d2)), no dense
    /// materialization; atom-chunked across the thread pool above
    /// [`kernels::PAR_MIN_WORK`] with block partials combined in block
    /// order (bit-identical for any thread count).
    ///
    /// **NaN contract (poisoned atoms):** the `c == 0.0` skip is false
    /// for a NaN coefficient — a non-finite atom weight (e.g. a poisoned
    /// entry from a desynced replay) therefore contaminates every output
    /// element LOUDLY instead of being silently dropped, so the LMO's
    /// singular vectors go non-finite and the master's
    /// `coordinator::sane_rank_one` gate rejects the resulting update.
    /// Pinned by the poisoned-atom tests here and in
    /// `rust/tests/factored.rs`.
    fn apply(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(y.len(), self.rows);
        let k = self.w.len();
        let nblocks = self.atom_blocks(k);
        if nblocks <= 1 {
            y.iter_mut().for_each(|z| *z = 0.0);
            for ((&w, u), v) in self.w.iter().zip(&self.us).zip(&self.vs) {
                let c = w * dot(v, x);
                if c == 0.0 {
                    continue;
                }
                kernels::axpy(y, c, u);
            }
            return;
        }
        let partials = kernels::Pool::map_chunks(nblocks, |b| {
            let mut part = vec![0.0f32; self.rows];
            for i in b * ATOM_CHUNK..((b + 1) * ATOM_CHUNK).min(k) {
                let c = self.w[i] * dot(&self.vs[i], x);
                if c == 0.0 {
                    continue;
                }
                kernels::axpy(&mut part, c, &self.us[i]);
            }
            part
        });
        y.iter_mut().for_each(|z| *z = 0.0);
        for part in partials {
            for (yr, p) in y.iter_mut().zip(part) {
                *yr += p;
            }
        }
    }

    /// `y = X^T x = sum_i w_i v_i (u_i . x)` — same chunking and NaN
    /// contract as [`FactoredMat::apply`].
    fn tapply(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(y.len(), self.cols);
        let k = self.w.len();
        let nblocks = self.atom_blocks(k);
        if nblocks <= 1 {
            y.iter_mut().for_each(|z| *z = 0.0);
            for ((&w, u), v) in self.w.iter().zip(&self.us).zip(&self.vs) {
                let c = w * dot(u, x);
                if c == 0.0 {
                    continue;
                }
                kernels::axpy(y, c, v);
            }
            return;
        }
        let partials = kernels::Pool::map_chunks(nblocks, |b| {
            let mut part = vec![0.0f32; self.cols];
            for i in b * ATOM_CHUNK..((b + 1) * ATOM_CHUNK).min(k) {
                let c = self.w[i] * dot(&self.us[i], x);
                if c == 0.0 {
                    continue;
                }
                kernels::axpy(&mut part, c, &self.vs[i]);
            }
            part
        });
        y.iter_mut().for_each(|z| *z = 0.0);
        for part in partials {
            for (yc, p) in y.iter_mut().zip(part) {
                *yc += p;
            }
        }
    }

    /// `y^T X x = sum_i w_i (y . u_i)(v_i . x)` — allocation-free in the
    /// serial regime; f64 block partials in block order above the work
    /// threshold.  The `w != 0.0` guard is true for NaN, so a poisoned
    /// weight propagates (see [`FactoredMat::apply`]).
    fn apply_dot(&self, y: &[f32], x: &[f32]) -> f32 {
        debug_assert_eq!(y.len(), self.rows);
        debug_assert_eq!(x.len(), self.cols);
        let k = self.w.len();
        let block_acc = |lo: usize, hi: usize| {
            let mut acc = 0.0f64;
            for i in lo..hi {
                let w = self.w[i];
                if w != 0.0 {
                    acc += w as f64 * dot(y, &self.us[i]) as f64 * dot(&self.vs[i], x) as f64;
                }
            }
            acc
        };
        let nblocks = self.atom_blocks(k);
        if nblocks <= 1 {
            return block_acc(0, k) as f32;
        }
        kernels::Pool::map_chunks(nblocks, |b| {
            block_acc(b * ATOM_CHUNK, ((b + 1) * ATOM_CHUNK).min(k))
        })
        .into_iter()
        .sum::<f64>() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_factored(rng: &mut Rng, d1: usize, d2: usize, k: usize) -> FactoredMat {
        let mut f = FactoredMat::zeros(d1, d2);
        for _ in 0..k {
            f.push_atom(
                rng.normal_f32(),
                Arc::new(rng.unit_vector(d1)),
                Arc::new(rng.unit_vector(d2)),
            );
        }
        f
    }

    fn frob_diff(a: &Mat, b: &Mat) -> f64 {
        let mut d = a.clone();
        d.axpy(-1.0, b);
        d.frob_norm()
    }

    #[test]
    fn apply_and_tapply_match_dense() {
        let mut rng = Rng::new(310);
        let f = random_factored(&mut rng, 7, 5, 6);
        let d = f.to_dense();
        let x: Vec<f32> = (0..5).map(|_| rng.normal_f32()).collect();
        let y: Vec<f32> = (0..7).map(|_| rng.normal_f32()).collect();
        let (mut fa, mut da) = (vec![0.0f32; 7], vec![0.0f32; 7]);
        f.apply(&x, &mut fa);
        d.matvec(&x, &mut da);
        for (a, b) in fa.iter().zip(&da) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        let (mut ft, mut dt) = (vec![0.0f32; 5], vec![0.0f32; 5]);
        f.tapply(&y, &mut ft);
        d.tmatvec(&y, &mut dt);
        for (a, b) in ft.iter().zip(&dt) {
            assert!((a - b).abs() < 1e-5);
        }
        let want = {
            let mut ax = vec![0.0f32; 7];
            d.matvec(&x, &mut ax);
            dot(&y, &ax)
        };
        assert!((f.apply_dot(&y, &x) - want).abs() < 1e-4 * (1.0 + want.abs()));
    }

    #[test]
    fn inner_flat_matches_dense_inner_product() {
        let mut rng = Rng::new(311);
        let f = random_factored(&mut rng, 6, 4, 5);
        let d = f.to_dense();
        let a: Vec<f32> = (0..24).map(|_| rng.normal_f32()).collect();
        let want = dot(&a, &d.data);
        assert!((f.inner_flat(&a) - want).abs() < 1e-5 * (1.0 + want.abs()));
    }

    #[test]
    fn fw_update_matches_dense_recursion() {
        let mut rng = Rng::new(312);
        let mut f = FactoredMat::zeros(6, 5);
        let mut d = Mat::zeros(6, 5);
        for k in 1..=20u64 {
            let u = rng.unit_vector(6);
            let v = rng.unit_vector(5);
            let eta = 2.0 / (k as f32 + 1.0);
            f.fw_rank_one_update(eta, -1.0, &u, &v);
            d.fw_rank_one_update(eta, -1.0, &u, &v);
        }
        assert!(frob_diff(&f.to_dense(), &d) < 1e-5 * (1.0 + d.frob_norm()));
        assert_eq!(f.peak_atoms(), 20);
    }

    #[test]
    fn recompression_caps_atoms_and_preserves_iterate() {
        let mut rng = Rng::new(313);
        let mut f = FactoredMat::with_cap(6, 5, 0); // floored to min+8 = 13
        assert_eq!(f.cap(), 13);
        let mut d = Mat::zeros(6, 5);
        for k in 1..=60u64 {
            let u = rng.unit_vector(6);
            let v = rng.unit_vector(5);
            let eta = 2.0 / (k as f32 + 1.0);
            f.fw_rank_one_update(eta, -1.0, &u, &v);
            d.fw_rank_one_update(eta, -1.0, &u, &v);
        }
        assert!(f.atoms() <= f.cap(), "{} atoms over cap {}", f.atoms(), f.cap());
        assert!(f.peak_atoms() > f.cap());
        let err = frob_diff(&f.to_dense(), &d) / (1.0 + d.frob_norm());
        assert!(err < 1e-4, "recompression moved the iterate: {err}");
    }

    #[test]
    fn nuclear_bound_dominates_true_norm() {
        let mut rng = Rng::new(314);
        let f = random_factored(&mut rng, 6, 6, 8);
        let exact = crate::linalg::nuclear_norm(&f.to_dense());
        let bound = f.nuclear_norm_bound();
        assert!(bound + 1e-6 >= exact, "bound {bound} < exact {exact}");
    }

    #[test]
    fn entry_and_atom_views_match_dense() {
        let mut rng = Rng::new(316);
        let f = random_factored(&mut rng, 5, 4, 3);
        let d = f.to_dense();
        for i in 0..5 {
            for j in 0..4 {
                assert!((f.entry(i, j) - d.at(i, j)).abs() < 1e-5);
            }
        }
        let mut rebuilt = FactoredMat::zeros(5, 4);
        for k in 0..f.atoms() {
            let (w, u, v) = f.atom(k);
            rebuilt.push_atom(w, u.clone(), v.clone());
        }
        assert!(frob_diff(&rebuilt.to_dense(), &d) < 1e-6);
    }

    #[test]
    fn away_update_matches_dense_algebra_and_drops_at_boundary() {
        let mut rng = Rng::new(317);
        let theta = 1.0f32;
        // two-atom convex combination: alpha = (0.6, 0.4)
        let (u0, v0) = (Arc::new(rng.unit_vector(5)), Arc::new(rng.unit_vector(4)));
        let (u1, v1) = (Arc::new(rng.unit_vector(5)), Arc::new(rng.unit_vector(4)));
        let mut f = FactoredMat::zeros(5, 4);
        f.push_atom(-0.6 * theta, u0.clone(), v0.clone());
        f.push_atom(-0.4 * theta, u1.clone(), v1.clone());
        let dense0 = f.to_dense();
        // away step on atom 1 (alpha = 0.4): X <- (1+eta)X - eta*V_1
        let eta = 0.25f32;
        let mut want = dense0.clone();
        want.scale(1.0 + eta);
        for i in 0..5 {
            for j in 0..4 {
                // V_1 = sign(w_1) * theta * u1 v1^T = -theta u1 v1^T
                *want.at_mut(i, j) -= eta * (-theta) * u1[i] * v1[j];
            }
        }
        f.away_update(1, eta, theta);
        assert!(frob_diff(&f.to_dense(), &want) < 1e-5 * (1.0 + want.frob_norm()));
        // feasibility by construction: eta <= alpha/(1-alpha) keeps the
        // convex mass, and hence the nuclear bound, inside theta
        assert!(f.nuclear_norm_bound() <= theta as f64 + 1e-5);
        // the boundary step alpha/(1-alpha) empties and drops the atom
        let mut g = FactoredMat::zeros(5, 4);
        g.push_atom(-0.7 * theta, u0.clone(), v0.clone());
        g.push_atom(-0.3 * theta, u1.clone(), v1.clone());
        let eta_max = 0.3 / (1.0 - 0.3);
        g.away_update(1, eta_max, theta);
        assert_eq!(g.atoms(), 1, "boundary away step must drop the atom");
        assert!(g.nuclear_norm_bound() <= theta as f64 + 1e-5);
    }

    #[test]
    fn pairwise_update_conserves_mass_and_matches_dense() {
        let mut rng = Rng::new(318);
        let theta = 1.0f32;
        let (u0, v0) = (Arc::new(rng.unit_vector(5)), Arc::new(rng.unit_vector(4)));
        let (u1, v1) = (Arc::new(rng.unit_vector(5)), Arc::new(rng.unit_vector(4)));
        let (us, vs) = (Arc::new(rng.unit_vector(5)), Arc::new(rng.unit_vector(4)));
        let mut f = FactoredMat::zeros(5, 4);
        f.push_atom(-0.5 * theta, u0, v0);
        f.push_atom(-0.5 * theta, u1.clone(), v1.clone());
        let dense0 = f.to_dense();
        let eta = 0.2f32;
        let mut want = dense0.clone();
        for i in 0..5 {
            for j in 0..4 {
                // d = S - V_1 with S = -theta us vs^T, V_1 = -theta u1 v1^T
                *want.at_mut(i, j) +=
                    eta * theta * (-us[i] * vs[j] + u1[i] * v1[j]);
            }
        }
        f.pairwise_update(1, eta, -theta, us.clone(), vs.clone());
        assert!(frob_diff(&f.to_dense(), &want) < 1e-5 * (1.0 + want.frob_norm()));
        // mass conserved: bound stays at theta
        assert!((f.nuclear_norm_bound() - theta as f64).abs() < 1e-5);
        // emptying step drops the source atom but keeps the new one
        let atoms_before = f.atoms();
        f.pairwise_update(1, 0.3, -theta, us, vs);
        assert_eq!(f.atoms(), atoms_before, "drop + push nets to the same count");
    }

    #[test]
    fn write_dense_into_reuses_buffer() {
        let mut rng = Rng::new(319);
        let f = random_factored(&mut rng, 6, 4, 5);
        let mut buf = Mat::zeros(0, 0);
        f.write_dense_into(&mut buf);
        assert!(frob_diff(&buf, &f.to_dense()) < 1e-6);
        // stale contents are overwritten, not accumulated
        f.write_dense_into(&mut buf);
        assert!(frob_diff(&buf, &f.to_dense()) < 1e-6);
    }

    #[test]
    fn nan_atom_weight_poisons_every_linop_output() {
        // The `c == 0.0` / `w != 0.0` guards are false/true for NaN, so a
        // poisoned atom weight (desynced-replay scenario) reaches every
        // output loudly instead of being silently skipped — even when its
        // factors are all zeros (NaN * 0.0 = NaN).
        let mut f = FactoredMat::zeros(3, 2);
        f.push_atom(1.0, Arc::new(vec![1.0, 2.0, 3.0]), Arc::new(vec![1.0, 0.5]));
        f.push_atom(f32::NAN, Arc::new(vec![0.0; 3]), Arc::new(vec![0.0; 2]));
        let mut y = vec![0.0f32; 3];
        f.apply(&[1.0, 1.0], &mut y);
        assert!(y.iter().all(|v| v.is_nan()), "apply swallowed the NaN atom: {y:?}");
        let mut z = vec![0.0f32; 2];
        f.tapply(&[1.0, 1.0, 1.0], &mut z);
        assert!(z.iter().all(|v| v.is_nan()), "tapply swallowed the NaN atom: {z:?}");
        assert!(f.apply_dot(&[1.0, 1.0, 1.0], &[1.0, 1.0]).is_nan());
        assert!(f.inner_flat(&[1.0; 6]).is_nan());
        assert!(f.entry(0, 0).is_nan());
    }

    #[test]
    fn zero_weight_atoms_are_dropped() {
        let mut rng = Rng::new(315);
        let mut f = FactoredMat::zeros(4, 4);
        f.push_atom(1.0, Arc::new(rng.unit_vector(4)), Arc::new(rng.unit_vector(4)));
        f.push_atom(0.0, Arc::new(rng.unit_vector(4)), Arc::new(rng.unit_vector(4)));
        f.recompress();
        assert_eq!(f.atoms(), 1);
    }
}
