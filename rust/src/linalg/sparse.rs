//! [`CooMat`]: a sparse matrix as COO triples, implementing [`LinOp`].
//!
//! Sparse matrix completion's minibatch gradient is nonzero only at the
//! sampled observed entries — at most `|batch|` coordinates out of
//! `d1 * d2`.  Holding it as `(row, col, val)` triples makes every
//! power-iteration matvec O(nnz) instead of O(d1 * d2), so the
//! operator-form LMO (`power_iteration` is generic over [`LinOp`]) costs
//! O(nnz * k) per step without ever materializing the gradient — the
//! sparsity payoff Bellet et al. (arXiv:1404.2644) identify as the point
//! of distributed FW on completion problems.
//!
//! Duplicate coordinates are allowed and sum (minibatches sample with
//! replacement, so the same observed entry can contribute twice); the
//! matvecs are linear in the triple list, which makes that free.

use super::kernels;
use super::mat::Mat;
use super::op::LinOp;

/// Triples per reduction block in the chunked `LinOp` paths: fixed-size
/// nnz ranges whose zeroed partials are combined in block order, so the
/// partition depends only on `nnz` — never the thread budget (the
/// kernels determinism contract).  Within a block the triples are
/// processed in storage order, which keeps duplicate coordinates summing
/// deterministically.
const NNZ_BLOCK: usize = 1 << 15;

/// Sparse `rows x cols` matrix as unsorted COO triples.
#[derive(Clone, Debug)]
pub struct CooMat {
    rows: usize,
    cols: usize,
    row_idx: Vec<u32>,
    col_idx: Vec<u32>,
    vals: Vec<f32>,
}

impl CooMat {
    pub fn with_capacity(rows: usize, cols: usize, nnz: usize) -> Self {
        debug_assert!(rows <= u32::MAX as usize && cols <= u32::MAX as usize);
        CooMat {
            rows,
            cols,
            row_idx: Vec::with_capacity(nnz),
            col_idx: Vec::with_capacity(nnz),
            vals: Vec::with_capacity(nnz),
        }
    }

    /// Append one `(i, j, v)` triple.  Duplicates accumulate additively.
    pub fn push(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.row_idx.push(i as u32);
        self.col_idx.push(j as u32);
        self.vals.push(v);
    }

    /// Stored triple count (duplicates counted separately).
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Iterate the stored `(i, j, v)` triples — O(nnz) inner products
    /// (e.g. `<grad, X>` of the FW dual gap against an entry oracle)
    /// without densifying.
    pub fn triples(&self) -> impl Iterator<Item = (usize, usize, f32)> + '_ {
        self.row_idx
            .iter()
            .zip(&self.col_idx)
            .zip(&self.vals)
            .map(|((&i, &j), &v)| (i as usize, j as usize, v))
    }

    /// Number of [`NNZ_BLOCK`] ranges the chunked `LinOp` paths use: 1
    /// (serial, identical to the historical scatter loop) while `nnz` is
    /// below [`kernels::PAR_MIN_WORK`], else `ceil(nnz / NNZ_BLOCK)`.  A
    /// function of `nnz` ONLY, never the thread budget.
    fn nnz_blocks(&self) -> usize {
        if self.vals.len() >= kernels::PAR_MIN_WORK {
            self.vals.len().div_ceil(NNZ_BLOCK)
        } else {
            1
        }
    }

    /// Dense materialization (tests / small dims only).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for ((&i, &j), &v) in self.row_idx.iter().zip(&self.col_idx).zip(&self.vals) {
            *m.at_mut(i as usize, j as usize) += v;
        }
        m
    }
}

impl LinOp for CooMat {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }

    /// `y = A x`: one multiply-add per stored triple — O(nnz).  Above
    /// the kernels work threshold the triple list is cut into fixed
    /// [`NNZ_BLOCK`] ranges scattered into zeroed per-block partials and
    /// combined in block order (bit-identical for any thread count).
    fn apply(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(y.len(), self.rows);
        let nblocks = self.nnz_blocks();
        if nblocks <= 1 {
            y.iter_mut().for_each(|z| *z = 0.0);
            for ((&i, &j), &v) in self.row_idx.iter().zip(&self.col_idx).zip(&self.vals) {
                y[i as usize] += v * x[j as usize];
            }
            return;
        }
        let partials = kernels::Pool::map_chunks(nblocks, |b| {
            let mut part = vec![0.0f32; self.rows];
            for t in b * NNZ_BLOCK..((b + 1) * NNZ_BLOCK).min(self.vals.len()) {
                part[self.row_idx[t] as usize] += self.vals[t] * x[self.col_idx[t] as usize];
            }
            part
        });
        y.iter_mut().for_each(|z| *z = 0.0);
        for part in partials {
            for (yr, p) in y.iter_mut().zip(part) {
                *yr += p;
            }
        }
    }

    /// `y = A^T x` — O(nnz), same chunking as [`CooMat::apply`].
    fn tapply(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(y.len(), self.cols);
        let nblocks = self.nnz_blocks();
        if nblocks <= 1 {
            y.iter_mut().for_each(|z| *z = 0.0);
            for ((&i, &j), &v) in self.row_idx.iter().zip(&self.col_idx).zip(&self.vals) {
                y[j as usize] += v * x[i as usize];
            }
            return;
        }
        let partials = kernels::Pool::map_chunks(nblocks, |b| {
            let mut part = vec![0.0f32; self.cols];
            for t in b * NNZ_BLOCK..((b + 1) * NNZ_BLOCK).min(self.vals.len()) {
                part[self.col_idx[t] as usize] += self.vals[t] * x[self.row_idx[t] as usize];
            }
            part
        });
        y.iter_mut().for_each(|z| *z = 0.0);
        for part in partials {
            for (yc, p) in y.iter_mut().zip(part) {
                *yc += p;
            }
        }
    }

    /// `y^T A x = sum_t v_t * y[i_t] * x[j_t]` — allocation-free O(nnz)
    /// in the serial regime; f64 block partials in block order above the
    /// work threshold.
    fn apply_dot(&self, y: &[f32], x: &[f32]) -> f32 {
        debug_assert_eq!(y.len(), self.rows);
        debug_assert_eq!(x.len(), self.cols);
        let block_acc = |lo: usize, hi: usize| {
            let mut acc = 0.0f64;
            for t in lo..hi {
                acc += self.vals[t] as f64
                    * y[self.row_idx[t] as usize] as f64
                    * x[self.col_idx[t] as usize] as f64;
            }
            acc
        };
        let nblocks = self.nnz_blocks();
        if nblocks <= 1 {
            return block_acc(0, self.vals.len()) as f32;
        }
        kernels::Pool::map_chunks(nblocks, |b| {
            block_acc(b * NNZ_BLOCK, ((b + 1) * NNZ_BLOCK).min(self.vals.len()))
        })
        .into_iter()
        .sum::<f64>() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dot, power_iteration};
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, rows: usize, cols: usize, nnz: usize) -> CooMat {
        let mut c = CooMat::with_capacity(rows, cols, nnz);
        for _ in 0..nnz {
            c.push(rng.next_below(rows), rng.next_below(cols), rng.normal_f32());
        }
        c
    }

    #[test]
    fn matvecs_match_dense() {
        let mut rng = Rng::new(320);
        let c = random_coo(&mut rng, 7, 5, 12); // likely duplicate coords
        let d = c.to_dense();
        let x: Vec<f32> = (0..5).map(|_| rng.normal_f32()).collect();
        let y: Vec<f32> = (0..7).map(|_| rng.normal_f32()).collect();
        let (mut ca, mut da) = (vec![0.0f32; 7], vec![0.0f32; 7]);
        c.apply(&x, &mut ca);
        d.matvec(&x, &mut da);
        for (a, b) in ca.iter().zip(&da) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        let (mut ct, mut dt) = (vec![0.0f32; 5], vec![0.0f32; 5]);
        c.tapply(&y, &mut ct);
        d.tmatvec(&y, &mut dt);
        for (a, b) in ct.iter().zip(&dt) {
            assert!((a - b).abs() < 1e-5);
        }
        let want = dot(&y, &da);
        assert!((c.apply_dot(&y, &x) - want).abs() < 1e-4 * (1.0 + want.abs()));
    }

    #[test]
    fn power_iteration_agrees_with_dense_operator() {
        let mut rng = Rng::new(321);
        let c = random_coo(&mut rng, 9, 6, 20);
        let d = c.to_dense();
        let v0 = rng.unit_vector(6);
        let sp = power_iteration(&c, &v0, 200, 1e-10);
        let de = power_iteration(&d, &v0, 200, 1e-10);
        assert!(
            (sp.sigma - de.sigma).abs() < 1e-4 * (1.0 + de.sigma.abs()),
            "sigma {} vs {}",
            sp.sigma,
            de.sigma
        );
        for (a, b) in sp.v.iter().zip(&de.v) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn duplicate_triples_sum() {
        let mut c = CooMat::with_capacity(2, 2, 2);
        c.push(0, 1, 1.5);
        c.push(0, 1, 0.5);
        assert_eq!(c.nnz(), 2);
        assert_eq!(c.to_dense().at(0, 1), 2.0);
    }
}
