//! [`Iterate`]: the FW iterate, in dense or factored representation.
//!
//! Every solver in the repo advances its model only through the Eqn-6
//! rank-one recursion, so the iterate can be held either as a dense
//! [`Mat`] (the reference path) or as a [`FactoredMat`] atom list (the
//! scale path: O((d1+d2)*k) memory, O(k) weight-shrink per update,
//! cheap clones for evaluator snapshots).  Which one a run uses is a
//! [`TrainSpec`](crate::session::TrainSpec) knob with per-objective
//! defaults; same-seed dense-vs-factored runs agree to f32 tolerance
//! (pinned by `rust/tests/factored.rs`).

use std::sync::Arc;

use super::factored::FactoredMat;
use super::mat::Mat;
use super::op::LinOp;
use super::svd::numerical_rank;
use crate::util::rng::Rng;

/// Iterate representation of one run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Repr {
    /// Dense `d1 x d2` array; every update is an O(d1*d2) GER.
    Dense,
    /// Rank-one atom list; see [`FactoredMat`].
    Factored,
}

impl Repr {
    pub fn label(&self) -> &'static str {
        match self {
            Repr::Dense => "dense",
            Repr::Factored => "factored",
        }
    }
}

/// The FW iterate in its chosen representation.
#[derive(Debug)]
pub enum Iterate {
    Dense(Mat),
    Factored(FactoredMat),
}

impl Clone for Iterate {
    fn clone(&self) -> Self {
        match self {
            Iterate::Dense(m) => Iterate::Dense(m.clone()),
            Iterate::Factored(f) => Iterate::Factored(f.clone()),
        }
    }

    /// Allocation-free when both sides are dense with matching dims (the
    /// SVRF snapshot path).
    fn clone_from(&mut self, src: &Self) {
        match (self, src) {
            (Iterate::Dense(a), Iterate::Dense(b))
                if a.rows == b.rows && a.cols == b.cols =>
            {
                a.data.copy_from_slice(&b.data)
            }
            (me, other) => *me = other.clone(),
        }
    }
}

impl Iterate {
    /// Zero iterate in the requested representation.
    pub fn zeros(repr: Repr, d1: usize, d2: usize) -> Iterate {
        match repr {
            Repr::Dense => Iterate::Dense(Mat::zeros(d1, d2)),
            Repr::Factored => Iterate::Factored(FactoredMat::zeros(d1, d2)),
        }
    }

    /// Random rank-one start on the nuclear sphere of radius `theta` —
    /// draws `u` then `v` from `rng` exactly like
    /// [`crate::algo::sfw::init_rank_one`], so dense and factored runs
    /// share one random stream for a fixed seed.
    pub fn init_rank_one(repr: Repr, d1: usize, d2: usize, theta: f32, rng: &mut Rng) -> Iterate {
        let u = rng.unit_vector(d1);
        let v = rng.unit_vector(d2);
        match repr {
            Repr::Dense => {
                let mut x = Mat::zeros(d1, d2);
                for i in 0..d1 {
                    for j in 0..d2 {
                        *x.at_mut(i, j) = theta * u[i] * v[j];
                    }
                }
                Iterate::Dense(x)
            }
            Repr::Factored => {
                let mut f = FactoredMat::zeros(d1, d2);
                f.push_atom(theta, Arc::new(u), Arc::new(v));
                Iterate::Factored(f)
            }
        }
    }

    pub fn repr(&self) -> Repr {
        match self {
            Iterate::Dense(_) => Repr::Dense,
            Iterate::Factored(_) => Repr::Factored,
        }
    }

    pub fn dims(&self) -> (usize, usize) {
        match self {
            Iterate::Dense(m) => (m.rows, m.cols),
            Iterate::Factored(f) => (f.rows, f.cols),
        }
    }

    /// Eqn-6 update `X <- (1 - eta) X + eta * scale * u v^T` on either
    /// representation.
    pub fn fw_rank_one_update(&mut self, eta: f32, scale: f32, u: &[f32], v: &[f32]) {
        match self {
            Iterate::Dense(m) => m.fw_rank_one_update(eta, scale, u, v),
            Iterate::Factored(f) => f.fw_rank_one_update(eta, scale, u, v),
        }
    }

    /// Eqn-6 update with shared factors (log-entry replay: the factored
    /// iterate adopts the entry's `Arc`s outright).
    pub fn fw_update_arc(&mut self, eta: f32, scale: f32, u: &Arc<Vec<f32>>, v: &Arc<Vec<f32>>) {
        match self {
            Iterate::Dense(m) => m.fw_rank_one_update(eta, scale, u, v),
            Iterate::Factored(f) => f.fw_update_arc(eta, scale, u.clone(), v.clone()),
        }
    }

    /// Materialize a dense copy (reporting / dense broadcasts).
    pub fn to_dense(&self) -> Mat {
        match self {
            Iterate::Dense(m) => m.clone(),
            Iterate::Factored(f) => f.to_dense(),
        }
    }

    /// Materialize, consuming self (no copy for the dense case).
    pub fn into_dense(self) -> Mat {
        match self {
            Iterate::Dense(m) => m,
            Iterate::Factored(f) => f.to_dense(),
        }
    }

    /// Final-iterate rank: the atom count for the factored form (its
    /// representation rank); for dense iterates [`dense_rank`].
    pub fn rank(&self) -> usize {
        match self {
            Iterate::Factored(f) => f.atoms(),
            Iterate::Dense(m) => dense_rank(m),
        }
    }

    /// Peak atom count held during the run (0 for dense iterates).
    pub fn peak_atoms(&self) -> usize {
        match self {
            Iterate::Dense(_) => 0,
            Iterate::Factored(f) => f.peak_atoms(),
        }
    }

    /// Mutable access to the factored atom list (None for dense
    /// iterates) — the away/pairwise step path mutates the active set
    /// through this.
    pub fn factored_mut(&mut self) -> Option<&mut FactoredMat> {
        match self {
            Iterate::Dense(_) => None,
            Iterate::Factored(f) => Some(f),
        }
    }

    /// `<mat(g), X>` against a row-major flattened gradient buffer of
    /// length `d1 * d2` — the `<grad, X>` half of the FW dual gap,
    /// computed without materializing a dense X on the factored path.
    pub fn inner_flat(&self, g: &[f32]) -> f64 {
        match self {
            Iterate::Dense(m) => {
                debug_assert_eq!(g.len(), m.data.len());
                m.data
                    .iter()
                    .zip(g.iter())
                    .map(|(a, b)| *a as f64 * *b as f64)
                    .sum()
            }
            Iterate::Factored(f) => f.inner_flat(g) as f64,
        }
    }
}

/// Reporting-path rank of a dense iterate: the numerical rank where the
/// SVD is cheap, the dimension bound beyond.  The ONE policy shared by
/// [`Iterate::rank`] and `RunCtx::report`.
pub fn dense_rank(m: &Mat) -> usize {
    if m.rows.min(m.cols) <= 64 {
        numerical_rank(m)
    } else {
        m.rows.min(m.cols)
    }
}

impl LinOp for Iterate {
    fn rows(&self) -> usize {
        self.dims().0
    }
    fn cols(&self) -> usize {
        self.dims().1
    }
    fn apply(&self, x: &[f32], y: &mut [f32]) {
        match self {
            Iterate::Dense(m) => m.apply(x, y),
            Iterate::Factored(f) => f.apply(x, y),
        }
    }
    fn tapply(&self, x: &[f32], y: &mut [f32]) {
        match self {
            Iterate::Dense(m) => m.tapply(x, y),
            Iterate::Factored(f) => f.tapply(x, y),
        }
    }
    fn apply_dot(&self, y: &[f32], x: &[f32]) -> f32 {
        match self {
            Iterate::Dense(m) => m.apply_dot(y, x),
            Iterate::Factored(f) => f.apply_dot(y, x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_rank_one_agrees_across_representations() {
        let theta = 1.5f32;
        let dense = Iterate::init_rank_one(Repr::Dense, 6, 4, theta, &mut Rng::new(9));
        let fact = Iterate::init_rank_one(Repr::Factored, 6, 4, theta, &mut Rng::new(9));
        let (d, f) = (dense.to_dense(), fact.to_dense());
        for (a, b) in d.data.iter().zip(&f.data) {
            assert!((a - b).abs() < 1e-6);
        }
        // and both match the historical Mat-returning initializer
        let legacy = crate::algo::sfw::init_rank_one(6, 4, theta, &mut Rng::new(9));
        for (a, b) in d.data.iter().zip(&legacy.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn updates_track_across_representations() {
        let mut rng = Rng::new(10);
        let mut a = Iterate::init_rank_one(Repr::Dense, 5, 5, 1.0, &mut Rng::new(77));
        let mut b = Iterate::init_rank_one(Repr::Factored, 5, 5, 1.0, &mut Rng::new(77));
        for k in 1..=15u64 {
            let u = rng.unit_vector(5);
            let v = rng.unit_vector(5);
            let eta = 2.0 / (k as f32 + 1.0);
            a.fw_rank_one_update(eta, -1.0, &u, &v);
            b.fw_rank_one_update(eta, -1.0, &u, &v);
        }
        let (da, db) = (a.to_dense(), b.to_dense());
        let mut d = da.clone();
        d.axpy(-1.0, &db);
        assert!(d.frob_norm() < 1e-5);
        assert_eq!(b.peak_atoms(), 16); // init atom + 15 updates
        assert_eq!(a.peak_atoms(), 0);
        assert!(b.rank() <= 16);
    }

    #[test]
    fn clone_from_reuses_dense_storage() {
        let mut rng = Rng::new(11);
        let a = Iterate::init_rank_one(Repr::Dense, 4, 3, 1.0, &mut rng);
        let mut b = Iterate::zeros(Repr::Dense, 4, 3);
        b.clone_from(&a);
        let mut d = a.to_dense();
        d.axpy(-1.0, &b.to_dense());
        assert_eq!(d.frob_norm(), 0.0);
    }
}
