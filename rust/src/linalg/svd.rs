//! Singular-value machinery: power-iteration 1-SVD (the Frank-Wolfe LMO)
//! and a one-sided Jacobi full SVD (needed only by the PGD baseline's
//! nuclear-ball projection and by tests as an exact oracle).
//!
//! The 1-SVD is written against [`LinOp`], so it runs on any implicit
//! operator — a dense gradient [`Mat`] or a
//! [`FactoredMat`](crate::linalg::FactoredMat) atom list — without
//! materializing anything, and without allocating beyond its output
//! vectors (the sigma recompute goes through [`LinOp::apply_dot`]).
//! The SIMD + thread-pool acceleration of
//! [`kernels`](crate::linalg::kernels) reaches the LMO transparently
//! through this seam: every `apply`/`tapply`/`apply_dot` an implementor
//! routes through the kernel layer speeds up the power iteration with no
//! change here — and bit-identically across SIMD width and thread count,
//! per the kernels determinism contract.

use super::mat::{norm2, normalize, Mat};
use super::op::LinOp;
use crate::util::rng::Rng;

/// Result of a leading-singular-triple computation.
#[derive(Clone, Debug)]
pub struct Svd1 {
    pub u: Vec<f32>,
    pub v: Vec<f32>,
    pub sigma: f32,
    pub iters: usize,
}

/// Leading singular triple of `g` by alternating power iteration.
///
/// This is the native-Rust twin of the Pallas/JAX `lmo_power` module: same
/// algorithm, same normalization placement, so the two paths can be tested
/// against each other.  `v0` is the start vector (callers randomize it),
/// `max_iters` caps work, `tol` stops early when the singular-value
/// estimate stabilizes — the paper solves the 1-SVD "to a practical
/// precision" (Appendix D cites Allen-Zhu et al. 2017).
pub fn power_iteration<A: LinOp + ?Sized>(g: &A, v0: &[f32], max_iters: usize, tol: f64) -> Svd1 {
    let (d1, d2) = (g.rows(), g.cols());
    assert_eq!(v0.len(), d2);
    let mut v = v0.to_vec();
    normalize(&mut v);
    let mut u = vec![0.0f32; d1];
    g.apply(&v, &mut u);
    normalize(&mut u);
    let mut sigma_prev = 0.0f64;
    let mut iters = 0;
    for k in 0..max_iters {
        iters = k + 1;
        // u <- G v / ||.||, v <- G^T u / ||.||
        g.apply(&v, &mut u);
        normalize(&mut u);
        g.tapply(&u, &mut v);
        let sigma = normalize(&mut v);
        if (sigma - sigma_prev).abs() <= tol * sigma.max(1e-30) {
            break;
        }
        sigma_prev = sigma;
    }
    // sigma = u^T G v (>= 0 by construction of the pair); apply_dot
    // avoids the historical `G v` recompute buffer, so the only
    // allocations per call are the returned (u, v) themselves
    let sigma = g.apply_dot(&u, &v);
    Svd1 { u, v, sigma, iters }
}

/// Power iteration with a random restart vector drawn from `rng`.
pub fn power_iteration_rand<A: LinOp + ?Sized>(
    g: &A,
    rng: &mut Rng,
    max_iters: usize,
    tol: f64,
) -> Svd1 {
    let v0 = rng.unit_vector(g.cols());
    power_iteration(g, &v0, max_iters, tol)
}

/// Full SVD by one-sided Jacobi: returns (U, sigma, V) with
/// A = U diag(sigma) V^T, sigma descending, U: (m, r), V: (n, r),
/// r = min(m, n).  Exact to f32 round-off; O(mn^2) per sweep — used by the
/// PGD baseline's projection and by tests, never on the SFW hot path.
pub fn jacobi_svd(a: &Mat) -> (Mat, Vec<f32>, Mat) {
    // Work on the transpose if wide, so columns <= rows.
    if a.cols > a.rows {
        let (v, s, u) = jacobi_svd(&a.transpose());
        return (u, s, v);
    }
    let (m, n) = (a.rows, a.cols);
    // Column-major copy of A's columns for cache-friendly column rotations.
    let mut cols: Vec<Vec<f32>> = (0..n)
        .map(|j| (0..m).map(|i| a.at(i, j)).collect())
        .collect();
    let mut v = Mat::zeros(n, n);
    for j in 0..n {
        *v.at_mut(j, j) = 1.0;
    }
    let eps = 1e-10f64;
    let max_sweeps = 60;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let app = dot64(&cols[p], &cols[p]);
                let aqq = dot64(&cols[q], &cols[q]);
                let apq = dot64(&cols[p], &cols[q]);
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) entry of A^T A.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                let (cf, sf) = (c as f32, s as f32);
                for i in 0..m {
                    let (xp, xq) = (cols[p][i], cols[q][i]);
                    cols[p][i] = cf * xp - sf * xq;
                    cols[q][i] = sf * xp + cf * xq;
                }
                for i in 0..n {
                    let (vp, vq) = (v.at(i, p), v.at(i, q));
                    *v.at_mut(i, p) = cf * vp - sf * vq;
                    *v.at_mut(i, q) = sf * vp + cf * vq;
                }
            }
        }
        if off < eps {
            break;
        }
    }
    // Singular values = column norms; U = normalized columns.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = cols.iter().map(|c| norm2(c)).collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());
    let mut u = Mat::zeros(m, n);
    let mut sigma = vec![0.0f32; n];
    let mut vperm = Mat::zeros(n, n);
    for (new_j, &old_j) in order.iter().enumerate() {
        sigma[new_j] = norms[old_j] as f32;
        let inv = if norms[old_j] > 0.0 { 1.0 / norms[old_j] } else { 0.0 };
        for i in 0..m {
            *u.at_mut(i, new_j) = (cols[old_j][i] as f64 * inv) as f32;
        }
        for i in 0..n {
            *vperm.at_mut(i, new_j) = v.at(i, old_j);
        }
    }
    (u, sigma, vperm)
}

/// Nuclear norm ||A||_* = sum of singular values (exact, via Jacobi SVD).
pub fn nuclear_norm(a: &Mat) -> f64 {
    let (_, s, _) = jacobi_svd(a);
    s.iter().map(|x| *x as f64).sum()
}

/// Numerical rank: singular values above `1e-6 * sigma_max` (exact, via
/// Jacobi SVD — reporting-path only, never the hot loop).
pub fn numerical_rank(a: &Mat) -> usize {
    let (_, s, _) = jacobi_svd(a);
    let s0 = s.first().copied().unwrap_or(0.0);
    if s0 <= 0.0 {
        return 0;
    }
    s.iter().filter(|&&x| x > 1e-6 * s0).count()
}

fn dot64(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reconstruct(u: &Mat, s: &[f32], v: &Mat) -> Mat {
        let mut us = u.clone();
        for j in 0..s.len() {
            for i in 0..us.rows {
                *us.at_mut(i, j) *= s[j];
            }
        }
        us.matmul(&v.transpose())
    }

    #[test]
    fn jacobi_svd_reconstructs() {
        let mut rng = Rng::new(11);
        for (m, n) in [(5, 3), (3, 5), (8, 8), (30, 30)] {
            let a = Mat::randn(m, n, 1.0, &mut rng);
            let (u, s, v) = jacobi_svd(&a);
            let r = reconstruct(&u, &s, &v);
            let err = {
                let mut d = a.clone();
                d.axpy(-1.0, &r);
                d.frob_norm() / a.frob_norm()
            };
            assert!(err < 1e-5, "({m},{n}) err {err}");
            // descending order
            for w in s.windows(2) {
                assert!(w[0] >= w[1] - 1e-6);
            }
        }
    }

    #[test]
    fn jacobi_svd_orthonormal_factors() {
        let mut rng = Rng::new(12);
        let a = Mat::randn(10, 6, 1.0, &mut rng);
        let (u, _, v) = jacobi_svd(&a);
        let utu = u.transpose().matmul(&u);
        let vtv = v.transpose().matmul(&v);
        for i in 0..6 {
            for j in 0..6 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((utu.at(i, j) - expect).abs() < 1e-4, "UtU");
                assert!((vtv.at(i, j) - expect).abs() < 1e-4, "VtV");
            }
        }
    }

    #[test]
    fn power_iteration_matches_jacobi_top_singular_value() {
        let mut rng = Rng::new(13);
        for (m, n) in [(6, 4), (30, 30), (20, 50)] {
            // boost the top direction so convergence is fast & unambiguous
            let mut a = Mat::randn(m, n, 1.0, &mut rng);
            let u = rng.unit_vector(m);
            let v = rng.unit_vector(n);
            let boost = 4.0 * ((m * n) as f32).sqrt();
            for i in 0..m {
                for j in 0..n {
                    *a.at_mut(i, j) += boost * u[i] * v[j];
                }
            }
            let (_, s, _) = jacobi_svd(&a);
            let p = power_iteration_rand(&a, &mut rng, 200, 1e-10);
            assert!(
                (p.sigma - s[0]).abs() / s[0] < 1e-3,
                "({m},{n}): power {} vs jacobi {}",
                p.sigma,
                s[0]
            );
            assert!((norm2(&p.u) - 1.0).abs() < 1e-5);
            assert!((norm2(&p.v) - 1.0).abs() < 1e-5);
            assert!(p.sigma >= 0.0);
        }
    }

    #[test]
    fn power_iteration_rank_one_is_exact() {
        let mut rng = Rng::new(14);
        let u = rng.unit_vector(7);
        let v = rng.unit_vector(5);
        let mut a = Mat::zeros(7, 5);
        for i in 0..7 {
            for j in 0..5 {
                *a.at_mut(i, j) = 3.5 * u[i] * v[j];
            }
        }
        let p = power_iteration_rand(&a, &mut rng, 50, 1e-12);
        assert!((p.sigma - 3.5).abs() < 1e-4);
        let align: f32 = u.iter().zip(&p.u).map(|(a, b)| a * b).sum();
        assert!(align.abs() > 0.9999);
    }

    #[test]
    fn nuclear_norm_of_diag() {
        let mut a = Mat::zeros(3, 3);
        *a.at_mut(0, 0) = 2.0;
        *a.at_mut(1, 1) = -1.0; // singular value is |.|
        *a.at_mut(2, 2) = 0.5;
        assert!((nuclear_norm(&a) - 3.5).abs() < 1e-5);
    }
}
