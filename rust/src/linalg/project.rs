//! Projections: Euclidean projection onto the simplex / l1-ball (Duchi et
//! al. 2008) and onto the nuclear-norm ball (full SVD + singular-value
//! l1-projection).  Used by the PGD baseline — the paper's point is that FW
//! *avoids* this O(D1 D2 min(D1,D2)) step; we implement it to reproduce the
//! comparison honestly.

use super::factored::FactoredMat;
use super::mat::Mat;
use super::svd::jacobi_svd;

/// Euclidean projection of `v` onto the simplex {x >= 0, sum x = z}.
pub fn simplex_projection(v: &[f32], z: f32) -> Vec<f32> {
    assert!(z > 0.0);
    let mut mu: Vec<f32> = v.to_vec();
    mu.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut cumsum = 0.0f64;
    let mut rho = 0usize;
    let mut theta = 0.0f64;
    for (j, &m) in mu.iter().enumerate() {
        cumsum += m as f64;
        let t = (cumsum - z as f64) / (j + 1) as f64;
        if (m as f64) - t > 0.0 {
            rho = j + 1;
            theta = t;
        }
    }
    let _ = rho;
    v.iter().map(|&x| (x as f64 - theta).max(0.0) as f32).collect()
}

/// Euclidean projection onto the l1-ball {||x||_1 <= z} (sign-split simplex).
pub fn l1_projection(v: &[f32], z: f32) -> Vec<f32> {
    let l1: f64 = v.iter().map(|x| x.abs() as f64).sum();
    if l1 <= z as f64 {
        return v.to_vec();
    }
    let abs: Vec<f32> = v.iter().map(|x| x.abs()).collect();
    let w = simplex_projection(&abs, z);
    v.iter().zip(w).map(|(&x, wi)| wi.copysign(x)).collect()
}

/// Euclidean projection onto the nuclear-norm ball {||X||_* <= theta}:
/// SVD, project the singular values onto the l1 ball, reconstruct.
/// Returns the input unchanged (no SVD) when already inside.
pub fn nuclear_ball_projection(x: &Mat, theta: f32) -> Mat {
    let (u, s, v) = jacobi_svd(x);
    let nn: f64 = s.iter().map(|x| *x as f64).sum();
    if nn <= theta as f64 + 1e-7 {
        return x.clone();
    }
    let s_proj = simplex_projection(&s, theta);
    // X' = U diag(s') V^T, skipping zeroed directions.
    let mut out = Mat::zeros(x.rows, x.cols);
    for (k, &sk) in s_proj.iter().enumerate() {
        if sk == 0.0 {
            continue;
        }
        for i in 0..x.rows {
            let uik = u.at(i, k) * sk;
            if uik == 0.0 {
                continue;
            }
            let row = out.row_mut(i);
            for j in 0..x.cols {
                row[j] += uik * v.at(j, k);
            }
        }
    }
    out
}

/// Nuclear-ball projection straight into factored form: the SVD the
/// projection needs anyway already IS the atom decomposition, so the
/// factored-mode PGD baseline gets its iterate for free — singular
/// directions zeroed by the simplex projection are simply not emitted
/// (the projection preserves the descending order, so
/// [`FactoredMat::from_svd`]'s cutoff applies).
pub fn factored_nuclear_projection(x: &Mat, theta: f32) -> FactoredMat {
    let (u, s, v) = jacobi_svd(x);
    let nn: f64 = s.iter().map(|x| *x as f64).sum();
    let s_kept: Vec<f32> = if nn <= theta as f64 + 1e-7 {
        s
    } else {
        simplex_projection(&s, theta)
    };
    FactoredMat::from_svd(&u, &s_kept, &v, 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::nuclear_norm;
    use crate::util::rng::Rng;

    #[test]
    fn simplex_projection_feasible_and_idempotent() {
        let v = vec![0.5, 0.3, 0.2];
        let p = simplex_projection(&v, 1.0);
        // already on the simplex -> unchanged
        for (a, b) in v.iter().zip(&p) {
            assert!((a - b).abs() < 1e-6);
        }
        let q = simplex_projection(&[2.0, 0.0, 0.0], 1.0);
        assert!((q.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(q.iter().all(|&x| x >= 0.0));
        assert!((q[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn simplex_projection_kkt_optimality() {
        // The projection must satisfy: p_i = max(v_i - theta, 0) for a
        // single threshold theta with sum p = z.  Verify via random probes:
        // no feasible direction improves the distance.
        let mut rng = Rng::new(20);
        for _ in 0..20 {
            let v: Vec<f32> = (0..8).map(|_| rng.normal_f32()).collect();
            let p = simplex_projection(&v, 1.0);
            let sum: f32 = p.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(p.iter().all(|&x| x >= 0.0));
            let d0: f64 = v.iter().zip(&p).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
            for _ in 0..30 {
                let q = {
                    let raw: Vec<f32> = (0..8).map(|_| rng.next_f32()).collect();
                    let s: f32 = raw.iter().sum();
                    raw.iter().map(|x| x / s).collect::<Vec<_>>()
                };
                let d1: f64 =
                    v.iter().zip(&q).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
                assert!(d1 >= d0 - 1e-6);
            }
        }
    }

    #[test]
    fn l1_projection_inside_is_identity() {
        let v = vec![0.1, -0.2, 0.05];
        assert_eq!(l1_projection(&v, 1.0), v);
    }

    #[test]
    fn l1_projection_shrinks_to_ball_preserving_signs() {
        let v = vec![3.0, -4.0, 0.0];
        let p = l1_projection(&v, 1.0);
        let l1: f32 = p.iter().map(|x| x.abs()).sum();
        assert!((l1 - 1.0).abs() < 1e-5);
        assert!(p[0] >= 0.0 && p[1] <= 0.0 && p[2].abs() < 1e-6);
    }

    #[test]
    fn nuclear_projection_feasible_and_identity_inside() {
        let mut rng = Rng::new(21);
        let x = Mat::randn(6, 5, 1.0, &mut rng);
        let p = nuclear_ball_projection(&x, 1.0);
        assert!(nuclear_norm(&p) <= 1.0 + 1e-4);
        // inside the ball -> unchanged
        let mut small = x.clone();
        let nn = nuclear_norm(&x) as f32;
        small.scale(0.5 / nn);
        let q = nuclear_ball_projection(&small, 1.0);
        let mut d = q.clone();
        d.axpy(-1.0, &small);
        assert!(d.frob_norm() < 1e-6);
    }

    #[test]
    fn factored_projection_matches_dense_projection() {
        let mut rng = Rng::new(23);
        for scale in [0.4f32, 2.0] {
            // one case inside the ball (identity path), one outside
            let mut x = Mat::randn(6, 5, 1.0, &mut rng);
            let nn = nuclear_norm(&x) as f32;
            x.scale(scale / nn);
            let dense = nuclear_ball_projection(&x, 1.0);
            let fact = factored_nuclear_projection(&x, 1.0).to_dense();
            let mut d = dense.clone();
            d.axpy(-1.0, &fact);
            assert!(
                d.frob_norm() < 1e-4 * (1.0 + dense.frob_norm()),
                "scale {scale}: diff {}",
                d.frob_norm()
            );
        }
    }

    #[test]
    fn nuclear_projection_is_contraction_toward_ball() {
        let mut rng = Rng::new(22);
        let x = Mat::randn(8, 8, 2.0, &mut rng);
        let p = nuclear_ball_projection(&x, 1.0);
        // distance to any feasible point >= distance from projection (obtuse
        // angle property), spot-check with rank-one feasible points
        let mut dxp = x.clone();
        dxp.axpy(-1.0, &p);
        let dist_p = dxp.frob_norm();
        for _ in 0..10 {
            let u = rng.unit_vector(8);
            let v = rng.unit_vector(8);
            let mut f = Mat::zeros(8, 8);
            for i in 0..8 {
                for j in 0..8 {
                    *f.at_mut(i, j) = u[i] * v[j];
                }
            }
            let mut d = x.clone();
            d.axpy(-1.0, &f);
            assert!(d.frob_norm() >= dist_p - 1e-4);
        }
    }
}
