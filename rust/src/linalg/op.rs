//! [`LinOp`]: the implicit linear-operator interface the LMO runs against.
//!
//! The Frank-Wolfe LMO only ever needs matrix-vector products `A x` /
//! `A^T x` of the gradient — never its entries — so `power_iteration`
//! is written against this trait instead of a concrete [`Mat`].  A dense
//! gradient is one implementation; a [`FactoredMat`] iterate (sum of
//! rank-one atoms) is another that never materializes the `d1 x d2`
//! array.  Implementations should override [`LinOp::apply_dot`] with an
//! allocation-free form: it is the hot-path sigma recompute of the LMO
//! (`u^T A v`), called once per `power_iteration`.
//!
//! [`FactoredMat`]: crate::linalg::FactoredMat

use super::kernels;
use super::mat::{dot, Mat};

/// Rows per f64 partial block of the dense [`LinOp::apply_dot`] override
/// (fixed-size blocks combined in block order; see the kernels
/// determinism contract).
const AD_ROW_BLOCK: usize = 64;

/// A linear operator `A: R^cols -> R^rows` exposed through matvecs.
pub trait LinOp {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    /// `y = A x` (`x` of length `cols`, `y` of length `rows`).
    fn apply(&self, x: &[f32], y: &mut [f32]);
    /// `y = A^T x` (`x` of length `rows`, `y` of length `cols`).
    fn tapply(&self, x: &[f32], y: &mut [f32]);
    /// `y^T A x` — the LMO's sigma estimate.  The default materializes
    /// `A x`; hot-path operators override it allocation-free.
    fn apply_dot(&self, y: &[f32], x: &[f32]) -> f32 {
        let mut ax = vec![0.0f32; self.rows()];
        self.apply(x, &mut ax);
        dot(y, &ax)
    }
}

impl LinOp for Mat {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn apply(&self, x: &[f32], y: &mut [f32]) {
        self.matvec(x, y);
    }
    fn tapply(&self, x: &[f32], y: &mut [f32]) {
        self.tmatvec(x, y);
    }
    /// Row-wise `sum_r y_r * (A x)_r` with the same f32-round-then-f64-
    /// accumulate placement as `dot(y, A x)` (equal to it up to f64
    /// summation order), so the generic LMO matches the historical dense
    /// path — without the `A x` scratch vector.  Above the kernels work
    /// threshold the row loop is cut into fixed [`AD_ROW_BLOCK`] f64
    /// partials combined in block order (bit-identical for any thread
    /// count).
    fn apply_dot(&self, y: &[f32], x: &[f32]) -> f32 {
        debug_assert_eq!(y.len(), self.rows);
        debug_assert_eq!(x.len(), self.cols);
        let block_acc = |lo: usize, hi: usize| {
            let mut acc = 0.0f64;
            for r in lo..hi {
                acc += y[r] as f64 * dot(self.row(r), x) as f64;
            }
            acc
        };
        let nblocks = if self.rows * self.cols >= kernels::PAR_MIN_WORK {
            self.rows.div_ceil(AD_ROW_BLOCK)
        } else {
            1
        };
        if nblocks <= 1 {
            return block_acc(0, self.rows) as f32;
        }
        kernels::Pool::map_chunks(nblocks, |b| {
            block_acc(b * AD_ROW_BLOCK, ((b + 1) * AD_ROW_BLOCK).min(self.rows))
        })
        .into_iter()
        .sum::<f64>() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mat_linop_matches_matvec_and_dot() {
        let mut rng = Rng::new(300);
        let a = Mat::randn(5, 7, 1.0, &mut rng);
        let x: Vec<f32> = (0..7).map(|_| rng.normal_f32()).collect();
        let y: Vec<f32> = (0..5).map(|_| rng.normal_f32()).collect();
        let mut ax = vec![0.0f32; 5];
        LinOp::apply(&a, &x, &mut ax);
        let mut ax_ref = vec![0.0f32; 5];
        a.matvec(&x, &mut ax_ref);
        assert_eq!(ax, ax_ref);
        // apply_dot override must equal the default (dot against A x)
        let want = dot(&y, &ax_ref);
        assert!((a.apply_dot(&y, &x) - want).abs() <= 1e-6 * (1.0 + want.abs()));
        let mut atx = vec![0.0f32; 7];
        LinOp::tapply(&a, &y, &mut atx);
        let mut atx_ref = vec![0.0f32; 7];
        a.tmatvec(&y, &mut atx_ref);
        assert_eq!(atx, atx_ref);
    }
}
