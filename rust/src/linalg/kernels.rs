//! Deterministic SIMD + thread-pooled compute kernels for the LMO hot path.
//!
//! Every solver funnels its per-step compute through a handful of loops —
//! the operator-form power iteration ([`crate::linalg::svd::power_iteration`]),
//! the per-atom [`crate::linalg::FactoredMat`] sums, and the O(nnz) sparse
//! gradient.  This module is the ONE implementation those loops share:
//! runtime-dispatched AVX2+FMA intrinsics with a scalar twin, plus a small
//! scoped thread pool ([`Pool`]), both engineered so the numeric result is
//! **bit-identical regardless of SIMD width and thread count**.
//!
//! # Dispatch rules
//!
//! * On `x86_64`, [`simd_enabled`] gates every intrinsic path behind
//!   `is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")`
//!   — checked at runtime, so one binary serves both old and new hosts.
//! * [`force_scalar`] pins the scalar twin for benches and property tests
//!   (the SIMD-vs-scalar pairs in `benches/hotpath.rs` drive it).
//! * On every other architecture the scalar twin is the only path.
//!
//! # Determinism contract
//!
//! The contract that lets `--threads N` stay bit-identical to
//! `--threads 1` (and lets the same-seed dense-vs-factored /
//! cross-transport suites in `rust/tests/{factored,chaos,sparse}.rs` keep
//! passing unchanged):
//!
//! 1. **Lane-striped f64 accumulation.**  Dot-like reductions use eight
//!    f64 lane accumulators with the fixed assignment `lane = i % 8`,
//!    combined by the fixed tree `(l0+l4, l1+l5, l2+l6, l3+l7)` then
//!    `(c0+c2) + (c1+c3)`.  The AVX2 path computes literally the same
//!    sums: each f32 product is exact in f64 (24+24 <= 53 mantissa bits),
//!    so `_mm256_fmadd_pd` rounds once per add exactly like the scalar
//!    `lane += a as f64 * b as f64`.
//! 2. **Fixed-size chunks, fixed combine order.**  Long reductions are
//!    split into [`CHUNK`]-element partial sums combined sequentially in
//!    chunk-index order — the same order whether the chunks were computed
//!    serially or by [`Pool`] workers.
//! 3. **Size-gated code paths.**  Whether a call takes the serial or the
//!    block-partial path depends ONLY on the problem size
//!    ([`PAR_MIN_WORK`]), never on the configured thread count.  Block
//!    partials start from zeroed buffers even when computed serially
//!    (direct accumulation could produce `-0.0` where `0.0 + (-0.0)`
//!    gives `+0.0`).
//! 4. **NaN propagation.**  No kernel skips an element because it is NaN:
//!    [`max_abs`] detects NaNs explicitly and returns NaN, and callers'
//!    `== 0.0` skip-guards are false for NaN, so a poisoned value always
//!    reaches the output (see `FactoredMat::apply`).
//!
//! # The pool
//!
//! [`Pool`] is not a persistent worker set: every call spawns scoped
//! `std::thread` workers over contiguous chunk stripes (the
//! `session::harness` idiom) and joins them before returning — no
//! channels at all, so there is nothing unbounded to leak.  The process
//! shares one thread budget ([`set_pool_threads`], wired from
//! `TrainSpec::threads` in `RunCtx::new`); concurrent runs racing on it
//! are benign because results are thread-count-invariant by construction.
//!
//! # Adding a kernel
//!
//! 1. Write the scalar twin first, using the lane-striped reduction (or
//!    no reduction at all for elementwise maps).
//! 2. Mirror it with `#[target_feature(enable = "avx2", enable = "fma")]`
//!    intrinsics that compute the SAME sums in the SAME order — a `//
//!    SAFETY:` comment on every `unsafe` (enforced by `sfw lint`).
//! 3. Dispatch through [`simd_enabled`] and add a bitwise SIMD-vs-scalar
//!    property test across odd lengths and remainder tails below.
//! 4. If the op is worth threading, split it on fixed-size chunks and
//!    combine partials in chunk order; gate on [`PAR_MIN_WORK`].

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Elements per reduction chunk (a multiple of 8 so full chunks hold
/// whole lane stripes).  Fixed: changing it changes results (legally —
/// nothing pins bits across builds, only across thread/SIMD configs).
pub const CHUNK: usize = 1024;

/// Minimum per-call element work before a kernel takes the block-partial
/// (threadable) path.  Below it the serial path is both faster and — by
/// contract rule 3 — the only path, independent of the thread budget.
pub const PAR_MIN_WORK: usize = 1 << 17;

static POOL_THREADS: AtomicUsize = AtomicUsize::new(1);
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Set the process-wide thread budget (floored at 1).  Wired from
/// `TrainSpec::threads` when a run context is built; every worker in the
/// process shares it.
pub fn set_pool_threads(n: usize) {
    POOL_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Current thread budget.
pub fn pool_threads() -> usize {
    POOL_THREADS.load(Ordering::Relaxed)
}

/// Pin the scalar twin even on AVX2 hosts (bench/test knob; results are
/// bit-identical either way, this only switches the instruction mix).
pub fn force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

/// Whether the intrinsic paths are live: `x86_64` with runtime-detected
/// AVX2 + FMA and no [`force_scalar`] override.
#[inline]
pub fn simd_enabled() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        !FORCE_SCALAR.load(Ordering::Relaxed)
            && is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Human-readable CPU dispatch state for bench/CI environment records
/// ("avx2+fma" or "scalar") — a bench compare across differing values
/// must be flagged, not silently judged (`scripts/bench_snapshot.py`).
pub fn cpu_features() -> String {
    if simd_enabled() { "avx2+fma".into() } else { "scalar".into() }
}

// ---------------------------------------------------------------------------
// Scoped thread pool
// ---------------------------------------------------------------------------

/// Scoped fork-join helper over fixed chunk grids.  See the module docs:
/// stateless, channel-free, deterministic by construction because chunk
/// results are combined in chunk-index order regardless of which thread
/// produced them.
pub struct Pool;

impl Pool {
    /// Evaluate `f(0..nchunks)` and return the results **in chunk order**,
    /// striping contiguous chunk ranges across up to [`pool_threads`]
    /// scoped workers.  With a budget of 1 (or a single chunk) this is a
    /// plain serial map — same outputs by construction.
    pub fn map_chunks<T, F>(nchunks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let threads = pool_threads().min(nchunks).max(1);
        if threads <= 1 {
            return (0..nchunks).map(f).collect();
        }
        let mut out = Vec::with_capacity(nchunks);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let f = &f;
                    let lo = nchunks * t / threads;
                    let hi = nchunks * (t + 1) / threads;
                    s.spawn(move || (lo..hi).map(f).collect::<Vec<T>>())
                })
                .collect();
            // join in spawn order => chunk order is preserved
            for h in handles {
                out.extend(h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)));
            }
        });
        out
    }

    /// Scatter variant: split `out` into `chunk`-sized disjoint slices and
    /// run `f(chunk_index, slice)` on each, striped across the pool.  Safe
    /// parallelism without any `unsafe`: `chunks_mut` hands every worker
    /// exclusive slices.  Outputs are disjoint, so this is trivially
    /// thread-count-invariant when `f(i, _)` itself is deterministic.
    pub fn for_chunks_mut<F>(out: &mut [f32], chunk: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        debug_assert!(chunk > 0);
        let mut parts: Vec<(usize, &mut [f32])> = out.chunks_mut(chunk).enumerate().collect();
        let n = parts.len();
        let threads = pool_threads().min(n).max(1);
        if threads <= 1 {
            for (i, p) in parts {
                f(i, p);
            }
            return;
        }
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(threads);
            for t in (0..threads).rev() {
                let stripe = parts.split_off(n * t / threads);
                let f = &f;
                handles.push(s.spawn(move || {
                    for (i, p) in stripe {
                        f(i, p);
                    }
                }));
            }
            for h in handles {
                h.join().unwrap_or_else(|e| std::panic::resume_unwind(e));
            }
        });
    }
}

// ---------------------------------------------------------------------------
// Reductions
// ---------------------------------------------------------------------------

/// The fixed lane-combine tree of contract rule 1.
#[inline]
fn combine_lanes(l: &[f64; 8]) -> f64 {
    let c = [l[0] + l[4], l[1] + l[5], l[2] + l[6], l[3] + l[7]];
    (c[0] + c[2]) + (c[1] + c[3])
}

fn dot_chunk_scalar(a: &[f32], b: &[f32]) -> f64 {
    let mut lanes = [0.0f64; 8];
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        lanes[i % 8] += x as f64 * y as f64;
    }
    combine_lanes(&lanes)
}

/// AVX2+FMA twin of [`dot_chunk_scalar`]: acc0 holds lanes 0..4, acc1
/// lanes 4..8, so element `i` lands in lane `i % 8` exactly like the
/// scalar stripe; the f32xf32 product is exact in f64, so the fused add
/// rounds identically to the scalar `lane += x as f64 * y as f64`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_chunk_avx2(a: &[f32], b: &[f32]) -> f64 {
    use std::arch::x86_64::*;
    let n = a.len();
    let blocks = n / 8;
    let mut lanes = [0.0f64; 8];
    // SAFETY: every pointer offset below is < n elements into a/b
    // (i * 8 + 7 < blocks * 8 <= n), and loadu/storeu tolerate any
    // alignment.  The caller guaranteed a.len() == b.len().
    unsafe {
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        for i in 0..blocks {
            let va = _mm256_loadu_ps(pa.add(i * 8));
            let vb = _mm256_loadu_ps(pb.add(i * 8));
            let lo = _mm256_mul_pd(
                _mm256_cvtps_pd(_mm256_castps256_ps128(va)),
                _mm256_cvtps_pd(_mm256_castps256_ps128(vb)),
            );
            let hi = _mm256_mul_pd(
                _mm256_cvtps_pd(_mm256_extractf128_ps(va, 1)),
                _mm256_cvtps_pd(_mm256_extractf128_ps(vb, 1)),
            );
            acc0 = _mm256_add_pd(acc0, lo);
            acc1 = _mm256_add_pd(acc1, hi);
        }
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc0);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc1);
    }
    for j in blocks * 8..n {
        lanes[j % 8] += a[j] as f64 * b[j] as f64;
    }
    combine_lanes(&lanes)
}

#[inline]
fn dot_chunk(a: &[f32], b: &[f32]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        if simd_enabled() {
            // SAFETY: simd_enabled() verified avx2+fma at runtime, and the
            // slices have equal length (asserted by the public entry).
            return unsafe { dot_chunk_avx2(a, b) };
        }
    }
    dot_chunk_scalar(a, b)
}

/// `sum_i a[i] * b[i]` with the deterministic f64 reduction of the module
/// contract.  Thread-parallel above [`PAR_MIN_WORK`]; the chunk partials
/// are combined in chunk order either way, so the result is independent
/// of both the thread budget and SIMD availability.
pub fn dot64(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    let nchunks = n.div_ceil(CHUNK).max(1);
    let chunk_dot = |c: usize| {
        let lo = c * CHUNK;
        let hi = (lo + CHUNK).min(n);
        dot_chunk(&a[lo..hi], &b[lo..hi])
    };
    if nchunks == 1 {
        return chunk_dot(0);
    }
    if n >= PAR_MIN_WORK && pool_threads() > 1 {
        Pool::map_chunks(nchunks, chunk_dot).into_iter().sum()
    } else {
        (0..nchunks).map(chunk_dot).sum()
    }
}

/// `sum_i v[i]^2` — [`dot64`] against itself (one reduction to rule them
/// all: `norm2`, `frob_norm`, and the PJRT tolerance checks agree by
/// construction).
#[inline]
pub fn sumsq(v: &[f32]) -> f64 {
    dot64(v, v)
}

// ---------------------------------------------------------------------------
// Elementwise axpy
// ---------------------------------------------------------------------------

fn axpy_scalar(y: &mut [f32], a: f32, x: &[f32]) {
    for (yi, &xi) in y.iter_mut().zip(x.iter()) {
        // f32::mul_add is a correctly-rounded fused multiply-add on every
        // target, so this matches _mm256_fmadd_ps bit-for-bit.
        *yi = xi.mul_add(a, *yi);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_avx2(y: &mut [f32], a: f32, x: &[f32]) {
    use std::arch::x86_64::*;
    let n = y.len();
    let blocks = n / 8;
    // SAFETY: every offset is < n elements into x/y (i * 8 + 7 <
    // blocks * 8 <= n); loadu/storeu tolerate any alignment; x and y are
    // distinct borrows so the store cannot alias the loads.
    unsafe {
        let va = _mm256_set1_ps(a);
        let (py, px) = (y.as_mut_ptr(), x.as_ptr());
        for i in 0..blocks {
            let vy = _mm256_loadu_ps(py.add(i * 8));
            let vx = _mm256_loadu_ps(px.add(i * 8));
            _mm256_storeu_ps(py.add(i * 8), _mm256_fmadd_ps(vx, va, vy));
        }
    }
    for j in blocks * 8..n {
        y[j] = x[j].mul_add(a, y[j]);
    }
}

/// `y[i] += a * x[i]`, fused (one rounding per element on every path).
/// Elementwise — no reduction, so order never matters; SIMD and scalar
/// agree bitwise because both use a correctly-rounded FMA.
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    {
        if simd_enabled() {
            // SAFETY: simd_enabled() verified avx2+fma at runtime; lengths
            // are equal (asserted above).
            unsafe { axpy_avx2(y, a, x) };
            return;
        }
    }
    axpy_scalar(y, a, x);
}

// ---------------------------------------------------------------------------
// max |x| with an explicit NaN contract
// ---------------------------------------------------------------------------

fn max_abs_scalar(v: &[f32]) -> f32 {
    let mut m = 0.0f32;
    let mut any_nan = false;
    for &x in v {
        any_nan |= x.is_nan();
        m = m.max(x.abs());
    }
    if any_nan { f32::NAN } else { m }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn max_abs_avx2(v: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = v.len();
    let blocks = n / 8;
    let mut head = [0.0f32; 8];
    let mut any_nan = false;
    // SAFETY: every offset is < n elements into v (i * 8 + 7 <
    // blocks * 8 <= n); loadu tolerates any alignment.  The abs mask
    // clears only the sign bit; NaNs are detected separately via the
    // unordered self-compare, so max_ps's NaN-dropping is irrelevant.
    unsafe {
        let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
        let mut vmax = _mm256_setzero_ps();
        let mut vnan = _mm256_setzero_ps();
        let p = v.as_ptr();
        for i in 0..blocks {
            let x = _mm256_loadu_ps(p.add(i * 8));
            vnan = _mm256_or_ps(vnan, _mm256_cmp_ps(x, x, _CMP_UNORD_Q));
            vmax = _mm256_max_ps(vmax, _mm256_and_ps(x, absmask));
        }
        any_nan |= _mm256_movemask_ps(vnan) != 0;
        _mm256_storeu_ps(head.as_mut_ptr(), vmax);
    }
    let mut m = 0.0f32;
    for &h in &head {
        m = m.max(h);
    }
    for j in blocks * 8..n {
        any_nan |= v[j].is_nan();
        m = m.max(v[j].abs());
    }
    if any_nan { f32::NAN } else { m }
}

/// `max_i |v[i]|` with an explicit NaN-propagation contract: **any NaN in
/// the input returns NaN** (a plain `f32::max` fold silently skips NaNs,
/// which let a poisoned gradient slide through the int8 `GradCodec` scale
/// scan unflagged).  Max over the non-NaN values is order-independent, so
/// SIMD and scalar agree bitwise.  Empty input returns 0.0.
pub fn max_abs(v: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if simd_enabled() {
            // SAFETY: simd_enabled() verified avx2 (and fma) at runtime.
            return unsafe { max_abs_avx2(v) };
        }
    }
    max_abs_scalar(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Serial all-scalar reference: the chunked reduction with the
    /// intrinsic path pinned off.  The public `dot64` must match this
    /// bit-for-bit whatever the host supports.
    fn dot64_scalar_ref(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len();
        let nchunks = n.div_ceil(CHUNK).max(1);
        (0..nchunks)
            .map(|c| {
                let lo = c * CHUNK;
                let hi = (lo + CHUNK).min(n);
                dot_chunk_scalar(&a[lo..hi], &b[lo..hi])
            })
            .sum()
    }

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn dot_simd_matches_scalar_bitwise_across_lengths() {
        // empty, 1-element, every remainder tail mod 8, chunk boundaries
        let lens: Vec<usize> =
            (0..=17).chain([31, 64, 100, 1023, 1024, 1025, 2048 + 3]).collect();
        for n in lens {
            let a = randv(n, 1000 + n as u64);
            let b = randv(n, 2000 + n as u64);
            let got = dot64(&a, &b);
            let want = dot64_scalar_ref(&a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "len {n}: {got} vs {want}");
        }
    }

    #[test]
    fn dot_is_bit_invariant_in_thread_count() {
        let n = PAR_MIN_WORK + 12345; // odd tail, forces the parallel path
        let a = randv(n, 7);
        let b = randv(n, 8);
        let serial = dot64(&a, &b);
        set_pool_threads(4);
        let threaded = dot64(&a, &b);
        set_pool_threads(1);
        assert_eq!(serial.to_bits(), threaded.to_bits());
        assert_eq!(serial.to_bits(), dot64_scalar_ref(&a, &b).to_bits());
    }

    #[test]
    fn axpy_simd_matches_scalar_bitwise() {
        for n in (0..=17).chain([100, 1000]) {
            let x = randv(n, 300 + n as u64);
            let y0 = randv(n, 400 + n as u64);
            let mut via_dispatch = y0.clone();
            axpy(&mut via_dispatch, 0.37, &x);
            let mut via_scalar = y0.clone();
            axpy_scalar(&mut via_scalar, 0.37, &x);
            for (a, b) in via_dispatch.iter().zip(&via_scalar) {
                assert_eq!(a.to_bits(), b.to_bits(), "len {n}");
            }
        }
    }

    #[test]
    fn max_abs_matches_scalar_and_propagates_nan() {
        for n in (0..=17).chain([100, 999]) {
            let v = randv(n, 500 + n as u64);
            let got = max_abs(&v);
            let want = max_abs_scalar(&v);
            assert_eq!(got.to_bits(), want.to_bits(), "len {n}");
        }
        assert_eq!(max_abs(&[]), 0.0);
        assert_eq!(max_abs(&[-3.5]), 3.5);
        // NaN anywhere (vector body or tail) => NaN out, on both paths
        for pos in [0, 3, 7, 8, 20, 22] {
            let mut v = randv(23, 600);
            v[pos] = f32::NAN;
            assert!(max_abs(&v).is_nan(), "NaN at {pos} swallowed");
            assert!(max_abs_scalar(&v).is_nan());
        }
    }

    #[test]
    fn forced_scalar_dispatch_is_bit_identical() {
        let a = randv(4096 + 5, 31);
        let b = randv(4096 + 5, 32);
        let native = dot64(&a, &b);
        force_scalar(true);
        let scalar = dot64(&a, &b);
        force_scalar(false);
        assert_eq!(native.to_bits(), scalar.to_bits());
    }

    #[test]
    fn map_chunks_preserves_chunk_order() {
        set_pool_threads(3);
        let got = Pool::map_chunks(17, |i| i * i);
        set_pool_threads(1);
        assert_eq!(got, (0..17).map(|i| i * i).collect::<Vec<_>>());
        assert!(Pool::map_chunks(0, |i| i).is_empty());
    }

    #[test]
    fn for_chunks_mut_covers_every_slice_once() {
        let mut buf = vec![0.0f32; 103];
        set_pool_threads(4);
        Pool::for_chunks_mut(&mut buf, 10, |i, s| {
            for x in s.iter_mut() {
                *x += 1.0 + i as f32;
            }
        });
        set_pool_threads(1);
        for (j, &x) in buf.iter().enumerate() {
            assert_eq!(x, 1.0 + (j / 10) as f32, "element {j}");
        }
    }

    #[test]
    fn pool_budget_floors_at_one() {
        set_pool_threads(0);
        assert_eq!(pool_threads(), 1);
    }
}
