//! Dense row-major f32 matrix used throughout the coordinator.
//!
//! f32 matches the XLA artifact dtype so the native Rust math path and the
//! PJRT path are directly comparable in tests.  The hot-loop operations
//! (rank-one update, scaled add, matvec) are written allocation-free and
//! route their inner loops through [`crate::linalg::kernels`] — the one
//! SIMD+threaded implementation whose results are bit-identical across
//! SIMD width and thread count (see the kernels module docs).

use super::kernels;
use crate::util::rng::Rng;

/// Rows per [`Mat::matvec`] output chunk (disjoint-output parallelism).
const MV_ROW_BLOCK: usize = 16;
/// Rows per [`Mat::tmatvec`] reduction block (fixed-size block partials
/// combined in block order — the partition depends only on the shape).
const TMV_ROW_BLOCK: usize = 64;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// i.i.d. N(0, sigma^2) entries.
    pub fn randn(rows: usize, cols: usize, sigma: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal_f32() * sigma).collect();
        Mat { rows, cols, data }
    }

    /// i.i.d. U[0, 1) entries.
    pub fn rand_uniform(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.next_f32()).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    pub fn scale(&mut self, s: f32) {
        self.data.iter_mut().for_each(|x| *x *= s);
    }

    /// self += s * other (elementwise fused axpy).
    pub fn axpy(&mut self, s: f32, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        kernels::axpy(&mut self.data, s, &other.data);
    }

    /// Frank-Wolfe iterate update:
    ///   X <- (1 - eta) * X + eta * scale * u v^T
    /// (the nuclear-ball LMO direction is U* = -theta u v^T, so callers pass
    /// scale = -theta).  Allocation-free rank-one GER fused with the scaling.
    pub fn fw_rank_one_update(&mut self, eta: f32, scale: f32, u: &[f32], v: &[f32]) {
        assert_eq!(u.len(), self.rows);
        assert_eq!(v.len(), self.cols);
        let keep = 1.0 - eta;
        let es = eta * scale;
        for (r, &ur) in u.iter().enumerate() {
            let row = self.row_mut(r);
            let c = es * ur;
            for (x, &vc) in row.iter_mut().zip(v.iter()) {
                *x = keep * *x + c * vc;
            }
        }
    }

    /// y = self @ x  (matvec).  Output rows are disjoint, so the
    /// row-chunked parallel path is bit-identical to the serial one for
    /// any thread count.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        if self.rows * self.cols >= kernels::PAR_MIN_WORK && kernels::pool_threads() > 1 {
            kernels::Pool::for_chunks_mut(y, MV_ROW_BLOCK, |b, ys| {
                let r0 = b * MV_ROW_BLOCK;
                for (i, yr) in ys.iter_mut().enumerate() {
                    *yr = dot(self.row(r0 + i), x);
                }
            });
            return;
        }
        for (r, yr) in y.iter_mut().enumerate() {
            *yr = dot(self.row(r), x);
        }
    }

    /// y = self^T @ x (transposed matvec, cache-friendly row sweep).
    /// Above [`kernels::PAR_MIN_WORK`] the rows are cut into fixed
    /// [`TMV_ROW_BLOCK`] blocks whose zeroed partials are combined in
    /// block order — the partition depends only on the shape, so
    /// `--threads N` is bit-identical to `--threads 1`.
    pub fn tmatvec(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        let nblocks = if self.rows * self.cols >= kernels::PAR_MIN_WORK {
            self.rows.div_ceil(TMV_ROW_BLOCK)
        } else {
            1
        };
        if nblocks <= 1 {
            y.iter_mut().for_each(|v| *v = 0.0);
            for (r, &xr) in x.iter().enumerate() {
                // NaN-safe skip: NaN != 0.0, so a poisoned x propagates
                if xr == 0.0 {
                    continue;
                }
                kernels::axpy(y, xr, self.row(r));
            }
            return;
        }
        let partials = kernels::Pool::map_chunks(nblocks, |b| {
            let lo = b * TMV_ROW_BLOCK;
            let hi = (lo + TMV_ROW_BLOCK).min(self.rows);
            let mut part = vec![0.0f32; self.cols];
            for r in lo..hi {
                let xr = x[r];
                if xr == 0.0 {
                    continue;
                }
                kernels::axpy(&mut part, xr, self.row(r));
            }
            part
        });
        y.iter_mut().for_each(|v| *v = 0.0);
        for part in partials {
            for (yc, p) in y.iter_mut().zip(part) {
                *yc += p;
            }
        }
    }

    /// C = self @ other (naive blocked matmul; substrate-scale sizes only).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut c = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self.at(i, k);
                if aik == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let crow = c.row_mut(i);
                for (cj, &bkj) in crow.iter_mut().zip(brow.iter()) {
                    *cj += aik * bkj;
                }
            }
        }
        c
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *t.at_mut(c, r) = self.at(r, c);
            }
        }
        t
    }

    /// <self, other> = trace(self^T other).
    pub fn inner(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        kernels::dot64(&self.data, &other.data)
    }

    pub fn frob_norm(&self) -> f64 {
        kernels::sumsq(&self.data).sqrt()
    }

    /// max |a_ij|, with the kernel layer's explicit NaN contract: any NaN
    /// entry returns NaN instead of being silently skipped by an
    /// `f32::max` fold (the int8 `GradCodec` scale scan relies on this to
    /// surface a poisoned gradient).
    pub fn max_abs(&self) -> f32 {
        kernels::max_abs(&self.data)
    }
}

/// dot product with f64 accumulation (keeps the native path close to XLA's
/// f32-with-wide-accumulator semantics on these sizes).  Dispatches to the
/// deterministic SIMD reduction in [`crate::linalg::kernels`].
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    kernels::dot64(a, b) as f32
}

/// ||v||_2 with f64 accumulation.
#[inline]
pub fn norm2(v: &[f32]) -> f64 {
    kernels::sumsq(v).sqrt()
}

/// v /= ||v||; returns the pre-normalization norm.
pub fn normalize(v: &mut [f32]) -> f64 {
    let n = norm2(v);
    if n > 0.0 {
        let inv = (1.0 / n) as f32;
        v.iter_mut().for_each(|x| *x *= inv);
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, v: &[f32]) -> Mat {
        Mat::from_vec(rows, cols, v.to_vec())
    }

    #[test]
    fn matvec_and_tmatvec_agree_with_matmul() {
        let a = mat(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let x = [1., 0., -1.];
        let mut y = [0.0; 2];
        a.matvec(&x, &mut y);
        assert_eq!(y, [-2.0, -2.0]);
        let u = [1., -1.];
        let mut z = [0.0; 3];
        a.tmatvec(&u, &mut z);
        assert_eq!(z, [-3.0, -3.0, -3.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = mat(2, 2, &[1., 2., 3., 4.]);
        let i = mat(2, 2, &[1., 0., 0., 1.]);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn fw_rank_one_update_matches_dense() {
        let mut rng = Rng::new(0);
        let mut x = Mat::randn(4, 3, 1.0, &mut rng);
        let x0 = x.clone();
        let u: Vec<f32> = (0..4).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..3).map(|_| rng.normal_f32()).collect();
        let (eta, theta) = (0.25f32, 2.0f32);
        x.fw_rank_one_update(eta, -theta, &u, &v);
        for r in 0..4 {
            for c in 0..3 {
                let expect = (1.0 - eta) * x0.at(r, c) - eta * theta * u[r] * v[c];
                assert!((x.at(r, c) - expect).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn inner_is_trace_inner_product() {
        let a = mat(2, 2, &[1., 2., 3., 4.]);
        let b = mat(2, 2, &[5., 6., 7., 8.]);
        // trace(A^T B) = 1*5+2*6+3*7+4*8 = 70
        assert_eq!(a.inner(&b), 70.0);
    }

    #[test]
    fn frob_norm_matches_definition() {
        let a = mat(2, 2, &[3., 0., 0., 4.]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn normalize_unitizes() {
        let mut v = vec![3.0, 4.0];
        let n = normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-12);
        assert!((norm2(&v) - 1.0).abs() < 1e-7);
    }

    #[test]
    fn dot_handles_remainders() {
        for n in 0..9 {
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let expect: f32 = a.iter().map(|x| x * x).sum();
            assert_eq!(dot(&a, &a), expect);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(9);
        let a = Mat::randn(5, 7, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }
}
