//! [`ErrorFeedback`] — the per-worker quantization-residual accumulator
//! for the compressed gradient uplink (`--uplink bf16|int8`).
//!
//! Plain quantization throws the rounding error away every round; error
//! feedback carries it forward instead: before quantizing, the worker
//! adds the residual of the previous round to the fresh gradient, and
//! after quantizing it stores the new residual (compensated minus
//! shipped).  The master then sees a sequence whose *running sum*
//! matches the uncompressed gradients up to one step of quantization
//! noise — the standard argument (Bellet et al., arXiv:1404.2644; also
//! the EF-SGD literature) for why compressed FW keeps its rate.
//!
//! The accumulator is a no-op when constructed inactive (the `f32`
//! codec), so call sites stay branch-free.

use crate::linalg::Mat;

/// Per-worker quantization-residual carrier.  One instance per worker
/// loop; never shared across workers (each compensates its own stream).
pub struct ErrorFeedback {
    active: bool,
    residual: Option<Mat>,
}

impl ErrorFeedback {
    /// `active = false` (the exact f32 codec) makes every method a no-op.
    pub fn new(active: bool) -> Self {
        ErrorFeedback { active, residual: None }
    }

    /// Add the carried residual into the gradient about to be quantized
    /// (no-op on the first round or when inactive).
    pub fn compensate(&self, g: &mut Mat) {
        if let (true, Some(r)) = (self.active, &self.residual) {
            g.axpy(1.0, r);
        }
    }

    /// Store the new residual: `compensated - shipped`, where `shipped`
    /// is the dequantized matrix the wire message actually carries.
    /// Call after quantizing; skip on poison rounds (a NaN residual
    /// would stick forever).
    pub fn absorb(&mut self, compensated: &Mat, shipped: &Mat) {
        if !self.active {
            return;
        }
        match &mut self.residual {
            Some(r) => r.clone_from(compensated),
            None => self.residual = Some(compensated.clone()),
        }
        if let Some(r) = &mut self.residual {
            r.axpy(-1.0, shipped);
        }
    }

    /// Frobenius norm of the carried residual (0 when empty/inactive) —
    /// the observable the boundedness tests pin.
    pub fn residual_norm(&self) -> f64 {
        match (&self.residual, self.active) {
            (Some(r), true) => r.frob_norm(),
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::grad_codec::{int8_dequant, int8_quant, int8_scale, GradCodec};
    use crate::util::rng::Rng;

    /// Quantize a matrix row-wise like the DistUp int8 wire variant.
    fn int8_roundtrip(g: &Mat) -> Mat {
        let mut out = g.clone();
        for r in 0..g.rows {
            let row = &g.data[r * g.cols..(r + 1) * g.cols];
            let s = int8_scale(row);
            for c in 0..g.cols {
                out.data[r * g.cols + c] = int8_dequant(int8_quant(row[c], s), s);
            }
        }
        out
    }

    #[test]
    fn inactive_feedback_is_a_no_op() {
        let mut ef = ErrorFeedback::new(false);
        let mut rng = Rng::new(60);
        let g0 = Mat::randn(6, 5, 1.0, &mut rng);
        let mut g = g0.clone();
        ef.compensate(&mut g);
        assert_eq!(g.data, g0.data);
        ef.absorb(&g, &int8_roundtrip(&g));
        assert_eq!(ef.residual_norm(), 0.0);
        ef.compensate(&mut g);
        assert_eq!(g.data, g0.data);
    }

    #[test]
    fn residual_stays_bounded_and_running_sums_track() {
        // Over T rounds of fresh gradients: with EF, the sum of shipped
        // (dequantized) matrices tracks the sum of true gradients to
        // within ONE round's quantization error; the residual never
        // grows (contraction property of scaled int8).
        assert!(GradCodec::Int8.is_lossy());
        let mut rng = Rng::new(61);
        let (rows, cols) = (8, 6);
        let mut ef = ErrorFeedback::new(true);
        let mut sum_true = Mat::zeros(rows, cols);
        let mut sum_shipped = Mat::zeros(rows, cols);
        for _ in 0..40 {
            let g_true = Mat::randn(rows, cols, 1.0, &mut rng);
            sum_true.axpy(1.0, &g_true);
            let mut g = g_true.clone();
            ef.compensate(&mut g);
            let shipped = int8_roundtrip(&g);
            ef.absorb(&g, &shipped);
            sum_shipped.axpy(1.0, &shipped);
            // residual bounded by one quantization step per entry:
            // |e| <= s/2 per entry, s <= max|g|/127
            assert!(
                ef.residual_norm() < 0.2,
                "residual blew up: {}",
                ef.residual_norm()
            );
        }
        let mut diff = sum_true.clone();
        diff.axpy(-1.0, &sum_shipped);
        // without EF the error would accumulate ~sqrt(T) * per-round
        // noise; with EF it is exactly the final residual
        assert!(
            (diff.frob_norm() - ef.residual_norm()).abs() < 1e-4,
            "sum gap {} != residual {}",
            diff.frob_norm(),
            ef.residual_norm()
        );
    }
}
