//! Singular Vector Averaging — the natural-but-WRONG baseline.
//!
//! The paper's introduction: "naively aggregating the low-rank updates
//! from the workers does not yield an algorithm that converges, as the
//! Singular Vector Averaging algorithm in the work of [Zheng et al.,
//! 2018]".  Each worker solves the LMO on its own minibatch gradient and
//! ships (u_w, v_w); the master sign-aligns and averages the vectors and
//! steps along the averaged rank-one direction.  Averaging singular
//! vectors is not the singular vector of the averaged gradient, so the
//! method stalls at a plateau — reproduced by the fig4 bench and pinned
//! by an integration test (SVA plateaus where SFW-asyn converges).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::algo::engine::StepEngine;
use crate::algo::schedule::{eta, BatchSchedule};
use crate::coordinator::eval::Evaluator;
use crate::coordinator::runner::RunResult;
use crate::linalg::{normalize, power_iteration_rand, Iterate, Mat, Repr};
use crate::metrics::{Counters, LossTrace};
use crate::objective::Objective;
use crate::util::rng::Rng;

pub struct SvaOptions {
    pub iterations: u64,
    pub workers: usize,
    pub batch: BatchSchedule,
    pub eval_every: u64,
    pub seed: u64,
    /// Master-side iterate representation (workers receive the dense
    /// broadcast either way — SVA is the dense-downlink baseline).
    pub repr: Repr,
    /// Dual-gap stopping tolerance (0 disables).  SVA's master never
    /// sees a gradient (workers ship singular vectors), so honoring
    /// `tol` pays a master-side probe gradient + 1-SVD per round,
    /// charged to the LMO counter.
    pub tol: f64,
}

enum Req {
    Compute { x: Arc<Mat>, m_share: usize },
    Stop,
}

struct Rep {
    u: Vec<f32>,
    v: Vec<f32>,
}

pub(crate) fn run_sva_impl<F>(
    obj: Arc<dyn Objective>,
    opts: &SvaOptions,
    mut make_engine: F,
) -> RunResult
where
    F: FnMut(usize) -> Box<dyn StepEngine>,
{
    let counters = Arc::new(Counters::new());
    let trace = Arc::new(LossTrace::new());
    let evaluator = Evaluator::new(obj.clone(), trace.clone());
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let rank1_bytes = (4 * (d1 + d2)) as u64;

    // lint: allow(bounded-channel-depth): depth <= W — one Rep per Req, and
    // each worker blocks on its Req queue after replying
    let (up_tx, up_rx): (Sender<Rep>, Receiver<Rep>) = channel();
    let mut down_txs = Vec::new();
    let mut handles = Vec::new();
    for w in 0..opts.workers {
        // lint: allow(bounded-channel-depth): depth <= 1 — the master issues
        // the next Req only after collecting this round's Reps
        let (tx, rx): (Sender<Req>, Receiver<Req>) = channel();
        down_txs.push(tx);
        let mut engine = make_engine(w);
        let up = up_tx.clone();
        let counters_w = counters.clone();
        let seed = opts.seed ^ 0xA11 ^ (w as u64) << 8;
        handles.push(std::thread::spawn(move || {
            let obj = engine.objective().clone();
            let mut rng = Rng::new(seed);
            let mut idx = Vec::new();
            while let Ok(Req::Compute { x, m_share }) = rx.recv() {
                rng.sample_indices(obj.n(), m_share, &mut idx);
                let out = engine.step(&x, &idx);
                counters_w.add_grad_evals(m_share as u64);
                counters_w.add_lmo();
                if up.send(Rep { u: out.u, v: out.v }).is_err() {
                    return;
                }
            }
        }));
    }
    drop(up_tx);

    let mut x = Iterate::init_rank_one(opts.repr, d1, d2, theta, &mut Rng::new(opts.seed));
    let mut probe_rng = Rng::new(opts.seed ^ 0x9E37_79B9);
    let mut probe_idx: Vec<usize> = Vec::new();
    let mut probe_g = Mat::zeros(d1, d2);
    evaluator.submit(trace.elapsed(), 0, f64::NAN, x.clone());
    // A dead worker ends the run early (with the partial trace) instead
    // of panicking the coordinator thread.
    'train: for k in 1..=opts.iterations {
        let m = opts.batch.m(k).max(opts.workers);
        let m_share = m / opts.workers;
        let xa = Arc::new(x.to_dense());
        for tx in &down_txs {
            counters.add_down((d1 * d2 * 4) as u64); // still broadcasts X
            let _ = tx.send(Req::Compute { x: xa.clone(), m_share });
        }
        // Dual-gap estimate for --tol, while the workers grind: probe
        // gradient at the broadcast X plus one 1-SVD (the workers only
        // ever ship singular vectors, so the master pays for its own).
        let gap = if opts.tol > 0.0 {
            probe_rng.sample_indices(obj.n(), m_share.max(1), &mut probe_idx);
            obj.grad_sum(&xa, &probe_idx, &mut probe_g);
            counters.add_grad_evals(probe_idx.len() as u64);
            let s = power_iteration_rand(&probe_g, &mut probe_rng, 50, 1e-6);
            counters.add_lmo();
            let gx: f64 = xa.inner(&probe_g);
            (gx + theta as f64 * s.sigma as f64) / probe_idx.len() as f64
        } else {
            f64::NAN
        };
        // average the singular vectors (sign-aligned to the first reply)
        let mut u_avg = vec![0.0f32; d1];
        let mut v_avg = vec![0.0f32; d2];
        let mut first: Option<Rep> = None;
        for _ in 0..opts.workers {
            let Ok(rep) = up_rx.recv() else {
                eprintln!("sva: worker died at iteration {k}; stopping early");
                break 'train;
            };
            counters.add_up(rank1_bytes); // rank-one upload (the SVA selling point)
            let sgn = match &first {
                None => 1.0f32,
                Some(f) => {
                    let du: f32 = f.u.iter().zip(&rep.u).map(|(a, b)| a * b).sum();
                    if du >= 0.0 {
                        1.0
                    } else {
                        -1.0
                    }
                }
            };
            for (a, b) in u_avg.iter_mut().zip(&rep.u) {
                *a += sgn * b;
            }
            for (a, b) in v_avg.iter_mut().zip(&rep.v) {
                *a += sgn * b;
            }
            if first.is_none() {
                first = Some(rep);
            }
        }
        normalize(&mut u_avg);
        normalize(&mut v_avg);
        counters.add_iteration();
        x.fw_rank_one_update(eta(k), -theta, &u_avg, &v_avg);
        let stop = opts.tol > 0.0 && gap.is_finite() && gap <= opts.tol;
        if stop || k % opts.eval_every == 0 || k == opts.iterations {
            evaluator.submit(trace.elapsed(), k, gap, x.clone());
        }
        if stop {
            break 'train;
        }
    }
    for tx in &down_txs {
        let _ = tx.send(Req::Stop);
    }
    for h in handles {
        let _ = h.join();
    }
    evaluator.finish();
    let (rank, peak_atoms) = (x.rank(), x.peak_atoms());
    RunResult { x: x.into_dense(), rank, peak_atoms, counters, trace, chaos: Default::default() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::NativeEngine;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::objective::MatrixSensing;

    #[test]
    fn sva_runs_and_counts_rank_one_uploads() {
        let mut rng = Rng::new(120);
        let p = MsParams { d1: 8, d2: 8, rank: 2, n: 1_000, noise_std: 0.05 };
        let obj: Arc<dyn Objective> =
            Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0));
        let opts = SvaOptions {
            iterations: 30,
            workers: 3,
            batch: BatchSchedule::Constant(96),
            eval_every: 10,
            seed: 121,
            repr: Repr::Dense,
            tol: 0.0,
        };
        let o2 = obj.clone();
        let r = run_sva_impl(obj, &opts, move |w| {
            Box::new(NativeEngine::new(o2.clone(), 40, 122 + w as u64))
        });
        let s = r.counters.snapshot();
        assert_eq!(s.iterations, 30);
        assert_eq!(s.bytes_up, 30 * 3 * 4 * (8 + 8));
        assert_eq!(s.lmo_calls, 30 * 3); // one per worker per round
    }
}
