//! SVRF-asyn (Algorithm 5): asynchronous, communication-efficient
//! Stochastic Variance-Reduced Frank-Wolfe.
//!
//! Outer epoch t: snapshot W_t, every worker recomputes ∇F(W_t) locally
//! (each worker holds all data — paper §2.2 — so the snapshot costs zero
//! communication beyond the rank-one catch-up slice).  Inner loop: the
//! Algorithm-3 async protocol with the variance-reduced gradient
//! ∇~ = (1/m) Σ_{i∈S} [∇f_i(X) − ∇f_i(W)] + ∇F(W), eta restarted per
//! epoch (eta_k = 2/(k+1) on the INNER index), N_t = 2^{t+3} − 2 inner
//! iterations (Thm 2).
//!
//! Epoch-boundary consistency: the master tracks each worker's last seen
//! epoch; an update computed against a previous epoch's W is dropped and
//! answered with `MasterMsg::UpdateW` (catch-up slice + boundary signal),
//! after which the worker re-snapshots.  Workers apply slices through the
//! idempotent `replay_after`, so overlapping catch-ups around boundaries
//! are harmless.

use std::sync::Arc;

use crate::algo::engine::StepEngine;
use crate::algo::schedule::{eta, select_eta, svrf_epoch_len, BatchSchedule, StepMethod};
use crate::comms::{GradCodec, MasterLink, WorkerLink};
use crate::coordinator::eval::Evaluator;
use crate::coordinator::messages::{MasterMsg, UpdateMsg};
use crate::coordinator::update_log::{replay_after, ApplyEntry, UpdateLog};
use crate::linalg::{Iterate, Mat, Repr};
use crate::metrics::{Counters, LossTrace};
use crate::objective::Objective;
use crate::util::rng::Rng;

pub struct SvrfAsynOptions {
    pub epochs: u32,
    pub tau: u64,
    pub batch: BatchSchedule,
    pub eval_every: u64,
    pub seed: u64,
    /// Iterate representation shared by master and workers.
    pub repr: Repr,
    /// Uplink codec for the rank-one `{u, v}` updates.
    pub uplink: GradCodec,
    /// Stop once an accepted update's VR dual-gap estimate falls to
    /// `tol` (0 disables) — same uplinked-gap convention as the plain
    /// SFW-asyn master.
    pub tol: f64,
    /// Step-size policy on the inner FW segment (non-vanilla runs the
    /// master-side probe-minibatch line search; away/pairwise are
    /// rejected at spec validation — no persistent active set here).
    pub step: StepMethod,
}

impl Default for SvrfAsynOptions {
    fn default() -> Self {
        SvrfAsynOptions {
            epochs: 4,
            tau: 8,
            batch: BatchSchedule::svrf_asyn(8, 4_096),
            eval_every: 10,
            seed: 0,
            repr: Repr::Dense,
            uplink: GradCodec::F32,
            tol: 0.0,
            step: StepMethod::Vanilla,
        }
    }
}

/// Master side of Algorithm 5.
pub(crate) fn run_svrf_master<L: MasterLink<UpdateMsg, MasterMsg> + ?Sized>(
    link: &mut L,
    obj: &Arc<dyn Objective>,
    opts: &SvrfAsynOptions,
    counters: &Counters,
    trace: &LossTrace,
    evaluator: &Evaluator,
) -> Iterate {
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let n = obj.n();
    let mut log = UpdateLog::new();
    let mut x = Iterate::init_rank_one(opts.repr, d1, d2, theta, &mut Rng::new(opts.seed));
    let mut probe_rng = Rng::new(opts.seed ^ 0x9E37_79B9);
    let mut probe_idx: Vec<usize> = Vec::new();
    let mut last_gap = f64::NAN;
    evaluator.submit(trace.elapsed(), 0, f64::NAN, x.clone());

    let w_count = link.workers();
    let mut last_epoch = vec![0u64; w_count];

    // Epoch 0 boundary: initial UpdateW broadcast (workers block on it).
    for w in 0..w_count {
        link.send_to(w, MasterMsg::UpdateW { t_m: 0, entries: Vec::new() });
    }

    let mut epoch: u64 = 0;
    let mut epoch_start: u64 = 0;
    'outer: while epoch < opts.epochs as u64 {
        let n_t = svrf_epoch_len(epoch as u32);
        while log.t_m() - epoch_start < n_t {
            let Some(upd) = link.recv() else { break 'outer };
            let w = upd.worker_id as usize;
            if w >= w_count {
                eprintln!("svrf-asyn: ignoring update with bad worker id {w}");
                continue;
            }
            let t_m = log.t_m();
            // The claimed sync point is gated and sliced on (it is the
            // worker's true iterate version); a FUTURE claim is frame
            // corruption — reject it but still reply (empty catch-up)
            // so the blocked sender's ping-pong loop stays live, and
            // let its next honest claim self-heal.  An in-range
            // corrupted claim at worst misjudges one gate decision and
            // yields a gapped slice, which the worker's gap-tolerant
            // `replay_after` refuses to apply.  (Same scheme as the
            // plain SFW-asyn master.)
            if upd.t_w > t_m {
                eprintln!(
                    "svrf-asyn: rejecting update claiming future iterate (t_w={} > t_m={t_m})",
                    upd.t_w
                );
                counters.add_dropped();
                link.send_to(w, MasterMsg::Updates { t_m, entries: Vec::new() });
                continue;
            }
            // corrupted-but-decodable update vectors: count, skip, resync
            if !crate::coordinator::sane_rank_one(&upd.u, &upd.v, d1, d2) {
                eprintln!("svrf-asyn: discarding corrupt update from worker {w}");
                counters.add_dropped();
                link.send_to(w, MasterMsg::Updates { t_m, entries: log.slice_from(upd.t_w) });
                continue;
            }
            // computed against an older epoch's W -> drop + boundary resync
            if last_epoch[w] < epoch || upd.t_w < epoch_start {
                counters.add_dropped();
                link.send_to(
                    w,
                    MasterMsg::UpdateW { t_m, entries: log.slice_from(upd.t_w) },
                );
                last_epoch[w] = epoch;
                continue;
            }
            // staleness gate within the epoch (Alg 5 line 8)
            if t_m - upd.t_w > opts.tau {
                counters.add_dropped();
                link.send_to(
                    w,
                    MasterMsg::Updates { t_m, entries: log.slice_from(upd.t_w) },
                );
                continue;
            }
            counters.note_accepted_delay(t_m - upd.t_w);
            let t_w = upd.t_w;
            let inner_k = (t_m - epoch_start) + 1;
            let step_eta = if opts.step == StepMethod::Vanilla {
                eta(inner_k)
            } else {
                // master-side stochastic line search (see run_master):
                // probe minibatch, phi in batch-SUM units, slope seeded
                // from the uplinked mean VR gap
                let m = (upd.m as usize).clamp(1, n);
                probe_rng.sample_indices(n, m, &mut probe_idx);
                let loss0 = obj.loss_batch_it(&x, &probe_idx);
                let slope0 = -(upd.gap * m as f64);
                select_eta(opts.step, inner_k, loss0, slope0, 1.0, &mut |e| {
                    let mut trial = x.clone();
                    trial.fw_rank_one_update(e, -theta, &upd.u, &upd.v);
                    obj.loss_batch_it(&trial, &probe_idx)
                })
            };
            let gap = upd.gap;
            let e = log.append_custom(upd.u, upd.v, step_eta, -theta);
            x.apply_entry(e);
            counters.add_iteration();
            last_gap = gap;
            let t_m = log.t_m();
            link.send_to(
                w,
                MasterMsg::Updates { t_m, entries: log.slice_from(t_w) },
            );
            let stop = opts.tol > 0.0 && gap.is_finite() && gap <= opts.tol;
            if stop || t_m % opts.eval_every == 0 {
                evaluator.submit(trace.elapsed(), t_m, gap, x.clone());
            }
            if stop {
                break 'outer;
            }
        }
        // epoch complete: W_{t+1} = X_{N_t}; boundary is announced lazily
        // through per-worker UpdateW resyncs above.
        epoch += 1;
        epoch_start = log.t_m();
        evaluator.submit(trace.elapsed(), epoch_start, last_gap, x.clone());
    }
    for w in 0..w_count {
        link.send_to(w, MasterMsg::Stop);
    }
    x
}

/// Worker side of Algorithm 5.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_svrf_worker<L: WorkerLink<UpdateMsg, MasterMsg> + ?Sized, E: StepEngine + ?Sized>(
    link: &mut L,
    engine: &mut E,
    worker_id: u32,
    batch: &BatchSchedule,
    seed: u64,
    counters: &Counters,
    repr: Repr,
    uplink: GradCodec,
) {
    let obj = engine.objective().clone();
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let n = obj.n();
    let mut x = Iterate::init_rank_one(repr, d1, d2, theta, &mut Rng::new(seed));
    let mut t_w = 0u64;
    #[allow(unused_assignments)]
    let mut epoch_start = 0u64;
    let mut rng = Rng::new(seed ^ 0x5F4F).fork(worker_id as u64 + 1);
    let mut idx: Vec<usize> = Vec::new();
    let mut w_snap = x.clone();
    let mut full_g = Mat::zeros(d1, d2);
    let mut gx = Mat::zeros(d1, d2);
    let mut gw = Mat::zeros(d1, d2);
    let all: Vec<usize> = (0..n).collect();

    // Block on the initial epoch-0 boundary.
    match link.recv() {
        Some(MasterMsg::UpdateW { entries, .. }) => {
            t_w = replay_after(&mut x, &entries, t_w);
            epoch_start = t_w;
        }
        _ => return,
    }
    // ∇F(W_0)
    let _ = engine.grad_sum_it(&x, &all, &mut full_g);
    full_g.scale(1.0 / n as f32);
    counters.add_grad_evals(n as u64);
    w_snap.clone_from(&x);

    loop {
        let inner_k = (t_w - epoch_start).max(0) + 1;
        let m = batch.m(inner_k);
        rng.sample_indices(n, m, &mut idx);
        // VR gradient: (grad(X) - grad(W))/m + ∇F(W)
        let loss_sum = engine.grad_sum_it(&x, &idx, &mut gx);
        let _ = engine.grad_sum_it(&w_snap, &idx, &mut gw);
        counters.add_grad_evals(2 * m as u64);
        gx.axpy(-1.0, &gw);
        gx.scale(1.0 / m as f32);
        gx.axpy(1.0, &full_g);
        let s = engine.lmo(&gx);
        counters.add_lmo();
        // gx is a MEAN gradient, so the uplinked gap estimate needs no /m
        let gap = x.inner_flat(&gx.data) + theta as f64 * s.sigma as f64;
        link.send(UpdateMsg::quantized(
            uplink,
            worker_id,
            t_w,
            s.u,
            s.v,
            s.sigma,
            loss_sum,
            m as u32,
            gap,
        ));
        match link.recv() {
            Some(MasterMsg::Updates { entries, .. }) => {
                // gap-tolerant: t_w advances only as far as entries
                // actually applied (see the plain worker loop)
                t_w = replay_after(&mut x, &entries, t_w);
            }
            Some(MasterMsg::UpdateW { entries, .. }) => {
                t_w = replay_after(&mut x, &entries, t_w);
                epoch_start = t_w;
                w_snap.clone_from(&x);
                let _ = engine.grad_sum_it(&w_snap, &all, &mut full_g);
                full_g.scale(1.0 / n as f32);
                counters.add_grad_evals(n as u64);
            }
            Some(MasterMsg::Stop) | None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::NativeEngine;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::nuclear_norm;
    use crate::objective::MatrixSensing;
    use crate::session::harness;

    #[test]
    fn svrf_asyn_converges() {
        let mut rng = Rng::new(140);
        let p = MsParams { d1: 10, d2: 10, rank: 2, n: 2_000, noise_std: 0.05 };
        let obj: Arc<dyn Objective> =
            Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0));
        let opts = SvrfAsynOptions {
            epochs: 3,
            tau: 8,
            batch: BatchSchedule::svrf_asyn(4, 512),
            eval_every: 10,
            seed: 141,
            repr: Repr::Dense,
            uplink: GradCodec::F32,
            ..SvrfAsynOptions::default()
        };
        let o2 = obj.clone();
        let r = harness::run_svrf_asyn(obj, &opts, harness::TransportOpts::local(3), move |w| {
            Box::new(NativeEngine::new(o2.clone(), 50, 142 + w as u64))
        });
        let pts = r.trace.points();
        assert!(
            pts.last().unwrap().loss < 0.4 * pts.first().unwrap().loss,
            "{} -> {}",
            pts.first().unwrap().loss,
            pts.last().unwrap().loss
        );
        assert!(nuclear_norm(&r.x) <= 1.0 + 1e-3);
        // total inner iterations = 6 + 14 + 30
        assert_eq!(r.counters.snapshot().iterations, 50);
    }
}
