//! The distributed coordinator — the paper's system contribution (L3).
//!
//! * [`master`] / [`worker`] — SFW-asyn (Algorithm 3): the asynchronous,
//!   O(D1+D2)-per-message protocol.
//! * [`svrf_asyn`] — SVRF-asyn (Algorithm 5).
//! * [`sync`] — SFW-dist (Algorithm 1), the synchronous baseline — now a
//!   framed protocol over the same [`crate::comms`] links as the
//!   asynchronous solvers, so it runs over TCP too.
//! * [`sva`] — Singular Vector Averaging, the divergent naive baseline.
//! * [`dfw_power`] — Zheng et al. 2018 distributed-power-iteration DFW,
//!   the O(T^2 (D1+D2)) communication prior art.
//! * [`update_log`] / [`messages`] — the rank-one log and the typed wire
//!   messages of every protocol (with their `Wire` codecs).
//! * [`eval`] — off-thread objective evaluation for loss traces.
//!
//! **Entry points:** training runs start from
//! [`crate::session::TrainSpec`], which owns the transport/engine/metrics
//! wiring for every algorithm here.  (The 0.2 deprecated `run_*` shims
//! in [`runner`], [`svrf_asyn`], [`sync`], [`sva`] and [`dfw_power`]
//! have been removed; this module now exports only the protocol option
//! types and the raw [`RunResult`].)

pub mod dfw_power;
pub mod eval;
pub mod master;
pub mod messages;
pub mod runner;
pub mod sva;
pub mod svrf_asyn;
pub mod sync;
pub mod update_log;
pub mod worker;

pub use messages::{DistDown, DistUp, LogEntry, MasterMsg, UpdateMsg};
pub use runner::{AsynOptions, RunResult};
pub use svrf_asyn::SvrfAsynOptions;
pub use sync::DistOptions;
pub use update_log::{replay, replay_after, ApplyEntry, UpdateLog};
pub use worker::Straggler;

/// Semantic sanity gate for a received rank-one update `{u, v}`: the
/// protocol's vectors are unit singular vectors from the LMO, so
/// anything with the wrong dimensions, non-finite entries, or a norm far
/// from 1 is a corrupted frame that still decoded — folding it into the
/// log would blow the iterate out of the nuclear ball (or poison it with
/// NaN).  The masters count such updates as dropped and resynchronize
/// the sender instead.
pub(crate) fn sane_rank_one(u: &[f32], v: &[f32], d1: usize, d2: usize) -> bool {
    if u.len() != d1 || v.len() != d2 {
        return false;
    }
    let norm_ok = |x: &[f32]| {
        let mut s = 0.0f64;
        for &a in x {
            if !a.is_finite() {
                return false;
            }
            s += a as f64 * a as f64;
        }
        let n = s.sqrt();
        (0.5..=2.0).contains(&n)
    };
    norm_ok(u) && norm_ok(v)
}
