//! The distributed coordinator — the paper's system contribution (L3).
//!
//! * [`master`] / [`worker`] — SFW-asyn (Algorithm 3): the asynchronous,
//!   O(D1+D2)-per-message protocol.
//! * [`svrf_asyn`] — SVRF-asyn (Algorithm 5).
//! * [`sync`] — SFW-dist (Algorithm 1), the synchronous baseline — now a
//!   framed protocol over the same [`crate::comms`] links as the
//!   asynchronous solvers, so it runs over TCP too.
//! * [`sva`] — Singular Vector Averaging, the divergent naive baseline.
//! * [`dfw_power`] — Zheng et al. 2018 distributed-power-iteration DFW,
//!   the O(T^2 (D1+D2)) communication prior art.
//! * [`update_log`] / [`messages`] — the rank-one log and the typed wire
//!   messages of every protocol (with their `Wire` codecs).
//! * [`eval`] — off-thread objective evaluation for loss traces.
//!
//! **Entry points:** training runs start from
//! [`crate::session::TrainSpec`], which owns the transport/engine/metrics
//! wiring for every algorithm here.  (The 0.2 deprecated `run_*` shims
//! in [`runner`], [`svrf_asyn`], [`sync`], [`sva`] and [`dfw_power`]
//! have been removed; this module now exports only the protocol option
//! types and the raw [`RunResult`].)

pub mod dfw_power;
pub mod eval;
pub mod master;
pub mod messages;
pub mod runner;
pub mod sva;
pub mod svrf_asyn;
pub mod sync;
pub mod update_log;
pub mod worker;

pub use messages::{DistDown, DistUp, LogEntry, MasterMsg, UpdateMsg};
pub use runner::{AsynOptions, RunResult};
pub use svrf_asyn::SvrfAsynOptions;
pub use sync::DistOptions;
pub use update_log::{replay, replay_after, UpdateLog};
pub use worker::Straggler;
