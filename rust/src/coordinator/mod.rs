//! The distributed coordinator — the paper's system contribution (L3).
//!
//! * [`master`] / [`worker`] — SFW-asyn (Algorithm 3): the asynchronous,
//!   O(D1+D2)-per-message protocol.
//! * [`svrf_asyn`] — SVRF-asyn (Algorithm 5).
//! * [`sync`] — SFW-dist (Algorithm 1), the synchronous baseline.
//! * [`sva`] — Singular Vector Averaging, the divergent naive baseline.
//! * [`dfw_power`] — Zheng et al. 2018 distributed-power-iteration DFW,
//!   the O(T^2 (D1+D2)) communication prior art.
//! * [`update_log`] / [`messages`] — the rank-one log and wire types.
//! * [`eval`] — off-thread objective evaluation for loss traces.
//!
//! **Entry points moved:** training runs start from
//! [`crate::session::TrainSpec`], which owns the transport/engine/metrics
//! wiring for every algorithm here.  The old `run_*` functions in
//! [`runner`], [`svrf_asyn`], [`sync`], [`sva`] and [`dfw_power`] remain
//! as thin deprecated shims for one release.

pub mod dfw_power;
pub mod eval;
pub mod master;
pub mod messages;
pub mod runner;
pub mod sva;
pub mod svrf_asyn;
pub mod sync;
pub mod update_log;
pub mod worker;

pub use messages::{LogEntry, MasterMsg, UpdateMsg};
#[allow(deprecated)]
pub use runner::{run_asyn_local, run_asyn_tcp};
pub use runner::{AsynOptions, RunResult};
#[allow(deprecated)]
pub use svrf_asyn::run_svrf_asyn_local;
pub use svrf_asyn::SvrfAsynOptions;
#[allow(deprecated)]
pub use sync::run_dist;
pub use sync::DistOptions;
pub use update_log::{replay, replay_after, UpdateLog};
pub use worker::Straggler;
