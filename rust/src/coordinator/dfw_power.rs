//! DFW-power: the distributed Frank-Wolfe of Zheng, Bellet & Gallinari
//! (2018) — the prior state of the art the paper compares its
//! communication bill against.
//!
//! Full-batch FW where the LMO itself is distributed: data is sharded
//! across workers; at FW iteration t each worker computes its local exact
//! gradient shard G_w once, then the master coordinates O(t) *distributed
//! power-iteration rounds*: broadcast v (D2 floats/worker), gather G_w v
//! (D1 floats/worker), broadcast u, gather G_w^T u.  Per-iteration comm is
//! O(t (D1 + D2)) per worker, so a T-iteration run costs O(T^2 (D1 + D2))
//! — versus SFW-asyn's O(T (D1 + D2)) (paper §1, Related Work).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::algo::schedule::eta;
use crate::coordinator::eval::Evaluator;
use crate::coordinator::runner::RunResult;
use crate::linalg::{normalize, power_iteration_rand, Iterate, Mat, Repr};
use crate::metrics::{Counters, LossTrace};
use crate::objective::Objective;
use crate::util::rng::Rng;

pub struct DfwOptions {
    pub iterations: u64,
    pub workers: usize,
    /// Power-iteration rounds at FW iteration t: `rounds_base + rounds_slope * t`
    /// (Zheng et al. use O(t); default 1 + t/2).
    pub rounds_base: u64,
    pub rounds_slope: f64,
    pub eval_every: u64,
    pub seed: u64,
    /// Master-side iterate representation (workers shard dense
    /// gradients either way — DFW's LMO is what is distributed).
    pub repr: Repr,
    /// Dual-gap stopping tolerance (0 disables).  The full gradient
    /// lives sharded across the workers, so honoring `tol` pays a
    /// master-side probe gradient (capped at 1024 samples) + 1-SVD per
    /// round, charged to the gradient/LMO counters.
    pub tol: f64,
}

impl Default for DfwOptions {
    fn default() -> Self {
        DfwOptions {
            iterations: 50,
            workers: 4,
            rounds_base: 1,
            rounds_slope: 0.5,
            eval_every: 5,
            seed: 0,
            repr: Repr::Dense,
            tol: 0.0,
        }
    }
}

enum Req {
    /// Recompute the local gradient shard at the (replayed) iterate.
    NewGrad { x: Arc<Mat> },
    /// One power half-step: u_partial = G_w v.
    Mv { v: Arc<Vec<f32>> },
    /// Other half: v_partial = G_w^T u.
    Mtv { u: Arc<Vec<f32>> },
    Stop,
}

enum Rep {
    Grad,
    Mv(Vec<f32>),
    Mtv(Vec<f32>),
}

pub(crate) fn run_dfw_power_impl(obj: Arc<dyn Objective>, opts: &DfwOptions) -> RunResult {
    let counters = Arc::new(Counters::new());
    let trace = Arc::new(LossTrace::new());
    let evaluator = Evaluator::new(obj.clone(), trace.clone());
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let n = obj.n();
    let w_count = opts.workers;

    // lint: allow(bounded-channel-depth): depth <= W — one Rep per Req, and
    // each worker blocks on its Req queue after replying
    let (up_tx, up_rx): (Sender<(usize, Rep)>, Receiver<(usize, Rep)>) = channel();
    let mut down_txs = Vec::new();
    let mut handles = Vec::new();
    for w in 0..w_count {
        // lint: allow(bounded-channel-depth): depth <= 1 — the power-iteration
        // master issues the next Req to a worker only after its reply
        let (tx, rx): (Sender<Req>, Receiver<Req>) = channel();
        down_txs.push(tx);
        let up = up_tx.clone();
        let obj = obj.clone();
        let counters_w = counters.clone();
        // static shard: indices w, w+W, w+2W, ...
        let shard: Vec<usize> = (w..n).step_by(w_count).collect();
        handles.push(std::thread::spawn(move || {
            let (d1, d2) = obj.dims();
            let mut g = Mat::zeros(d1, d2);
            let mut buf1 = vec![0.0f32; d1];
            let mut buf2 = vec![0.0f32; d2];
            loop {
                match rx.recv() {
                    Ok(Req::NewGrad { x }) => {
                        let _ = obj.grad_sum(&x, &shard, &mut g);
                        counters_w.add_grad_evals(shard.len() as u64);
                        if up.send((w, Rep::Grad)).is_err() {
                            return;
                        }
                    }
                    Ok(Req::Mv { v }) => {
                        g.matvec(&v, &mut buf1);
                        if up.send((w, Rep::Mv(buf1.clone()))).is_err() {
                            return;
                        }
                    }
                    Ok(Req::Mtv { u }) => {
                        g.tmatvec(&u, &mut buf2);
                        if up.send((w, Rep::Mtv(buf2.clone()))).is_err() {
                            return;
                        }
                    }
                    Ok(Req::Stop) | Err(_) => return,
                }
            }
        }));
    }
    drop(up_tx);

    let mut x = Iterate::init_rank_one(opts.repr, d1, d2, theta, &mut Rng::new(opts.seed));
    evaluator.submit(trace.elapsed(), 0, f64::NAN, x.clone());
    let mut rng = Rng::new(opts.seed ^ 0xDF);
    let mut probe_rng = Rng::new(opts.seed ^ 0x9E37_79B9);
    let mut probe_idx: Vec<usize> = Vec::new();
    let mut probe_g = Mat::zeros(d1, d2);
    // A dead worker or an out-of-phase reply ends the run early (with the
    // partial trace) instead of panicking the coordinator thread.
    'train: for t in 1..=opts.iterations {
        // 1. fresh local gradients at X_t (X broadcast: dense down)
        let xa = Arc::new(x.to_dense());
        for tx in &down_txs {
            counters.add_down((d1 * d2 * 4) as u64);
            let _ = tx.send(Req::NewGrad { x: xa.clone() });
        }
        // Dual-gap estimate for --tol, while the workers re-grad their
        // shards: the sharded full gradient never reaches the master, so
        // it pays its own probe gradient + 1-SVD (same scheme as SVA).
        let gap = if opts.tol > 0.0 {
            let pm = n.min(1024);
            probe_rng.sample_indices(n, pm, &mut probe_idx);
            obj.grad_sum(&xa, &probe_idx, &mut probe_g);
            counters.add_grad_evals(pm as u64);
            let s = power_iteration_rand(&probe_g, &mut probe_rng, 50, 1e-6);
            counters.add_lmo();
            let gx: f64 = xa.inner(&probe_g);
            (gx + theta as f64 * s.sigma as f64) / pm as f64
        } else {
            f64::NAN
        };
        for _ in 0..w_count {
            if up_rx.recv().is_err() {
                eprintln!("dfw-power: worker died at iteration {t}; stopping early");
                break 'train;
            }
        }
        // 2. O(t) distributed power-iteration rounds
        let rounds = opts.rounds_base + (opts.rounds_slope * t as f64).floor() as u64;
        let mut v = rng.unit_vector(d2);
        let mut u = vec![0.0f32; d1];
        for _ in 0..rounds {
            // u = sum_w G_w v
            let va = Arc::new(v.clone());
            for tx in &down_txs {
                counters.add_down((d2 * 4) as u64);
                let _ = tx.send(Req::Mv { v: va.clone() });
            }
            u.iter_mut().for_each(|z| *z = 0.0);
            for _ in 0..w_count {
                match up_rx.recv() {
                    Ok((_, Rep::Mv(part))) => {
                        counters.add_up((d1 * 4) as u64);
                        for (a, b) in u.iter_mut().zip(&part) {
                            *a += b;
                        }
                    }
                    Ok(_) => {
                        eprintln!("dfw-power: protocol violation in Mv round at t={t}; stopping");
                        break 'train;
                    }
                    Err(_) => {
                        eprintln!("dfw-power: worker died at iteration {t}; stopping early");
                        break 'train;
                    }
                }
            }
            normalize(&mut u);
            // v = sum_w G_w^T u
            let ua = Arc::new(u.clone());
            for tx in &down_txs {
                counters.add_down((d1 * 4) as u64);
                let _ = tx.send(Req::Mtv { u: ua.clone() });
            }
            v.iter_mut().for_each(|z| *z = 0.0);
            for _ in 0..w_count {
                match up_rx.recv() {
                    Ok((_, Rep::Mtv(part))) => {
                        counters.add_up((d2 * 4) as u64);
                        for (a, b) in v.iter_mut().zip(&part) {
                            *a += b;
                        }
                    }
                    Ok(_) => {
                        eprintln!("dfw-power: protocol violation in Mtv round at t={t}; stopping");
                        break 'train;
                    }
                    Err(_) => {
                        eprintln!("dfw-power: worker died at iteration {t}; stopping early");
                        break 'train;
                    }
                }
            }
            normalize(&mut v);
        }
        counters.add_lmo();
        counters.add_iteration();
        x.fw_rank_one_update(eta(t), -theta, &u, &v);
        let stop = opts.tol > 0.0 && gap.is_finite() && gap <= opts.tol;
        if stop || t % opts.eval_every == 0 || t == opts.iterations {
            evaluator.submit(trace.elapsed(), t, gap, x.clone());
        }
        if stop {
            break 'train;
        }
    }
    for tx in &down_txs {
        let _ = tx.send(Req::Stop);
    }
    for h in handles {
        let _ = h.join();
    }
    evaluator.finish();
    let (rank, peak_atoms) = (x.rank(), x.peak_atoms());
    RunResult { x: x.into_dense(), rank, peak_atoms, counters, trace, chaos: Default::default() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::nuclear_norm;
    use crate::objective::MatrixSensing;

    #[test]
    fn dfw_power_converges_with_quadratic_comm() {
        let mut rng = Rng::new(130);
        let p = MsParams { d1: 8, d2: 8, rank: 2, n: 1_000, noise_std: 0.05 };
        let obj: Arc<dyn Objective> =
            Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0));
        let opts = DfwOptions {
            iterations: 40,
            workers: 3,
            rounds_base: 2,
            rounds_slope: 0.5,
            eval_every: 10,
            seed: 131,
            repr: Repr::Dense,
            tol: 0.0,
        };
        let r = run_dfw_power_impl(obj, &opts);
        let pts = r.trace.points();
        assert!(
            pts.last().unwrap().loss < 0.4 * pts.first().unwrap().loss,
            "{} -> {}",
            pts.first().unwrap().loss,
            pts.last().unwrap().loss
        );
        assert!(nuclear_norm(&r.x) <= 1.0 + 1e-3);
        // power-round comm grows with t: total up-bytes exceed T * one-round
        let s = r.counters.snapshot();
        let one_round_up = 3 * 4 * (8 + 8) as u64;
        assert!(s.bytes_up > 40 * one_round_up, "comm should be superlinear in T");
    }
}
