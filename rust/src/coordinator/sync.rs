//! SFW-dist (Algorithm 1): the synchronous distributed baseline.
//!
//! Per iteration the master broadcasts the dense iterate X — O(D1*D2)
//! bytes to each of W workers — each worker returns its dense partial
//! gradient — O(D1*D2) bytes again — and the master aggregates, solves the
//! LMO itself, and updates.  The barrier makes every iteration as slow as
//! the slowest worker; the byte counters make the O(D1*D2) vs O(D1+D2)
//! contrast measurable (comm_cost bench).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::algo::engine::StepEngine;
use crate::algo::schedule::{eta, BatchSchedule};
use crate::algo::sfw::init_rank_one;
use crate::coordinator::eval::Evaluator;
use crate::coordinator::runner::RunResult;
use crate::coordinator::worker::Straggler;
use crate::linalg::Mat;
use crate::metrics::{Counters, LossTrace};
use crate::objective::Objective;
use crate::util::rng::Rng;

pub struct DistOptions {
    pub iterations: u64,
    pub workers: usize,
    pub batch: BatchSchedule,
    pub eval_every: u64,
    pub seed: u64,
    pub straggler: Option<Straggler>,
}

enum RoundMsg {
    /// Broadcast of the dense iterate + per-worker share m/W.
    Compute { x: Arc<Mat>, m_share: usize },
    Stop,
}

struct RoundReply {
    grad_sum: Mat,
    /// Minibatch loss telemetry (kept on the wire for parity with Alg 3;
    /// the master reports full-objective loss via the evaluator instead).
    #[allow(dead_code)]
    loss_sum: f64,
}

/// Run synchronous SFW-dist; the master thread is the caller.
/// `make_engine(w)` supplies each worker's gradient engine; worker 0's
/// engine type is also instantiated at the master (`make_engine(usize::MAX)`)
/// for the LMO.
pub(crate) fn run_dist_impl<F>(
    obj: Arc<dyn Objective>,
    opts: &DistOptions,
    mut make_engine: F,
) -> RunResult
where
    F: FnMut(usize) -> Box<dyn StepEngine>,
{
    let counters = Arc::new(Counters::new());
    let trace = Arc::new(LossTrace::new());
    let evaluator = Evaluator::new(obj.clone(), trace.clone());
    let (d1, d2) = obj.dims();
    let k_bytes = (d1 * d2 * 4) as u64;
    let theta = obj.theta();
    let n = obj.n();

    // spawn workers
    let (up_tx, up_rx): (Sender<RoundReply>, Receiver<RoundReply>) = channel();
    let mut down_txs = Vec::new();
    let mut handles = Vec::new();
    for w in 0..opts.workers {
        let (tx, rx): (Sender<RoundMsg>, Receiver<RoundMsg>) = channel();
        down_txs.push(tx);
        let mut engine = make_engine(w);
        let up = up_tx.clone();
        let counters_w = counters.clone();
        let straggler = opts.straggler;
        let seed = opts.seed ^ 0x5BC ^ (w as u64) << 8;
        handles.push(std::thread::spawn(move || {
            let obj = engine.objective().clone();
            let (d1, d2) = obj.dims();
            let mut rng = Rng::new(seed);
            let mut idx = Vec::new();
            let mut g = Mat::zeros(d1, d2);
            while let Ok(RoundMsg::Compute { x, m_share }) = rx.recv() {
                rng.sample_indices(obj.n(), m_share, &mut idx);
                let loss_sum = engine.grad_sum(&x, &idx, &mut g);
                counters_w.add_grad_evals(m_share as u64);
                if let Some(s) = &straggler {
                    s.sleep(&mut rng, m_share as u64);
                }
                if up.send(RoundReply { grad_sum: g.clone(), loss_sum }).is_err() {
                    return;
                }
            }
        }));
    }
    drop(up_tx);

    let mut master_engine = make_engine(usize::MAX);
    let mut x = init_rank_one(d1, d2, theta, &mut Rng::new(opts.seed));
    evaluator.submit(trace.elapsed(), 0, x.clone());
    let mut grad = Mat::zeros(d1, d2);
    for k in 1..=opts.iterations {
        let m = opts.batch.m(k).max(opts.workers);
        let m_share = m / opts.workers;
        let xa = Arc::new(x.clone());
        for tx in &down_txs {
            // dense parameter broadcast: O(D1 D2) down per worker
            counters.add_down(k_bytes);
            let _ = tx.send(RoundMsg::Compute { x: xa.clone(), m_share });
        }
        // barrier: wait for ALL workers (the straggler pays here)
        grad.fill(0.0);
        for _ in 0..opts.workers {
            let reply = up_rx.recv().expect("worker died");
            counters.add_up(k_bytes); // dense gradient upload
            grad.axpy(1.0, &reply.grad_sum);
        }
        let s = master_engine.lmo(&grad);
        counters.add_lmo();
        counters.add_iteration();
        x.fw_rank_one_update(eta(k), -theta, &s.u, &s.v);
        let _ = n;
        if k % opts.eval_every == 0 || k == opts.iterations {
            evaluator.submit(trace.elapsed(), k, x.clone());
        }
    }
    for tx in &down_txs {
        let _ = tx.send(RoundMsg::Stop);
    }
    for h in handles {
        let _ = h.join();
    }
    evaluator.finish();
    RunResult { x, counters, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::NativeEngine;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::nuclear_norm;
    use crate::objective::MatrixSensing;

    #[test]
    fn dist_converges_and_counts_dense_traffic() {
        let mut rng = Rng::new(110);
        let p = MsParams { d1: 10, d2: 10, rank: 2, n: 3_000, noise_std: 0.05 };
        let obj: Arc<dyn Objective> =
            Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0));
        let opts = DistOptions {
            iterations: 100,
            workers: 4,
            batch: BatchSchedule::sfw(2.0, 1_024),
            eval_every: 20,
            seed: 111,
            straggler: None,
        };
        let o2 = obj.clone();
        let r = run_dist_impl(obj, &opts, move |w| {
            Box::new(NativeEngine::new(o2.clone(), 60, 112u64.wrapping_add(w as u64)))
        });
        let pts = r.trace.points();
        assert!(pts.last().unwrap().loss < 0.4 * pts.first().unwrap().loss);
        assert!(nuclear_norm(&r.x) <= 1.0 + 1e-3);
        let s = r.counters.snapshot();
        assert_eq!(s.iterations, 100);
        assert_eq!(s.lmo_calls, 100); // master-side only
        // dense O(D1*D2) traffic each way, every round, every worker
        assert_eq!(s.bytes_down, 100 * 4 * (10 * 10 * 4));
        assert_eq!(s.bytes_up, 100 * 4 * (10 * 10 * 4));
    }
}
