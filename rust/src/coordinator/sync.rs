//! SFW-dist (Algorithm 1): the synchronous distributed baseline, now a
//! framed `(DistUp, DistDown)` protocol over the generic comms links.
//!
//! Per iteration the master broadcasts the iterate — in **dense** mode
//! the full X, O(D1*D2) bytes to each of W workers; in **factored**
//! mode only the rank-one atoms appended since the previous round
//! ([`DistDown::ComputeFactored`]), O(D1+D2) bytes per round, with
//! every worker reconstructing X locally from the shared-seed X_0 —
//! each worker returns its dense partial gradient, and the master
//! aggregates, solves the LMO itself, and updates.  The barrier makes
//! every iteration as slow as the slowest worker; the links' byte
//! accounting makes the O(D1*D2) vs O(D1+D2) downlink contrast
//! measurable (comm_cost bench, smoke-sweep artifact), and the same
//! master/worker loops run over in-process channels or real TCP
//! ([`crate::session::harness`] picks the transport).
//!
//! The factored downlink relies on the links' reliable in-order
//! delivery (true for both transports; the chaos layer injects only
//! delays on the master->worker direction) — a worker that misses a
//! delta could not resynchronize, unlike the stateless dense broadcast.
//! Replay is idempotent and gap-tolerant regardless (`replay_after`),
//! and a worker that does detect a rejected or gapped slice marks
//! itself desynced and thereafter answers with non-finite gradients, so
//! the master's corrupt-gradient gate drops (and counts) its
//! contributions instead of silently folding stale-X gradients into
//! every remaining reduction.
//!
//! Replies are reduced in worker-rank order (not arrival order), so the
//! float summation — and therefore the whole run — is bit-identical
//! across transports for a fixed seed.

use std::sync::Arc;

use crate::algo::engine::StepEngine;
use crate::algo::schedule::{eta, select_eta, BatchSchedule, StepMethod};
use crate::comms::{GradCodec, MasterLink, WorkerLink};
use crate::coordinator::eval::Evaluator;
use crate::coordinator::messages::{DistDown, DistUp, LogEntry};
use crate::coordinator::update_log::{replay_after, ApplyEntry};
use crate::coordinator::worker::Straggler;
use crate::linalg::{ErrorFeedback, Iterate, Mat, Repr};
use crate::metrics::{Counters, LossTrace};
use crate::objective::Objective;
use crate::util::rng::Rng;

pub struct DistOptions {
    pub iterations: u64,
    pub batch: BatchSchedule,
    pub eval_every: u64,
    pub seed: u64,
    pub straggler: Option<Straggler>,
    /// Iterate representation — also selects the downlink wire variant
    /// (dense X broadcast vs atoms-since-last-round).
    pub repr: Repr,
    /// Uplink gradient codec — selects the `DistUp` wire variant; lossy
    /// codecs get per-worker error feedback on the gradient stream.
    pub uplink: GradCodec,
    /// Stop once the master's own dual-gap estimate — computed from the
    /// aggregated round gradient and its LMO — falls to `tol` (0
    /// disables).  Unlike the async solvers this gap is exact for the
    /// round's minibatch: no staleness, the barrier saw every share.
    pub tol: f64,
    /// Step-size policy; non-vanilla selects eta by probe-minibatch line
    /// search on the master (away/pairwise rejected at spec validation).
    pub step: StepMethod,
}

/// Master side of Algorithm 1.  `master_engine` supplies the LMO (worker
/// engines only compute gradients).
///
/// Liveness caveat (inherited from the synchronous barrier, same as the
/// pre-comms thread implementation and MPI collectives): if one of
/// several workers dies mid-run, the round blocks waiting for its reply
/// — only the loss of ALL workers turns `recv` into a clean `None`.
/// Worker-failure detection/timeouts are a deliberate non-goal of
/// Algorithm 1; use the asynchronous solvers for crash tolerance.
pub(crate) fn run_dist_master<L: MasterLink<DistUp, DistDown> + ?Sized>(
    link: &mut L,
    obj: &Arc<dyn Objective>,
    opts: &DistOptions,
    master_engine: &mut dyn StepEngine,
    counters: &Counters,
    trace: &LossTrace,
    evaluator: &Evaluator,
) -> Iterate {
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let workers = link.workers();
    let n = obj.n();
    let mut x = Iterate::init_rank_one(opts.repr, d1, d2, theta, &mut Rng::new(opts.seed));
    evaluator.submit(trace.elapsed(), 0, f64::NAN, x.clone());
    let mut probe_rng = Rng::new(opts.seed ^ 0x9E37_79B9);
    let mut probe_idx: Vec<usize> = Vec::new();
    let mut grad = Mat::zeros(d1, d2);
    // Factored mode: atoms accepted since the last broadcast (0 or 1 in
    // lockstep; more only after all-corrupt skipped rounds) and the
    // entry counter workers replay against.
    let mut pending: Vec<LogEntry> = Vec::new();
    let mut t_log: u64 = 0;
    for k in 1..=opts.iterations {
        let m = opts.batch.m(k).max(workers);
        let m_share = (m / workers) as u32;
        match opts.repr {
            Repr::Dense => {
                // dense parameter broadcast: O(D1 D2) down per worker
                // (one snapshot per round; the local transport shares
                // it by Arc)
                let xa = Arc::new(x.to_dense());
                for w in 0..workers {
                    link.send_to(w, DistDown::Compute { k, m_share, x: xa.clone() });
                }
            }
            Repr::Factored => {
                // factored downlink: only the atoms the workers are
                // missing — O(D1 + D2) per round instead of O(D1 D2)
                let entries = std::mem::take(&mut pending);
                for w in 0..workers {
                    link.send_to(
                        w,
                        DistDown::ComputeFactored { k, m_share, entries: entries.clone() },
                    );
                }
            }
        }
        // barrier: wait for ALL workers (the straggler pays here); slot
        // replies by rank so the reduction order is deterministic.  A
        // reply with an out-of-range rank, the wrong round index, or a
        // rank that already answered this round (duplicated / reordered
        // frames under fault injection) is counted and skipped — never a
        // panic, and never folded into the wrong reduction.  Losing all
        // workers mid-round aborts the run gracefully with the progress
        // made so far.
        let mut replies: Vec<Option<Mat>> = (0..workers).map(|_| None).collect();
        let mut answered = vec![false; workers];
        let mut filled = 0usize;
        while filled < workers {
            let Some(up) = link.recv() else {
                eprintln!(
                    "sfw-dist: all workers lost mid-round {k}; aborting at t={}",
                    k - 1
                );
                evaluator.submit(trace.elapsed(), k - 1, f64::NAN, x.clone());
                return x;
            };
            let w = up.worker_id as usize;
            if w >= workers || up.k != k || answered[w] {
                eprintln!(
                    "sfw-dist: ignoring reply (rank {w}, round {} vs {k}, answered={})",
                    up.k,
                    *answered.get(w).unwrap_or(&false)
                );
                counters.add_dropped();
                continue;
            }
            answered[w] = true;
            filled += 1;
            // a corrupted gradient (wrong shape or non-finite entries)
            // must not poison the reduction: count it as a dropped
            // contribution and reduce without it
            let ok = up.grad.rows == d1
                && up.grad.cols == d2
                && up.grad.data.iter().all(|v| v.is_finite());
            if ok {
                replies[w] = Some(up.grad);
            } else {
                eprintln!("sfw-dist: discarding corrupt gradient from rank {w} in round {k}");
                counters.add_dropped();
            }
        }
        grad.fill(0.0);
        let mut contributed = 0usize;
        for g in replies.into_iter().flatten() {
            grad.axpy(1.0, &g);
            contributed += 1;
        }
        // every contribution corrupt (possible under fault injection):
        // an LMO on the zero matrix would hand back NaN vectors and
        // poison the iterate — skip the update, keep the round
        if contributed == 0 {
            eprintln!("sfw-dist: round {k} lost every gradient contribution; skipping update");
            counters.add_iteration();
            if k % opts.eval_every == 0 || k == opts.iterations {
                evaluator.submit(trace.elapsed(), k, f64::NAN, x.clone());
            }
            continue;
        }
        let s = master_engine.lmo(&grad);
        counters.add_lmo();
        counters.add_iteration();
        // Exact-for-this-round dual gap: `grad` is the SUM gradient over
        // the contributing workers' samples, so divide by their count.
        let round_m = contributed * m_share as usize;
        let gap = (x.inner_flat(&grad.data) + theta as f64 * s.sigma as f64)
            / round_m.max(1) as f64;
        let step_eta = if opts.step == StepMethod::Vanilla {
            eta(k)
        } else {
            let pm = round_m.clamp(1, n);
            probe_rng.sample_indices(n, pm, &mut probe_idx);
            let loss0 = obj.loss_batch_it(&x, &probe_idx);
            let slope0 = -(gap * pm as f64);
            select_eta(opts.step, k, loss0, slope0, 1.0, &mut |e| {
                let mut trial = x.clone();
                trial.fw_rank_one_update(e, -theta, &s.u, &s.v);
                obj.loss_batch_it(&trial, &probe_idx)
            })
        };
        let e = LogEntry {
            k: t_log + 1,
            eta: step_eta,
            scale: -theta,
            u: Arc::new(s.u),
            v: Arc::new(s.v),
        };
        x.apply_entry(&e);
        if opts.repr == Repr::Factored {
            t_log += 1;
            pending.push(e);
        }
        let stop = opts.tol > 0.0 && gap.is_finite() && gap <= opts.tol;
        if stop || k % opts.eval_every == 0 || k == opts.iterations {
            evaluator.submit(trace.elapsed(), k, gap, x.clone());
        }
        if stop {
            break;
        }
    }
    for w in 0..workers {
        link.send_to(w, DistDown::Stop);
    }
    x
}

/// Worker side of Algorithm 1: gradient rounds until Stop.  Handles both
/// downlink variants; in factored rounds it advances a local iterate by
/// replaying the broadcast atoms (idempotent, gap-tolerant) instead of
/// receiving X.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_dist_worker<L: WorkerLink<DistUp, DistDown> + ?Sized, E: StepEngine + ?Sized>(
    link: &mut L,
    engine: &mut E,
    worker_id: u32,
    seed: u64,
    straggler: Option<Straggler>,
    counters: &Counters,
    repr: Repr,
    uplink: GradCodec,
) {
    let obj = engine.objective().clone();
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let n = obj.n();
    let mut rng = Rng::new(seed ^ 0x5BC ^ (worker_id as u64) << 8);
    let mut idx: Vec<usize> = Vec::new();
    let mut g = Mat::zeros(d1, d2);
    // Local iterate from the shared-seed X_0 (same recipe as the
    // master's), advanced only by broadcast atoms.  Built lazily: a
    // dense-mode worker receives X itself and never needs one.
    let mut x_loc: Option<Iterate> = None;
    let mut t_w = 0u64;
    // Set once a slice is rejected: the delta protocol cannot resync a
    // worker that missed atoms (unlike the async catch-up protocol), so
    // a desynced worker must not keep shipping gradients of a stale X.
    let mut desynced = false;
    // Lossy-uplink residual carrier: compensate the fresh gradient with
    // last round's quantization error, ship, absorb the new error.
    // No-op under the exact f32 codec.
    let mut ef = ErrorFeedback::new(uplink.is_lossy());
    loop {
        match link.recv() {
            Some(DistDown::Compute { k, m_share, x }) => {
                rng.sample_indices(n, m_share as usize, &mut idx);
                let loss_sum = engine.grad_sum(&x, &idx, &mut g);
                counters.add_grad_evals(idx.len() as u64);
                if let Some(s) = &straggler {
                    s.sleep(&mut rng, idx.len() as u64);
                }
                // echo k so the barrier can match replies to rounds
                ef.compensate(&mut g);
                let up = DistUp::quantized(uplink, worker_id, k, loss_sum, g.clone());
                ef.absorb(&g, &up.grad);
                link.send(up);
            }
            Some(DistDown::ComputeFactored { k, m_share, entries }) => {
                let x_loc = x_loc.get_or_insert_with(|| {
                    Iterate::init_rank_one(repr, d1, d2, theta, &mut Rng::new(seed))
                });
                // a corrupted entry must not poison the persistent local
                // iterate: apply only slices that look like Eqn-6 steps
                let sane = entries.iter().all(|e| {
                    e.eta.is_finite()
                        && e.scale.is_finite()
                        && crate::coordinator::sane_rank_one(&e.u, &e.v, d1, d2)
                });
                if sane && !desynced {
                    t_w = replay_after(x_loc, &entries, t_w);
                    // replay must land exactly on the slice's last entry;
                    // falling short (a gap anywhere in the slice — e.g. a
                    // corrupted entry index, which the value gate above
                    // cannot see) means atoms were lost for good — same
                    // desync as a rejected slice
                    if entries.last().is_some_and(|e| t_w < e.k) {
                        desynced = true;
                    }
                } else if !desynced {
                    eprintln!(
                        "sfw-dist: worker {worker_id} rejecting corrupt atom slice in round {k}"
                    );
                    desynced = true;
                }
                if desynced {
                    // A stale-X gradient folded silently into the
                    // reduction would skew every remaining round; a
                    // non-finite one is dropped (and counted) by the
                    // master's corrupt-gradient gate while keeping the
                    // barrier live.
                    eprintln!(
                        "sfw-dist: worker {worker_id} desynced; sending poisoned reply \
                         for round {k} so the master drops this contribution"
                    );
                    g.fill(f32::NAN);
                    // poison round: skip compensate/absorb (a NaN
                    // residual would stick forever); the quantized
                    // constructor preserves NaN under every codec
                    link.send(DistUp::quantized(uplink, worker_id, k, 0.0, g.clone()));
                    continue;
                }
                rng.sample_indices(n, m_share as usize, &mut idx);
                let loss_sum = engine.grad_sum_it(x_loc, &idx, &mut g);
                counters.add_grad_evals(idx.len() as u64);
                if let Some(s) = &straggler {
                    s.sleep(&mut rng, idx.len() as u64);
                }
                ef.compensate(&mut g);
                let up = DistUp::quantized(uplink, worker_id, k, loss_sum, g.clone());
                ef.absorb(&g, &up.grad);
                link.send(up);
            }
            Some(DistDown::Stop) | None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::NativeEngine;
    use crate::comms::Wire;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::nuclear_norm;
    use crate::objective::MatrixSensing;
    use crate::session::harness;

    fn dist_obj(seed: u64) -> Arc<dyn Objective> {
        let mut rng = Rng::new(seed);
        let p = MsParams { d1: 10, d2: 10, rank: 2, n: 3_000, noise_std: 0.05 };
        Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0))
    }

    #[test]
    fn dist_converges_and_counts_dense_traffic() {
        let obj = dist_obj(110);
        let opts = DistOptions {
            iterations: 100,
            batch: BatchSchedule::sfw(2.0, 1_024),
            eval_every: 20,
            seed: 111,
            straggler: None,
            repr: Repr::Dense,
            uplink: GradCodec::F32,
            tol: 0.0,
            step: StepMethod::Vanilla,
        };
        let o2 = obj.clone();
        let r = harness::run_dist(obj, &opts, harness::TransportOpts::local(4), move |w| {
            Box::new(NativeEngine::new(o2.clone(), 60, 112u64.wrapping_add(w as u64)))
        });
        let pts = r.trace.points();
        assert!(pts.last().unwrap().loss < 0.4 * pts.first().unwrap().loss);
        assert!(nuclear_norm(&r.x) <= 1.0 + 1e-3);
        let s = r.counters.snapshot();
        assert_eq!(s.iterations, 100);
        assert_eq!(s.lmo_calls, 100); // master-side only
        // dense O(D1*D2) traffic each way, every round, every worker —
        // expected totals derived from the real frame sizes.
        let per_down =
            DistDown::Compute { k: 1, m_share: 1, x: Arc::new(Mat::zeros(10, 10)) }.wire_bytes();
        let per_up = DistUp::dense(0, 1, 0.0, Mat::zeros(10, 10)).wire_bytes();
        assert_eq!(s.bytes_down, 100 * 4 * per_down + 4 * DistDown::Stop.wire_bytes());
        assert_eq!(s.bytes_up, 100 * 4 * per_up);
        assert_eq!(s.msgs_up, 100 * 4);
        assert_eq!(s.msgs_down, 100 * 4 + 4);
        assert!(per_down >= 4 * 10 * 10 && per_up >= 4 * 10 * 10);
    }

    #[test]
    fn factored_dist_matches_dense_and_shrinks_downlink() {
        let obj = dist_obj(115);
        let run = |repr: Repr| {
            let opts = DistOptions {
                iterations: 40,
                batch: BatchSchedule::Constant(256),
                eval_every: 10,
                seed: 116,
                straggler: None,
                repr,
                uplink: GradCodec::F32,
                tol: 0.0,
                step: StepMethod::Vanilla,
            };
            let o2 = obj.clone();
            harness::run_dist(obj.clone(), &opts, harness::TransportOpts::local(2), move |w| {
                Box::new(NativeEngine::new(o2.clone(), 60, 117u64.wrapping_add(w as u64)))
            })
        };
        let dense = run(Repr::Dense);
        let fact = run(Repr::Factored);
        // same-seed agreement to f32 tolerance on the final iterate
        let mut diff = dense.x.clone();
        diff.axpy(-1.0, &fact.x);
        let rel = diff.frob_norm() / (1.0 + dense.x.frob_norm());
        assert!(rel < 1e-2, "dense vs factored diverged: rel {rel}");
        // the factored downlink is the paper-relevant win: measurably
        // below the dense broadcast (uplink unchanged: dense gradients)
        let (sd, sf) = (dense.counters.snapshot(), fact.counters.snapshot());
        assert!(
            sf.bytes_down * 2 < sd.bytes_down,
            "factored downlink {} not clearly below dense {}",
            sf.bytes_down,
            sd.bytes_down
        );
        assert_eq!(sf.msgs_down, sd.msgs_down);
        assert_eq!(sf.bytes_up, sd.bytes_up);
        // factored run reports its atom budget
        assert!(fact.peak_atoms > 0 && fact.rank > 0);
        assert_eq!(dense.peak_atoms, 0);
    }

    #[test]
    fn int8_uplink_with_error_feedback_tracks_f32_and_shrinks_uplink() {
        // Wide-ish dims so the per-row scale overhead amortizes: at
        // 12x24 the int8 uplink frame is (28+48+288) vs f32 (28+1152),
        // a >3x byte win the counters must reflect exactly.
        let mut rng = Rng::new(120);
        let p = MsParams { d1: 12, d2: 24, rank: 2, n: 3_000, noise_std: 0.05 };
        let obj: Arc<dyn Objective> =
            Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0));
        let run = |uplink: GradCodec| {
            let opts = DistOptions {
                iterations: 60,
                batch: BatchSchedule::Constant(256),
                eval_every: 10,
                seed: 121,
                straggler: None,
                repr: Repr::Dense,
                uplink,
                tol: 0.0,
                step: StepMethod::Vanilla,
            };
            let o2 = obj.clone();
            harness::run_dist(obj.clone(), &opts, harness::TransportOpts::local(2), move |w| {
                Box::new(NativeEngine::new(o2.clone(), 60, 122u64.wrapping_add(w as u64)))
            })
        };
        let exact = run(GradCodec::F32);
        let quant = run(GradCodec::Int8);
        // compressed run converges: same qualitative drop as f32, and
        // the finals agree to the pinned smoke tolerance
        let (pe, pq) = (exact.trace.points(), quant.trace.points());
        let (le, lq) = (pe.last().unwrap().loss, pq.last().unwrap().loss);
        assert!(lq < 0.5 * pq.first().unwrap().loss, "int8 run failed to converge: {lq}");
        assert!(
            (lq - le).abs() <= 0.2 * le + 1e-3,
            "int8 final loss {lq} drifted from f32 {le}"
        );
        // uplink bytes: exact closed-form ratio, >= 3x at these dims
        let (se, sq) = (exact.counters.snapshot(), quant.counters.snapshot());
        let per_f32 = DistUp::dense(0, 1, 0.0, Mat::zeros(12, 24)).wire_bytes();
        let per_i8 =
            DistUp::quantized(GradCodec::Int8, 0, 1, 0.0, Mat::zeros(12, 24)).wire_bytes();
        assert_eq!(se.bytes_up, 60 * 2 * per_f32);
        assert_eq!(sq.bytes_up, 60 * 2 * per_i8);
        assert!(se.bytes_up as f64 / sq.bytes_up as f64 >= 3.0);
        // downlink untouched by the uplink codec
        assert_eq!(se.bytes_down, sq.bytes_down);
    }
}
