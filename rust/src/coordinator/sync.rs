//! SFW-dist (Algorithm 1): the synchronous distributed baseline, now a
//! framed `(DistUp, DistDown)` protocol over the generic comms links.
//!
//! Per iteration the master broadcasts the dense iterate X — O(D1*D2)
//! bytes to each of W workers — each worker returns its dense partial
//! gradient — O(D1*D2) bytes again — and the master aggregates, solves
//! the LMO itself, and updates.  The barrier makes every iteration as
//! slow as the slowest worker; the links' byte accounting makes the
//! O(D1*D2) vs O(D1+D2) contrast measurable (comm_cost bench), and the
//! same master/worker loops run over in-process channels or real TCP
//! ([`crate::session::harness`] picks the transport).
//!
//! Replies are reduced in worker-rank order (not arrival order), so the
//! float summation — and therefore the whole run — is bit-identical
//! across transports for a fixed seed.

use std::sync::Arc;

use crate::algo::engine::StepEngine;
use crate::algo::schedule::{eta, BatchSchedule};
use crate::algo::sfw::init_rank_one;
use crate::comms::{MasterLink, WorkerLink};
use crate::coordinator::eval::Evaluator;
use crate::coordinator::messages::{DistDown, DistUp};
use crate::coordinator::worker::Straggler;
use crate::linalg::Mat;
use crate::metrics::{Counters, LossTrace};
use crate::objective::Objective;
use crate::util::rng::Rng;

pub struct DistOptions {
    pub iterations: u64,
    pub batch: BatchSchedule,
    pub eval_every: u64,
    pub seed: u64,
    pub straggler: Option<Straggler>,
}

/// Master side of Algorithm 1.  `master_engine` supplies the LMO (worker
/// engines only compute gradients).
///
/// Liveness caveat (inherited from the synchronous barrier, same as the
/// pre-comms thread implementation and MPI collectives): if one of
/// several workers dies mid-run, the round blocks waiting for its reply
/// — only the loss of ALL workers turns `recv` into a clean `None`.
/// Worker-failure detection/timeouts are a deliberate non-goal of
/// Algorithm 1; use the asynchronous solvers for crash tolerance.
pub(crate) fn run_dist_master<L: MasterLink<DistUp, DistDown> + ?Sized>(
    link: &mut L,
    obj: &Arc<dyn Objective>,
    opts: &DistOptions,
    master_engine: &mut dyn StepEngine,
    counters: &Counters,
    trace: &LossTrace,
    evaluator: &Evaluator,
) -> Mat {
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let workers = link.workers();
    let mut x = init_rank_one(d1, d2, theta, &mut Rng::new(opts.seed));
    evaluator.submit(trace.elapsed(), 0, x.clone());
    let mut grad = Mat::zeros(d1, d2);
    for k in 1..=opts.iterations {
        let m = opts.batch.m(k).max(workers);
        let m_share = (m / workers) as u32;
        let xa = Arc::new(x.clone());
        for w in 0..workers {
            // dense parameter broadcast: O(D1 D2) down per worker (one
            // snapshot per round; the local transport shares it by Arc)
            link.send_to(w, DistDown::Compute { k, m_share, x: xa.clone() });
        }
        // barrier: wait for ALL workers (the straggler pays here); slot
        // replies by rank so the reduction order is deterministic.  A
        // reply with an out-of-range rank, the wrong round index, or a
        // rank that already answered this round (duplicated / reordered
        // frames under fault injection) is counted and skipped — never a
        // panic, and never folded into the wrong reduction.  Losing all
        // workers mid-round aborts the run gracefully with the progress
        // made so far.
        let mut replies: Vec<Option<Mat>> = (0..workers).map(|_| None).collect();
        let mut answered = vec![false; workers];
        let mut filled = 0usize;
        while filled < workers {
            let Some(up) = link.recv() else {
                eprintln!(
                    "sfw-dist: all workers lost mid-round {k}; aborting at t={}",
                    k - 1
                );
                evaluator.submit(trace.elapsed(), k - 1, x.clone());
                return x;
            };
            let w = up.worker_id as usize;
            if w >= workers || up.k != k || answered[w] {
                eprintln!(
                    "sfw-dist: ignoring reply (rank {w}, round {} vs {k}, answered={})",
                    up.k,
                    *answered.get(w).unwrap_or(&false)
                );
                counters.add_dropped();
                continue;
            }
            answered[w] = true;
            filled += 1;
            // a corrupted gradient (wrong shape or non-finite entries)
            // must not poison the reduction: count it as a dropped
            // contribution and reduce without it
            let ok = up.grad.rows == d1
                && up.grad.cols == d2
                && up.grad.data.iter().all(|v| v.is_finite());
            if ok {
                replies[w] = Some(up.grad);
            } else {
                eprintln!("sfw-dist: discarding corrupt gradient from rank {w} in round {k}");
                counters.add_dropped();
            }
        }
        grad.fill(0.0);
        let mut contributed = false;
        for g in replies.into_iter().flatten() {
            grad.axpy(1.0, &g);
            contributed = true;
        }
        // every contribution corrupt (possible under fault injection):
        // an LMO on the zero matrix would hand back NaN vectors and
        // poison the iterate — skip the update, keep the round
        if !contributed {
            eprintln!("sfw-dist: round {k} lost every gradient contribution; skipping update");
            counters.add_iteration();
            if k % opts.eval_every == 0 || k == opts.iterations {
                evaluator.submit(trace.elapsed(), k, x.clone());
            }
            continue;
        }
        let s = master_engine.lmo(&grad);
        counters.add_lmo();
        counters.add_iteration();
        x.fw_rank_one_update(eta(k), -theta, &s.u, &s.v);
        if k % opts.eval_every == 0 || k == opts.iterations {
            evaluator.submit(trace.elapsed(), k, x.clone());
        }
    }
    for w in 0..workers {
        link.send_to(w, DistDown::Stop);
    }
    x
}

/// Worker side of Algorithm 1: gradient rounds until Stop.
pub(crate) fn run_dist_worker<L: WorkerLink<DistUp, DistDown> + ?Sized, E: StepEngine + ?Sized>(
    link: &mut L,
    engine: &mut E,
    worker_id: u32,
    seed: u64,
    straggler: Option<Straggler>,
    counters: &Counters,
) {
    let obj = engine.objective().clone();
    let (d1, d2) = obj.dims();
    let n = obj.n();
    let mut rng = Rng::new(seed ^ 0x5BC ^ (worker_id as u64) << 8);
    let mut idx: Vec<usize> = Vec::new();
    let mut g = Mat::zeros(d1, d2);
    loop {
        match link.recv() {
            Some(DistDown::Compute { k, m_share, x }) => {
                rng.sample_indices(n, m_share as usize, &mut idx);
                let loss_sum = engine.grad_sum(&x, &idx, &mut g);
                counters.add_grad_evals(idx.len() as u64);
                if let Some(s) = &straggler {
                    s.sleep(&mut rng, idx.len() as u64);
                }
                // echo k so the barrier can match replies to rounds
                link.send(DistUp { worker_id, k, loss_sum, grad: g.clone() });
            }
            Some(DistDown::Stop) | None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::NativeEngine;
    use crate::comms::Wire;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::nuclear_norm;
    use crate::objective::MatrixSensing;
    use crate::session::harness;

    #[test]
    fn dist_converges_and_counts_dense_traffic() {
        let mut rng = Rng::new(110);
        let p = MsParams { d1: 10, d2: 10, rank: 2, n: 3_000, noise_std: 0.05 };
        let obj: Arc<dyn Objective> =
            Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0));
        let opts = DistOptions {
            iterations: 100,
            batch: BatchSchedule::sfw(2.0, 1_024),
            eval_every: 20,
            seed: 111,
            straggler: None,
        };
        let o2 = obj.clone();
        let r = harness::run_dist(obj, &opts, harness::TransportOpts::local(4), move |w| {
            Box::new(NativeEngine::new(o2.clone(), 60, 112u64.wrapping_add(w as u64)))
        });
        let pts = r.trace.points();
        assert!(pts.last().unwrap().loss < 0.4 * pts.first().unwrap().loss);
        assert!(nuclear_norm(&r.x) <= 1.0 + 1e-3);
        let s = r.counters.snapshot();
        assert_eq!(s.iterations, 100);
        assert_eq!(s.lmo_calls, 100); // master-side only
        // dense O(D1*D2) traffic each way, every round, every worker —
        // expected totals derived from the real frame sizes.
        let per_down =
            DistDown::Compute { k: 1, m_share: 1, x: Arc::new(Mat::zeros(10, 10)) }.wire_bytes();
        let per_up =
            DistUp { worker_id: 0, k: 1, loss_sum: 0.0, grad: Mat::zeros(10, 10) }.wire_bytes();
        assert_eq!(s.bytes_down, 100 * 4 * per_down + 4 * DistDown::Stop.wire_bytes());
        assert_eq!(s.bytes_up, 100 * 4 * per_up);
        assert_eq!(s.msgs_up, 100 * 4);
        assert_eq!(s.msgs_down, 100 * 4 + 4);
        assert!(per_down >= 4 * 10 * 10 && per_up >= 4 * 10 * 10);
    }
}
