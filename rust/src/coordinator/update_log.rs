//! The master's rank-one update log and Eqn (6) replay.
//!
//! The log IS the model state on the wire: appending an accepted worker
//! update produces entry k with eta_k = 2/(k+1); any worker holding the
//! iterate X_{t} can reconstruct X_{t'} (t' > t) by replaying entries
//! t+1 ..= t', each a rank-one GER — O((t'-t)(D1+D2) * min(D1,D2))...
//! actually O((t'-t) * D1 * D2) compute but only O((t'-t)(D1+D2)) bytes,
//! which is the paper's entire communication saving.
//!
//! Replay is generic over [`ApplyEntry`], so it drives a dense [`Mat`]
//! (O(D1*D2) GER per entry) or an [`Iterate`] in factored form — where a
//! log entry is adopted as an atom outright (`Arc` clone, O(1)): the
//! catch-up replay and the factored iterate are literally one
//! representation.

use crate::algo::schedule::eta;
use crate::coordinator::messages::LogEntry;
use crate::linalg::{Iterate, Mat};
use std::sync::Arc;

/// Anything that can absorb one Eqn-6 log entry.
pub trait ApplyEntry {
    fn apply_entry(&mut self, e: &LogEntry);
}

impl ApplyEntry for Mat {
    fn apply_entry(&mut self, e: &LogEntry) {
        self.fw_rank_one_update(e.eta, e.scale, &e.u, &e.v);
    }
}

impl ApplyEntry for Iterate {
    fn apply_entry(&mut self, e: &LogEntry) {
        self.fw_update_arc(e.eta, e.scale, &e.u, &e.v);
    }
}

/// Append-only rank-one update log (entry k at index k-1).
#[derive(Default)]
pub struct UpdateLog {
    entries: Vec<LogEntry>,
}

impl UpdateLog {
    pub fn new() -> Self {
        UpdateLog { entries: Vec::new() }
    }

    /// Current master iteration t_m (number of accepted updates).
    pub fn t_m(&self) -> u64 {
        self.entries.len() as u64
    }

    /// Accept a worker update: creates entry k = t_m + 1 with the theorem
    /// step size eta_k = 2/(k+1) and scale = -theta.
    pub fn append(&mut self, u: Vec<f32>, v: Vec<f32>, theta: f32) -> &LogEntry {
        let k = self.t_m() + 1;
        let e = eta(k);
        self.append_custom(u, v, e, -theta)
    }

    /// Append with an explicit step size (SVRF-asyn restarts eta_k per
    /// epoch: eta is indexed by the INNER iteration, not the global one).
    pub fn append_custom(&mut self, u: Vec<f32>, v: Vec<f32>, eta: f32, scale: f32) -> &LogEntry {
        let k = self.t_m() + 1;
        let idx = self.entries.len();
        self.entries.push(LogEntry { k, eta, scale, u: Arc::new(u), v: Arc::new(v) });
        &self.entries[idx]
    }

    /// The catch-up slice a worker at iteration `t_w` needs to reach the
    /// current t_m: entries t_w+1 ..= t_m (cheap Arc clones).
    pub fn slice_from(&self, t_w: u64) -> Vec<LogEntry> {
        let from = t_w as usize;
        self.entries[from.min(self.entries.len())..].to_vec()
    }

    /// Entries in (t_a, t_b] for partial catch-ups.
    pub fn slice_between(&self, t_a: u64, t_b: u64) -> Vec<LogEntry> {
        let lo = (t_a as usize).min(self.entries.len());
        let hi = (t_b as usize).min(self.entries.len());
        self.entries[lo..hi].to_vec()
    }

    pub fn entry(&self, k: u64) -> Option<&LogEntry> {
        self.entries.get((k - 1) as usize)
    }
}

/// Replay Eqn (6) over `x` (which must be at iteration entries[0].k - 1):
/// X_k = (1 - eta_k) X_{k-1} + eta_k * scale_k * u_k v_k^T.
/// Returns the new iteration count.
pub fn replay<X: ApplyEntry + ?Sized>(x: &mut X, entries: &[LogEntry]) -> Option<u64> {
    let mut last = None;
    for e in entries {
        if let Some(prev) = last {
            debug_assert_eq!(e.k, prev + 1, "non-contiguous log slice");
        }
        x.apply_entry(e);
        last = Some(e.k);
    }
    last
}

/// Idempotent, gap-tolerant replay: apply only entries with k > `t_cur`
/// (a worker may receive overlapping slices around SVRF epoch
/// boundaries; applying an entry twice would corrupt the iterate), and
/// stop at the first gap (a slice cut from a point ahead of ours — a
/// corrupted sync-point claim echoed back; applying past the gap would
/// silently skip updates).  Returns the new iteration: unchanged when
/// the whole slice gapped, so the next exchange re-slices from the true
/// sync point and self-heals.
pub fn replay_after<X: ApplyEntry + ?Sized>(x: &mut X, entries: &[LogEntry], t_cur: u64) -> u64 {
    let mut t = t_cur;
    for e in entries {
        if e.k <= t {
            continue;
        }
        if e.k > t + 1 {
            break;
        }
        x.apply_entry(e);
        t = e.k;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::nuclear_norm;
    use crate::prop_assert;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn random_log(rng: &mut Rng, n: usize, d1: usize, d2: usize, theta: f32) -> UpdateLog {
        let mut log = UpdateLog::new();
        for _ in 0..n {
            let u = rng.unit_vector(d1);
            let v = rng.unit_vector(d2);
            log.append(u, v, theta);
        }
        log
    }

    #[test]
    fn append_assigns_sequential_k_and_eta() {
        let mut rng = Rng::new(80);
        let log = random_log(&mut rng, 5, 4, 3, 1.0);
        for k in 1..=5u64 {
            let e = log.entry(k).unwrap();
            assert_eq!(e.k, k);
            assert!((e.eta - 2.0 / (k as f32 + 1.0)).abs() < 1e-7);
            assert_eq!(e.scale, -1.0);
        }
        assert_eq!(log.t_m(), 5);
    }

    #[test]
    fn replay_full_log_equals_incremental_master_copy() {
        // Property: a worker replaying any suffix from its own t_w lands on
        // exactly the master's X (the correctness core of Algorithm 3).
        check("replay-suffix", 81, 30, |rng| {
            let d1 = 2 + rng.next_below(6);
            let d2 = 2 + rng.next_below(6);
            let n = 1 + rng.next_below(12);
            let theta = 1.0f32;
            let log = random_log(rng, n, d1, d2, theta);

            // master copy: applied entry-by-entry as they were accepted
            let mut master = crate::algo::init_rank_one(d1, d2, theta, &mut rng.fork(1));
            let x0 = master.clone();
            replay(&mut master, &log.slice_from(0));

            // worker stopped at random t_w, then catches up with the slice
            let t_w = rng.next_below(n + 1) as u64;
            let mut worker = x0.clone();
            replay(&mut worker, &log.slice_between(0, t_w));
            replay(&mut worker, &log.slice_from(t_w));

            let mut diff = worker.clone();
            diff.axpy(-1.0, &master);
            prop_assert!(
                diff.frob_norm() < 1e-5,
                "suffix replay diverged: {} (t_w={t_w}, n={n})",
                diff.frob_norm()
            );
            Ok(())
        });
    }

    #[test]
    fn replay_preserves_nuclear_ball() {
        // Every X_k is a convex combination of feasible points, so
        // ||X_k||_* <= theta for all k, whatever the update sequence.
        check("nuclear-feasible", 82, 20, |rng| {
            let theta = 1.0f32;
            let log = random_log(rng, 15, 6, 5, theta);
            let mut x = crate::algo::init_rank_one(6, 5, theta, &mut rng.fork(2));
            for k in 1..=15u64 {
                replay(&mut x, &log.slice_between(k - 1, k));
                let nn = nuclear_norm(&x);
                prop_assert!(nn <= theta as f64 + 1e-4, "||X_{k}||_* = {nn}");
            }
            Ok(())
        });
    }

    #[test]
    fn replay_after_refuses_gapped_slices() {
        // A slice cut from a point ahead of the worker's sync point (the
        // echo of a bit-corrupted t_w claim) must apply NOTHING: neither
        // the iterate nor t advances, so the next exchange re-slices
        // from the true sync point and self-heals.
        let mut rng = Rng::new(84);
        let log = random_log(&mut rng, 8, 3, 3, 1.0);
        let mut x = crate::algo::init_rank_one(3, 3, 1.0, &mut rng.fork(3));
        let before = x.clone();
        // worker is at t=2; slice starts at entry 6 — gap of 3
        let t = replay_after(&mut x, &log.slice_from(5), 2);
        assert_eq!(t, 2, "t advanced across a gap");
        assert_eq!(x.data, before.data, "iterate advanced across a gap");
        // the contiguous prefix of a partially-gapped slice still applies
        let mut y = before.clone();
        let t = replay_after(&mut y, &log.slice_from(2), 2);
        assert_eq!(t, 8);
    }

    #[test]
    fn factored_replay_matches_dense_replay() {
        // The factored iterate absorbs log entries as atoms; replaying
        // the same slice into a dense Mat and a factored Iterate must
        // land on the same matrix (to f32 round-off) — the "entries ARE
        // the atoms" unification.
        use crate::linalg::{Iterate, Repr};
        let mut rng = Rng::new(85);
        let log = random_log(&mut rng, 12, 5, 4, 1.0);
        let mut dense = crate::algo::init_rank_one(5, 4, 1.0, &mut Rng::new(86));
        let mut fact = Iterate::init_rank_one(Repr::Factored, 5, 4, 1.0, &mut Rng::new(86));
        replay(&mut dense, &log.slice_from(0));
        let t = replay_after(&mut fact, &log.slice_from(0), 0);
        assert_eq!(t, 12);
        let mut diff = fact.to_dense();
        diff.axpy(-1.0, &dense);
        assert!(diff.frob_norm() < 1e-5, "representations diverged: {}", diff.frob_norm());
        // atoms = init atom + 12 replayed entries, shared via Arc
        assert_eq!(fact.peak_atoms(), 13);
    }

    #[test]
    fn slices_partition_cleanly() {
        let mut rng = Rng::new(83);
        let log = random_log(&mut rng, 10, 3, 3, 1.0);
        let a = log.slice_between(0, 4);
        let b = log.slice_from(4);
        assert_eq!(a.len(), 4);
        assert_eq!(b.len(), 6);
        assert_eq!(a.last().unwrap().k + 1, b.first().unwrap().k);
        assert!(log.slice_from(10).is_empty());
        assert!(log.slice_from(99).is_empty());
    }
}
