//! Harness that wires master + workers over a transport and runs one
//! SFW-asyn training job end to end (threads for workers, caller thread
//! for the master — mirroring one MPI rank per process).

use std::sync::Arc;
use std::time::Duration;

use crate::algo::engine::StepEngine;
use crate::algo::schedule::BatchSchedule;
use crate::coordinator::eval::Evaluator;
use crate::coordinator::master::{run_master, MasterOptions};
use crate::coordinator::worker::{run_worker, Straggler, WorkerOptions};
use crate::linalg::Mat;
use crate::metrics::{Counters, LossTrace};
use crate::objective::Objective;
use crate::transport::local::local_links;


pub struct AsynOptions {
    pub iterations: u64,
    pub tau: u64,
    pub workers: usize,
    pub batch: BatchSchedule,
    pub eval_every: u64,
    pub seed: u64,
    pub straggler: Option<Straggler>,
    /// Injected one-way link latency for the local transport.
    pub link_latency: Option<Duration>,
}

impl Default for AsynOptions {
    fn default() -> Self {
        AsynOptions {
            iterations: 300,
            tau: 8,
            workers: 4,
            batch: BatchSchedule::sfw_asyn(0.5, 8, 10_000),
            eval_every: 10,
            seed: 42,
            straggler: None,
            link_latency: None,
        }
    }
}

pub struct RunResult {
    pub x: Mat,
    pub counters: Arc<Counters>,
    pub trace: Arc<LossTrace>,
}

/// Run SFW-asyn over the in-process transport.  `make_engine(w)` builds
/// worker w's compute engine (native math or a PJRT artifact executor).
pub fn run_asyn_local<F>(
    obj: Arc<dyn Objective>,
    opts: &AsynOptions,
    mut make_engine: F,
) -> RunResult
where
    F: FnMut(usize) -> Box<dyn StepEngine>,
{
    let counters = Arc::new(Counters::new());
    let trace = Arc::new(LossTrace::new());
    let (mut mlink, wlinks) = local_links(opts.workers, counters.clone(), opts.link_latency);
    let evaluator = Evaluator::new(obj.clone(), trace.clone());

    let mut handles = Vec::new();
    for (w, mut wlink) in wlinks.into_iter().enumerate() {
        let mut engine = make_engine(w);
        let counters = counters.clone();
        let wopts = WorkerOptions {
            worker_id: w as u32,
            batch: opts.batch.clone(),
            seed: opts.seed,
            straggler: opts.straggler,
        };
        handles.push(std::thread::spawn(move || {
            run_worker(&mut wlink, engine.as_mut(), &wopts, &counters);
        }));
    }

    let mopts = MasterOptions {
        iterations: opts.iterations,
        tau: opts.tau,
        eval_every: opts.eval_every,
        seed: opts.seed,
    };
    let x = run_master(&mut mlink, &obj, &mopts, &counters, &trace, &evaluator);
    for h in handles {
        let _ = h.join();
    }
    evaluator.finish();
    RunResult { x, counters, trace }
}

/// Run SFW-asyn over real localhost TCP sockets (same protocol, true
/// serialization + kernel queues).  Master binds an ephemeral port.
pub fn run_asyn_tcp<F>(
    obj: Arc<dyn Objective>,
    opts: &AsynOptions,
    mut make_engine: F,
) -> RunResult
where
    F: FnMut(usize) -> Box<dyn StepEngine>,
{
    use crate::transport::tcp::{tcp_master, tcp_worker};
    let counters = Arc::new(Counters::new());
    let trace = Arc::new(LossTrace::new());
    let evaluator = Evaluator::new(obj.clone(), trace.clone());

    // Bind first on an ephemeral port, then hand the resolved address to
    // the workers.
    let workers = opts.workers;
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let counters_m = counters.clone();
    let master_thread = {
        let obj = obj.clone();
        let trace = trace.clone();
        let mopts = MasterOptions {
            iterations: opts.iterations,
            tau: opts.tau,
            eval_every: opts.eval_every,
            seed: opts.seed,
        };
        std::thread::spawn(move || {
            // accept() inside tcp_master blocks until all workers connect;
            // publish the address before constructing it.
            let listener_addr = "127.0.0.1:0";
            let (mut mlink, addr) = {
                // Bind manually to learn the port before accepting.
                let l = std::net::TcpListener::bind(listener_addr).unwrap();
                let addr = l.local_addr().unwrap();
                drop(l); // tcp_master re-binds; tiny race acceptable on loopback
                addr_tx.send(addr).unwrap();
                let (m, a) = tcp_master(&addr.to_string(), workers, counters_m.clone()).unwrap();
                (m, a)
            };
            let _ = addr;
            let x = run_master(&mut mlink, &obj, &mopts, &counters_m, &trace, &evaluator);
            evaluator.finish();
            x
        })
    };
    let addr = addr_rx.recv().unwrap();
    // workers connect (retry briefly while master rebinds)
    let mut handles = Vec::new();
    for w in 0..opts.workers {
        let mut engine = make_engine(w);
        let counters = counters.clone();
        let wopts = WorkerOptions {
            worker_id: w as u32,
            batch: opts.batch.clone(),
            seed: opts.seed,
            straggler: opts.straggler,
        };
        handles.push(std::thread::spawn(move || {
            let mut link = {
                let mut tries = 0;
                loop {
                    match tcp_worker(&addr.to_string(), w as u32, counters.clone()) {
                        Ok(l) => break l,
                        Err(e) if tries < 50 => {
                            tries += 1;
                            std::thread::sleep(Duration::from_millis(20));
                            let _ = e;
                        }
                        Err(e) => panic!("worker {w} cannot connect: {e}"),
                    }
                }
            };
            run_worker(&mut link, engine.as_mut(), &wopts, &counters);
        }));
    }
    let x = master_thread.join().unwrap();
    for h in handles {
        let _ = h.join();
    }
    RunResult { x, counters, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::NativeEngine;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::nuclear_norm;
    use crate::objective::MatrixSensing;
    use crate::util::rng::Rng;

    fn obj(seed: u64) -> Arc<dyn Objective> {
        let mut rng = Rng::new(seed);
        let p = MsParams { d1: 10, d2: 10, rank: 2, n: 3_000, noise_std: 0.05 };
        Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0))
    }

    #[test]
    fn asyn_local_converges_with_multiple_workers() {
        let obj = obj(95);
        let opts = AsynOptions {
            iterations: 150,
            tau: 8,
            workers: 4,
            batch: BatchSchedule::sfw_asyn(2.0, 8, 1_024),
            eval_every: 15,
            seed: 96,
            straggler: None,
            link_latency: None,
        };
        let o2 = obj.clone();
        let r = run_asyn_local(obj, &opts, move |w| {
            Box::new(NativeEngine::new(o2.clone(), 60, 97 + w as u64))
        });
        let pts = r.trace.points();
        assert!(pts.len() >= 2);
        let first = pts.first().unwrap().loss;
        let last = pts.last().unwrap().loss;
        assert!(last < 0.4 * first, "no progress: {first} -> {last}");
        assert!(nuclear_norm(&r.x) <= 1.0 + 1e-3);
        let s = r.counters.snapshot();
        assert_eq!(s.iterations, 150);
        // every accepted update = one up message; drops add more
        assert!(s.msgs_up >= 150);
        // comm stays rank-one sized: strictly less than one dense gradient
        // per master iteration
        let dense = (10 * 10 * 4) as u64;
        assert!(s.bytes_up < s.msgs_up * dense);
    }

    #[test]
    fn asyn_respects_delay_gate() {
        // tau = 0 with many workers forces drops: iterations still reach T
        // and dropped counter is visible.
        let obj = obj(98);
        let opts = AsynOptions {
            iterations: 60,
            tau: 0,
            workers: 4,
            batch: BatchSchedule::Constant(32),
            eval_every: 30,
            seed: 99,
            straggler: None,
            link_latency: None,
        };
        let o2 = obj.clone();
        let r = run_asyn_local(obj, &opts, move |w| {
            Box::new(NativeEngine::new(o2.clone(), 30, 100 + w as u64))
        });
        let s = r.counters.snapshot();
        assert_eq!(s.iterations, 60);
        assert!(s.dropped_updates > 0, "tau=0 with 4 workers must drop");
    }
}
