//! SFW-asyn protocol options and the raw run result.
//!
//! Training runs start from [`crate::session::TrainSpec`]; the harness
//! that wires master + workers over a transport lives in
//! `sfw::session::harness` with the transport as a spec field.  This
//! module keeps the protocol-level types that harness and solvers share:
//!
//! ```no_run
//! use sfw::session::{TaskSpec, TrainSpec, Transport};
//! let r = TrainSpec::new(TaskSpec::ms_small())
//!     .algo("sfw-asyn")
//!     .transport(Transport::Tcp)
//!     .run()
//!     .unwrap();
//! ```
//!
//! (Run-scale knobs — worker count, transport, injected link latency —
//! are not protocol options: they live in the harness's
//! `TransportOpts`, built from the spec.)

use std::sync::Arc;

use crate::algo::schedule::{BatchSchedule, StepMethod};
use crate::chaos::ChaosCounters;
use crate::comms::GradCodec;
use crate::coordinator::worker::Straggler;
use crate::linalg::{Mat, Repr};
use crate::metrics::{Counters, LossTrace};

pub struct AsynOptions {
    pub iterations: u64,
    pub tau: u64,
    pub batch: BatchSchedule,
    pub eval_every: u64,
    pub seed: u64,
    pub straggler: Option<Straggler>,
    /// Iterate representation shared by master and workers.
    pub repr: Repr,
    /// Uplink codec for the rank-one `{u, v}` updates.
    pub uplink: GradCodec,
    /// Dual-gap stopping tolerance (0 disables); the master stops on the
    /// uplinked worker gap.
    pub tol: f64,
    /// Step-size policy (non-vanilla = master-side probe line search).
    pub step: StepMethod,
}

impl Default for AsynOptions {
    fn default() -> Self {
        AsynOptions {
            iterations: 300,
            tau: 8,
            batch: BatchSchedule::sfw_asyn(0.5, 8, 10_000),
            eval_every: 10,
            seed: 42,
            straggler: None,
            repr: Repr::Dense,
            uplink: GradCodec::F32,
            tol: 0.0,
            step: StepMethod::Vanilla,
        }
    }
}

pub struct RunResult {
    pub x: Mat,
    /// Final-iterate rank (atom count in factored mode; numerical rank
    /// or dimension bound in dense mode — see `Iterate::rank`).
    pub rank: usize,
    /// Peak atom count held by the master's iterate (0 in dense mode).
    pub peak_atoms: usize,
    pub counters: Arc<Counters>,
    pub trace: Arc<LossTrace>,
    /// Injected-fault accounting (all zeros when no
    /// [`FaultPlan`](crate::chaos::FaultPlan) was installed).
    pub chaos: Arc<ChaosCounters>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::engine::NativeEngine;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::nuclear_norm;
    use crate::objective::{MatrixSensing, Objective};
    use crate::session::harness::{self, TransportOpts};
    use crate::util::rng::Rng;

    fn obj(seed: u64) -> Arc<dyn Objective> {
        let mut rng = Rng::new(seed);
        let p = MsParams { d1: 10, d2: 10, rank: 2, n: 3_000, noise_std: 0.05 };
        Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0))
    }

    #[test]
    fn asyn_local_converges_with_multiple_workers() {
        let obj = obj(95);
        let opts = AsynOptions {
            iterations: 150,
            tau: 8,
            batch: BatchSchedule::sfw_asyn(2.0, 8, 1_024),
            eval_every: 15,
            seed: 96,
            straggler: None,
            repr: Repr::Dense,
            uplink: GradCodec::F32,
            ..AsynOptions::default()
        };
        let o2 = obj.clone();
        let r = harness::run_asyn(obj, &opts, TransportOpts::local(4), move |w| {
            Box::new(NativeEngine::new(o2.clone(), 60, 97 + w as u64))
        });
        let pts = r.trace.points();
        assert!(pts.len() >= 2);
        let first = pts.first().unwrap().loss;
        let last = pts.last().unwrap().loss;
        assert!(last < 0.4 * first, "no progress: {first} -> {last}");
        assert!(nuclear_norm(&r.x) <= 1.0 + 1e-3);
        let s = r.counters.snapshot();
        assert_eq!(s.iterations, 150);
        // every accepted update = one up message; drops add more
        assert!(s.msgs_up >= 150);
        // comm stays rank-one sized: strictly less than one dense gradient
        // per master iteration
        let dense = (10 * 10 * 4) as u64;
        assert!(s.bytes_up < s.msgs_up * dense);
    }

    #[test]
    fn asyn_respects_delay_gate() {
        // tau = 0 with many workers forces drops: iterations still reach T
        // and dropped counter is visible.
        let obj = obj(98);
        let opts = AsynOptions {
            iterations: 60,
            tau: 0,
            batch: BatchSchedule::Constant(32),
            eval_every: 30,
            seed: 99,
            straggler: None,
            repr: Repr::Dense,
            uplink: GradCodec::F32,
            ..AsynOptions::default()
        };
        let o2 = obj.clone();
        let r = harness::run_asyn(obj, &opts, TransportOpts::local(4), move |w| {
            Box::new(NativeEngine::new(o2.clone(), 30, 100 + w as u64))
        });
        let s = r.counters.snapshot();
        assert_eq!(s.iterations, 60);
        assert!(s.dropped_updates > 0, "tau=0 with 4 workers must drop");
    }
}
