//! SFW-asyn run entry points — **deprecated shims**.
//!
//! The harness that wires master + workers over a transport moved to
//! `sfw::session` (one implementation, transport as a spec field); prefer
//!
//! ```no_run
//! use sfw::session::{TaskSpec, TrainSpec, Transport};
//! let r = TrainSpec::new(TaskSpec::ms_small())
//!     .algo("sfw-asyn")
//!     .transport(Transport::Tcp)
//!     .run()
//!     .unwrap();
//! ```
//!
//! These wrappers are kept for one release for downstream callers that
//! still hold an [`AsynOptions`] + engine closure.

use std::sync::Arc;
use std::time::Duration;

use crate::algo::engine::StepEngine;
use crate::algo::schedule::BatchSchedule;
use crate::coordinator::worker::Straggler;
use crate::linalg::Mat;
use crate::metrics::{Counters, LossTrace};
use crate::objective::Objective;
use crate::session::Transport;

pub struct AsynOptions {
    pub iterations: u64,
    pub tau: u64,
    pub workers: usize,
    pub batch: BatchSchedule,
    pub eval_every: u64,
    pub seed: u64,
    pub straggler: Option<Straggler>,
    /// Injected one-way link latency for the local transport.
    pub link_latency: Option<Duration>,
}

impl Default for AsynOptions {
    fn default() -> Self {
        AsynOptions {
            iterations: 300,
            tau: 8,
            workers: 4,
            batch: BatchSchedule::sfw_asyn(0.5, 8, 10_000),
            eval_every: 10,
            seed: 42,
            straggler: None,
            link_latency: None,
        }
    }
}

pub struct RunResult {
    pub x: Mat,
    pub counters: Arc<Counters>,
    pub trace: Arc<LossTrace>,
}

/// Run SFW-asyn over the in-process transport.  `make_engine(w)` builds
/// worker w's compute engine (native math or a PJRT artifact executor).
#[deprecated(since = "0.2.0", note = "use sfw::session::TrainSpec with .algo(\"sfw-asyn\")")]
pub fn run_asyn_local<F>(
    obj: Arc<dyn Objective>,
    opts: &AsynOptions,
    make_engine: F,
) -> RunResult
where
    F: FnMut(usize) -> Box<dyn StepEngine>,
{
    crate::session::harness::run_asyn(obj, opts, Transport::Local, make_engine)
}

/// Run SFW-asyn over real localhost TCP sockets (same protocol, true
/// serialization + kernel queues).  Master binds an ephemeral port.
#[deprecated(
    since = "0.2.0",
    note = "use sfw::session::TrainSpec with .algo(\"sfw-asyn\").transport(Transport::Tcp)"
)]
pub fn run_asyn_tcp<F>(
    obj: Arc<dyn Objective>,
    opts: &AsynOptions,
    make_engine: F,
) -> RunResult
where
    F: FnMut(usize) -> Box<dyn StepEngine>,
{
    crate::session::harness::run_asyn(obj, opts, Transport::Tcp, make_engine)
}

#[cfg(test)]
#[allow(deprecated)] // exercises the back-compat shims on purpose
mod tests {
    use super::*;
    use crate::algo::engine::NativeEngine;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::nuclear_norm;
    use crate::objective::MatrixSensing;
    use crate::util::rng::Rng;

    fn obj(seed: u64) -> Arc<dyn Objective> {
        let mut rng = Rng::new(seed);
        let p = MsParams { d1: 10, d2: 10, rank: 2, n: 3_000, noise_std: 0.05 };
        Arc::new(MatrixSensing::new(MatrixSensingData::generate(&p, &mut rng), 1.0))
    }

    #[test]
    fn asyn_local_converges_with_multiple_workers() {
        let obj = obj(95);
        let opts = AsynOptions {
            iterations: 150,
            tau: 8,
            workers: 4,
            batch: BatchSchedule::sfw_asyn(2.0, 8, 1_024),
            eval_every: 15,
            seed: 96,
            straggler: None,
            link_latency: None,
        };
        let o2 = obj.clone();
        let r = run_asyn_local(obj, &opts, move |w| {
            Box::new(NativeEngine::new(o2.clone(), 60, 97 + w as u64))
        });
        let pts = r.trace.points();
        assert!(pts.len() >= 2);
        let first = pts.first().unwrap().loss;
        let last = pts.last().unwrap().loss;
        assert!(last < 0.4 * first, "no progress: {first} -> {last}");
        assert!(nuclear_norm(&r.x) <= 1.0 + 1e-3);
        let s = r.counters.snapshot();
        assert_eq!(s.iterations, 150);
        // every accepted update = one up message; drops add more
        assert!(s.msgs_up >= 150);
        // comm stays rank-one sized: strictly less than one dense gradient
        // per master iteration
        let dense = (10 * 10 * 4) as u64;
        assert!(s.bytes_up < s.msgs_up * dense);
    }

    #[test]
    fn asyn_respects_delay_gate() {
        // tau = 0 with many workers forces drops: iterations still reach T
        // and dropped counter is visible.
        let obj = obj(98);
        let opts = AsynOptions {
            iterations: 60,
            tau: 0,
            workers: 4,
            batch: BatchSchedule::Constant(32),
            eval_every: 30,
            seed: 99,
            straggler: None,
            link_latency: None,
        };
        let o2 = obj.clone();
        let r = run_asyn_local(obj, &opts, move |w| {
            Box::new(NativeEngine::new(o2.clone(), 30, 100 + w as u64))
        });
        let s = r.counters.snapshot();
        assert_eq!(s.iterations, 60);
        assert!(s.dropped_updates > 0, "tau=0 with 4 workers must drop");
    }
}
