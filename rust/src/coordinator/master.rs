//! The SFW-asyn master loop (Algorithm 3, lines 1–13) — the paper's
//! system contribution.
//!
//! The master never waits for stragglers: it blocks on *any* worker's
//! `{u, v, t_w}` message, gates it on bounded staleness
//! (`t_m - t_w > tau` => drop, but still ship the catch-up slice so the
//! straggler resynchronizes), appends accepted updates to the rank-one
//! log, and replies with exactly the log entries the sender is missing.
//! The model copy (dense or factored, per `MasterOptions::repr`) is
//! maintained out of the reply path and snapshotted to the off-thread
//! evaluator ("not run in real time; maintain a copy for output only" —
//! Alg 3 line 12); in factored mode the copy adopts the log entries as
//! atoms, so log and iterate are one representation.

use std::sync::Arc;

use crate::algo::schedule::{eta, select_eta, StepMethod};
use crate::comms::MasterLink;
use crate::coordinator::eval::Evaluator;
use crate::coordinator::messages::{MasterMsg, UpdateMsg};
use crate::coordinator::update_log::{ApplyEntry, UpdateLog};
use crate::linalg::{Iterate, Repr};
use crate::metrics::{Counters, LossTrace};
use crate::objective::Objective;
use crate::util::rng::Rng;

pub struct MasterOptions {
    /// Max master iterations T.
    pub iterations: u64,
    /// Max delay tolerance tau.
    pub tau: u64,
    /// Snapshot X to the evaluator every this many accepted updates.
    pub eval_every: u64,
    /// Seed shared with the workers: X_0 = init_rank_one(seed) on both
    /// sides, standing in for the paper's initial {u_0, v_0} broadcast.
    pub seed: u64,
    /// Iterate representation of the master's model copy.  In factored
    /// mode the copy shares the update log's atom `Arc`s — the log IS
    /// the iterate.
    pub repr: Repr,
    /// Stop once an ACCEPTED update's dual-gap estimate falls to `tol`
    /// (0 disables).  The gap rides the uplink: it is the minibatch FW
    /// gap at the sending worker's boundedly-stale iterate — the same
    /// quantity the serial solvers stop on, delayed by at most tau steps.
    pub tol: f64,
    /// Step-size policy for accepted updates.  Non-vanilla policies run a
    /// master-side stochastic line search: the master samples its own
    /// probe minibatch and evaluates candidate steps along the worker's
    /// atom (gradient-free, loss evaluations only).  Away/pairwise need a
    /// serial active set and are rejected at spec validation.
    pub step: StepMethod,
}

/// Run the master until T accepted updates, then stop all workers.
/// Returns the final iterate X_T.
pub fn run_master<L: MasterLink<UpdateMsg, MasterMsg> + ?Sized>(
    link: &mut L,
    obj: &Arc<dyn Objective>,
    opts: &MasterOptions,
    counters: &Counters,
    trace: &LossTrace,
    evaluator: &Evaluator,
) -> Iterate {
    let (d1, d2) = obj.dims();
    let theta = obj.theta();
    let n = obj.n();
    let mut log = UpdateLog::new();
    let mut x = Iterate::init_rank_one(opts.repr, d1, d2, theta, &mut Rng::new(opts.seed));
    // Probe sampler for master-side step policies — forked off the shared
    // seed so it never collides with any worker's index stream.
    let mut probe_rng = Rng::new(opts.seed ^ 0x9E37_79B9);
    let mut probe_idx: Vec<usize> = Vec::new();
    evaluator.submit(trace.elapsed(), 0, f64::NAN, x.clone());

    while log.t_m() < opts.iterations {
        let Some(upd) = link.recv() else { break };
        let w = upd.worker_id as usize;
        // an out-of-range rank (corrupt or misconfigured external
        // worker) must not index the link's reply table
        if w >= link.workers() {
            eprintln!("sfw-asyn: ignoring update with bad worker id {}", upd.worker_id);
            continue;
        }
        let t_m = log.t_m();
        // The claimed sync point is the worker's true iterate version —
        // the quantity Thm 1's delay gate is about — so it is what gets
        // gated and sliced on, even though a bit flip can mangle it.  A
        // FUTURE claim would wrap the delay subtraction and cannot be
        // sliced for: reject it, but still REPLY (empty catch-up) —
        // the sender is a rank-addressed worker blocked on this reply,
        // and silence would wedge its ping-pong loop (fatal with a
        // single worker).  An in-range corrupted claim at worst
        // misjudges one gate decision and produces a gapped slice,
        // which the worker's gap-tolerant `replay_after` refuses to
        // apply — its next, honest claim self-heals.
        if upd.t_w > t_m {
            eprintln!(
                "sfw-asyn: rejecting update claiming future iterate (t_w={} > t_m={t_m})",
                upd.t_w
            );
            counters.add_dropped();
            link.send_to(w, MasterMsg::Updates { t_m, entries: Vec::new() });
            continue;
        }
        // corrupted-but-decodable update vectors (wrong dims, NaN, wild
        // norms) are counted, skipped and the sender resynchronized —
        // never appended to the log, never a panic
        if !crate::coordinator::sane_rank_one(&upd.u, &upd.v, d1, d2) {
            eprintln!("sfw-asyn: discarding corrupt update from worker {w}");
            counters.add_dropped();
            link.send_to(w, MasterMsg::Updates { t_m, entries: log.slice_from(upd.t_w) });
            continue;
        }
        let delay = t_m - upd.t_w;
        if delay > opts.tau {
            // Alg 3 line 7: drop, but resynchronize the straggler.
            counters.add_dropped();
            link.send_to(w, MasterMsg::Updates { t_m, entries: log.slice_from(upd.t_w) });
            continue;
        }
        counters.note_accepted_delay(delay);
        let k = log.t_m() + 1;
        let step_eta = if opts.step == StepMethod::Vanilla {
            eta(k)
        } else {
            // Stochastic line search along the worker's atom: probe
            // minibatch of the update's own size, phi in batch-SUM units,
            // slope seeded from the uplinked (mean) gap times m.
            let m = (upd.m as usize).clamp(1, n);
            probe_rng.sample_indices(n, m, &mut probe_idx);
            let loss0 = obj.loss_batch_it(&x, &probe_idx);
            let slope0 = -(upd.gap * m as f64);
            select_eta(opts.step, k, loss0, slope0, 1.0, &mut |e| {
                let mut trial = x.clone();
                trial.fw_rank_one_update(e, -theta, &upd.u, &upd.v);
                obj.loss_batch_it(&trial, &probe_idx)
            })
        };
        let gap = upd.gap;
        let e = log.append_custom(upd.u, upd.v, step_eta, -theta);
        x.apply_entry(e);
        counters.add_iteration();
        let t_m = log.t_m();
        link.send_to(w, MasterMsg::Updates { t_m, entries: log.slice_from(upd.t_w) });
        let stop = opts.tol > 0.0 && gap.is_finite() && gap <= opts.tol;
        if stop || t_m % opts.eval_every == 0 || t_m == opts.iterations {
            evaluator.submit(trace.elapsed(), t_m, gap, x.clone());
        }
        if stop {
            break;
        }
    }
    for w in 0..link.workers() {
        link.send_to(w, MasterMsg::Stop);
    }
    x
}
