//! Off-thread objective evaluation.
//!
//! Full-objective evaluation is a pass over all N samples — orders of
//! magnitude more work than one master iteration.  Algorithm 3's master
//! keeps its model copy "not run in real time ... for output only"; we
//! honor that by snapshotting the iterate with its wall-clock timestamp
//! and shipping it to a dedicated evaluator thread, so the loss curves of
//! Figures 4–7 are timestamped at snapshot time and the hot loop never
//! pays for an evaluation.  Snapshots are [`Iterate`]s: a dense snapshot
//! is one D1*D2 memcpy, a factored snapshot is an O(k) atom-list clone
//! (`Arc`'d factors) — another place the factored representation pays.

use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::linalg::Iterate;
use crate::metrics::LossTrace;
use crate::objective::Objective;

pub struct Evaluator {
    tx: Option<Sender<(f64, u64, f64, Iterate)>>,
    handle: Option<JoinHandle<()>>,
}

impl Evaluator {
    pub fn new(obj: Arc<dyn Objective>, trace: Arc<LossTrace>) -> Self {
        // lint: allow(bounded-channel-depth): depth <= iterations/eval_every
        // — deliberately unbounded so a slow loss_full never backpressures
        // the solver loop; snapshots are O(k) atom clones, not dense copies
        let (tx, rx) = channel::<(f64, u64, f64, Iterate)>();
        let handle = std::thread::spawn(move || {
            for (t, k, gap, x) in rx {
                let loss = obj.loss_full_it(&x);
                trace.record_at_gap(t, k, loss, gap);
            }
        });
        Evaluator { tx: Some(tx), handle: Some(handle) }
    }

    /// Submit a snapshot taken at time `t` (seconds since trace start),
    /// carrying the dual-gap estimate in hand at snapshot time (NaN when
    /// the submitting loop has none — e.g. the t=0 init point).
    pub fn submit(&self, t: f64, k: u64, gap: f64, x: Iterate) {
        if let Some(tx) = &self.tx {
            let _ = tx.send((t, k, gap, x));
        }
    }

    /// Drain the queue and join the thread.
    pub fn finish(mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Evaluator {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix_sensing::{MatrixSensingData, MsParams};
    use crate::linalg::Mat;
    use crate::objective::MatrixSensing;
    use crate::util::rng::Rng;

    #[test]
    fn evaluator_records_at_submitted_timestamps() {
        let mut rng = Rng::new(90);
        let p = MsParams { d1: 4, d2: 4, rank: 1, n: 100, noise_std: 0.1 };
        let obj: Arc<dyn Objective> = Arc::new(MatrixSensing::new(
            MatrixSensingData::generate(&p, &mut rng),
            1.0,
        ));
        let trace = Arc::new(LossTrace::new());
        let ev = Evaluator::new(obj.clone(), trace.clone());
        let x = Mat::zeros(4, 4);
        ev.submit(1.5, 10, f64::NAN, Iterate::Dense(x.clone()));
        ev.submit(2.5, 20, 0.125, Iterate::Dense(x.clone()));
        ev.finish();
        let pts = trace.points();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].t, 1.5);
        assert_eq!(pts[1].iteration, 20);
        assert!(pts[0].gap.is_nan());
        assert_eq!(pts[1].gap, 0.125);
        assert!((pts[0].loss - obj.loss_full(&x)).abs() < 1e-12);
    }
}
